"""L1 Pallas kernel: grouped routed-expert SwiGLU.

This is the MoE hot path the serving engine calls once per layer: the
rust dispatcher gathers each routed expert's tokens into a fixed
`capacity` block (padding unused slots), and this kernel runs every
expert's SwiGLU in one launch:

    xs  [n_experts, capacity, d]
    Wg  [n_experts, d, m]        (m = expert size, d_h / N)
    Wu  [n_experts, d, m]
    Wd  [n_experts, m, d]
    ->  [n_experts, capacity, d]

The grid iterates experts × capacity tiles; BlockSpec pins one expert's
weight panel in VMEM while its token tile streams through — the same
schedule GPU MoE kernels express with one threadblock per expert, which
is the hardware adaptation (batched-einsum MXU form instead of a loop
of small GEMMs); docs/ARCHITECTURE.md's L1 row maps it into the stack.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_C = 128


def _experts_kernel(xs_ref, wg_ref, wu_ref, wd_ref, o_ref):
    x = xs_ref[0]  # [bc, d]
    wg = wg_ref[0]  # [d, m]
    wu = wu_ref[0]
    wd = wd_ref[0]  # [m, d]
    h = jax.nn.silu(x @ wg) * (x @ wu)
    o_ref[0] = h @ wd


@functools.partial(jax.jit, static_argnames=("block_c",))
def routed_experts(xs, w_gate, w_up, w_down, block_c: int = BLOCK_C):
    """Batched per-expert SwiGLU over gathered token blocks."""
    n_e, cap, d = xs.shape
    m = w_gate.shape[2]
    bc = min(block_c, cap)
    if cap % bc != 0:
        bc = cap
    grid = (n_e, cap // bc)
    return pl.pallas_call(
        _experts_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, d), lambda e, c: (e, c, 0)),
            pl.BlockSpec((1, d, m), lambda e, c: (e, 0, 0)),
            pl.BlockSpec((1, d, m), lambda e, c: (e, 0, 0)),
            pl.BlockSpec((1, m, d), lambda e, c: (e, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bc, d), lambda e, c: (e, c, 0)),
        out_shape=jax.ShapeDtypeStruct((n_e, cap, d), xs.dtype),
        interpret=True,
    )(xs, w_gate, w_up, w_down)
