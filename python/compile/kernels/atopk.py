"""L1 Pallas kernel: ATopK activation mask (paper §A.2, Eq. 14).

Marks, per token, the top-`k` hidden activations by magnitude. Used by
the `ffn_hidden`/profiling artifacts so the rust profiler can consume a
ready-made binary activation matrix.

Threshold form: a position is active iff |h| >= k-th largest |h| of its
row (ties at the threshold may over-mark — the rust profiler and the
oracle use the same rule, so all three layers agree bit-for-bit).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _atopk_kernel(h_ref, o_ref, *, k: int):
    h = jnp.abs(h_ref[...])
    thresh = jnp.sort(h, axis=-1)[:, -k]
    o_ref[...] = (h >= thresh[:, None]).astype(h_ref.dtype)


@functools.partial(jax.jit, static_argnames=("k", "block_q"))
def atopk_mask(h, k: int, block_q: int = 128):
    """Binary mask [q, d_h] of each row's top-k |activations|."""
    q, d_h = h.shape
    assert 1 <= k <= d_h, f"k={k} out of range for d_h={d_h}"
    bq = min(block_q, q)
    if q % bq != 0:
        bq = q
    return pl.pallas_call(
        functools.partial(_atopk_kernel, k=k),
        grid=(q // bq,),
        in_specs=[pl.BlockSpec((bq, d_h), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bq, d_h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((q, d_h), h.dtype),
        interpret=True,
    )(h)
