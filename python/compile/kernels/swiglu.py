"""L1 Pallas kernel: tiled SwiGLU FFN.

The FFN `y = (Swish(x·Wg) ⊙ (x·Wu)) · Wd` is the paper's compute
hot-spot — it is what CMoE sparsifies. The kernel tiles the hidden
dimension `d_h` so each grid step streams one (Wg, Wu, Wd) column block
through VMEM and accumulates its rank-`bdh` contribution into the
output block:

    grid = (q_tiles, dh_tiles)
    x     [bq, d]    — revisited across dh tiles (stays in VMEM)
    Wg/Wu [d, bdh]   — one hidden block per step
    Wd    [bdh, d]
    y     [bq, d]    — accumulated in place across the dh axis

TPU mapping (docs/ARCHITECTURE.md, L1 kernels): with d=128, bdh=128,
f32, the working set
is bq·d + 3·d·bdh + bq·d ≈ 200 KiB ≪ 16 MiB VMEM; the MXU sees
[bq,128]×[128,128] matmuls — full systolic tiles. On this CPU testbed
the kernel MUST run under interpret=True (Mosaic custom-calls cannot
execute on the CPU PJRT plugin); numerics are identical.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes, MXU-shaped. Shrunk automatically for small inputs.
BLOCK_Q = 128
BLOCK_DH = 128


def _swiglu_kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref):
    j = pl.program_id(1)
    x = x_ref[...]
    h = jax.nn.silu(x @ wg_ref[...]) * (x @ wu_ref[...])
    y = h @ wd_ref[...]

    @pl.when(j == 0)
    def _init():
        o_ref[...] = y

    @pl.when(j > 0)
    def _acc():
        o_ref[...] += y


@jax.custom_vjp
def swiglu_ffn(x, w_gate, w_up, w_down):
    """Tiled SwiGLU FFN (Pallas forward, analytic backward). [q, d_out]."""
    return _swiglu_ffn_fwd_only(x, w_gate, w_up, w_down)


def _swiglu_vjp_fwd(x, w_gate, w_up, w_down):
    y = _swiglu_ffn_fwd_only(x, w_gate, w_up, w_down)
    return y, (x, w_gate, w_up, w_down)


def _swiglu_vjp_bwd(res, dy):
    # analytic SwiGLU backward (the kernel has no interpret-mode AD rule)
    x, w_gate, w_up, w_down = res
    g = x @ w_gate
    u = x @ w_up
    sig = jax.nn.sigmoid(g)
    s = g * sig
    h = s * u
    dh = dy @ w_down.T
    d_wd = h.T @ dy
    du = dh * s
    dg = dh * u * (sig * (1.0 + g * (1.0 - sig)))
    dx = dg @ w_gate.T + du @ w_up.T
    d_wg = x.T @ dg
    d_wu = x.T @ du
    return dx, d_wg, d_wu, d_wd


swiglu_ffn.defvjp(_swiglu_vjp_fwd, _swiglu_vjp_bwd)


@functools.partial(jax.jit, static_argnames=("block_q", "block_dh"))
def _swiglu_ffn_fwd_only(x, w_gate, w_up, w_down, block_q: int = BLOCK_Q, block_dh: int = BLOCK_DH):
    q, d = x.shape
    d_h = w_gate.shape[1]
    d_out = w_down.shape[1]
    bq = min(block_q, q)
    bdh = min(block_dh, d_h)
    # pallas needs exact tiling; fall back to one tile on ragged shapes
    if q % bq != 0:
        bq = q
    if d_h % bdh != 0:
        bdh = d_h
    grid = (q // bq, d_h // bdh)
    return pl.pallas_call(
        _swiglu_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, bdh), lambda i, j: (0, j)),
            pl.BlockSpec((d, bdh), lambda i, j: (0, j)),
            pl.BlockSpec((bdh, d_out), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, d_out), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((q, d_out), x.dtype),
        interpret=True,
    )(x, w_gate, w_up, w_down)


def _hidden_kernel(x_ref, wg_ref, wu_ref, o_ref):
    x = x_ref[...]
    o_ref[...] = jax.nn.silu(x @ wg_ref[...]) * (x @ wu_ref[...])


@functools.partial(jax.jit, static_argnames=("block_q", "block_dh"))
def swiglu_hidden(x, w_gate, w_up, block_q: int = BLOCK_Q, block_dh: int = BLOCK_DH):
    """Hidden states H = Swish(x·Wg) ⊙ (x·Wu) (profiling path). [q, d_h]."""
    q, d = x.shape
    d_h = w_gate.shape[1]
    bq = min(block_q, q)
    bdh = min(block_dh, d_h)
    if q % bq != 0:
        bq = q
    if d_h % bdh != 0:
        bdh = d_h
    grid = (q // bq, d_h // bdh)
    return pl.pallas_call(
        _hidden_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, bdh), lambda i, j: (0, j)),
            pl.BlockSpec((d, bdh), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bq, bdh), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((q, d_h), x.dtype),
        interpret=True,
    )(x, w_gate, w_up)
