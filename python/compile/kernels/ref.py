"""Pure-jnp reference oracles for every Pallas kernel.

These are the correctness ground truth: pytest sweeps shapes/dtypes with
hypothesis and asserts the kernels match these to float tolerance. They
are also what the L2 model calls when CMOE_NO_PALLAS=1 (debug escape
hatch); the AOT build always uses the kernels.
"""

import jax
import jax.numpy as jnp


def swiglu_hidden_ref(x, w_gate, w_up):
    """H = Swish(x @ Wg) * (x @ Wu)   (paper Eq. 13)."""
    return jax.nn.silu(x @ w_gate) * (x @ w_up)


def swiglu_ffn_ref(x, w_gate, w_up, w_down):
    """F(x) = H @ Wd   (paper Eq. 3)."""
    return swiglu_hidden_ref(x, w_gate, w_up) @ w_down


def routed_experts_ref(xs, w_gate, w_up, w_down):
    """Per-expert SwiGLU over gathered token blocks.

    xs:      [n_experts, capacity, d]
    w_gate:  [n_experts, d, m]
    w_up:    [n_experts, d, m]
    w_down:  [n_experts, m, d]
    returns  [n_experts, capacity, d]
    """
    h = jax.nn.silu(jnp.einsum("ecd,edm->ecm", xs, w_gate)) * jnp.einsum(
        "ecd,edm->ecm", xs, w_up
    )
    return jnp.einsum("ecm,emd->ecd", h, w_down)


def atopk_mask_ref(h, k):
    """ATopK activation mask (paper Eq. 14), threshold form.

    A position is active iff |h| >= (k-th largest |h| in its row).
    With ties at the threshold this can mark more than k positions;
    both kernel and oracle use the same rule so they agree exactly.
    """
    a = jnp.abs(h)
    thresh = jnp.sort(a, axis=-1)[..., -k]
    return (a >= thresh[..., None]).astype(jnp.float32)
