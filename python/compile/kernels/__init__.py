"""Pallas kernels (L1) + pure-jnp oracles."""

from . import ref
from .atopk import atopk_mask
from .experts import routed_experts
from .swiglu import swiglu_ffn, swiglu_hidden

__all__ = ["ref", "atopk_mask", "routed_experts", "swiglu_ffn", "swiglu_hidden"]
