"""L2: the JAX transformer (build-time only — never on the request path).

Architecture mirrors the rust reference forward bit-for-bit
(`rust/src/eval/forward.rs`): byte-vocab embedding + learned absolute
positions, pre-RMSNorm (eps 1e-6) attention and SwiGLU FFN blocks with
residuals, final RMSNorm, untied unembedding. The FFN runs through the
L1 Pallas kernels so they lower into the same HLO the rust runtime
executes.

Parameter names match the `.cmw` tensor names exactly (see
`rust/src/model/format.rs`), e.g. ``layers.0.attn.wq``.
"""

import functools
import os

import jax
import jax.numpy as jnp

from .kernels import ref, routed_experts, swiglu_ffn, swiglu_hidden

# Debug escape hatch: route FFN through the pure-jnp oracle instead of
# the Pallas kernels (artifact builds always use the kernels).
_NO_PALLAS = os.environ.get("CMOE_NO_PALLAS") == "1"

MODEL_ZOO = {
    # name: (vocab, d_model, n_layers, n_heads, d_ff, max_seq) — keep in
    # sync with rust/src/model/zoo.rs
    "tiny": (256, 64, 2, 4, 256, 128),
    "small": (256, 128, 4, 4, 512, 256),
    "base": (256, 256, 6, 8, 1024, 256),
}


def config(name):
    vocab, d_model, n_layers, n_heads, d_ff, max_seq = MODEL_ZOO[name]
    return dict(
        name=name,
        vocab=vocab,
        d_model=d_model,
        n_layers=n_layers,
        n_heads=n_heads,
        d_ff=d_ff,
        max_seq=max_seq,
    )


def init_params(cfg, key):
    """Initialize a dense model as a flat {name: array} dict."""
    d, dh, v = cfg["d_model"], cfg["d_ff"], cfg["vocab"]
    std_p = (1.0 / d) ** 0.5
    keys = iter(jax.random.split(key, 6 + 7 * cfg["n_layers"]))
    p = {
        "embed": jax.random.normal(next(keys), (v, d)) * 0.02,
        "pos": jax.random.normal(next(keys), (cfg["max_seq"], d)) * 0.02,
        "final_norm": jnp.ones((d,)),
        "unembed": jax.random.normal(next(keys), (d, v)) * std_p,
    }
    for l in range(cfg["n_layers"]):
        pre = f"layers.{l}"
        p[f"{pre}.attn_norm"] = jnp.ones((d,))
        p[f"{pre}.ffn_norm"] = jnp.ones((d,))
        for w in ("wq", "wk", "wv", "wo"):
            p[f"{pre}.attn.{w}"] = jax.random.normal(next(keys), (d, d)) * std_p
        p[f"{pre}.ffn.w_gate"] = jax.random.normal(next(keys), (d, dh)) * std_p
        p[f"{pre}.ffn.w_up"] = jax.random.normal(next(keys), (d, dh)) * std_p
        p[f"{pre}.ffn.w_down"] = jax.random.normal(next(keys), (dh, d)) * std_p
    return p


def rmsnorm(x, g, eps=1e-6):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * g


def _ffn(x2d, w_gate, w_up, w_down):
    if _NO_PALLAS:
        return ref.swiglu_ffn_ref(x2d, w_gate, w_up, w_down)
    return swiglu_ffn(x2d, w_gate, w_up, w_down)


def _attention(x, wq, wk, wv, wo, n_heads, mask):
    """Batched causal attention. x: [B, S, d]; mask: [S, T] additive."""
    b, s, d = x.shape
    hd = d // n_heads
    q = (x @ wq).reshape(b, s, n_heads, hd)
    k = (x @ wk).reshape(b, s, n_heads, hd)
    v = (x @ wv).reshape(b, s, n_heads, hd)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / (hd**0.5)
    scores = scores + mask[None, None, :, :]
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhqk,bkhd->bqhd", probs, v).reshape(b, s, d)
    return ctx @ wo


def _attention_kv(x, kv_k, kv_v, wq, wk, wv, wo, n_heads, pos):
    """One decode step with a static-size KV cache.

    x:      [B, d]        current token's hidden state
    kv_k/v: [B, H, T, hd] cache (only positions < pos are valid)
    pos:    i32 [B] — per-ROW write/attend position, so rows of one
            batch may sit at different KV depths (continuous batching:
            slots admitted at different times decode together). A
            scalar is also accepted (all rows at the same depth — the
            wave path / legacy artifacts).
    Returns (out [B, d], new_kv_k, new_kv_v).
    """
    b, d = x.shape
    t = kv_k.shape[2]
    hd = d // n_heads
    q = (x @ wq).reshape(b, n_heads, hd)
    k_new = (x @ wk).reshape(b, n_heads, hd)
    v_new = (x @ wv).reshape(b, n_heads, hd)
    pos = jnp.asarray(pos)
    if pos.ndim == 0:
        kv_k = jax.lax.dynamic_update_slice(kv_k, k_new[:, :, None, :], (0, 0, pos, 0))
        kv_v = jax.lax.dynamic_update_slice(kv_v, v_new[:, :, None, :], (0, 0, pos, 0))
        valid = jnp.arange(t)[None, None, :] <= pos
    else:
        # per-row scatter: row i writes its new K/V at pos[i] and
        # attends positions <= pos[i]
        rows = jnp.arange(b)[:, None]
        heads = jnp.arange(n_heads)[None, :]
        kv_k = kv_k.at[rows, heads, pos[:, None], :].set(k_new)
        kv_v = kv_v.at[rows, heads, pos[:, None], :].set(v_new)
        valid = jnp.arange(t)[None, None, :] <= pos[:, None, None]
    scores = jnp.einsum("bhd,bhtd->bht", q, kv_k) / (hd**0.5)
    scores = jnp.where(valid, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bht,bhtd->bhd", probs, kv_v).reshape(b, d)
    return ctx @ wo, kv_k, kv_v


# ---------------------------------------------------------------------------
# Dense model
# ---------------------------------------------------------------------------


def prefill(params, tokens, cfg, kv_len):
    """Prefill `tokens: [B, S]` → (logits [B, S, V], kv [L, 2, B, H, kv_len, hd]).

    The KV cache is allocated at `kv_len >= S` so decode can append.
    """
    b, s = tokens.shape
    d = cfg["d_model"]
    n_heads = cfg["n_heads"]
    hd = d // n_heads
    n_layers = cfg["n_layers"]
    x = params["embed"][tokens] + params["pos"][:s][None, :, :]
    mask = jnp.where(jnp.arange(s)[:, None] >= jnp.arange(s)[None, :], 0.0, -1e30)
    # PERF L2-1: build per-layer caches and stack once (avoids L×2
    # whole-cache copies from incremental .at[].set updates)
    kv_layers = []
    pad = kv_len - s
    for l in range(n_layers):
        pre = f"layers.{l}"
        xn = rmsnorm(x, params[f"{pre}.attn_norm"])
        # recompute k/v for the cache (same projections)
        k = (xn @ params[f"{pre}.attn.wk"]).reshape(b, s, n_heads, hd)
        v = (xn @ params[f"{pre}.attn.wv"]).reshape(b, s, n_heads, hd)
        kt = jnp.pad(k.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, pad), (0, 0)))
        vt = jnp.pad(v.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, pad), (0, 0)))
        kv_layers.append(jnp.stack([kt, vt]))
        x = x + _attention(
            xn,
            params[f"{pre}.attn.wq"],
            params[f"{pre}.attn.wk"],
            params[f"{pre}.attn.wv"],
            params[f"{pre}.attn.wo"],
            n_heads,
            mask,
        )
        xn = rmsnorm(x, params[f"{pre}.ffn_norm"])
        y = _ffn(
            xn.reshape(b * s, d),
            params[f"{pre}.ffn.w_gate"],
            params[f"{pre}.ffn.w_up"],
            params[f"{pre}.ffn.w_down"],
        ).reshape(b, s, d)
        x = x + y
    logits = rmsnorm(x, params["final_norm"]) @ params["unembed"]
    return logits, jnp.stack(kv_layers)


def _prefill_cont_body(params, tokens, kv, start, cfg, layer_ffn):
    """Shared body of the suffix-continuation prefill artifacts.

    tokens: [B, S] — row i holds prompt positions start[i]..start[i]+S
    kv:     [L, 2, B, H, T, hd] — existing cache; positions < start[i]
            must hold the prefix's K/V (mapped from the prefix cache or
            written by earlier chunks)
    start:  i32 [B] per-row global position of the row's first token

    Each row computes exactly its S tokens at their true positions:
    embeddings index `pos` at start+j, new K/V scatter into the cache
    at start+j (the per-row scatter idiom of `_attention_kv`), and
    attention sees the merged cache under the causal rule "key position
    p visible to query j iff p <= start+j" — so cached prefix K/V and
    same-call earlier tokens are both attended, identically to a
    monolithic prefill of the whole prompt. Masked positions underflow
    to exactly 0 after softmax, so the extra (invisible) cache columns
    cannot perturb the logits: chunked output is bit-identical to
    monolithic.

    `layer_ffn(l, x2d)` supplies the FFN (dense or masked-MoE).
    Returns (logits [B, S, V], new kv).
    """
    b, s = tokens.shape
    d = cfg["d_model"]
    n_heads = cfg["n_heads"]
    hd = d // n_heads
    t = kv.shape[4]
    start = jnp.asarray(start)
    pos = start[:, None] + jnp.arange(s)[None, :]  # [B, S] global positions
    x = params["embed"][tokens] + params["pos"][pos]
    rows = jnp.arange(b)[:, None, None]
    heads = jnp.arange(n_heads)[None, :, None]
    pcols = pos[:, None, :]
    valid = jnp.arange(t)[None, None, None, :] <= pos[:, None, :, None]  # [B,1,S,T]
    new_kv = []  # PERF L2-1: stack once (see decode_step)
    for l in range(cfg["n_layers"]):
        pre = f"layers.{l}"
        xn = rmsnorm(x, params[f"{pre}.attn_norm"])
        q = (xn @ params[f"{pre}.attn.wq"]).reshape(b, s, n_heads, hd).transpose(0, 2, 1, 3)
        k = (xn @ params[f"{pre}.attn.wk"]).reshape(b, s, n_heads, hd).transpose(0, 2, 1, 3)
        v = (xn @ params[f"{pre}.attn.wv"]).reshape(b, s, n_heads, hd).transpose(0, 2, 1, 3)
        kv_k = kv[l, 0].at[rows, heads, pcols, :].set(k)
        kv_v = kv[l, 1].at[rows, heads, pcols, :].set(v)
        scores = jnp.einsum("bhqd,bhtd->bhqt", q, kv_k) / (hd**0.5)
        scores = jnp.where(valid, scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhqt,bhtd->bhqd", probs, kv_v)
        x = x + ctx.transpose(0, 2, 1, 3).reshape(b, s, d) @ params[f"{pre}.attn.wo"]
        xn = rmsnorm(x, params[f"{pre}.ffn_norm"])
        x = x + layer_ffn(l, xn.reshape(b * s, d)).reshape(b, s, d)
        new_kv.append(jnp.stack([kv_k, kv_v]))
    logits = rmsnorm(x, params["final_norm"]) @ params["unembed"]
    return logits, jnp.stack(new_kv)


def prefill_cont(params, tokens, kv, start, cfg):
    """Dense suffix-continuation prefill (see `_prefill_cont_body`)."""

    def layer_ffn(l, x2d):
        pre = f"layers.{l}"
        return _ffn(
            x2d,
            params[f"{pre}.ffn.w_gate"],
            params[f"{pre}.ffn.w_up"],
            params[f"{pre}.ffn.w_down"],
        )

    return _prefill_cont_body(params, tokens, kv, start, cfg, layer_ffn)


def decode_step(params, token, kv, pos, cfg):
    """One decode step.

    token: [B] i32; kv: [L, 2, B, H, T, hd]; pos: i32 [B] per-row
    positions (scalar also accepted — see `_attention_kv`).
    Returns (logits [B, V], new kv).
    """
    b = token.shape[0]
    d = cfg["d_model"]
    n_heads = cfg["n_heads"]
    x = params["embed"][token] + params["pos"][pos]
    # PERF L2-1 (docs/ARCHITECTURE.md): collect per-layer caches and
    # stack ONCE at the end — `kv.at[l].set(...)` per layer materializes
    # a full-cache copy per layer (8 × 134 MB at b32/t256), which
    # dominated the dense decode step.
    new_kv = []
    for l in range(cfg["n_layers"]):
        pre = f"layers.{l}"
        xn = rmsnorm(x, params[f"{pre}.attn_norm"])
        out, kk, vv = _attention_kv(
            xn,
            kv[l, 0],
            kv[l, 1],
            params[f"{pre}.attn.wq"],
            params[f"{pre}.attn.wk"],
            params[f"{pre}.attn.wv"],
            params[f"{pre}.attn.wo"],
            n_heads,
            pos,
        )
        new_kv.append(jnp.stack([kk, vv]))
        x = x + out
        xn = rmsnorm(x, params[f"{pre}.ffn_norm"])
        x = x + _ffn(
            xn,
            params[f"{pre}.ffn.w_gate"],
            params[f"{pre}.ffn.w_up"],
            params[f"{pre}.ffn.w_down"],
        )
    logits = rmsnorm(x, params["final_norm"]) @ params["unembed"]
    return logits, jnp.stack(new_kv)


# ---------------------------------------------------------------------------
# MoE building blocks (monolithic in-graph routing, Eq. 4/8/9)
# ---------------------------------------------------------------------------


def moe_ffn_masked(x2d, shared_w, expert_w, router_w, gate_scale, gate_bias, n_k):
    """Masked MoE FFN for a flat batch `x2d: [q, d]`.

    shared_w:  (w_gate [d, sh], w_up, w_down [sh, d])
    expert_w:  (w_gate [Nr, d, m], w_up, w_down [Nr, m, d])
    router_w:  (w_gate_r [d, Nr], w_up_r [d, Nr])
    Computes all experts and masks by the top-`n_k` gate (no FLOP saving
    — this is the 1-call correctness/eval path; the serving engine's
    grouped dispatch realizes the savings).
    """
    q, d = x2d.shape
    sw_g, sw_u, sw_d = shared_w
    ew_g, ew_u, ew_d = expert_w
    rw_g, rw_u = router_w
    n_r = ew_g.shape[0]

    out = _ffn(x2d, sw_g, sw_u, sw_d) if sw_g.shape[1] > 0 else jnp.zeros_like(x2d)

    scores = ref.swiglu_hidden_ref(x2d, rw_g, rw_u)  # [q, Nr]
    sp = jax.nn.softmax(scores, axis=-1)
    ranked = sp + gate_bias[None, :]
    # top-N_k via sort threshold — lax.top_k lowers to a `topk` HLO
    # attribute that xla_extension 0.5.1's text parser rejects; with
    # continuous scores the >=-threshold rule selects exactly N_k.
    thresh = jnp.sort(ranked, axis=-1)[:, -n_k]
    selected = ranked >= thresh[:, None]
    gates = jnp.where(selected, 1.0 + sp * gate_scale[None, :], 0.0)

    if _NO_PALLAS:
        ys = ref.routed_experts_ref(jnp.broadcast_to(x2d, (n_r, q, d)), ew_g, ew_u, ew_d)
    else:
        ys = routed_experts(jnp.broadcast_to(x2d, (n_r, q, d)), ew_g, ew_u, ew_d)
    return out + jnp.einsum("eqd,qe->qd", ys, gates)


def moe_prefill(params, moe_params, tokens, cfg, kv_len, n_k):
    """Prefill with every FFN replaced by the masked MoE layer."""
    b, s = tokens.shape
    d = cfg["d_model"]
    n_heads = cfg["n_heads"]
    hd = d // n_heads
    n_layers = cfg["n_layers"]
    x = params["embed"][tokens] + params["pos"][:s][None, :, :]
    mask = jnp.where(jnp.arange(s)[:, None] >= jnp.arange(s)[None, :], 0.0, -1e30)
    # PERF L2-1: build per-layer caches and stack once (avoids L×2
    # whole-cache copies from incremental .at[].set updates)
    kv_layers = []
    pad = kv_len - s
    for l in range(n_layers):
        pre = f"layers.{l}"
        xn = rmsnorm(x, params[f"{pre}.attn_norm"])
        k = (xn @ params[f"{pre}.attn.wk"]).reshape(b, s, n_heads, hd)
        v = (xn @ params[f"{pre}.attn.wv"]).reshape(b, s, n_heads, hd)
        kt = jnp.pad(k.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, pad), (0, 0)))
        vt = jnp.pad(v.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, pad), (0, 0)))
        kv_layers.append(jnp.stack([kt, vt]))
        x = x + _attention(
            xn,
            params[f"{pre}.attn.wq"],
            params[f"{pre}.attn.wk"],
            params[f"{pre}.attn.wv"],
            params[f"{pre}.attn.wo"],
            n_heads,
            mask,
        )
        xn = rmsnorm(x, params[f"{pre}.ffn_norm"])
        mp = moe_params[l]
        y = moe_ffn_masked(
            xn.reshape(b * s, d),
            mp["shared"],
            mp["experts"],
            mp["router"],
            mp["scale"],
            mp["bias"],
            n_k,
        ).reshape(b, s, d)
        x = x + y
    logits = rmsnorm(x, params["final_norm"]) @ params["unembed"]
    return logits, jnp.stack(kv_layers)


def moe_prefill_cont(params, moe_params, tokens, kv, start, cfg, n_k):
    """Masked-MoE suffix-continuation prefill (see `_prefill_cont_body`)."""

    def layer_ffn(l, x2d):
        mp = moe_params[l]
        return moe_ffn_masked(
            x2d, mp["shared"], mp["experts"], mp["router"], mp["scale"], mp["bias"], n_k
        )

    return _prefill_cont_body(params, tokens, kv, start, cfg, layer_ffn)


def moe_decode_step(params, moe_params, token, kv, pos, cfg, n_k):
    """Decode step with every FFN replaced by the masked MoE layer.

    moe_params[l] = dict(shared=(g,u,d), experts=(g,u,d), router=(g,u),
    scale, bias). `pos` is i32 [B] per-row (scalar accepted).
    """
    b = token.shape[0]
    n_heads = cfg["n_heads"]
    x = params["embed"][token] + params["pos"][pos]
    new_kv = []  # PERF L2-1: stack once (see decode_step)
    for l in range(cfg["n_layers"]):
        pre = f"layers.{l}"
        xn = rmsnorm(x, params[f"{pre}.attn_norm"])
        out, kk, vv = _attention_kv(
            xn,
            kv[l, 0],
            kv[l, 1],
            params[f"{pre}.attn.wq"],
            params[f"{pre}.attn.wk"],
            params[f"{pre}.attn.wv"],
            params[f"{pre}.attn.wo"],
            n_heads,
            pos,
        )
        new_kv.append(jnp.stack([kk, vv]))
        x = x + out
        xn = rmsnorm(x, params[f"{pre}.ffn_norm"])
        mp = moe_params[l]
        x = x + moe_ffn_masked(
            xn, mp["shared"], mp["experts"], mp["router"], mp["scale"], mp["bias"], n_k
        )
    logits = rmsnorm(x, params["final_norm"]) @ params["unembed"]
    return logits, jnp.stack(new_kv)


# ---------------------------------------------------------------------------
# Pieces for rust-orchestrated MoE serving (one call per stage)
# ---------------------------------------------------------------------------


def embed_tokens(params, token, pos):
    """[B] → [B, d]. `pos` i32 [B] (per-row) or scalar — numpy
    indexing broadcasts either way."""
    return params["embed"][token] + params["pos"][pos]


def attn_layer(x, kv_layer, wq, wk, wv, wo, attn_norm, pos, n_heads):
    """Pre-norm attention block with residual for ONE layer.

    x: [B, d]; kv_layer: [2, B, H, T, hd]. Returns (x', new kv_layer).
    """
    xn = rmsnorm(x, attn_norm)
    out, kk, vv = _attention_kv(xn, kv_layer[0], kv_layer[1], wq, wk, wv, wo, n_heads, pos)
    return x + out, jnp.stack([kk, vv])


def ffn_norm_apply(x, g):
    """The FFN pre-norm (rust adds the residual after expert dispatch)."""
    return rmsnorm(x, g)


def router_scores(x2d, rw_g, rw_u):
    """Analytical router scores (Eq. 8)."""
    return ref.swiglu_hidden_ref(x2d, rw_g, rw_u)


def attn_moe_pre(
    x, kv_layer, wq, wk, wv, wo, attn_norm, ffn_norm, rw_g, rw_u, sw_g, sw_u, sw_d, pos, n_heads
):
    """PERF L3-1: the fused per-layer "pre" step for orchestrated MoE —
    attention + residual, FFN pre-norm, router scores and the shared
    expert in ONE artifact call (replaces attn → rmsnorm → router →
    shared_ffn, saving 3 executes + 2 uploads + 1 download per layer).

    Returns (x' [B,d], new kv_layer, xn [B,d], scores [B,Nr],
    shared_y [B,d]); rust gathers expert blocks from xn and finishes
    with the grouped-experts kernel.
    """
    xn = rmsnorm(x, attn_norm)
    out, kk, vv = _attention_kv(xn, kv_layer[0], kv_layer[1], wq, wk, wv, wo, n_heads, pos)
    x = x + out
    xn = rmsnorm(x, ffn_norm)
    scores = ref.swiglu_hidden_ref(xn, rw_g, rw_u)
    if sw_g.shape[1] > 0:
        shared_y = _ffn(xn, sw_g, sw_u, sw_d)
    else:
        shared_y = jnp.zeros_like(x)
    return x, jnp.stack([kk, vv]), xn, scores, shared_y


def final_logits(x, final_norm, unembed):
    return rmsnorm(x, final_norm) @ unembed


# ---------------------------------------------------------------------------
# Training (used by pretrain.py only)
# ---------------------------------------------------------------------------


def loss_fn(params, tokens, cfg):
    """Mean next-token cross-entropy over [B, S] token batches."""
    logits, _ = prefill(params, tokens, cfg, kv_len=tokens.shape[1])
    logp = jax.nn.log_softmax(logits[:, :-1, :], axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


@functools.partial(jax.jit, static_argnames=("cfg_name", "lr"))
def adam_step(params, m, v, t, tokens, cfg_name, lr=1e-3):
    cfg = config(cfg_name)
    loss, grads = jax.value_and_grad(loss_fn)(params, tokens, cfg)
    b1, b2, eps = 0.9, 0.95, 1e-8
    t = t + 1
    new_params, new_m, new_v = {}, {}, {}
    for k in params:
        g = grads[k]
        new_m[k] = b1 * m[k] + (1 - b1) * g
        new_v[k] = b2 * v[k] + (1 - b2) * g * g
        mh = new_m[k] / (1 - b1**t)
        vh = new_v[k] / (1 - b2**t)
        new_params[k] = params[k] - lr * mh / (jnp.sqrt(vh) + eps)
    return new_params, new_m, new_v, t, loss
