"""AOT lowering: JAX/Pallas → HLO text artifacts + manifest.

Every artifact is a jitted function lowered ONCE and written as HLO
*text* (xla_extension 0.5.1 rejects jax>=0.5's 64-bit-id serialized
protos — see /opt/xla-example/README.md). Weights are ARGUMENTS, never
baked in, so one artifact serves any checkpoint of matching shape; the
manifest records the exact argument order for the rust runtime.

Decode-family artifacts (decode_*, attn_layer, attn_moe_pre, embed)
take per-ROW positions `pos: i32[B]` so rows of one batch may sit at
different KV depths — the ABI the rust engine's continuous in-flight
batching requires (slots admitted at different times decode together).
The wave path passes the same position for every row.

Artifact families (per model config):
  prefill_dense_{m}_b{B}_s{S}_t{T}   tokens → logits + KV cache
  prefill_cont_dense_{m}_b{B}_s{S}_t{T}
                                     suffix-continuation prefill: S
                                     tokens per row at per-row global
                                     positions `start: i32[B]` against
                                     an existing KV cache (prefix-cache
                                     hits and chunked prefill; MoE
                                     variant prefill_cont_moe_*); S runs
                                     over multiples of CONT_GRID_STEP
  decode_dense_{m}_b{B}_t{T}         one dense decode step
  decode_moe_{m}_{spec}_b{B}_t{T}    monolithic masked-MoE decode step
  embed_{m}_b{B}                     token+position embedding
  attn_layer_{m}_b{B}_t{T}           one attention block (MoE orchestration)
  rmsnorm_{m}_b{B}                   FFN pre-norm
  router_{m}_e{Nr}_b{B}              analytical router scores
  ffn_{m}_h{H}_b{B}                  SwiGLU FFN slice (shared expert)
  experts_{m}_e{Nr}_mm{M}_c{C}       grouped routed experts (Pallas)
  logits_{m}_b{B}                    final norm + unembedding
  ffn_hidden_{m}_q{Q}                hidden states (profiling)
  atopk_{m}_q{Q}_k{K}                ATopK activation mask (profiling)

Also triggers pretraining of the `small` checkpoint if absent.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .kernels import atopk_mask, routed_experts, swiglu_ffn, swiglu_hidden

F32 = jnp.float32
I32 = jnp.int32

# Suffix-continuation prefill grid pitch: prefill_cont_* artifacts are
# emitted at suffix lengths S = CONT_GRID_STEP, 2*CONT_GRID_STEP, ...
# up to the largest monolithic prefill length. Must agree with
# `CONT_GRID_STEP` in rust/src/serving/engine.rs — the registered copy
# the mirror-drift lint checks lives in
# scripts/mirror_chunked_prefill.py (see lint/drift.rs REGISTRY).
CONT_GRID_STEP = 16


def to_hlo_text(lowered):
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


class Emitter:
    def __init__(self, out_dir):
        self.out_dir = out_dir
        self.manifest = {"version": 1, "models": {}, "artifacts": {}}

    def emit(self, name, fn, args, outputs_doc, meta=None):
        """args: list of (argname, ShapeDtypeStruct)."""
        specs = [s for _, s in args]
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(self.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        self.manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "args": [
                {
                    "name": n,
                    "shape": list(s.shape),
                    "dtype": "i32" if s.dtype == jnp.int32 else "f32",
                }
                for n, s in args
            ],
            "outputs": outputs_doc,
            "meta": meta or {},
        }
        print(f"  {name}: {len(text)} chars, {len(args)} args")

    def save_manifest(self):
        path = os.path.join(self.out_dir, "manifest.json")
        with open(path, "w") as f:
            json.dump(self.manifest, f, indent=1, sort_keys=True)
        print(f"wrote {path} ({len(self.manifest['artifacts'])} artifacts)")


# ---------------------------------------------------------------------------
# argument plumbing: dense params are flattened in sorted-name order
# ---------------------------------------------------------------------------


def dense_param_names(cfg, include_ffn=True):
    names = ["embed", "final_norm", "pos", "unembed"]
    for l in range(cfg["n_layers"]):
        pre = f"layers.{l}"
        names += [
            f"{pre}.attn.wk", f"{pre}.attn.wo", f"{pre}.attn.wq", f"{pre}.attn.wv",
            f"{pre}.attn_norm", f"{pre}.ffn_norm",
        ]
        if include_ffn:
            names += [f"{pre}.ffn.w_down", f"{pre}.ffn.w_gate", f"{pre}.ffn.w_up"]
    return sorted(names)


def dense_param_specs(cfg, include_ffn=True):
    d, dh, v, t = cfg["d_model"], cfg["d_ff"], cfg["vocab"], cfg["max_seq"]
    shapes = {
        "embed": (v, d),
        "pos": (t, d),
        "final_norm": (d,),
        "unembed": (d, v),
    }
    for l in range(cfg["n_layers"]):
        pre = f"layers.{l}"
        shapes[f"{pre}.attn_norm"] = (d,)
        shapes[f"{pre}.ffn_norm"] = (d,)
        for w in ("wq", "wk", "wv", "wo"):
            shapes[f"{pre}.attn.{w}"] = (d, d)
        shapes[f"{pre}.ffn.w_gate"] = (d, dh)
        shapes[f"{pre}.ffn.w_up"] = (d, dh)
        shapes[f"{pre}.ffn.w_down"] = (dh, d)
    return [(n, spec(shapes[n])) for n in dense_param_names(cfg, include_ffn)]


def moe_param_names(cfg, n_shared_neurons, n_r):
    """MoE per-layer stacked tensors, sorted. The rust runtime stacks
    expert slices into these shapes when loading a converted model."""
    names = []
    for l in range(cfg["n_layers"]):
        pre = f"moe.{l}"
        names += [
            f"{pre}.bias", f"{pre}.experts.w_down", f"{pre}.experts.w_gate",
            f"{pre}.experts.w_up", f"{pre}.router.w_gate_r", f"{pre}.router.w_up_r",
            f"{pre}.scale", f"{pre}.shared.w_down", f"{pre}.shared.w_gate",
            f"{pre}.shared.w_up",
        ]
    return sorted(names)


def moe_param_specs(cfg, sh, n_r, m):
    d = cfg["d_model"]
    shapes = {}
    for l in range(cfg["n_layers"]):
        pre = f"moe.{l}"
        shapes[f"{pre}.shared.w_gate"] = (d, sh)
        shapes[f"{pre}.shared.w_up"] = (d, sh)
        shapes[f"{pre}.shared.w_down"] = (sh, d)
        shapes[f"{pre}.experts.w_gate"] = (n_r, d, m)
        shapes[f"{pre}.experts.w_up"] = (n_r, d, m)
        shapes[f"{pre}.experts.w_down"] = (n_r, m, d)
        shapes[f"{pre}.router.w_gate_r"] = (d, n_r)
        shapes[f"{pre}.router.w_up_r"] = (d, n_r)
        shapes[f"{pre}.scale"] = (n_r,)
        shapes[f"{pre}.bias"] = (n_r,)
    return [(n, spec(shapes[n])) for n in moe_param_names(cfg, sh, n_r)]


def rebuild_params(names, flat):
    return dict(zip(names, flat))


# ---------------------------------------------------------------------------
# artifact builders
# ---------------------------------------------------------------------------


def emit_model_artifacts(em, name, batches, specs_moe, kv_lens, prefill_lens):
    cfg = model.config(name)
    d, v, dh = cfg["d_model"], cfg["vocab"], cfg["d_ff"]
    h = cfg["n_heads"]
    hd = d // h
    nl = cfg["n_layers"]
    em.manifest["models"][name] = cfg
    pnames = dense_param_names(cfg)
    pspecs = dense_param_specs(cfg)

    for b in batches:
        for t in kv_lens:
            # ---- dense decode ----
            def decode_fn(*flat, _cfg=cfg, _n=len(pnames)):
                params = rebuild_params(pnames, flat[:_n])
                token, kv, pos = flat[_n], flat[_n + 1], flat[_n + 2]
                logits, kv = model.decode_step(params, token, kv, pos, _cfg)
                return logits, kv

            args = pspecs + [
                ("token", spec((b,), I32)),
                ("kv", spec((nl, 2, b, h, t, hd))),
                ("pos", spec((b,), I32)),
            ]
            em.emit(
                f"decode_dense_{name}_b{b}_t{t}",
                decode_fn,
                args,
                ["logits[b,v]", "kv"],
                {"model": name, "batch": b, "kv_len": t},
            )

            # ---- prefill ----
            for s in prefill_lens:
                if s > t:
                    continue

                def prefill_fn(*flat, _cfg=cfg, _n=len(pnames), _t=t):
                    params = rebuild_params(pnames, flat[:_n])
                    tokens = flat[_n]
                    logits, kv = model.prefill(params, tokens, _cfg, kv_len=_t)
                    return logits, kv

                em.emit(
                    f"prefill_dense_{name}_b{b}_s{s}_t{t}",
                    prefill_fn,
                    pspecs + [("tokens", spec((b, s), I32))],
                    ["logits[b,s,v]", "kv"],
                    {"model": name, "batch": b, "seq": s, "kv_len": t},
                )

            # ---- suffix-continuation prefill grid ----
            # one entry per CONT_GRID_STEP multiple up to the largest
            # monolithic prefill length: the engine picks the smallest
            # entry covering a row's uncached suffix (prefix-cache
            # hits) or the largest fitting the chunk budget (chunked
            # prefill); tokens land at per-row positions start..start+s
            cont_lens = [
                c
                for c in range(CONT_GRID_STEP, max(prefill_lens) + 1, CONT_GRID_STEP)
                if c <= t
            ]
            for s in cont_lens:

                def prefill_cont_fn(*flat, _cfg=cfg, _n=len(pnames)):
                    params = rebuild_params(pnames, flat[:_n])
                    tokens, kv, start = flat[_n], flat[_n + 1], flat[_n + 2]
                    return model.prefill_cont(params, tokens, kv, start, _cfg)

                em.emit(
                    f"prefill_cont_dense_{name}_b{b}_s{s}_t{t}",
                    prefill_cont_fn,
                    pspecs
                    + [
                        ("tokens", spec((b, s), I32)),
                        ("kv", spec((nl, 2, b, h, t, hd))),
                        ("start", spec((b,), I32)),
                    ],
                    ["logits[b,s,v]", "kv"],
                    {"model": name, "batch": b, "seq": s, "kv_len": t},
                )

            # ---- monolithic MoE decode/prefill per spec ----
            # converted models have no dense FFN weights, so MoE
            # artifacts take the FFN-less dense param set
            pnames_nf = dense_param_names(cfg, include_ffn=False)
            pspecs_nf = dense_param_specs(cfg, include_ffn=False)
            for spec_str, (n_s, n_k, n_tot) in specs_moe.items():
                m = dh // n_tot
                n_r = n_tot - n_s
                sh = n_s * m
                mnames = moe_param_names(cfg, sh, n_r)
                mspecs = moe_param_specs(cfg, sh, n_r, m)

                def unpack_moe(mflat, _cfg=cfg):
                    moe_params = []
                    for l in range(_cfg["n_layers"]):
                        pre = f"moe.{l}"
                        moe_params.append(
                            dict(
                                shared=(
                                    mflat[f"{pre}.shared.w_gate"],
                                    mflat[f"{pre}.shared.w_up"],
                                    mflat[f"{pre}.shared.w_down"],
                                ),
                                experts=(
                                    mflat[f"{pre}.experts.w_gate"],
                                    mflat[f"{pre}.experts.w_up"],
                                    mflat[f"{pre}.experts.w_down"],
                                ),
                                router=(
                                    mflat[f"{pre}.router.w_gate_r"],
                                    mflat[f"{pre}.router.w_up_r"],
                                ),
                                scale=mflat[f"{pre}.scale"],
                                bias=mflat[f"{pre}.bias"],
                            )
                        )
                    return moe_params

                def moe_decode_fn(
                    *flat, _cfg=cfg, _np=len(pnames_nf), _nm=len(mnames), _nk=n_k, _up=unpack_moe
                ):
                    params = rebuild_params(pnames_nf, flat[:_np])
                    mflat = rebuild_params(mnames, flat[_np : _np + _nm])
                    moe_params = _up(mflat)
                    token, kv, pos = flat[_np + _nm], flat[_np + _nm + 1], flat[_np + _nm + 2]
                    logits, kv = model.moe_decode_step(
                        params, moe_params, token, kv, pos, _cfg, _nk
                    )
                    return logits, kv

                em.emit(
                    f"decode_moe_{name}_{spec_str}_b{b}_t{t}",
                    moe_decode_fn,
                    pspecs_nf
                    + mspecs
                    + [
                        ("token", spec((b,), I32)),
                        ("kv", spec((nl, 2, b, h, t, hd))),
                        ("pos", spec((b,), I32)),
                    ],
                    ["logits[b,v]", "kv"],
                    {"model": name, "spec": spec_str, "batch": b, "kv_len": t},
                )

                for s in prefill_lens:
                    if s > t:
                        continue

                    def moe_prefill_fn(
                        *flat,
                        _cfg=cfg,
                        _np=len(pnames_nf),
                        _nm=len(mnames),
                        _nk=n_k,
                        _t=t,
                        _up=unpack_moe,
                    ):
                        params = rebuild_params(pnames_nf, flat[:_np])
                        mflat = rebuild_params(mnames, flat[_np : _np + _nm])
                        moe_params = _up(mflat)
                        tokens = flat[_np + _nm]
                        logits, kv = model.moe_prefill(
                            params, moe_params, tokens, _cfg, _t, _nk
                        )
                        return logits, kv

                    em.emit(
                        f"prefill_moe_{name}_{spec_str}_b{b}_s{s}_t{t}",
                        moe_prefill_fn,
                        pspecs_nf + mspecs + [("tokens", spec((b, s), I32))],
                        ["logits[b,s,v]", "kv"],
                        {"model": name, "spec": spec_str, "batch": b, "seq": s, "kv_len": t},
                    )

                for s in [
                    c
                    for c in range(CONT_GRID_STEP, max(prefill_lens) + 1, CONT_GRID_STEP)
                    if c <= t
                ]:

                    def moe_prefill_cont_fn(
                        *flat,
                        _cfg=cfg,
                        _np=len(pnames_nf),
                        _nm=len(mnames),
                        _nk=n_k,
                        _up=unpack_moe,
                    ):
                        params = rebuild_params(pnames_nf, flat[:_np])
                        mflat = rebuild_params(mnames, flat[_np : _np + _nm])
                        moe_params = _up(mflat)
                        tokens = flat[_np + _nm]
                        kv = flat[_np + _nm + 1]
                        start = flat[_np + _nm + 2]
                        return model.moe_prefill_cont(
                            params, moe_params, tokens, kv, start, _cfg, _nk
                        )

                    em.emit(
                        f"prefill_cont_moe_{name}_{spec_str}_b{b}_s{s}_t{t}",
                        moe_prefill_cont_fn,
                        pspecs_nf
                        + mspecs
                        + [
                            ("tokens", spec((b, s), I32)),
                            ("kv", spec((nl, 2, b, h, t, hd))),
                            ("start", spec((b,), I32)),
                        ],
                        ["logits[b,s,v]", "kv"],
                        {"model": name, "spec": spec_str, "batch": b, "seq": s, "kv_len": t},
                    )

        # ---- orchestration pieces (batch-dependent; kv per length) ----
        for t in kv_lens:
            em.emit(
                f"split_kv_{name}_b{b}_t{t}",
                lambda kv, _nl=nl: tuple(kv[l] for l in range(_nl)),
                [("kv", spec((nl, 2, b, h, t, hd)))],
                [f"kv_layer_{l}" for l in range(nl)],
                {"model": name, "batch": b, "kv_len": t},
            )
            em.emit(
                f"attn_layer_{name}_b{b}_t{t}",
                lambda x, kv_layer, wq, wk, wv, wo, g, pos, _h=h: model.attn_layer(
                    x, kv_layer, wq, wk, wv, wo, g, pos, _h
                ),
                [
                    ("x", spec((b, d))),
                    ("kv_layer", spec((2, b, h, t, hd))),
                    ("wq", spec((d, d))),
                    ("wk", spec((d, d))),
                    ("wv", spec((d, d))),
                    ("wo", spec((d, d))),
                    ("attn_norm", spec((d,))),
                    ("pos", spec((b,), I32)),
                ],
                ["x[b,d]", "kv_layer"],
                {"model": name, "batch": b, "kv_len": t},
            )
        em.emit(
            f"embed_{name}_b{b}",
            lambda embed, pos_table, token, pos: (embed[token] + pos_table[pos],),
            [
                ("embed", spec((v, d))),
                ("pos_table", spec((cfg["max_seq"], d))),
                ("token", spec((b,), I32)),
                ("pos", spec((b,), I32)),
            ],
            ["x[b,d]"],
            {"model": name, "batch": b},
        )
        em.emit(
            f"rmsnorm_{name}_b{b}",
            lambda x, g: (model.rmsnorm(x, g),),
            [("x", spec((b, d))), ("g", spec((d,)))],
            ["xn[b,d]"],
            {"model": name, "batch": b},
        )
        em.emit(
            f"logits_{name}_b{b}",
            lambda x, g, u: (model.final_logits(x, g, u),),
            [("x", spec((b, d))), ("final_norm", spec((d,))), ("unembed", spec((d, v)))],
            ["logits[b,v]"],
            {"model": name, "batch": b},
        )

    # ---- batch-independent pieces ----
    for spec_str, (n_s, n_k, n_tot) in specs_moe.items():
        m = dh // n_tot
        n_r = n_tot - n_s
        sh = n_s * m
        # fused pre-step (PERF L3-1) per batch × kv length
        for b in batches:
            for t in kv_lens:
                em.emit(
                    f"attn_moe_pre_{name}_e{n_r}_h{sh}_b{b}_t{t}",
                    lambda x, kvl, wq, wk, wv, wo, an, fn, rg, ru, sg, su, sd, pos, _h=h: (
                        model.attn_moe_pre(
                            x, kvl, wq, wk, wv, wo, an, fn, rg, ru, sg, su, sd, pos, _h
                        )
                    ),
                    [
                        ("x", spec((b, d))),
                        ("kv_layer", spec((2, b, h, t, hd))),
                        ("wq", spec((d, d))),
                        ("wk", spec((d, d))),
                        ("wv", spec((d, d))),
                        ("wo", spec((d, d))),
                        ("attn_norm", spec((d,))),
                        ("ffn_norm", spec((d,))),
                        ("w_gate_r", spec((d, n_r))),
                        ("w_up_r", spec((d, n_r))),
                        ("shared.w_gate", spec((d, sh))),
                        ("shared.w_up", spec((d, sh))),
                        ("shared.w_down", spec((sh, d))),
                        ("pos", spec((b,), I32)),
                    ],
                    ["x[b,d]", "kv_layer", "xn[b,d]", "scores[b,nr]", "shared_y[b,d]"],
                    {"model": name, "batch": b, "kv_len": t, "n_r": n_r, "hidden": sh},
                )
        for b in batches:
            em.emit(
                f"router_{name}_e{n_r}_b{b}",
                lambda x, g, u: (model.router_scores(x, g, u),),
                [("x", spec((b, d))), ("w_gate_r", spec((d, n_r))), ("w_up_r", spec((d, n_r)))],
                ["scores[b,nr]"],
                {"model": name, "batch": b, "n_r": n_r},
            )
            em.emit(
                f"ffn_{name}_h{sh}_b{b}",
                lambda x, g, u, dn: (swiglu_ffn(x, g, u, dn),),
                [
                    ("x", spec((b, d))),
                    ("w_gate", spec((d, sh))),
                    ("w_up", spec((d, sh))),
                    ("w_down", spec((sh, d))),
                ],
                ["y[b,d]"],
                {"model": name, "batch": b, "hidden": sh},
            )
            # expert capacity: ceil(b * n_k / n_r) rounded up with slack
            cap = max(1, -(-b * n_k // n_r))
            cap = int(2 ** np.ceil(np.log2(max(cap, 1))))
            em.emit(
                f"experts_{name}_e{n_r}_mm{m}_c{cap}_b{b}",
                lambda xs, g, u, dn: (routed_experts(xs, g, u, dn),),
                [
                    ("xs", spec((n_r, cap, d))),
                    ("w_gate", spec((n_r, d, m))),
                    ("w_up", spec((n_r, d, m))),
                    ("w_down", spec((n_r, m, d))),
                ],
                ["ys[nr,c,d]"],
                {"model": name, "batch": b, "n_r": n_r, "m": m, "capacity": cap},
            )

    # ---- profiling pieces ----
    for q in (128, 256):
        em.emit(
            f"ffn_hidden_{name}_q{q}",
            lambda x, g, u: (swiglu_hidden(x, g, u),),
            [("x", spec((q, d))), ("w_gate", spec((d, dh))), ("w_up", spec((d, dh)))],
            ["h[q,dh]"],
            {"model": name, "q": q},
        )
    for k in (10, 32):
        if k <= dh:
            em.emit(
                f"atopk_{name}_q128_k{k}",
                lambda hh, _k=k: (atopk_mask(hh, _k),),
                [("h", spec((128, dh)))],
                ["mask[q,dh]"],
                {"model": name, "k": k},
            )
    em.emit(
        f"dense_ffn_{name}_q128",
        lambda x, g, u, dn: (swiglu_ffn(x, g, u, dn),),
        [
            ("x", spec((128, d))),
            ("w_gate", spec((d, dh))),
            ("w_up", spec((d, dh))),
            ("w_down", spec((dh, d))),
        ],
        ["y[q,d]"],
        {"model": name, "q": 128},
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--skip-pretrain", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    em = Emitter(args.out)

    # tiny: test artifacts only (b1), fast to lower
    print("== tiny ==")
    emit_model_artifacts(
        em,
        "tiny",
        batches=[1],
        specs_moe={"S2A2E8": (2, 2, 8)},
        kv_lens=[128],
        prefill_lens=[16],
    )

    # small: the serving/eval workhorse — all six Table 9 configs
    print("== small ==")
    emit_model_artifacts(
        em,
        "small",
        batches=[1, 8, 32],
        specs_moe={
            "S1A5E8": (1, 5, 8),
            "S3A3E8": (3, 3, 8),
            "S2A4E8": (2, 4, 8),
            "S4A8E16": (4, 8, 16),
            "S6A6E16": (6, 6, 16),
            "S3A9E16": (3, 9, 16),
        },
        kv_lens=[64, 256],
        prefill_lens=[16, 64],
    )

    em.save_manifest()

    # pretrain the small checkpoint (skipped if present)
    ckpt = os.path.join(args.out, "small.cmw")
    if not args.skip_pretrain and not os.path.exists(ckpt):
        from . import pretrain

        pretrain.main(args.out)


if __name__ == "__main__":
    main()
