"""Numpy writer/reader for the `.cmw` weight format.

Byte-compatible with `rust/src/model/format.rs`:
    magic "CMW1" | u64 header_len | JSON header (padded) | f32 LE data
The header's "tensors" map gives shape + byte offset into the data
section; "config" carries the TransformerConfig; "meta.layer_kinds"
marks dense vs MoE layers.
"""

import json
import struct

import numpy as np

MAGIC = b"CMW1"
ALIGN = 64


def write_cmw(path, config, meta, tensors):
    """tensors: dict name -> np.ndarray (float32)."""
    offset = 0
    theader = {}
    names = sorted(tensors)  # rust writes BTreeMap order; match it
    for name in names:
        arr = np.ascontiguousarray(tensors[name], dtype=np.float32)
        theader[name] = {"shape": list(arr.shape), "offset": offset}
        offset += arr.size * 4
    header = json.dumps(
        {"config": config, "meta": meta, "tensors": theader}, separators=(",", ":")
    ).encode()
    data_start = 4 + 8 + len(header)
    pad = (ALIGN - data_start % ALIGN) % ALIGN
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<Q", len(header) + pad))
        f.write(header)
        f.write(b" " * pad)
        for name in names:
            arr = np.ascontiguousarray(tensors[name], dtype=np.float32)
            f.write(arr.astype("<f4").tobytes())


def read_cmw(path):
    """Returns (config, meta, {name: np.ndarray})."""
    with open(path, "rb") as f:
        magic = f.read(4)
        assert magic == MAGIC, f"{path}: not a CMW1 file"
        (hlen,) = struct.unpack("<Q", f.read(8))
        header = json.loads(f.read(hlen).decode().rstrip())
        data = f.read()
    tensors = {}
    for name, ent in header["tensors"].items():
        shape = tuple(ent["shape"])
        n = int(np.prod(shape)) if shape else 1
        off = ent["offset"]
        tensors[name] = np.frombuffer(data, dtype="<f4", count=n, offset=off).reshape(shape)
    return header["config"], header["meta"], tensors
