"""Synthetic corpus generators — the python mirror of
`rust/src/data/corpus.rs` (same lexicon, same transition rules, same
domain structure; independent RNG, so the *distribution* matches, which
is what pretraining needs).
"""

import random

LEXICON = [
    "the", "model", "expert", "router", "token", "layer", "neuron", "dense", "sparse", "gate",
    "shared", "routed", "cache", "batch", "serve", "fast", "slow", "high", "low", "with", "from",
    "into", "over", "under", "runs", "emits", "learns", "splits", "merges", "activates",
]


def gen_markov(n_bytes, seed=0):
    rng = random.Random(seed)
    n = len(LEXICON)
    out = []
    size = 0
    cur = rng.randrange(n)
    while size < n_bytes:
        w = LEXICON[cur]
        out.append(w)
        size += len(w) + 1
        r = rng.random()
        if r < 0.45:
            cur = (2 * cur + 1) % n
        elif r < 0.8:
            cur = (3 * cur + 2) % n
        else:
            cur = rng.randrange(n)
        if rng.random() < 0.07:
            out[-1] = w + "."
    return " ".join(out)[:n_bytes]


def gen_arith(n_bytes, seed=0):
    rng = random.Random(seed)
    out = []
    size = 0
    while size < n_bytes:
        if rng.random() < 0.7:
            a = rng.randrange(100)
            b = rng.randrange(100)
            s = f"{a}+{b}={a + b};"
        else:
            period = rng.randrange(2, 5)
            reps = rng.randrange(2, 5)
            start = ord("a") + rng.randrange(6)
            unit = "".join(chr(start + k) for k in range(period))
            s = unit * reps + ";"
        out.append(s)
        size += len(s)
    return "".join(out)[:n_bytes]


def mixed_corpus(n_bytes, seed=0):
    """50/50 interleave of both domains (the pretraining corpus)."""
    half = n_bytes // 2
    return gen_markov(half, seed) + gen_arith(n_bytes - half, seed + 1)


def encode(text):
    """Byte-level tokenization (matches rust/src/data/mod.rs)."""
    return list(text.encode("utf-8"))
