"""Build-time pretraining of the `small` checkpoint.

Trains the L2 transformer on the mixed synthetic corpus (markov text +
arithmetic/patterns) with Adam for a few hundred steps — enough for the
FFN to develop the structured activation statistics CMoE exploits —
then writes `artifacts/small.cmw` plus the loss curve
(`artifacts/pretrain_log.json`). Runs exactly once per `make artifacts`.

Env knobs: CMOE_PRETRAIN_STEPS (default 400), CMOE_PRETRAIN_MODEL
(default "small").
"""

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import datagen, model
from .cmw import write_cmw


def make_batches(tokens, batch, seq, seed=0):
    rng = np.random.default_rng(seed)
    tokens = np.asarray(tokens, dtype=np.int32)
    n = len(tokens) - seq - 1
    while True:
        starts = rng.integers(0, n, size=batch)
        yield np.stack([tokens[s : s + seq] for s in starts])


def pretrain(model_name="small", steps=400, batch=8, seq=128, lr=1e-3, seed=0, log_every=20):
    cfg = model.config(model_name)
    seq = min(seq, cfg["max_seq"])
    key = jax.random.PRNGKey(seed)
    params = model.init_params(cfg, key)
    m = {k: jnp.zeros_like(v) for k, v in params.items()}
    v = {k: jnp.zeros_like(v_) for k, v_ in params.items()}
    t = jnp.array(0, jnp.int32)

    corpus = datagen.mixed_corpus(600_000, seed=seed)
    tokens = datagen.encode(corpus)
    batches = make_batches(tokens, batch, seq, seed)

    log = []
    t0 = time.time()
    for step in range(steps):
        xb = jnp.asarray(next(batches))
        params, m, v, t, loss = model.adam_step(params, m, v, t, xb, model_name, lr)
        if step % log_every == 0 or step == steps - 1:
            log.append({"step": step, "loss": float(loss), "elapsed_s": time.time() - t0})
            print(f"step {step:4d}  loss {float(loss):.4f}  ({time.time() - t0:.1f}s)")
    return params, cfg, log


def save_checkpoint(params, cfg, path):
    tensors = {k: np.asarray(v) for k, v in params.items()}
    config = {
        "name": cfg["name"],
        "vocab": cfg["vocab"],
        "d_model": cfg["d_model"],
        "n_layers": cfg["n_layers"],
        "n_heads": cfg["n_heads"],
        "d_ff": cfg["d_ff"],
        "max_seq": cfg["max_seq"],
    }
    meta = {"layer_kinds": ["dense"] * cfg["n_layers"]}
    write_cmw(path, config, meta, tensors)


def main(out_dir="../artifacts"):
    model_name = os.environ.get("CMOE_PRETRAIN_MODEL", "small")
    steps = int(os.environ.get("CMOE_PRETRAIN_STEPS", "400"))
    params, cfg, log = pretrain(model_name, steps=steps)
    os.makedirs(out_dir, exist_ok=True)
    ckpt = os.path.join(out_dir, f"{model_name}.cmw")
    save_checkpoint(params, cfg, ckpt)
    with open(os.path.join(out_dir, "pretrain_log.json"), "w") as f:
        json.dump({"model": model_name, "steps": steps, "log": log}, f, indent=1)
    print(f"wrote {ckpt} (final loss {log[-1]['loss']:.4f})")


if __name__ == "__main__":
    main()
