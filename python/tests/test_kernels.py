"""L1 kernel correctness: Pallas vs pure-jnp oracle (the CORE
correctness signal of the build). Hypothesis sweeps shapes; fixed cases
pin the paper-relevant configurations."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import atopk_mask, ref, routed_experts, swiglu_ffn, swiglu_hidden

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")


def rand(key, shape, scale=0.5):
    return jax.random.normal(key, shape) * scale


dims = st.integers(min_value=1, max_value=64)


@given(q=dims, d=dims, dh=st.integers(min_value=1, max_value=96))
def test_swiglu_ffn_matches_ref(q, d, dh):
    k = jax.random.PRNGKey(q * 10007 + d * 101 + dh)
    ks = jax.random.split(k, 4)
    x = rand(ks[0], (q, d), 1.0)
    wg = rand(ks[1], (d, dh))
    wu = rand(ks[2], (d, dh))
    wd = rand(ks[3], (dh, d))
    got = swiglu_ffn(x, wg, wu, wd)
    want = ref.swiglu_ffn_ref(x, wg, wu, wd)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@given(q=dims, d=dims, dh=st.integers(min_value=1, max_value=96))
def test_swiglu_hidden_matches_ref(q, d, dh):
    k = jax.random.PRNGKey(q * 7 + d * 31 + dh * 3)
    ks = jax.random.split(k, 3)
    x = rand(ks[0], (q, d), 1.0)
    wg = rand(ks[1], (d, dh))
    wu = rand(ks[2], (d, dh))
    np.testing.assert_allclose(
        swiglu_hidden(x, wg, wu), ref.swiglu_hidden_ref(x, wg, wu), rtol=1e-4, atol=1e-4
    )


@given(
    ne=st.integers(min_value=1, max_value=8),
    cap=st.integers(min_value=1, max_value=32),
    d=st.integers(min_value=1, max_value=32),
    m=st.integers(min_value=1, max_value=32),
)
def test_routed_experts_matches_ref(ne, cap, d, m):
    k = jax.random.PRNGKey(ne * 1009 + cap * 97 + d * 11 + m)
    ks = jax.random.split(k, 4)
    xs = rand(ks[0], (ne, cap, d), 1.0)
    wg = rand(ks[1], (ne, d, m))
    wu = rand(ks[2], (ne, d, m))
    wd = rand(ks[3], (ne, m, d))
    np.testing.assert_allclose(
        routed_experts(xs, wg, wu, wd),
        ref.routed_experts_ref(xs, wg, wu, wd),
        rtol=1e-4,
        atol=1e-4,
    )


@given(
    q=st.integers(min_value=1, max_value=64),
    dh=st.integers(min_value=2, max_value=96),
    data=st.data(),
)
def test_atopk_matches_ref(q, dh, data):
    k = data.draw(st.integers(min_value=1, max_value=dh))
    key = jax.random.PRNGKey(q * 31 + dh)
    h = jax.random.normal(key, (q, dh))
    np.testing.assert_array_equal(atopk_mask(h, k), ref.atopk_mask_ref(h, k))


def test_swiglu_paper_shapes():
    """The `small` model's exact FFN shape (d=128, d_h=512)."""
    k = jax.random.PRNGKey(0)
    ks = jax.random.split(k, 4)
    x = rand(ks[0], (256, 128), 1.0)
    wg, wu = rand(ks[1], (128, 512)), rand(ks[2], (128, 512))
    wd = rand(ks[3], (512, 128))
    # d_h=512 accumulation-order differences need a slightly wider band
    np.testing.assert_allclose(
        swiglu_ffn(x, wg, wu, wd), ref.swiglu_ffn_ref(x, wg, wu, wd), rtol=2e-3, atol=1e-3
    )


def test_atopk_marks_at_least_k():
    k = jax.random.PRNGKey(1)
    h = jax.random.normal(k, (32, 64))
    mask = np.asarray(atopk_mask(h, 10))
    assert (mask.sum(axis=1) >= 10).all()


def test_atopk_exactly_k_without_ties():
    # continuous random values: ties have measure zero
    k = jax.random.PRNGKey(2)
    h = jax.random.normal(k, (16, 48))
    mask = np.asarray(atopk_mask(h, 7))
    np.testing.assert_array_equal(mask.sum(axis=1), np.full(16, 7))


def test_experts_zero_capacity_padding():
    """Padded (zero) token slots must produce zero outputs."""
    k = jax.random.PRNGKey(3)
    ks = jax.random.split(k, 4)
    xs = jnp.zeros((4, 8, 16)).at[:, :2, :].set(rand(ks[0], (4, 2, 16), 1.0))
    wg, wu = rand(ks[1], (4, 16, 8)), rand(ks[2], (4, 16, 8))
    wd = rand(ks[3], (4, 8, 16))
    ys = np.asarray(routed_experts(xs, wg, wu, wd))
    np.testing.assert_allclose(ys[:, 2:, :], 0.0, atol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32])
def test_swiglu_dtypes(dtype):
    k = jax.random.PRNGKey(4)
    ks = jax.random.split(k, 4)
    x = rand(ks[0], (32, 16), 1.0).astype(dtype)
    wg, wu = rand(ks[1], (16, 64)).astype(dtype), rand(ks[2], (16, 64)).astype(dtype)
    wd = rand(ks[3], (64, 16)).astype(dtype)
    got = swiglu_ffn(x, wg, wu, wd)
    assert got.dtype == dtype
