"""CMW format round-trip + cross-checks against the datagen mirror."""

import numpy as np

from compile import datagen
from compile.cmw import read_cmw, write_cmw


def test_cmw_roundtrip(tmp_path):
    path = str(tmp_path / "t.cmw")
    rng = np.random.default_rng(0)
    tensors = {
        "a": rng.normal(size=(3, 4)).astype(np.float32),
        "b.c": rng.normal(size=(7,)).astype(np.float32),
        "layers.0.attn.wq": rng.normal(size=(8, 8)).astype(np.float32),
    }
    cfg = {"d_model": 8, "name": "t"}
    meta = {"layer_kinds": ["dense"]}
    write_cmw(path, cfg, meta, tensors)
    c2, m2, t2 = read_cmw(path)
    assert c2 == cfg
    assert m2 == meta
    for k, v in tensors.items():
        np.testing.assert_array_equal(t2[k], v)


def test_cmw_header_is_aligned(tmp_path):
    path = str(tmp_path / "a.cmw")
    write_cmw(path, {}, {}, {"x": np.zeros((5,), np.float32)})
    raw = open(path, "rb").read()
    import struct

    (hlen,) = struct.unpack("<Q", raw[4:12])
    assert (12 + hlen) % 64 == 0


def test_datagen_domains_differ():
    a = datagen.gen_markov(500, 1)
    b = datagen.gen_arith(500, 1)
    assert a != b
    assert "+" in b and "=" in b
    assert "+" not in a


def test_datagen_arith_correct():
    s = datagen.gen_arith(3000, 2)
    checked = 0
    for part in s.split(";"):
        if "=" in part and "+" in part:
            lhs, rhs = part.split("=")
            try:
                a, b = lhs.split("+")
                assert int(a) + int(b) == int(rhs)
                checked += 1
            except ValueError:
                pass  # truncated tail
    assert checked > 20


def test_encode_is_bytes():
    assert datagen.encode("AB") == [65, 66]
    assert max(datagen.encode(datagen.mixed_corpus(1000, 3))) < 256
