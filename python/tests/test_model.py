"""L2 model tests: shapes, prefill/decode parity, MoE gating math."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model


def setup(name="tiny", seed=0):
    cfg = model.config(name)
    params = model.init_params(cfg, jax.random.PRNGKey(seed))
    return cfg, params


def test_prefill_shapes():
    cfg, p = setup()
    toks = jnp.zeros((2, 10), jnp.int32)
    logits, kv = model.prefill(p, toks, cfg, kv_len=32)
    assert logits.shape == (2, 10, cfg["vocab"])
    assert kv.shape == (cfg["n_layers"], 2, 2, cfg["n_heads"], 32, cfg["d_model"] // cfg["n_heads"])


def test_prefill_decode_parity():
    """Decoding token t with the prefix KV must equal prefill's logits."""
    cfg, p = setup()
    toks = (jnp.arange(9, dtype=jnp.int32) * 13 % 256)[None, :]
    full_logits, _ = model.prefill(p, toks, cfg, kv_len=16)
    # build kv from the first 8 tokens, then decode token 8
    _, kv = model.prefill(p, toks[:, :8], cfg, kv_len=16)
    step_logits, _ = model.decode_step(p, toks[:, 8], kv, jnp.array(8, jnp.int32), cfg)
    np.testing.assert_allclose(step_logits, full_logits[:, 8, :], rtol=2e-4, atol=2e-4)


def test_decode_appends_kv():
    cfg, p = setup()
    toks = jnp.zeros((1, 4), jnp.int32)
    _, kv = model.prefill(p, toks, cfg, kv_len=8)
    tok = jnp.array([7], jnp.int32)
    _, kv2 = model.decode_step(p, tok, kv, jnp.array(4, jnp.int32), cfg)
    # position 4 must now be non-zero in layer 0 keys
    assert float(jnp.abs(kv2[0, 0, :, :, 4, :]).sum()) > 0.0
    # earlier positions unchanged
    np.testing.assert_allclose(kv2[0, 0, :, :, :4, :], kv[0, 0, :, :, :4, :])


def test_causality():
    cfg, p = setup()
    a = (jnp.arange(10, dtype=jnp.int32) * 7 % 256)[None, :]
    b = a.at[0, 9].set((a[0, 9] + 1) % 256)
    la, _ = model.prefill(p, a, cfg, kv_len=16)
    lb, _ = model.prefill(p, b, cfg, kv_len=16)
    np.testing.assert_allclose(la[:, :9, :], lb[:, :9, :], rtol=1e-5, atol=1e-5)


def _moe_params_from_dense(p, cfg, n_s, n_tot, seed=1):
    """Split each FFN into contiguous experts (test partition)."""
    d, dh = cfg["d_model"], cfg["d_ff"]
    m = dh // n_tot
    n_r = n_tot - n_s
    sh = n_s * m
    out = []
    key = jax.random.PRNGKey(seed)
    for l in range(cfg["n_layers"]):
        pre = f"layers.{l}"
        wg, wu, wd = p[f"{pre}.ffn.w_gate"], p[f"{pre}.ffn.w_up"], p[f"{pre}.ffn.w_down"]
        ew_g = jnp.stack([wg[:, sh + e * m : sh + (e + 1) * m] for e in range(n_r)])
        ew_u = jnp.stack([wu[:, sh + e * m : sh + (e + 1) * m] for e in range(n_r)])
        ew_d = jnp.stack([wd[sh + e * m : sh + (e + 1) * m, :] for e in range(n_r)])
        # representative = first neuron of each expert
        reps = [sh + e * m for e in range(n_r)]
        out.append(
            dict(
                shared=(wg[:, :sh], wu[:, :sh], wd[:sh, :]),
                experts=(ew_g, ew_u, ew_d),
                router=(wg[:, reps], wu[:, reps]),
                scale=jnp.zeros((n_r,)),
                bias=jnp.zeros((n_r,)),
            )
        )
    return out


def test_moe_all_active_equals_dense_decode():
    cfg, p = setup()
    moe_params = _moe_params_from_dense(p, cfg, n_s=2, n_tot=8)
    toks = jnp.zeros((1, 4), jnp.int32)
    _, kv = model.prefill(p, toks, cfg, kv_len=8)
    tok = jnp.array([5], jnp.int32)
    pos = jnp.array(4, jnp.int32)
    dense_logits, _ = model.decode_step(p, tok, kv, pos, cfg)
    moe_logits, _ = model.moe_decode_step(p, moe_params, tok, kv, pos, cfg, n_k=6)
    np.testing.assert_allclose(moe_logits, dense_logits, rtol=2e-4, atol=2e-4)


def test_moe_sparse_differs_but_close():
    cfg, p = setup()
    moe_params = _moe_params_from_dense(p, cfg, n_s=2, n_tot=8)
    toks = jnp.zeros((1, 4), jnp.int32)
    _, kv = model.prefill(p, toks, cfg, kv_len=8)
    tok = jnp.array([5], jnp.int32)
    pos = jnp.array(4, jnp.int32)
    dense_logits, _ = model.decode_step(p, tok, kv, pos, cfg)
    moe_logits, _ = model.moe_decode_step(p, moe_params, tok, kv, pos, cfg, n_k=3)
    diff = float(jnp.abs(moe_logits - dense_logits).max())
    assert diff > 1e-6, "sparse MoE identical to dense?"
    rel = float(jnp.linalg.norm(moe_logits - dense_logits) / jnp.linalg.norm(dense_logits))
    assert rel < 0.8, f"sparse MoE too far from dense: {rel}"


def test_moe_gate_bias_changes_selection_not_output_scale():
    cfg, p = setup()
    mp = _moe_params_from_dense(p, cfg, n_s=2, n_tot=8)
    x = jax.random.normal(jax.random.PRNGKey(2), (4, cfg["d_model"]))
    l0 = mp[0]
    y0 = model.moe_ffn_masked(x, l0["shared"], l0["experts"], l0["router"], l0["scale"], l0["bias"], 3)
    # gates are binary (scale=0) regardless of bias
    big_bias = l0["bias"].at[0].set(100.0)
    y1 = model.moe_ffn_masked(x, l0["shared"], l0["experts"], l0["router"], l0["scale"], big_bias, 3)
    assert y1.shape == y0.shape
    assert not np.allclose(np.asarray(y0), np.asarray(y1)), "bias should change selection"


def test_training_reduces_loss():
    cfg, p = setup()
    m = {k: jnp.zeros_like(v) for k, v in p.items()}
    v = {k: jnp.zeros_like(vv) for k, vv in p.items()}
    t = jnp.array(0, jnp.int32)
    key = jax.random.PRNGKey(3)
    toks = jax.random.randint(key, (4, 32), 0, 255)
    first = None
    for _ in range(30):
        p, m, v, t, loss = model.adam_step(p, m, v, t, toks, "tiny", 3e-3)
        if first is None:
            first = float(loss)
    assert float(loss) < first * 0.9, f"loss {first} -> {float(loss)}"
