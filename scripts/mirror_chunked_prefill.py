#!/usr/bin/env python3
"""Line-faithful python mirror of the chunked-prefill serving math.

`scripts/check.sh` runs this as the fallback gate when no rust
toolchain is on PATH (the repo's historical situation — see the
ROADMAP's standing caveat). Every function here transcribes its rust
counterpart statement by statement, so a behavioral disagreement is a
bug in one of the two, not a modeling artifact:

  Rng (PCG32 + Lemire)   <- rust/src/util/rng.rs      new/next_u32/next_u64/f32/below
  argmax sampling        <- rust/src/util/rng.rs      sample_logits (temperature <= 0)
  stub_logits            <- rust/src/serving/scheduler.rs
  stub_reference         <- rust/src/serving/scheduler.rs
  percentile             <- rust/src/util/stats.rs    (f32::total_cmp ordering)
  Sim (chunk budget)     <- rust/src/serving/scheduler.rs ContinuousSession::step,
                            specialized to the chunked-sweep config: all-Normal
                            FIFO, max_wait 0, no preemption, no prefix cache
  plan_row               <- rust/src/serving/engine.rs EngineStepForward::plan_row
  poisson/gen_long_trace <- rust/src/bench_harness/exp_serving.rs
  chunked_sim            <- rust/src/bench_harness/exp_serving.rs (token-time
                            metering: a step costs the prefill suffix tokens +
                            decode rows it computes)

The checks mirror what `rust/tests/chunked_prefill.rs` and the
exp_serving unit tests pin natively:

  1. percentile survives NaN samples (total_cmp ordering: NaN sorts
     after +inf, low/mid percentiles stay finite) and interpolates
     linearly on clean data;
  2. the per-step chunk-budget plan: head-of-line admission order, no
     zero-token takes, budget never exceeded, monolithic (budget 0)
     completes everything in one step;
  3. token identity: chunked streams are bit-identical to monolithic
     and to the per-request stub_reference replay, at any budget, and
     total compute tokens are equal (chunking moves work, never adds
     or drops it);
  4. TTFT-steps accounting: an uncontended request's ttft_steps is
     exactly ceil(plen / chunk) (1 when monolithic) — the stamp lands
     on the final chunk, never on earlier ones;
  5. plan_row: every plan makes progress (end > cached), continuation
     rows back-extend (start <= cached, suffix on the CONT_GRID_STEP
     grid), the monolithic fallback recomputes from 0, and the
     no-artifact-covers-it case raises instead of looping;
  6. the chunked sweep at the pinned seed 0xC0DE (the exact seed
     `chunked_sweep_cuts_tail_latency_without_changing_tokens` uses):
     chunking is a pure reordering of equal work, so the honest claim
     has two faces — tpot_p99 (the stall a monolithic prefill inflicts
     on live decode gaps) collapses at every arrival rate, while
     ttft_p99 drops outright at moderate load (arrivals stop waiting
     out monolithic mega-steps) and stays within 10% under overload,
     where queue wait dominates both arms. Token identity and compute
     equality are hard invariants throughout.

Exits 0 and prints a one-line summary per check on success; raises on
the first violation.
"""

import math
import random
import struct

import numpy as np

F32 = np.float32
MASK64 = (1 << 64) - 1

# Shared numeric constants, registered with the mirror-drift rule of
# `cmoe lint` / scripts/mirror_lint.py: each NAME below must define the
# same value as its rust counterpart (lint/drift.rs REGISTRY names the
# file pairs), or the lint gate fails.
DEFAULT_PREFILL_CHUNK_TOKENS = 256  # rust/src/serving/batcher.rs
CONT_GRID_STEP = 16  # rust/src/serving/engine.rs

# PCG32/FNV constants — registered against scripts/mirror_dynamic_k.py;
# repeated here because this mirror is standalone by design.
PCG_MULT = 6364136223846793005
SPLITMIX_GAMMA = 0x9E3779B97F4A7C15
SPLITMIX_MIX1 = 0xBF58476D1CE4E5B9
SPLITMIX_MIX2 = 0x94D049BB133111EB
FNV_OFFSET_BASIS = 0xCBF29CE484222325
FNV_PRIME = 0x100000001B3

# rust/src/bench_harness/exp_serving.rs — sweep shape
SWEEP_VOCAB = 23
SWEEP_KV_CAP = 128
SWEEP_POOL = 32  # largest bucket of SWEEP_BUCKETS = [1, 8, 32]
CHUNK_SWEEP_BUDGET = 32
CHUNK_ARRIVAL_TICK = 64


# ---------------------------------------------------------------------------
# rust/src/util/rng.rs — PCG32 (state/inc u64, 32-bit output)
# ---------------------------------------------------------------------------


def _splitmix64(x):
    x = (x + SPLITMIX_GAMMA) & MASK64
    z = x
    z = ((z ^ (z >> 30)) * SPLITMIX_MIX1) & MASK64
    z = ((z ^ (z >> 27)) * SPLITMIX_MIX2) & MASK64
    return x, z ^ (z >> 31)


class Rng:
    def __init__(self, seed):
        s = seed & MASK64
        s, init_state = _splitmix64(s)
        s, inc = _splitmix64(s)
        self.inc = inc | 1
        self.state = (init_state + self.inc) & MASK64
        self.next_u32()

    def next_u32(self):
        old = self.state
        self.state = (old * PCG_MULT + self.inc) & MASK64
        xorshifted = (((old >> 18) ^ old) >> 27) & 0xFFFFFFFF
        rot = old >> 59
        return ((xorshifted >> rot) | (xorshifted << ((32 - rot) & 31))) & 0xFFFFFFFF

    def next_u64(self):
        return (self.next_u32() << 32) | self.next_u32()

    def f32(self):
        # (x >> 8) * 2^-24 is exact in both f32 and f64, so a plain
        # python float carries the bit-identical value
        return float(self.next_u32() >> 8) * (1.0 / (1 << 24))

    def below(self, bound):
        # Lemire's unbiased method on next_u64
        assert bound > 0, "below(0)"
        x = self.next_u64()
        m = x * bound
        low = m & MASK64
        if low < bound:
            t = ((-bound) & MASK64) % bound  # bound.wrapping_neg() % bound
            while low < t:
                x = self.next_u64()
                m = x * bound
                low = m & MASK64
        return m >> 64


def argmax_first(logits):
    """sample_logits at temperature <= 0: first strict max wins, and the
    rng stream is NOT consumed."""
    best = 0
    for i in range(1, len(logits)):
        if logits[i] > logits[best]:
            best = i
    return best


# ---------------------------------------------------------------------------
# rust/src/serving/scheduler.rs — stub model + run-to-completion reference
# ---------------------------------------------------------------------------


def stub_logits(ctx, vocab):
    h = FNV_OFFSET_BASIS
    for t in ctx:
        h ^= t & MASK64
        h = (h * FNV_PRIME) & MASK64
    rng = Rng(h ^ vocab)
    return [rng.f32() for _ in range(vocab)]


def stub_reference(prompt, max_new, vocab, kv_cap, stop_token=None):
    """stub_reference at temperature 0 (argmax): the token stream any
    correct scheduler must emit for this request, chunked or not."""
    ctx = list(prompt)
    pos = len(ctx)
    gen = []
    tok = argmax_first(stub_logits(ctx, vocab))
    gen.append(tok)
    cur = tok
    done = stop_token == tok or len(gen) >= max_new or pos >= kv_cap
    while not done:
        ctx.append(cur)
        tok = argmax_first(stub_logits(ctx, vocab))
        gen.append(tok)
        cur = tok
        pos += 1
        done = stop_token == tok or len(gen) >= max_new or pos >= kv_cap
    return gen


# ---------------------------------------------------------------------------
# rust/src/util/stats.rs — percentile (f32::total_cmp ordering)
# ---------------------------------------------------------------------------


def _total_cmp_key(x):
    # f32::total_cmp: compare sign-magnitude bit patterns flipped into
    # lexicographic order; NaN (exponent all-ones, nonzero mantissa)
    # sorts after +inf
    bits = struct.unpack(">i", struct.pack(">f", x))[0]
    bits ^= (bits >> 31) & 0x7FFFFFFF
    return bits


def percentile(xs, p):
    if not xs:
        return F32(0.0)
    v = sorted((F32(x) for x in xs), key=_total_cmp_key)
    rank = (p / 100.0) * (len(v) - 1)
    lo = int(math.floor(rank))
    hi = int(math.ceil(rank))
    if lo == hi:
        return v[lo]
    w = F32(rank - lo)
    return F32(F32(v[lo] * F32(F32(1.0) - w)) + F32(v[hi] * w))


# ---------------------------------------------------------------------------
# rust/src/serving/scheduler.rs — ContinuousSession::step, specialized
# to the chunked-sweep configuration: all requests Priority::Normal
# (global FIFO), max_wait 0 (no hold window), PreemptMode::Off, no
# queue cap, no prefix cache (map_prefix -> None, cached always 0).
# ---------------------------------------------------------------------------


class Sim:
    def __init__(self, pool, chunk, vocab, kv_cap):
        self.pool = pool
        self.chunk = chunk
        self.vocab = vocab
        self.kv_cap = kv_cap
        self.queue = []  # FIFO of (request, enqueue_step)
        self.slots = [None] * pool
        # free stack: fresh slots pop in ascending order; retired slots
        # push on top and recycle first (LIFO)
        self.free = list(range(pool))[::-1]
        self.prefilling = []  # admission order; budget spends front-first
        self.step_idx = 0
        self.compute_tokens = 0  # CostMeter: prefill suffixes + decode rows

    def enqueue(self, req):
        self.queue.append((req, self.step_idx))

    def is_idle(self):
        return not self.queue and len(self.free) == self.pool

    def _retire(self, sid, entry, out):
        st = self.slots[sid]
        out.append(
            {
                "id": st["req"]["id"],
                "tokens": st["generated"],
                # first_token_step - enqueue_step + 1
                "ttft_steps": st["first_token_step"] - st["enqueue_step"] + 1,
                "first_token_step": st["first_token_step"],
                "decode_span_steps": st["last_token_step"] - st["first_token_step"],
            }
        )
        self.slots[sid] = None
        self.free.append(sid)

    def step(self):
        entry = self.step_idx
        self.step_idx += 1
        out = []

        # --- admission: FIFO into free slots ---
        admitted = []
        while self.free and self.queue:
            req, enq_step = self.queue.pop(0)
            sid = self.free.pop()
            self.slots[sid] = {
                "req": req,
                "ctx": [],  # the slot's KV: one token per column
                "prefilled": 0,  # no prefix cache: cached == 0
                "generated": [],
                "cur": 0,
                "pos": 0,
                "enqueue_step": enq_step,
                "first_token_step": None,
                "last_token_step": 0,
            }
            admitted.append(sid)
        self.prefilling.extend(admitted)

        # --- prefill: spend the chunk budget down the list in
        # admission order; 0 = unbounded (monolithic) ---
        if self.prefilling:
            remaining = math.inf if self.chunk == 0 else self.chunk
            batch = []
            for sid in self.prefilling:
                st = self.slots[sid]
                need = len(st["req"]["prompt"]) - st["prefilled"]
                if remaining == 0 and need > 0:
                    break  # head-of-line: later slots wait
                take = min(need, remaining)
                remaining -= take
                batch.append((sid, st["prefilled"], st["prefilled"] + take))
            for sid, cached, end in batch:
                st = self.slots[sid]
                prompt = st["req"]["prompt"]
                st["ctx"].extend(prompt[cached:end])
                self.compute_tokens += end - cached
                if end < len(prompt):
                    # non-final chunk: KV advanced, logits discarded
                    st["prefilled"] = end
                    continue
                st["prefilled"] = end
                st["pos"] = end
                tok = argmax_first(stub_logits(st["ctx"], self.vocab))
                st["generated"] = [tok]
                st["cur"] = tok
                st["first_token_step"] = entry
                st["last_token_step"] = entry
                done = (
                    st["req"].get("stop_token") == tok
                    or len(st["generated"]) >= st["req"]["max_new"]
                    or st["pos"] >= self.kv_cap
                )
                if done:
                    self._retire(sid, entry, out)
            self.prefilling = [
                sid
                for sid in self.prefilling
                if self.slots[sid] is not None and not self.slots[sid]["generated"]
            ]

        # --- one decode step over live slots with a first token,
        # ascending slot order (mid-prefill slots hold KV but nothing
        # to decode) ---
        rows = [
            sid
            for sid in range(self.pool)
            if self.slots[sid] is not None and self.slots[sid]["generated"]
        ]
        self.compute_tokens += len(rows)
        for sid in rows:
            st = self.slots[sid]
            st["ctx"].append(st["cur"])
            tok = argmax_first(stub_logits(st["ctx"], self.vocab))
            st["generated"].append(tok)
            st["cur"] = tok
            st["pos"] += 1
            st["last_token_step"] = entry
            done = (
                st["req"].get("stop_token") == tok
                or len(st["generated"]) >= st["req"]["max_new"]
                or st["pos"] >= self.kv_cap
            )
            if done:
                self._retire(sid, entry, out)
        return out


# ---------------------------------------------------------------------------
# rust/src/serving/engine.rs — EngineStepForward::plan_row
# ---------------------------------------------------------------------------


def plan_row(cached, n, mono_lens, cont_lens):
    """-> (is_cont, s, start, end); raises when no artifact can carry
    the row forward (the rust side bails with the same condition)."""
    max_mono = mono_lens[-1]
    if cached == 0:
        end = min(n, max_mono)
        s = next((l for l in mono_lens if l >= end), max_mono)
        return (False, s, 0, end)
    suffix = n - cached
    # full coverage: smallest cont s with suffix <= s <= n (the row
    # back-extends into cached tokens; overlap recomputed, not re-stored)
    s = next((s for s in cont_lens if suffix <= s <= n), None)
    if s is not None:
        return (True, s, n - s, n)
    # partial coverage: largest cont s entirely inside fresh tokens
    s = next((s for s in reversed(cont_lens) if s <= suffix), None)
    if s is not None:
        return (True, s, cached, cached + s)
    # no usable continuation artifact: monolithic recompute fallback
    end = min(n, max_mono)
    if end <= cached:
        raise ValueError(
            "prefill continuation impossible: %d cached, max mono %d" % (cached, max_mono)
        )
    s = next((l2 for l2 in mono_lens if l2 >= end), max_mono)
    return (False, s, 0, end)


# ---------------------------------------------------------------------------
# rust/src/bench_harness/exp_serving.rs — trace + token-time sim
# ---------------------------------------------------------------------------


def poisson(rng, lam):
    l = math.exp(-lam)
    k = 0
    p = 1.0
    while True:
        p *= rng.f32()
        if p <= l:
            return k
        k += 1


def gen_long_trace(rng, lam, n_req):
    out = []
    tick = 0
    while len(out) < n_req:
        for _ in range(poisson(rng, lam)):
            if len(out) >= n_req:
                break
            rid = len(out)
            long = rng.f32() < 0.25
            plen = 64 + rng.below(33) if long else 2 + rng.below(9)
            prompt = [rng.below(SWEEP_VOCAB) for _ in range(plen)]
            max_new = 2 + rng.below(8) if long else 4 + rng.below(13)
            out.append(
                (tick * CHUNK_ARRIVAL_TICK, {"id": rid, "prompt": prompt, "max_new": max_new})
            )
        tick += 1
    return out


def chunked_sim(trace, chunk):
    """Token-time replay: the clock advances by each step's metered
    compute; arrivals enqueue at the first step boundary at or after
    their stamp. Returns per-id streams, compute totals, and ttft/tpot
    samples in token units."""
    sim = Sim(SWEEP_POOL, chunk, SWEEP_VOCAB, SWEEP_KV_CAP)
    nxt = 0
    t_tok = 0
    step_end = []
    enq_step = {}
    arrival = {r["id"]: t for t, r in trace}
    raw = []
    while nxt < len(trace) or not sim.is_idle():
        if sim.is_idle() and nxt < len(trace) and trace[nxt][0] > t_tok:
            t_tok = trace[nxt][0]  # idle: jump to the next arrival
        while nxt < len(trace) and trace[nxt][0] <= t_tok:
            enq_step[trace[nxt][1]["id"]] = sim.step_idx
            sim.enqueue(trace[nxt][1])
            nxt += 1
        before = sim.compute_tokens
        raw.extend(sim.step())
        cost = max(sim.compute_tokens - before, 1)
        t_tok += cost
        step_end.append(t_tok)
        assert len(step_end) < 10_000_000, "chunked sim failed to converge"
    tokens_by_id = [None] * len(trace)
    ttft_tok = []
    tpot_tok = []
    for r in raw:
        rid = r["id"]
        # the rust post-processing reconstructs the first-token step as
        # enq_step + ttft_steps - 1; the sim recorded it directly, so
        # the identity itself is checked here
        ft = enq_step[rid] + r["ttft_steps"] - 1
        assert ft == r["first_token_step"], "ttft_steps reconstruction diverged"
        ttft_tok.append(float(step_end[ft] - arrival[rid]))
        span = r["decode_span_steps"]
        # the first decode shares the final-chunk step, so tokens 1 and
        # 2 land together: span is len-2 gaps (0 for single-token)
        assert span == max(len(r["tokens"]) - 2, 0), "decode span vs stream length"
        for s in range(ft, ft + span):
            tpot_tok.append(float(step_end[s + 1] - step_end[s]))
        tokens_by_id[rid] = r["tokens"]
    return {
        "tokens_by_id": tokens_by_id,
        "steps": len(step_end),
        "compute_tokens": sim.compute_tokens,
        "ttft_tok": ttft_tok,
        "tpot_tok": tpot_tok,
    }


# ---------------------------------------------------------------------------
# checks
# ---------------------------------------------------------------------------


def check_percentile():
    xs = [float(i) for i in range(101)]
    for p in (0.0, 50.0, 99.0, 100.0):
        assert abs(float(percentile(xs, p)) - p) < 1e-6
    assert abs(float(percentile([1.0, 2.0], 50.0)) - 1.5) < 1e-6, "linear interpolation"
    # NaN orders after +inf under total_cmp: low/mid percentiles stay
    # finite, only the top sees the NaN
    xs = [3.0, float("nan"), 1.0, 2.0]
    assert abs(float(percentile(xs, 50.0)) - 2.5) < 1e-6
    assert abs(float(percentile(xs, 0.0)) - 1.0) < 1e-6
    assert math.isnan(float(percentile(xs, 100.0)))
    assert math.isnan(float(percentile([float("nan")], 50.0)))
    assert float(percentile([], 50.0)) == 0.0
    print("ok: percentile (total_cmp ordering, NaN confined to the top)")


def check_chunk_budget(rand, cases=300):
    for _ in range(cases):
        n = rand.randint(1, 8)
        needs = [rand.randint(1, 100) for _ in range(n)]
        chunk = rand.choice([0, 1, 7, 32, 256])
        # the budget loop, verbatim
        remaining = math.inf if chunk == 0 else chunk
        takes = []
        for need in needs:
            if remaining == 0 and need > 0:
                break
            take = min(need, remaining)
            remaining -= take
            takes.append(take)
        if chunk == 0:
            assert takes == needs, "monolithic must complete everything"
        else:
            assert sum(takes) <= chunk, "budget exceeded"
            assert all(t >= 1 for t in takes), "zero-token take"
            # head-of-line: work is a prefix of admission order, and the
            # budget only stops short when it is actually exhausted
            if len(takes) < len(needs):
                assert sum(takes) == chunk, "stopped short with budget left"
    print(f"ok: chunk-budget plan (head-of-line, bounded, monolithic complete; {cases} cases)")


def check_token_identity(rand, cases=12):
    for _ in range(cases):
        n_req = rand.randint(4, 16)
        trace = []
        t = 0
        for rid in range(n_req):
            plen = rand.choice([1, 2, 5, 17, 40, 90])
            prompt = [rand.randrange(SWEEP_VOCAB) for _ in range(plen)]
            trace.append((t, {"id": rid, "prompt": prompt, "max_new": rand.randint(1, 12)}))
            t += rand.randint(0, 30)
        runs = [chunked_sim(trace, c) for c in (0, 1, 3, CHUNK_SWEEP_BUDGET, 256)]
        ref = [
            stub_reference(r["prompt"], r["max_new"], SWEEP_VOCAB, SWEEP_KV_CAP)
            for _, r in trace
        ]
        for run in runs:
            assert run["tokens_by_id"] == ref, "scheduled stream diverged from reference"
            assert run["compute_tokens"] == runs[0]["compute_tokens"], "compute changed"
    print(f"ok: token identity + compute equality across budgets ({cases} traces)")


def check_ttft_accounting(rand, cases=60):
    for _ in range(cases):
        plen = rand.randint(1, 120)
        chunk = rand.choice([0, 1, 5, 16, 32, 256])
        trace = [
            (0, {"id": 0, "prompt": [rand.randrange(SWEEP_VOCAB) for _ in range(plen)],
                 "max_new": rand.randint(1, 6)})
        ]
        run = chunked_sim(trace, chunk)
        sim = Sim(SWEEP_POOL, chunk, SWEEP_VOCAB, SWEEP_KV_CAP)
        sim.enqueue(trace[0][1])
        res = []
        while not sim.is_idle():
            res.extend(sim.step())
        want = 1 if chunk == 0 else math.ceil(plen / chunk)
        assert res[0]["ttft_steps"] == want, (
            f"uncontended ttft_steps {res[0]['ttft_steps']} != ceil({plen}/{chunk}) = {want}"
        )
        assert run["tokens_by_id"][0] == res[0]["tokens"]
    print(f"ok: uncontended ttft_steps == ceil(plen/chunk), stamped at the final chunk ({cases})")


def check_plan_row(rand, cases=500):
    mono = [16, 64]
    cont = list(range(CONT_GRID_STEP, 64 + 1, CONT_GRID_STEP))
    for _ in range(cases):
        n = rand.randint(1, 120)
        cached = rand.randint(0, n - 1)
        is_cont, s, start, end = plan_row(cached, n, mono, cont)
        assert end > cached, "plan made no progress"
        assert end <= n and start <= cached, "plan outside the row"
        if is_cont:
            assert s in cont and end - start == s, "cont suffix off the grid"
            # full coverage ends at n; partial fits entirely in fresh tokens
            assert end == n or start == cached
        else:
            assert start == 0 and s in mono or s == mono[-1]
            assert end <= mono[-1] or end <= n
    # the bail case: a cached extent at/past the largest monolithic
    # length with no continuation artifacts cannot move forward
    try:
        plan_row(70, 80, mono, [])
        raise AssertionError("expected plan_row to raise")
    except ValueError:
        pass
    print(f"ok: plan_row coverage/progress invariants ({cases} rows)")


def check_chunked_sweep():
    """The pinned-seed sweep the rust unit test asserts: seed 0xC0DE,
    96 requests. tpot_p99 must collapse at every load; ttft_p99 must
    drop outright at moderate load (λ = 2) and hold within 10% under
    overload (λ = 3), with streams and total compute untouched."""
    for lam in (2.0, 3.0):
        rng = Rng(0xC0DE ^ int(lam * 8.0) ^ 0xC41F)
        trace = gen_long_trace(rng, lam, 96)
        mono = chunked_sim(trace, 0)
        chunked = chunked_sim(trace, CHUNK_SWEEP_BUDGET)
        assert mono["tokens_by_id"] == chunked["tokens_by_id"], "token stream changed"
        ref = [
            stub_reference(r["prompt"], r["max_new"], SWEEP_VOCAB, SWEEP_KV_CAP)
            for _, r in trace
        ]
        assert mono["tokens_by_id"] == ref, "scheduled stream diverged from reference"
        assert mono["compute_tokens"] == chunked["compute_tokens"], "compute changed"
        mt, ct = percentile(mono["ttft_tok"], 99.0), percentile(chunked["ttft_tok"], 99.0)
        mp, cp = percentile(mono["tpot_tok"], 99.0), percentile(chunked["tpot_tok"], 99.0)
        assert float(cp) < float(mp), f"tpot_p99 not cut at λ={lam}: {cp} vs {mp}"
        assert float(ct) <= 1.10 * float(mt), f"ttft_p99 past 10% at λ={lam}: {ct} vs {mt}"
        if lam == 2.0:
            assert float(ct) < float(mt), f"ttft_p99 not cut at moderate load: {ct} vs {mt}"
        print(
            f"ok: λ={lam} ttft_p99 {float(mt):.0f}→{float(ct):.0f} tok, "
            f"tpot_p99 {float(mp):.0f}→{float(cp):.0f} tok "
            f"({mono['compute_tokens']} compute tokens both arms)"
        )
    print("ok: chunked sweep at seed 0xC0DE — decode-gap tail collapses, TTFT tail honest")


def main():
    rand = random.Random(0xC41F)
    check_percentile()
    check_chunk_budget(rand)
    check_token_identity(rand)
    check_ttft_accounting(rand)
    check_plan_row(rand)
    check_chunked_sweep()
    print("mirror_chunked_prefill: all checks passed")


if __name__ == "__main__":
    main()
