#!/usr/bin/env bash
# Local pre-PR gate (documented in docs/ARCHITECTURE.md):
#   build → tests → docs → clippy, all warnings fatal.
set -euo pipefail

cd "$(dirname "$0")/../rust"

if ! command -v cargo >/dev/null 2>&1; then
    echo "error: cargo not found on PATH — install a Rust toolchain (>= 1.70)" >&2
    echo "       (rustup.rs, or your distro's rustc+cargo packages)" >&2
    exit 1
fi

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

echo "==> cargo doc --no-deps"
RUSTDOCFLAGS="${RUSTDOCFLAGS:--D warnings}" cargo doc --no-deps

echo "==> cargo clippy --all-targets -- -D warnings"
cargo clippy --all-targets -- -D warnings

echo "check.sh: all gates passed"
