#!/usr/bin/env bash
# Local pre-PR gate (documented in docs/ARCHITECTURE.md):
#   build → tests → docs → clippy, all warnings fatal.
set -euo pipefail

cd "$(dirname "$0")/../rust"

if ! command -v cargo >/dev/null 2>&1; then
    echo "error: cargo not found on PATH — install a Rust toolchain (>= 1.70)" >&2
    echo "       (rustup.rs, or your distro's rustc+cargo packages)" >&2
    exit 1
fi

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

# Pin the conversion-pipeline contract explicitly and under the release
# profile (the debug pass above already ran them once; release reuses
# the build from step 1 and additionally catches optimization-dependent
# drift in the bit-identity guarantee): the staged Pipeline's cmoe
# method must stay bit-identical to converter::convert_model, and every
# registry method must satisfy the partition invariants.
echo "==> golden CMoE pipeline equivalence + method-registry parity (release)"
cargo test -q --release --test pipeline_golden --test method_registry

# Pin the continuous-batching contract the same way: the scheduler
# property suite (bucket/FIFO/slot invariants) and the seeded-trace
# simulation (token identity vs the run-to-completion reference, no
# starvation) are host-only — they must pass on a clone with no
# artifacts, and under --release to catch optimization-dependent drift.
echo "==> continuous-batching scheduler + seeded-trace simulation (release)"
cargo test -q --release --test scheduler --test continuous_sim

# Pin the paged-KV contract: randomized page-pool traces (no leak /
# double free / stale read, refcounts == live mappings, monotone high
# water) and the prefix-cache trie vs its brute-force reference (plus
# LRU eviction never touching a live-mapped prefix). Host-only, and
# release-pinned for the same optimization-drift reason.
echo "==> paged KV pool + prefix cache property suites (release)"
cargo test -q --release --test page_pool --test prefix_cache

# Pin the overload-survival contract: preempt/resume token invisibility
# (park and drop modes, random mixed-priority traces) and fault
# containment (any injected forward failure degrades one request, never
# the process). Host-only, release-pinned like the suites above.
echo "==> preemption + fault-containment property suites (release)"
cargo test -q --release --test preemption --test fault_injection

echo "==> cargo doc --no-deps"
RUSTDOCFLAGS="${RUSTDOCFLAGS:--D warnings}" cargo doc --no-deps

echo "==> cargo clippy --all-targets -- -D warnings"
cargo clippy --all-targets -- -D warnings

echo "check.sh: all gates passed"
