#!/usr/bin/env bash
# Local pre-PR gate (documented in docs/ARCHITECTURE.md).
#
# With a rust toolchain on PATH this is the real thing:
#   build → tests → release-pinned property suites → docs → clippy
#   → the artifact-free bench exports (repo-root BENCH_*.json),
# all warnings fatal.
#
# Without one (the repo's historical situation — see the ROADMAP
# caveat) it falls back, loudly, to the committed line-faithful python
# mirrors under scripts/mirror_*.py so the algorithmic core is still
# exercised. The fallback is NOT the gate: it validates the math, not
# the crate.
set -euo pipefail

repo="$(cd "$(dirname "$0")/.." && pwd)"

if ! command -v cargo >/dev/null 2>&1; then
    echo "!! ==================================================================" >&2
    echo "!! check.sh: no rust toolchain on PATH — the REAL tier-1 gate"         >&2
    echo "!! (cargo build/test, release-pinned suites, clippy, bench exports)"   >&2
    echo "!! DID NOT RUN. Falling back to the line-faithful python mirrors."     >&2
    echo "!! Install rustc+cargo (>= 1.70, rustup.rs) and re-run for the gate."  >&2
    echo "!! ==================================================================" >&2
    py=python3
    command -v "$py" >/dev/null 2>&1 || { echo "error: python3 not found either — nothing can run" >&2; exit 1; }
    status=0
    for mirror in "$repo"/scripts/mirror_*.py; do
        [ -e "$mirror" ] || { echo "error: no mirror scripts found under scripts/" >&2; exit 1; }
        echo "==> $py ${mirror#"$repo"/}"
        "$py" "$mirror" || status=$?
    done
    if [ "$status" -ne 0 ]; then
        echo "check.sh: python mirrors FAILED (and the real gate never ran)" >&2
        exit "$status"
    fi
    echo "check.sh: python mirrors passed — but the rust gate DID NOT RUN" >&2
    exit 0
fi

cd "$repo/rust"

echo "==> cargo build --release"
cargo build --release

# Static invariant gate first — it is the cheapest check and its
# failures (stray Instant::now, unwrap in serving/, mirror drift) are
# the ones most likely to slip through a green test run. The python
# twin of this step is scripts/mirror_lint.py (same rules, same lexer).
echo "==> cmoe lint (static invariant gate)"
cargo run --release --quiet -- lint

echo "==> cargo test -q"
cargo test -q

# Pin the conversion-pipeline contract explicitly and under the release
# profile (the debug pass above already ran them once; release reuses
# the build from step 1 and additionally catches optimization-dependent
# drift in the bit-identity guarantee): the staged Pipeline's cmoe
# method must stay bit-identical to converter::convert_model, and every
# registry method must satisfy the partition invariants.
echo "==> golden CMoE pipeline equivalence + method-registry parity (release)"
cargo test -q --release --test pipeline_golden --test method_registry

# Pin the continuous-batching contract the same way: the scheduler
# property suite (bucket/FIFO/slot invariants) and the seeded-trace
# simulation (token identity vs the run-to-completion reference, no
# starvation) are host-only — they must pass on a clone with no
# artifacts, and under --release to catch optimization-dependent drift.
echo "==> continuous-batching scheduler + seeded-trace simulation (release)"
cargo test -q --release --test scheduler --test continuous_sim

# Pin the paged-KV contract: randomized page-pool traces (no leak /
# double free / stale read, refcounts == live mappings, monotone high
# water) and the prefix-cache trie vs its brute-force reference (plus
# LRU eviction never touching a live-mapped prefix). Host-only, and
# release-pinned for the same optimization-drift reason.
echo "==> paged KV pool + prefix cache property suites (release)"
cargo test -q --release --test page_pool --test prefix_cache

# Pin the overload-survival contract: preempt/resume token invisibility
# (park and drop modes, random mixed-priority traces) and fault
# containment (any injected forward failure degrades one request, never
# the process). Host-only, release-pinned like the suites above.
echo "==> preemption + fault-containment property suites (release)"
cargo test -q --release --test preemption --test fault_injection

# Pin the dynamic-activation contract: threshold-0 dynamic-k must be
# bit-identical to fixed top-k from routing through the grouped forward
# (the strongest optimization-drift candidate in the repo — float
# compares under --release), and effort tiers must change the forward
# (not just the gauges) while Full-tier streams stay bit-identical with
# tiering on or off, across preemption in both modes.
echo "==> dynamic-k + effort-tier property suites (release)"
cargo test -q --release --test dynamic_k --test effort_tiers

# Pin the chunked-prefill contract: any per-step prefill token budget
# (prefix cache on or off, preemption mid-prefill included) must be
# token-invisible and leak-free, with TTFT stamped at the final chunk
# and never fabricated for requests that die before a first token.
echo "==> chunked-prefill property suite (release)"
cargo test -q --release --test chunked_prefill

# Pin the expert-storage contract: all-Fp32Resident paths (slices and
# quant-off TieredStore) bit-identical through the trait-generic
# dispatcher, int8 band divergence inside the per-token gate-weighted
# analytic bound, and residency bookkeeping exactly matching an
# independent shadow model under routing drift. Float compares under
# --release are exactly the optimization-drift candidates this pin is
# for; the python twin is scripts/mirror_quant.py.
echo "==> expert-storage + residency-tier property suite (release)"
cargo test -q --release --test quant_store

echo "==> cargo doc --no-deps"
RUSTDOCFLAGS="${RUSTDOCFLAGS:--D warnings}" cargo doc --no-deps

echo "==> cargo clippy --all-targets -- -D warnings"
cargo clippy --all-targets -- -D warnings

# Regenerate the artifact-free bench exports (repo-root BENCH_*.json):
# dispatch + slo + quant each export their own file; serving refreshes
# BENCH_serving, BENCH_prefix and BENCH_dynk in one run. These are the
# cross-PR trajectory artifacts the ROADMAP tracks.
echo "==> bench exports (BENCH_dispatch/serving/prefix/slo/dynk/quant.json)"
cargo run --release --quiet -- bench --exp dispatch --out results
cargo run --release --quiet -- bench --exp slo --out results
cargo run --release --quiet -- bench --exp serving --out results
cargo run --release --quiet -- bench --exp quant --out results

echo "check.sh: all gates passed"
