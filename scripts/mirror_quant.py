#!/usr/bin/env python3
"""Line-faithful python mirror of the int8 expert-storage math.

`scripts/check.sh` runs this as the fallback gate when no rust
toolchain is on PATH (the repo's historical situation — see the
ROADMAP's standing caveat). Every function here transcribes its rust
counterpart statement by statement in float32 semantics (numpy), so a
behavioral disagreement is a bug in one of the two, not a modeling
artifact:

  quantize / dequantize  <- rust/src/quant/mod.rs  QuantizedTensor
  matmul_rows_q8         <- rust/src/tensor/ops.rs matmul_rows_q8
                            (fused dequant epilogue; f32 accumulation
                            in the same kk-ascending order)
  swiglu_rows_q8         <- rust/src/quant/mod.rs  QuantizedFfn::
                            swiglu_rows_into (silu from tensor/ops.rs)
  divergence_bound       <- rust/src/quant/mod.rs  QuantizedFfn::
                            divergence_bound (interval propagation)
  TieredStore.note_step  <- rust/src/moe/store.rs  TieredStore
                            (EMA residency policy, exact transitions)

The checks mirror what `rust/src/quant/mod.rs`'s unit tests and
`rust/tests/quant_store.rs` pin natively:

  1. per-column symmetric quantization round-trips within
     max_error_bound (= max scale / 2), zero columns get scale 1.0 and
     stay finite, and quantized_bytes accounting gives exactly the
     4r/(r+4) compression algebra — strictly below 4x;
  2. the fused-dequant kernel (raw sum x*q, scale epilogue) agrees with
     dequantize-then-fp32-matmul to f32 tolerance on random bands;
  3. the int8 SwiGLU's true divergence from the fp32 original stays
     inside the analytic divergence_bound on randomized FFNs and
     input scales (the soundness property the rust suite asserts);
  4. TieredStore policy replay: cold-start warm set is the first `cap`
     experts, hits/misses meter against the residency the step
     dispatched under, drifted traffic misses then prefetches exactly
     once per drifted-to expert and demotes exactly once per
     drifted-from expert, quant=False is the identity policy, and no
     expert is ever without a view;
  5. note_step against an independent shadow model (recomputed EMA +
     top-cap sort per step) agrees on every hit/miss/prefetch/demotion
     count over long random traces.

Exits 0 and prints a one-line summary per check on success; raises on
the first violation.
"""

import math
import random

import numpy as np

F32 = np.float32

# Shared numeric constants, registered with the mirror-drift rule of
# `cmoe lint`: each NAME below must define the same value as its rust
# counterpart (lint/drift.rs REGISTRY names the file pairs), or the
# lint gate fails.
INT8_CLAMP = 127.0  # rust/src/quant/mod.rs
SCALE_EPS = 0.00000001  # rust/src/quant/mod.rs
RESIDENCY_EMA_DECAY = 0.875  # rust/src/moe/store.rs
DEFAULT_RESIDENT_CAP = 6  # rust/src/moe/store.rs

SILU_LIP = 1.1  # rust/src/quant/mod.rs (private const)

FP32_RESIDENT = "Fp32Resident"
INT8_RESIDENT = "Int8Resident"
INT8_HOST = "Int8Host"


def silu(x):
    # rust/src/tensor/ops.rs silu: x / (1 + exp(-x)), f32 end to end
    x = np.asarray(x, dtype=F32)
    return (x / (F32(1.0) + np.exp(-x, dtype=F32))).astype(F32)


# ---------------------------------------------------------------------------
# rust/src/quant/mod.rs — QuantizedTensor
# ---------------------------------------------------------------------------


def quantize(w):
    """Column-wise symmetric int8: q = round(w / s), s = max|w_col|/127."""
    w = np.asarray(w, dtype=F32)
    assert w.ndim == 2
    col_max = np.max(np.abs(w), axis=0).astype(F32)
    scales = np.where(col_max > F32(SCALE_EPS), col_max / F32(INT8_CLAMP), F32(1.0)).astype(F32)
    q = np.clip(np.round(w / scales), -INT8_CLAMP, INT8_CLAMP).astype(np.int8)
    return q, scales


def dequantize(q, scales):
    return (q.astype(F32) * scales.astype(F32)).astype(F32)


def max_error_bound(scales):
    return F32(np.max(scales) * F32(0.5)) if scales.size else F32(0.0)


def quantized_bytes(q, scales):
    # int8 payload + one f32 scale per output column
    return q.size + scales.size * 4


# ---------------------------------------------------------------------------
# rust/src/tensor/ops.rs — matmul_rows_q8 (fused dequant epilogue)
# ---------------------------------------------------------------------------

KB = 64  # k-block, matching the fp32 band kernel


def matmul_rows_q8(a_rows, q, scales, k, n):
    """Raw sum(x*q) accumulated in f32, kk-ascending inside KB blocks,
    then one per-column scale multiply — same accumulation order as the
    rust kernel, so the two agree bit-for-bit per output element."""
    a_rows = np.asarray(a_rows, dtype=F32).reshape(-1, k)
    rows = a_rows.shape[0]
    qf = q.astype(F32).reshape(k, n)
    out = np.zeros((rows, n), dtype=F32)
    for kb in range(0, k, KB):
        k_end = min(kb + KB, k)
        for r in range(rows):
            for kk in range(kb, k_end):
                av = a_rows[r, kk]
                if av == F32(0.0):
                    continue  # zero-skip, same as the rust kernel
                out[r] += (av * qf[kk]).astype(F32)
    return (out * scales.astype(F32)).astype(F32)


# ---------------------------------------------------------------------------
# rust/src/quant/mod.rs — QuantizedFfn forward + divergence bound
# ---------------------------------------------------------------------------


def swiglu_rows(x_rows, w_gate, w_up, w_down):
    """fp32 reference band: silu(x@Wg) * (x@Wu) @ Wd in f32."""
    x = np.asarray(x_rows, dtype=F32)
    g = (x @ np.asarray(w_gate, dtype=F32)).astype(F32)
    u = (x @ np.asarray(w_up, dtype=F32)).astype(F32)
    h = (silu(g) * u).astype(F32)
    return (h @ np.asarray(w_down, dtype=F32)).astype(F32)


class QuantFfn:
    def __init__(self, w_gate, w_up, w_down):
        self.d = np.asarray(w_gate).shape[0]
        self.m = np.asarray(w_gate).shape[1]
        self.g_q, self.g_s = quantize(w_gate)
        self.u_q, self.u_s = quantize(w_up)
        self.d_q, self.d_s = quantize(w_down)

    def quantized_bytes(self):
        return (
            quantized_bytes(self.g_q, self.g_s)
            + quantized_bytes(self.u_q, self.u_s)
            + quantized_bytes(self.d_q, self.d_s)
        )

    def swiglu_rows_q8(self, x_rows):
        d, m = self.d, self.m
        hidden = matmul_rows_q8(x_rows, self.g_q, self.g_s, d, m)
        up = matmul_rows_q8(x_rows, self.u_q, self.u_s, d, m)
        h = (silu(hidden) * up).astype(F32)
        return matmul_rows_q8(h, self.d_q, self.d_s, m, d)

    def divergence_bound(self, x_rows):
        d, m = self.d, self.m
        x = np.asarray(x_rows, dtype=F32).reshape(-1, d)
        if x.shape[0] == 0:
            return 0.0
        bg = float(max_error_bound(self.g_s))
        bu = float(max_error_bound(self.u_s))
        bd = float(max_error_bound(self.d_s))
        wd_max = float(np.max(np.abs(dequantize(self.d_q, self.d_s))))
        hidden = matmul_rows_q8(x, self.g_q, self.g_s, d, m)
        up = matmul_rows_q8(x, self.u_q, self.u_s, d, m)
        worst = 0.0
        for r in range(x.shape[0]):
            x_abs = float(np.sum(np.abs(x[r])))
            dg = x_abs * bg
            du = x_abs * bu
            sg = np.abs(silu(hidden[r]))
            ua = np.abs(up[r])
            sum_h = float(np.sum(sg * ua))
            sum_dh = float(np.sum(sg * du + (ua + du) * SILU_LIP * dg))
            worst = max(worst, sum_h * bd + sum_dh * (wd_max + bd))
        return worst


# ---------------------------------------------------------------------------
# rust/src/moe/store.rs — TieredStore residency policy
# ---------------------------------------------------------------------------


class TieredStore:
    def __init__(self, n, quant, resident_cap):
        cap = max(resident_cap, 1)
        cap = min(cap, max(n, 1))
        if quant:
            # cold-start: first cap experts warm, rest cold
            self.residency = [INT8_RESIDENT if e < cap else INT8_HOST for e in range(n)]
        else:
            self.residency = [FP32_RESIDENT] * n
        self.ema = [0.0] * n
        self.resident_cap = cap
        self.quant = quant
        self.n = n

    def view(self, e):
        # no-lost-experts: every index always resolves to a tier
        assert 0 <= e < self.n
        return "int8" if self.quant else "fp32"

    def note_step(self, counts):
        assert len(counts) == self.n
        delta = {"hits": 0, "misses": 0, "prefetches": 0, "demotions": 0}
        for e, c in enumerate(counts):
            if c == 0:
                continue
            if self.residency[e] == INT8_HOST:
                delta["misses"] += 1
            else:
                delta["hits"] += 1
        if not self.quant:
            return delta
        total = sum(counts)
        for e, c in enumerate(counts):
            frac = 0.0 if total == 0 else F32(F32(c) / F32(total))
            self.ema[e] = float(
                F32(F32(RESIDENCY_EMA_DECAY) * F32(self.ema[e]))
                + F32(F32(1.0 - RESIDENCY_EMA_DECAY) * F32(frac))
            )
        # warm set = top resident_cap by EMA, ties break on index
        order = sorted(range(self.n), key=lambda e: (-self.ema[e], e))
        for rank, e in enumerate(order):
            want = INT8_RESIDENT if rank < self.resident_cap else INT8_HOST
            if self.residency[e] == INT8_HOST and want == INT8_RESIDENT:
                delta["prefetches"] += 1
            elif self.residency[e] == INT8_RESIDENT and want == INT8_HOST:
                delta["demotions"] += 1
            self.residency[e] = want
        return delta


# ---------------------------------------------------------------------------
# checks
# ---------------------------------------------------------------------------


def rand_mat(rand, r, c, std=0.5):
    return np.asarray(
        [[rand.gauss(0.0, std) for _ in range(c)] for _ in range(r)], dtype=F32
    )


def check_quantize_roundtrip(rand, cases=60):
    for _ in range(cases):
        r, c = rand.randint(2, 48), rand.randint(2, 40)
        w = rand_mat(rand, r, c)
        if rand.random() < 0.3:
            w[:, rand.randrange(c)] = 0.0  # plant an all-zero column
        q, s = quantize(w)
        back = dequantize(q, s)
        assert np.all(np.isfinite(back)), "dequantize produced non-finite values"
        err = float(np.max(np.abs(w - back)))
        bound = float(max_error_bound(s)) + 1e-6
        assert err <= bound, f"roundtrip err {err} > bound {bound}"
        assert np.all(np.abs(q.astype(np.int32)) <= int(INT8_CLAMP)), "-128 leaked"
        assert quantized_bytes(q, s) == r * c + c * 4, "byte accounting drifted"
        # ratio = 4rc / (rc + 4c) = 4r / (r + 4): strictly below 4x,
        # approaching it as rows grow — scales are not free
        ratio = (r * c * 4) / quantized_bytes(q, s)
        assert abs(ratio - 4 * r / (r + 4)) < 1e-9 and ratio < 4.0, f"ratio {ratio}"
    print(f"ok: symmetric per-column int8 roundtrip + byte accounting ({cases} mats)")


def check_fused_kernel(rand, cases=40):
    for _ in range(cases):
        k, n = rand.randint(2, 96), rand.randint(2, 24)
        rows = rand.randint(1, 6)
        w = rand_mat(rand, k, n)
        x = rand_mat(rand, rows, k, std=1.0)
        if rand.random() < 0.5:
            x[x < 0.4] = 0.0  # exercise the zero-skip path
        q, s = quantize(w)
        fused = matmul_rows_q8(x, q, s, k, n)
        sim = (x @ dequantize(q, s)).astype(F32)
        tol = 1e-3 * max(1.0, float(np.max(np.abs(sim))))
        worst = float(np.max(np.abs(fused - sim)))
        assert worst <= tol, f"fused dequant diverged from simulated: {worst} > {tol}"
    print(f"ok: fused-dequant kernel matches dequantize-then-matmul ({cases} bands)")


def check_divergence_bound(rand, cases=25):
    nonzero = 0
    for _ in range(cases):
        d, m = rand.randint(4, 16), rand.randint(4, 32)
        rows = rand.randint(1, 8)
        wg, wu = rand_mat(rand, d, m), rand_mat(rand, d, m)
        wd = rand_mat(rand, m, d)
        qf = QuantFfn(wg, wu, wd)
        for scale in (0.5, 1.0, 2.0):
            x = rand_mat(rand, rows, d, std=scale)
            y_q = qf.swiglu_rows_q8(x)
            y_fp = swiglu_rows(x, wg, wu, wd)
            worst = float(np.max(np.abs(y_q - y_fp)))
            bound = qf.divergence_bound(x)
            assert worst <= bound * 1.01 + 1e-4, f"divergence {worst} > bound {bound}"
            if worst > 0.0:
                nonzero += 1
    assert nonzero > 0, "int8 never diverged from fp32 — quantization is a no-op?"
    print(f"ok: int8 SwiGLU divergence inside analytic bound ({cases} ffns x 3 scales)")


def check_residency_policy():
    # quant=False: identity policy, hits only, no transitions ever
    off = TieredStore(4, False, 2)
    for _ in range(10):
        d = off.note_step([5, 0, 1, 0])
        assert d == {"hits": 2, "misses": 0, "prefetches": 0, "demotions": 0}
    assert off.residency == [FP32_RESIDENT] * 4 and off.view(3) == "fp32"

    # quant=True: cold start warms the first cap experts
    st = TieredStore(4, True, 2)
    assert st.residency == [INT8_RESIDENT, INT8_RESIDENT, INT8_HOST, INT8_HOST]
    misses = 0
    for _ in range(8):
        misses += st.note_step([8, 8, 0, 0])["misses"]
    assert misses == 0, "warm experts missed"
    # drift: traffic moves to experts 2/3 — miss first, then exactly one
    # prefetch each and exactly one demotion each for 0/1
    pf = dm = ms = 0
    for _ in range(20):
        s = st.note_step([0, 0, 8, 8])
        pf += s["prefetches"]
        dm += s["demotions"]
        ms += s["misses"]
    assert ms > 0, "cold experts never missed before promotion"
    assert pf == 2 and dm == 2, f"drift transitions pf={pf} dm={dm}, want 2/2"
    assert st.residency == [INT8_HOST, INT8_HOST, INT8_RESIDENT, INT8_RESIDENT]
    s = st.note_step([0, 0, 8, 8])
    assert s == {"hits": 2, "misses": 0, "prefetches": 0, "demotions": 0}
    # cap clamps into [1, n] and every expert always has a view
    tiny = TieredStore(3, True, 99)
    assert tiny.resident_cap == 3
    assert all(tiny.view(e) == "int8" for e in range(3))
    assert TieredStore(5, True, 0).resident_cap == 1
    print("ok: residency policy (cold start, drift prefetch/demote, identity off)")


def check_residency_shadow(rand, steps=300, n=9):
    """Replay a random trace through note_step and an independent shadow
    model; every counter must agree exactly at every step."""
    cap = 3
    st = TieredStore(n, True, cap)
    ema = [0.0] * n
    res = [INT8_RESIDENT if e < cap else INT8_HOST for e in range(n)]
    hot = list(range(n))  # drifting preference order
    for step in range(steps):
        if step % 40 == 0:
            rand.shuffle(hot)
        counts = [0] * n
        for _ in range(16):
            e = hot[min(rand.randrange(1, 4), rand.randrange(1, 4)) - 1]
            if rand.random() < 0.15:
                e = rand.randrange(n)
            counts[e] += 1
        got = st.note_step(counts)
        # shadow: recompute hits/misses against pre-update residency,
        # then EMA + full re-sort, counting transitions
        want = {"hits": 0, "misses": 0, "prefetches": 0, "demotions": 0}
        for e, c in enumerate(counts):
            if c == 0:
                continue
            want["misses" if res[e] == INT8_HOST else "hits"] += 1
        total = sum(counts)
        for e in range(n):
            frac = 0.0 if total == 0 else counts[e] / total
            ema[e] = RESIDENCY_EMA_DECAY * ema[e] + (1.0 - RESIDENCY_EMA_DECAY) * frac
        order = sorted(range(n), key=lambda e: (-ema[e], e))
        warm = set(order[:cap])
        for e in range(n):
            w = INT8_RESIDENT if e in warm else INT8_HOST
            if res[e] == INT8_HOST and w == INT8_RESIDENT:
                want["prefetches"] += 1
            elif res[e] == INT8_RESIDENT and w == INT8_HOST:
                want["demotions"] += 1
            res[e] = w
        # f32 vs f64 EMA can disagree only at exact ties, which the
        # index tie-break resolves identically; counters must match
        assert got == want, f"step {step}: note_step {got} != shadow {want}"
        assert res == st.residency, f"step {step}: residency diverged"
        assert sum(1 for r in st.residency if r == INT8_RESIDENT) == cap
    print(f"ok: note_step equals independent shadow model over {steps} steps")


def check_paper_defaults():
    # spot-check registered values against their definitions
    assert INT8_CLAMP == 127.0 and SCALE_EPS == 1e-8
    assert RESIDENCY_EMA_DECAY == 0.875 and DEFAULT_RESIDENT_CAP == 6
    # half-life of the EMA at decay 0.875 is ~5.2 steps — a cap-6 warm
    # set re-converges within a few steps of a routing shift
    half_life = math.log(0.5) / math.log(RESIDENCY_EMA_DECAY)
    assert 4.0 < half_life < 6.0
    print("ok: registered constants and EMA half-life sanity")


def main():
    rand = random.Random(0x0E8)
    check_quantize_roundtrip(rand)
    check_fused_kernel(rand)
    check_divergence_bound(rand)
    check_residency_policy()
    check_residency_shadow(rand)
    check_paper_defaults()
    print("mirror_quant: all checks passed")


if __name__ == "__main__":
    main()
