#!/usr/bin/env python3
"""Line-faithful python mirror of the `cmoe lint` static-analysis gate.

`scripts/check.sh` runs this as the fallback gate when no rust
toolchain is on PATH (the repo's historical situation — see the
ROADMAP's standing caveat). Every function transcribes its rust
counterpart statement by statement, so a behavioral disagreement is a
bug in one of the two, not a modeling artifact:

  scan / scan_py        <- rust/src/lint/lexer.rs   scan, scan_py
  parse_directives      <- rust/src/lint/rules.rs   parse_directives
  allowed_lines         <- rust/src/lint/rules.rs   allowed_lines
  test_regions          <- rust/src/lint/rules.rs   test_regions
  scan_rules            <- rust/src/lint/rules.rs   scan_rules
  REGISTRY / check_drift<- rust/src/lint/drift.rs   REGISTRY, check
  lint_source/lint_tree <- rust/src/lint/mod.rs     lint_source, lint_tree

Run modes:

  1. with no arguments: fixture self-tests (each rule fires on a
     known-bad snippet, the allowlist suppresses with a reason and
     rejects without one), then the full-tree lint. Exits nonzero and
     prints findings if the tree is not clean — this IS the gate on
     rustc-less images.
  2. `--self-test-only`: just the fixtures (used by debugging).

The five rules and their scopes are documented in
docs/ARCHITECTURE.md ("Static invariants") and rust/src/lint/mod.rs.
"""

import os
import sys

# ---------------------------------------------------------------------------
# rust/src/lint/lexer.rs — token model: (line, kind, value)
#   kind "ident"/"num": value is the text; kind "sym": value is one char
# ---------------------------------------------------------------------------


def _is_ident_start(c):
    return c.isalpha() or c == "_"


def _is_ident_cont(c):
    return c.isalnum() or c == "_"


def scan(src):
    """Tokenize rust source; returns (tokens, comments).

    tokens: list of (line, kind, value) with comments and string/char
    literal contents stripped. comments: list of (line, text) for every
    `//` line comment.
    """
    cs = src
    n = len(cs)
    i = 0
    line = 1
    tokens = []
    comments = []
    while i < n:
        c = cs[i]
        if c == "\n":
            line += 1
            i += 1
            continue
        if c.isspace():
            i += 1
            continue
        if c == "/" and i + 1 < n and cs[i + 1] == "/":
            start = i + 2
            j = start
            while j < n and cs[j] != "\n":
                j += 1
            comments.append((line, cs[start:j]))
            i = j
            continue
        if c == "/" and i + 1 < n and cs[i + 1] == "*":
            depth = 1
            i += 2
            while i < n and depth > 0:
                if cs[i] == "\n":
                    line += 1
                    i += 1
                elif cs[i] == "/" and i + 1 < n and cs[i + 1] == "*":
                    depth += 1
                    i += 2
                elif cs[i] == "*" and i + 1 < n and cs[i + 1] == "/":
                    depth -= 1
                    i += 2
                else:
                    i += 1
            continue
        if c in ("r", "b"):
            if c == "r":
                raw_candidate, j = True, i + 1
            elif i + 1 < n and cs[i + 1] == "r":
                raw_candidate, j = True, i + 2
            else:
                raw_candidate, j = False, i + 1
            if raw_candidate:
                hashes = 0
                while j < n and cs[j] == "#":
                    hashes += 1
                    j += 1
                if j < n and cs[j] == '"':
                    i = j + 1
                    while i < n:
                        if cs[i] == "\n":
                            line += 1
                            i += 1
                            continue
                        if cs[i] == '"':
                            k = 0
                            while k < hashes and i + 1 + k < n and cs[i + 1 + k] == "#":
                                k += 1
                            if k == hashes:
                                i += 1 + hashes
                                break
                        i += 1
                    continue
                # not a raw string — fall through to identifier
            elif j < n and (cs[j] == '"' or cs[j] == "'"):
                quote = cs[j]
                i = j + 1
                while i < n:
                    if cs[i] == "\\":
                        if i + 1 < n and cs[i + 1] == "\n":
                            line += 1
                        i += 2
                        continue
                    if cs[i] == "\n":
                        line += 1
                        i += 1
                        continue
                    if cs[i] == quote:
                        i += 1
                        break
                    i += 1
                continue
        if c == '"':
            i += 1
            while i < n:
                if cs[i] == "\\":
                    if i + 1 < n and cs[i + 1] == "\n":
                        line += 1
                    i += 2
                    continue
                if cs[i] == "\n":
                    line += 1
                    i += 1
                    continue
                if cs[i] == '"':
                    i += 1
                    break
                i += 1
            continue
        if c == "'":
            if i + 1 < n and cs[i + 1] == "\\":
                i += 3
                while i < n and cs[i] != "'":
                    if cs[i] == "\n":
                        line += 1
                    i += 1
                i += 1
                continue
            if i + 2 < n and cs[i + 2] == "'" and cs[i + 1] != "'":
                i += 3
                continue
            i += 1
            continue
        if _is_ident_start(c):
            s = i
            i += 1
            while i < n and _is_ident_cont(cs[i]):
                i += 1
            tokens.append((line, "ident", cs[s:i]))
            continue
        if c.isdigit():
            s = i
            hexlit = c == "0" and i + 1 < n and cs[i + 1] in ("x", "X")
            i += 1
            while i < n:
                d = cs[i]
                if d.isalnum() or d == "_":
                    i += 1
                    if (
                        not hexlit
                        and d in ("e", "E")
                        and i < n
                        and cs[i] in ("+", "-")
                    ):
                        i += 1
                    continue
                if d == "." and i + 1 < n and cs[i + 1].isdigit():
                    i += 1
                    continue
                break
            tokens.append((line, "num", cs[s:i]))
            continue
        tokens.append((line, "sym", c))
        i += 1
    return tokens, comments


def _skip_py_string(cs, i, line):
    """Mirror of lexer.rs skip_py_string; returns (next_index, line)."""
    n = len(cs)
    q = cs[i]
    triple = i + 2 < n and cs[i + 1] == q and cs[i + 2] == q
    if triple:
        i += 3
        while i < n:
            if cs[i] == "\n":
                line += 1
                i += 1
                continue
            if cs[i] == "\\":
                if i + 1 < n and cs[i + 1] == "\n":
                    line += 1
                i += 2
                continue
            if cs[i] == q and i + 2 < n and cs[i + 1] == q and cs[i + 2] == q:
                return i + 3, line
            if cs[i] == q and i + 2 >= n:
                return n, line
            i += 1
        return n, line
    i += 1
    while i < n:
        if cs[i] == "\\":
            if i + 1 < n and cs[i + 1] == "\n":
                line += 1
            i += 2
            continue
        if cs[i] == "\n":
            line += 1
            return i + 1, line
        if cs[i] == q:
            return i + 1, line
        i += 1
    return n, line


def scan_py(src):
    """Python-lite tokenizer (mirror-drift only); mirrors lexer.rs scan_py."""
    cs = src
    n = len(cs)
    i = 0
    line = 1
    tokens = []
    comments = []
    while i < n:
        c = cs[i]
        if c == "\n":
            line += 1
            i += 1
            continue
        if c.isspace():
            i += 1
            continue
        if c == "#":
            start = i + 1
            j = start
            while j < n and cs[j] != "\n":
                j += 1
            comments.append((line, cs[start:j]))
            i = j
            continue
        if c == '"' or c == "'":
            i, line = _skip_py_string(cs, i, line)
            continue
        if _is_ident_start(c):
            s = i
            i += 1
            while i < n and _is_ident_cont(cs[i]):
                i += 1
            word = cs[s:i]
            is_prefix = (
                len(word) <= 2
                and all(ch in "rRbBuUfF" for ch in word)
                and i < n
                and (cs[i] == '"' or cs[i] == "'")
            )
            if is_prefix:
                i, line = _skip_py_string(cs, i, line)
                continue
            tokens.append((line, "ident", word))
            continue
        if c.isdigit():
            s = i
            hexlit = c == "0" and i + 1 < n and cs[i + 1] in ("x", "X")
            i += 1
            while i < n:
                d = cs[i]
                if d.isalnum() or d == "_":
                    i += 1
                    if (
                        not hexlit
                        and d in ("e", "E")
                        and i < n
                        and cs[i] in ("+", "-")
                    ):
                        i += 1
                    continue
                if d == "." and i + 1 < n and cs[i + 1].isdigit():
                    i += 1
                    continue
                break
            tokens.append((line, "num", cs[s:i]))
            continue
        tokens.append((line, "sym", c))
        i += 1
    return tokens, comments


# ---------------------------------------------------------------------------
# rust/src/lint/rules.rs — directives, scopes, token rules
# ---------------------------------------------------------------------------

KNOWN_RULES = [
    "clock-discipline",
    "panic-discipline",
    "hot-path-alloc",
    "determinism",
    "mirror-drift",
]
RULE_ALLOW_SYNTAX = "allow-syntax"

LINT_PREFIX = "lint:"  # kept out of comment position so self-lint stays clean
ALLOW_OPEN = "allow("


def _is_sym(t, c):
    return t[1] == "sym" and t[2] == c


def _is_ident(t, name):
    return t[1] == "ident" and t[2] == name


def _ident(t):
    return t[2] if t[1] == "ident" else None


def parse_directives(comments):
    """Each directive: ("allow", line, rule) | ("hot-path", line)
    | ("malformed", line, message)."""
    out = []
    for line, raw in comments:
        t = raw.lstrip("/!").strip()
        if not t.startswith(LINT_PREFIX):
            continue
        body = t[len(LINT_PREFIX):].strip()
        if body == "hot-path":
            out.append(("hot-path", line))
            continue
        if body.startswith(ALLOW_OPEN):
            rest = body[len(ALLOW_OPEN):]
            p = rest.find(")")
            if p < 0:
                out.append(("malformed", line, "unclosed `allow(` directive"))
                continue
            rule = rest[:p].strip()
            reason = rest[p + 1:].strip()
            while reason[:1] in ("—", "–", "-", ":", ","):
                reason = reason[1:].strip()
            if rule not in KNOWN_RULES:
                out.append(("malformed", line, "allow() names unknown rule `%s`" % rule))
            elif not reason:
                out.append(
                    ("malformed", line, "allow(%s) requires a written reason" % rule)
                )
            else:
                out.append(("allow", line, rule))
            continue
        out.append(("malformed", line, "unrecognized lint directive `%s`" % body))
    return out


def allowed_lines(directives):
    out = {}
    for d in directives:
        if d[0] == "allow":
            _, line, rule = d
            out.setdefault(line, set()).add(rule)
            out.setdefault(line + 1, set()).add(rule)
    return out


def match_brace(tokens, opening):
    depth = 0
    i = opening
    while i < len(tokens):
        if _is_sym(tokens[i], "{"):
            depth += 1
        elif _is_sym(tokens[i], "}"):
            depth -= 1
            if depth == 0:
                return i
        i += 1
    return max(len(tokens) - 1, 0)


def test_regions(tokens):
    out = []
    i = 0
    while i + 6 < len(tokens):
        is_cfg_test = (
            _is_sym(tokens[i], "#")
            and _is_sym(tokens[i + 1], "[")
            and _is_ident(tokens[i + 2], "cfg")
            and _is_sym(tokens[i + 3], "(")
            and _is_ident(tokens[i + 4], "test")
            and _is_sym(tokens[i + 5], ")")
            and _is_sym(tokens[i + 6], "]")
        )
        if is_cfg_test:
            j = i + 7
            while (
                j < len(tokens)
                and not _is_sym(tokens[j], "{")
                and not _is_sym(tokens[j], ";")
            ):
                j += 1
            if j < len(tokens) and _is_sym(tokens[j], "{"):
                end = match_brace(tokens, j)
                out.append((j, end))
                i = end + 1
                continue
        i += 1
    return out


def _in_regions(regions, idx):
    return any(a <= idx <= b for a, b in regions)


def _is_path2(t, i, a, b):
    return (
        i + 3 < len(t)
        and _is_ident(t[i], a)
        and _is_sym(t[i + 1], ":")
        and _is_sym(t[i + 2], ":")
        and _is_ident(t[i + 3], b)
    )


def clock_scope(path):
    return path.startswith("rust/src/") and path != "rust/src/serving/clock.rs"


def panic_scope(path):
    return path.startswith("rust/src/serving/") or path.startswith("rust/src/runtime/")


def determinism_scope(path):
    return (
        path.startswith("rust/src/serving/")
        or path.startswith("rust/src/moe/")
        or path.startswith("rust/src/pipeline/")
    )


PANIC_METHODS = ["unwrap", "expect"]
PANIC_MACROS = ["panic", "unreachable", "todo", "unimplemented"]
ALLOC_METHODS = ["to_vec", "to_owned", "clone", "collect"]
ALLOC_PATHS = [
    ("Vec", "new"),
    ("Vec", "with_capacity"),
    ("Box", "new"),
    ("String", "new"),
    ("String", "from"),
    ("String", "with_capacity"),
]
ALLOC_MACROS = ["vec", "format"]


def _finding(rule, path, line, message):
    return {"rule": rule, "path": path, "line": line, "message": message}


def _alloc_finding(path, line, what):
    return _finding(
        "hot-path-alloc",
        path,
        line,
        "%s allocates inside a `lint: hot-path` fn (arena reuse only)" % what,
    )


def _scan_hot_path(path, t, opening, close, out):
    i = opening
    while i <= close and i < len(t):
        for a, b in ALLOC_PATHS:
            if _is_path2(t, i, a, b):
                out.append(_alloc_finding(path, t[i][0], "%s::%s" % (a, b)))
        if i + 1 < len(t) and _is_sym(t[i + 1], "!"):
            m = _ident(t[i])
            if m in ALLOC_MACROS and (i == 0 or not _is_sym(t[i - 1], "#")):
                out.append(_alloc_finding(path, t[i][0], m + "!"))
        if i + 2 < len(t) and _is_sym(t[i], ".") and (
            _is_sym(t[i + 2], "(") or _is_sym(t[i + 2], ":")
        ):
            m = _ident(t[i + 1])
            if m in ALLOC_METHODS:
                out.append(_alloc_finding(path, t[i + 1][0], ".%s()" % m))
        i += 1


def scan_rules(path, tokens, directives):
    t = tokens
    tests = test_regions(t)
    out = []

    for d in directives:
        if d[0] == "malformed":
            out.append(_finding(RULE_ALLOW_SYNTAX, path, d[1], d[2]))

    if clock_scope(path):
        for i in range(len(t)):
            if _in_regions(tests, i):
                continue
            for src in ("Instant", "SystemTime"):
                if _is_path2(t, i, src, "now"):
                    out.append(
                        _finding(
                            "clock-discipline",
                            path,
                            t[i][0],
                            "%s::now() bypasses the injectable Clock seam "
                            "(route through serving::clock::Clock)" % src,
                        )
                    )

    if panic_scope(path):
        for i in range(len(t)):
            if _in_regions(tests, i):
                continue
            if i + 2 < len(t) and _is_sym(t[i], ".") and _is_sym(t[i + 2], "("):
                m = _ident(t[i + 1])
                if m in PANIC_METHODS:
                    out.append(
                        _finding(
                            "panic-discipline",
                            path,
                            t[i + 1][0],
                            ".%s() can panic the serving process; return a typed "
                            "error (fault containment promises per-request failures)"
                            % m,
                        )
                    )
            if i + 1 < len(t) and _is_sym(t[i + 1], "!"):
                m = _ident(t[i])
                if m in PANIC_MACROS and (
                    i == 0
                    or (not _is_sym(t[i - 1], ".") and not _is_sym(t[i - 1], "#"))
                ):
                    out.append(
                        _finding(
                            "panic-discipline",
                            path,
                            t[i][0],
                            "%s! can panic the serving process; return a typed "
                            "error or allowlist with the unreachability invariant"
                            % m,
                        )
                    )

    if determinism_scope(path):
        for i, tok in enumerate(t):
            if _in_regions(tests, i):
                continue
            for ty in ("HashMap", "HashSet"):
                if _is_ident(tok, ty):
                    out.append(
                        _finding(
                            "determinism",
                            path,
                            tok[0],
                            "%s iteration order is nondeterministic; replay "
                            "determinism requires BTreeMap/BTreeSet here" % ty,
                        )
                    )

    for d in directives:
        if d[0] != "hot-path":
            continue
        line = d[1]
        fn_idx = next(
            (k for k, tok in enumerate(t) if tok[0] >= line and _is_ident(tok, "fn")),
            None,
        )
        if fn_idx is None:
            out.append(
                _finding(
                    RULE_ALLOW_SYNTAX, path, line, "hot-path directive does not precede a fn"
                )
            )
            continue
        opening = next(
            (j for j in range(fn_idx, len(t)) if _is_sym(t[j], "{")), None
        )
        if opening is None:
            out.append(
                _finding(RULE_ALLOW_SYNTAX, path, line, "hot-path fn has no body")
            )
            continue
        close = match_brace(t, opening)
        _scan_hot_path(path, t, opening, close, out)

    return out


# ---------------------------------------------------------------------------
# rust/src/lint/drift.rs — shared-constant registry
# ---------------------------------------------------------------------------

MIRROR_DYNK = "scripts/mirror_dynamic_k.py"
MIRROR_CHUNK = "scripts/mirror_chunked_prefill.py"
MIRROR_QUANT = "scripts/mirror_quant.py"

REGISTRY = [
    ("PCG_MULT", "rust/src/util/rng.rs", MIRROR_DYNK),
    ("SPLITMIX_GAMMA", "rust/src/util/rng.rs", MIRROR_DYNK),
    ("SPLITMIX_MIX1", "rust/src/util/rng.rs", MIRROR_DYNK),
    ("SPLITMIX_MIX2", "rust/src/util/rng.rs", MIRROR_DYNK),
    ("FNV_OFFSET_BASIS", "rust/src/serving/scheduler.rs", MIRROR_DYNK),
    ("FNV_PRIME", "rust/src/serving/scheduler.rs", MIRROR_DYNK),
    ("DEFAULT_TIER_FULL", "rust/src/serving/request.rs", MIRROR_DYNK),
    ("DEFAULT_TIER_DEGRADED", "rust/src/serving/request.rs", MIRROR_DYNK),
    ("PAPER_RATIO_HIGH", "rust/src/moe/gating.rs", MIRROR_DYNK),
    ("PAPER_RATIO_LOW", "rust/src/moe/gating.rs", MIRROR_DYNK),
    ("PAPER_N_K", "rust/src/moe/gating.rs", MIRROR_DYNK),
    ("PAPER_K_HIGH", "rust/src/moe/gating.rs", MIRROR_DYNK),
    ("PAPER_K_LOW", "rust/src/moe/gating.rs", MIRROR_DYNK),
    ("DEFAULT_PREFILL_CHUNK_TOKENS", "rust/src/serving/batcher.rs", MIRROR_CHUNK),
    ("CONT_GRID_STEP", "rust/src/serving/engine.rs", MIRROR_CHUNK),
    ("INT8_CLAMP", "rust/src/quant/mod.rs", MIRROR_QUANT),
    ("SCALE_EPS", "rust/src/quant/mod.rs", MIRROR_QUANT),
    ("RESIDENCY_EMA_DECAY", "rust/src/moe/store.rs", MIRROR_QUANT),
    ("DEFAULT_RESIDENT_CAP", "rust/src/moe/store.rs", MIRROR_QUANT),
]


def parse_num_lit(s):
    """-> ("int", v) | ("float", v) | None; int/float kinds never agree."""
    s = s.replace("_", "")
    if s.startswith("0x") or s.startswith("0X"):
        try:
            return ("int", int(s[2:], 16))
        except ValueError:
            return None
    if "." in s or "e" in s or "E" in s:
        try:
            return ("float", float(s))
        except ValueError:
            return None
    try:
        return ("int", int(s))
    except ValueError:
        return None


def _num_at(t, i):
    neg, j = (True, i + 1) if i < len(t) and _is_sym(t[i], "-") else (False, i)
    if j >= len(t) or t[j][1] != "num":
        return None
    v = parse_num_lit(t[j][2])
    if v is None:
        return None
    if neg:
        return (v[0], -v[1])
    return v


def extract_rust(tokens, name):
    for i in range(max(len(tokens) - 1, 0)):
        if _is_ident(tokens[i], "const") and _is_ident(tokens[i + 1], name):
            line = tokens[i + 1][0]
            j = i + 2
            while (
                j < len(tokens)
                and not _is_sym(tokens[j], "=")
                and not _is_sym(tokens[j], ";")
            ):
                j += 1
            if j < len(tokens) and _is_sym(tokens[j], "="):
                return (line, _num_at(tokens, j + 1))
            return (line, None)
    return None


def extract_py(tokens, name):
    for i in range(max(len(tokens) - 1, 0)):
        assigns = (
            _is_ident(tokens[i], name)
            and _is_sym(tokens[i + 1], "=")
            and not (i + 2 < len(tokens) and _is_sym(tokens[i + 2], "="))
            and (i == 0 or not _is_sym(tokens[i - 1], "."))
        )
        if assigns:
            return (tokens[i][0], _num_at(tokens, i + 2))
    return None


def check_drift(root):
    out = []
    for name, rust_rel, py_rel in REGISTRY:
        try:
            with open(os.path.join(root, rust_rel), encoding="utf-8") as f:
                rust_side = extract_rust(scan(f.read())[0], name)
        except OSError as err:
            out.append(
                _finding("mirror-drift", rust_rel, 1, "cannot read registered file: %s" % err)
            )
            continue
        try:
            with open(os.path.join(root, py_rel), encoding="utf-8") as f:
                py_side = extract_py(scan_py(f.read())[0], name)
        except OSError as err:
            out.append(
                _finding("mirror-drift", py_rel, 1, "cannot read registered mirror: %s" % err)
            )
            continue
        if rust_side is None:
            out.append(
                _finding("mirror-drift", rust_rel, 1, "registered constant %s not defined here" % name)
            )
            continue
        rl, rv = rust_side
        if rv is None:
            out.append(
                _finding(
                    "mirror-drift",
                    rust_rel,
                    rl,
                    "registered constant %s is not a single numeric literal" % name,
                )
            )
            continue
        if py_side is None:
            out.append(
                _finding(
                    "mirror-drift", py_rel, 1, "registered constant %s not defined in the mirror" % name
                )
            )
            continue
        pl, pv = py_side
        if pv is None:
            out.append(
                _finding(
                    "mirror-drift",
                    py_rel,
                    pl,
                    "registered constant %s is not a single numeric literal" % name,
                )
            )
            continue
        if rv != pv:
            out.append(
                _finding(
                    "mirror-drift",
                    rust_rel,
                    rl,
                    "%s = %s here but %s in %s — the mirror cross-validation is void"
                    % (name, _fmt_val(rv), _fmt_val(pv), py_rel),
                )
            )
    return out


def _fmt_val(v):
    kind, x = v
    if kind == "float" and x == int(x):
        # match rust's {} float formatting (1 -> "1", 0.25 -> "0.25")
        return str(int(x))
    return str(x)


# ---------------------------------------------------------------------------
# rust/src/lint/mod.rs — per-file pipeline + tree walk
# ---------------------------------------------------------------------------


def lint_source(path, src):
    tokens, comments = scan(src)
    directives = parse_directives(comments)
    allowed = allowed_lines(directives)
    findings = scan_rules(path, tokens, directives)
    return [
        f
        for f in findings
        if f["rule"] == RULE_ALLOW_SYNTAX
        or f["rule"] not in allowed.get(f["line"], set())
    ]


def rust_files(root):
    out = []
    for sub in ("rust/src", "rust/tests", "rust/benches"):
        base = os.path.join(root, sub)
        for dirpath, _dirnames, filenames in os.walk(base):
            for fn in filenames:
                if fn.endswith(".rs"):
                    out.append(os.path.join(dirpath, fn))
    out.sort()
    return out


def lint_tree(root):
    out = []
    for path in rust_files(root):
        rel = os.path.relpath(path, root).replace(os.sep, "/")
        with open(path, encoding="utf-8") as f:
            out.extend(lint_source(rel, f.read()))
    out.extend(check_drift(root))
    out.sort(key=lambda f: (f["path"], f["line"], f["rule"]))
    return out


# ---------------------------------------------------------------------------
# fixture self-tests: each rule must fire on a known-bad snippet and the
# allowlist must suppress (with a reason) / reject (without). These are
# the same fixtures rust/tests/lint_rules.rs embeds.
# ---------------------------------------------------------------------------

# Assembled from parts so this file's own comment scan (if ever pointed
# at it) and plain greps don't confuse fixture text with directives.
ALLOW = "// " + LINT_PREFIX + " allow"
HOTPATH = "// " + LINT_PREFIX + " hot-path"

FIX_CLOCK = "fn f() { let t = std::time::Instant::now(); }\n"
FIX_CLOCK_SYS = "fn f() { let t = SystemTime::now(); }\n"
FIX_PANIC = "fn f(x: Option<u8>) -> u8 { x.unwrap() }\n"
FIX_PANIC_MACRO = "fn f() { unreachable!(\"no\") }\n"
FIX_DETERMINISM = "use std::collections::HashMap;\n"
FIX_HOTPATH = HOTPATH + "\nfn f() -> Vec<u8> { vec![0u8].to_vec() }\n"
FIX_ALLOWED = (
    ALLOW + "(clock-discipline) — fixture: wall-clock is the point here\n"
    "fn f() { let t = std::time::Instant::now(); }\n"
)
FIX_ALLOW_NO_REASON = (
    ALLOW + "(clock-discipline)\n" "fn f() { let t = std::time::Instant::now(); }\n"
)
FIX_ALLOW_UNKNOWN = ALLOW + "(no-such-rule) — whatever\nfn f() {}\n"
FIX_STRING_IMMUNE = 'fn f() -> &\'static str { "Instant::now() .unwrap()" }\n'
FIX_TEST_REGION = (
    "#[cfg(test)]\nmod tests {\n    fn f(x: Option<u8>) -> u8 { x.unwrap() }\n}\n"
)


def _rules_of(findings):
    return sorted(set(f["rule"] for f in findings))


def self_test():
    serving = "rust/src/serving/fixture.rs"

    got = lint_source(serving, FIX_CLOCK)
    assert _rules_of(got) == ["clock-discipline"], got
    assert got[0]["line"] == 1, got
    got = lint_source(serving, FIX_CLOCK_SYS)
    assert _rules_of(got) == ["clock-discipline"], got
    assert not lint_source("rust/src/serving/clock.rs", FIX_CLOCK)
    assert not lint_source("rust/tests/fixture.rs", FIX_CLOCK)
    print("ok: clock-discipline fires in scope, silent in clock.rs and tests/")

    got = lint_source(serving, FIX_PANIC)
    assert _rules_of(got) == ["panic-discipline"], got
    got = lint_source("rust/src/runtime/fixture.rs", FIX_PANIC_MACRO)
    assert _rules_of(got) == ["panic-discipline"], got
    assert not lint_source("rust/src/moe/fixture.rs", FIX_PANIC)
    assert not lint_source(serving, FIX_TEST_REGION)
    print("ok: panic-discipline fires in serving/ + runtime/, skips cfg(test)")

    got = lint_source(serving, FIX_DETERMINISM)
    assert _rules_of(got) == ["determinism"], got
    assert not lint_source("rust/src/util/fixture.rs", FIX_DETERMINISM)
    print("ok: determinism fires on HashMap in scope only")

    got = lint_source("rust/src/moe/fixture.rs", FIX_HOTPATH)
    assert _rules_of(got) == ["hot-path-alloc"], got
    assert len(got) == 2, got  # vec![…] and .to_vec()
    print("ok: hot-path-alloc fires inside annotated fn (%d sites)" % len(got))

    assert not lint_source(serving, FIX_ALLOWED)
    got = lint_source(serving, FIX_ALLOW_NO_REASON)
    assert _rules_of(got) == [RULE_ALLOW_SYNTAX, "clock-discipline"], got
    got = lint_source(serving, FIX_ALLOW_UNKNOWN)
    assert _rules_of(got) == [RULE_ALLOW_SYNTAX], got
    print("ok: allowlist suppresses with reason, rejects without / unknown rule")

    assert not lint_source(serving, FIX_STRING_IMMUNE)
    print("ok: string literals are invisible to every rule")


def main():
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    self_test()
    if "--self-test-only" in sys.argv[1:]:
        print("mirror_lint: self-tests passed")
        return
    findings = lint_tree(root)
    for f in findings:
        print("%s:%d: [%s] %s" % (f["path"], f["line"], f["rule"], f["message"]))
    if findings:
        print("mirror_lint: %d finding(s)" % len(findings))
        sys.exit(1)
    print("mirror_lint: tree is clean (%d rust files scanned)" % len(rust_files(root)))


if __name__ == "__main__":
    main()
