#!/usr/bin/env python3
"""Line-faithful python mirror of the serve-time dynamic-activation math.

`scripts/check.sh` runs this as the fallback gate when no rust
toolchain is on PATH (the repo's historical situation — see the
ROADMAP's standing caveat). Every function here transcribes its rust
counterpart statement by statement in float32 semantics (numpy), so a
behavioral disagreement is a bug in one of the two, not a modeling
artifact:

  normalized_entropy     <- rust/src/moe/gating.rs  normalized_entropy
  DynamicK.k_for         <- rust/src/moe/gating.rs  DynamicK::k_for
  k_for_ratio            <- rust/src/moe/gating.rs  k_for_ratio
  softmax / top_k        <- rust/src/tensor/ops.rs  softmax, top_k_indices
  select_experts         <- rust/src/moe/gating.rs  route_from_scores_dynamic
                            (ranking + selection per token; no weights)
  Rng / stub_logits[_at] <- rust/src/util/rng.rs (PCG32) and
                            rust/src/serving/scheduler.rs

The checks mirror what `rust/tests/dynamic_k.rs` and
`rust/tests/effort_tiers.rs` pin natively:

  1. threshold == 0 is exactly the fixed top-k path (identical
     selection and k on randomized score rows);
  2. k stays inside [k_min, cap] and the dynamic selection is a
     *prefix* of the fixed ranking (prefix-stable top-k);
  3. per-token k — hence total routed rows — is non-increasing as the
     entropy threshold rises;
  4. k_for_ratio algebra: the paper's 75%/25% points on N_k = 4 land
     on k = 3 / k = 1, NaN and >= 1 ratios are the full path, the
     result clamps into [1, k_full];
  5. stub_logits_at: ratio >= 1 (and NaN) is bit-exactly stub_logits,
     reduced ratios hash only the last ceil(ratio*len) tokens (never
     fewer than one), stay a pure function of (ctx, ratio), and
     actually diverge from full effort on long contexts.

Exits 0 and prints a one-line summary per check on success; raises on
the first violation.
"""

import math
import random
import struct

import numpy as np

F32 = np.float32

# ---------------------------------------------------------------------------
# rust/src/util/rng.rs — PCG32 (state/inc u64, 32-bit output)
# ---------------------------------------------------------------------------

MASK64 = (1 << 64) - 1

# Shared numeric constants, registered with the mirror-drift rule of
# `cmoe lint` / scripts/mirror_lint.py: each NAME below must define the
# same value as its rust counterpart (lint/drift.rs REGISTRY names the
# file pairs), or the lint gate fails. That turns this mirror's
# bit-exactness story from convention into a checked property.
PCG_MULT = 6364136223846793005  # rust/src/util/rng.rs
SPLITMIX_GAMMA = 0x9E3779B97F4A7C15  # rust/src/util/rng.rs
SPLITMIX_MIX1 = 0xBF58476D1CE4E5B9  # rust/src/util/rng.rs
SPLITMIX_MIX2 = 0x94D049BB133111EB  # rust/src/util/rng.rs
FNV_OFFSET_BASIS = 0xCBF29CE484222325  # rust/src/serving/scheduler.rs
FNV_PRIME = 0x100000001B3  # rust/src/serving/scheduler.rs
DEFAULT_TIER_FULL = 1.0  # rust/src/serving/request.rs
DEFAULT_TIER_DEGRADED = 0.25  # rust/src/serving/request.rs
PAPER_RATIO_HIGH = 0.75  # rust/src/moe/gating.rs
PAPER_RATIO_LOW = 0.25  # rust/src/moe/gating.rs
PAPER_N_K = 4  # rust/src/moe/gating.rs
PAPER_K_HIGH = 3  # rust/src/moe/gating.rs
PAPER_K_LOW = 1  # rust/src/moe/gating.rs


def _splitmix64(x):
    x = (x + SPLITMIX_GAMMA) & MASK64
    z = x
    z = ((z ^ (z >> 30)) * SPLITMIX_MIX1) & MASK64
    z = ((z ^ (z >> 27)) * SPLITMIX_MIX2) & MASK64
    return x, z ^ (z >> 31)


class Rng:
    def __init__(self, seed):
        s = seed & MASK64
        s, init_state = _splitmix64(s)
        s, inc = _splitmix64(s)
        self.inc = inc | 1
        self.state = (init_state + self.inc) & MASK64
        self.next_u32()

    def next_u32(self):
        old = self.state
        self.state = (old * PCG_MULT + self.inc) & MASK64
        xorshifted = (((old >> 18) ^ old) >> 27) & 0xFFFFFFFF
        rot = old >> 59
        return ((xorshifted >> rot) | (xorshifted << ((32 - rot) & 31))) & 0xFFFFFFFF

    def f32(self):
        return F32(self.next_u32() >> 8) * F32(1.0 / (1 << 24))


# ---------------------------------------------------------------------------
# rust/src/moe/gating.rs — entropy, DynamicK, k_for_ratio
# ---------------------------------------------------------------------------


def normalized_entropy(p):
    n = len(p)
    if n <= 1:
        return F32(0.0)
    h = F32(0.0)
    for x in p:
        if x > 0.0:
            h = F32(h - F32(x * F32(np.log(x))))
    return F32(np.clip(F32(h / F32(np.log(F32(n)))), 0.0, 1.0))


class DynamicK:
    def __init__(self, threshold, k_min):
        self.threshold = F32(threshold)
        self.k_min = k_min

    def is_active(self):
        return self.threshold > 0.0  # NaN and <= 0 both read as fixed

    def k_for(self, sp, k_max):
        if not self.is_active() or k_max <= 1:
            return k_max
        k_min = max(1, min(self.k_min, k_max))
        frac = F32(min(F32(normalized_entropy(sp) / self.threshold), F32(1.0)))
        # rust `f32 as usize` truncates; .round() is round-half-away
        k = k_min + int(float(np.round(F32(F32(k_max - k_min) * frac))))
        return max(k_min, min(k, k_max))


def k_for_ratio(ratio, k_full):
    if k_full == 0:
        return 0
    k = float(np.ceil(F32(F32(ratio) * F32(k_full))))
    if math.isnan(k):
        return k_full
    # rust `f32 as usize` saturates at 0 for negatives
    return max(1, min(int(max(k, 0.0)), k_full))


# ---------------------------------------------------------------------------
# rust/src/tensor/ops.rs — softmax, top_k_indices (prefix-stable)
# ---------------------------------------------------------------------------


def softmax(xs):
    xs = np.asarray(xs, dtype=F32)
    m = F32(np.max(xs)) if xs.size else F32(-np.inf)
    exps = np.exp(xs - m, dtype=F32)
    s = F32(np.sum(exps, dtype=F32))
    return (exps / s).astype(F32)


def top_k_indices(xs, k):
    k = min(k, len(xs))
    best = []
    for i, v in enumerate(xs):
        pos = next(
            (j for j, b in enumerate(best) if v > xs[b] or (v == xs[b] and i < b)),
            len(best),
        )
        if pos < k:
            best.insert(pos, i)
            if len(best) > k:
                best.pop()
    return best


def select_experts(scores_row, gate_bias, dk, n_k, cap=None):
    """Ranking + selection of route_from_scores_dynamic for one token."""
    sp = softmax(scores_row)
    eff_cap = n_k if cap is None else max(1, min(cap, n_k))
    k = dk.k_for(sp, eff_cap)
    ranked = (sp + np.asarray(gate_bias, dtype=F32)).astype(F32)
    return top_k_indices(list(ranked), k), k


# ---------------------------------------------------------------------------
# rust/src/serving/scheduler.rs — stub_logits, stub_logits_at
# ---------------------------------------------------------------------------


def stub_logits(ctx, vocab):
    h = FNV_OFFSET_BASIS
    for t in ctx:
        h ^= t & MASK64
        h = (h * FNV_PRIME) & MASK64
    rng = Rng(h ^ vocab)
    return [rng.f32() for _ in range(vocab)]


def stub_logits_at(ctx, vocab, ratio):
    if not (F32(ratio) < 1.0) or not ctx:  # NaN falls through to full
        return stub_logits(ctx, vocab)
    w = int(float(np.ceil(F32(F32(ratio) * F32(len(ctx))))))
    w = max(1, min(w, len(ctx)))
    return stub_logits(ctx[len(ctx) - w:], vocab)


# ---------------------------------------------------------------------------
# checks
# ---------------------------------------------------------------------------


def random_scores(rand, n):
    return np.asarray([rand.gauss(0.0, 1.5) for _ in range(n)], dtype=F32)


def check_threshold_zero_fixed(rand, cases=400):
    for _ in range(cases):
        n_r = rand.randint(2, 12)
        n_k = rand.randint(1, n_r)
        s = random_scores(rand, n_r)
        bias = random_scores(rand, n_r) * F32(0.1)
        fixed, kf = select_experts(s, bias, DynamicK(0.0, rand.randint(1, 4)), n_k)
        assert kf == n_k, f"threshold=0 must spend exactly N_k, got {kf} != {n_k}"
        ref = top_k_indices(list(softmax(s) + bias), n_k)
        assert fixed == ref, f"threshold=0 selection diverged: {fixed} vs {ref}"
    print(f"ok: threshold=0 is the fixed top-k path ({cases} rows)")


def check_bounds_and_prefix(rand, cases=400):
    for _ in range(cases):
        n_r = rand.randint(2, 12)
        n_k = rand.randint(1, n_r)
        k_min = rand.randint(1, 4)
        thr = rand.uniform(1e-3, 1.0)
        cap = rand.randint(1, n_r) if rand.random() < 0.5 else None
        s = random_scores(rand, n_r)
        bias = random_scores(rand, n_r) * F32(0.1)
        dyn, k = select_experts(s, bias, DynamicK(thr, k_min), n_k, cap)
        eff_cap = n_k if cap is None else max(1, min(cap, n_k))
        lo = max(1, min(k_min, eff_cap)) if eff_cap > 1 else eff_cap
        assert lo <= k <= eff_cap, f"k={k} outside [{lo}, {eff_cap}]"
        fixed, _ = select_experts(s, bias, DynamicK(0.0, 1), n_k)
        assert dyn == fixed[:k], f"dynamic selection not a prefix: {dyn} vs {fixed}"
    print(f"ok: k in [k_min, cap] and selection is a prefix of fixed ({cases} rows)")


def check_threshold_monotone(rand, cases=200):
    for _ in range(cases):
        n_r = rand.randint(2, 12)
        n_k = rand.randint(2, n_r) if n_r >= 2 else 1
        k_min = rand.randint(1, 3)
        sp = softmax(random_scores(rand, n_r))
        thresholds = sorted([0.0, 1.0] + [rand.uniform(0.0, 1.0) for _ in range(4)])
        ks = [DynamicK(t, k_min).k_for(sp, n_k) for t in thresholds]
        for a, b in zip(ks, ks[1:]):
            assert a >= b, f"k rose with threshold: {ks} at {thresholds}"
    print(f"ok: per-token k non-increasing in threshold ({cases} rows)")


def check_k_for_ratio():
    assert k_for_ratio(PAPER_RATIO_HIGH, PAPER_N_K) == PAPER_K_HIGH
    assert k_for_ratio(PAPER_RATIO_LOW, PAPER_N_K) == PAPER_K_LOW
    assert k_for_ratio(1.0, 4) == 4 and k_for_ratio(2.0, 4) == 4
    assert k_for_ratio(float("nan"), 4) == 4
    assert k_for_ratio(0.0, 4) == 1 and k_for_ratio(-1.0, 4) == 1
    assert k_for_ratio(0.5, 0) == 0
    for k_full in range(1, 9):
        last = None
        for i in range(0, 101):
            k = k_for_ratio(i / 100.0, k_full)
            assert 1 <= k <= k_full
            assert last is None or k >= last, "k_for_ratio not monotone in ratio"
            last = k
    print("ok: k_for_ratio algebra (paper points 0.75->3, 0.25->1 on N_k=4)")


def check_stub_tiers(rand, cases=300):
    diverged = 0
    for _ in range(cases):
        n = rand.randint(1, 40)
        ctx = [rand.randint(0, 99) for _ in range(n)]
        vocab = rand.randint(2, 31)
        full = stub_logits(ctx, vocab)
        for r in (DEFAULT_TIER_FULL, 1.5, float("nan")):
            assert stub_logits_at(ctx, vocab, r) == full, "full effort not exact"
        ratio = rand.choice([DEFAULT_TIER_DEGRADED, 0.5, PAPER_RATIO_HIGH])
        a = stub_logits_at(ctx, vocab, ratio)
        assert a == stub_logits_at(ctx, vocab, ratio), "not pure in (ctx, ratio)"
        w = max(1, min(int(math.ceil(ratio * n)), n))
        assert a == stub_logits(ctx[n - w:], vocab), "window math diverged"
        if a != full:
            diverged += 1
    assert diverged > 0, "reduced ratios never changed any logits"
    # bit-level spot check of the PCG32 mirror: f32 values are exactly
    # representable, so exact equality across runs is meaningful
    v = stub_logits([1, 2, 3], 7)
    assert all(0.0 <= x < 1.0 for x in v) and len(set(struct.pack("f", x) for x in v)) > 1
    print(f"ok: stub tier windowing ({cases} ctxs, {diverged} diverged from full)")


def main():
    rand = random.Random(0xD1A7)
    check_threshold_zero_fixed(rand)
    check_bounds_and_prefix(rand)
    check_threshold_monotone(rand)
    check_k_for_ratio()
    check_stub_tiers(rand)
    print("mirror_dynamic_k: all checks passed")


if __name__ == "__main__":
    main()
