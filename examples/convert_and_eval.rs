//! Deep-dive example: the full conversion pipeline with per-stage
//! introspection — shared-expert capture, cluster quality,
//! representative neurons, router agreement, reconstruction error, and
//! optional gate fine-tuning.

use cmoe::converter::{convert_ffn_timed, reconstruction_error, ConvertOptions};
use cmoe::data::corpus::{gen_corpus, CorpusSpec, Domain};
use cmoe::eval::forward::DenseForward;
use cmoe::model::{LayerFfn, ModelWeights};
use cmoe::moe::{finetune_gates, route_tokens, FinetuneConfig};
use cmoe::profiling::profile_dense_model;
use cmoe::tensor::swiglu_hidden;

fn main() -> anyhow::Result<()> {
    let model = ModelWeights::load("artifacts/small.cmw")?;
    let spec = "S3A3E8".parse()?;

    // calibration + profiling
    let calib_text =
        gen_corpus(&CorpusSpec { domain: Domain::Markov, bytes: 8 * 256 + 64, seed: 7 });
    let calib = cmoe::data::encode(&calib_text)[..8 * 256].to_vec();
    let profiles = profile_dense_model(&model, &calib, 256, 10);

    // convert layer 0 with introspection
    let ffn = model.dense_ffn(0).clone();
    let (moe, report) = convert_ffn_timed(&ffn, &profiles[0], &spec, &ConvertOptions::default())?;
    println!("== layer 0 conversion ==");
    println!(
        "stages: shared {:?} | clustering {:?} | router {:?} | slicing {:?}",
        report.shared_select, report.clustering, report.router, report.slicing
    );
    let mu = profiles[0].rates();
    let shared_mean_rate: f32 =
        moe.shared_neurons.iter().map(|&i| mu[i]).sum::<f32>() / moe.shared_neurons.len() as f32;
    let routed_mean_rate: f32 = moe
        .expert_neurons
        .iter()
        .flatten()
        .map(|&i| mu[i])
        .sum::<f32>()
        / (moe.expert_neurons.len() * moe.expert_neurons[0].len()) as f32;
    println!(
        "shared-expert mean activation rate {:.3} vs routed {:.3} (paper §3.2: shared ≫ routed)",
        shared_mean_rate, routed_mean_rate
    );
    println!("representatives: {:?}", moe.representatives);

    // reconstruction error + router agreement on held-out inputs
    let fwd = DenseForward::new(&model);
    let probe_toks: Vec<usize> = cmoe::data::encode(&gen_corpus(&CorpusSpec {
        domain: Domain::Markov,
        bytes: 300,
        seed: 42,
    }))[..256]
        .to_vec();
    let probe = fwd.capture_ffn_inputs(&probe_toks).remove(0);
    println!("reconstruction error: {:.4}", reconstruction_error(&ffn, &moe, &probe));

    let h = swiglu_hidden(&probe, &ffn.w_gate, &ffn.w_up);
    let dec = route_tokens(&moe, &probe);
    let mut top1_hits = 0;
    for t in 0..probe.shape[0] {
        let best_true = (0..moe.experts.len())
            .max_by(|&a, &b| {
                let la: f32 = moe.expert_neurons[a].iter().map(|&i| h.at2(t, i).abs()).sum();
                let lb: f32 = moe.expert_neurons[b].iter().map(|&i| h.at2(t, i).abs()).sum();
                la.partial_cmp(&lb).unwrap()
            })
            .unwrap();
        if dec[t].experts.contains(&best_true) {
            top1_hits += 1;
        }
    }
    println!(
        "router selects the true max-mass expert for {}/{} tokens (chance ≈ {:.0})",
        top1_hits,
        probe.shape[0],
        probe.shape[0] as f64 * spec_chance(&moe)
    );

    // gate fine-tuning on the calibration inputs
    let mut moe_ft = moe.clone();
    let rep = finetune_gates(&mut moe_ft, &probe, &FinetuneConfig::default());
    println!(
        "gate fine-tune: loss {:.5} -> {:.5} over {} steps",
        rep.loss_before, rep.loss_after, rep.steps
    );

    // whole-model conversion for completeness
    let conv = cmoe::converter::convert_model(
        &model,
        &profiles,
        &spec,
        &ConvertOptions::default(),
    )?;
    let n_moe = conv
        .model
        .layers
        .iter()
        .filter(|l| matches!(l.ffn, LayerFfn::Moe(_)))
        .count();
    println!("whole model: {n_moe} MoE layers in {:?}", conv.report.total);
    Ok(())
}

fn spec_chance(moe: &cmoe::model::MoeLayerWeights) -> f64 {
    moe.spec.active as f64 / moe.spec.routed() as f64
}
