//! Hierarchical restructuring (paper §4.4): apply CMoE *recursively* to
//! the routed experts of an already-converted layer, producing two-level
//! routing and finer-grained sparsity — the Qwen3-30B-A3B experiment's
//! analog on this testbed.

use cmoe::converter::{
    convert_ffn, hier_moe_forward, hierarchical_convert, reconstruction_error, ConvertOptions,
};
use cmoe::data::corpus::{gen_corpus, CorpusSpec, Domain};
use cmoe::eval::forward::DenseForward;
use cmoe::model::ModelWeights;
use cmoe::profiling::profile_dense_model;
use cmoe::tensor;

fn main() -> anyhow::Result<()> {
    let model = ModelWeights::load("artifacts/small.cmw")?;
    let calib_text =
        gen_corpus(&CorpusSpec { domain: Domain::Markov, bytes: 8 * 256 + 64, seed: 7 });
    let calib = cmoe::data::encode(&calib_text)[..8 * 256].to_vec();
    let profiles = profile_dense_model(&model, &calib, 256, 10);

    // level 1: dense FFN -> S2A2E8 MoE (experts of 64 neurons)
    let ffn = model.dense_ffn(0).clone();
    let top_spec = "S2A2E8".parse()?;
    let moe = convert_ffn(&ffn, &profiles[0], &top_spec, &ConvertOptions::default())?;
    println!(
        "level 1: {} → {} routed experts × {} neurons + shared {}",
        top_spec,
        moe.experts.len(),
        moe.experts[0].hidden_dim(),
        moe.shared.hidden_dim()
    );

    // level 2: each routed expert -> S1A2E4 sub-MoE (sub-experts of 16)
    let sub_spec = "S1A2E4".parse()?;
    let hier = hierarchical_convert(&moe, &profiles[0], &sub_spec, &ConvertOptions::default())?;
    println!(
        "level 2: each expert → {} (sub-experts of {} neurons)",
        sub_spec,
        hier.sub[0].experts[0].hidden_dim()
    );
    println!(
        "active neuron fraction: flat {:.3} → hierarchical {:.3}",
        moe.spec.active_fraction(),
        hier.active_fraction()
    );

    // quality: reconstruction error of flat vs hierarchical on held-out
    // FFN inputs
    let fwd = DenseForward::new(&model);
    let probe_toks: Vec<usize> = cmoe::data::encode(&gen_corpus(&CorpusSpec {
        domain: Domain::Markov,
        bytes: 300,
        seed: 42,
    }))[..256]
        .to_vec();
    let probe = fwd.capture_ffn_inputs(&probe_toks).remove(0);
    let dense_out = tensor::swiglu_ffn(&probe, &ffn.w_gate, &ffn.w_up, &ffn.w_down);
    let hier_out = hier_moe_forward(&hier, &probe);
    let mut diff = dense_out.clone();
    for (a, b) in diff.data.iter_mut().zip(&hier_out.data) {
        *a -= b;
    }
    println!(
        "reconstruction error: flat {:.4} | hierarchical {:.4}",
        reconstruction_error(&ffn, &moe, &probe),
        diff.norm() / dense_out.norm()
    );
    println!(
        "FFN FLOPs multiplier: flat ×{:.3} | hierarchical ×{:.3} (finer sparsity)",
        moe.spec.active_fraction(),
        hier.active_fraction()
    );
    Ok(())
}
