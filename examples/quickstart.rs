//! Quickstart: profile → convert → evaluate, in ~40 lines.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use cmoe::converter::{convert_model, ConvertOptions};
use cmoe::data::corpus::{gen_corpus, CorpusSpec, Domain};
use cmoe::eval::{choice_accuracy, perplexity};
use cmoe::model::ModelWeights;
use cmoe::profiling::profile_dense_model;

fn main() -> anyhow::Result<()> {
    // 1. load the pretrained dense checkpoint (built by `make artifacts`)
    let model = ModelWeights::load("artifacts/small.cmw")?;
    println!("loaded '{}': {} params", model.config.name, model.config.param_count());

    // 2. profile FFN activations on a tiny calibration set (8 × 256 tok)
    let calib_text =
        gen_corpus(&CorpusSpec { domain: Domain::Markov, bytes: 8 * 256 + 64, seed: 7 });
    let calib = cmoe::data::encode(&calib_text)[..8 * 256].to_vec();
    let profiles = profile_dense_model(&model, &calib, 256, 10);
    for (l, p) in profiles.iter().enumerate() {
        println!("layer {l}: activation-rate bimodality {:.3} (>0.556 ⇒ bimodal)", p.rate_bimodality());
    }

    // 3. analytical restructuring: S3A3E8 = 25% FFN sparsity
    let spec = "S3A3E8".parse()?;
    let conv = convert_model(&model, &profiles, &spec, &ConvertOptions::default())?;
    println!("converted in {:?} (analytical, no training)", conv.report.total);

    // 4. compare dense vs converted
    let eval_text =
        gen_corpus(&CorpusSpec { domain: Domain::Markov, bytes: 4096 + 64, seed: 99 });
    let eval_toks = cmoe::data::encode(&eval_text)[..4096].to_vec();
    let suite = cmoe::eval::tasks::TaskSuite {
        name: "Arith".into(),
        tasks: cmoe::data::gen_choice_tasks(cmoe::data::tasks_gen::TaskFamily::Arith, 60, 3),
    };
    println!(
        "dense:     PPL {:.2}  arith-acc {:.1}%",
        perplexity(&model, &eval_toks, 256),
        choice_accuracy(&model, &suite) * 100.0
    );
    println!(
        "CMoE 25%:  PPL {:.2}  arith-acc {:.1}%",
        perplexity(&conv.model, &eval_toks, 256),
        choice_accuracy(&conv.model, &suite) * 100.0
    );
    conv.model.save("converted_small.cmw")?;
    println!("saved converted model to converted_small.cmw");
    Ok(())
}
