//! END-TO-END serving driver (the DESIGN.md validation workload):
//! loads the pretrained `small` checkpoint, converts it to CMoE
//! (S3A3E8, 25% sparsity), and serves batched generation requests in
//! all three execution modes through the compiled PJRT artifacts,
//! reporting latency/throughput. This proves every layer composes:
//! Pallas kernels (L1) → jax model artifacts (L2) → rust coordinator,
//! batcher and expert dispatcher (L3).
//!
//! ```sh
//! make artifacts && cargo run --release --example serve_moe
//! ```

use cmoe::converter::{convert_model, ConvertOptions};
use cmoe::data::corpus::{gen_corpus, CorpusSpec, Domain};
use cmoe::data::{decode, encode};
use cmoe::model::ModelWeights;
use cmoe::profiling::profile_dense_model;
use cmoe::serving::{Engine, EngineConfig, ExecMode, GenParams, Request};
use std::sync::Arc;
use std::time::Duration;

fn make_requests(n: usize, max_new: usize) -> Vec<Request> {
    (0..n)
        .map(|i| {
            // arithmetic prompts — the model was trained on this domain,
            // so generations are checkably sensible
            let text = gen_corpus(&CorpusSpec {
                domain: Domain::Arith,
                bytes: 16,
                seed: 1000 + i as u64,
            });
            Request::new(
                i as u64,
                encode(&text),
                GenParams { max_new_tokens: max_new, temperature: 0.0, seed: i as u64, stop_token: None },
            )
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let rt = Arc::new(cmoe::runtime::XlaRuntime::load("artifacts")?);
    let dense = ModelWeights::load("artifacts/small.cmw")?;
    println!(
        "model 'small': {} params, {} layers",
        dense.config.param_count(),
        dense.config.n_layers
    );

    // --- convert: profile + analytical restructure (paper §4) ---
    let calib_text =
        gen_corpus(&CorpusSpec { domain: Domain::Markov, bytes: 8 * 256 + 64, seed: 7 });
    let calib = encode(&calib_text)[..8 * 256].to_vec();
    let profiles = profile_dense_model(&dense, &calib, 256, 10);
    let spec = "S3A3E8".parse()?;
    let conv = convert_model(&dense, &profiles, &spec, &ConvertOptions::default())?;
    println!("converted to {spec} in {:?}\n", conv.report.total);
    let moe = conv.model;

    let batch = 8;
    let n_requests = 24;
    let max_new = 24;

    for (label, mode, model) in [
        ("dense baseline   ", ExecMode::Dense, &dense),
        ("MoE monolithic   ", ExecMode::MoeMonolithic, &moe),
        ("MoE orchestrated ", ExecMode::MoeOrchestrated, &moe),
    ] {
        let mut cfg = match mode {
            ExecMode::Dense => EngineConfig::dense("small", 64),
            m => EngineConfig::moe("small", 64, spec, m),
        };
        cfg.batcher.buckets = vec![1, batch];
        cfg.batcher.max_wait = Duration::ZERO;
        let engine = Engine::new(rt.clone(), model.clone(), cfg)?;

        // warmup (compilation) then the measured run
        engine.run_queue(make_requests(batch, 2))?;
        engine.metrics.lock().unwrap().waves.clear();
        let t0 = std::time::Instant::now();
        let results = engine.run_queue(make_requests(n_requests, max_new))?;
        let wall = t0.elapsed();

        let m = engine.metrics.lock().unwrap();
        println!(
            "{label} {} reqs in {:>8.2?} | decode {:>7.1} tok/s | TTFT p50 {:>6.1}ms | latency p50 {:>7.1}ms",
            results.len(),
            wall,
            m.decode_tps(),
            m.ttft_p50_ms(),
            m.latency_p50_ms(),
        );
        if mode == ExecMode::MoeOrchestrated {
            // show a sample generation: the model continues arithmetic
            let r = &results[0];
            println!(
                "    sample: prompt … -> {:?}",
                decode(&r.tokens)
            );
        }
    }
    Ok(())
}
