//! Serving benchmarks. Two sections:
//!
//! 1. **Grouped-dispatch sweep** (always runs, artifact-free): dense vs
//!    per-token vs grouped expert execution across batch size and
//!    activation ratio — the evidence that grouped dispatch turns CMoE's
//!    FLOP savings into throughput, and that its scratch arena stops
//!    allocating after warmup (the "arena growths" column must be 0).
//! 2. **Engine end-to-end** (Tables 7/9 backing): decode throughput per
//!    mode × batch × context through the real engine + PJRT artifacts;
//!    requires `make artifacts`. Runs the run-to-completion wave path
//!    on purpose — it isolates the decode-kernel delta (device-resident
//!    KV, fixed batch); the scheduling comparison is `cmoe bench --exp
//!    serving`.

use cmoe::bench_harness::runner::BenchRunner;
use cmoe::eval::forward::DenseForward;
use cmoe::model::ModelWeights;
use cmoe::profiling::ActivationProfile;
use cmoe::serving::{Engine, EngineConfig, ExecMode, GenParams, Request};
use cmoe::util::Rng;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    match cmoe::bench_harness::exp_serving::dispatch_sweep_table(
        7,
        5,
        Duration::from_millis(60),
    ) {
        Ok(t) => println!("{}\n", t.render()),
        Err(e) => eprintln!("dispatch sweep failed: {e:#}"),
    }

    let Some(dir) = cmoe::test_artifact_dir() else {
        eprintln!("artifacts missing — engine section skipped (run `make artifacts` first)");
        return;
    };
    let rt = Arc::new(cmoe::runtime::XlaRuntime::load(&dir).unwrap());

    // prefer the pretrained checkpoint; fall back to random weights
    // (throughput doesn't depend on weight values)
    let dense = ModelWeights::load(dir.join("small.cmw"))
        .unwrap_or_else(|_| {
            let cfg = cmoe::model::model_config("small").unwrap();
            ModelWeights::random(&cfg, &mut Rng::new(7))
        });

    // convert once
    let mut rng = Rng::new(8);
    let calib: Vec<usize> = (0..1024).map(|_| rng.below(250)).collect();
    let profiles: Vec<ActivationProfile> = DenseForward::new(&dense)
        .capture_hidden(&calib[..256])
        .iter()
        .map(|h| ActivationProfile::from_hidden(h, 10))
        .collect();
    let spec = "S3A3E8".parse().unwrap();
    let moe = cmoe::converter::convert_model(
        &dense,
        &profiles,
        &spec,
        &cmoe::converter::ConvertOptions::default(),
    )
    .unwrap()
    .model;

    let r = BenchRunner::new("serving").with_budget(3, Duration::from_secs(2));
    for (batch, kv) in [(1usize, 64usize), (8, 64), (32, 64)] {
        let steps = 16usize;
        let make_reqs = |n: usize| -> Vec<Request> {
            (0..n)
                .map(|i| {
                    let prompt: Vec<usize> = (0..16).map(|j| (i * 7 + j * 13) % 250).collect();
                    Request::new(
                        i as u64,
                        prompt,
                        GenParams { max_new_tokens: steps, ..Default::default() },
                    )
                })
                .collect()
        };

        // dense monolithic
        let mut cfg = EngineConfig::dense("small", kv);
        cfg.batcher.buckets = vec![batch];
        cfg.batcher.max_wait = Duration::ZERO;
        let engine = Engine::new(rt.clone(), dense.clone(), cfg).unwrap();
        engine.run_queue_waves(make_reqs(batch)).unwrap(); // warmup/compile
        r.bench(
            &format!("decode_dense_b{batch}_kv{kv}"),
            Some((batch * steps) as f64),
            || {
                engine.run_queue_waves(make_reqs(batch)).unwrap();
            },
        );

        // MoE orchestrated (the FLOP-saving path)
        let mut cfg =
            EngineConfig::moe("small", kv, spec, ExecMode::MoeOrchestrated);
        cfg.batcher.buckets = vec![batch];
        cfg.batcher.max_wait = Duration::ZERO;
        let engine = Engine::new(rt.clone(), moe.clone(), cfg).unwrap();
        engine.run_queue_waves(make_reqs(batch)).unwrap();
        r.bench(
            &format!("decode_moe_orch_b{batch}_kv{kv}"),
            Some((batch * steps) as f64),
            || {
                engine.run_queue_waves(make_reqs(batch)).unwrap();
            },
        );

        // MoE monolithic (masked, 1 call/step)
        let mut cfg = EngineConfig::moe("small", kv, spec, ExecMode::MoeMonolithic);
        cfg.batcher.buckets = vec![batch];
        cfg.batcher.max_wait = Duration::ZERO;
        let engine = Engine::new(rt.clone(), moe.clone(), cfg).unwrap();
        engine.run_queue_waves(make_reqs(batch)).unwrap();
        r.bench(
            &format!("decode_moe_mono_b{batch}_kv{kv}"),
            Some((batch * steps) as f64),
            || {
                engine.run_queue_waves(make_reqs(batch)).unwrap();
            },
        );
    }
}
