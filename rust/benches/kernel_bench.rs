//! Hot-path micro benchmarks: rust tensor ops (the conversion/eval
//! path) and the compiled XLA kernels (the serving path).

use cmoe::bench_harness::runner::BenchRunner;
use cmoe::tensor::{self, Tensor};
use cmoe::util::Rng;

fn main() {
    let r = BenchRunner::new("kernel");
    let mut rng = Rng::new(2);

    // rust-side matmuls at model shapes
    for (m, k, n, label) in [
        (32usize, 128usize, 512usize, "ffn_gate_b32"),
        (32, 512, 128, "ffn_down_b32"),
        (256, 128, 256, "logits_s256"),
    ] {
        let a = Tensor::randn(&mut rng, &[m, k], 0.5);
        let b = Tensor::randn(&mut rng, &[k, n], 0.5);
        let flops = 2.0 * (m * k * n) as f64;
        r.bench(&format!("matmul_{label}_{m}x{k}x{n}"), Some(flops), || {
            std::hint::black_box(tensor::matmul(&a, &b));
        });
    }

    // SwiGLU FFN forward (rust reference)
    let x = Tensor::randn(&mut rng, &[32, 128], 0.5);
    let wg = Tensor::randn(&mut rng, &[128, 512], 0.1);
    let wu = Tensor::randn(&mut rng, &[128, 512], 0.1);
    let wd = Tensor::randn(&mut rng, &[512, 128], 0.1);
    r.bench("swiglu_ffn_rust_b32", Some(32.0), || {
        std::hint::black_box(tensor::swiglu_ffn(&x, &wg, &wu, &wd));
    });

    // top-k + softmax (router hot path)
    let scores: Vec<f32> = (0..8).map(|_| rng.normal()).collect();
    r.bench("router_topk_softmax_e8", Some(1.0), || {
        let sp = tensor::softmax(&scores);
        std::hint::black_box(tensor::top_k_indices(&sp, 3));
    });

    // compiled XLA kernels (skipped without artifacts)
    if let Some(dir) = cmoe::test_artifact_dir() {
        let rt = cmoe::runtime::XlaRuntime::load(dir).unwrap();
        let x = Tensor::randn(&mut rng, &[128, 128], 0.5);
        let wg = Tensor::randn(&mut rng, &[128, 512], 0.1);
        let wu = Tensor::randn(&mut rng, &[128, 512], 0.1);
        let wd = Tensor::randn(&mut rng, &[512, 128], 0.1);
        let bufs = [
            rt.upload(&x).unwrap(),
            rt.upload(&wg).unwrap(),
            rt.upload(&wu).unwrap(),
            rt.upload(&wd).unwrap(),
        ];
        let args: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        rt.execute("dense_ffn_small_q128", &args).unwrap(); // compile warmup
        r.bench("xla_dense_ffn_small_q128", Some(128.0), || {
            std::hint::black_box(rt.execute("dense_ffn_small_q128", &args).unwrap());
        });

        // grouped experts kernel (S3A3E8 shapes: e5, m64)
        let name = rt
            .artifact_names()
            .into_iter()
            .find(|n| n.starts_with("experts_small_e5_mm64") && n.ends_with("_b32"))
            .expect("experts artifact");
        let shapes: Vec<Vec<usize>> =
            rt.manifest.artifacts[&name].args.iter().map(|a| a.shape.clone()).collect();
        let ebufs: Vec<xla::PjRtBuffer> = shapes
            .iter()
            .map(|s| rt.upload(&Tensor::randn(&mut rng, s, 0.1)).unwrap())
            .collect();
        let eargs: Vec<&xla::PjRtBuffer> = ebufs.iter().collect();
        rt.execute(&name, &eargs).unwrap();
        let tokens = shapes[0][0] * shapes[0][1];
        r.bench("xla_grouped_experts_e5", Some(tokens as f64), || {
            std::hint::black_box(rt.execute(&name, &eargs).unwrap());
        });
    } else {
        eprintln!("(artifacts missing — XLA kernel benches skipped)");
    }
}
