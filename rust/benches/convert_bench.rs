//! Conversion-path benchmarks (Table 6 backing): LAP solve, balanced
//! k-means, profiling, full-layer and full-model conversion.

use cmoe::bench_harness::runner::BenchRunner;
use cmoe::clustering::balanced_kmeans;
use cmoe::converter::{convert_ffn, ConvertOptions};
use cmoe::lap::{solve, CostMatrix};
use cmoe::model::{model_config, FfnWeights, ModelWeights};
use cmoe::profiling::ActivationProfile;
use cmoe::tensor::{swiglu_hidden, Tensor};
use cmoe::util::Rng;

fn main() {
    let r = BenchRunner::new("convert");
    let mut rng = Rng::new(1);

    // --- LAP solver at conversion-relevant sizes ---
    for n in [64usize, 256, 448] {
        let m = CostMatrix::from_fn(n, n, |i, j| ((i * 31 + j * 17) % 101) as f64 / 10.0);
        r.bench(&format!("jv_lap_{n}x{n}"), None, || {
            std::hint::black_box(solve(&m));
        });
    }

    // --- balanced k-means on binary activation columns ---
    let q = 512;
    let n_pts = 320; // small model S3A3E8: 512 - 192 shared, 5 experts x 64
    let mut pts = Tensor::zeros(&[n_pts, q]);
    for v in pts.data.iter_mut() {
        *v = if rng.f32() < 0.1 { 1.0 } else { 0.0 };
    }
    let init: Vec<usize> = (0..5).collect();
    r.bench("balanced_kmeans_320x512_k5", None, || {
        std::hint::black_box(balanced_kmeans(&pts, 5, &init, 4));
    });

    // --- activation profiling (ATopK) ---
    let h = Tensor::randn(&mut rng, &[2048, 512], 1.0);
    r.bench("profile_2048x512_ka10", Some(2048.0), || {
        std::hint::black_box(ActivationProfile::from_hidden(&h, 10));
    });

    // --- one-layer CMoE conversion (small dims) ---
    let d = 128;
    let d_h = 512;
    let ffn = FfnWeights {
        w_gate: Tensor::randn(&mut rng, &[d, d_h], 0.1),
        w_up: Tensor::randn(&mut rng, &[d, d_h], 0.1),
        w_down: Tensor::randn(&mut rng, &[d_h, d], 0.1),
    };
    let x = Tensor::randn(&mut rng, &[2048, d], 1.0);
    let hh = swiglu_hidden(&x, &ffn.w_gate, &ffn.w_up);
    let prof = ActivationProfile::from_hidden(&hh, 10);
    let spec = "S3A3E8".parse().unwrap();
    r.bench("convert_ffn_small_layer", None, || {
        std::hint::black_box(convert_ffn(&ffn, &prof, &spec, &ConvertOptions::default()).unwrap());
    });

    // --- whole-model conversion (the Table 6 headline) ---
    let cfg = model_config("small").unwrap();
    let model = ModelWeights::random(&cfg, &mut rng);
    let fwd = cmoe::eval::forward::DenseForward::new(&model);
    let calib: Vec<usize> = (0..512).map(|i| (i * 13) % cfg.vocab).collect();
    let profiles: Vec<ActivationProfile> = fwd
        .capture_hidden(&calib[..256])
        .iter()
        .map(|h| ActivationProfile::from_hidden(h, 10))
        .collect();
    r.bench("convert_model_small_4layers", None, || {
        std::hint::black_box(
            cmoe::converter::convert_model(&model, &profiles, &spec, &ConvertOptions::default())
                .unwrap(),
        );
    });
}
