//! # CMoE — Analytical FFN-to-MoE Restructuring
//!
//! A production-oriented reproduction of *"Analytical FFN-to-MoE
//! Restructuring via Activation Pattern Analysis"* (the CMoE system):
//! a post-training framework that converts dense SwiGLU FFN layers into
//! sparse Mixture-of-Experts layers using only a tiny calibration set,
//! with an **analytical router** derived from representative-neuron
//! statistics — no router training required.
//!
//! ## Architecture (three layers)
//!
//! * **L3 (this crate)** — the coordinator: the staged, resumable
//!   conversion [`pipeline`] (one API over CMoE and every baseline,
//!   with a method registry and checkpointable stage artifacts), the
//!   CMoE conversion math ([`converter`]), baselines ([`baselines`]),
//!   serving engine ([`serving`]) with continuous batching and
//!   zero-allocation grouped expert dispatch, evaluation ([`eval`]) and
//!   the bench harness ([`bench_harness`]) that regenerates every
//!   table/figure of the paper.
//!
//! The end-to-end picture (module map, execution modes, and the decode
//! wave's path through the grouped dispatcher) is documented in
//! `docs/ARCHITECTURE.md` at the repo root.
//! * **L2 (python/compile/model.py)** — the JAX transformer, lowered once
//!   to HLO text artifacts (`make artifacts`).
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the SwiGLU /
//!   grouped-expert hot paths, lowered inside the same HLO.
//!
//! Python never runs on the request path; [`runtime`] loads the AOT
//! artifacts through the PJRT C API (`xla` crate) and executes them.
//!
//! ## Quick start
//!
//! ```no_run
//! use cmoe::model::ModelWeights;
//! use cmoe::pipeline::Pipeline;
//!
//! let weights = ModelWeights::load("artifacts/small.cmw").unwrap();
//! // any registered method: cmoe, moefication, …, or "<base>+cmoe-router"
//! let run = Pipeline::for_method("cmoe").unwrap()
//!     .spec("S3A3E8".parse().unwrap())
//!     .finetune(2048)
//!     .run(&weights)
//!     .unwrap();
//! println!("{}", run.summary());
//! run.model.save("converted.cmw").unwrap();
//! ```

pub mod util;
pub mod tensor;
pub mod lap;
pub mod clustering;
pub mod model;
pub mod profiling;
pub mod converter;
pub mod baselines;
pub mod pipeline;
pub mod moe;
pub mod runtime;
pub mod serving;
pub mod lint;
pub mod eval;
pub mod quant;
pub mod data;
pub mod bench_harness;

#[cfg(test)]
pub(crate) mod testutil;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Version string reported by the CLI.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");

/// Default location of AOT artifacts relative to the repo root.
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";

/// Artifact directory for tests: `$CARGO_MANIFEST_DIR/artifacts` when it
/// holds a manifest, else `None` (runtime-dependent tests self-skip so a
/// fresh clone can still `cargo test` before `make artifacts`).
pub fn test_artifact_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(DEFAULT_ARTIFACT_DIR);
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        None
    }
}
