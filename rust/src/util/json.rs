//! Minimal JSON reader/writer (serde is unavailable offline).
//!
//! Supports the full JSON grammar minus exotic number forms; numbers are
//! stored as `f64`. Used for the artifact manifest, bench results and
//! config files. Parsing is recursive-descent; emission is stable-ordered
//! (object insertion order preserved).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// BTreeMap keeps key order deterministic for golden tests.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// `obj["key"]` convenience; returns Null for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    // ---- builders --------------------------------------------------------

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }
    pub fn set(&mut self, key: &str, v: impl Into<Json>) -> &mut Self {
        if let Json::Obj(o) = self {
            o.insert(key.to_string(), v.into());
        }
        self
    }

    /// Pretty-print with 2-space indent.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push(']');
            }
            Json::Obj(o) => {
                if o.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    write_escaped(out, k);
                    out.push_str(if pretty { ": " } else { ":" });
                    v.write(out, indent + 1, pretty);
                }
                if pretty {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        f.write_str(&s)
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<f32> for Json {
    fn from(v: f32) -> Json {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Error with byte offset for diagnostics. (Display/Error are
/// hand-implemented — thiserror is unavailable offline, like the rest
/// of the usual crates; see the module docs.)
#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 5 > self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // advance over one UTF-8 scalar
                    let start = self.pos;
                    let len = utf8_len(self.bytes[start]);
                    let end = (start + len).min(self.bytes.len());
                    s.push_str(
                        std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "3", "-2.5", "\"hi\""] {
            let v = Json::parse(s).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").as_str().unwrap(), "x\ny");
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b"), &Json::Null);
    }

    #[test]
    fn missing_key_is_null() {
        let v = Json::parse(r#"{"a": 1}"#).unwrap();
        assert_eq!(v.get("zzz"), &Json::Null);
    }

    #[test]
    fn rejects_trailing() {
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
    }

    #[test]
    fn builder_and_pretty() {
        let mut o = Json::obj();
        o.set("name", "cmoe").set("n", 8usize).set("ok", true);
        let s = o.pretty();
        let back = Json::parse(&s).unwrap();
        assert_eq!(back.get("name").as_str().unwrap(), "cmoe");
        assert_eq!(back.get("n").as_usize().unwrap(), 8);
        assert_eq!(back.get("ok").as_bool().unwrap(), true);
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé");
    }

    #[test]
    fn escaped_emission_reparses() {
        let v = Json::Str("line\n\"quote\"\ttab".into());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }
}
