//! Scoped data-parallel helpers built on `std::thread` (rayon/tokio are
//! unavailable offline).
//!
//! The converter and the rust-side tensor math use [`par_chunks_mut`] /
//! [`par_for`] to spread embarrassingly parallel work over cores. The
//! serving engine uses plain dedicated threads (see `serving::engine`),
//! not this pool.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use (cores, capped; overridable with
/// the `CMOE_THREADS` env var).
pub fn num_threads() -> usize {
    static CACHED: AtomicUsize = AtomicUsize::new(0);
    let c = CACHED.load(Ordering::Relaxed);
    if c != 0 {
        return c;
    }
    let n = std::env::var("CMOE_THREADS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4))
        .clamp(1, 64);
    CACHED.store(n, Ordering::Relaxed);
    n
}

/// Run `f(chunk_index, chunk)` over disjoint mutable chunks of `data`
/// in parallel. Chunks are `chunk_size` long (last may be shorter).
pub fn par_chunks_mut<T: Send, F>(data: &mut [T], chunk_size: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Send + Sync,
{
    assert!(chunk_size > 0);
    let nthreads = num_threads();
    if data.len() <= chunk_size || nthreads == 1 {
        for (i, chunk) in data.chunks_mut(chunk_size).enumerate() {
            f(i, chunk);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    let chunks: Vec<(usize, &mut [T])> = data.chunks_mut(chunk_size).enumerate().collect();
    let chunks = std::sync::Mutex::new(chunks.into_iter().map(Some).collect::<Vec<_>>());
    std::thread::scope(|s| {
        for _ in 0..nthreads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                let item = {
                    let mut guard = chunks.lock().unwrap();
                    if i >= guard.len() {
                        return;
                    }
                    guard[i].take()
                };
                if let Some((idx, chunk)) = item {
                    f(idx, chunk);
                }
            });
        }
    });
}

/// Parallel for over `0..n`: each worker claims indices atomically.
pub fn par_for<F>(n: usize, f: F)
where
    F: Fn(usize) + Send + Sync,
{
    let nthreads = num_threads().min(n.max(1));
    if nthreads <= 1 || n <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..nthreads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    return;
                }
                f(i);
            });
        }
    });
}

/// Parallel map collecting results in order.
pub fn par_map<T: Send, F>(n: usize, f: F) -> Vec<T>
where
    F: Fn(usize) -> T + Send + Sync,
{
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    {
        let slots: Vec<std::sync::Mutex<&mut Option<T>>> =
            out.iter_mut().map(std::sync::Mutex::new).collect();
        par_for(n, |i| {
            let v = f(i);
            **slots[i].lock().unwrap() = Some(v);
        });
    }
    out.into_iter().map(|o| o.expect("par_map slot unfilled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn par_for_covers_all_indices_once() {
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        par_for(1000, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn par_map_order() {
        let v = par_map(257, |i| i * 3);
        assert_eq!(v.len(), 257);
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i * 3);
        }
    }

    #[test]
    fn par_chunks_mut_writes_disjoint() {
        let mut data = vec![0usize; 1003];
        par_chunks_mut(&mut data, 100, |idx, chunk| {
            for v in chunk.iter_mut() {
                *v = idx + 1;
            }
        });
        assert!(data.iter().all(|&v| v > 0));
        assert_eq!(data[0], 1);
        assert_eq!(data[1002], 11);
    }

    #[test]
    fn num_threads_sane() {
        let n = num_threads();
        assert!((1..=64).contains(&n));
    }
}
