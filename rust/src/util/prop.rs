//! Mini property-based testing support (proptest is unavailable offline).
//!
//! [`check`] runs a property over `n` seeded random cases; on failure it
//! reruns with decreasing "size" to report a smaller counterexample seed.
//! Generators are plain closures over [`crate::util::Rng`], so properties
//! can build arbitrary structured inputs.

use crate::util::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    /// Maximum "size" hint passed to the generator (cases ramp 1..=size).
    pub max_size: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 128, seed: 0xC0FFEE, max_size: 48 }
    }
}

/// Run `prop(rng, size)`; panic with the failing seed/size if it returns
/// `Err(reason)`. Size ramps up so early cases are small.
pub fn check<F>(name: &str, cfg: Config, prop: F)
where
    F: Fn(&mut Rng, usize) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let size = 1 + (case * cfg.max_size) / cfg.cases.max(1);
        let case_seed = cfg.seed ^ (case as u64).wrapping_mul(super::rng::SPLITMIX_GAMMA);
        let mut rng = Rng::new(case_seed);
        if let Err(reason) = prop(&mut rng, size) {
            // try to find a smaller failure by shrinking size
            let mut min_fail = (size, case_seed, reason.clone());
            for s in 1..size {
                let mut r2 = Rng::new(case_seed);
                if let Err(re) = prop(&mut r2, s) {
                    min_fail = (s, case_seed, re);
                    break;
                }
            }
            panic!(
                "property '{name}' failed (case {case}, size {}, seed {:#x}): {}",
                min_fail.0, min_fail.1, min_fail.2
            );
        }
    }
}

/// Assert-style helper for inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check("sum-commutes", Config::default(), |rng, size| {
            let a: Vec<i64> = (0..size).map(|_| rng.below(100) as i64).collect();
            let fwd: i64 = a.iter().sum();
            let bwd: i64 = a.iter().rev().sum();
            prop_assert!(fwd == bwd, "sum mismatch {fwd} vs {bwd}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_seed() {
        check("always-fails", Config { cases: 3, ..Default::default() }, |_, _| {
            Err("nope".to_string())
        });
    }

    #[test]
    fn size_ramps() {
        // sizes observed must be nondecreasing-ish and within bounds
        let seen = std::sync::Mutex::new(Vec::new());
        check("size-ramp", Config { cases: 10, max_size: 20, ..Default::default() }, |_, size| {
            seen.lock().unwrap().push(size);
            Ok(())
        });
        let v = seen.lock().unwrap();
        assert!(v.iter().all(|&s| (1..=20).contains(&s)));
        assert!(v.first().unwrap() <= v.last().unwrap());
    }
}
