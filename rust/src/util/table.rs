//! Aligned plain-text table rendering for the bench harness — every
//! `cmoe bench --exp tableN` prints rows in the same shape as the
//! paper's tables.

/// A simple column-aligned table.
#[derive(Debug, Default, Clone)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    /// Render with per-column width alignment.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for i in 0..ncol {
                if i > 0 {
                    line.push_str("  ");
                }
                let cell = &cells[i];
                line.push_str(cell);
                for _ in cell.chars().count()..widths[i] {
                    line.push(' ');
                }
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Export as a JSON object (for results/*.json).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut o = Json::obj();
        o.set("title", self.title.as_str());
        o.set("header", self.header.clone());
        o.set(
            "rows",
            Json::Arr(self.rows.iter().map(|r| Json::from(r.clone())).collect()),
        );
        o
    }
}

/// Format helper: fixed decimals.
pub fn f(v: f64, decimals: usize) -> String {
    format!("{:.*}", decimals, v)
}

/// Format helper: percent with sign, e.g. `-16.6%`.
pub fn pct(v: f64) -> String {
    format!("{:+.1}%", v * 100.0)
}

/// Format helper: speedup, e.g. `1.17x`.
pub fn speedup(v: f64) -> String {
    format!("{:.2}x", v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns_columns() {
        let mut t = Table::new("Demo", &["Method", "PPL"]);
        t.row(vec!["Dense".into(), "5.27".into()]);
        t.row(vec!["Ours (25%)".into(), "5.78".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert!(lines[0].contains("Demo"));
        assert!(lines[1].starts_with("Method"));
        // column starts align
        let col = lines[1].find("PPL").unwrap();
        assert_eq!(lines[3].find("5.27").unwrap(), col);
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn json_export() {
        let mut t = Table::new("x", &["a"]);
        t.row(vec!["1".into()]);
        let j = t.to_json();
        assert_eq!(j.get("title").as_str().unwrap(), "x");
        assert_eq!(j.get("rows").as_arr().unwrap().len(), 1);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(f(1.2345, 2), "1.23");
        assert_eq!(pct(-0.166), "-16.6%");
        assert_eq!(speedup(1.171), "1.17x");
    }
}
