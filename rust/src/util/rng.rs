//! Deterministic pseudo-random number generation.
//!
//! PCG32 (O'Neill 2014) seeded through SplitMix64, plus the sampling
//! helpers the rest of the crate needs (uniform, normal, shuffle,
//! choice-without-replacement, categorical). Everything is reproducible
//! from a single `u64` seed, which the bench harness and tests rely on.

/// PCG32 generator: 64-bit state, 64-bit stream, 32-bit output.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
    inc: u64,
}

/// PCG32 LCG multiplier. Registered with the mirror-drift lint rule:
/// `scripts/mirror_dynamic_k.py` must define the same value, or
/// `cmoe lint` fails — the python mirrors' bit-exactness claim depends
/// on these constants agreeing (see `lint::drift::REGISTRY`).
pub const PCG_MULT: u64 = 6364136223846793005;

/// SplitMix64 golden-gamma increment (mirror-drift registered).
pub const SPLITMIX_GAMMA: u64 = 0x9E3779B97F4A7C15;
/// SplitMix64 first mixing multiplier (mirror-drift registered).
pub const SPLITMIX_MIX1: u64 = 0xBF58476D1CE4E5B9;
/// SplitMix64 second mixing multiplier (mirror-drift registered).
pub const SPLITMIX_MIX2: u64 = 0x94D049BB133111EB;

/// SplitMix64 step — used to spread user seeds over the whole state space.
#[inline]
pub fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(SPLITMIX_GAMMA);
    let mut z = *x;
    z = (z ^ (z >> 30)).wrapping_mul(SPLITMIX_MIX1);
    z = (z ^ (z >> 27)).wrapping_mul(SPLITMIX_MIX2);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a seed. Two different seeds produce
    /// independent-looking streams.
    pub fn new(seed: u64) -> Self {
        let mut s = seed;
        let init_state = splitmix64(&mut s);
        let init_inc = splitmix64(&mut s) | 1;
        let mut rng = Rng { state: 0, inc: init_inc };
        rng.state = init_state.wrapping_add(init_inc);
        rng.next_u32();
        rng
    }

    /// Derive a child generator (for per-thread / per-layer streams).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let a = self.next_u64() ^ tag.wrapping_mul(SPLITMIX_GAMMA);
        Rng::new(a)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)` with 32 bits of precision.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, bound)` (Lemire's method, unbiased).
    #[inline]
    pub fn below(&mut self, bound: usize) -> usize {
        assert!(bound > 0, "below(0)");
        let bound = bound as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform integer in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller (cached second value omitted for
    /// simplicity; profiling shows this is nowhere near a hot path).
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Normal with given mean / std.
    pub fn normal_ms(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal()
    }

    /// Fill a slice with i.i.d. normals scaled by `std`.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal() * std;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "choose_k: k={k} > n={n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.range(i, n);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f32 = weights.iter().sum();
        assert!(total > 0.0, "categorical: zero total weight");
        let mut t = self.f32() * total;
        for (i, &w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Sample from logits with temperature (softmax sampling). Returns the
    /// chosen index. Used by the serving engine's sampler.
    pub fn sample_logits(&mut self, logits: &[f32], temperature: f32) -> usize {
        if temperature <= 0.0 {
            // argmax
            let mut best = 0;
            for i in 1..logits.len() {
                if logits[i] > logits[best] {
                    best = i;
                }
            }
            return best;
        }
        let inv_t = 1.0 / temperature;
        let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut probs: Vec<f32> = logits.iter().map(|&l| ((l - max) * inv_t).exp()).collect();
        let sum: f32 = probs.iter().sum();
        for p in probs.iter_mut() {
            *p /= sum;
        }
        self.categorical(&probs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_stream() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4, "streams should not correlate, same={same}");
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 7];
        let n = 70_000;
        for _ in 0..n {
            counts[r.below(7)] += 1;
        }
        for &c in &counts {
            let expect = n / 7;
            assert!((c as i64 - expect as i64).abs() < (expect as i64) / 10, "count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn choose_k_distinct() {
        let mut r = Rng::new(13);
        let picks = r.choose_k(50, 20);
        let mut sorted = picks.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
        assert!(picks.iter().all(|&i| i < 50));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(19);
        let w = [1.0f32, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio={ratio}");
    }

    #[test]
    fn sample_logits_greedy_is_argmax() {
        let mut r = Rng::new(23);
        let logits = [0.1f32, 5.0, -2.0, 4.9];
        for _ in 0..10 {
            assert_eq!(r.sample_logits(&logits, 0.0), 1);
        }
    }
}
