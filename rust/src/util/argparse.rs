//! Minimal CLI argument parser (clap is unavailable offline).
//!
//! Supports `prog <subcommand> --flag value --switch positional...`.
//! Flags may be given as `--name value` or `--name=value`.

use std::collections::BTreeMap;

/// Parsed command line: subcommand, named flags, positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub flags: BTreeMap<String, String>,
    pub switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    /// `known_switches` lists boolean flags that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(args: I, known_switches: &[&str]) -> Args {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if known_switches.contains(&name) {
                    out.switches.push(name.to_string());
                } else if let Some(next) = it.peek() {
                    if next.starts_with("--") {
                        out.switches.push(name.to_string());
                    } else {
                        out.flags.insert(name.to_string(), it.next().unwrap());
                    }
                } else {
                    out.switches.push(name.to_string());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env(known_switches: &[&str]) -> Args {
        Args::parse(std::env::args().skip(1), known_switches)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name) || self.flags.contains_key(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = Args::parse(v(&["convert", "--model", "small", "--spec=S3A3E8", "x.cmw"]), &[]);
        assert_eq!(a.subcommand.as_deref(), Some("convert"));
        assert_eq!(a.get("model"), Some("small"));
        assert_eq!(a.get("spec"), Some("S3A3E8"));
        assert_eq!(a.positional, vec!["x.cmw"]);
    }

    #[test]
    fn switches() {
        let a = Args::parse(v(&["serve", "--verbose", "--port", "8080"]), &["verbose"]);
        assert!(a.has("verbose"));
        assert_eq!(a.get_usize("port", 0), 8080);
    }

    #[test]
    fn trailing_switch() {
        let a = Args::parse(v(&["bench", "--dry-run"]), &[]);
        assert!(a.has("dry-run"));
    }

    #[test]
    fn adjacent_switches_without_registry() {
        let a = Args::parse(v(&["x", "--a", "--b", "val"]), &[]);
        assert!(a.has("a"));
        assert_eq!(a.get("b"), Some("val"));
    }

    #[test]
    fn defaults() {
        let a = Args::parse(v(&["eval"]), &[]);
        assert_eq!(a.get_or("out", "results"), "results");
        assert_eq!(a.get_f64("temp", 0.7), 0.7);
    }
}
