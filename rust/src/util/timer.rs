//! Wall-clock timing helpers used by the conversion report, metrics and
//! the bench harness.
//!
//! This module is the measurement core of the in-repo criterion
//! replacement: wall time IS the quantity under study, so its
//! `Instant::now` calls carry clock-discipline allows instead of going
//! through the serving `Clock` seam (which exists to make *serving*
//! latency logic testable, not to virtualize benchmarks).

use std::time::{Duration, Instant};

/// A simple start/lap timer.
#[derive(Debug, Clone)]
pub struct Timer {
    start: Instant,
    last: Instant,
}

impl Timer {
    pub fn start() -> Self {
        // lint: allow(clock-discipline) — bench/report timer: wall time is the measurand
        let now = Instant::now();
        Timer { start: now, last: now }
    }

    /// Time since construction.
    pub fn total(&self) -> Duration {
        self.start.elapsed()
    }

    /// Time since the previous `lap()` (or construction).
    pub fn lap(&mut self) -> Duration {
        // lint: allow(clock-discipline) — bench/report timer: wall time is the measurand
        let now = Instant::now();
        let d = now - self.last;
        self.last = now;
        d
    }
}

/// Run `f` at least `min_iters` times and for at least `min_time`,
/// returning per-iteration durations — the measurement core of the
/// in-repo criterion replacement (see `bench_harness::runner`).
pub fn measure<F: FnMut()>(mut f: F, min_iters: usize, min_time: Duration) -> Vec<Duration> {
    let mut samples = Vec::new();
    // lint: allow(clock-discipline) — bench measurement loop: wall time is the measurand
    let t0 = Instant::now();
    loop {
        // lint: allow(clock-discipline) — bench measurement loop: wall time is the measurand
        let s = Instant::now();
        f();
        samples.push(s.elapsed());
        if samples.len() >= min_iters && t0.elapsed() >= min_time {
            break;
        }
        // hard cap so a pathologically slow subject cannot hang a bench run
        if samples.len() >= 100_000 {
            break;
        }
    }
    samples
}

/// Format a duration human-readably (µs/ms/s).
pub fn fmt_duration(d: Duration) -> String {
    let us = d.as_secs_f64() * 1e6;
    if us < 1000.0 {
        format!("{us:.1}µs")
    } else if us < 1_000_000.0 {
        format!("{:.2}ms", us / 1000.0)
    } else {
        format!("{:.3}s", us / 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lap_and_total_advance() {
        let mut t = Timer::start();
        std::thread::sleep(Duration::from_millis(2));
        let lap1 = t.lap();
        assert!(lap1 >= Duration::from_millis(1));
        assert!(t.total() >= lap1);
    }

    #[test]
    fn measure_returns_enough_samples() {
        let samples = measure(
            || {
                std::hint::black_box(1 + 1);
            },
            10,
            Duration::from_millis(1),
        );
        assert!(samples.len() >= 10);
    }

    #[test]
    fn fmt_duration_units() {
        assert!(fmt_duration(Duration::from_micros(5)).ends_with("µs"));
        assert!(fmt_duration(Duration::from_millis(5)).ends_with("ms"));
        assert!(fmt_duration(Duration::from_secs(5)).ends_with('s'));
    }
}
