//! Descriptive statistics and histograms used by profiling, metrics and
//! the bench harness.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f32>() / xs.len() as f32
}

/// Population standard deviation.
pub fn std_dev(xs: &[f32]) -> f32 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f32>() / xs.len() as f32).sqrt()
}

/// Percentile by linear interpolation on sorted copy; `p` in [0, 100].
///
/// Sorts with [`f32::total_cmp`] so a stray NaN sample (e.g. from a
/// zero-duration rate division upstream) orders deterministically
/// after every finite value instead of panicking the summary; a NaN
/// can then only surface in the extreme top percentiles it actually
/// occupies.
pub fn percentile(xs: &[f32], p: f64) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f32> = xs.to_vec();
    v.sort_by(f32::total_cmp);
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = (rank - lo as f64) as f32;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Fixed-width histogram over `[lo, hi)` with `bins` buckets; values
/// outside the range are clamped into the edge buckets.
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f32,
    pub hi: f32,
    pub counts: Vec<u64>,
    pub total: u64,
}

impl Histogram {
    pub fn new(lo: f32, hi: f32, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Histogram { lo, hi, counts: vec![0; bins], total: 0 }
    }

    pub fn from_values(xs: &[f32], lo: f32, hi: f32, bins: usize) -> Self {
        let mut h = Histogram::new(lo, hi, bins);
        for &x in xs {
            h.add(x);
        }
        h
    }

    pub fn add(&mut self, x: f32) {
        let bins = self.counts.len();
        let t = ((x - self.lo) / (self.hi - self.lo) * bins as f32) as isize;
        let idx = t.clamp(0, bins as isize - 1) as usize;
        self.counts[idx] += 1;
        self.total += 1;
    }

    /// Bucket centers (for plotting / export).
    pub fn centers(&self) -> Vec<f32> {
        let w = (self.hi - self.lo) / self.counts.len() as f32;
        (0..self.counts.len()).map(|i| self.lo + w * (i as f32 + 0.5)).collect()
    }

    /// Normalized densities (sum = 1).
    pub fn densities(&self) -> Vec<f64> {
        let t = self.total.max(1) as f64;
        self.counts.iter().map(|&c| c as f64 / t).collect()
    }

    /// Render an ASCII bar chart — used by `cmoe bench --exp fig*`.
    pub fn ascii(&self, width: usize) -> String {
        let max = self.counts.iter().copied().max().unwrap_or(1).max(1);
        let centers = self.centers();
        let mut out = String::new();
        for (i, &c) in self.counts.iter().enumerate() {
            let bar = "#".repeat((c as usize * width / max as usize).max(usize::from(c > 0)));
            out.push_str(&format!("{:>9.4} | {:<width$} {}\n", centers[i], bar, c, width = width));
        }
        out
    }
}

/// Bimodality coefficient (Pfister et al.): (skew² + 1) / kurtosis.
/// Values > 5/9 suggest bi- or multi-modality. Used to quantify the
/// paper's Figure-2 observation on activation rates.
pub fn bimodality_coefficient(xs: &[f32]) -> f64 {
    let n = xs.len() as f64;
    if n < 4.0 {
        return 0.0;
    }
    let m = mean(xs) as f64;
    let mut m2 = 0.0;
    let mut m3 = 0.0;
    let mut m4 = 0.0;
    for &x in xs {
        let d = x as f64 - m;
        m2 += d * d;
        m3 += d * d * d;
        m4 += d * d * d * d;
    }
    m2 /= n;
    m3 /= n;
    m4 /= n;
    if m2 <= 0.0 {
        return 0.0;
    }
    let skew = m3 / m2.powf(1.5);
    let kurt = m4 / (m2 * m2);
    // small-sample correction per the standard BC definition
    let corr = 3.0 * (n - 1.0) * (n - 1.0) / ((n - 2.0) * (n - 3.0));
    (skew * skew + 1.0) / (kurt + corr - 3.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        let xs = [1.0f32, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-6);
        assert!((std_dev(&xs) - 1.1180339).abs() < 1e-5);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f32> = (0..=100).map(|i| i as f32).collect();
        assert!((percentile(&xs, 50.0) - 50.0).abs() < 1e-6);
        assert!((percentile(&xs, 99.0) - 99.0).abs() < 1e-6);
        assert!((percentile(&xs, 0.0) - 0.0).abs() < 1e-6);
    }

    #[test]
    fn percentile_survives_nan_samples() {
        // regression: partial_cmp(..).unwrap() panicked on the first
        // NaN; total_cmp orders NaN after +inf, so low/mid percentiles
        // stay finite and only the top of the distribution sees it.
        let xs = [3.0f32, f32::NAN, 1.0, 2.0];
        let p50 = percentile(&xs, 50.0);
        assert!((p50 - 2.0).abs() < 1e-6, "p50 = {p50}");
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-6);
        assert!(percentile(&xs, 100.0).is_nan());
        assert!(percentile(&[f32::NAN], 50.0).is_nan());
    }

    #[test]
    fn histogram_counts_and_clamping() {
        let mut h = Histogram::new(0.0, 1.0, 10);
        h.add(0.05);
        h.add(0.95);
        h.add(-3.0); // clamps to first
        h.add(7.0); // clamps to last
        assert_eq!(h.counts[0], 2);
        assert_eq!(h.counts[9], 2);
        assert_eq!(h.total, 4);
        let d = h.densities();
        assert!((d.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn bimodality_separates_uni_and_bi() {
        // unimodal normal-ish
        let mut r = crate::util::Rng::new(3);
        let uni: Vec<f32> = (0..5000).map(|_| r.normal()).collect();
        // bimodal: two well-separated spikes
        let bi: Vec<f32> =
            (0..5000).map(|i| if i % 10 == 0 { 1.0 } else { 0.05 + 0.01 * r.normal() }).collect();
        let b_uni = bimodality_coefficient(&uni);
        let b_bi = bimodality_coefficient(&bi);
        assert!(b_uni < 5.0 / 9.0, "unimodal BC = {b_uni}");
        assert!(b_bi > 5.0 / 9.0, "bimodal BC = {b_bi}");
    }

    #[test]
    fn ascii_renders() {
        let h = Histogram::from_values(&[0.1, 0.1, 0.9], 0.0, 1.0, 4);
        let s = h.ascii(20);
        assert!(s.lines().count() == 4);
        assert!(s.contains('#'));
    }
}
