//! Small self-contained substrates: RNG, JSON, CLI parsing, statistics,
//! thread pool, property-testing helpers, timing and table formatting.
//!
//! These exist because the build environment is fully offline — the usual
//! crates (rand, serde, clap, criterion, proptest, tokio) are not
//! available, so the library carries its own minimal, well-tested
//! equivalents (see DESIGN.md §2).

pub mod rng;
pub mod json;
pub mod argparse;
pub mod stats;
pub mod pool;
pub mod prop;
pub mod timer;
pub mod table;

pub use rng::Rng;
pub use timer::Timer;
