//! Small self-contained substrates: RNG, JSON, CLI parsing, statistics,
//! thread pool, property-testing helpers, timing and table formatting.
//!
//! These exist because the build environment is fully offline — the usual
//! crates (rand, serde, clap, criterion, proptest, tokio) are not
//! available, so the library carries its own minimal, well-tested
//! equivalents (docs/ARCHITECTURE.md module map: `util`).

pub mod rng;
pub mod json;
pub mod argparse;
pub mod stats;
pub mod pool;
pub mod prop;
pub mod timer;
pub mod table;

pub use rng::Rng;
pub use timer::Timer;

/// Lock a mutex, recovering from poisoning instead of panicking.
///
/// The serving stack's fault-containment contract (PR 6) promises that
/// one failing request never takes down the process. A poisoned mutex
/// means some thread panicked mid-update; for the state guarded this
/// way in this crate (metrics counters, gating bias adapters, compiled-
/// executable caches, channel senders) the data is still structurally
/// valid and availability beats purity — so we take the guard and keep
/// serving. Anything needing transactional integrity must not use this.
pub fn lock_unpoisoned<T>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Walk up from the current directory to the root of *this* repository
/// — the first ancestor carrying the CMoE checkout signature
/// (`ROADMAP.md` next to `rust/Cargo.toml`), so the bench harness can
/// drop cross-PR trajectory files (`BENCH_*.json`) in a stable place.
/// Deliberately NOT just "nearest `.git`": an installed binary run
/// inside an unrelated checkout must not scribble into it.
pub fn repo_root() -> Option<std::path::PathBuf> {
    let mut dir = std::env::current_dir().ok()?;
    loop {
        if dir.join("ROADMAP.md").exists() && dir.join("rust").join("Cargo.toml").exists() {
            return Some(dir);
        }
        if !dir.pop() {
            return None;
        }
    }
}
