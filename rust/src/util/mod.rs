//! Small self-contained substrates: RNG, JSON, CLI parsing, statistics,
//! thread pool, property-testing helpers, timing and table formatting.
//!
//! These exist because the build environment is fully offline — the usual
//! crates (rand, serde, clap, criterion, proptest, tokio) are not
//! available, so the library carries its own minimal, well-tested
//! equivalents (docs/ARCHITECTURE.md module map: `util`).

pub mod rng;
pub mod json;
pub mod argparse;
pub mod stats;
pub mod pool;
pub mod prop;
pub mod timer;
pub mod table;

pub use rng::Rng;
pub use timer::Timer;

/// Walk up from the current directory to the root of *this* repository
/// — the first ancestor carrying the CMoE checkout signature
/// (`ROADMAP.md` next to `rust/Cargo.toml`), so the bench harness can
/// drop cross-PR trajectory files (`BENCH_*.json`) in a stable place.
/// Deliberately NOT just "nearest `.git`": an installed binary run
/// inside an unrelated checkout must not scribble into it.
pub fn repo_root() -> Option<std::path::PathBuf> {
    let mut dir = std::env::current_dir().ok()?;
    loop {
        if dir.join("ROADMAP.md").exists() && dir.join("rust").join("Cargo.toml").exists() {
            return Some(dir);
        }
        if !dir.pop() {
            return None;
        }
    }
}
