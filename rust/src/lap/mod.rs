//! Linear Assignment Problem solvers.
//!
//! The balanced-clustering step of CMoE (§A.3) assigns `N_r·m` neurons to
//! `N_r` clusters of exactly `m` slots each by replicating each cluster
//! column `m` times and solving the resulting square LAP with the
//! **Jonker–Volgenant** shortest-augmenting-path algorithm
//! (Jonker & Volgenant 1988), `O(n³)` worst case.
//!
//! [`solve`] is the JV solver (dual potentials + Dijkstra-style
//! augmentation, the same scheme scipy's `linear_sum_assignment` uses);
//! [`solve_greedy`] is a fast approximate fallback used by ablations.

/// Cost matrix in row-major order, `nr × nc` with `nr <= nc`.
#[derive(Clone, Debug)]
pub struct CostMatrix {
    pub nr: usize,
    pub nc: usize,
    pub cost: Vec<f64>,
}

impl CostMatrix {
    pub fn new(nr: usize, nc: usize) -> Self {
        assert!(nr <= nc, "LAP requires rows <= cols (got {nr}x{nc})");
        CostMatrix { nr, nc, cost: vec![0.0; nr * nc] }
    }

    pub fn from_fn(nr: usize, nc: usize, f: impl Fn(usize, usize) -> f64) -> Self {
        let mut m = CostMatrix::new(nr, nc);
        for i in 0..nr {
            for j in 0..nc {
                m.cost[i * nc + j] = f(i, j);
            }
        }
        m
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        self.cost[i * self.nc + j]
    }
}

/// Result: `row_to_col[i]` is the column assigned to row `i`;
/// `total` is the summed cost.
#[derive(Clone, Debug)]
pub struct Assignment {
    pub row_to_col: Vec<usize>,
    pub total: f64,
}

/// Exact LAP via shortest augmenting paths with dual potentials.
///
/// For each row we grow a Dijkstra tree over columns until reaching an
/// unassigned column, then augment along the path and update potentials.
/// Costs may be any finite f64.
pub fn solve(m: &CostMatrix) -> Assignment {
    let (nr, nc) = (m.nr, m.nc);
    const UNASSIGNED: usize = usize::MAX;
    // col j -> row assigned to it
    let mut col_to_row = vec![UNASSIGNED; nc];
    let mut row_to_col = vec![UNASSIGNED; nr];
    // dual potential on columns
    let mut v = vec![0.0f64; nc];

    // scratch
    let mut shortest = vec![0.0f64; nc];
    let mut prev_col = vec![UNASSIGNED; nc];
    let mut done = vec![false; nc];

    for cur_row in 0..nr {
        // Dijkstra from cur_row over the reduced-cost graph
        shortest.iter_mut().for_each(|x| *x = f64::INFINITY);
        done.iter_mut().for_each(|x| *x = false);
        prev_col.iter_mut().for_each(|x| *x = UNASSIGNED);

        let mut min_dist = 0.0f64;
        let mut i = cur_row; // row being scanned
        let mut h = 0.0f64; // reduced cost of the matched edge into row i
        let mut sink = UNASSIGNED;
        // path bookkeeping: prev_col[j] = column scanned before j on path
        let mut last_col = UNASSIGNED;

        while sink == UNASSIGNED {
            // relax edges from row i: dist = min_dist + (c[i,j]-v[j]) - h,
            // where h = c[i,last_col] - v[last_col] - min_dist (JV 1987)
            let base = i * nc;
            for j in 0..nc {
                if done[j] {
                    continue;
                }
                let red = m.cost[base + j] - v[j] - h;
                if red < shortest[j] {
                    shortest[j] = red;
                    prev_col[j] = last_col;
                }
            }
            // pick closest not-done column
            let mut best = UNASSIGNED;
            let mut best_d = f64::INFINITY;
            for j in 0..nc {
                if !done[j] && shortest[j] < best_d {
                    best_d = shortest[j];
                    best = j;
                }
            }
            debug_assert!(best != UNASSIGNED, "LAP: no augmenting path (non-finite costs?)");
            min_dist = best_d;
            done[best] = true;
            last_col = best;
            if col_to_row[best] == UNASSIGNED {
                sink = best;
            } else {
                i = col_to_row[best];
                h = m.cost[i * nc + best] - v[best] - min_dist;
            }
        }

        // update potentials for scanned columns
        for j in 0..nc {
            if done[j] && j != sink {
                v[j] += shortest[j] - min_dist;
            }
        }

        // augment: walk back via prev_col
        let mut j = sink;
        loop {
            let pc = prev_col[j];
            let r = if pc == UNASSIGNED { cur_row } else { col_to_row[pc] };
            col_to_row[j] = r;
            row_to_col[r] = j;
            if pc == UNASSIGNED {
                break;
            }
            j = pc;
        }
    }

    let total = (0..nr).map(|i| m.at(i, row_to_col[i])).sum();
    Assignment { row_to_col, total }
}

/// Greedy approximate LAP: repeatedly take the globally cheapest
/// (row, col) among unassigned. `O(nr·nc·log)`-ish via sort.
pub fn solve_greedy(m: &CostMatrix) -> Assignment {
    let (nr, nc) = (m.nr, m.nc);
    let mut edges: Vec<(usize, usize)> = (0..nr)
        .flat_map(|i| (0..nc).map(move |j| (i, j)))
        .collect();
    edges.sort_by(|&(ai, aj), &(bi, bj)| m.at(ai, aj).partial_cmp(&m.at(bi, bj)).unwrap());
    let mut row_done = vec![false; nr];
    let mut col_done = vec![false; nc];
    let mut row_to_col = vec![usize::MAX; nr];
    let mut assigned = 0;
    for (i, j) in edges {
        if !row_done[i] && !col_done[j] {
            row_done[i] = true;
            col_done[j] = true;
            row_to_col[i] = j;
            assigned += 1;
            if assigned == nr {
                break;
            }
        }
    }
    let total = (0..nr).map(|i| m.at(i, row_to_col[i])).sum();
    Assignment { row_to_col, total }
}

/// Brute-force optimal assignment for tests (square-ish, nr <= 9).
#[cfg(test)]
pub fn solve_brute(m: &CostMatrix) -> Assignment {
    fn rec(
        m: &CostMatrix,
        row: usize,
        used: &mut Vec<bool>,
        cur: f64,
        cur_asg: &mut Vec<usize>,
        best: &mut (f64, Vec<usize>),
    ) {
        if row == m.nr {
            if cur < best.0 {
                *best = (cur, cur_asg.clone());
            }
            return;
        }
        for j in 0..m.nc {
            if !used[j] {
                used[j] = true;
                cur_asg.push(j);
                rec(m, row + 1, used, cur + m.at(row, j), cur_asg, best);
                cur_asg.pop();
                used[j] = false;
            }
        }
    }
    let mut best = (f64::INFINITY, vec![]);
    rec(m, 0, &mut vec![false; m.nc], 0.0, &mut Vec::new(), &mut best);
    Assignment { row_to_col: best.1, total: best.0 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Config};

    fn assert_valid(a: &Assignment, nr: usize) {
        assert_eq!(a.row_to_col.len(), nr);
        let mut seen = std::collections::HashSet::new();
        for &c in &a.row_to_col {
            assert!(seen.insert(c), "column {c} assigned twice");
        }
    }

    #[test]
    fn known_small_case() {
        // classic 3x3
        let m = CostMatrix::from_fn(3, 3, |i, j| [[4., 1., 3.], [2., 0., 5.], [3., 2., 2.]][i][j]);
        let a = solve(&m);
        assert_valid(&a, 3);
        assert!((a.total - 5.0).abs() < 1e-9, "total={}", a.total); // 1 + 2 + 2
    }

    #[test]
    fn rectangular_case() {
        let m = CostMatrix::from_fn(2, 4, |i, j| ((i * 4 + j) as f64 * 7.0) % 5.0);
        let a = solve(&m);
        assert_valid(&a, 2);
        let b = solve_brute(&m);
        assert!((a.total - b.total).abs() < 1e-9);
    }

    #[test]
    fn matches_brute_force_on_random() {
        check("jv-vs-brute", Config { cases: 60, max_size: 7, ..Default::default() }, |rng, size| {
            let nr = rng.range(1, size + 1);
            let nc = rng.range(nr, size + 2);
            let mut vals = vec![0.0f64; nr * nc];
            for v in vals.iter_mut() {
                *v = (rng.below(1000) as f64) / 100.0;
            }
            let m = CostMatrix { nr, nc, cost: vals };
            let jv = solve(&m);
            let bf = solve_brute(&m);
            crate::prop_assert!(
                (jv.total - bf.total).abs() < 1e-9,
                "jv {} vs brute {} on {nr}x{nc}",
                jv.total,
                bf.total
            );
            let mut seen = std::collections::HashSet::new();
            for &c in &jv.row_to_col {
                crate::prop_assert!(seen.insert(c), "dup column");
            }
            let _ = m;
            Ok(())
        });
    }

    #[test]
    fn negative_costs_ok() {
        let m = CostMatrix::from_fn(3, 3, |i, j| -((i + 1) as f64) * ((j + 1) as f64));
        let a = solve(&m);
        let b = solve_brute(&m);
        assert!((a.total - b.total).abs() < 1e-9);
    }

    #[test]
    fn greedy_is_valid_and_close() {
        let m = CostMatrix::from_fn(6, 6, |i, j| ((i * 31 + j * 17) % 13) as f64);
        let g = solve_greedy(&m);
        assert_valid(&g, 6);
        let opt = solve(&m);
        assert!(g.total >= opt.total - 1e-9, "greedy beat optimal?!");
    }

    #[test]
    fn identity_costs_prefer_diagonal() {
        let m = CostMatrix::from_fn(4, 4, |i, j| if i == j { 0.0 } else { 10.0 });
        let a = solve(&m);
        assert_eq!(a.row_to_col, vec![0, 1, 2, 3]);
        assert_eq!(a.total, 0.0);
    }
}
