//! Expert weight storage behind a trait: placement and precision are
//! **policy**, not plumbing (ROADMAP item 5; the same seam item 1's
//! multi-device placement needs).
//!
//! The grouped dispatcher used to take `&[FfnWeights]` — an implicit
//! "every expert is fp32 and resident" assumption baked into the call
//! signature. [`ExpertStore`] replaces that: the dispatcher asks the
//! store for a per-expert [`ExpertView`] and runs whichever band kernel
//! the view selects (fp32 `swiglu_rows_into` or the fused-dequant int8
//! twin). Plain slices implement the trait with every expert
//! [`ExpertResidency::Fp32Resident`], so all pre-existing call sites
//! are the exact old code path — bit-identical by construction.
//!
//! On top of the trait, [`TieredStore`] adds the cold-expert residency
//! tier: per-expert routing occupancy is tracked as an EMA over steps
//! ([`RESIDENCY_EMA_DECAY`]), the top [`TieredStore::resident_cap`]
//! experts by EMA stay `Int8Resident`, and the rest demote to
//! `Int8Host`. A cold expert that the routing trend warms back up is
//! *prefetched* (promoted before its next dispatch would miss). Today
//! every tier lives in host memory — residency is a policy and
//! metering layer whose hit/miss/prefetch/demotion counters are real,
//! while the actual device placement lands with ROADMAP item 1; the
//! shadow-model tests in `rust/tests/quant_store.rs` pin the policy's
//! bookkeeping exactly.
//!
//! Invariants:
//! * The **shared expert is never stored here** — it stays fp32 in the
//!   layer weights (the precision asymmetry of PAPERS.md 2505.03531).
//! * `quant = false` ⇒ every expert reports `Fp32Resident` and views
//!   resolve to the original fp32 weights: serving output is
//!   bit-identical to pre-trait code.
//! * No expert is ever lost: every routed expert always has a view;
//!   demotion changes *where the bytes notionally live*, not whether
//!   the dispatch can run.
//! * No `HashMap`/`HashSet` (the serving determinism lint applies to
//!   callers; this module keeps the same discipline with dense Vecs).

use crate::model::FfnWeights;
use crate::quant::QuantizedFfn;

/// Per-step EMA decay for expert routing occupancy:
/// `ema = RESIDENCY_EMA_DECAY · ema + (1 − decay) · fraction`.
/// Drift-registered against `scripts/mirror_quant.py`.
pub const RESIDENCY_EMA_DECAY: f32 = 0.875;

/// Default number of routed experts kept resident by a [`TieredStore`]
/// (CLI `--resident-cap`). Drift-registered like the decay.
pub const DEFAULT_RESIDENT_CAP: usize = 6;

/// Where (and in what precision) one expert's weights live.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExpertResidency {
    /// Full-precision, dispatch-ready. The only state plain fp32
    /// stores ever report.
    Fp32Resident,
    /// Int8, dispatch-ready (the warm quantized tier).
    Int8Resident,
    /// Int8, demoted to host residence (the cold tier). Still
    /// executable — a dispatch against it is a *miss* that the
    /// promotion policy should have prevented.
    Int8Host,
}

/// A borrowed, dispatch-ready view of one expert's weights. The band
/// kernel is selected by the variant.
#[derive(Clone, Copy, Debug)]
pub enum ExpertView<'a> {
    Fp32(&'a FfnWeights),
    Int8(&'a QuantizedFfn),
}

/// Storage policy seam for routed experts. `Sync` because the grouped
/// dispatcher hands `&dyn`/generic stores to scoped band threads.
pub trait ExpertStore: Sync {
    fn n_experts(&self) -> usize;
    /// Current storage state of expert `e`.
    fn residency(&self, e: usize) -> ExpertResidency;
    /// Dispatch-ready weights for expert `e`. Must succeed for every
    /// `e < n_experts()` regardless of residency (the no-lost-experts
    /// invariant).
    fn view(&self, e: usize) -> ExpertView<'_>;
}

impl ExpertStore for [FfnWeights] {
    fn n_experts(&self) -> usize {
        self.len()
    }
    fn residency(&self, _e: usize) -> ExpertResidency {
        ExpertResidency::Fp32Resident
    }
    fn view(&self, e: usize) -> ExpertView<'_> {
        ExpertView::Fp32(&self[e])
    }
}

impl ExpertStore for Vec<FfnWeights> {
    fn n_experts(&self) -> usize {
        self.len()
    }
    fn residency(&self, _e: usize) -> ExpertResidency {
        ExpertResidency::Fp32Resident
    }
    fn view(&self, e: usize) -> ExpertView<'_> {
        ExpertView::Fp32(&self[e])
    }
}

/// Step-delta residency counters returned by [`TieredStore::note_step`]
/// (the engine accumulates them into `EngineMetrics::residency`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ResidencyDelta {
    /// Routed experts that were dispatch-warm (`Fp32Resident` or
    /// `Int8Resident`) when the step routed to them.
    pub hits: u64,
    /// Routed experts that were `Int8Host` when the step routed to
    /// them — dispatches the promotion policy failed to prefetch.
    pub misses: u64,
    /// Promotions `Int8Host → Int8Resident` performed after this step
    /// (the routing trend warmed the expert back up).
    pub prefetches: u64,
    /// Demotions `Int8Resident → Int8Host` performed after this step.
    pub demotions: u64,
}

/// Quantized expert storage with a cold-expert residency tier.
///
/// `quant = false` is the identity policy: fp32 views, everything
/// `Fp32Resident`, `note_step` only counts hits. `quant = true` serves
/// int8 views for every expert and runs the EMA promotion policy over
/// `resident_cap`.
#[derive(Clone, Debug)]
pub struct TieredStore {
    fp32: Vec<FfnWeights>,
    int8: Vec<QuantizedFfn>,
    residency: Vec<ExpertResidency>,
    /// EMA of each expert's share of routed rows, updated per step.
    ema: Vec<f32>,
    resident_cap: usize,
    quant: bool,
}

impl TieredStore {
    /// Build from the layer's routed experts. With `quant = false` the
    /// int8 copies are still built (they are small) but never served;
    /// residency stays all-`Fp32Resident` forever.
    pub fn new(experts: &[FfnWeights], quant: bool, resident_cap: usize) -> TieredStore {
        let n = experts.len();
        let cap = resident_cap.max(1).min(n.max(1));
        let int8 = experts.iter().map(QuantizedFfn::quantize).collect();
        let residency = if quant {
            // cold-start: the first `cap` experts are warm, the rest
            // cold — the EMA takes over from the first routed step
            (0..n)
                .map(|e| {
                    if e < cap {
                        ExpertResidency::Int8Resident
                    } else {
                        ExpertResidency::Int8Host
                    }
                })
                .collect()
        } else {
            vec![ExpertResidency::Fp32Resident; n]
        };
        TieredStore {
            fp32: experts.to_vec(),
            int8,
            residency,
            ema: vec![0.0; n],
            resident_cap: cap,
            quant,
        }
    }

    pub fn resident_cap(&self) -> usize {
        self.resident_cap
    }

    /// Observe one step's per-expert routed-row counts: count
    /// hits/misses against the residency the step actually dispatched
    /// under, then update the EMA and reshuffle the warm set (top
    /// `resident_cap` by EMA). Promotions out of `Int8Host` are
    /// prefetches; evictions out of `Int8Resident` are demotions.
    pub fn note_step(&mut self, counts: &[usize]) -> ResidencyDelta {
        let n = self.fp32.len();
        assert_eq!(counts.len(), n, "per-expert counts must cover every expert");
        let mut delta = ResidencyDelta::default();
        for (e, &c) in counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            match self.residency[e] {
                ExpertResidency::Int8Host => delta.misses += 1,
                _ => delta.hits += 1,
            }
        }
        if !self.quant {
            return delta;
        }
        let total: usize = counts.iter().sum();
        for (e, &c) in counts.iter().enumerate() {
            let frac = if total == 0 { 0.0 } else { c as f32 / total as f32 };
            self.ema[e] = RESIDENCY_EMA_DECAY * self.ema[e] + (1.0 - RESIDENCY_EMA_DECAY) * frac;
        }
        // warm set = top resident_cap by EMA; ties break on expert
        // index (deterministic — no hasher anywhere near this)
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by(|&a, &b| {
            self.ema[b].partial_cmp(&self.ema[a]).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
        });
        for (rank, &e) in order.iter().enumerate() {
            let want = if rank < self.resident_cap {
                ExpertResidency::Int8Resident
            } else {
                ExpertResidency::Int8Host
            };
            match (self.residency[e], want) {
                (ExpertResidency::Int8Host, ExpertResidency::Int8Resident) => {
                    delta.prefetches += 1;
                }
                (ExpertResidency::Int8Resident, ExpertResidency::Int8Host) => {
                    delta.demotions += 1;
                }
                _ => {}
            }
            self.residency[e] = want;
        }
        delta
    }

    /// Bytes the warm tier holds (int8 residents; fp32 when quant is
    /// off) — the capacity the resident cap actually bounds.
    pub fn resident_bytes(&self) -> usize {
        self.residency
            .iter()
            .enumerate()
            .map(|(e, r)| match r {
                ExpertResidency::Fp32Resident => {
                    (self.fp32[e].w_gate.numel()
                        + self.fp32[e].w_up.numel()
                        + self.fp32[e].w_down.numel())
                        * 4
                }
                ExpertResidency::Int8Resident => self.int8[e].quantized_bytes(),
                ExpertResidency::Int8Host => 0,
            })
            .sum()
    }
}

impl ExpertStore for TieredStore {
    fn n_experts(&self) -> usize {
        self.fp32.len()
    }
    fn residency(&self, e: usize) -> ExpertResidency {
        self.residency[e]
    }
    fn view(&self, e: usize) -> ExpertView<'_> {
        if self.quant {
            // both int8 states are executable (host memory today);
            // Int8Host dispatches are metered as misses by note_step
            ExpertView::Int8(&self.int8[e])
        } else {
            ExpertView::Fp32(&self.fp32[e])
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use crate::util::Rng;

    fn experts(rng: &mut Rng, n: usize, d: usize, m: usize) -> Vec<FfnWeights> {
        (0..n)
            .map(|_| FfnWeights {
                w_gate: Tensor::randn(rng, &[d, m], 0.5),
                w_up: Tensor::randn(rng, &[d, m], 0.5),
                w_down: Tensor::randn(rng, &[m, d], 0.5),
            })
            .collect()
    }

    #[test]
    fn plain_slices_are_all_fp32_resident() {
        let mut rng = Rng::new(601);
        let es = experts(&mut rng, 3, 4, 8);
        let store: &[FfnWeights] = &es;
        assert_eq!(store.n_experts(), 3);
        for e in 0..3 {
            assert_eq!(store.residency(e), ExpertResidency::Fp32Resident);
            assert!(matches!(store.view(e), ExpertView::Fp32(_)));
        }
    }

    #[test]
    fn quant_off_is_identity_policy() {
        let mut rng = Rng::new(602);
        let es = experts(&mut rng, 4, 4, 8);
        let mut store = TieredStore::new(&es, false, 2);
        for _ in 0..10 {
            let d = store.note_step(&[5, 0, 1, 0]);
            assert_eq!(d.misses, 0);
            assert_eq!(d.prefetches + d.demotions, 0);
        }
        for e in 0..4 {
            assert_eq!(store.residency(e), ExpertResidency::Fp32Resident);
            // fp32 views must be the original weights, not a round trip
            let ExpertView::Fp32(w) = store.view(e) else {
                panic!("quant=false served a non-fp32 view")
            };
            assert_eq!(w.w_gate.data, es[e].w_gate.data);
        }
    }

    #[test]
    fn routing_drift_demotes_and_prefetches() {
        let mut rng = Rng::new(603);
        let es = experts(&mut rng, 4, 4, 8);
        let mut store = TieredStore::new(&es, true, 2);
        // phase 1: all traffic on experts 0/1 — they stay warm
        let mut d = ResidencyDelta::default();
        for _ in 0..8 {
            let s = store.note_step(&[8, 8, 0, 0]);
            d.misses += s.misses;
        }
        assert_eq!(d.misses, 0, "warm experts missed");
        assert_eq!(store.residency(2), ExpertResidency::Int8Host);
        // phase 2: traffic drifts to experts 2/3 — first touches miss,
        // then the EMA promotes them (prefetch) and demotes 0/1
        let mut prefetches = 0;
        let mut demotions = 0;
        let mut misses = 0;
        for _ in 0..20 {
            let s = store.note_step(&[0, 0, 8, 8]);
            prefetches += s.prefetches;
            demotions += s.demotions;
            misses += s.misses;
        }
        assert!(misses > 0, "cold experts never missed before promotion");
        assert_eq!(prefetches, 2, "drifted-to experts not prefetched exactly once each");
        assert_eq!(demotions, 2, "drifted-from experts not demoted exactly once each");
        assert_eq!(store.residency(2), ExpertResidency::Int8Resident);
        assert_eq!(store.residency(3), ExpertResidency::Int8Resident);
        assert_eq!(store.residency(0), ExpertResidency::Int8Host);
        // steady state: no more transitions, no more misses
        let s = store.note_step(&[0, 0, 8, 8]);
        assert_eq!(s, ResidencyDelta { hits: 2, ..Default::default() });
    }

    #[test]
    fn every_expert_always_has_a_view() {
        let mut rng = Rng::new(604);
        let es = experts(&mut rng, 5, 4, 8);
        let store = TieredStore::new(&es, true, 1);
        for e in 0..5 {
            // cold or warm, the view exists and has the right shape
            let ExpertView::Int8(q) = store.view(e) else {
                panic!("quant=true served a non-int8 view")
            };
            assert_eq!(q.hidden_dim(), 8);
        }
        assert!(store.resident_bytes() > 0);
        assert_eq!(store.resident_cap(), 1);
    }
}
