//! MoE runtime logic: analytical-router scoring, top-`N_k` gating with
//! load-balancing bias (Eq. 9), expert utilization tracking, the
//! adaptive bias updater (§4.3), and the lightweight gate fine-tuner.

mod gating;
mod balance;
mod finetune;

pub use balance::{BalanceConfig, BiasAdapter, UtilizationTracker};
pub use finetune::{finetune_gates, FinetuneConfig, FinetuneReport};
pub use gating::{moe_ffn_forward, route_from_scores, route_tokens, GateDecision, MoeForwardStats};
