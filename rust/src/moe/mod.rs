//! MoE runtime logic: analytical-router scoring, top-`N_k` gating with
//! load-balancing bias (Eq. 9), expert utilization tracking, the
//! adaptive bias updater (§4.3), and the lightweight gate fine-tuner.
//!
//! Routing produces two views of the same decision: the per-token
//! [`GateDecision`] list (what evaluation and fine-tuning consume) and
//! the expert-major [`GroupedRouting`] index lists (what the serving
//! engine's grouped dispatcher consumes — see
//! `serving::dispatch::GroupedDispatcher` for the execution side and
//! the layout invariants). Expert *weights* sit behind the
//! [`ExpertStore`] storage-policy trait (`store`): fp32 slices, or the
//! quantized [`TieredStore`] with its cold-expert residency tier.

mod gating;
mod balance;
mod finetune;
mod store;

pub use balance::{BalanceConfig, BiasAdapter, UtilizationTracker};
pub use finetune::{finetune_gates, FinetuneConfig, FinetuneReport};
pub use store::{
    ExpertResidency, ExpertStore, ExpertView, ResidencyDelta, TieredStore,
    DEFAULT_RESIDENT_CAP, RESIDENCY_EMA_DECAY,
};
pub use gating::{
    k_for_ratio, moe_ffn_forward, moe_ffn_forward_dynamic, normalized_entropy,
    route_from_scores, route_from_scores_dynamic, route_tokens, route_tokens_dynamic,
    DynamicK, GateDecision, GroupedRouting, MoeForwardStats,
};
