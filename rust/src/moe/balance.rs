//! Auxiliary-loss-free load balancing (§4.3, after DeepSeek-V3).
//!
//! After each step, expert `i`'s bias is nudged by ±γ toward the uniform
//! utilization target `p* = 1/N_r`: overloaded experts are made less
//! attractive for *selection* (the bias is added to scores pre-top-k but
//! never multiplies outputs). The serving engine runs a [`BiasAdapter`]
//! per MoE layer online; the fine-tuner runs one per layer during its
//! epoch.

use crate::model::MoeLayerWeights;

/// Tracks per-expert token counts within an adaptation window.
#[derive(Clone, Debug)]
pub struct UtilizationTracker {
    pub counts: Vec<u64>,
    pub total: u64,
}

impl UtilizationTracker {
    pub fn new(n_experts: usize) -> Self {
        UtilizationTracker { counts: vec![0; n_experts], total: 0 }
    }

    pub fn record(&mut self, expert_tokens: &[usize]) {
        assert_eq!(expert_tokens.len(), self.counts.len());
        for (c, &n) in self.counts.iter_mut().zip(expert_tokens) {
            *c += n as u64;
        }
        self.total += expert_tokens.iter().sum::<usize>() as u64;
    }

    pub fn reset(&mut self) {
        self.counts.iter_mut().for_each(|c| *c = 0);
        self.total = 0;
    }

    /// Utilization fractions p_i (sum to 1 when total > 0).
    pub fn fractions(&self) -> Vec<f64> {
        self.counts
            .iter()
            .map(|&c| if self.total == 0 { 0.0 } else { c as f64 / self.total as f64 })
            .collect()
    }

    /// Max-over-min imbalance ratio (∞ if some expert got zero tokens
    /// and others didn't; 1.0 is perfectly balanced).
    pub fn imbalance(&self) -> f64 {
        let max = self.counts.iter().copied().max().unwrap_or(0);
        let min = self.counts.iter().copied().min().unwrap_or(0);
        if max == 0 {
            1.0
        } else if min == 0 {
            f64::INFINITY
        } else {
            max as f64 / min as f64
        }
    }
}

/// Configuration for bias adaptation.
#[derive(Clone, Copy, Debug)]
pub struct BalanceConfig {
    /// Bias step γ (paper: 1e-3).
    pub gamma: f32,
    /// Steps between bias updates (1 = every batch).
    pub interval: usize,
}

impl Default for BalanceConfig {
    fn default() -> Self {
        BalanceConfig { gamma: 1e-3, interval: 1 }
    }
}

/// Online adaptive-bias updater for one MoE layer.
#[derive(Clone, Debug)]
pub struct BiasAdapter {
    pub cfg: BalanceConfig,
    pub tracker: UtilizationTracker,
    steps: usize,
}

impl BiasAdapter {
    pub fn new(n_routed: usize, cfg: BalanceConfig) -> Self {
        BiasAdapter { cfg, tracker: UtilizationTracker::new(n_routed), steps: 0 }
    }

    /// Record a step's routing counts and, on the update interval, nudge
    /// the layer's biases: overloaded (p_i > p*) ⇒ b_i -= γ, underloaded
    /// ⇒ b_i += γ.
    pub fn step(&mut self, moe: &mut MoeLayerWeights, expert_tokens: &[usize]) {
        self.tracker.record(expert_tokens);
        self.steps += 1;
        if self.steps % self.cfg.interval != 0 || self.tracker.total == 0 {
            return;
        }
        let p_star = 1.0 / moe.spec.routed() as f64;
        let fr = self.tracker.fractions();
        for (i, &p) in fr.iter().enumerate() {
            if p > p_star {
                moe.gate_bias[i] -= self.cfg.gamma;
            } else if p < p_star {
                moe.gate_bias[i] += self.cfg.gamma;
            }
        }
        self.tracker.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::{moe_ffn_forward, route_tokens};
    use crate::tensor::Tensor;
    use crate::util::Rng;

    fn skewed_moe(rng: &mut Rng) -> crate::model::MoeLayerWeights {
        use crate::converter::{convert_ffn, ConvertOptions};
        use crate::model::FfnWeights;
        use crate::profiling::ActivationProfile;
        let d = 12;
        let d_h = 48;
        let ffn = FfnWeights {
            w_gate: Tensor::randn(rng, &[d, d_h], 0.5),
            w_up: Tensor::randn(rng, &[d, d_h], 0.5),
            w_down: Tensor::randn(rng, &[d_h, d], 0.5),
        };
        let x = Tensor::randn(rng, &[128, d], 1.0);
        let h = crate::tensor::swiglu_hidden(&x, &ffn.w_gate, &ffn.w_up);
        let prof = ActivationProfile::from_hidden(&h, 6);
        convert_ffn(&ffn, &prof, &"S2A2E8".parse().unwrap(), &ConvertOptions::default()).unwrap()
    }

    #[test]
    fn tracker_fractions_and_imbalance() {
        let mut t = UtilizationTracker::new(3);
        t.record(&[8, 1, 1]);
        let f = t.fractions();
        assert!((f[0] - 0.8).abs() < 1e-12);
        assert!((t.imbalance() - 8.0).abs() < 1e-12);
        t.reset();
        assert_eq!(t.total, 0);
        assert_eq!(t.imbalance(), 1.0);
    }

    #[test]
    fn bias_moves_toward_underloaded() {
        let mut rng = Rng::new(21);
        let mut moe = skewed_moe(&mut rng);
        let mut adapter = BiasAdapter::new(moe.spec.routed(), BalanceConfig::default());
        adapter.step(&mut moe, &[100, 0, 0, 0, 0, 0]);
        assert!(moe.gate_bias[0] < 0.0, "overloaded expert bias should drop");
        assert!(moe.gate_bias[1] > 0.0, "underloaded expert bias should rise");
    }

    #[test]
    fn adaptation_reduces_imbalance_end_to_end() {
        // Figure 5: run many batches with adaptation; the post-adaptation
        // utilization spread must shrink.
        let mut rng = Rng::new(22);
        let mut moe = skewed_moe(&mut rng);
        // manufacture a hot expert (the paper's Figure-5 "before" state):
        // a large initial bias forces expert 0 into nearly every top-k;
        // adaptation must drain it back toward uniform utilization.
        moe.gate_bias[0] = 0.5;
        moe.gate_bias[1] = -0.3;
        // measure initial imbalance
        let measure = |moe: &crate::model::MoeLayerWeights, rng: &mut Rng| -> f64 {
            let x = Tensor::randn(rng, &[256, 12], 1.0);
            let (_, stats) = moe_ffn_forward(moe, &x);
            let u = stats.utilization();
            let max = u.iter().cloned().fold(0.0, f64::max);
            let min = u.iter().cloned().fold(1.0, f64::min);
            max - min
        };
        let before = measure(&moe, &mut rng);
        let mut adapter =
            BiasAdapter::new(moe.spec.routed(), BalanceConfig { gamma: 5e-3, interval: 1 });
        for _ in 0..400 {
            let x = Tensor::randn(&mut rng, &[32, 12], 1.0);
            let dec = route_tokens(&moe, &x);
            let mut counts = vec![0usize; moe.spec.routed()];
            for d in &dec {
                for &e in &d.experts {
                    counts[e] += 1;
                }
            }
            adapter.step(&mut moe, &counts);
        }
        let after = measure(&moe, &mut rng);
        assert!(
            after < before * 0.7 || after < 0.05,
            "imbalance did not improve: before={before:.4} after={after:.4}"
        );
    }
}
