//! Lightweight gate fine-tuning (§4.3's learnable scaling + load
//! balancing, on the 2k-sample budget of the paper).
//!
//! The paper fine-tunes with LoRA against the language-model loss; in
//! this reproduction the fine-tuner optimizes the *layerwise
//! reconstruction loss* `‖F_MoE(x) − F_dense(x)‖²` — the standard
//! post-training substitute (docs/ARCHITECTURE.md, "The conversion
//! pipeline"). Because conversion is a
//! pure partition, the dense teacher equals the all-experts-active MoE
//! output, so no extra weights are needed.
//!
//! Gradients of the loss w.r.t. the gate scales `u` are analytic:
//! with `g_i = 1 + s'_i·u_i` (Eq. 9) and residual
//! `r = F_MoE − F_dense`, we get `∂L/∂u_i = 2·s'_i·⟨E_i(x), r⟩` for
//! selected experts. `u` is updated with Adam; the load-balance bias is
//! co-adapted by a [`super::BiasAdapter`] exactly as in serving.

use crate::model::MoeLayerWeights;
use crate::moe::balance::{BalanceConfig, BiasAdapter};
use crate::moe::gating::route_tokens;
use crate::tensor::{self, Tensor};

/// Fine-tuning hyperparameters (paper: lr 1e-3 for router scaling,
/// γ = 1e-3 for load balancing, 1 epoch).
#[derive(Clone, Copy, Debug)]
pub struct FinetuneConfig {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub batch: usize,
    pub epochs: usize,
    pub balance: BalanceConfig,
}

impl Default for FinetuneConfig {
    fn default() -> Self {
        FinetuneConfig {
            lr: 1e-3,
            beta1: 0.9,
            beta2: 0.95,
            eps: 1e-8,
            batch: 32,
            epochs: 1,
            balance: BalanceConfig::default(),
        }
    }
}

/// Outcome of a fine-tuning run.
#[derive(Clone, Debug)]
pub struct FinetuneReport {
    pub loss_before: f64,
    pub loss_after: f64,
    pub steps: usize,
    pub samples: usize,
}

/// Mean reconstruction loss over a batch (teacher = all experts active).
fn reconstruction_loss(moe: &MoeLayerWeights, x: &Tensor) -> f64 {
    let (sparse, _) = crate::moe::moe_ffn_forward(moe, x);
    let dense = dense_teacher(moe, x);
    let mut s = 0.0f64;
    for (a, b) in sparse.data.iter().zip(&dense.data) {
        let d = (a - b) as f64;
        s += d * d;
    }
    s / x.shape[0] as f64
}

/// Dense FFN output recomposed from the partition (gates = 1, all on).
fn dense_teacher(moe: &MoeLayerWeights, x: &Tensor) -> Tensor {
    let mut out =
        tensor::swiglu_ffn(x, &moe.shared.w_gate, &moe.shared.w_up, &moe.shared.w_down);
    for e in &moe.experts {
        let ye = tensor::swiglu_ffn(x, &e.w_gate, &e.w_up, &e.w_down);
        tensor::add_inplace(&mut out, &ye);
    }
    out
}

/// Fine-tune the gate scales `u` (and co-adapt biases `b`) of one MoE
/// layer on calibration inputs `x: [q, d]`.
pub fn finetune_gates(
    moe: &mut MoeLayerWeights,
    x: &Tensor,
    cfg: &FinetuneConfig,
) -> FinetuneReport {
    let q = x.shape[0];
    let n_r = moe.spec.routed();
    let loss_before = reconstruction_loss(moe, x);

    let mut m_adam = vec![0.0f32; n_r];
    let mut v_adam = vec![0.0f32; n_r];
    let mut t_step = 0usize;
    let mut adapter = BiasAdapter::new(n_r, cfg.balance);

    for _epoch in 0..cfg.epochs {
        for start in (0..q).step_by(cfg.batch) {
            let end = (start + cfg.batch).min(q);
            let idx: Vec<usize> = (start..end).collect();
            let xb = x.select_rows(&idx);
            let b = xb.shape[0];

            // forward with current gates
            let decisions = route_tokens(moe, &xb);
            let dense = dense_teacher(moe, &xb);
            // residual r = F_moe - F_dense, accumulated per token
            let mut grad = vec![0.0f32; n_r];
            let mut counts = vec![0usize; n_r];
            // compute per-expert outputs once per token group
            let (sparse, _) = crate::moe::moe_ffn_forward(moe, &xb);
            let d = xb.shape[1];
            for (t, dec) in decisions.iter().enumerate() {
                let r: Vec<f32> = (0..d)
                    .map(|j| sparse.at2(t, j) - dense.at2(t, j))
                    .collect();
                let sp = tensor::softmax(&dec.scores);
                let xt = xb.select_rows(&[t]);
                for &e in &dec.experts {
                    counts[e] += 1;
                    // E_e(x_t) · r
                    let ye = tensor::swiglu_ffn(
                        &xt,
                        &moe.experts[e].w_gate,
                        &moe.experts[e].w_up,
                        &moe.experts[e].w_down,
                    );
                    let dot: f32 = ye.data.iter().zip(&r).map(|(a, b)| a * b).sum();
                    grad[e] += 2.0 * sp[e] * dot / b as f32;
                }
            }

            // Adam update on u
            t_step += 1;
            let bc1 = 1.0 - cfg.beta1.powi(t_step as i32);
            let bc2 = 1.0 - cfg.beta2.powi(t_step as i32);
            for i in 0..n_r {
                m_adam[i] = cfg.beta1 * m_adam[i] + (1.0 - cfg.beta1) * grad[i];
                v_adam[i] = cfg.beta2 * v_adam[i] + (1.0 - cfg.beta2) * grad[i] * grad[i];
                let mh = m_adam[i] / bc1;
                let vh = v_adam[i] / bc2;
                moe.gate_scale[i] -= cfg.lr * mh / (vh.sqrt() + cfg.eps);
            }
            adapter.step(moe, &counts);
        }
    }

    let loss_after = reconstruction_loss(moe, x);
    FinetuneReport { loss_before, loss_after, steps: t_step, samples: q }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::converter::{convert_ffn, ConvertOptions};
    use crate::model::{FfnWeights, MoeSpec};
    use crate::profiling::ActivationProfile;
    use crate::util::Rng;

    fn setup(rng: &mut Rng) -> (FfnWeights, MoeLayerWeights, Tensor) {
        let d = 12;
        let d_h = 64;
        let ffn = FfnWeights {
            w_gate: Tensor::randn(rng, &[d, d_h], 0.5),
            w_up: Tensor::randn(rng, &[d, d_h], 0.5),
            w_down: Tensor::randn(rng, &[d_h, d], 0.5),
        };
        let xc = Tensor::randn(rng, &[256, d], 1.0);
        let h = tensor::swiglu_hidden(&xc, &ffn.w_gate, &ffn.w_up);
        let prof = ActivationProfile::from_hidden(&h, 12);
        let spec: MoeSpec = "S2A2E8".parse().unwrap();
        let moe = convert_ffn(&ffn, &prof, &spec, &ConvertOptions::default()).unwrap();
        (ffn, moe, xc)
    }

    #[test]
    fn teacher_equals_original_dense_ffn() {
        let mut rng = Rng::new(51);
        let (ffn, moe, _) = setup(&mut rng);
        let x = Tensor::randn(&mut rng, &[9, 12], 1.0);
        let teacher = dense_teacher(&moe, &x);
        let dense = tensor::swiglu_ffn(&x, &ffn.w_gate, &ffn.w_up, &ffn.w_down);
        assert!(teacher.max_abs_diff(&dense) < 1e-4);
    }

    #[test]
    fn finetune_reduces_reconstruction_loss() {
        let mut rng = Rng::new(52);
        let (_, mut moe, xc) = setup(&mut rng);
        let cfg = FinetuneConfig { epochs: 3, ..Default::default() };
        let report = finetune_gates(&mut moe, &xc, &cfg);
        assert!(report.steps > 0);
        assert!(
            report.loss_after <= report.loss_before,
            "loss went up: {} -> {}",
            report.loss_before,
            report.loss_after
        );
        // u must have moved
        assert!(moe.gate_scale.iter().any(|&u| u.abs() > 1e-6));
    }

    #[test]
    fn finetune_zero_epochs_is_noop() {
        let mut rng = Rng::new(53);
        let (_, mut moe, xc) = setup(&mut rng);
        let cfg = FinetuneConfig { epochs: 0, ..Default::default() };
        let report = finetune_gates(&mut moe, &xc, &cfg);
        assert_eq!(report.steps, 0);
        assert_eq!(report.loss_before, report.loss_after);
        assert!(moe.gate_scale.iter().all(|&u| u == 0.0));
    }

    #[test]
    fn more_data_helps_or_holds() {
        // Figure 4 shape: loss(2k-sample FT) <= loss(64-sample FT) on the
        // same held-out probe (within tolerance).
        let mut rng = Rng::new(54);
        let (_, moe0, xc) = setup(&mut rng);
        let probe = Tensor::randn(&mut rng, &[128, 12], 1.0);
        let mut small = moe0.clone();
        let mut large = moe0.clone();
        let cfg = FinetuneConfig { epochs: 2, ..Default::default() };
        let idx_small: Vec<usize> = (0..64).collect();
        finetune_gates(&mut small, &xc.select_rows(&idx_small), &cfg);
        finetune_gates(&mut large, &xc, &cfg);
        let l_small = reconstruction_loss(&small, &probe);
        let l_large = reconstruction_loss(&large, &probe);
        assert!(
            l_large <= l_small * 1.10,
            "2k-sample FT much worse than 64-sample: {l_large} vs {l_small}"
        );
    }
}
