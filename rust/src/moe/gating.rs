//! Routing and gated MoE forward (rust reference path).
//!
//! The serving engine executes experts through XLA artifacts; this
//! module is the bit-exact rust-side reference used by evaluation, the
//! fine-tuner, and tests. Routing logic (scores → bias → top-N_k →
//! gates) is shared by both paths via [`route_tokens`].
//!
//! Since ROADMAP item 4 the expert count per token is a *runtime*
//! quantity: [`DynamicK`] floats k between `k_min` and the layer's
//! configured N_k on router entropy (confident tokens route to fewer
//! experts), and a per-row cap lets effort tiers shrink k_max for
//! whole requests ([`k_for_ratio`]). The fixed-k path is the
//! `threshold == 0`, no-cap special case and stays bit-identical by
//! construction: [`route_from_scores`] delegates to
//! [`route_from_scores_dynamic`] with [`DynamicK::fixed`].

use crate::model::MoeLayerWeights;
use crate::tensor::{self, Tensor};

/// Routing decision for one token.
#[derive(Clone, Debug)]
pub struct GateDecision {
    /// Selected routed-expert ids, unordered. Length is N_k on the
    /// fixed path; under [`DynamicK`] or a per-row tier cap it is the
    /// token's own k ∈ [k_min, k_max] — consumers must not assume a
    /// uniform length ([`GroupedRouting::rebuild`] never did).
    pub experts: Vec<usize>,
    /// Gate value per selected expert (`1 + s'_i · u_i`, Eq. 9).
    pub gates: Vec<f32>,
    /// Raw router scores `s` (len = N_r) — kept for fine-tuning.
    pub scores: Vec<f32>,
}

/// Router-entropy-thresholded dynamic-k policy (ROADMAP item 4; the
/// dense→dynamic-k line of PAPERS.md, arXiv 2310.04361).
///
/// Per token, the softmaxed router distribution's *normalized* entropy
/// `h ∈ [0, 1]` measures routing uncertainty. A token routes to
///
/// ```text
/// k = k_min + round((k_max - k_min) · min(h / threshold, 1))
/// ```
///
/// so a confident router (h ≪ threshold) spends `k_min` experts and an
/// uncertain one saturates at `k_max`. `threshold == 0` disables the
/// policy: every token gets exactly `k_max` (the fixed-k path,
/// bit-identical to the pre-dynamic router).
///
/// Monotonicity (pinned by `rust/tests/dynamic_k.rs`): for a fixed
/// token, raising `threshold` never raises k — `h / threshold` is
/// non-increasing in the denominator under IEEE-754 rounding, and
/// `min`, the affine map, and `round` preserve that — so the total
/// routed-row count over a batch is non-increasing in the threshold.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DynamicK {
    /// Normalized-entropy threshold in `[0, 1]`. `0.0` = fixed top-k.
    pub threshold: f32,
    /// Floor on per-token expert count (clamped into `[1, k_max]`).
    pub k_min: usize,
}

impl Default for DynamicK {
    fn default() -> DynamicK {
        DynamicK::fixed()
    }
}

impl DynamicK {
    /// The disabled policy: every token routes to exactly `k_max`.
    pub fn fixed() -> DynamicK {
        DynamicK { threshold: 0.0, k_min: 1 }
    }

    /// Whether the policy can change anything (threshold strictly
    /// positive — NaN and non-positive thresholds mean "fixed").
    pub fn is_active(&self) -> bool {
        self.threshold > 0.0
    }

    /// Expert count for one token given its softmaxed router
    /// distribution `sp` and an effective cap `k_max`.
    pub fn k_for(&self, sp: &[f32], k_max: usize) -> usize {
        if !self.is_active() || k_max <= 1 {
            return k_max;
        }
        let k_min = self.k_min.clamp(1, k_max);
        let frac = (normalized_entropy(sp) / self.threshold).min(1.0);
        let k = k_min + ((k_max - k_min) as f32 * frac).round() as usize;
        k.clamp(k_min, k_max)
    }
}

/// Shannon entropy of `p` normalized by `ln(len)` into `[0, 1]`.
/// Defined as 0 for degenerate distributions (`len <= 1`), where the
/// router has no choice to be uncertain about.
pub fn normalized_entropy(p: &[f32]) -> f32 {
    let n = p.len();
    if n <= 1 {
        return 0.0;
    }
    let mut h = 0.0f32;
    for &x in p {
        if x > 0.0 {
            h -= x * x.ln();
        }
    }
    (h / (n as f32).ln()).clamp(0.0, 1.0)
}

/// The paper's high activation-ratio operating point (75% — lossless
/// in the paper's Table 1). Mirror-drift registered: `cmoe lint` fails
/// if `scripts/mirror_dynamic_k.py` disagrees (`lint::drift::REGISTRY`).
pub const PAPER_RATIO_HIGH: f32 = 0.75;
/// The paper's low (fast) activation-ratio operating point (25%,
/// mirror-drift registered).
pub const PAPER_RATIO_LOW: f32 = 0.25;
/// The routed-expert count the paper's operating points are quoted on
/// (mirror-drift registered).
pub const PAPER_N_K: usize = 4;
/// `k_for_ratio(PAPER_RATIO_HIGH, PAPER_N_K)` — pinned so the algebra's
/// operating points can't drift silently (mirror-drift registered).
pub const PAPER_K_HIGH: usize = 3;
/// `k_for_ratio(PAPER_RATIO_LOW, PAPER_N_K)` (mirror-drift registered).
pub const PAPER_K_LOW: usize = 1;

/// Per-row k cap for an activation-ratio operating point (the effort-
/// tier → compute mapping): a request served at `ratio` of full effort
/// routes each token to at most `ceil(ratio · k_full)` experts,
/// clamped into `[1, k_full]`. `ratio >= 1` is exactly the full path.
pub fn k_for_ratio(ratio: f32, k_full: usize) -> usize {
    if k_full == 0 {
        return 0;
    }
    let k = (ratio * k_full as f32).ceil();
    if k.is_nan() {
        return k_full;
    }
    (k as usize).clamp(1, k_full)
}

/// Compute router scores for a batch of (normed) token vectors
/// `x: [q, d]` and produce per-token gate decisions.
///
/// Scores are the representative-neuron SwiGLU responses (Eq. 8);
/// selection adds the load-balance bias *only for ranking* (the bias
/// never scales outputs), gates are `1 + softmax(s)_i · u_i`.
pub fn route_tokens(moe: &MoeLayerWeights, x: &Tensor) -> Vec<GateDecision> {
    let scores = moe.router.scores(x);
    route_from_scores(moe, &scores)
}

/// [`route_tokens`] generalized to runtime activation: a [`DynamicK`]
/// policy plus an optional per-row k cap (`row_k_max[t]`, from effort
/// tiers via [`k_for_ratio`]).
pub fn route_tokens_dynamic(
    moe: &MoeLayerWeights,
    x: &Tensor,
    dk: DynamicK,
    row_k_max: Option<&[usize]>,
) -> Vec<GateDecision> {
    let scores = moe.router.scores(x);
    route_from_scores_dynamic(moe, &scores, dk, row_k_max)
}

/// Gate decisions from precomputed raw router scores `[q, N_r]` (the
/// fused-artifact path computes scores on device; this finishes the
/// bias + top-N_k + gate logic on host, where the bias adapts).
pub fn route_from_scores(moe: &MoeLayerWeights, scores: &Tensor) -> Vec<GateDecision> {
    route_from_scores_dynamic(moe, scores, DynamicK::fixed(), None)
}

/// [`route_from_scores`] generalized to runtime activation.
///
/// Per token `t` the effective cap is `min(row_k_max[t], N_k)` (or
/// N_k without caps), then [`DynamicK::k_for`] picks `k` within
/// `[k_min, cap]` from router entropy. Selection ranks by
/// `softmax(s) + bias` exactly as the fixed path does; because
/// [`tensor::top_k_indices`] is prefix-stable (descending, ties by
/// lower index), the k experts chosen here are always a prefix of the
/// fixed path's k_max choice — with `threshold == 0` and no caps the
/// decisions are *bit-identical* to [`route_from_scores`].
pub fn route_from_scores_dynamic(
    moe: &MoeLayerWeights,
    scores: &Tensor,
    dk: DynamicK,
    row_k_max: Option<&[usize]>,
) -> Vec<GateDecision> {
    let q = scores.shape[0];
    let n_r = moe.spec.routed();
    debug_assert_eq!(scores.shape[1], n_r);
    let n_k = moe.spec.active;
    if let Some(caps) = row_k_max {
        debug_assert_eq!(caps.len(), q, "row_k_max must have one cap per token");
    }
    let mut out = Vec::with_capacity(q);
    for t in 0..q {
        let s = scores.row(t);
        let sp = tensor::softmax(s);
        let cap = row_k_max.map_or(n_k, |caps| caps[t].clamp(1, n_k));
        let k = dk.k_for(&sp, cap);
        let ranked: Vec<f32> = (0..n_r).map(|i| sp[i] + moe.gate_bias[i]).collect();
        let selected = tensor::top_k_indices(&ranked, k);
        let gates = selected.iter().map(|&i| 1.0 + sp[i] * moe.gate_scale[i]).collect();
        out.push(GateDecision { experts: selected, gates, scores: s.to_vec() });
    }
    out
}

/// Expert-major (CSR-style) routing layout for one wave: for each
/// routed expert, the contiguous list of (token, gate) assignments.
///
/// This is the "expert → token index list" view the grouped dispatcher
/// consumes, inverted from the per-token [`GateDecision`] list the
/// router emits. Layout invariants (relied on by
/// `serving::dispatch::GroupedDispatcher` and its parity tests):
///
/// * rows `offsets[e] .. offsets[e+1]` belong to expert `e`, experts
///   ascending — the *expert block layout* of every gathered buffer;
/// * within an expert block, tokens keep ascending wave order;
/// * `token_idx`/`gates` are parallel arrays of length
///   [`GroupedRouting::total_rows`].
///
/// [`GroupedRouting::rebuild`] is allocation-free once the buffers have
/// grown to the wave's steady-state size (vectors are reused via
/// `clear` + `resize`), which is what keeps the decode hot loop free of
/// per-wave heap traffic.
#[derive(Clone, Debug, Default)]
pub struct GroupedRouting {
    n_experts: usize,
    /// `offsets[e]..offsets[e+1]` = rows of expert `e`; length
    /// `n_experts + 1`.
    offsets: Vec<usize>,
    /// Wave-token index of each row, expert-major.
    token_idx: Vec<usize>,
    /// Gate value of each row (parallel to `token_idx`).
    gates: Vec<f32>,
    /// Scratch write cursors for the fill pass.
    cursor: Vec<usize>,
}

impl GroupedRouting {
    pub fn new(n_experts: usize) -> GroupedRouting {
        GroupedRouting {
            n_experts,
            offsets: vec![0; n_experts + 1],
            token_idx: Vec::new(),
            gates: Vec::new(),
            cursor: vec![0; n_experts],
        }
    }

    /// Invert per-token decisions into the expert-major layout.
    /// Two passes (count, then fill) — no sorting, `O(assignments)`.
    /// Reuses all internal buffers; only grows them when a wave is
    /// larger than anything seen before.
    // lint: hot-path
    pub fn rebuild(&mut self, n_experts: usize, decisions: &[GateDecision]) {
        self.n_experts = n_experts;
        self.offsets.clear();
        self.offsets.resize(n_experts + 1, 0);
        self.cursor.clear();
        self.cursor.resize(n_experts, 0);
        // count into offsets[e + 1], then prefix-sum
        for dec in decisions {
            debug_assert_eq!(
                dec.experts.len(),
                dec.gates.len(),
                "malformed GateDecision: experts/gates length mismatch"
            );
            for &e in &dec.experts {
                debug_assert!(e < n_experts, "expert {e} out of range {n_experts}");
                self.offsets[e + 1] += 1;
            }
        }
        for e in 0..n_experts {
            self.offsets[e + 1] += self.offsets[e];
        }
        let total = self.offsets[n_experts];
        self.token_idx.clear();
        self.token_idx.resize(total, 0);
        self.gates.clear();
        self.gates.resize(total, 0.0);
        self.cursor.copy_from_slice(&self.offsets[..n_experts]);
        for (t, dec) in decisions.iter().enumerate() {
            for (&e, &g) in dec.experts.iter().zip(&dec.gates) {
                let row = self.cursor[e];
                self.cursor[e] += 1;
                self.token_idx[row] = t;
                self.gates[row] = g;
            }
        }
    }

    pub fn n_experts(&self) -> usize {
        self.n_experts
    }

    /// Total gathered rows (= total (token, expert) assignments).
    pub fn total_rows(&self) -> usize {
        *self.offsets.last().unwrap_or(&0)
    }

    /// Row range of expert `e` in the gathered buffers.
    pub fn expert_rows(&self, e: usize) -> std::ops::Range<usize> {
        self.offsets[e]..self.offsets[e + 1]
    }

    /// Tokens routed to expert `e`.
    pub fn count(&self, e: usize) -> usize {
        self.offsets[e + 1] - self.offsets[e]
    }

    /// The expert owning gathered row `r` (`r < total_rows()`); skips
    /// empty experts. O(log n_experts).
    pub fn expert_of_row(&self, r: usize) -> usize {
        debug_assert!(r < self.total_rows());
        self.offsets.partition_point(|&o| o <= r) - 1
    }

    /// Wave-token index per row, expert-major.
    pub fn token_idx(&self) -> &[usize] {
        &self.token_idx
    }

    /// Gate value per row, parallel to [`GroupedRouting::token_idx`].
    pub fn gates(&self) -> &[f32] {
        &self.gates
    }
}

/// Statistics of one MoE forward (feeds Figure 5 and the FLOPs counter).
#[derive(Clone, Debug, Default)]
pub struct MoeForwardStats {
    /// tokens routed to each expert
    pub expert_tokens: Vec<usize>,
    /// total tokens processed
    pub tokens: usize,
}

impl MoeForwardStats {
    /// Utilization fraction p_i per expert (shares of routed tokens;
    /// sums to 1 when any token was routed).
    pub fn utilization(&self) -> Vec<f64> {
        let total: usize = self.expert_tokens.iter().sum();
        self.expert_tokens
            .iter()
            .map(|&c| if total == 0 { 0.0 } else { c as f64 / total as f64 })
            .collect()
    }
}

/// Full MoE FFN forward `F_MoE(x) = E_s(x) + Σ g_i E_i(x)` (Eq. 4) for a
/// batch `x: [q, d]`. Returns output and routing stats.
pub fn moe_ffn_forward(moe: &MoeLayerWeights, x: &Tensor) -> (Tensor, MoeForwardStats) {
    moe_ffn_forward_dynamic(moe, x, DynamicK::fixed(), None)
}

/// [`moe_ffn_forward`] under runtime activation: dynamic-k and/or
/// per-row tier caps decide how many experts each token's sum spans.
/// With [`DynamicK::fixed`] and no caps this *is* the fixed forward.
pub fn moe_ffn_forward_dynamic(
    moe: &MoeLayerWeights,
    x: &Tensor,
    dk: DynamicK,
    row_k_max: Option<&[usize]>,
) -> (Tensor, MoeForwardStats) {
    let q = x.shape[0];
    let d = x.shape[1];
    let decisions = route_tokens_dynamic(moe, x, dk, row_k_max);

    // shared expert: dense over the whole batch
    let mut out = tensor::swiglu_ffn(x, &moe.shared.w_gate, &moe.shared.w_up, &moe.shared.w_down);

    // group tokens by expert so each expert runs one batched GEMM —
    // the same schedule the serving engine's dispatcher uses.
    let n_r = moe.spec.routed();
    let mut groups: Vec<Vec<(usize, f32)>> = vec![Vec::new(); n_r];
    for (t, dec) in decisions.iter().enumerate() {
        for (k, &e) in dec.experts.iter().enumerate() {
            groups[e].push((t, dec.gates[k]));
        }
    }
    // G-MoEfication compensation: deactivated experts contribute their
    // calibration-mean output instead of zero. Add the total once, then
    // subtract each *selected* expert's compensation inside its group.
    if let Some(comp) = &moe.compensation {
        let mut total = vec![0.0f32; d];
        for c in comp {
            for (t, v) in total.iter_mut().zip(c) {
                *t += v;
            }
        }
        for t in 0..q {
            let row = out.row_mut(t);
            for (o, v) in row.iter_mut().zip(&total) {
                *o += v;
            }
        }
        for (t, dec) in decisions.iter().enumerate() {
            let row = out.row_mut(t);
            for &e in &dec.experts {
                for (o, v) in row.iter_mut().zip(&comp[e]) {
                    *o -= v;
                }
            }
        }
    }

    let mut stats = MoeForwardStats { expert_tokens: vec![0; n_r], tokens: q };
    for (e, group) in groups.iter().enumerate() {
        stats.expert_tokens[e] = group.len();
        if group.is_empty() {
            continue;
        }
        let idx: Vec<usize> = group.iter().map(|&(t, _)| t).collect();
        let xe = x.select_rows(&idx);
        let ye = tensor::swiglu_ffn(&xe, &moe.experts[e].w_gate, &moe.experts[e].w_up, &moe.experts[e].w_down);
        for (r, &(t, g)) in group.iter().enumerate() {
            let src = ye.row(r);
            let dst = &mut out.row_mut(t)[..d];
            for (o, v) in dst.iter_mut().zip(src) {
                *o += g * v;
            }
        }
    }
    (out, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::converter::{convert_ffn, ConvertOptions};
    use crate::model::{FfnWeights, MoeSpec};
    use crate::profiling::ActivationProfile;
    use crate::util::Rng;

    /// Build a converted MoE layer from a random FFN for testing.
    fn test_moe(rng: &mut Rng, spec: &str) -> (FfnWeights, MoeLayerWeights) {
        let d = 16;
        let d_h = 64;
        let ffn = FfnWeights {
            w_gate: Tensor::randn(rng, &[d, d_h], 0.4),
            w_up: Tensor::randn(rng, &[d, d_h], 0.4),
            w_down: Tensor::randn(rng, &[d_h, d], 0.4),
        };
        let x = Tensor::randn(rng, &[200, d], 1.0);
        let h = tensor::swiglu_hidden(&x, &ffn.w_gate, &ffn.w_up);
        let prof = ActivationProfile::from_hidden(&h, 8);
        let spec: MoeSpec = spec.parse().unwrap();
        let moe = convert_ffn(&ffn, &prof, &spec, &ConvertOptions::default()).unwrap();
        (ffn, moe)
    }

    #[test]
    fn all_experts_active_reconstructs_exactly() {
        // With N_k = N_r and u = 0 the MoE must equal the dense FFN
        // (partition + gates of 1 ⇒ identical sum, Eq. 5 with S_de = ∅).
        let mut rng = Rng::new(11);
        let (ffn, moe) = test_moe(&mut rng, "S3A5E8");
        let x = Tensor::randn(&mut rng, &[12, 16], 1.0);
        let dense = tensor::swiglu_ffn(&x, &ffn.w_gate, &ffn.w_up, &ffn.w_down);
        let (sparse, _) = moe_ffn_forward(&moe, &x);
        assert!(
            dense.max_abs_diff(&sparse) < 1e-4,
            "full-activation MoE differs from dense: {}",
            dense.max_abs_diff(&sparse)
        );
    }

    #[test]
    fn sparse_moe_is_close_but_not_exact() {
        let mut rng = Rng::new(12);
        let (ffn, moe) = test_moe(&mut rng, "S3A3E8");
        let x = Tensor::randn(&mut rng, &[40, 16], 1.0);
        let dense = tensor::swiglu_ffn(&x, &ffn.w_gate, &ffn.w_up, &ffn.w_down);
        let (sparse, stats) = moe_ffn_forward(&moe, &x);
        let rel = {
            let mut diff = dense.clone();
            for (a, b) in diff.data.iter_mut().zip(&sparse.data) {
                *a -= b;
            }
            diff.norm() / dense.norm()
        };
        assert!(rel < 0.8, "reconstruction error too large: {rel}");
        assert!(rel > 0.0, "sparse forward suspiciously exact");
        // every token went to exactly N_k experts
        let total: usize = stats.expert_tokens.iter().sum();
        assert_eq!(total, 40 * 3);
    }

    #[test]
    fn route_tokens_respects_nk_and_bias() {
        let mut rng = Rng::new(13);
        let (_, mut moe) = test_moe(&mut rng, "S3A3E8");
        let x = Tensor::randn(&mut rng, &[10, 16], 1.0);
        let dec = route_tokens(&moe, &x);
        for d in &dec {
            assert_eq!(d.experts.len(), 3);
            assert_eq!(d.scores.len(), 5);
            // default gates are exactly 1 (u initialized to 0)
            assert!(d.gates.iter().all(|&g| (g - 1.0).abs() < 1e-7));
        }
        // huge bias forces expert 4 into every selection...
        moe.gate_bias[4] = 1e6;
        let dec2 = route_tokens(&moe, &x);
        assert!(dec2.iter().all(|d| d.experts.contains(&4)));
        // ...but gates stay at 1: bias must not leak into outputs
        for d in &dec2 {
            assert!(d.gates.iter().all(|&g| (g - 1.0).abs() < 1e-7));
        }
    }

    #[test]
    fn gate_scale_changes_gates() {
        let mut rng = Rng::new(14);
        let (_, mut moe) = test_moe(&mut rng, "S3A3E8");
        for u in moe.gate_scale.iter_mut() {
            *u = 2.0;
        }
        let x = Tensor::randn(&mut rng, &[5, 16], 1.0);
        let dec = route_tokens(&moe, &x);
        for d in &dec {
            assert!(d.gates.iter().all(|&g| g > 1.0), "gates {:?}", d.gates);
        }
    }

    #[test]
    fn grouped_routing_inverts_decisions() {
        let dec = vec![
            GateDecision { experts: vec![2, 0], gates: vec![0.5, 1.5], scores: vec![] },
            GateDecision { experts: vec![0], gates: vec![2.0], scores: vec![] },
            GateDecision { experts: vec![2], gates: vec![3.0], scores: vec![] },
        ];
        let mut r = GroupedRouting::new(4);
        r.rebuild(4, &dec);
        assert_eq!(r.total_rows(), 4);
        // expert 0: tokens 0, 1 in wave order
        assert_eq!(r.expert_rows(0), 0..2);
        assert_eq!(&r.token_idx()[0..2], &[0, 1]);
        assert_eq!(&r.gates()[0..2], &[1.5, 2.0]);
        // experts 1 and 3 are empty
        assert_eq!(r.count(1), 0);
        assert_eq!(r.count(3), 0);
        // expert 2: tokens 0, 2
        assert_eq!(r.expert_rows(2), 2..4);
        assert_eq!(&r.token_idx()[2..4], &[0, 2]);
        assert_eq!(&r.gates()[2..4], &[0.5, 3.0]);
        // row → expert lookup skips the empty expert 1
        assert_eq!(r.expert_of_row(0), 0);
        assert_eq!(r.expert_of_row(1), 0);
        assert_eq!(r.expert_of_row(2), 2);
        assert_eq!(r.expert_of_row(3), 2);
    }

    #[test]
    fn grouped_routing_reuse_across_waves() {
        // rebuild must stay correct when the expert count and wave size
        // shrink and grow between calls (buffer-reuse paths)
        let mut r = GroupedRouting::new(2);
        let big: Vec<GateDecision> = (0..20)
            .map(|t| GateDecision {
                experts: vec![t % 5],
                gates: vec![t as f32],
                scores: vec![],
            })
            .collect();
        r.rebuild(5, &big);
        assert_eq!(r.total_rows(), 20);
        assert_eq!(r.n_experts(), 5);
        for e in 0..5 {
            assert_eq!(r.count(e), 4);
        }
        // shrink to an empty wave
        r.rebuild(3, &[]);
        assert_eq!(r.total_rows(), 0);
        assert_eq!(r.n_experts(), 3);
        assert_eq!(r.count(2), 0);
        // grow again
        r.rebuild(5, &big);
        let total: usize = (0..5).map(|e| r.count(e)).sum();
        assert_eq!(total, 20);
        // conservation: every (token, expert, gate) triple shows up once
        for (t, dec) in big.iter().enumerate() {
            let e = dec.experts[0];
            let rows = r.expert_rows(e);
            let hit = rows
                .clone()
                .filter(|&row| r.token_idx()[row] == t && r.gates()[row] == dec.gates[0])
                .count();
            assert_eq!(hit, 1, "token {t} expert {e}");
        }
    }

    #[test]
    fn grouped_routing_conservation_property() {
        crate::util::prop::check(
            "grouped-routing-conservation",
            crate::util::prop::Config { cases: 48, max_size: 32, ..Default::default() },
            |rng, size| {
                let b = rng.range(1, size + 2);
                let n_e = rng.range(1, 9);
                let dec: Vec<GateDecision> = (0..b)
                    .map(|_| {
                        let k = rng.range(1, n_e + 1);
                        let experts = rng.choose_k(n_e, k);
                        GateDecision {
                            gates: (0..k).map(|_| rng.normal()).collect(),
                            experts,
                            scores: vec![],
                        }
                    })
                    .collect();
                let total: usize = dec.iter().map(|d| d.experts.len()).sum();
                let mut r = GroupedRouting::new(n_e);
                r.rebuild(n_e, &dec);
                crate::prop_assert!(r.total_rows() == total, "row count mismatch");
                let counted: usize = (0..n_e).map(|e| r.count(e)).sum();
                crate::prop_assert!(counted == total, "offsets don't cover rows");
                for e in 0..n_e {
                    let rows = r.expert_rows(e);
                    crate::prop_assert!(rows.start <= rows.end, "offsets not monotone");
                    // tokens ascend within an expert block
                    let toks = &r.token_idx()[rows];
                    crate::prop_assert!(
                        toks.windows(2).all(|w| w[0] < w[1]),
                        "tokens out of order for expert {e}: {toks:?}"
                    );
                }
                Ok(())
            },
        );
    }

    #[test]
    fn normalized_entropy_edges() {
        // degenerate distributions carry no uncertainty
        assert_eq!(normalized_entropy(&[]), 0.0);
        assert_eq!(normalized_entropy(&[1.0]), 0.0);
        // a point mass scores 0, uniform scores 1 (up to rounding)
        assert_eq!(normalized_entropy(&[1.0, 0.0, 0.0, 0.0]), 0.0);
        let u = normalized_entropy(&[0.25; 4]);
        assert!((u - 1.0).abs() < 1e-6, "uniform entropy {u}");
        // skewed lands strictly between
        let s = normalized_entropy(&[0.7, 0.1, 0.1, 0.1]);
        assert!(s > 0.0 && s < 1.0, "skewed entropy {s}");
    }

    #[test]
    fn k_for_ratio_operating_points() {
        // the paper's 25% / 75% points over k_full = 4
        assert_eq!(k_for_ratio(PAPER_RATIO_LOW, PAPER_N_K), PAPER_K_LOW);
        assert_eq!(k_for_ratio(PAPER_RATIO_HIGH, PAPER_N_K), PAPER_K_HIGH);
        // full effort and anything above is exactly k_full
        assert_eq!(k_for_ratio(1.0, 4), 4);
        assert_eq!(k_for_ratio(2.0, 4), 4);
        // never below one expert, never above k_full, NaN = full
        assert_eq!(k_for_ratio(0.0, 4), 1);
        assert_eq!(k_for_ratio(-1.0, 4), 1);
        assert_eq!(k_for_ratio(f32::NAN, 4), 4);
        assert_eq!(k_for_ratio(0.5, 0), 0);
    }

    #[test]
    fn dynamic_k_zero_threshold_is_fixed_path() {
        let mut rng = Rng::new(15);
        let (_, moe) = test_moe(&mut rng, "S2A3E8");
        let x = Tensor::randn(&mut rng, &[24, 16], 1.0);
        let fixed = route_tokens(&moe, &x);
        let dynamic = route_tokens_dynamic(&moe, &x, DynamicK::fixed(), None);
        for (a, b) in fixed.iter().zip(&dynamic) {
            assert_eq!(a.experts, b.experts);
            assert_eq!(a.gates, b.gates);
            assert_eq!(a.scores, b.scores);
        }
    }

    #[test]
    fn dynamic_k_respects_bounds_and_row_caps() {
        let mut rng = Rng::new(16);
        let (_, moe) = test_moe(&mut rng, "S2A3E8");
        let x = Tensor::randn(&mut rng, &[24, 16], 1.0);
        let dk = DynamicK { threshold: 0.9, k_min: 1 };
        let dec = route_tokens_dynamic(&moe, &x, dk, None);
        assert!(dec.iter().all(|d| (1..=3).contains(&d.experts.len())));
        // a per-row cap of 1 forces exactly one expert everywhere
        let caps = vec![1usize; 24];
        let capped = route_tokens_dynamic(&moe, &x, dk, Some(&caps));
        assert!(capped.iter().all(|d| d.experts.len() == 1));
        // and the capped choice is a prefix of the uncapped ranking
        for (a, b) in capped.iter().zip(&dec) {
            assert_eq!(a.experts[0], b.experts[0]);
        }
    }

    #[test]
    fn utilization_sums_to_one() {
        let stats = MoeForwardStats { expert_tokens: vec![10, 30, 0, 20], tokens: 60 };
        let u = stats.utilization();
        assert!((u.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert_eq!(u[2], 0.0);
    }
}
