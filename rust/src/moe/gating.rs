//! Routing and gated MoE forward (rust reference path).
//!
//! The serving engine executes experts through XLA artifacts; this
//! module is the bit-exact rust-side reference used by evaluation, the
//! fine-tuner, and tests. Routing logic (scores → bias → top-N_k →
//! gates) is shared by both paths via [`route_tokens`].

use crate::model::MoeLayerWeights;
use crate::tensor::{self, Tensor};

/// Routing decision for one token.
#[derive(Clone, Debug)]
pub struct GateDecision {
    /// Selected routed-expert ids (len = N_k), unordered.
    pub experts: Vec<usize>,
    /// Gate value per selected expert (`1 + s'_i · u_i`, Eq. 9).
    pub gates: Vec<f32>,
    /// Raw router scores `s` (len = N_r) — kept for fine-tuning.
    pub scores: Vec<f32>,
}

/// Compute router scores for a batch of (normed) token vectors
/// `x: [q, d]` and produce per-token gate decisions.
///
/// Scores are the representative-neuron SwiGLU responses (Eq. 8);
/// selection adds the load-balance bias *only for ranking* (the bias
/// never scales outputs), gates are `1 + softmax(s)_i · u_i`.
pub fn route_tokens(moe: &MoeLayerWeights, x: &Tensor) -> Vec<GateDecision> {
    let scores = moe.router.scores(x);
    route_from_scores(moe, &scores)
}

/// Gate decisions from precomputed raw router scores `[q, N_r]` (the
/// fused-artifact path computes scores on device; this finishes the
/// bias + top-N_k + gate logic on host, where the bias adapts).
pub fn route_from_scores(moe: &MoeLayerWeights, scores: &Tensor) -> Vec<GateDecision> {
    let q = scores.shape[0];
    let n_r = moe.spec.routed();
    debug_assert_eq!(scores.shape[1], n_r);
    let n_k = moe.spec.active;
    let mut out = Vec::with_capacity(q);
    for t in 0..q {
        let s = scores.row(t);
        let sp = tensor::softmax(s);
        let ranked: Vec<f32> = (0..n_r).map(|i| sp[i] + moe.gate_bias[i]).collect();
        let selected = tensor::top_k_indices(&ranked, n_k);
        let gates = selected.iter().map(|&i| 1.0 + sp[i] * moe.gate_scale[i]).collect();
        out.push(GateDecision { experts: selected, gates, scores: s.to_vec() });
    }
    out
}

/// Statistics of one MoE forward (feeds Figure 5 and the FLOPs counter).
#[derive(Clone, Debug, Default)]
pub struct MoeForwardStats {
    /// tokens routed to each expert
    pub expert_tokens: Vec<usize>,
    /// total tokens processed
    pub tokens: usize,
}

impl MoeForwardStats {
    /// Utilization fraction p_i per expert (shares of routed tokens;
    /// sums to 1 when any token was routed).
    pub fn utilization(&self) -> Vec<f64> {
        let total: usize = self.expert_tokens.iter().sum();
        self.expert_tokens
            .iter()
            .map(|&c| if total == 0 { 0.0 } else { c as f64 / total as f64 })
            .collect()
    }
}

/// Full MoE FFN forward `F_MoE(x) = E_s(x) + Σ g_i E_i(x)` (Eq. 4) for a
/// batch `x: [q, d]`. Returns output and routing stats.
pub fn moe_ffn_forward(moe: &MoeLayerWeights, x: &Tensor) -> (Tensor, MoeForwardStats) {
    let q = x.shape[0];
    let d = x.shape[1];
    let decisions = route_tokens(moe, x);

    // shared expert: dense over the whole batch
    let mut out = tensor::swiglu_ffn(x, &moe.shared.w_gate, &moe.shared.w_up, &moe.shared.w_down);

    // group tokens by expert so each expert runs one batched GEMM —
    // the same schedule the serving engine's dispatcher uses.
    let n_r = moe.spec.routed();
    let mut groups: Vec<Vec<(usize, f32)>> = vec![Vec::new(); n_r];
    for (t, dec) in decisions.iter().enumerate() {
        for (k, &e) in dec.experts.iter().enumerate() {
            groups[e].push((t, dec.gates[k]));
        }
    }
    // G-MoEfication compensation: deactivated experts contribute their
    // calibration-mean output instead of zero. Add the total once, then
    // subtract each *selected* expert's compensation inside its group.
    if let Some(comp) = &moe.compensation {
        let mut total = vec![0.0f32; d];
        for c in comp {
            for (t, v) in total.iter_mut().zip(c) {
                *t += v;
            }
        }
        for t in 0..q {
            let row = out.row_mut(t);
            for (o, v) in row.iter_mut().zip(&total) {
                *o += v;
            }
        }
        for (t, dec) in decisions.iter().enumerate() {
            let row = out.row_mut(t);
            for &e in &dec.experts {
                for (o, v) in row.iter_mut().zip(&comp[e]) {
                    *o -= v;
                }
            }
        }
    }

    let mut stats = MoeForwardStats { expert_tokens: vec![0; n_r], tokens: q };
    for (e, group) in groups.iter().enumerate() {
        stats.expert_tokens[e] = group.len();
        if group.is_empty() {
            continue;
        }
        let idx: Vec<usize> = group.iter().map(|&(t, _)| t).collect();
        let xe = x.select_rows(&idx);
        let ye = tensor::swiglu_ffn(&xe, &moe.experts[e].w_gate, &moe.experts[e].w_up, &moe.experts[e].w_down);
        for (r, &(t, g)) in group.iter().enumerate() {
            let src = ye.row(r);
            let dst = &mut out.row_mut(t)[..d];
            for (o, v) in dst.iter_mut().zip(src) {
                *o += g * v;
            }
        }
    }
    (out, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::converter::{convert_ffn, ConvertOptions};
    use crate::model::{FfnWeights, MoeSpec};
    use crate::profiling::ActivationProfile;
    use crate::util::Rng;

    /// Build a converted MoE layer from a random FFN for testing.
    fn test_moe(rng: &mut Rng, spec: &str) -> (FfnWeights, MoeLayerWeights) {
        let d = 16;
        let d_h = 64;
        let ffn = FfnWeights {
            w_gate: Tensor::randn(rng, &[d, d_h], 0.4),
            w_up: Tensor::randn(rng, &[d, d_h], 0.4),
            w_down: Tensor::randn(rng, &[d_h, d], 0.4),
        };
        let x = Tensor::randn(rng, &[200, d], 1.0);
        let h = tensor::swiglu_hidden(&x, &ffn.w_gate, &ffn.w_up);
        let prof = ActivationProfile::from_hidden(&h, 8);
        let spec: MoeSpec = spec.parse().unwrap();
        let moe = convert_ffn(&ffn, &prof, &spec, &ConvertOptions::default()).unwrap();
        (ffn, moe)
    }

    #[test]
    fn all_experts_active_reconstructs_exactly() {
        // With N_k = N_r and u = 0 the MoE must equal the dense FFN
        // (partition + gates of 1 ⇒ identical sum, Eq. 5 with S_de = ∅).
        let mut rng = Rng::new(11);
        let (ffn, moe) = test_moe(&mut rng, "S3A5E8");
        let x = Tensor::randn(&mut rng, &[12, 16], 1.0);
        let dense = tensor::swiglu_ffn(&x, &ffn.w_gate, &ffn.w_up, &ffn.w_down);
        let (sparse, _) = moe_ffn_forward(&moe, &x);
        assert!(
            dense.max_abs_diff(&sparse) < 1e-4,
            "full-activation MoE differs from dense: {}",
            dense.max_abs_diff(&sparse)
        );
    }

    #[test]
    fn sparse_moe_is_close_but_not_exact() {
        let mut rng = Rng::new(12);
        let (ffn, moe) = test_moe(&mut rng, "S3A3E8");
        let x = Tensor::randn(&mut rng, &[40, 16], 1.0);
        let dense = tensor::swiglu_ffn(&x, &ffn.w_gate, &ffn.w_up, &ffn.w_down);
        let (sparse, stats) = moe_ffn_forward(&moe, &x);
        let rel = {
            let mut diff = dense.clone();
            for (a, b) in diff.data.iter_mut().zip(&sparse.data) {
                *a -= b;
            }
            diff.norm() / dense.norm()
        };
        assert!(rel < 0.8, "reconstruction error too large: {rel}");
        assert!(rel > 0.0, "sparse forward suspiciously exact");
        // every token went to exactly N_k experts
        let total: usize = stats.expert_tokens.iter().sum();
        assert_eq!(total, 40 * 3);
    }

    #[test]
    fn route_tokens_respects_nk_and_bias() {
        let mut rng = Rng::new(13);
        let (_, mut moe) = test_moe(&mut rng, "S3A3E8");
        let x = Tensor::randn(&mut rng, &[10, 16], 1.0);
        let dec = route_tokens(&moe, &x);
        for d in &dec {
            assert_eq!(d.experts.len(), 3);
            assert_eq!(d.scores.len(), 5);
            // default gates are exactly 1 (u initialized to 0)
            assert!(d.gates.iter().all(|&g| (g - 1.0).abs() < 1e-7));
        }
        // huge bias forces expert 4 into every selection...
        moe.gate_bias[4] = 1e6;
        let dec2 = route_tokens(&moe, &x);
        assert!(dec2.iter().all(|d| d.experts.contains(&4)));
        // ...but gates stay at 1: bias must not leak into outputs
        for d in &dec2 {
            assert!(d.gates.iter().all(|&g| (g - 1.0).abs() < 1e-7));
        }
    }

    #[test]
    fn gate_scale_changes_gates() {
        let mut rng = Rng::new(14);
        let (_, mut moe) = test_moe(&mut rng, "S3A3E8");
        for u in moe.gate_scale.iter_mut() {
            *u = 2.0;
        }
        let x = Tensor::randn(&mut rng, &[5, 16], 1.0);
        let dec = route_tokens(&moe, &x);
        for d in &dec {
            assert!(d.gates.iter().all(|&g| g > 1.0), "gates {:?}", d.gates);
        }
    }

    #[test]
    fn utilization_sums_to_one() {
        let stats = MoeForwardStats { expert_tokens: vec![10, 30, 0, 20], tokens: 60 };
        let u = stats.utilization();
        assert!((u.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert_eq!(u[2], 0.0);
    }
}
