//! Activation profiling (paper §3, §A.2).
//!
//! Given FFN hidden states `H ∈ R^{q×d_h}` captured on a calibration
//! set, build the ATopK binary activation matrix `A` (per token, the
//! top-`K_a` neurons by |h|), per-neuron activation rates `μ`, and the
//! statistics behind Figures 1–2 (activation distribution, bimodality).
//!
//! Hidden states come either from [`crate::runtime`] (the `ffn_hidden`
//! artifact — the production path) or from [`crate::tensor::swiglu_hidden`]
//! (pure-rust path used by tests and the conversion CLI when artifacts
//! are not built yet).

use crate::tensor::{atopk_mask, Tensor};
use crate::util::stats::{bimodality_coefficient, Histogram};

/// Activation profile of ONE FFN layer over a calibration set.
#[derive(Clone, Debug)]
pub struct ActivationProfile {
    /// Neuron count `d_h`.
    pub d_h: usize,
    /// Tokens profiled `q`.
    pub q: usize,
    /// ATopK parameter `K_a`.
    pub k_a: usize,
    /// Binary activation matrix, row-major `[q, d_h]` (Eq. 14).
    pub a: Vec<u8>,
    /// Per-neuron mean |h| (used by the WINA baseline and router checks).
    pub mean_abs_h: Vec<f32>,
    /// Sampled raw activations (for the Figure-1 histogram).
    pub h_sample: Vec<f32>,
}

impl ActivationProfile {
    /// Build a profile from hidden states `h: [q, d_h]`.
    pub fn from_hidden(h: &Tensor, k_a: usize) -> ActivationProfile {
        assert_eq!(h.rank(), 2);
        let (q, d_h) = (h.shape[0], h.shape[1]);
        assert!(k_a <= d_h, "K_a={k_a} > d_h={d_h}");
        let a = atopk_mask(h, k_a);
        let mut mean_abs_h = vec![0.0f32; d_h];
        for t in 0..q {
            let row = h.row(t);
            for (i, v) in row.iter().enumerate() {
                mean_abs_h[i] += v.abs();
            }
        }
        for v in mean_abs_h.iter_mut() {
            *v /= q as f32;
        }
        // reservoir-free subsample for fig1: every k-th value, cap 100k
        let stride = (q * d_h / 100_000).max(1);
        let h_sample: Vec<f32> = h.data.iter().step_by(stride).copied().collect();
        ActivationProfile { d_h, q, k_a, a, mean_abs_h, h_sample }
    }

    /// Merge another profile of the same layer (concatenates tokens).
    pub fn merge(&mut self, other: &ActivationProfile) {
        assert_eq!(self.d_h, other.d_h);
        assert_eq!(self.k_a, other.k_a);
        let q0 = self.q;
        self.a.extend_from_slice(&other.a);
        for i in 0..self.d_h {
            self.mean_abs_h[i] = (self.mean_abs_h[i] * q0 as f32
                + other.mean_abs_h[i] * other.q as f32)
                / (q0 + other.q) as f32;
        }
        self.h_sample.extend_from_slice(&other.h_sample);
        self.q += other.q;
    }

    /// Activation rates `μ_i = mean(c_i)` (Eq. 15).
    pub fn rates(&self) -> Vec<f32> {
        let mut mu = vec![0.0f32; self.d_h];
        for t in 0..self.q {
            let row = &self.a[t * self.d_h..(t + 1) * self.d_h];
            for (i, &b) in row.iter().enumerate() {
                mu[i] += b as f32;
            }
        }
        for v in mu.iter_mut() {
            *v /= self.q as f32;
        }
        mu
    }

    /// Activation feature column `c_i ∈ {0,1}^q` of neuron `i`.
    pub fn column(&self, i: usize) -> Vec<f32> {
        (0..self.q).map(|t| self.a[t * self.d_h + i] as f32).collect()
    }

    /// Rows = selected neurons, cols = tokens: the points clustered by
    /// balanced K-means (`[n, q]`).
    pub fn columns_tensor(&self, neurons: &[usize]) -> Tensor {
        let mut t = Tensor::zeros(&[neurons.len(), self.q]);
        for (r, &i) in neurons.iter().enumerate() {
            let row = t.row_mut(r);
            for (tok, v) in row.iter_mut().enumerate() {
                *v = self.a[tok * self.d_h + i] as f32;
            }
        }
        t
    }

    /// Figure 1: histogram of raw hidden activations.
    pub fn activation_histogram(&self, bins: usize) -> Histogram {
        let lo = self.h_sample.iter().cloned().fold(f32::INFINITY, f32::min);
        let hi = self.h_sample.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let (lo, hi) = if lo < hi { (lo, hi) } else { (-1.0, 1.0) };
        Histogram::from_values(&self.h_sample, lo, hi + 1e-6, bins)
    }

    /// Figure 2: histogram of activation rates.
    pub fn rate_histogram(&self, bins: usize) -> Histogram {
        Histogram::from_values(&self.rates(), 0.0, 1.0 + 1e-6, bins)
    }

    /// Bimodality coefficient of the rate distribution (> 5/9 ⇒ the
    /// two-group structure of §3.2 is present).
    pub fn rate_bimodality(&self) -> f64 {
        bimodality_coefficient(&self.rates())
    }

    /// Fraction of |h| values below `threshold` — quantifies Figure 1's
    /// "sharply peaked at zero".
    pub fn sparsity_fraction(&self, threshold: f32) -> f64 {
        if self.h_sample.is_empty() {
            return 0.0;
        }
        self.h_sample.iter().filter(|v| v.abs() < threshold).count() as f64
            / self.h_sample.len() as f64
    }

    /// Indices of the `n` highest-rate neurons (shared-expert candidates,
    /// Eq. 16). Ties broken by lower index.
    pub fn top_rate_neurons(&self, n: usize) -> Vec<usize> {
        let mu = self.rates();
        crate::tensor::top_k_indices(&mu, n)
    }

    /// Overlap |A ∩ B| / n between the top-`n` neuron sets of two
    /// profiles — the paper's domain-invariance measurement (§5.3,
    /// 80–86% overlap across math/science/code).
    pub fn shared_overlap(&self, other: &ActivationProfile, n: usize) -> f64 {
        let a: std::collections::HashSet<usize> = self.top_rate_neurons(n).into_iter().collect();
        let b: std::collections::HashSet<usize> = other.top_rate_neurons(n).into_iter().collect();
        a.intersection(&b).count() as f64 / n as f64
    }
}

/// Capture profiles for every layer of a dense model with pure-rust
/// matmuls (no XLA dependency): runs the *real* forward pass token by
/// token including attention, so the hidden states match the model the
/// serving path executes. `tokens: [q]` ids, processed in one sequence
/// chunk per `seq_len` window.
pub fn profile_dense_model(
    model: &crate::model::ModelWeights,
    token_ids: &[usize],
    seq_len: usize,
    k_a: usize,
) -> Vec<ActivationProfile> {
    let fwd = crate::eval::forward::DenseForward::new(model);
    let mut profiles: Vec<Option<ActivationProfile>> = vec![None; model.config.n_layers];
    for chunk in token_ids.chunks(seq_len) {
        let caps = fwd.capture_hidden(chunk);
        for (l, h) in caps.into_iter().enumerate() {
            let p = ActivationProfile::from_hidden(&h, k_a);
            match &mut profiles[l] {
                Some(acc) => acc.merge(&p),
                slot => *slot = Some(p),
            }
        }
    }
    profiles.into_iter().map(|p| p.expect("no calibration tokens")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn synthetic_hidden(rng: &mut Rng, q: usize, d_h: usize, hot: &[usize]) -> Tensor {
        // "hot" neurons get large activations on every token; others are
        // small noise with occasional structured spikes.
        let mut h = Tensor::zeros(&[q, d_h]);
        for t in 0..q {
            let row = h.row_mut(t);
            for (i, v) in row.iter_mut().enumerate() {
                *v = 0.01 * rng.normal();
            }
            for &i in hot {
                row[i] = 2.0 + rng.normal() * 0.1;
            }
            // a few conditional neurons fire per token
            for _ in 0..4 {
                let i = rng.below(d_h);
                row[i] += 1.0;
            }
        }
        h
    }

    #[test]
    fn rates_detect_hot_neurons() {
        let mut rng = Rng::new(1);
        let hot = [3usize, 17, 42];
        let h = synthetic_hidden(&mut rng, 200, 64, &hot);
        let p = ActivationProfile::from_hidden(&h, 8);
        let mu = p.rates();
        for &i in &hot {
            assert!(mu[i] > 0.99, "hot neuron {i} rate {}", mu[i]);
        }
        let top = p.top_rate_neurons(3);
        let mut ts = top.clone();
        ts.sort_unstable();
        assert_eq!(ts, hot.to_vec());
    }

    #[test]
    fn rates_are_k_over_dh_on_average() {
        let mut rng = Rng::new(2);
        let h = Tensor::randn(&mut rng, &[100, 50], 1.0);
        let p = ActivationProfile::from_hidden(&h, 10);
        let mu = p.rates();
        let mean_rate: f32 = mu.iter().sum::<f32>() / 50.0;
        assert!((mean_rate - 0.2).abs() < 1e-6, "mean rate {mean_rate} != K_a/d_h");
    }

    #[test]
    fn merge_concatenates() {
        let mut rng = Rng::new(3);
        let h1 = Tensor::randn(&mut rng, &[30, 16], 1.0);
        let h2 = Tensor::randn(&mut rng, &[20, 16], 1.0);
        let mut p1 = ActivationProfile::from_hidden(&h1, 4);
        let p2 = ActivationProfile::from_hidden(&h2, 4);
        p1.merge(&p2);
        assert_eq!(p1.q, 50);
        assert_eq!(p1.a.len(), 50 * 16);
        let mean_rate: f32 = p1.rates().iter().sum::<f32>() / 16.0;
        assert!((mean_rate - 0.25).abs() < 1e-6);
    }

    #[test]
    fn bimodality_on_structured_activations() {
        let mut rng = Rng::new(4);
        let hot: Vec<usize> = (0..8).collect();
        let h = synthetic_hidden(&mut rng, 300, 128, &hot);
        let p = ActivationProfile::from_hidden(&h, 12);
        assert!(p.rate_bimodality() > 5.0 / 9.0, "bimodality {}", p.rate_bimodality());
    }

    #[test]
    fn columns_tensor_matches_column() {
        let mut rng = Rng::new(5);
        let h = Tensor::randn(&mut rng, &[40, 12], 1.0);
        let p = ActivationProfile::from_hidden(&h, 3);
        let t = p.columns_tensor(&[5, 9]);
        assert_eq!(t.shape, vec![2, 40]);
        assert_eq!(t.row(0), p.column(5).as_slice());
        assert_eq!(t.row(1), p.column(9).as_slice());
    }

    #[test]
    fn overlap_of_identical_profiles_is_one() {
        let mut rng = Rng::new(6);
        let h = Tensor::randn(&mut rng, &[50, 32], 1.0);
        let p = ActivationProfile::from_hidden(&h, 6);
        assert_eq!(p.shared_overlap(&p, 8), 1.0);
    }

    #[test]
    fn sparsity_fraction_counts_near_zero() {
        let h = Tensor::from_vec(vec![0.001, -0.002, 5.0, 0.0003], &[1, 4]);
        let p = ActivationProfile::from_hidden(&h, 1);
        assert!((p.sparsity_fraction(0.01) - 0.75).abs() < 1e-9);
    }
}
