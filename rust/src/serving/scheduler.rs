//! Continuous in-flight batching: a fixed pool of KV slots, per-step
//! admission and retirement, and a minimal-covering compiled-bucket
//! choice — the scheduler half of the serving engine.
//!
//! The run-to-completion wave path ([`crate::serving::Engine::run_queue_waves`])
//! holds a whole batch hostage until its longest member finishes:
//! retired neighbors pad every GEMM and queued requests wait for the
//! wave boundary. This module inverts that control flow. A
//! [`Scheduler`] owns `max(buckets)` KV slots; every step it
//!
//! 1. **admits** queued requests FIFO into free slots (recycling
//!    retired slots before touching fresh ones),
//! 2. **prefills** the admissions and samples their first token,
//! 3. runs **one decode step** over the live slots at the smallest
//!    compiled batch bucket covering them, and
//! 4. **retires** every request that hit its stop token,
//!    `max_new_tokens`, or the KV capacity — freeing the slot for the
//!    next step's admission.
//!
//! Scheduling is pure host logic, factored away from the artifact
//! runtime behind the [`StepForward`] trait so it is exhaustively
//! testable without compiled artifacts: [`StubForward`] is a
//! deterministic host-only model whose logits depend only on a
//! request's own context, which makes "continuous batching preserves
//! each request's exact token stream" a checkable property
//! (`tests/scheduler.rs`, `tests/continuous_sim.rs`). The artifact
//! engine drives the *same* [`ContinuousSession`] through its
//! `EngineStepForward` implementation.
//!
//! Invariants (property-tested):
//! * a slot is never double-assigned; `live + free == pool` always;
//! * admission order is FIFO in enqueue order;
//! * retired slots are reused before never-used slots;
//! * the step bucket is the smallest configured bucket ≥ live count;
//! * per-request output is token-identical to running that request
//!   alone (batch rows are independent), hence identical to the
//!   run-to-completion wave engine;
//! * a request waits at most the pool-serialized work of the requests
//!   ahead of it (no starvation; FIFO admission bounds queue wait);
//! * prefix sharing is invisible in token space: admission may map a
//!   prompt's cached prefix pages ([`StepForward::map_prefix`]) so
//!   prefill only computes the suffix, but per-request output stays
//!   bit-identical with the cache on or off (`tests/continuous_sim.rs`
//!   pins it; the saving shows up only in the prefill-token and
//!   page-occupancy gauges).

use crate::runtime::KvSlotPool;
use crate::serving::batcher::{covering_bucket, Batcher, BatcherConfig};
use crate::serving::metrics::{PageMetrics, SchedulerMetrics, WaveMetrics};
use crate::serving::prefix_cache::PrefixCache;
use crate::serving::request::{Request, RequestResult};
use crate::util::Rng;
use anyhow::Result;
use std::collections::HashMap;
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------------
// Slot pool
// ---------------------------------------------------------------------------

/// Per-slot generation state while a request is in flight.
#[derive(Debug)]
pub struct SlotState {
    pub request: Request,
    /// When the request entered the admission queue.
    pub enqueued: Instant,
    /// When it was admitted into this slot.
    pub admitted_at: Instant,
    /// Scheduler steps spent waiting in the queue before admission.
    pub queued_steps: u64,
    /// Sampling stream (seeded from the request, so the token stream
    /// is independent of batch composition).
    pub rng: Rng,
    /// Tokens generated so far (first token comes from prefill).
    pub generated: Vec<usize>,
    /// Last sampled token — the next decode step's input.
    pub cur: i32,
    /// Next KV write position (starts at the prefill length).
    pub pos: usize,
    /// Enqueue→first-token time, set when prefill samples.
    pub ttft: Option<Duration>,
}

/// The KV-slot pool + bucket policy. Owns which request occupies which
/// slot; knows nothing about tokens or devices (that is the session's
/// and the [`StepForward`] impl's job).
pub struct Scheduler {
    /// Compiled batch buckets, ascending, deduplicated.
    buckets: Vec<usize>,
    slots: Vec<Option<SlotState>>,
    /// Free-slot stack. Initialized so fresh slots pop in ascending
    /// order; retired slots are pushed on top and therefore reused
    /// before any never-used slot (LIFO keeps the working set warm).
    free: Vec<usize>,
    /// Slots that have ever held a request (feeds the reuse gauge).
    used: Vec<bool>,
    pub metrics: SchedulerMetrics,
}

impl Scheduler {
    /// Pool size is the largest bucket: the engine can never run a
    /// batch bigger than its largest compiled artifact.
    pub fn new(buckets: &[usize]) -> Scheduler {
        assert!(!buckets.is_empty(), "need at least one batch bucket");
        let mut buckets = buckets.to_vec();
        buckets.sort_unstable();
        buckets.dedup();
        assert!(buckets[0] >= 1, "bucket 0 is not a batch");
        let pool = *buckets.last().unwrap();
        Scheduler {
            buckets,
            slots: (0..pool).map(|_| None).collect(),
            free: (0..pool).rev().collect(),
            used: vec![false; pool],
            metrics: SchedulerMetrics::default(),
        }
    }

    pub fn pool_size(&self) -> usize {
        self.slots.len()
    }

    pub fn free_count(&self) -> usize {
        self.free.len()
    }

    pub fn live(&self) -> usize {
        self.slots.len() - self.free.len()
    }

    pub fn is_idle(&self) -> bool {
        self.free.len() == self.slots.len()
    }

    pub fn buckets(&self) -> &[usize] {
        &self.buckets
    }

    /// Smallest configured bucket covering `n` live slots. `n` never
    /// exceeds the pool (== the largest bucket) by construction.
    pub fn min_bucket(&self, n: usize) -> usize {
        debug_assert!(n >= 1 && n <= self.pool_size());
        covering_bucket(&self.buckets, n)
    }

    /// Assign a request to a free slot. Panics if the pool is full —
    /// callers must check [`Scheduler::free_count`] first.
    pub fn assign(
        &mut self,
        request: Request,
        enqueued: Instant,
        queued_steps: u64,
        now: Instant,
    ) -> usize {
        let sid = self.free.pop().expect("scheduler: no free slot");
        assert!(self.slots[sid].is_none(), "scheduler: slot {sid} double-assigned");
        if self.used[sid] {
            self.metrics.slot_reuses += 1;
        }
        self.used[sid] = true;
        self.metrics.admitted += 1;
        self.metrics
            .queue_wait_ms
            .push(now.saturating_duration_since(enqueued).as_secs_f32() * 1e3);
        let rng = Rng::new(request.params.seed);
        self.slots[sid] = Some(SlotState {
            request,
            enqueued,
            admitted_at: now,
            queued_steps,
            rng,
            generated: Vec::new(),
            cur: 0,
            pos: 0,
            ttft: None,
        });
        self.metrics.peak_live = self.metrics.peak_live.max(self.live());
        sid
    }

    /// Retire a slot, returning its state and freeing the slot for the
    /// next admission (ahead of never-used slots).
    pub fn retire(&mut self, sid: usize) -> SlotState {
        let st = self.slots[sid].take().expect("scheduler: retiring an empty slot");
        self.free.push(sid);
        self.metrics.retired += 1;
        st
    }

    pub fn slot(&self, sid: usize) -> &SlotState {
        self.slots[sid].as_ref().expect("scheduler: empty slot")
    }

    pub fn slot_mut(&mut self, sid: usize) -> &mut SlotState {
        self.slots[sid].as_mut().expect("scheduler: empty slot")
    }

    /// Live slot ids, ascending — the step's row order. Ascending order
    /// is deterministic and stable under retirement, which keeps traces
    /// replayable; it does not affect values (batch rows are
    /// independent through the model).
    pub fn live_rows(&self, out: &mut Vec<usize>) {
        out.clear();
        out.extend(
            self.slots.iter().enumerate().filter(|(_, s)| s.is_some()).map(|(i, _)| i),
        );
    }

    /// Record one executed decode step at `bucket` with `live` rows.
    pub fn record_step(&mut self, bucket: usize, live: usize) {
        self.metrics.decode_steps += 1;
        self.metrics.live_row_steps += live as u64;
        self.metrics.bucket_row_steps += bucket as u64;
    }
}

// ---------------------------------------------------------------------------
// The forward abstraction
// ---------------------------------------------------------------------------

/// Result of prefilling one request into a slot.
pub struct PrefillOutcome {
    /// Last-position logits row (the first sample's distribution).
    pub logits: Vec<f32>,
    /// KV length after prefill — the first decode step's position.
    pub pos: usize,
}

/// What the scheduler needs from a model: prefill into a slot, one
/// batched decode step over named slots, and slot KV release. The
/// artifact engine implements this against PJRT buffers + the paged
/// per-slot [`KvSlotPool`]; [`StubForward`] implements it as a
/// deterministic host function for artifact-free testing.
pub trait StepForward {
    /// Map the longest cached prefix of `prompt` into `slot`'s KV
    /// ahead of prefill (prefix-cache backends — the session calls
    /// this at admission). `None` means this backend consulted no
    /// cache (the session then skips hit-rate accounting, so a
    /// cache-less run never reports a meaningless 0% hit rate);
    /// `Some(n)` maps `n` leading prompt tokens, always less than
    /// `prompt.len()`, so prefill still computes the last prompt
    /// position and produces the first token's logits. The default
    /// never consults a cache.
    fn map_prefix(&mut self, _slot: usize, _prompt: &[usize]) -> Option<usize> {
        None
    }

    /// Batched prefill of newly admitted requests; `prompts[i]` goes
    /// to KV slot `slots[i]`, whose leading `cached[i]` tokens are
    /// already resident (from [`StepForward::map_prefix`]) —
    /// implementations prefill only the suffix `prompts[i][cached[i]..]`.
    /// Returns one outcome per slot, same order. Implementations must
    /// keep each row's result independent of the other rows (the
    /// token-identity guarantee rests on it).
    fn prefill(
        &mut self,
        slots: &[usize],
        prompts: &[&[usize]],
        cached: &[usize],
    ) -> Result<Vec<PrefillOutcome>>;

    /// One decode step: `slots` are the live rows (ascending),
    /// `tokens[i]`/`pos[i]` their input token and KV position, padded
    /// on device to `bucket` rows. Returns one logits row per live
    /// slot, same order.
    fn decode(
        &mut self,
        slots: &[usize],
        tokens: &[i32],
        pos: &[usize],
        bucket: usize,
    ) -> Result<Vec<Vec<f32>>>;

    /// The slot retired — its KV may be recycled.
    fn release(&mut self, slot: usize);

    /// Per-slot KV capacity; a request whose position reaches this is
    /// force-retired (same truncation rule as the wave engine's
    /// `pos < kv_len` loop bound).
    fn kv_capacity(&self) -> usize;

    /// Paged-KV gauges, when this backend owns a page pool. Default:
    /// no pages to report.
    fn page_metrics(&self) -> Option<PageMetrics> {
        None
    }
}

// ---------------------------------------------------------------------------
// The continuous session: admission → prefill → decode → retire
// ---------------------------------------------------------------------------

/// One continuous-batching run: an admission queue ([`Batcher`]), the
/// slot pool, and a [`StepForward`] backend. [`ContinuousSession::step`]
/// executes one scheduler step and returns the requests retired by it;
/// callers ingest new requests between steps ([`ContinuousSession::enqueue`]),
/// which is exactly how the threaded server achieves mid-flight
/// admission.
pub struct ContinuousSession<F: StepForward> {
    batcher: Batcher,
    sched: Scheduler,
    fwd: F,
    /// Steps executed so far (admission bookkeeping is step-indexed so
    /// queue waits are measurable in deterministic simulation tests).
    step_idx: u64,
    /// Request id → step index at enqueue.
    arrivals: HashMap<u64, u64>,
    // reused step buffers — the steady-state scheduling loop performs
    // no per-step allocations outside the forward itself
    admit_buf: Vec<(Request, Instant)>,
    slot_buf: Vec<usize>,
    cached_buf: Vec<usize>,
    rows_buf: Vec<usize>,
    toks_buf: Vec<i32>,
    pos_buf: Vec<usize>,
    /// Page-counter snapshot at the last [`ContinuousSession::take_page_metrics`]
    /// flush, so repeated flushes of one long-lived session (the
    /// threaded server flushes at every idle) report deltas instead of
    /// re-adding lifetime totals.
    pages_flushed: PageMetrics,
    /// Requests retired during the in-progress step. Normally drained
    /// by [`ContinuousSession::step`]'s Ok return; if the step's
    /// forward fails *after* some requests already retired (admission
    /// phase succeeded, decode failed), their completed results stay
    /// here — [`ContinuousSession::take_finished`] delivers them so an
    /// engine error never swallows a finished generation.
    finished_buf: Vec<RequestResult>,
    // run aggregates, flushed as one WaveMetrics per busy period
    prefill_time: Duration,
    decode_time: Duration,
    run_decode_steps: usize,
    run_prompt_tokens: usize,
    run_generated: usize,
}

impl<F: StepForward> ContinuousSession<F> {
    pub fn new(cfg: BatcherConfig, fwd: F) -> ContinuousSession<F> {
        let sched = Scheduler::new(&cfg.buckets);
        ContinuousSession {
            batcher: Batcher::new(cfg),
            sched,
            fwd,
            step_idx: 0,
            arrivals: HashMap::new(),
            admit_buf: Vec::new(),
            slot_buf: Vec::new(),
            cached_buf: Vec::new(),
            rows_buf: Vec::new(),
            toks_buf: Vec::new(),
            pos_buf: Vec::new(),
            pages_flushed: PageMetrics::default(),
            finished_buf: Vec::new(),
            prefill_time: Duration::ZERO,
            decode_time: Duration::ZERO,
            run_decode_steps: 0,
            run_prompt_tokens: 0,
            run_generated: 0,
        }
    }

    pub fn enqueue(&mut self, r: Request) {
        self.arrivals.insert(r.id, self.step_idx);
        self.batcher.push(r);
    }

    /// Queue depth (not yet admitted).
    pub fn pending(&self) -> usize {
        self.batcher.len()
    }

    pub fn live(&self) -> usize {
        self.sched.live()
    }

    /// No queued work and no live slots.
    pub fn is_idle(&self) -> bool {
        self.batcher.is_empty() && self.sched.is_idle()
    }

    pub fn step_index(&self) -> u64 {
        self.step_idx
    }

    pub fn metrics(&self) -> &SchedulerMetrics {
        &self.sched.metrics
    }

    pub fn forward(&self) -> &F {
        &self.fwd
    }

    pub fn forward_mut(&mut self) -> &mut F {
        &mut self.fwd
    }

    /// Take the accumulated scheduler gauges (resets them).
    pub fn take_metrics(&mut self) -> SchedulerMetrics {
        std::mem::take(&mut self.sched.metrics)
    }

    /// Paged-KV gauges since the previous call (event counters as
    /// deltas; point/monotone gauges current) — so a long-lived
    /// session flushed repeatedly into [`crate::serving::EngineMetrics`]
    /// never double-counts. `None` when the backend has no page pool.
    pub fn take_page_metrics(&mut self) -> Option<PageMetrics> {
        let cur = self.fwd.page_metrics()?;
        let delta = PageMetrics {
            page_len: cur.page_len,
            pages_in_use: cur.pages_in_use,
            cached_pages: cur.cached_pages,
            high_water_pages: cur.high_water_pages,
            cow_copies: cur.cow_copies.saturating_sub(self.pages_flushed.cow_copies),
            shared_maps: cur.shared_maps.saturating_sub(self.pages_flushed.shared_maps),
            evicted_pages: cur.evicted_pages.saturating_sub(self.pages_flushed.evicted_pages),
        };
        self.pages_flushed = cur;
        Some(delta)
    }

    /// Summarize the run so far as one [`WaveMetrics`] (resets the
    /// aggregates). `None` if nothing was generated.
    pub fn take_run_summary(&mut self) -> Option<WaveMetrics> {
        if self.run_generated == 0 {
            return None;
        }
        let w = WaveMetrics {
            batch: self.sched.pool_size(),
            prompt_tokens: self.run_prompt_tokens,
            generated_tokens: self.run_generated,
            prefill: self.prefill_time,
            decode: self.decode_time,
            decode_steps: self.run_decode_steps,
        };
        self.prefill_time = Duration::ZERO;
        self.decode_time = Duration::ZERO;
        self.run_decode_steps = 0;
        self.run_prompt_tokens = 0;
        self.run_generated = 0;
        Some(w)
    }

    /// Results completed by a step that later returned `Err` (the
    /// forward failed after some requests had already retired). Empty
    /// after any successful [`ContinuousSession::step`]. Callers on
    /// the error path must deliver these before failing the rest.
    pub fn take_finished(&mut self) -> Vec<RequestResult> {
        std::mem::take(&mut self.finished_buf)
    }

    /// Abandon everything in flight and queued (engine error path).
    /// Returns the affected request ids. Completed-but-undelivered
    /// results are NOT aborted — drain them first via
    /// [`ContinuousSession::take_finished`].
    pub fn abort_all(&mut self) -> Vec<u64> {
        let mut ids = Vec::new();
        self.rows_buf.clear();
        self.sched.live_rows(&mut self.rows_buf);
        let rows = std::mem::take(&mut self.rows_buf);
        for sid in rows {
            let st = self.sched.retire(sid);
            self.fwd.release(sid);
            ids.push(st.request.id);
        }
        while let Some((r, _)) = self.batcher.pop_front() {
            ids.push(r.id);
        }
        self.arrivals.clear();
        ids
    }

    /// Run until idle (standalone-queue convenience; the threaded
    /// server calls [`ContinuousSession::step`] directly so it can
    /// ingest arrivals between steps). Results are sorted by id.
    pub fn drain(&mut self) -> Result<Vec<RequestResult>> {
        let mut out = Vec::new();
        while !self.is_idle() {
            out.extend(self.step()?);
        }
        out.sort_by_key(|r| r.id);
        Ok(out)
    }

    /// One scheduler step: admit into free slots, prefill admissions
    /// (their first token samples now — TTFT is enqueue→here), then
    /// one decode step over all live slots at the minimal covering
    /// bucket. Returns the requests retired during the step.
    pub fn step(&mut self) -> Result<Vec<RequestResult>> {
        let now = Instant::now();
        let entry_step = self.step_idx;
        self.step_idx += 1;
        let kv_cap = self.fwd.kv_capacity();

        // --- admission: FIFO into free slots; the batcher's hold
        // window applies only while the engine is idle (an idle engine
        // may wait for a fuller first batch; a busy one admits
        // immediately — free slots are pure upside) ---
        let free = self.sched.free_count();
        if free > 0 && !self.batcher.is_empty() {
            self.batcher.admit_into(free, self.sched.is_idle(), &mut self.admit_buf);
            if !self.admit_buf.is_empty() {
                self.slot_buf.clear();
                for (r, enq) in self.admit_buf.drain(..) {
                    let arrival = self.arrivals.remove(&r.id).unwrap_or(entry_step);
                    let waited = entry_step.saturating_sub(arrival);
                    self.run_prompt_tokens += r.prompt.len();
                    self.slot_buf.push(self.sched.assign(r, enq, waited, now));
                }
                // prefix-cache admission: ask the backend to map each
                // prompt's longest cached prefix before prefill, and
                // meter the prefill tokens it saves
                self.cached_buf.clear();
                for &sid in &self.slot_buf {
                    let mapped = {
                        let prompt = self.sched.slot(sid).request.prompt.as_slice();
                        self.fwd.map_prefix(sid, prompt)
                    };
                    let plen = self.sched.slot(sid).request.prompt.len();
                    let cached = mapped.unwrap_or(0);
                    debug_assert!(cached < plen.max(1), "mapped prefix must leave a suffix");
                    if mapped.is_some() {
                        self.sched.metrics.prefix_lookups += 1;
                        if cached > 0 {
                            self.sched.metrics.prefix_hits += 1;
                            self.sched.metrics.prefill_tokens_saved += cached as u64;
                        }
                    }
                    self.sched.metrics.prefill_tokens += (plen - cached) as u64;
                    self.cached_buf.push(cached);
                }
                let t0 = Instant::now();
                let prompts: Vec<&[usize]> = self
                    .slot_buf
                    .iter()
                    .map(|&sid| self.sched.slot(sid).request.prompt.as_slice())
                    .collect();
                let outcomes = self.fwd.prefill(&self.slot_buf, &prompts, &self.cached_buf)?;
                drop(prompts);
                self.prefill_time += t0.elapsed();
                // stamp after the forward: TTFT includes prefill compute
                let t_first = Instant::now();
                assert_eq!(outcomes.len(), self.slot_buf.len(), "prefill outcome count");
                for (i, out) in outcomes.into_iter().enumerate() {
                    let sid = self.slot_buf[i];
                    let done = {
                        let st = self.sched.slot_mut(sid);
                        st.pos = out.pos;
                        let tok =
                            st.rng.sample_logits(&out.logits, st.request.params.temperature);
                        st.generated.push(tok);
                        st.cur = tok as i32;
                        st.ttft = Some(t_first.saturating_duration_since(st.enqueued));
                        self.run_generated += 1;
                        st.request.params.stop_token == Some(tok)
                            || st.generated.len() >= st.request.params.max_new_tokens
                            || st.pos >= kv_cap
                    };
                    if done {
                        let st = self.sched.retire(sid);
                        self.fwd.release(sid);
                        let r = finish(st, t_first);
                        self.finished_buf.push(r);
                    }
                }
            }
        }

        // --- one decode step over the live slots ---
        self.sched.live_rows(&mut self.rows_buf);
        if self.rows_buf.is_empty() {
            return Ok(std::mem::take(&mut self.finished_buf));
        }
        let live = self.rows_buf.len();
        let bucket = self.sched.min_bucket(live);
        self.toks_buf.clear();
        self.pos_buf.clear();
        for &sid in &self.rows_buf {
            let st = self.sched.slot(sid);
            debug_assert!(st.pos < kv_cap, "live slot at KV capacity");
            self.toks_buf.push(st.cur);
            self.pos_buf.push(st.pos);
        }
        let t0 = Instant::now();
        let logits = self.fwd.decode(&self.rows_buf, &self.toks_buf, &self.pos_buf, bucket)?;
        self.decode_time += t0.elapsed();
        self.run_decode_steps += 1;
        // stamp after the forward: latency includes the final decode
        let t_done = Instant::now();
        assert_eq!(logits.len(), live, "decode logits row count");
        for (i, row) in logits.iter().enumerate() {
            let sid = self.rows_buf[i];
            let done = {
                let st = self.sched.slot_mut(sid);
                let tok = st.rng.sample_logits(row, st.request.params.temperature);
                st.generated.push(tok);
                st.cur = tok as i32;
                st.pos += 1;
                self.run_generated += 1;
                st.request.params.stop_token == Some(tok)
                    || st.generated.len() >= st.request.params.max_new_tokens
                    || st.pos >= kv_cap
            };
            if done {
                let st = self.sched.retire(sid);
                self.fwd.release(sid);
                let r = finish(st, t_done);
                self.finished_buf.push(r);
            }
        }
        self.sched.record_step(bucket, live);
        Ok(std::mem::take(&mut self.finished_buf))
    }
}

/// Package a retired slot as a request result. Continuous-batching
/// TTFT is user-perceived (enqueue→first token); `queued` is the
/// enqueue→admission wait the scheduler controlled.
fn finish(st: SlotState, now: Instant) -> RequestResult {
    RequestResult {
        id: st.request.id,
        tokens: st.generated,
        ttft: st.ttft.unwrap_or_default(),
        latency: now.saturating_duration_since(st.enqueued),
        queued: st.admitted_at.saturating_duration_since(st.enqueued),
        queued_steps: st.queued_steps,
    }
}

// ---------------------------------------------------------------------------
// Deterministic stub model (tests, simulations, benches)
// ---------------------------------------------------------------------------

/// Deterministic logits for a context: hash the tokens, expand through
/// the repo Rng. A row depends only on its own context, never on batch
/// composition — the property that makes scheduler-order bugs visible
/// as token divergence.
pub fn stub_logits(ctx: &[usize], vocab: usize) -> Vec<f32> {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325; // FNV-1a offset basis
    for &t in ctx {
        h ^= t as u64;
        h = h.wrapping_mul(0x100_0000_01b3); // FNV-1a prime
    }
    let mut rng = Rng::new(h ^ vocab as u64);
    (0..vocab).map(|_| rng.f32()).collect()
}

/// Host-only [`StepForward`] over a real paged [`KvSlotPool`]: each
/// slot's "KV cache" is its token context, stored one token per KV
/// column (layers = heads = head_dim = 1, so a token column is its
/// `[k, v]` pair and the k-plane value *is* the token id). Decode
/// reconstructs the context **from the pages** before computing
/// logits, so any page-table bug — aliasing, stale data after
/// recycling, a broken copy-on-write — shows up as token divergence in
/// the scheduler suites, not just as a bad gauge. Used by the
/// scheduler/simulation tests and the artifact-free serving benches;
/// also a template for plugging non-PJRT backends into the session.
///
/// With [`StubForward::with_prefix_cache`] the stub additionally runs
/// a [`PrefixCache`] in front of prefill: admission maps a prompt's
/// cached prefix pages and prefill writes only the suffix — the
/// host-only proof of the sharing path's token identity and
/// prefill-compute savings.
pub struct StubForward {
    vocab: usize,
    kv_cap: usize,
    kv: KvSlotPool,
    cache: Option<PrefixCache>,
    /// Release calls observed (tests assert slot hygiene).
    pub released: u64,
    /// Prompt tokens written by prefill (suffix only under prefix
    /// hits) — the stub's own compute meter, cross-checked against
    /// `SchedulerMetrics::prefill_tokens`.
    pub prefilled_tokens: u64,
}

/// Tokens per page of the stub's KV pool (small, so short test
/// prompts still span several pages).
pub const STUB_PAGE_LEN: usize = 4;

impl StubForward {
    pub fn new(pool: usize, vocab: usize, kv_cap: usize) -> StubForward {
        StubForward::build(pool, vocab, kv_cap, STUB_PAGE_LEN, false)
    }

    /// Stub with the prompt-prefix cache enabled at `page_len`.
    pub fn with_prefix_cache(
        pool: usize,
        vocab: usize,
        kv_cap: usize,
        page_len: usize,
    ) -> StubForward {
        StubForward::build(pool, vocab, kv_cap, page_len, true)
    }

    fn build(
        pool: usize,
        vocab: usize,
        kv_cap: usize,
        page_len: usize,
        prefix: bool,
    ) -> StubForward {
        StubForward {
            vocab,
            kv_cap,
            // unbounded page budget: the host stub's pressure/eviction
            // behavior is pinned by the dedicated pool/cache suites
            kv: KvSlotPool::new(pool, 1, 1, kv_cap, 1, page_len, None),
            cache: prefix.then(|| PrefixCache::new(page_len)),
            released: 0,
            prefilled_tokens: 0,
        }
    }

    /// Live contexts currently held (slot hygiene checks).
    pub fn live_contexts(&self) -> usize {
        (0..self.kv.pool_size()).filter(|&s| self.kv.extent(s) > 0).count()
    }

    /// The paged KV pool (page-level assertions in tests).
    pub fn kv(&self) -> &KvSlotPool {
        &self.kv
    }

    /// Reconstruct a slot's token context `[0, n)` from its KV pages.
    fn read_ctx(&self, slot: usize, n: usize) -> Vec<usize> {
        let mut col = [0.0f32; 2];
        let mut ctx = Vec::with_capacity(n);
        for t in 0..n {
            self.kv.read_token(slot, t, &mut col);
            ctx.push(col[0] as usize);
        }
        ctx
    }
}

impl StepForward for StubForward {
    fn map_prefix(&mut self, slot: usize, prompt: &[usize]) -> Option<usize> {
        let cache = self.cache.as_mut()?;
        let (pages, tokens) = cache.lookup(prompt);
        // the last prompt position must still prefill (its logits seed
        // the first sample), so a fully-covered prompt maps everything
        // but re-runs one token — COW keeps the cached page intact
        let cached = tokens.min(prompt.len().saturating_sub(1));
        if pages.is_empty() || cached == 0 {
            return Some(0);
        }
        self.kv.map_shared(slot, &pages, tokens);
        Some(cached)
    }

    fn prefill(
        &mut self,
        slots: &[usize],
        prompts: &[&[usize]],
        cached: &[usize],
    ) -> Result<Vec<PrefillOutcome>> {
        let mut out = Vec::with_capacity(slots.len());
        for ((&sid, &p), &c) in slots.iter().zip(prompts).zip(cached) {
            anyhow::ensure!(
                if c == 0 { self.kv.extent(sid) == 0 } else { self.kv.extent(sid) <= p.len() },
                "stub: prefill into a live slot {sid}"
            );
            for (t, &tok) in p.iter().enumerate().skip(c) {
                self.kv.write_token(sid, t, &[tok as f32, 0.0]);
            }
            self.prefilled_tokens += (p.len() - c) as u64;
            // logits come from the page-reconstructed context: a wrong
            // prefix mapping diverges the token stream right here
            let ctx = self.read_ctx(sid, p.len());
            out.push(PrefillOutcome { logits: stub_logits(&ctx, self.vocab), pos: p.len() });
            if self.cache.is_some() {
                let full = p.len() / self.kv.page_len();
                let pages: Vec<usize> = self.kv.slot_pages(sid)[..full].to_vec();
                let key = &p[..full * self.kv.page_len()];
                if let Some(cache) = &mut self.cache {
                    cache.insert(key, &pages, self.kv.pages_mut());
                }
            }
        }
        Ok(out)
    }

    fn decode(
        &mut self,
        slots: &[usize],
        tokens: &[i32],
        pos: &[usize],
        bucket: usize,
    ) -> Result<Vec<Vec<f32>>> {
        anyhow::ensure!(slots.len() <= bucket, "stub: {} rows > bucket {bucket}", slots.len());
        let mut out = Vec::with_capacity(slots.len());
        for ((&sid, &tok), &p) in slots.iter().zip(tokens).zip(pos) {
            anyhow::ensure!(self.kv.extent(sid) == p, "stub: decode on a stale slot {sid}");
            self.kv.write_token(sid, p, &[tok as f32, 0.0]);
            let ctx = self.read_ctx(sid, p + 1);
            out.push(stub_logits(&ctx, self.vocab));
        }
        Ok(out)
    }

    fn release(&mut self, slot: usize) {
        self.kv.release(slot);
        self.released += 1;
    }

    fn kv_capacity(&self) -> usize {
        self.kv_cap
    }

    fn page_metrics(&self) -> Option<PageMetrics> {
        Some(PageMetrics {
            page_len: self.kv.page_len(),
            pages_in_use: self.kv.pages().pages_in_use(),
            high_water_pages: self.kv.pages().high_water_pages,
            cow_copies: self.kv.pages().cow_copies,
            shared_maps: self.kv.shared_maps,
            cached_pages: self.cache.as_ref().map_or(0, |c| c.cached_pages()),
            evicted_pages: self.cache.as_ref().map_or(0, |c| c.evicted_pages),
        })
    }
}

/// Run-to-completion reference for one request against the stub model:
/// the same sampling rule as the engines, no scheduler involved. Since
/// batch rows are independent, this is exactly what any correct
/// scheduler must emit for the request.
pub fn stub_reference(r: &Request, vocab: usize, kv_cap: usize) -> Vec<usize> {
    let mut rng = Rng::new(r.params.seed);
    let mut ctx = r.prompt.clone();
    let mut pos = ctx.len();
    let mut gen = Vec::new();
    let tok = rng.sample_logits(&stub_logits(&ctx, vocab), r.params.temperature);
    gen.push(tok);
    let mut cur = tok;
    let mut done = r.params.stop_token == Some(tok)
        || gen.len() >= r.params.max_new_tokens
        || pos >= kv_cap;
    while !done {
        ctx.push(cur);
        let tok = rng.sample_logits(&stub_logits(&ctx, vocab), r.params.temperature);
        gen.push(tok);
        cur = tok;
        pos += 1;
        done = r.params.stop_token == Some(tok)
            || gen.len() >= r.params.max_new_tokens
            || pos >= kv_cap;
    }
    gen
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::request::GenParams;

    fn req(id: u64, max_new: usize) -> Request {
        Request::new(
            id,
            vec![1, 2, 3],
            GenParams { max_new_tokens: max_new, temperature: 0.0, seed: id, stop_token: None },
        )
    }

    #[test]
    fn pool_and_bucket_shape() {
        let s = Scheduler::new(&[8, 1, 32, 8]);
        assert_eq!(s.pool_size(), 32);
        assert_eq!(s.buckets(), &[1, 8, 32]);
        assert_eq!(s.min_bucket(1), 1);
        assert_eq!(s.min_bucket(2), 8);
        assert_eq!(s.min_bucket(8), 8);
        assert_eq!(s.min_bucket(9), 32);
        assert_eq!(s.min_bucket(32), 32);
    }

    #[test]
    fn retired_slots_recycle_first() {
        let mut s = Scheduler::new(&[4]);
        let now = Instant::now();
        let a = s.assign(req(0, 4), now, 0, now);
        let b = s.assign(req(1, 4), now, 0, now);
        assert_eq!((a, b), (0, 1));
        s.retire(a);
        // the just-retired slot 0 is taken before fresh slot 2
        let c = s.assign(req(2, 4), now, 0, now);
        assert_eq!(c, 0);
        assert_eq!(s.metrics.slot_reuses, 1);
        assert_eq!(s.live(), 2);
        assert_eq!(s.free_count() + s.live(), s.pool_size());
    }

    #[test]
    fn session_runs_queue_to_completion() {
        let cfg = BatcherConfig { buckets: vec![1, 4], max_wait: Duration::ZERO };
        let mut sess = ContinuousSession::new(cfg, StubForward::new(4, 11, usize::MAX));
        for i in 0..6 {
            sess.enqueue(req(i, 3 + i as usize % 3));
        }
        let results = sess.drain().unwrap();
        assert_eq!(results.len(), 6);
        for r in &results {
            assert_eq!(r.tokens, stub_reference(&req(r.id, 3 + r.id as usize % 3), 11, usize::MAX));
        }
        assert!(sess.is_idle());
        assert_eq!(sess.forward().live_contexts(), 0, "every slot released");
        let m = sess.take_metrics();
        assert_eq!(m.admitted, 6);
        assert_eq!(m.retired, 6);
        assert!(m.slot_reuses >= 2, "6 requests through a 4-slot pool must recycle");
        let w = sess.take_run_summary().unwrap();
        assert_eq!(w.generated_tokens, results.iter().map(|r| r.tokens.len()).sum::<usize>());
    }

    #[test]
    fn kv_capacity_truncates() {
        let cfg = BatcherConfig { buckets: vec![1], max_wait: Duration::ZERO };
        // prompt len 3, cap 5 → prefill at pos 3, two decode steps
        let mut sess = ContinuousSession::new(cfg, StubForward::new(1, 7, 5));
        sess.enqueue(req(0, 100));
        let results = sess.drain().unwrap();
        assert_eq!(results[0].tokens.len(), 3, "1 prefill + (cap-prompt) decode tokens");
        assert_eq!(results[0].tokens, stub_reference(&req(0, 100), 7, 5));
    }

    #[test]
    fn abort_clears_everything() {
        let cfg = BatcherConfig { buckets: vec![2], max_wait: Duration::ZERO };
        let mut sess = ContinuousSession::new(cfg, StubForward::new(2, 7, usize::MAX));
        for i in 0..5 {
            sess.enqueue(req(i, 50));
        }
        sess.step().unwrap(); // two live, three queued
        assert_eq!(sess.live(), 2);
        let mut ids = sess.abort_all();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
        assert!(sess.is_idle());
        assert_eq!(sess.forward().live_contexts(), 0);
    }

    #[test]
    fn page_metric_flushes_are_deltas_not_lifetime_totals() {
        // the threaded server flushes one long-lived session at every
        // idle; event counters must arrive as deltas or the engine
        // gauges double-count
        let cfg = BatcherConfig { buckets: vec![1, 2], max_wait: Duration::ZERO };
        let mut sess =
            ContinuousSession::new(cfg, StubForward::with_prefix_cache(2, 11, 64, 4));
        let mk = |id: u64| {
            Request::new(
                id,
                vec![1, 2, 3, 4, 5, 6, 7, 8, 9],
                GenParams { max_new_tokens: 2, temperature: 0.0, seed: id, stop_token: None },
            )
        };
        for i in 0..4 {
            sess.enqueue(mk(i));
        }
        sess.drain().unwrap();
        let a = sess.take_page_metrics().unwrap();
        assert_eq!(a.shared_maps, 2, "second admission pair must map the cached prefix");
        for i in 4..6 {
            sess.enqueue(mk(i));
        }
        sess.drain().unwrap();
        let b = sess.take_page_metrics().unwrap();
        assert_eq!(b.shared_maps, 2, "flush must report the delta, not lifetime totals");
        assert!(b.high_water_pages >= a.high_water_pages, "high water is monotone");
        let c = sess.take_page_metrics().unwrap();
        assert_eq!(
            (c.shared_maps, c.cow_copies, c.evicted_pages),
            (0, 0, 0),
            "an idle re-flush reports no new events"
        );
    }

    #[test]
    fn stub_logits_depend_only_on_context() {
        let a = stub_logits(&[1, 2, 3], 13);
        let b = stub_logits(&[1, 2, 3], 13);
        let c = stub_logits(&[1, 2, 4], 13);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_eq!(a.len(), 13);
    }
}
