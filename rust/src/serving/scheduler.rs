//! Continuous in-flight batching: a fixed pool of KV slots, per-step
//! admission and retirement, and a minimal-covering compiled-bucket
//! choice — the scheduler half of the serving engine.
//!
//! The run-to-completion wave path ([`crate::serving::Engine::run_queue_waves`])
//! holds a whole batch hostage until its longest member finishes:
//! retired neighbors pad every GEMM and queued requests wait for the
//! wave boundary. This module inverts that control flow. A
//! [`Scheduler`] owns `max(buckets)` KV slots; every step it
//!
//! 1. **admits** queued requests FIFO into free slots (recycling
//!    retired slots before touching fresh ones),
//! 2. **prefills** the admissions and samples their first token,
//! 3. runs **one decode step** over the live slots at the smallest
//!    compiled batch bucket covering them, and
//! 4. **retires** every request that hit its stop token,
//!    `max_new_tokens`, or the KV capacity — freeing the slot for the
//!    next step's admission.
//!
//! Scheduling is pure host logic, factored away from the artifact
//! runtime behind the [`StepForward`] trait so it is exhaustively
//! testable without compiled artifacts: [`StubForward`] is a
//! deterministic host-only model whose logits depend only on a
//! request's own context, which makes "continuous batching preserves
//! each request's exact token stream" a checkable property
//! (`tests/scheduler.rs`, `tests/continuous_sim.rs`). The artifact
//! engine drives the *same* [`ContinuousSession`] through its
//! `EngineStepForward` implementation.
//!
//! Invariants (property-tested):
//! * a slot is never double-assigned; `live + free == pool` always;
//! * admission order is FIFO in enqueue order;
//! * retired slots are reused before never-used slots;
//! * the step bucket is the smallest configured bucket ≥ live count;
//! * per-request output is token-identical to running that request
//!   alone (batch rows are independent), hence identical to the
//!   run-to-completion wave engine;
//! * a request waits at most the pool-serialized work of the requests
//!   ahead of it (no starvation; FIFO admission bounds queue wait).

use crate::serving::batcher::{covering_bucket, Batcher, BatcherConfig};
use crate::serving::metrics::{SchedulerMetrics, WaveMetrics};
use crate::serving::request::{Request, RequestResult};
use crate::util::Rng;
use anyhow::Result;
use std::collections::HashMap;
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------------
// Slot pool
// ---------------------------------------------------------------------------

/// Per-slot generation state while a request is in flight.
#[derive(Debug)]
pub struct SlotState {
    pub request: Request,
    /// When the request entered the admission queue.
    pub enqueued: Instant,
    /// When it was admitted into this slot.
    pub admitted_at: Instant,
    /// Scheduler steps spent waiting in the queue before admission.
    pub queued_steps: u64,
    /// Sampling stream (seeded from the request, so the token stream
    /// is independent of batch composition).
    pub rng: Rng,
    /// Tokens generated so far (first token comes from prefill).
    pub generated: Vec<usize>,
    /// Last sampled token — the next decode step's input.
    pub cur: i32,
    /// Next KV write position (starts at the prefill length).
    pub pos: usize,
    /// Enqueue→first-token time, set when prefill samples.
    pub ttft: Option<Duration>,
}

/// The KV-slot pool + bucket policy. Owns which request occupies which
/// slot; knows nothing about tokens or devices (that is the session's
/// and the [`StepForward`] impl's job).
pub struct Scheduler {
    /// Compiled batch buckets, ascending, deduplicated.
    buckets: Vec<usize>,
    slots: Vec<Option<SlotState>>,
    /// Free-slot stack. Initialized so fresh slots pop in ascending
    /// order; retired slots are pushed on top and therefore reused
    /// before any never-used slot (LIFO keeps the working set warm).
    free: Vec<usize>,
    /// Slots that have ever held a request (feeds the reuse gauge).
    used: Vec<bool>,
    pub metrics: SchedulerMetrics,
}

impl Scheduler {
    /// Pool size is the largest bucket: the engine can never run a
    /// batch bigger than its largest compiled artifact.
    pub fn new(buckets: &[usize]) -> Scheduler {
        assert!(!buckets.is_empty(), "need at least one batch bucket");
        let mut buckets = buckets.to_vec();
        buckets.sort_unstable();
        buckets.dedup();
        assert!(buckets[0] >= 1, "bucket 0 is not a batch");
        let pool = *buckets.last().unwrap();
        Scheduler {
            buckets,
            slots: (0..pool).map(|_| None).collect(),
            free: (0..pool).rev().collect(),
            used: vec![false; pool],
            metrics: SchedulerMetrics::default(),
        }
    }

    pub fn pool_size(&self) -> usize {
        self.slots.len()
    }

    pub fn free_count(&self) -> usize {
        self.free.len()
    }

    pub fn live(&self) -> usize {
        self.slots.len() - self.free.len()
    }

    pub fn is_idle(&self) -> bool {
        self.free.len() == self.slots.len()
    }

    pub fn buckets(&self) -> &[usize] {
        &self.buckets
    }

    /// Smallest configured bucket covering `n` live slots. `n` never
    /// exceeds the pool (== the largest bucket) by construction.
    pub fn min_bucket(&self, n: usize) -> usize {
        debug_assert!(n >= 1 && n <= self.pool_size());
        covering_bucket(&self.buckets, n)
    }

    /// Assign a request to a free slot. Panics if the pool is full —
    /// callers must check [`Scheduler::free_count`] first.
    pub fn assign(
        &mut self,
        request: Request,
        enqueued: Instant,
        queued_steps: u64,
        now: Instant,
    ) -> usize {
        let sid = self.free.pop().expect("scheduler: no free slot");
        assert!(self.slots[sid].is_none(), "scheduler: slot {sid} double-assigned");
        if self.used[sid] {
            self.metrics.slot_reuses += 1;
        }
        self.used[sid] = true;
        self.metrics.admitted += 1;
        self.metrics
            .queue_wait_ms
            .push(now.saturating_duration_since(enqueued).as_secs_f32() * 1e3);
        let rng = Rng::new(request.params.seed);
        self.slots[sid] = Some(SlotState {
            request,
            enqueued,
            admitted_at: now,
            queued_steps,
            rng,
            generated: Vec::new(),
            cur: 0,
            pos: 0,
            ttft: None,
        });
        self.metrics.peak_live = self.metrics.peak_live.max(self.live());
        sid
    }

    /// Retire a slot, returning its state and freeing the slot for the
    /// next admission (ahead of never-used slots).
    pub fn retire(&mut self, sid: usize) -> SlotState {
        let st = self.slots[sid].take().expect("scheduler: retiring an empty slot");
        self.free.push(sid);
        self.metrics.retired += 1;
        st
    }

    pub fn slot(&self, sid: usize) -> &SlotState {
        self.slots[sid].as_ref().expect("scheduler: empty slot")
    }

    pub fn slot_mut(&mut self, sid: usize) -> &mut SlotState {
        self.slots[sid].as_mut().expect("scheduler: empty slot")
    }

    /// Live slot ids, ascending — the step's row order. Ascending order
    /// is deterministic and stable under retirement, which keeps traces
    /// replayable; it does not affect values (batch rows are
    /// independent through the model).
    pub fn live_rows(&self, out: &mut Vec<usize>) {
        out.clear();
        out.extend(
            self.slots.iter().enumerate().filter(|(_, s)| s.is_some()).map(|(i, _)| i),
        );
    }

    /// Record one executed decode step at `bucket` with `live` rows.
    pub fn record_step(&mut self, bucket: usize, live: usize) {
        self.metrics.decode_steps += 1;
        self.metrics.live_row_steps += live as u64;
        self.metrics.bucket_row_steps += bucket as u64;
    }
}

// ---------------------------------------------------------------------------
// The forward abstraction
// ---------------------------------------------------------------------------

/// Result of prefilling one request into a slot.
pub struct PrefillOutcome {
    /// Last-position logits row (the first sample's distribution).
    pub logits: Vec<f32>,
    /// KV length after prefill — the first decode step's position.
    pub pos: usize,
}

/// What the scheduler needs from a model: prefill into a slot, one
/// batched decode step over named slots, and slot KV release. The
/// artifact engine implements this against PJRT buffers + the
/// per-slot `runtime::KvSlotPool`; [`StubForward`] implements it as a
/// deterministic host function for artifact-free testing.
pub trait StepForward {
    /// Batched prefill of newly admitted requests; `prompts[i]` goes
    /// to KV slot `slots[i]`. Returns one outcome per slot, same
    /// order. Implementations must keep each row's result independent
    /// of the other rows (the token-identity guarantee rests on it).
    fn prefill(&mut self, slots: &[usize], prompts: &[&[usize]]) -> Result<Vec<PrefillOutcome>>;

    /// One decode step: `slots` are the live rows (ascending),
    /// `tokens[i]`/`pos[i]` their input token and KV position, padded
    /// on device to `bucket` rows. Returns one logits row per live
    /// slot, same order.
    fn decode(
        &mut self,
        slots: &[usize],
        tokens: &[i32],
        pos: &[usize],
        bucket: usize,
    ) -> Result<Vec<Vec<f32>>>;

    /// The slot retired — its KV may be recycled.
    fn release(&mut self, slot: usize);

    /// Per-slot KV capacity; a request whose position reaches this is
    /// force-retired (same truncation rule as the wave engine's
    /// `pos < kv_len` loop bound).
    fn kv_capacity(&self) -> usize;
}

// ---------------------------------------------------------------------------
// The continuous session: admission → prefill → decode → retire
// ---------------------------------------------------------------------------

/// One continuous-batching run: an admission queue ([`Batcher`]), the
/// slot pool, and a [`StepForward`] backend. [`ContinuousSession::step`]
/// executes one scheduler step and returns the requests retired by it;
/// callers ingest new requests between steps ([`ContinuousSession::enqueue`]),
/// which is exactly how the threaded server achieves mid-flight
/// admission.
pub struct ContinuousSession<F: StepForward> {
    batcher: Batcher,
    sched: Scheduler,
    fwd: F,
    /// Steps executed so far (admission bookkeeping is step-indexed so
    /// queue waits are measurable in deterministic simulation tests).
    step_idx: u64,
    /// Request id → step index at enqueue.
    arrivals: HashMap<u64, u64>,
    // reused step buffers — the steady-state scheduling loop performs
    // no per-step allocations outside the forward itself
    admit_buf: Vec<(Request, Instant)>,
    slot_buf: Vec<usize>,
    rows_buf: Vec<usize>,
    toks_buf: Vec<i32>,
    pos_buf: Vec<usize>,
    /// Requests retired during the in-progress step. Normally drained
    /// by [`ContinuousSession::step`]'s Ok return; if the step's
    /// forward fails *after* some requests already retired (admission
    /// phase succeeded, decode failed), their completed results stay
    /// here — [`ContinuousSession::take_finished`] delivers them so an
    /// engine error never swallows a finished generation.
    finished_buf: Vec<RequestResult>,
    // run aggregates, flushed as one WaveMetrics per busy period
    prefill_time: Duration,
    decode_time: Duration,
    run_decode_steps: usize,
    run_prompt_tokens: usize,
    run_generated: usize,
}

impl<F: StepForward> ContinuousSession<F> {
    pub fn new(cfg: BatcherConfig, fwd: F) -> ContinuousSession<F> {
        let sched = Scheduler::new(&cfg.buckets);
        ContinuousSession {
            batcher: Batcher::new(cfg),
            sched,
            fwd,
            step_idx: 0,
            arrivals: HashMap::new(),
            admit_buf: Vec::new(),
            slot_buf: Vec::new(),
            rows_buf: Vec::new(),
            toks_buf: Vec::new(),
            pos_buf: Vec::new(),
            finished_buf: Vec::new(),
            prefill_time: Duration::ZERO,
            decode_time: Duration::ZERO,
            run_decode_steps: 0,
            run_prompt_tokens: 0,
            run_generated: 0,
        }
    }

    pub fn enqueue(&mut self, r: Request) {
        self.arrivals.insert(r.id, self.step_idx);
        self.batcher.push(r);
    }

    /// Queue depth (not yet admitted).
    pub fn pending(&self) -> usize {
        self.batcher.len()
    }

    pub fn live(&self) -> usize {
        self.sched.live()
    }

    /// No queued work and no live slots.
    pub fn is_idle(&self) -> bool {
        self.batcher.is_empty() && self.sched.is_idle()
    }

    pub fn step_index(&self) -> u64 {
        self.step_idx
    }

    pub fn metrics(&self) -> &SchedulerMetrics {
        &self.sched.metrics
    }

    pub fn forward(&self) -> &F {
        &self.fwd
    }

    pub fn forward_mut(&mut self) -> &mut F {
        &mut self.fwd
    }

    /// Take the accumulated scheduler gauges (resets them).
    pub fn take_metrics(&mut self) -> SchedulerMetrics {
        std::mem::take(&mut self.sched.metrics)
    }

    /// Summarize the run so far as one [`WaveMetrics`] (resets the
    /// aggregates). `None` if nothing was generated.
    pub fn take_run_summary(&mut self) -> Option<WaveMetrics> {
        if self.run_generated == 0 {
            return None;
        }
        let w = WaveMetrics {
            batch: self.sched.pool_size(),
            prompt_tokens: self.run_prompt_tokens,
            generated_tokens: self.run_generated,
            prefill: self.prefill_time,
            decode: self.decode_time,
            decode_steps: self.run_decode_steps,
        };
        self.prefill_time = Duration::ZERO;
        self.decode_time = Duration::ZERO;
        self.run_decode_steps = 0;
        self.run_prompt_tokens = 0;
        self.run_generated = 0;
        Some(w)
    }

    /// Results completed by a step that later returned `Err` (the
    /// forward failed after some requests had already retired). Empty
    /// after any successful [`ContinuousSession::step`]. Callers on
    /// the error path must deliver these before failing the rest.
    pub fn take_finished(&mut self) -> Vec<RequestResult> {
        std::mem::take(&mut self.finished_buf)
    }

    /// Abandon everything in flight and queued (engine error path).
    /// Returns the affected request ids. Completed-but-undelivered
    /// results are NOT aborted — drain them first via
    /// [`ContinuousSession::take_finished`].
    pub fn abort_all(&mut self) -> Vec<u64> {
        let mut ids = Vec::new();
        self.rows_buf.clear();
        self.sched.live_rows(&mut self.rows_buf);
        let rows = std::mem::take(&mut self.rows_buf);
        for sid in rows {
            let st = self.sched.retire(sid);
            self.fwd.release(sid);
            ids.push(st.request.id);
        }
        while let Some((r, _)) = self.batcher.pop_front() {
            ids.push(r.id);
        }
        self.arrivals.clear();
        ids
    }

    /// Run until idle (standalone-queue convenience; the threaded
    /// server calls [`ContinuousSession::step`] directly so it can
    /// ingest arrivals between steps). Results are sorted by id.
    pub fn drain(&mut self) -> Result<Vec<RequestResult>> {
        let mut out = Vec::new();
        while !self.is_idle() {
            out.extend(self.step()?);
        }
        out.sort_by_key(|r| r.id);
        Ok(out)
    }

    /// One scheduler step: admit into free slots, prefill admissions
    /// (their first token samples now — TTFT is enqueue→here), then
    /// one decode step over all live slots at the minimal covering
    /// bucket. Returns the requests retired during the step.
    pub fn step(&mut self) -> Result<Vec<RequestResult>> {
        let now = Instant::now();
        let entry_step = self.step_idx;
        self.step_idx += 1;
        let kv_cap = self.fwd.kv_capacity();

        // --- admission: FIFO into free slots; the batcher's hold
        // window applies only while the engine is idle (an idle engine
        // may wait for a fuller first batch; a busy one admits
        // immediately — free slots are pure upside) ---
        let free = self.sched.free_count();
        if free > 0 && !self.batcher.is_empty() {
            self.batcher.admit_into(free, self.sched.is_idle(), &mut self.admit_buf);
            if !self.admit_buf.is_empty() {
                self.slot_buf.clear();
                for (r, enq) in self.admit_buf.drain(..) {
                    let arrival = self.arrivals.remove(&r.id).unwrap_or(entry_step);
                    let waited = entry_step.saturating_sub(arrival);
                    self.run_prompt_tokens += r.prompt.len();
                    self.slot_buf.push(self.sched.assign(r, enq, waited, now));
                }
                let t0 = Instant::now();
                let prompts: Vec<&[usize]> = self
                    .slot_buf
                    .iter()
                    .map(|&sid| self.sched.slot(sid).request.prompt.as_slice())
                    .collect();
                let outcomes = self.fwd.prefill(&self.slot_buf, &prompts)?;
                drop(prompts);
                self.prefill_time += t0.elapsed();
                // stamp after the forward: TTFT includes prefill compute
                let t_first = Instant::now();
                assert_eq!(outcomes.len(), self.slot_buf.len(), "prefill outcome count");
                for (i, out) in outcomes.into_iter().enumerate() {
                    let sid = self.slot_buf[i];
                    let done = {
                        let st = self.sched.slot_mut(sid);
                        st.pos = out.pos;
                        let tok =
                            st.rng.sample_logits(&out.logits, st.request.params.temperature);
                        st.generated.push(tok);
                        st.cur = tok as i32;
                        st.ttft = Some(t_first.saturating_duration_since(st.enqueued));
                        self.run_generated += 1;
                        st.request.params.stop_token == Some(tok)
                            || st.generated.len() >= st.request.params.max_new_tokens
                            || st.pos >= kv_cap
                    };
                    if done {
                        let st = self.sched.retire(sid);
                        self.fwd.release(sid);
                        let r = finish(st, t_first);
                        self.finished_buf.push(r);
                    }
                }
            }
        }

        // --- one decode step over the live slots ---
        self.sched.live_rows(&mut self.rows_buf);
        if self.rows_buf.is_empty() {
            return Ok(std::mem::take(&mut self.finished_buf));
        }
        let live = self.rows_buf.len();
        let bucket = self.sched.min_bucket(live);
        self.toks_buf.clear();
        self.pos_buf.clear();
        for &sid in &self.rows_buf {
            let st = self.sched.slot(sid);
            debug_assert!(st.pos < kv_cap, "live slot at KV capacity");
            self.toks_buf.push(st.cur);
            self.pos_buf.push(st.pos);
        }
        let t0 = Instant::now();
        let logits = self.fwd.decode(&self.rows_buf, &self.toks_buf, &self.pos_buf, bucket)?;
        self.decode_time += t0.elapsed();
        self.run_decode_steps += 1;
        // stamp after the forward: latency includes the final decode
        let t_done = Instant::now();
        assert_eq!(logits.len(), live, "decode logits row count");
        for (i, row) in logits.iter().enumerate() {
            let sid = self.rows_buf[i];
            let done = {
                let st = self.sched.slot_mut(sid);
                let tok = st.rng.sample_logits(row, st.request.params.temperature);
                st.generated.push(tok);
                st.cur = tok as i32;
                st.pos += 1;
                self.run_generated += 1;
                st.request.params.stop_token == Some(tok)
                    || st.generated.len() >= st.request.params.max_new_tokens
                    || st.pos >= kv_cap
            };
            if done {
                let st = self.sched.retire(sid);
                self.fwd.release(sid);
                let r = finish(st, t_done);
                self.finished_buf.push(r);
            }
        }
        self.sched.record_step(bucket, live);
        Ok(std::mem::take(&mut self.finished_buf))
    }
}

/// Package a retired slot as a request result. Continuous-batching
/// TTFT is user-perceived (enqueue→first token); `queued` is the
/// enqueue→admission wait the scheduler controlled.
fn finish(st: SlotState, now: Instant) -> RequestResult {
    RequestResult {
        id: st.request.id,
        tokens: st.generated,
        ttft: st.ttft.unwrap_or_default(),
        latency: now.saturating_duration_since(st.enqueued),
        queued: st.admitted_at.saturating_duration_since(st.enqueued),
        queued_steps: st.queued_steps,
    }
}

// ---------------------------------------------------------------------------
// Deterministic stub model (tests, simulations, benches)
// ---------------------------------------------------------------------------

/// Deterministic logits for a context: hash the tokens, expand through
/// the repo Rng. A row depends only on its own context, never on batch
/// composition — the property that makes scheduler-order bugs visible
/// as token divergence.
pub fn stub_logits(ctx: &[usize], vocab: usize) -> Vec<f32> {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325; // FNV-1a offset basis
    for &t in ctx {
        h ^= t as u64;
        h = h.wrapping_mul(0x100_0000_01b3); // FNV-1a prime
    }
    let mut rng = Rng::new(h ^ vocab as u64);
    (0..vocab).map(|_| rng.f32()).collect()
}

/// Host-only [`StepForward`]: each slot's "KV cache" is its token
/// context. Used by the scheduler test suites and the artifact-free
/// serving bench; also a template for plugging non-PJRT backends into
/// the session.
pub struct StubForward {
    vocab: usize,
    kv_cap: usize,
    ctx: Vec<Option<Vec<usize>>>,
    /// Release calls observed (tests assert slot hygiene).
    pub released: u64,
}

impl StubForward {
    pub fn new(pool: usize, vocab: usize, kv_cap: usize) -> StubForward {
        StubForward { vocab, kv_cap, ctx: (0..pool).map(|_| None).collect(), released: 0 }
    }

    /// Live contexts currently held (slot hygiene checks).
    pub fn live_contexts(&self) -> usize {
        self.ctx.iter().filter(|c| c.is_some()).count()
    }
}

impl StepForward for StubForward {
    fn prefill(&mut self, slots: &[usize], prompts: &[&[usize]]) -> Result<Vec<PrefillOutcome>> {
        let mut out = Vec::with_capacity(slots.len());
        for (&sid, &p) in slots.iter().zip(prompts) {
            anyhow::ensure!(self.ctx[sid].is_none(), "stub: prefill into a live slot {sid}");
            let ctx = p.to_vec();
            out.push(PrefillOutcome { logits: stub_logits(&ctx, self.vocab), pos: ctx.len() });
            self.ctx[sid] = Some(ctx);
        }
        Ok(out)
    }

    fn decode(
        &mut self,
        slots: &[usize],
        tokens: &[i32],
        _pos: &[usize],
        bucket: usize,
    ) -> Result<Vec<Vec<f32>>> {
        anyhow::ensure!(slots.len() <= bucket, "stub: {} rows > bucket {bucket}", slots.len());
        let mut out = Vec::with_capacity(slots.len());
        for (&sid, &tok) in slots.iter().zip(tokens) {
            let ctx = self.ctx[sid].as_mut().expect("stub: decode on empty slot");
            ctx.push(tok as usize);
            out.push(stub_logits(ctx, self.vocab));
        }
        Ok(out)
    }

    fn release(&mut self, slot: usize) {
        self.ctx[slot] = None;
        self.released += 1;
    }

    fn kv_capacity(&self) -> usize {
        self.kv_cap
    }
}

/// Run-to-completion reference for one request against the stub model:
/// the same sampling rule as the engines, no scheduler involved. Since
/// batch rows are independent, this is exactly what any correct
/// scheduler must emit for the request.
pub fn stub_reference(r: &Request, vocab: usize, kv_cap: usize) -> Vec<usize> {
    let mut rng = Rng::new(r.params.seed);
    let mut ctx = r.prompt.clone();
    let mut pos = ctx.len();
    let mut gen = Vec::new();
    let tok = rng.sample_logits(&stub_logits(&ctx, vocab), r.params.temperature);
    gen.push(tok);
    let mut cur = tok;
    let mut done = r.params.stop_token == Some(tok)
        || gen.len() >= r.params.max_new_tokens
        || pos >= kv_cap;
    while !done {
        ctx.push(cur);
        let tok = rng.sample_logits(&stub_logits(&ctx, vocab), r.params.temperature);
        gen.push(tok);
        cur = tok;
        pos += 1;
        done = r.params.stop_token == Some(tok)
            || gen.len() >= r.params.max_new_tokens
            || pos >= kv_cap;
    }
    gen
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::request::GenParams;

    fn req(id: u64, max_new: usize) -> Request {
        Request::new(
            id,
            vec![1, 2, 3],
            GenParams { max_new_tokens: max_new, temperature: 0.0, seed: id, stop_token: None },
        )
    }

    #[test]
    fn pool_and_bucket_shape() {
        let s = Scheduler::new(&[8, 1, 32, 8]);
        assert_eq!(s.pool_size(), 32);
        assert_eq!(s.buckets(), &[1, 8, 32]);
        assert_eq!(s.min_bucket(1), 1);
        assert_eq!(s.min_bucket(2), 8);
        assert_eq!(s.min_bucket(8), 8);
        assert_eq!(s.min_bucket(9), 32);
        assert_eq!(s.min_bucket(32), 32);
    }

    #[test]
    fn retired_slots_recycle_first() {
        let mut s = Scheduler::new(&[4]);
        let now = Instant::now();
        let a = s.assign(req(0, 4), now, 0, now);
        let b = s.assign(req(1, 4), now, 0, now);
        assert_eq!((a, b), (0, 1));
        s.retire(a);
        // the just-retired slot 0 is taken before fresh slot 2
        let c = s.assign(req(2, 4), now, 0, now);
        assert_eq!(c, 0);
        assert_eq!(s.metrics.slot_reuses, 1);
        assert_eq!(s.live(), 2);
        assert_eq!(s.free_count() + s.live(), s.pool_size());
    }

    #[test]
    fn session_runs_queue_to_completion() {
        let cfg = BatcherConfig { buckets: vec![1, 4], max_wait: Duration::ZERO };
        let mut sess = ContinuousSession::new(cfg, StubForward::new(4, 11, usize::MAX));
        for i in 0..6 {
            sess.enqueue(req(i, 3 + i as usize % 3));
        }
        let results = sess.drain().unwrap();
        assert_eq!(results.len(), 6);
        for r in &results {
            assert_eq!(r.tokens, stub_reference(&req(r.id, 3 + r.id as usize % 3), 11, usize::MAX));
        }
        assert!(sess.is_idle());
        assert_eq!(sess.forward().live_contexts(), 0, "every slot released");
        let m = sess.take_metrics();
        assert_eq!(m.admitted, 6);
        assert_eq!(m.retired, 6);
        assert!(m.slot_reuses >= 2, "6 requests through a 4-slot pool must recycle");
        let w = sess.take_run_summary().unwrap();
        assert_eq!(w.generated_tokens, results.iter().map(|r| r.tokens.len()).sum::<usize>());
    }

    #[test]
    fn kv_capacity_truncates() {
        let cfg = BatcherConfig { buckets: vec![1], max_wait: Duration::ZERO };
        // prompt len 3, cap 5 → prefill at pos 3, two decode steps
        let mut sess = ContinuousSession::new(cfg, StubForward::new(1, 7, 5));
        sess.enqueue(req(0, 100));
        let results = sess.drain().unwrap();
        assert_eq!(results[0].tokens.len(), 3, "1 prefill + (cap-prompt) decode tokens");
        assert_eq!(results[0].tokens, stub_reference(&req(0, 100), 7, 5));
    }

    #[test]
    fn abort_clears_everything() {
        let cfg = BatcherConfig { buckets: vec![2], max_wait: Duration::ZERO };
        let mut sess = ContinuousSession::new(cfg, StubForward::new(2, 7, usize::MAX));
        for i in 0..5 {
            sess.enqueue(req(i, 50));
        }
        sess.step().unwrap(); // two live, three queued
        assert_eq!(sess.live(), 2);
        let mut ids = sess.abort_all();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
        assert!(sess.is_idle());
        assert_eq!(sess.forward().live_contexts(), 0);
    }

    #[test]
    fn stub_logits_depend_only_on_context() {
        let a = stub_logits(&[1, 2, 3], 13);
        let b = stub_logits(&[1, 2, 3], 13);
        let c = stub_logits(&[1, 2, 4], 13);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_eq!(a.len(), 13);
    }
}
