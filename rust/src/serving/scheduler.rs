//! Continuous in-flight batching: a fixed pool of KV slots, per-step
//! admission and retirement, and a minimal-covering compiled-bucket
//! choice — the scheduler half of the serving engine.
//!
//! The run-to-completion wave path ([`crate::serving::Engine::run_queue_waves`])
//! holds a whole batch hostage until its longest member finishes:
//! retired neighbors pad every GEMM and queued requests wait for the
//! wave boundary. This module inverts that control flow. A
//! [`Scheduler`] owns `max(buckets)` KV slots; every step it
//!
//! 1. **preempts** live low-priority slots when a deadline-urgent
//!    higher class would otherwise wait ([`crate::serving::PreemptMode`]),
//! 2. **admits** queued requests by priority class into free slots
//!    (recycling retired slots before touching fresh ones), resuming
//!    preempted victims ahead of equal-or-lower-class fresh work,
//! 3. **prefills** the admissions — monolithically, or in fixed
//!    token-budget chunks ([`BatcherConfig::prefill_chunk_tokens`])
//!    interleaved with decode steps so one long prompt cannot freeze
//!    the live decodes; a request samples its first token only when
//!    its final chunk runs,
//! 4. runs **one decode step** over the live slots at the smallest
//!    compiled batch bucket covering them, and
//! 5. **retires** every request that hit its stop token,
//!    `max_new_tokens`, or the KV capacity — freeing the slot for the
//!    next step's admission.
//!
//! Scheduling is pure host logic, factored away from the artifact
//! runtime behind the [`StepForward`] trait so it is exhaustively
//! testable without compiled artifacts: [`StubForward`] is a
//! deterministic host-only model whose logits depend only on a
//! request's own context, which makes "continuous batching preserves
//! each request's exact token stream" a checkable property
//! (`tests/scheduler.rs`, `tests/continuous_sim.rs`). The artifact
//! engine drives the *same* [`ContinuousSession`] through its
//! `EngineStepForward` implementation.
//!
//! Invariants (property-tested):
//! * a slot is never double-assigned; `live + free == pool` always;
//! * admission order is FIFO within a priority class; across classes
//!   it is deadline urgency, then aging promotion, then class order
//!   (all-default-priority workloads degenerate to exact global FIFO);
//! * retired slots are reused before never-used slots;
//! * the step bucket is the smallest configured bucket ≥ live count;
//! * per-request output is token-identical to running that request
//!   alone (batch rows are independent), hence identical to the
//!   run-to-completion wave engine — **including across preemption**:
//!   a victim resumed from parked KV or recomputed from its token
//!   history emits the same stream as an unpreempted run
//!   (`tests/preemption.rs`);
//! * a request waits at most the pool-serialized work of the requests
//!   ahead of it plus the aging threshold (aging bounds starvation
//!   under persistent higher-class load);
//! * prefix sharing is invisible in token space: admission may map a
//!   prompt's cached prefix pages ([`StepForward::map_prefix`]) so
//!   prefill only computes the suffix, but per-request output stays
//!   bit-identical with the cache on or off (`tests/continuous_sim.rs`
//!   pins it; the saving shows up only in the prefill-token and
//!   page-occupancy gauges).
//!
//! **Fault containment** (`tests/fault_injection.rs`): a failing
//! forward call never takes down the session. A failed batched prefill
//! or decode is retried one request at a time from authoritative
//! host-side token state; requests that fail in isolation are retired
//! with a typed [`RequestFailure`] (drained via
//! [`ContinuousSession::take_failures`]) and their slot and KV pages
//! reclaimed, while every other request keeps its exact token stream.
//! Scheduler bookkeeping violations surface as [`SchedError`] values,
//! not panics.

use crate::runtime::{KvSlotPool, ParkedSlot};
use crate::serving::batcher::{
    covering_bucket, Batcher, BatcherConfig, ConfigError, PreemptMode, SubmitOutcome,
};
use crate::serving::clock::Clock;
use crate::serving::metrics::{PageMetrics, SchedulerMetrics, WaveMetrics};
use crate::serving::prefix_cache::PrefixCache;
use crate::serving::request::{
    EffortTier, Priority, Request, RequestFailure, RequestResult, TierRatios,
};
use crate::util::Rng;
use anyhow::Result;
use std::collections::VecDeque;
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------------
// Slot pool
// ---------------------------------------------------------------------------

/// A scheduler bookkeeping violation, surfaced as a recoverable value
/// instead of a panic so one bad request cannot take down the serving
/// process (the session retires the request with a typed
/// [`RequestFailure`] and keeps stepping).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedError {
    /// No free slot — callers must check [`Scheduler::free_count`].
    PoolFull,
    /// The slot holds no request (double retire / stale id).
    EmptySlot(usize),
}

impl std::fmt::Display for SchedError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SchedError::PoolFull => write!(f, "scheduler pool has no free slot"),
            SchedError::EmptySlot(sid) => write!(f, "scheduler slot {sid} is empty"),
        }
    }
}

impl std::error::Error for SchedError {}

/// Per-slot generation state while a request is in flight.
#[derive(Debug)]
pub struct SlotState {
    pub request: Request,
    /// When the request entered the admission queue.
    pub enqueued: Instant,
    /// When it was admitted into this slot.
    pub admitted_at: Instant,
    /// Scheduler steps spent waiting in the queue before admission.
    pub queued_steps: u64,
    /// Monotone admission stamp (re-stamped on resume). Preemption
    /// victimizes the *youngest* admission of the lowest class — the
    /// request with the least sunk work.
    pub admit_seq: u64,
    /// Sampling stream (seeded from the request, so the token stream
    /// is independent of batch composition).
    pub rng: Rng,
    /// Tokens generated so far (first token comes from prefill).
    pub generated: Vec<usize>,
    /// Last sampled token — the next decode step's input.
    pub cur: i32,
    /// Next KV write position (starts at the prefill length).
    pub pos: usize,
    /// Enqueue→first-token time, set when prefill samples.
    pub ttft: Option<Duration>,
    /// Prompt tokens already resident in the slot's KV: the mapped
    /// prefix plus every completed prefill chunk. Equal to the prompt
    /// length once prefill finishes; strictly less while the request
    /// is mid-prefill under a chunk budget
    /// (`BatcherConfig::prefill_chunk_tokens`).
    pub prefilled: usize,
    /// Prompt tokens currently credited to `prefill_tokens_saved` for
    /// this slot (the mapped-prefix length at admission). A prefill
    /// outcome whose computed range starts below this (back-extension
    /// overlap, or the monolithic fallback recomputing from 0) pays
    /// the difference back — the savings meter only keeps compute that
    /// was actually skipped.
    pub saved_credit: usize,
    /// Step index at which the request entered the admission queue
    /// (the batcher's arrival stamp — survives preemption, so the
    /// step-denominated TTFT covers preempted waits too).
    pub enqueue_step: u64,
    /// Step index that sampled the first token (`None` until then).
    pub first_token_step: Option<u64>,
    /// Step index that sampled the most recent token.
    pub last_token_step: u64,
}

/// The KV-slot pool + bucket policy. Owns which request occupies which
/// slot; knows nothing about tokens or devices (that is the session's
/// and the [`StepForward`] impl's job).
pub struct Scheduler {
    /// Compiled batch buckets, ascending, deduplicated.
    buckets: Vec<usize>,
    slots: Vec<Option<SlotState>>,
    /// Free-slot stack. Initialized so fresh slots pop in ascending
    /// order; retired slots are pushed on top and therefore reused
    /// before any never-used slot (LIFO keeps the working set warm).
    free: Vec<usize>,
    /// Slots that have ever held a request (feeds the reuse gauge).
    used: Vec<bool>,
    /// Next [`SlotState::admit_seq`] stamp.
    next_admit_seq: u64,
    pub metrics: SchedulerMetrics,
}

impl Scheduler {
    /// Pool size is the largest bucket: the engine can never run a
    /// batch bigger than its largest compiled artifact. Bucket lists
    /// are validated (non-empty, no zero bucket) and normalized
    /// (sorted, deduplicated) — a bad config is a typed error, not a
    /// panic.
    pub fn new(buckets: &[usize]) -> Result<Scheduler, ConfigError> {
        let buckets =
            BatcherConfig { buckets: buckets.to_vec(), ..Default::default() }.normalized()?;
        let Some(&pool) = buckets.last() else {
            // normalized() already rejects empty bucket lists; keep the
            // typed error rather than a panic if that ever changes.
            return Err(ConfigError::NoBuckets);
        };
        Ok(Scheduler {
            buckets,
            slots: (0..pool).map(|_| None).collect(),
            free: (0..pool).rev().collect(),
            used: vec![false; pool],
            next_admit_seq: 0,
            metrics: SchedulerMetrics::default(),
        })
    }

    pub fn pool_size(&self) -> usize {
        self.slots.len()
    }

    pub fn free_count(&self) -> usize {
        self.free.len()
    }

    pub fn live(&self) -> usize {
        self.slots.len() - self.free.len()
    }

    pub fn is_idle(&self) -> bool {
        self.free.len() == self.slots.len()
    }

    pub fn buckets(&self) -> &[usize] {
        &self.buckets
    }

    /// Smallest configured bucket covering `n` live slots. `n` never
    /// exceeds the pool (== the largest bucket) by construction.
    pub fn min_bucket(&self, n: usize) -> usize {
        debug_assert!(n >= 1 && n <= self.pool_size());
        covering_bucket(&self.buckets, n)
    }

    /// Remove a slot's state without retiring it (preemption / failure
    /// paths — no `retired` metric). The slot returns to the free
    /// stack. `None` if the slot was already empty.
    pub fn detach(&mut self, sid: usize) -> Option<SlotState> {
        let st = self.slots[sid].take()?;
        self.free.push(sid);
        Some(st)
    }

    /// Install an in-flight state into a free slot. On a full pool the
    /// state is handed back untouched (the caller re-queues it).
    pub fn install(&mut self, st: SlotState) -> Result<usize, SlotState> {
        let Some(sid) = self.free.pop() else { return Err(st) };
        debug_assert!(self.slots[sid].is_none(), "scheduler: slot {sid} double-assigned");
        if self.used[sid] {
            self.metrics.slot_reuses += 1;
        }
        self.used[sid] = true;
        self.slots[sid] = Some(st);
        self.metrics.peak_live = self.metrics.peak_live.max(self.live());
        Ok(sid)
    }

    /// Assign a request to a free slot. [`SchedError::PoolFull`] when
    /// there is none — callers check [`Scheduler::free_count`] first;
    /// the error path exists so a bookkeeping bug degrades one request
    /// instead of the process.
    pub fn assign(
        &mut self,
        request: Request,
        enqueued: Instant,
        queued_steps: u64,
        now: Instant,
        enqueue_step: u64,
    ) -> Result<usize, SchedError> {
        let rng = Rng::new(request.params.seed);
        let wait_ms = now.saturating_duration_since(enqueued).as_secs_f32() * 1e3;
        let st = SlotState {
            request,
            enqueued,
            admitted_at: now,
            queued_steps,
            admit_seq: self.next_admit_seq,
            rng,
            generated: Vec::new(),
            cur: 0,
            pos: 0,
            ttft: None,
            prefilled: 0,
            saved_credit: 0,
            enqueue_step,
            first_token_step: None,
            last_token_step: 0,
        };
        let sid = self.install(st).map_err(|_| SchedError::PoolFull)?;
        self.next_admit_seq += 1;
        self.metrics.admitted += 1;
        self.metrics.queue_wait_ms.push(wait_ms);
        Ok(sid)
    }

    /// Re-install a preempted request's state (token history, RNG
    /// stream and timing survive preemption verbatim; only the
    /// admission stamp is renewed). Counts toward `resumed`, not
    /// `admitted`. On a full pool the state is handed back.
    pub fn resume(&mut self, mut st: SlotState) -> Result<usize, SlotState> {
        st.admit_seq = self.next_admit_seq;
        let sid = self.install(st)?;
        self.next_admit_seq += 1;
        self.metrics.resumed += 1;
        Ok(sid)
    }

    /// Retire a slot, returning its state and freeing the slot for the
    /// next admission (ahead of never-used slots).
    pub fn retire(&mut self, sid: usize) -> Result<SlotState, SchedError> {
        let st = self.detach(sid).ok_or(SchedError::EmptySlot(sid))?;
        self.metrics.retired += 1;
        Ok(st)
    }

    /// The slot to preempt so a deadline-urgent request of class
    /// `above` can run: the live slot of the **largest** class index
    /// strictly below `above` in priority (Low before Normal), and
    /// within that class the **youngest** admission (least sunk work —
    /// the vLLM recompute-the-newcomer discipline). `None` when no
    /// live slot is strictly lower-class than `above`.
    pub fn pick_victim(&self, above: Priority) -> Option<usize> {
        self.slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| s.as_ref().map(|st| (i, st)))
            .filter(|(_, st)| st.request.priority.index() > above.index())
            .max_by_key(|&(_, st)| (st.request.priority.index(), st.admit_seq))
            .map(|(i, _)| i)
    }

    /// Whether `sid` currently holds a request (chunked-prefill
    /// bookkeeping checks this before touching a slot that may have
    /// been detached mid-step by a contained fault).
    pub fn occupied(&self, sid: usize) -> bool {
        self.slots[sid].is_some()
    }

    pub fn slot(&self, sid: usize) -> &SlotState {
        // lint: allow(panic-discipline) — accessor contract: callers pass sids from live_slots()/admit(), which only yield occupied slots; an empty slot here is scheduler-internal corruption, not a request fault
        self.slots[sid].as_ref().expect("scheduler: empty slot")
    }

    pub fn slot_mut(&mut self, sid: usize) -> &mut SlotState {
        // lint: allow(panic-discipline) — accessor contract: callers pass sids from live_slots()/admit(), which only yield occupied slots; an empty slot here is scheduler-internal corruption, not a request fault
        self.slots[sid].as_mut().expect("scheduler: empty slot")
    }

    /// Live slot ids, ascending — the step's row order. Ascending order
    /// is deterministic and stable under retirement, which keeps traces
    /// replayable; it does not affect values (batch rows are
    /// independent through the model).
    pub fn live_rows(&self, out: &mut Vec<usize>) {
        out.clear();
        out.extend(
            self.slots.iter().enumerate().filter(|(_, s)| s.is_some()).map(|(i, _)| i),
        );
    }

    /// Record one executed decode step at `bucket` with `live` rows.
    pub fn record_step(&mut self, bucket: usize, live: usize) {
        self.metrics.decode_steps += 1;
        self.metrics.live_row_steps += live as u64;
        self.metrics.bucket_row_steps += bucket as u64;
    }
}

// ---------------------------------------------------------------------------
// The forward abstraction
// ---------------------------------------------------------------------------

/// Result of prefilling one request into a slot.
pub struct PrefillOutcome {
    /// Last-position logits row (the first sample's distribution).
    pub logits: Vec<f32>,
    /// KV length after prefill — the first decode step's position.
    pub pos: usize,
    /// First prompt position this call actually *computed*. Equal to
    /// the cached-prefix length when a continuation artifact covered
    /// the suffix exactly; lower when the plan back-extended onto the
    /// compiled grid or fell back to a monolithic prefill (which
    /// recomputes from 0 even over cached tokens). The session uses
    /// this to reconcile `prefill_tokens_saved` with the compute that
    /// was genuinely skipped.
    pub start: usize,
}

/// What the scheduler needs from a model: prefill into a slot, one
/// batched decode step over named slots, and slot KV release. The
/// artifact engine implements this against PJRT buffers + the paged
/// per-slot [`KvSlotPool`]; [`StubForward`] implements it as a
/// deterministic host function for artifact-free testing.
pub trait StepForward {
    /// Map the longest cached prefix of `prompt` into `slot`'s KV
    /// ahead of prefill (prefix-cache backends — the session calls
    /// this at admission). `Ok(None)` means this backend consulted no
    /// cache (the session then skips hit-rate accounting, so a
    /// cache-less run never reports a meaningless 0% hit rate);
    /// `Ok(Some(n))` maps `n` leading prompt tokens, always less than
    /// `prompt.len()`, so prefill still computes the last prompt
    /// position and produces the first token's logits. An `Err` is
    /// contained: the session releases the slot's (possibly partial)
    /// mapping and prefills uncached. The default never consults a
    /// cache.
    fn map_prefix(&mut self, _slot: usize, _prompt: &[usize]) -> Result<Option<usize>> {
        Ok(None)
    }

    /// Batched prefill of newly admitted requests; `prompts[i]` goes
    /// to KV slot `slots[i]`, whose leading `cached[i]` tokens are
    /// already resident (from [`StepForward::map_prefix`]) —
    /// implementations prefill only the suffix `prompts[i][cached[i]..]`.
    /// Returns one outcome per slot, same order. Implementations must
    /// keep each row's result independent of the other rows (the
    /// token-identity guarantee rests on it). An `Err` fails no one by
    /// itself: the session releases the batch's slots and retries each
    /// request in isolation, retiring only individually-failing ones.
    fn prefill(
        &mut self,
        slots: &[usize],
        prompts: &[&[usize]],
        cached: &[usize],
    ) -> Result<Vec<PrefillOutcome>>;

    /// One decode step: `slots` are the live rows (ascending),
    /// `tokens[i]`/`pos[i]` their input token and KV position, padded
    /// on device to `bucket` rows. Returns one logits row per live
    /// slot, same order. An `Err` is contained the same way as a
    /// prefill failure: each row is rebuilt from host-side token state
    /// and decoded alone.
    fn decode(
        &mut self,
        slots: &[usize],
        tokens: &[i32],
        pos: &[usize],
        bucket: usize,
    ) -> Result<Vec<Vec<f32>>>;

    /// The slot retired — its KV may be recycled.
    fn release(&mut self, slot: usize);

    /// Detach the slot's KV intact for a preempted request
    /// ([`PreemptMode::Park`]): the returned [`ParkedSlot`] keeps its
    /// page references, so the KV survives any interleaved work and
    /// [`StepForward::unpark`] restores it bit-identically. `None`
    /// means this backend cannot park (the session falls back to
    /// drop + recompute). The default cannot park.
    fn park(&mut self, _slot: usize) -> Option<ParkedSlot> {
        None
    }

    /// Reattach KV parked by [`StepForward::park`] to a (new) slot.
    /// Only ever called with this backend's own parked state; a
    /// backend that never returns `Some` from `park` is never asked
    /// to unpark.
    fn unpark(&mut self, _slot: usize, _parked: ParkedSlot) {
        // lint: allow(panic-discipline) — default-impl invariant: a ParkedSlot only exists if this backend's park() returned Some, and this default park() always returns None, so no ParkedSlot can reach it
        unreachable!("unpark without a matching park — the session only resumes parked KV through the backend that parked it");
    }

    /// A parked request was aborted before resuming — drop its page
    /// references. Backends that never park have nothing to do.
    fn drop_parked(&mut self, _parked: ParkedSlot) {}

    /// Per-slot KV capacity; a request whose position reaches this is
    /// force-retired (same truncation rule as the wave engine's
    /// `pos < kv_len` loop bound).
    fn kv_capacity(&self) -> usize;

    /// Set the activation-ratio operating point for `slot`'s rows
    /// (effort tiers, ROADMAP item 4). The session calls this right
    /// after a request is assigned or resumed into `slot`, before any
    /// prefill or decode touches it; `ratio >= 1` means full effort.
    /// Backends without tiered execution ignore it — the default is a
    /// no-op, preserving the untiered behavior (the tier then remains
    /// a metering-only signal).
    fn set_slot_ratio(&mut self, _slot: usize, _ratio: f32) {}

    /// Paged-KV gauges, when this backend owns a page pool. Default:
    /// no pages to report.
    fn page_metrics(&self) -> Option<PageMetrics> {
        None
    }
}

// ---------------------------------------------------------------------------
// The continuous session: preempt → admit → prefill → decode → retire
// ---------------------------------------------------------------------------

/// A preempted request awaiting resume: its full host-side state plus
/// (in [`PreemptMode::Park`]) its detached KV pages. In drop mode `kv`
/// is `None` and resume recomputes the KV from `st`'s token history.
struct Preempted {
    st: SlotState,
    kv: Option<ParkedSlot>,
}

/// One continuous-batching run: an admission queue ([`Batcher`]), the
/// slot pool, and a [`StepForward`] backend. [`ContinuousSession::step`]
/// executes one scheduler step and returns the requests retired by it;
/// callers ingest new requests between steps ([`ContinuousSession::enqueue`]),
/// which is exactly how the threaded server achieves mid-flight
/// admission.
pub struct ContinuousSession<F: StepForward> {
    batcher: Batcher,
    sched: Scheduler,
    fwd: F,
    /// Time source — [`Clock::manual`] in deterministic tests.
    clock: Clock,
    /// Copied from the config at construction.
    preempt_mode: PreemptMode,
    /// Tier → activation-ratio operating points (copied from the
    /// config). Pushed to the backend per slot at admission/resume and
    /// metered per decoded row.
    tier_ratios: TierRatios,
    /// Steps executed so far (admission bookkeeping is step-indexed so
    /// queue waits are measurable in deterministic simulation tests).
    step_idx: u64,
    /// Preempted requests awaiting a free slot, FIFO per arrival of
    /// the preemption (resume prefers the front).
    preempted: VecDeque<Preempted>,
    /// Per-step prefill token budget copied from the config
    /// (`BatcherConfig::prefill_chunk_tokens`; 0 = unbounded, i.e.
    /// monolithic prefill).
    chunk_tokens: usize,
    /// Slots holding admitted-but-not-fully-prefilled requests, in
    /// admission order (resumed mid-prefill victims re-enter at the
    /// front — they carry sunk work). Each step spends the chunk
    /// budget down this list; a slot leaves it when its final chunk
    /// samples the first token, or when it is preempted, failed or
    /// aborted.
    prefilling: Vec<usize>,
    // reused step buffers — the steady-state scheduling loop performs
    // no per-step allocations outside the forward itself
    slot_buf: Vec<usize>,
    cached_buf: Vec<usize>,
    /// Per-chunk prefill end positions, aligned with `slot_buf`.
    ends_buf: Vec<usize>,
    rows_buf: Vec<usize>,
    toks_buf: Vec<i32>,
    pos_buf: Vec<usize>,
    /// Page-counter snapshot at the last [`ContinuousSession::take_page_metrics`]
    /// flush, so repeated flushes of one long-lived session (the
    /// threaded server flushes at every idle) report deltas instead of
    /// re-adding lifetime totals.
    pages_flushed: PageMetrics,
    /// Requests retired during the in-progress step. Normally drained
    /// by [`ContinuousSession::step`]'s Ok return; if the step's
    /// forward fails *after* some requests already retired (admission
    /// phase succeeded, decode failed), their completed results stay
    /// here — [`ContinuousSession::take_finished`] delivers them so an
    /// engine error never swallows a finished generation.
    finished_buf: Vec<RequestResult>,
    /// Requests retired *with an error* (fault containment). Drained
    /// via [`ContinuousSession::take_failures`]; the threaded server
    /// turns each into a typed per-ticket error.
    failed_buf: Vec<RequestFailure>,
    // run aggregates, flushed as one WaveMetrics per busy period
    prefill_time: Duration,
    decode_time: Duration,
    run_decode_steps: usize,
    run_prompt_tokens: usize,
    run_generated: usize,
}

impl<F: StepForward> ContinuousSession<F> {
    pub fn new(cfg: BatcherConfig, fwd: F) -> Result<ContinuousSession<F>, ConfigError> {
        ContinuousSession::with_clock(cfg, fwd, Clock::wall())
    }

    /// Session on an explicit time source — [`Clock::manual`] makes
    /// hold-window, queue-wait and deadline behavior deterministic in
    /// tests.
    pub fn with_clock(
        cfg: BatcherConfig,
        fwd: F,
        clock: Clock,
    ) -> Result<ContinuousSession<F>, ConfigError> {
        let sched = Scheduler::new(&cfg.buckets)?;
        let preempt_mode = cfg.preempt;
        let tier_ratios = cfg.tier_ratios;
        let chunk_tokens = cfg.prefill_chunk_tokens;
        let batcher = Batcher::with_clock(cfg, clock.clone())?;
        Ok(ContinuousSession {
            batcher,
            sched,
            fwd,
            clock,
            preempt_mode,
            tier_ratios,
            step_idx: 0,
            preempted: VecDeque::new(),
            chunk_tokens,
            prefilling: Vec::new(),
            slot_buf: Vec::new(),
            cached_buf: Vec::new(),
            ends_buf: Vec::new(),
            rows_buf: Vec::new(),
            toks_buf: Vec::new(),
            pos_buf: Vec::new(),
            pages_flushed: PageMetrics::default(),
            finished_buf: Vec::new(),
            failed_buf: Vec::new(),
            prefill_time: Duration::ZERO,
            decode_time: Duration::ZERO,
            run_decode_steps: 0,
            run_prompt_tokens: 0,
            run_generated: 0,
        })
    }

    /// Submit a request. Bounded admission: the outcome says whether
    /// it was queued normally, queued at a degraded effort tier
    /// (the queue is past `queue_cap` but within `degrade_margin`), or
    /// shed ([`SubmitOutcome::Rejected`] — the request was **not**
    /// queued and will produce no result).
    pub fn enqueue(&mut self, r: Request) -> SubmitOutcome {
        let out = self.batcher.push_at(r, self.clock.now(), self.step_idx);
        match &out {
            SubmitOutcome::Queued => {}
            SubmitOutcome::QueuedDegraded => self.sched.metrics.degraded_admissions += 1,
            SubmitOutcome::Rejected(_) => self.sched.metrics.shed_requests += 1,
        }
        out
    }

    /// Queue depth (not yet admitted), excluding preempted requests.
    pub fn pending(&self) -> usize {
        self.batcher.len()
    }

    /// Preempted requests awaiting resume.
    pub fn preempted_pending(&self) -> usize {
        self.preempted.len()
    }

    pub fn live(&self) -> usize {
        self.sched.live()
    }

    /// No queued work, no live slots, no preempted requests.
    pub fn is_idle(&self) -> bool {
        self.batcher.is_empty() && self.sched.is_idle() && self.preempted.is_empty()
    }

    pub fn step_index(&self) -> u64 {
        self.step_idx
    }

    pub fn metrics(&self) -> &SchedulerMetrics {
        &self.sched.metrics
    }

    pub fn forward(&self) -> &F {
        &self.fwd
    }

    pub fn forward_mut(&mut self) -> &mut F {
        &mut self.fwd
    }

    /// Take the accumulated scheduler gauges (resets them).
    pub fn take_metrics(&mut self) -> SchedulerMetrics {
        std::mem::take(&mut self.sched.metrics)
    }

    /// Paged-KV gauges since the previous call (event counters as
    /// deltas; point/monotone gauges current) — so a long-lived
    /// session flushed repeatedly into [`crate::serving::EngineMetrics`]
    /// never double-counts. `None` when the backend has no page pool.
    pub fn take_page_metrics(&mut self) -> Option<PageMetrics> {
        let cur = self.fwd.page_metrics()?;
        let delta = PageMetrics {
            page_len: cur.page_len,
            pages_in_use: cur.pages_in_use,
            cached_pages: cur.cached_pages,
            high_water_pages: cur.high_water_pages,
            cow_copies: cur.cow_copies.saturating_sub(self.pages_flushed.cow_copies),
            shared_maps: cur.shared_maps.saturating_sub(self.pages_flushed.shared_maps),
            evicted_pages: cur.evicted_pages.saturating_sub(self.pages_flushed.evicted_pages),
        };
        self.pages_flushed = cur;
        Some(delta)
    }

    /// Summarize the run so far as one [`WaveMetrics`] (resets the
    /// aggregates). `None` if nothing was generated.
    pub fn take_run_summary(&mut self) -> Option<WaveMetrics> {
        if self.run_generated == 0 {
            return None;
        }
        let w = WaveMetrics {
            batch: self.sched.pool_size(),
            prompt_tokens: self.run_prompt_tokens,
            generated_tokens: self.run_generated,
            prefill: self.prefill_time,
            decode: self.decode_time,
            decode_steps: self.run_decode_steps,
        };
        self.prefill_time = Duration::ZERO;
        self.decode_time = Duration::ZERO;
        self.run_decode_steps = 0;
        self.run_prompt_tokens = 0;
        self.run_generated = 0;
        Some(w)
    }

    /// Results completed by a step that later returned `Err` (the
    /// forward failed after some requests had already retired). Empty
    /// after any successful [`ContinuousSession::step`]. Callers on
    /// the error path must deliver these before failing the rest.
    pub fn take_finished(&mut self) -> Vec<RequestResult> {
        std::mem::take(&mut self.finished_buf)
    }

    /// Requests retired with a contained fault since the last call
    /// (typed per-request errors — the rest of the session kept
    /// serving). Callers deliver these alongside results.
    pub fn take_failures(&mut self) -> Vec<RequestFailure> {
        std::mem::take(&mut self.failed_buf)
    }

    /// Abandon everything in flight, preempted and queued (engine
    /// error path). Returns the affected request ids. Completed-but-
    /// undelivered results are NOT aborted — drain them first via
    /// [`ContinuousSession::take_finished`].
    pub fn abort_all(&mut self) -> Vec<u64> {
        let mut ids = Vec::new();
        self.prefilling.clear();
        self.rows_buf.clear();
        self.sched.live_rows(&mut self.rows_buf);
        let rows = std::mem::take(&mut self.rows_buf);
        for sid in rows {
            if let Some(st) = self.sched.detach(sid) {
                self.fwd.release(sid);
                if st.generated.is_empty() {
                    self.sched.metrics.no_first_token += 1;
                }
                ids.push(st.request.id);
            }
        }
        for p in self.preempted.drain(..) {
            if let Some(kv) = p.kv {
                self.fwd.drop_parked(kv);
            }
            if p.st.generated.is_empty() {
                self.sched.metrics.no_first_token += 1;
            }
            ids.push(p.st.request.id);
        }
        while let Some((r, _)) = self.batcher.pop_front() {
            ids.push(r.id);
        }
        ids
    }

    /// Run until idle (standalone-queue convenience; the threaded
    /// server calls [`ContinuousSession::step`] directly so it can
    /// ingest arrivals between steps). Results are sorted by id;
    /// contained per-request faults stay in
    /// [`ContinuousSession::take_failures`].
    pub fn drain(&mut self) -> Result<Vec<RequestResult>> {
        let mut out = Vec::new();
        while !self.is_idle() {
            out.extend(self.step()?);
        }
        out.sort_by_key(|r| r.id);
        Ok(out)
    }

    /// One scheduler step: preempt for deadline-urgent classes, admit
    /// into free slots (resumes first among equals), prefill
    /// admissions (their first token samples now — TTFT is
    /// enqueue→here), then one decode step over all live slots at the
    /// minimal covering bucket. Returns the requests retired during
    /// the step; contained faults land in
    /// [`ContinuousSession::take_failures`].
    pub fn step(&mut self) -> Result<Vec<RequestResult>> {
        let now = self.clock.now();
        let entry_step = self.step_idx;
        self.step_idx += 1;
        let kv_cap = self.fwd.kv_capacity();

        // --- preemption: if deadline-urgent queued requests cannot all
        // be admitted from free slots, evict strictly-lower-class live
        // slots (youngest first). Each eviction's slot is earmarked for
        // one urgent request, so the budget stays consumed. ---
        if self.preempt_mode != PreemptMode::Off && !self.batcher.is_empty() {
            let urgent = self.batcher.urgent_by_class(entry_step);
            let mut budget = self.sched.free_count();
            'classes: for (c, &n) in urgent.iter().enumerate() {
                for _ in 0..n {
                    if budget > 0 {
                        budget -= 1;
                        continue;
                    }
                    let Some(victim) = self.sched.pick_victim(Priority::ALL[c]) else {
                        break 'classes;
                    };
                    self.preempt_slot(victim);
                }
            }
        }

        // --- admission: by class into free slots, resumes preferred
        // among equal classes (they have sunk work). The batcher's hold
        // window applies only while the engine is fully idle (an idle
        // engine may wait for a fuller first batch; a busy one admits
        // immediately — free slots are pure upside). ---
        let idle = self.sched.is_idle() && self.preempted.is_empty();
        let holding = self.batcher.holding(idle, now);
        self.slot_buf.clear();
        while self.sched.free_count() > 0 {
            let p_class = self.preempted.front().map(|p| p.st.request.priority);
            let b_class = if holding { None } else { self.batcher.peek_next(entry_step) };
            let resume_now = match (p_class, b_class) {
                (None, None) => break,
                (Some(p), Some(b)) => p <= b,
                (Some(_), None) => true,
                (None, Some(_)) => false,
            };
            if resume_now {
                if !self.resume_one() {
                    break;
                }
                continue;
            }
            let Some((r, enq, arrival)) = self.batcher.pop_next(entry_step) else { break };
            let waited = entry_step.saturating_sub(arrival);
            if let Some(d) = r.deadline_steps {
                if waited > d {
                    self.sched.metrics.deadline_misses += 1;
                }
            }
            self.run_prompt_tokens += r.prompt.len();
            let rid = r.id;
            let tier = r.tier;
            match self.sched.assign(r, enq, waited, now, arrival) {
                Ok(sid) => {
                    // the backend learns the row's operating point
                    // before any prefill/decode touches the slot
                    self.fwd.set_slot_ratio(sid, self.tier_ratios.ratio(tier));
                    self.slot_buf.push(sid);
                }
                Err(e) => {
                    self.sched.metrics.failed += 1;
                    self.failed_buf.push(RequestFailure { id: rid, error: e.to_string() });
                }
            }
        }

        // --- prefix-map the fresh admissions. The prefill-work gauges
        // meter here, once per request, regardless of how many chunks
        // later carry the work out. ---
        for i in 0..self.slot_buf.len() {
            let sid = self.slot_buf[i];
            let mapped = {
                let prompt = self.sched.slot(sid).request.prompt.as_slice();
                self.fwd.map_prefix(sid, prompt)
            };
            let mapped = match mapped {
                Ok(m) => m,
                Err(_) => {
                    // contained: drop the (possibly partial)
                    // mapping and prefill uncached
                    self.fwd.release(sid);
                    self.sched.metrics.faults_contained += 1;
                    None
                }
            };
            let plen = self.sched.slot(sid).request.prompt.len();
            let cached = mapped.unwrap_or(0);
            debug_assert!(cached < plen.max(1), "mapped prefix must leave a suffix");
            if mapped.is_some() {
                self.sched.metrics.prefix_lookups += 1;
                if cached > 0 {
                    self.sched.metrics.prefix_hits += 1;
                    self.sched.metrics.prefill_tokens_saved += cached as u64;
                }
            }
            self.sched.metrics.prefill_tokens += (plen - cached) as u64;
            let st = self.sched.slot_mut(sid);
            st.prefilled = cached;
            // provisional credit: a later prefill outcome that computes
            // below `cached` (no covering continuation artifact) pays
            // the recomputed overlap back out of the saved gauge
            st.saved_credit = cached;
            self.prefilling.push(sid);
        }

        // --- prefill: spend this step's chunk budget down the
        // mid-prefill list (admission order; resumed victims sit at
        // the front). With no budget (`prefill_chunk_tokens == 0`)
        // every pending prefill completes this step — the monolithic
        // path. A request's first token samples only when its *final*
        // chunk runs; earlier chunks advance KV and discard logits, so
        // TTFT stamps at the real first token, never at chunk
        // completion. ---
        if !self.prefilling.is_empty() {
            let mut remaining =
                if self.chunk_tokens == 0 { usize::MAX } else { self.chunk_tokens };
            self.slot_buf.clear();
            self.cached_buf.clear();
            self.ends_buf.clear();
            for i in 0..self.prefilling.len() {
                let sid = self.prefilling[i];
                let st = self.sched.slot(sid);
                let need = st.request.prompt.len() - st.prefilled;
                if remaining == 0 && need > 0 {
                    break;
                }
                let take = need.min(remaining);
                remaining -= take;
                self.slot_buf.push(sid);
                self.cached_buf.push(st.prefilled);
                self.ends_buf.push(st.prefilled + take);
            }
            if !self.slot_buf.is_empty() {
                let t0 = self.clock.now();
                let prompts: Vec<&[usize]> = self
                    .slot_buf
                    .iter()
                    .zip(&self.ends_buf)
                    .map(|(&sid, &end)| &self.sched.slot(sid).request.prompt[..end])
                    .collect();
                let res = self.fwd.prefill(&self.slot_buf, &prompts, &self.cached_buf);
                drop(prompts);
                self.prefill_time += self.clock.now().saturating_duration_since(t0);
                let outcomes: Vec<Option<PrefillOutcome>> = match res {
                    Ok(o) if o.len() == self.slot_buf.len() => {
                        o.into_iter().map(Some).collect()
                    }
                    Ok(o) => {
                        self.sched.metrics.faults_contained += 1;
                        let msg = format!(
                            "prefill returned {} outcomes for {} slots",
                            o.len(),
                            self.slot_buf.len()
                        );
                        self.recover_prefill(&msg)
                    }
                    Err(e) => {
                        self.sched.metrics.faults_contained += 1;
                        self.recover_prefill(&format!("{e:#}"))
                    }
                };
                // stamp after the forward: TTFT includes prefill compute
                let t_first = self.clock.now();
                for (i, out) in outcomes.into_iter().enumerate() {
                    let Some(out) = out else { continue };
                    let sid = self.slot_buf[i];
                    let plen = self.sched.slot(sid).request.prompt.len();
                    // reconcile the savings meter with what this call
                    // actually computed: a start below the credited
                    // prefix means the overlap was recomputed (grid
                    // back-extension or monolithic fallback), so move
                    // that many tokens from "saved" back to "computed".
                    // Invariant on every path, asserted by the chunked
                    // prefill suite:
                    //   prefill_tokens + prefill_tokens_saved == Σ plen
                    let credit = self.sched.slot(sid).saved_credit;
                    if out.start < credit {
                        let reclaim = (credit - out.start) as u64;
                        self.sched.metrics.prefill_tokens += reclaim;
                        self.sched.metrics.prefill_tokens_saved =
                            self.sched.metrics.prefill_tokens_saved.saturating_sub(reclaim);
                        self.sched.slot_mut(sid).saved_credit = out.start;
                    }
                    if out.pos < plen {
                        // non-final chunk: KV advanced, logits discarded.
                        // A backend may stop short of the planned end
                        // (the artifact engine caps a chunk at its
                        // largest compiled length) — any forward
                        // progress is legal, zero progress is not (it
                        // would loop forever).
                        debug_assert!(out.pos > self.cached_buf[i], "prefill chunk made no progress");
                        self.sched.slot_mut(sid).prefilled = out.pos;
                        continue;
                    }
                    let done = {
                        let st = self.sched.slot_mut(sid);
                        st.prefilled = plen;
                        st.pos = out.pos;
                        let tok =
                            st.rng.sample_logits(&out.logits, st.request.params.temperature);
                        st.generated.push(tok);
                        st.cur = tok as i32;
                        st.ttft = Some(t_first.saturating_duration_since(st.enqueued));
                        st.first_token_step = Some(entry_step);
                        st.last_token_step = entry_step;
                        self.run_generated += 1;
                        st.request.params.stop_token == Some(tok)
                            || st.generated.len() >= st.request.params.max_new_tokens
                            || st.pos >= kv_cap
                    };
                    if done {
                        self.retire_finished(sid, t_first);
                    }
                }
                // completed slots now hold a first token; failed ones
                // were detached by fail_slot — both leave the list
                let sched = &self.sched;
                self.prefilling
                    .retain(|&sid| sched.occupied(sid) && sched.slot(sid).generated.is_empty());
            }
        }

        // --- one decode step over the live slots that have a first
        // token (mid-prefill slots hold KV but nothing to decode) ---
        self.sched.live_rows(&mut self.rows_buf);
        {
            let sched = &self.sched;
            self.rows_buf.retain(|&sid| !sched.slot(sid).generated.is_empty());
        }
        if self.rows_buf.is_empty() {
            return Ok(std::mem::take(&mut self.finished_buf));
        }
        let live = self.rows_buf.len();
        let bucket = self.sched.min_bucket(live);
        self.toks_buf.clear();
        self.pos_buf.clear();
        for &sid in &self.rows_buf {
            let st = self.sched.slot(sid);
            debug_assert!(st.pos < kv_cap, "live slot at KV capacity");
            self.toks_buf.push(st.cur);
            self.pos_buf.push(st.pos);
        }
        let t0 = self.clock.now();
        let res = self.fwd.decode(&self.rows_buf, &self.toks_buf, &self.pos_buf, bucket);
        match res {
            Ok(logits) if logits.len() == live => {
                self.decode_time += self.clock.now().saturating_duration_since(t0);
                self.run_decode_steps += 1;
                // stamp after the forward: latency includes the final decode
                let t_done = self.clock.now();
                for (i, row) in logits.iter().enumerate() {
                    let sid = self.rows_buf[i];
                    let (done, tier) = {
                        let st = self.sched.slot_mut(sid);
                        let tok = st.rng.sample_logits(row, st.request.params.temperature);
                        st.generated.push(tok);
                        st.cur = tok as i32;
                        st.pos += 1;
                        st.last_token_step = entry_step;
                        self.run_generated += 1;
                        let done = st.request.params.stop_token == Some(tok)
                            || st.generated.len() >= st.request.params.max_new_tokens
                            || st.pos >= kv_cap;
                        (done, st.request.tier)
                    };
                    // per-tier activation metering, decode-row
                    // denominated (each live row that decoded a token
                    // counts once at its operating point)
                    self.sched.metrics.record_tier_row(tier, self.tier_ratios.ratio(tier));
                    if done {
                        self.retire_finished(sid, t_done);
                    }
                }
                self.sched.record_step(bucket, live);
            }
            Ok(logits) => {
                self.sched.metrics.faults_contained += 1;
                let msg = format!("decode returned {} rows for {live} live", logits.len());
                self.recover_decode(kv_cap, &msg);
            }
            Err(e) => {
                self.sched.metrics.faults_contained += 1;
                self.recover_decode(kv_cap, &format!("{e:#}"));
            }
        }
        Ok(std::mem::take(&mut self.finished_buf))
    }

    /// Retire a done slot into `finished_buf`; a bookkeeping violation
    /// is contained, not propagated.
    fn retire_finished(&mut self, sid: usize, now: Instant) {
        match self.sched.retire(sid) {
            Ok(st) => {
                self.fwd.release(sid);
                self.finished_buf.push(finish(st, now));
            }
            Err(_) => self.sched.metrics.faults_contained += 1,
        }
    }

    /// Evict a live slot for a deadline-urgent higher class. In park
    /// mode the KV pages come along detached; otherwise (drop mode, or
    /// a backend that cannot park) the KV is released and resume will
    /// recompute it from the token history.
    fn preempt_slot(&mut self, sid: usize) {
        let Some(st) = self.sched.detach(sid) else {
            self.sched.metrics.faults_contained += 1;
            return;
        };
        // a mid-prefill victim leaves the chunk list with its state;
        // resume re-enters it at the front (its slot id may be reused
        // by a fresh admission before then)
        self.prefilling.retain(|&s| s != sid);
        self.sched.metrics.preemptions += 1;
        let kv = if self.preempt_mode == PreemptMode::Park { self.fwd.park(sid) } else { None };
        if kv.is_some() {
            self.sched.metrics.preempt_parked += 1;
        } else {
            self.fwd.release(sid);
            self.sched.metrics.preempt_dropped += 1;
        }
        self.preempted.push_back(Preempted { st, kv });
    }

    /// Resume the front preempted request into a free slot. `false`
    /// when there is nothing to resume or no slot (state is pushed
    /// back untouched). Parked KV reattaches; dropped KV is recomputed
    /// through the prefix cache from the request's own token history —
    /// either way the RNG stream and generated tokens continue exactly
    /// where preemption cut them.
    fn resume_one(&mut self) -> bool {
        let Some(Preempted { st, kv }) = self.preempted.pop_front() else { return false };
        let tier = st.request.tier;
        let sid = match self.sched.resume(st) {
            Ok(sid) => sid,
            Err(st) => {
                self.preempted.push_front(Preempted { st, kv });
                return false;
            }
        };
        // preemption preserves the tier: the resumed rows keep running
        // at the same operating point as before eviction
        self.fwd.set_slot_ratio(sid, self.tier_ratios.ratio(tier));
        // a victim evicted mid-prefill (no first token yet) re-enters
        // the chunk list at the front — it carries sunk work. Parked KV
        // keeps its partial extent and chunking continues at
        // `prefilled`; dropped KV restarts the prompt from zero, with
        // the lost progress metered as recompute.
        if self.sched.slot(sid).generated.is_empty() {
            match kv {
                Some(parked) => self.fwd.unpark(sid, parked),
                None => {
                    let st = self.sched.slot_mut(sid);
                    let lost = st.prefilled as u64;
                    st.prefilled = 0;
                    // the lost extent (mapped prefix included) is
                    // metered as preemption recompute here, so the
                    // savings meter must not also pay it back when the
                    // restarted prefill reports start = 0
                    st.saved_credit = 0;
                    self.sched.metrics.preempt_recompute_tokens += lost;
                }
            }
            self.prefilling.insert(0, sid);
            return true;
        }
        match kv {
            Some(parked) => self.fwd.unpark(sid, parked),
            None => {
                // authoritative context: prompt ++ all generated tokens
                // except the last (which is `cur`, the next decode
                // input — exactly the KV content at preemption)
                let ctx = {
                    let st = self.sched.slot(sid);
                    let mut ctx = st.request.prompt.clone();
                    ctx.extend_from_slice(&st.generated[..st.generated.len() - 1]);
                    debug_assert_eq!(ctx.len(), st.pos, "resume context length");
                    ctx
                };
                let cached = match self.fwd.map_prefix(sid, &ctx) {
                    Ok(m) => m.unwrap_or(0),
                    Err(_) => {
                        self.fwd.release(sid);
                        self.sched.metrics.faults_contained += 1;
                        0
                    }
                };
                self.sched.metrics.preempt_recompute_tokens += (ctx.len() - cached) as u64;
                match self.fwd.prefill(&[sid], &[ctx.as_slice()], &[cached]) {
                    Ok(o) if o.len() == 1 => {
                        // logits discarded: this position's token was
                        // already sampled before preemption
                        debug_assert_eq!(o[0].pos, ctx.len(), "resume prefill extent");
                    }
                    Ok(o) => {
                        let msg = format!("resume prefill returned {} outcomes", o.len());
                        self.fail_slot(sid, msg);
                    }
                    Err(e) => self.fail_slot(sid, format!("resume prefill: {e:#}")),
                }
            }
        }
        true
    }

    /// Retire a live slot with a typed error (fault containment): the
    /// slot and its KV are reclaimed, the request id and error go to
    /// [`ContinuousSession::take_failures`], the session keeps
    /// serving.
    fn fail_slot(&mut self, sid: usize, error: String) {
        let Some(st) = self.sched.detach(sid) else {
            self.sched.metrics.faults_contained += 1;
            return;
        };
        self.prefilling.retain(|&s| s != sid);
        self.fwd.release(sid);
        self.sched.metrics.failed += 1;
        if st.generated.is_empty() {
            // failed before its first token: no TTFT sample exists —
            // count it instead of letting a 0ms default skew the tail
            self.sched.metrics.no_first_token += 1;
        }
        self.failed_buf.push(RequestFailure { id: st.request.id, error });
    }

    /// A batched prefill failed: retry each admission in isolation so
    /// only individually-failing requests are lost. Slots are released
    /// first (the batch attempt may have partially written KV) and
    /// re-mapped through the prefix cache; prefix/hit gauges are not
    /// re-metered (the admission already counted them).
    fn recover_prefill(&mut self, batch_err: &str) -> Vec<Option<PrefillOutcome>> {
        let slots = self.slot_buf.clone();
        let mut out = Vec::with_capacity(slots.len());
        for &sid in &slots {
            self.fwd.release(sid);
            let (prompt, tier) = {
                let st = self.sched.slot(sid);
                (st.request.prompt.clone(), st.request.tier)
            };
            // the release above may have cleared backend slot state;
            // re-establish the occupant's tier before its prefill
            self.fwd.set_slot_ratio(sid, self.tier_ratios.ratio(tier));
            let cached = match self.fwd.map_prefix(sid, &prompt) {
                Ok(m) => m.unwrap_or(0),
                Err(_) => {
                    self.fwd.release(sid);
                    self.sched.metrics.faults_contained += 1;
                    0
                }
            };
            match self.fwd.prefill(&[sid], &[prompt.as_slice()], &[cached]) {
                Ok(mut o) if o.len() == 1 => out.push(Some(o.remove(0))),
                Ok(o) => {
                    let msg = format!(
                        "prefill (isolated after batch failure '{batch_err}') returned {} outcomes",
                        o.len()
                    );
                    self.fail_slot(sid, msg);
                    out.push(None);
                }
                Err(e) => {
                    self.fail_slot(sid, format!("prefill: {e:#} (batch failure: {batch_err})"));
                    out.push(None);
                }
            }
        }
        out
    }

    /// A batched decode failed: rebuild each live row's KV from its
    /// authoritative host-side token state (release → map_prefix →
    /// prefill, logits discarded) and decode it alone. Rows that fail
    /// in isolation retire with a typed error; the rest advance
    /// exactly one token, same as the batched step would have.
    fn recover_decode(&mut self, kv_cap: usize, batch_err: &str) {
        // step() bumped the counter on entry; the isolated replays
        // still belong to the step being recovered
        let cur_step = self.step_idx.saturating_sub(1);
        let rows = self.rows_buf.clone();
        for &sid in &rows {
            let (ctx, cur, pos, tier) = {
                let st = self.sched.slot(sid);
                let mut ctx = st.request.prompt.clone();
                ctx.extend_from_slice(&st.generated[..st.generated.len() - 1]);
                debug_assert_eq!(ctx.len(), st.pos, "recover context length");
                (ctx, st.cur, st.pos, st.request.tier)
            };
            self.fwd.release(sid);
            // same occupant, rebuilt slot: re-establish its tier so the
            // isolated replay runs at the ratio the batch step used
            self.fwd.set_slot_ratio(sid, self.tier_ratios.ratio(tier));
            let mut cached = match self.fwd.map_prefix(sid, &ctx) {
                Ok(m) => m.unwrap_or(0),
                Err(_) => {
                    self.fwd.release(sid);
                    self.sched.metrics.faults_contained += 1;
                    0
                }
            };
            // a backend may rebuild the KV in several partial prefills
            // (the artifact engine caps one call at its largest
            // compiled length); loop until the context is covered, and
            // treat zero progress as the row's failure
            let mut rebuilt = true;
            while cached < ctx.len() {
                match self.fwd.prefill(&[sid], &[ctx.as_slice()], &[cached]) {
                    Ok(o) if o.len() == 1 && o[0].pos > cached => cached = o[0].pos,
                    Ok(o) => {
                        let msg = format!(
                            "decode recovery prefill returned {} outcomes at pos {:?} (batch failure: {batch_err})",
                            o.len(),
                            o.first().map(|x| x.pos)
                        );
                        self.fail_slot(sid, msg);
                        rebuilt = false;
                        break;
                    }
                    Err(e) => {
                        self.fail_slot(
                            sid,
                            format!("decode recovery prefill: {e:#} (batch failure: {batch_err})"),
                        );
                        rebuilt = false;
                        break;
                    }
                }
            }
            if !rebuilt {
                continue;
            }
            let bucket = self.sched.min_bucket(1);
            match self.fwd.decode(&[sid], &[cur], &[pos], bucket) {
                Ok(logits) if logits.len() == 1 => {
                    self.run_decode_steps += 1;
                    let t_done = self.clock.now();
                    let (done, tier) = {
                        let st = self.sched.slot_mut(sid);
                        let tok =
                            st.rng.sample_logits(&logits[0], st.request.params.temperature);
                        st.generated.push(tok);
                        st.cur = tok as i32;
                        st.pos += 1;
                        st.last_token_step = cur_step;
                        self.run_generated += 1;
                        let done = st.request.params.stop_token == Some(tok)
                            || st.generated.len() >= st.request.params.max_new_tokens
                            || st.pos >= kv_cap;
                        (done, st.request.tier)
                    };
                    self.sched.metrics.record_tier_row(tier, self.tier_ratios.ratio(tier));
                    self.sched.record_step(bucket, 1);
                    if done {
                        self.retire_finished(sid, t_done);
                    }
                }
                Ok(logits) => {
                    let msg = format!(
                        "isolated decode returned {} rows (batch failure: {batch_err})",
                        logits.len()
                    );
                    self.fail_slot(sid, msg);
                }
                Err(e) => {
                    self.fail_slot(sid, format!("decode: {e:#} (batch failure: {batch_err})"));
                }
            }
        }
    }
}

/// Package a retired slot as a request result. Continuous-batching
/// TTFT is user-perceived (enqueue→first token); `queued` is the
/// enqueue→admission wait the scheduler controlled. A slot retired
/// before sampling anything keeps `ttft: None` — the old
/// `unwrap_or_default()` here recorded a dishonest 0ms sample for
/// exactly those requests and dragged the percentiles down.
fn finish(st: SlotState, now: Instant) -> RequestResult {
    RequestResult {
        id: st.request.id,
        tokens: st.generated,
        ttft: st.ttft,
        ttft_steps: st
            .first_token_step
            .map(|s| s.saturating_sub(st.enqueue_step) + 1),
        decode_span_steps: st
            .first_token_step
            .map_or(0, |f| st.last_token_step.saturating_sub(f)),
        latency: now.saturating_duration_since(st.enqueued),
        queued: st.admitted_at.saturating_duration_since(st.enqueued),
        queued_steps: st.queued_steps,
        priority: st.request.priority,
        tier: st.request.tier,
    }
}

// ---------------------------------------------------------------------------
// Deterministic stub model (tests, simulations, benches)
// ---------------------------------------------------------------------------

/// FNV-1a offset basis for the stub-model context hash. Mirror-drift
/// registered: `scripts/mirror_dynamic_k.py` must agree or `cmoe lint`
/// fails (see `lint::drift::REGISTRY`).
pub const FNV_OFFSET_BASIS: u64 = 0xcbf2_9ce4_8422_2325;
/// FNV-1a prime (mirror-drift registered).
pub const FNV_PRIME: u64 = 0x100_0000_01b3;

/// Deterministic logits for a context: hash the tokens, expand through
/// the repo Rng. A row depends only on its own context, never on batch
/// composition — the property that makes scheduler-order bugs visible
/// as token divergence.
pub fn stub_logits(ctx: &[usize], vocab: usize) -> Vec<f32> {
    let mut h: u64 = FNV_OFFSET_BASIS;
    for &t in ctx {
        h ^= t as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    let mut rng = Rng::new(h ^ vocab as u64);
    (0..vocab).map(|_| rng.f32()).collect()
}

/// [`stub_logits`] at an activation-ratio operating point. Full effort
/// (`ratio >= 1`, or anything non-finite) is *exactly* [`stub_logits`];
/// a reduced ratio computes logits from only the last
/// `ceil(ratio · len)` context tokens (never fewer than one). This is
/// the stub's model of a cheaper activation point: still a pure
/// function of the row's own context — so scheduling, preemption and
/// drop-mode replay stay token-invisible at any fixed ratio — but with
/// outputs that genuinely differ from full effort, so the tier tests
/// can tell the backend really ran the reduced operating point.
pub fn stub_logits_at(ctx: &[usize], vocab: usize, ratio: f32) -> Vec<f32> {
    if !(ratio < 1.0) || ctx.is_empty() {
        return stub_logits(ctx, vocab);
    }
    let w = ((ratio * ctx.len() as f32).ceil() as usize).clamp(1, ctx.len());
    stub_logits(&ctx[ctx.len() - w..], vocab)
}

/// Host-only [`StepForward`] over a real paged [`KvSlotPool`]: each
/// slot's "KV cache" is its token context, stored one token per KV
/// column (layers = heads = head_dim = 1, so a token column is its
/// `[k, v]` pair and the k-plane value *is* the token id). Decode
/// reconstructs the context **from the pages** before computing
/// logits, so any page-table bug — aliasing, stale data after
/// recycling, a broken copy-on-write, a parked table resumed onto the
/// wrong slot — shows up as token divergence in the scheduler suites,
/// not just as a bad gauge. Used by the scheduler/simulation tests and
/// the artifact-free serving benches; also a template for plugging
/// non-PJRT backends into the session.
///
/// With [`StubForward::with_prefix_cache`] the stub additionally runs
/// a [`PrefixCache`] in front of prefill: admission maps a prompt's
/// cached prefix pages and prefill writes only the suffix — the
/// host-only proof of the sharing path's token identity and
/// prefill-compute savings.
pub struct StubForward {
    vocab: usize,
    kv_cap: usize,
    kv: KvSlotPool,
    cache: Option<PrefixCache>,
    /// Release calls observed (tests assert slot hygiene).
    pub released: u64,
    /// Prompt tokens written by prefill (suffix only under prefix
    /// hits) — the stub's own compute meter, cross-checked against
    /// `SchedulerMetrics::prefill_tokens` (+
    /// `preempt_recompute_tokens` when drop-mode preemption ran).
    pub prefilled_tokens: u64,
    /// Per-slot activation ratio (effort tiers): logits run through
    /// [`stub_logits_at`] at this operating point. 1.0 (full effort)
    /// until the session says otherwise via
    /// [`StepForward::set_slot_ratio`]; a slot's ratio is overwritten
    /// at every (re)assignment, so stale values never leak across
    /// occupants.
    ratios: Vec<f32>,
}

/// Tokens per page of the stub's KV pool (small, so short test
/// prompts still span several pages).
pub const STUB_PAGE_LEN: usize = 4;

impl StubForward {
    pub fn new(pool: usize, vocab: usize, kv_cap: usize) -> StubForward {
        StubForward::build(pool, vocab, kv_cap, STUB_PAGE_LEN, false)
    }

    /// Stub with the prompt-prefix cache enabled at `page_len`.
    pub fn with_prefix_cache(
        pool: usize,
        vocab: usize,
        kv_cap: usize,
        page_len: usize,
    ) -> StubForward {
        StubForward::build(pool, vocab, kv_cap, page_len, true)
    }

    fn build(
        pool: usize,
        vocab: usize,
        kv_cap: usize,
        page_len: usize,
        prefix: bool,
    ) -> StubForward {
        StubForward {
            vocab,
            kv_cap,
            // unbounded page budget: the host stub's pressure/eviction
            // behavior is pinned by the dedicated pool/cache suites
            kv: KvSlotPool::new(pool, 1, 1, kv_cap, 1, page_len, None),
            cache: prefix.then(|| PrefixCache::new(page_len)),
            released: 0,
            prefilled_tokens: 0,
            ratios: vec![1.0; pool],
        }
    }

    /// The activation ratio a slot is currently serving at (tests).
    pub fn slot_ratio(&self, slot: usize) -> f32 {
        self.ratios[slot]
    }

    /// Live contexts currently held (slot hygiene checks).
    pub fn live_contexts(&self) -> usize {
        (0..self.kv.pool_size()).filter(|&s| self.kv.extent(s) > 0).count()
    }

    /// The paged KV pool (page-level assertions in tests).
    pub fn kv(&self) -> &KvSlotPool {
        &self.kv
    }

    /// Reconstruct a slot's token context `[0, n)` from its KV pages.
    fn read_ctx(&self, slot: usize, n: usize) -> Vec<usize> {
        let mut col = [0.0f32; 2];
        let mut ctx = Vec::with_capacity(n);
        for t in 0..n {
            self.kv.read_token(slot, t, &mut col);
            ctx.push(col[0] as usize);
        }
        ctx
    }
}

impl StepForward for StubForward {
    fn map_prefix(&mut self, slot: usize, prompt: &[usize]) -> Result<Option<usize>> {
        let Some(cache) = self.cache.as_mut() else { return Ok(None) };
        let (pages, tokens) = cache.lookup(prompt);
        // the last prompt position must still prefill (its logits seed
        // the first sample), so a fully-covered prompt maps everything
        // but re-runs one token — COW keeps the cached page intact
        let cached = tokens.min(prompt.len().saturating_sub(1));
        if pages.is_empty() || cached == 0 {
            return Ok(Some(0));
        }
        self.kv.map_shared(slot, &pages, tokens);
        Ok(Some(cached))
    }

    fn prefill(
        &mut self,
        slots: &[usize],
        prompts: &[&[usize]],
        cached: &[usize],
    ) -> Result<Vec<PrefillOutcome>> {
        let mut out = Vec::with_capacity(slots.len());
        for ((&sid, &p), &c) in slots.iter().zip(prompts).zip(cached) {
            anyhow::ensure!(
                if c == 0 { self.kv.extent(sid) == 0 } else { self.kv.extent(sid) <= p.len() },
                "stub: prefill into a live slot {sid}"
            );
            for (t, &tok) in p.iter().enumerate().skip(c) {
                self.kv.write_token(sid, t, &[tok as f32, 0.0]);
            }
            self.prefilled_tokens += (p.len() - c) as u64;
            // logits come from the page-reconstructed context: a wrong
            // prefix mapping diverges the token stream right here
            let ctx = self.read_ctx(sid, p.len());
            let logits = stub_logits_at(&ctx, self.vocab, self.ratios[sid]);
            // the stub computes exactly the uncached suffix, so its
            // start equals the cached length — never a reclaim
            out.push(PrefillOutcome { logits, pos: p.len(), start: c });
            if self.cache.is_some() {
                let full = p.len() / self.kv.page_len();
                let pages: Vec<usize> = self.kv.slot_pages(sid)[..full].to_vec();
                let key = &p[..full * self.kv.page_len()];
                if let Some(cache) = &mut self.cache {
                    cache.insert(key, &pages, self.kv.pages_mut());
                }
            }
        }
        Ok(out)
    }

    fn decode(
        &mut self,
        slots: &[usize],
        tokens: &[i32],
        pos: &[usize],
        bucket: usize,
    ) -> Result<Vec<Vec<f32>>> {
        anyhow::ensure!(slots.len() <= bucket, "stub: {} rows > bucket {bucket}", slots.len());
        let mut out = Vec::with_capacity(slots.len());
        for ((&sid, &tok), &p) in slots.iter().zip(tokens).zip(pos) {
            anyhow::ensure!(self.kv.extent(sid) == p, "stub: decode on a stale slot {sid}");
            self.kv.write_token(sid, p, &[tok as f32, 0.0]);
            let ctx = self.read_ctx(sid, p + 1);
            out.push(stub_logits_at(&ctx, self.vocab, self.ratios[sid]));
        }
        Ok(out)
    }

    fn release(&mut self, slot: usize) {
        self.kv.release(slot);
        self.released += 1;
    }

    fn park(&mut self, slot: usize) -> Option<ParkedSlot> {
        Some(self.kv.park(slot))
    }

    fn unpark(&mut self, slot: usize, parked: ParkedSlot) {
        self.kv.unpark(slot, parked);
    }

    fn drop_parked(&mut self, parked: ParkedSlot) {
        self.kv.drop_parked(parked);
    }

    fn kv_capacity(&self) -> usize {
        self.kv_cap
    }

    fn set_slot_ratio(&mut self, slot: usize, ratio: f32) {
        self.ratios[slot] = ratio;
    }

    fn page_metrics(&self) -> Option<PageMetrics> {
        Some(PageMetrics {
            page_len: self.kv.page_len(),
            pages_in_use: self.kv.pages().pages_in_use(),
            high_water_pages: self.kv.pages().high_water_pages,
            cow_copies: self.kv.pages().cow_copies,
            shared_maps: self.kv.shared_maps,
            cached_pages: self.cache.as_ref().map_or(0, |c| c.cached_pages()),
            evicted_pages: self.cache.as_ref().map_or(0, |c| c.evicted_pages),
        })
    }
}

/// Run-to-completion reference for one request against the stub model:
/// the same sampling rule as the engines, no scheduler involved. Since
/// batch rows are independent, this is exactly what any correct
/// scheduler must emit for the request — batched or not, preempted or
/// not.
pub fn stub_reference(r: &Request, vocab: usize, kv_cap: usize) -> Vec<usize> {
    stub_reference_tiered(r, vocab, kv_cap, TierRatios { full: 1.0, degraded: 1.0 })
}

/// [`stub_reference`] with effort tiers applied: the request runs at
/// `ratios.ratio(r.tier)` throughout ([`stub_logits_at`]), which is
/// exactly what a correct tier-aware session must emit for it — again
/// batched or not, preempted or not (the tier survives preemption).
/// With both ratios at 1 this *is* [`stub_reference`].
pub fn stub_reference_tiered(
    r: &Request,
    vocab: usize,
    kv_cap: usize,
    ratios: TierRatios,
) -> Vec<usize> {
    let ratio = ratios.ratio(r.tier);
    let mut rng = Rng::new(r.params.seed);
    let mut ctx = r.prompt.clone();
    let mut pos = ctx.len();
    let mut gen = Vec::new();
    let tok = rng.sample_logits(&stub_logits_at(&ctx, vocab, ratio), r.params.temperature);
    gen.push(tok);
    let mut cur = tok;
    let mut done = r.params.stop_token == Some(tok)
        || gen.len() >= r.params.max_new_tokens
        || pos >= kv_cap;
    while !done {
        ctx.push(cur);
        let tok = rng.sample_logits(&stub_logits_at(&ctx, vocab, ratio), r.params.temperature);
        gen.push(tok);
        cur = tok;
        pos += 1;
        done = r.params.stop_token == Some(tok)
            || gen.len() >= r.params.max_new_tokens
            || pos >= kv_cap;
    }
    gen
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::request::GenParams;

    fn req(id: u64, max_new: usize) -> Request {
        Request::new(
            id,
            vec![1, 2, 3],
            GenParams { max_new_tokens: max_new, temperature: 0.0, seed: id, stop_token: None },
        )
    }

    fn cfg(buckets: Vec<usize>) -> BatcherConfig {
        BatcherConfig { buckets, max_wait: Duration::ZERO, ..Default::default() }
    }

    #[test]
    fn pool_and_bucket_shape() {
        let s = Scheduler::new(&[8, 1, 32, 8]).unwrap();
        assert_eq!(s.pool_size(), 32);
        assert_eq!(s.buckets(), &[1, 8, 32]);
        assert_eq!(s.min_bucket(1), 1);
        assert_eq!(s.min_bucket(2), 8);
        assert_eq!(s.min_bucket(8), 8);
        assert_eq!(s.min_bucket(9), 32);
        assert_eq!(s.min_bucket(32), 32);
    }

    #[test]
    fn bad_bucket_configs_are_typed_errors_not_panics() {
        assert_eq!(Scheduler::new(&[]).err(), Some(ConfigError::NoBuckets));
        assert_eq!(Scheduler::new(&[4, 0]).err(), Some(ConfigError::ZeroBucket));
        let sess_err = ContinuousSession::new(cfg(vec![]), StubForward::new(1, 7, 16)).err();
        assert_eq!(sess_err, Some(ConfigError::NoBuckets));
    }

    #[test]
    fn retired_slots_recycle_first() {
        let mut s = Scheduler::new(&[4]).unwrap();
        let now = Instant::now();
        let a = s.assign(req(0, 4), now, 0, now, 0).unwrap();
        let b = s.assign(req(1, 4), now, 0, now, 0).unwrap();
        assert_eq!((a, b), (0, 1));
        s.retire(a).unwrap();
        // the just-retired slot 0 is taken before fresh slot 2
        let c = s.assign(req(2, 4), now, 0, now, 0).unwrap();
        assert_eq!(c, 0);
        assert_eq!(s.metrics.slot_reuses, 1);
        assert_eq!(s.live(), 2);
        assert_eq!(s.free_count() + s.live(), s.pool_size());
    }

    #[test]
    fn pool_full_and_double_retire_are_recoverable_errors() {
        let mut s = Scheduler::new(&[1]).unwrap();
        let now = Instant::now();
        let a = s.assign(req(0, 4), now, 0, now, 0).unwrap();
        assert_eq!(s.assign(req(1, 4), now, 0, now, 0).err(), Some(SchedError::PoolFull));
        s.retire(a).unwrap();
        assert_eq!(s.retire(a).err(), Some(SchedError::EmptySlot(a)));
        // the pool is still usable after both error paths
        assert!(s.assign(req(2, 4), now, 0, now, 0).is_ok());
    }

    #[test]
    fn victim_is_youngest_of_lowest_class() {
        let mut s = Scheduler::new(&[4]).unwrap();
        let now = Instant::now();
        let high = s.assign(req(0, 4).with_priority(Priority::High), now, 0, now, 0).unwrap();
        let norm = s.assign(req(1, 4).with_priority(Priority::Normal), now, 0, now, 0).unwrap();
        let low_old = s.assign(req(2, 4).with_priority(Priority::Low), now, 0, now, 0).unwrap();
        let low_new = s.assign(req(3, 4).with_priority(Priority::Low), now, 0, now, 0).unwrap();
        // lowest class first, youngest admission within it
        assert_eq!(s.pick_victim(Priority::High), Some(low_new));
        s.retire(low_new).unwrap();
        assert_eq!(s.pick_victim(Priority::High), Some(low_old));
        s.retire(low_old).unwrap();
        assert_eq!(s.pick_victim(Priority::High), Some(norm));
        // nothing strictly below Low; High cannot victimize High
        assert_eq!(s.pick_victim(Priority::Low), None);
        s.retire(norm).unwrap();
        assert_eq!(s.pick_victim(Priority::High), None);
        let _ = high;
    }

    #[test]
    fn session_runs_queue_to_completion() {
        let mut sess =
            ContinuousSession::new(cfg(vec![1, 4]), StubForward::new(4, 11, usize::MAX)).unwrap();
        for i in 0..6 {
            assert!(sess.enqueue(req(i, 3 + i as usize % 3)).is_queued());
        }
        let results = sess.drain().unwrap();
        assert_eq!(results.len(), 6);
        for r in &results {
            assert_eq!(r.tokens, stub_reference(&req(r.id, 3 + r.id as usize % 3), 11, usize::MAX));
        }
        assert!(sess.is_idle());
        assert_eq!(sess.forward().live_contexts(), 0, "every slot released");
        let m = sess.take_metrics();
        assert_eq!(m.admitted, 6);
        assert_eq!(m.retired, 6);
        assert!(m.slot_reuses >= 2, "6 requests through a 4-slot pool must recycle");
        let w = sess.take_run_summary().unwrap();
        assert_eq!(w.generated_tokens, results.iter().map(|r| r.tokens.len()).sum::<usize>());
    }

    #[test]
    fn kv_capacity_truncates() {
        // prompt len 3, cap 5 → prefill at pos 3, two decode steps
        let mut sess = ContinuousSession::new(cfg(vec![1]), StubForward::new(1, 7, 5)).unwrap();
        sess.enqueue(req(0, 100));
        let results = sess.drain().unwrap();
        assert_eq!(results[0].tokens.len(), 3, "1 prefill + (cap-prompt) decode tokens");
        assert_eq!(results[0].tokens, stub_reference(&req(0, 100), 7, 5));
    }

    #[test]
    fn abort_clears_everything() {
        let mut sess =
            ContinuousSession::new(cfg(vec![2]), StubForward::new(2, 7, usize::MAX)).unwrap();
        for i in 0..5 {
            sess.enqueue(req(i, 50));
        }
        sess.step().unwrap(); // two live, three queued
        assert_eq!(sess.live(), 2);
        let mut ids = sess.abort_all();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
        assert!(sess.is_idle());
        assert_eq!(sess.forward().live_contexts(), 0);
    }

    #[test]
    fn abort_drops_parked_kv() {
        let mut c = cfg(vec![2]);
        c.preempt = PreemptMode::Park;
        let mut sess = ContinuousSession::new(c, StubForward::new(2, 13, usize::MAX)).unwrap();
        sess.enqueue(req(0, 40).with_priority(Priority::Low));
        sess.enqueue(req(1, 40).with_priority(Priority::Low));
        sess.step().unwrap();
        sess.enqueue(req(2, 40).with_priority(Priority::High).with_deadline_steps(0));
        sess.step().unwrap();
        assert_eq!(sess.preempted_pending(), 1, "High's arrival must park a Low");
        let mut ids = sess.abort_all();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2]);
        assert!(sess.is_idle());
        assert_eq!(sess.forward().live_contexts(), 0);
        assert_eq!(sess.forward().kv().pages().pages_in_use(), 0, "parked pages reclaimed");
    }

    #[test]
    fn park_preemption_is_token_invisible() {
        let mut c = cfg(vec![2]);
        c.preempt = PreemptMode::Park;
        let mut sess = ContinuousSession::new(c, StubForward::new(2, 17, usize::MAX)).unwrap();
        let low = |id: u64| req(id, 12).with_priority(Priority::Low);
        let high = req(2, 4).with_priority(Priority::High).with_deadline_steps(0);
        sess.enqueue(low(0));
        sess.enqueue(low(1));
        sess.step().unwrap();
        sess.step().unwrap(); // both Lows mid-decode
        sess.enqueue(high.clone());
        let mut results = sess.step().unwrap(); // urgent High evicts the youngest Low
        assert_eq!(sess.preempted_pending() + sess.live(), 3 - results.len());
        results.extend(sess.drain().unwrap());
        results.sort_by_key(|r| r.id);
        assert_eq!(results.len(), 3);
        assert_eq!(results[0].tokens, stub_reference(&low(0), 17, usize::MAX));
        assert_eq!(results[1].tokens, stub_reference(&low(1), 17, usize::MAX));
        assert_eq!(results[2].tokens, stub_reference(&high, 17, usize::MAX));
        assert_eq!(results[2].priority, Priority::High);
        let m = sess.take_metrics();
        assert_eq!((m.preemptions, m.preempt_parked, m.preempt_dropped), (1, 1, 0));
        assert_eq!(m.resumed, 1);
        assert_eq!((m.failed, m.faults_contained), (0, 0));
        assert_eq!(m.preempt_recompute_tokens, 0, "parked KV never recomputes");
        assert_eq!(sess.forward().live_contexts(), 0);
        assert_eq!(sess.forward().kv().pages().pages_in_use(), 0);
    }

    #[test]
    fn drop_preemption_recomputes_and_matches() {
        let mut c = cfg(vec![2]);
        c.preempt = PreemptMode::Drop;
        let mut sess = ContinuousSession::new(c, StubForward::new(2, 17, usize::MAX)).unwrap();
        let low = |id: u64| req(id, 12).with_priority(Priority::Low);
        let high = req(2, 4).with_priority(Priority::High).with_deadline_steps(0);
        sess.enqueue(low(0));
        sess.enqueue(low(1));
        sess.step().unwrap();
        sess.step().unwrap();
        sess.enqueue(high.clone());
        let mut results = sess.step().unwrap();
        results.extend(sess.drain().unwrap());
        results.sort_by_key(|r| r.id);
        assert_eq!(results.len(), 3);
        for r in &results {
            let want = if r.id == 2 { high.clone() } else { low(r.id) };
            assert_eq!(r.tokens, stub_reference(&want, 17, usize::MAX), "request {}", r.id);
        }
        let m = sess.take_metrics();
        assert_eq!((m.preemptions, m.preempt_parked, m.preempt_dropped), (1, 0, 1));
        assert_eq!(m.resumed, 1);
        assert!(m.preempt_recompute_tokens > 0, "dropped KV must recompute");
        // the stub's own write meter covers prefill + recompute exactly
        assert_eq!(
            sess.forward().prefilled_tokens,
            m.prefill_tokens + m.preempt_recompute_tokens
        );
        assert_eq!(sess.forward().live_contexts(), 0);
    }

    #[test]
    fn bounded_queue_degrades_then_sheds_through_the_session() {
        let mut c = cfg(vec![1]);
        c.queue_cap = Some(1);
        c.degrade_margin = 1;
        let mut sess = ContinuousSession::new(c, StubForward::new(1, 7, usize::MAX)).unwrap();
        assert_eq!(sess.enqueue(req(0, 2)), SubmitOutcome::Queued);
        assert_eq!(sess.enqueue(req(1, 2)), SubmitOutcome::QueuedDegraded);
        let SubmitOutcome::Rejected(shed) = sess.enqueue(req(2, 2)) else {
            panic!("third push must shed");
        };
        assert_eq!(shed.priority, Priority::Normal);
        let results = sess.drain().unwrap();
        assert_eq!(results.len(), 2, "the shed request produces no result");
        let m = sess.take_metrics();
        assert_eq!((m.degraded_admissions, m.shed_requests), (1, 1));
    }

    #[test]
    fn page_metric_flushes_are_deltas_not_lifetime_totals() {
        // the threaded server flushes one long-lived session at every
        // idle; event counters must arrive as deltas or the engine
        // gauges double-count
        let mut sess =
            ContinuousSession::new(cfg(vec![1, 2]), StubForward::with_prefix_cache(2, 11, 64, 4))
                .unwrap();
        let mk = |id: u64| {
            Request::new(
                id,
                vec![1, 2, 3, 4, 5, 6, 7, 8, 9],
                GenParams { max_new_tokens: 2, temperature: 0.0, seed: id, stop_token: None },
            )
        };
        for i in 0..4 {
            sess.enqueue(mk(i));
        }
        sess.drain().unwrap();
        let a = sess.take_page_metrics().unwrap();
        assert_eq!(a.shared_maps, 2, "second admission pair must map the cached prefix");
        for i in 4..6 {
            sess.enqueue(mk(i));
        }
        sess.drain().unwrap();
        let b = sess.take_page_metrics().unwrap();
        assert_eq!(b.shared_maps, 2, "flush must report the delta, not lifetime totals");
        assert!(b.high_water_pages >= a.high_water_pages, "high water is monotone");
        let c = sess.take_page_metrics().unwrap();
        assert_eq!(
            (c.shared_maps, c.cow_copies, c.evicted_pages),
            (0, 0, 0),
            "an idle re-flush reports no new events"
        );
    }

    #[test]
    fn chunked_prefill_interleaves_decodes_and_keeps_tokens_identical() {
        let long = Request::new(
            1,
            (1..=24).map(|t| t % 7).collect(),
            GenParams { max_new_tokens: 4, temperature: 0.0, seed: 9, stop_token: None },
        );
        let short = req(0, 6);
        let run = |chunk: usize| {
            let mut c = cfg(vec![1, 2]);
            c.prefill_chunk_tokens = chunk;
            let mut sess =
                ContinuousSession::new(c, StubForward::new(2, 17, usize::MAX)).unwrap();
            sess.enqueue(short.clone());
            sess.enqueue(long.clone());
            let mut out = sess.drain().unwrap();
            out.sort_by_key(|r| r.id);
            let m = sess.take_metrics();
            assert_eq!(sess.forward().live_contexts(), 0);
            (out, m)
        };
        let (chunked, m) = run(8);
        let (mono, m_mono) = run(0);
        for (a, b) in chunked.iter().zip(&mono) {
            assert_eq!(a.tokens, b.tokens, "chunking must be token-invisible");
        }
        assert_eq!(chunked[0].tokens, stub_reference(&short, 17, usize::MAX));
        assert_eq!(chunked[1].tokens, stub_reference(&long, 17, usize::MAX));
        // total prefill work is the same; only its step placement moved
        assert_eq!(m.prefill_tokens, m_mono.prefill_tokens);
        // short admits at step 0 and first-tokens immediately; the
        // 24-token prompt spends 8 tokens/step: 5 at step 0 (short's 3
        // took budget), 8+8 at steps 1-2, final 3 at step 3
        assert_eq!(chunked[0].ttft_steps, Some(1));
        assert_eq!(chunked[1].ttft_steps, Some(4));
        assert_eq!(mono[1].ttft_steps, Some(1), "monolithic prefill finishes in one step");
        // the short request decoded while the long prompt was still
        // prefilling: its 6 tokens span steps 0..4 untouched
        assert_eq!(chunked[0].decode_span_steps, 4);
        assert_eq!(m.no_first_token, 0);
    }

    #[test]
    fn chunked_prefill_ttft_stamps_at_first_token_not_chunk_completion() {
        let r = Request::new(
            0,
            (0..10).collect(),
            GenParams { max_new_tokens: 3, temperature: 0.0, seed: 5, stop_token: None },
        );
        let mut c = cfg(vec![1]);
        c.prefill_chunk_tokens = 4;
        let mut sess = ContinuousSession::new(c, StubForward::new(1, 11, usize::MAX)).unwrap();
        sess.enqueue(r.clone());
        // chunks [0,4) and [4,8) complete without sampling anything
        assert!(sess.step().unwrap().is_empty());
        assert!(sess.step().unwrap().is_empty());
        assert_eq!(sess.live(), 1);
        assert_eq!(sess.metrics().decode_steps, 0, "nothing decodable during chunking");
        let out = sess.drain().unwrap();
        assert_eq!(out[0].tokens, stub_reference(&r, 11, usize::MAX));
        // first token sampled at step 2 (the final [8,10) chunk), not
        // at either earlier chunk completion
        assert_eq!(out[0].ttft_steps, Some(3));
    }

    #[test]
    fn mid_prefill_preemption_resumes_without_leaks_in_both_modes() {
        for mode in [PreemptMode::Park, PreemptMode::Drop] {
            let short_low = Request::new(
                0,
                vec![1, 2, 3],
                GenParams { max_new_tokens: 8, temperature: 0.0, seed: 0, stop_token: None },
            )
            .with_priority(Priority::Low);
            let long_low = Request::new(
                1,
                (1..=16).map(|t| t % 5).collect(),
                GenParams { max_new_tokens: 3, temperature: 0.0, seed: 1, stop_token: None },
            )
            .with_priority(Priority::Low);
            let high = req(2, 2).with_priority(Priority::High).with_deadline_steps(0);
            let mut c = cfg(vec![1, 2]);
            c.preempt = mode;
            c.prefill_chunk_tokens = 4;
            let mut sess =
                ContinuousSession::new(c, StubForward::new(2, 17, usize::MAX)).unwrap();
            sess.enqueue(short_low.clone());
            sess.enqueue(long_low.clone());
            sess.step().unwrap(); // long is mid-prefill (short took 3 of the 4-token budget)
            sess.enqueue(high.clone());
            let mut results = sess.step().unwrap(); // urgent High evicts mid-prefill long
            results.extend(sess.drain().unwrap());
            results.sort_by_key(|r| r.id);
            assert_eq!(results.len(), 3, "{mode:?}");
            assert_eq!(results[0].tokens, stub_reference(&short_low, 17, usize::MAX));
            assert_eq!(results[1].tokens, stub_reference(&long_low, 17, usize::MAX));
            assert_eq!(results[2].tokens, stub_reference(&high, 17, usize::MAX));
            let m = sess.take_metrics();
            assert_eq!(m.preemptions, 1, "{mode:?}");
            assert_eq!(m.resumed, 1, "{mode:?}");
            assert_eq!(m.no_first_token, 0, "{mode:?}");
            match mode {
                PreemptMode::Park => {
                    assert_eq!(m.preempt_recompute_tokens, 0, "parked chunks never recompute")
                }
                _ => {
                    assert!(m.preempt_recompute_tokens > 0, "dropped chunks must recompute");
                    assert_eq!(
                        sess.forward().prefilled_tokens,
                        m.prefill_tokens + m.preempt_recompute_tokens,
                        "write meter covers prefill + mid-prefill recompute exactly"
                    );
                }
            }
            assert_eq!(sess.forward().live_contexts(), 0, "{mode:?}");
            assert_eq!(sess.forward().kv().pages().pages_in_use(), 0, "no leaked pages");
        }
    }

    #[test]
    fn finish_without_first_token_reports_none_not_zero() {
        let now = Instant::now();
        let st = SlotState {
            request: req(7, 4),
            enqueued: now,
            admitted_at: now,
            queued_steps: 2,
            admit_seq: 0,
            rng: Rng::new(0),
            generated: Vec::new(),
            cur: 0,
            pos: 0,
            ttft: None,
            prefilled: 3,
            saved_credit: 0,
            enqueue_step: 0,
            first_token_step: None,
            last_token_step: 0,
        };
        let r = finish(st, now);
        assert_eq!(r.ttft, None, "no first token → no TTFT sample, not 0ms");
        assert_eq!(r.ttft_steps, None);
        assert_eq!(r.decode_span_steps, 0);
        assert!(r.tokens.is_empty());
    }

    #[test]
    fn stub_logits_depend_only_on_context() {
        let a = stub_logits(&[1, 2, 3], 13);
        let b = stub_logits(&[1, 2, 3], 13);
        let c = stub_logits(&[1, 2, 4], 13);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_eq!(a.len(), 13);
    }
}
