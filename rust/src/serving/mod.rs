//! The serving engine: continuous batching over AOT-compiled decode
//! steps, with three execution modes —
//!
//! * **Dense** — the monolithic `decode_dense_*` artifact (baseline).
//! * **MoeMonolithic** — one `decode_moe_*` call per step with in-graph
//!   masked routing (all experts computed; the 1-call eval path).
//! * **MoeOrchestrated** — the paper's serving contribution realized:
//!   attention via artifacts, routing coordinated in rust, and routed
//!   experts executed by **grouped dispatch** — tokens gathered into
//!   contiguous per-expert blocks, one SwiGLU GEMM per expert per
//!   layer, results scattered back, all through a reusable per-engine
//!   scratch arena so the steady-state decode loop performs no per-wave
//!   buffer allocations. FLOPs are actually skipped for deactivated
//!   experts, and the load-balancing bias adapts online (§4.3). The
//!   legacy capacity-factor device schedule remains available via
//!   [`ExpertExec::DeviceCapacity`].
//!
//! Scheduling is wave-based continuous batching: requests queue, the
//! batcher forms the largest bucket-sized wave available, the wave
//! prefills together and decodes until every member finishes; finished
//! slots are masked out. Python is never on this path.
//!
//! The grouped-dispatch data layout and determinism guarantees are
//! documented in [`dispatch`]'s module docs and, end to end, in
//! `docs/ARCHITECTURE.md` at the repo root.

mod request;
mod batcher;
mod engine;
pub mod dispatch;
mod metrics;
mod server;

pub use batcher::{Batcher, BatcherConfig};
pub use dispatch::{per_token_reference, DispatchArena, ExpertDispatcher, GroupedDispatcher};
pub use engine::{Engine, EngineConfig, ExecMode, ExpertExec};
pub use metrics::{DispatchMetrics, EngineMetrics, WaveMetrics};
pub use request::{GenParams, Request, RequestResult};
pub use server::{EngineServer, Ticket};
