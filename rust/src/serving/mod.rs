//! The serving engine: **continuous in-flight batching** over
//! AOT-compiled decode steps, with three execution modes —
//!
//! * **Dense** — the monolithic `decode_dense_*` artifact (baseline).
//! * **MoeMonolithic** — one `decode_moe_*` call per step with in-graph
//!   masked routing (all experts computed; the 1-call eval path).
//! * **MoeOrchestrated** — the paper's serving contribution realized:
//!   attention via artifacts, routing coordinated in rust, and routed
//!   experts executed by **grouped dispatch** — tokens gathered into
//!   contiguous per-expert blocks, one SwiGLU GEMM per expert per
//!   layer, results scattered back, all through a reusable per-engine
//!   scratch arena so the steady-state decode loop performs no per-wave
//!   buffer allocations. FLOPs are actually skipped for deactivated
//!   experts, and the load-balancing bias adapts online (§4.3). The
//!   legacy capacity-factor device schedule remains available via
//!   [`ExpertExec::DeviceCapacity`].
//!
//! Scheduling is per-step continuous batching ([`scheduler`]): the
//! engine owns a fixed pool of KV slots sized to the largest compiled
//! batch bucket; every decode step it admits queued requests into free
//! slots (FIFO), retires requests the step they hit their stop token /
//! `max_new_tokens` / KV capacity, and runs the step at the smallest
//! compiled bucket covering the live slots — so finished requests
//! never pad a GEMM and queued requests never wait for a wave
//! boundary. Per-request token streams are bit-identical to the
//! run-to-completion wave path ([`Engine::run_queue_waves`], kept as
//! the benchmark baseline and correctness oracle). Python is never on
//! this path.
//!
//! The grouped-dispatch data layout and determinism guarantees are
//! documented in [`dispatch`]'s module docs; the slot lifecycle and
//! continuous-batching invariants in [`scheduler`]'s — and, end to
//! end, in `docs/ARCHITECTURE.md` at the repo root.

mod request;
mod batcher;
mod clock;
mod engine;
pub mod dispatch;
pub mod fault;
mod metrics;
pub mod prefix_cache;
pub mod scheduler;
mod server;

pub use batcher::{
    covering_bucket, Batcher, BatcherConfig, ConfigError, PreemptMode, ShedLoad, SubmitOutcome,
    DEFAULT_PREFILL_CHUNK_TOKENS,
};
pub use clock::Clock;
pub use dispatch::{per_token_reference, DispatchArena, ExpertDispatcher, GroupedDispatcher};
pub use engine::{
    Engine, EngineConfig, EngineStepForward, ExecMode, ExpertExec, CONT_GRID_STEP, DEFAULT_PAGE_LEN,
};
pub use fault::FaultInjectingForward;
pub use metrics::{
    DispatchMetrics, EngineMetrics, PageMetrics, ResidencyMetrics, SchedulerMetrics, WaveMetrics,
};
pub use prefix_cache::PrefixCache;
pub use request::{
    EffortTier, GenParams, Priority, Request, RequestFailure, RequestResult, TierRatios,
};
pub use scheduler::{
    stub_logits, stub_logits_at, stub_reference, stub_reference_tiered, ContinuousSession,
    PrefillOutcome, SchedError, Scheduler, SlotState, StepForward, StubForward, STUB_PAGE_LEN,
};
pub use server::{EngineServer, ServeError, Ticket};
