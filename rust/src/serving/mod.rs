//! The serving engine: continuous batching over AOT-compiled decode
//! steps, with three execution modes —
//!
//! * **Dense** — the monolithic `decode_dense_*` artifact (baseline).
//! * **MoeMonolithic** — one `decode_moe_*` call per step with in-graph
//!   masked routing (all experts computed; the 1-call eval path).
//! * **MoeOrchestrated** — the paper's serving contribution realized:
//!   attention via artifacts, routing + capacity-factor expert dispatch
//!   coordinated in rust, experts executed by the grouped Pallas
//!   artifact — FLOPs actually skipped for deactivated experts, and
//!   load-balancing bias adapted online (§4.3).
//!
//! Scheduling is wave-based continuous batching: requests queue, the
//! batcher forms the largest bucket-sized wave available, the wave
//! prefills together and decodes until every member finishes; finished
//! slots are masked out. Python is never on this path.

mod request;
mod batcher;
mod engine;
mod dispatch;
mod metrics;
mod server;

pub use batcher::{Batcher, BatcherConfig};
pub use dispatch::ExpertDispatcher;
pub use engine::{Engine, EngineConfig, ExecMode};
pub use metrics::{EngineMetrics, WaveMetrics};
pub use request::{GenParams, Request, RequestResult};
pub use server::{EngineServer, Ticket};
