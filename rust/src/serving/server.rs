//! Threaded serving front-end: asynchronous request submission over
//! channels with a dedicated engine thread stepping the continuous-
//! batching scheduler (tokio is unavailable offline; std::thread +
//! mpsc is the substrate — see docs/ARCHITECTURE.md, "Build &
//! verification").
//!
//! The serve loop interleaves channel ingestion with scheduler steps:
//! arrivals drained between steps are admitted into free KV slots at
//! the *next* step — requests join a running batch mid-flight instead
//! of waiting for the current batch to finish (the head-of-line
//! pathology of the old wave loop).
//!
//! Overload and faults surface per ticket as typed [`ServeError`]s:
//! a bounded-queue rejection fails the ticket immediately with
//! [`ServeError::ShedLoad`] (the queue never grows without bound), a
//! contained per-request fault fails only that ticket with
//! [`ServeError::Request`], and only an unrecoverable engine error —
//! which aborts everything in flight — reports
//! [`ServeError::Engine`].
//!
//! The PJRT wrapper types are `Rc`-based (not `Send`), so the server
//! thread owns the *entire* runtime: `start` takes the artifact
//! directory and builds the `XlaRuntime` + `Engine` inside the thread.
//!
//! ```no_run
//! # use cmoe::serving::*;
//! let cfg = cmoe::model::model_config("tiny").unwrap();
//! let mut rng = cmoe::util::Rng::new(0);
//! let model = cmoe::model::ModelWeights::random(&cfg, &mut rng);
//! let server =
//!     EngineServer::start("artifacts", model, EngineConfig::dense("tiny", 64)).unwrap();
//! let ticket = server.submit(Request::new(0, vec![1, 2, 3], GenParams::default()));
//! match ticket.wait_typed() {
//!     Ok(result) => println!("{} tokens", result.tokens.len()),
//!     Err(ServeError::ShedLoad(s)) => eprintln!("overloaded, retry later: {s}"),
//!     Err(e) => eprintln!("request failed: {e}"),
//! }
//! server.shutdown();
//! ```

use crate::model::ModelWeights;
use crate::runtime::XlaRuntime;
use crate::serving::batcher::{ShedLoad, SubmitOutcome};
use crate::serving::engine::{Engine, EngineConfig};
use crate::serving::request::{Request, RequestResult};
use anyhow::Result;
use std::collections::BTreeMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Why a submitted request produced no result — typed so callers can
/// distinguish "back off and retry" from "this request is bad" from
/// "the engine is down".
#[derive(Clone, Debug)]
pub enum ServeError {
    /// Bounded admission shed the request before queueing it; the
    /// payload says which class queue was full and how deep it was.
    /// Retryable after backoff.
    ShedLoad(ShedLoad),
    /// This request alone failed (contained fault) — the engine kept
    /// serving everything else.
    Request(String),
    /// The engine failed unrecoverably (or its thread is gone); all
    /// in-flight requests were aborted.
    Engine(String),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::ShedLoad(s) => write!(f, "shed: {s}"),
            ServeError::Request(e) => write!(f, "request failed: {e}"),
            ServeError::Engine(e) => write!(f, "engine failed: {e}"),
        }
    }
}

impl std::error::Error for ServeError {}

enum Msg {
    Submit(Request, Sender<Result<RequestResult, ServeError>>),
    Shutdown,
}

/// A pending result handle.
pub struct Ticket {
    rx: Receiver<Result<RequestResult, ServeError>>,
}

impl Ticket {
    /// Block until the request completes, with the typed outcome.
    pub fn wait_typed(self) -> Result<RequestResult, ServeError> {
        self.rx
            .recv()
            .unwrap_or_else(|_| {
                Err(ServeError::Engine("engine thread dropped the request".into()))
            })
    }

    /// Block until the request completes (anyhow convenience; the
    /// typed outcome is [`Ticket::wait_typed`]).
    pub fn wait(self) -> Result<RequestResult> {
        self.wait_typed().map_err(anyhow::Error::new)
    }

    /// Non-blocking poll.
    pub fn try_wait(&self) -> Option<Result<RequestResult>> {
        match self.rx.try_recv() {
            Ok(r) => Some(r.map_err(anyhow::Error::new)),
            Err(_) => None,
        }
    }
}

/// The engine thread handle. `Sync`: multiple threads may `submit`
/// concurrently (the sender sits behind a mutex — mpsc senders are
/// `Send` but not `Sync`).
pub struct EngineServer {
    tx: std::sync::Mutex<Sender<Msg>>,
    handle: Option<JoinHandle<()>>,
}

impl EngineServer {
    /// Spawn the engine thread, constructing the PJRT runtime + engine
    /// inside it (runtime handles are not `Send`). Returns once the
    /// engine is ready; compilation still happens lazily per artifact.
    pub fn start(
        artifact_dir: impl Into<std::path::PathBuf>,
        model: ModelWeights,
        cfg: EngineConfig,
    ) -> Result<Self> {
        let dir = artifact_dir.into();
        let (tx, rx) = channel::<Msg>();
        let (ready_tx, ready_rx) = channel::<Result<(), String>>();
        let handle = std::thread::Builder::new()
            .name("cmoe-engine".into())
            .spawn(move || {
                let engine = match XlaRuntime::load(&dir)
                    .and_then(|rt| Engine::new(Arc::new(rt), model, cfg))
                {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(format!("{e:#}")));
                        return;
                    }
                };
                serve_loop(engine, rx)
            })
            .map_err(|e| anyhow::anyhow!("spawn engine thread: {e}"))?;
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("engine thread died during startup"))?
            .map_err(anyhow::Error::msg)?;
        Ok(EngineServer { tx: std::sync::Mutex::new(tx), handle: Some(handle) })
    }

    /// Enqueue a request; returns a ticket to wait on. Under overload
    /// the ticket fails fast with [`ServeError::ShedLoad`] instead of
    /// queueing without bound.
    pub fn submit(&self, r: Request) -> Ticket {
        let (tx, rx) = channel();
        // if the engine is gone the ticket errors on wait()
        let _ = crate::util::lock_unpoisoned(&self.tx).send(Msg::Submit(r, tx));
        Ticket { rx }
    }

    /// Stop the engine after draining queued requests.
    pub fn shutdown(mut self) {
        let _ = crate::util::lock_unpoisoned(&self.tx).send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for EngineServer {
    fn drop(&mut self) {
        let _ = crate::util::lock_unpoisoned(&self.tx).send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn serve_loop(engine: Engine, rx: Receiver<Msg>) {
    let mut session = engine.continuous_session();
    let mut waiters: BTreeMap<u64, Sender<Result<RequestResult, ServeError>>> = BTreeMap::new();
    let mut draining = false;
    // submit one arrival: shed-load fails the ticket immediately so
    // the queue stays bounded and the caller can back off
    let mut admit = |session: &mut crate::serving::scheduler::ContinuousSession<_>,
                     waiters: &mut BTreeMap<u64, Sender<Result<RequestResult, ServeError>>>,
                     r: Request,
                     tx: Sender<Result<RequestResult, ServeError>>| {
        let id = r.id;
        match session.enqueue(r) {
            SubmitOutcome::Queued | SubmitOutcome::QueuedDegraded => {
                waiters.insert(id, tx);
            }
            SubmitOutcome::Rejected(shed) => {
                let _ = tx.send(Err(ServeError::ShedLoad(shed)));
            }
        }
    };
    loop {
        // ingest — block briefly when idle, drain eagerly otherwise;
        // everything drained here is admitted at the next step
        let timeout =
            if session.is_idle() && !draining { Duration::from_millis(50) } else { Duration::ZERO };
        match rx.recv_timeout(timeout) {
            Ok(Msg::Submit(r, tx)) => {
                admit(&mut session, &mut waiters, r, tx);
                // keep ingesting whatever is immediately available
                while let Ok(msg) = rx.try_recv() {
                    match msg {
                        Msg::Submit(r, tx) => admit(&mut session, &mut waiters, r, tx),
                        Msg::Shutdown => draining = true,
                    }
                }
            }
            Ok(Msg::Shutdown) => draining = true,
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => draining = true,
        }

        if !session.is_idle() {
            match session.step() {
                Ok(finished) => {
                    if !finished.is_empty() {
                        engine.record_results(&finished);
                        for res in finished {
                            if let Some(tx) = waiters.remove(&res.id) {
                                let _ = tx.send(Ok(res));
                            }
                        }
                    }
                    // contained faults: fail exactly the affected
                    // tickets; the session is still serving the rest
                    for failure in session.take_failures() {
                        if let Some(tx) = waiters.remove(&failure.id) {
                            let _ = tx.send(Err(ServeError::Request(failure.error)));
                        }
                    }
                }
                Err(e) => {
                    // requests that completed earlier in the failed
                    // step are done — deliver them before failing the
                    // rest (a lost Sender would hang its Ticket::wait)
                    let done = session.take_finished();
                    if !done.is_empty() {
                        engine.record_results(&done);
                        for res in done {
                            if let Some(tx) = waiters.remove(&res.id) {
                                let _ = tx.send(Ok(res));
                            }
                        }
                    }
                    for failure in session.take_failures() {
                        if let Some(tx) = waiters.remove(&failure.id) {
                            let _ = tx.send(Err(ServeError::Request(failure.error)));
                        }
                    }
                    // an unrecoverable step poisons everything else in
                    // flight: fail the affected waiters and reset
                    let msg = format!("{e:#}");
                    for id in session.abort_all() {
                        if let Some(tx) = waiters.remove(&id) {
                            let _ = tx.send(Err(ServeError::Engine(msg.clone())));
                        }
                    }
                }
            }
            if session.is_idle() {
                engine.flush_session(&mut session);
            }
        }

        if draining && session.is_idle() {
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::request::GenParams;

    // The full-engine path is covered by rust/tests/serving_e2e.rs;
    // here we exercise the channel plumbing with artifacts when present.
    #[test]
    fn server_round_trip_or_skip() {
        let Some(dir) = crate::test_artifact_dir() else { return };
        let cfg = crate::model::model_config("tiny").unwrap();
        let mut rng = crate::util::Rng::new(77);
        let model = ModelWeights::random(&cfg, &mut rng);
        let mut ecfg = EngineConfig::dense("tiny", 128);
        ecfg.batcher.buckets = vec![1];
        ecfg.batcher.max_wait = Duration::ZERO;
        let server = EngineServer::start(dir, model, ecfg).unwrap();
        let t1 = server.submit(Request::new(
            1,
            vec![1, 2, 3],
            GenParams { max_new_tokens: 3, ..Default::default() },
        ));
        let t2 = server.submit(Request::new(
            2,
            vec![4, 5, 6],
            GenParams { max_new_tokens: 3, ..Default::default() },
        ));
        let r1 = t1.wait().unwrap();
        let r2 = t2.wait().unwrap();
        assert_eq!(r1.id, 1);
        assert_eq!(r2.id, 2);
        assert_eq!(r1.tokens.len(), 3);
        server.shutdown();
    }

    #[test]
    fn ticket_try_wait_is_nonblocking() {
        let (_tx, rx) = channel();
        let t = Ticket { rx };
        assert!(t.try_wait().is_none());
    }

    #[test]
    fn serve_error_display_is_typed() {
        let shed = ServeError::ShedLoad(ShedLoad {
            priority: crate::serving::Priority::Normal,
            queue_len: 9,
        });
        assert!(shed.to_string().starts_with("shed: "));
        assert!(ServeError::Request("boom".into()).to_string().contains("boom"));
        assert!(ServeError::Engine("down".into()).to_string().contains("down"));
    }
}
