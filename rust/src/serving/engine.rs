//! The serving engine over compiled decode steps, with two scheduling
//! paths:
//!
//! * **Continuous in-flight batching** (default — [`Engine::run_queue`],
//!   [`Engine::continuous_session`]): the `serving::scheduler` session
//!   admits queued requests into free KV slots at every decode step,
//!   retires them the step they finish, and runs each step at the
//!   smallest compiled bucket covering the live slots. KV lives
//!   per-slot in a host **paged** [`KvSlotPool`] (fixed-size
//!   refcounted pages; shared-prefix rows deduplicate through a
//!   [`PrefixCache`] when `EngineConfig::prefix_cache` is on) and is
//!   gathered/scattered around each artifact call
//!   ([`EngineStepForward`]).
//! * **Run-to-completion waves** ([`Engine::run_queue_waves`],
//!   [`Engine::generate_wave`]): the pre-continuous reference path —
//!   one batch prefills together and decodes until its last member
//!   finishes, KV device-resident for the wave. Kept for benchmarking
//!   (the continuous-vs-waves sweep) and as the token-identity oracle.
//!
//! In [`ExecMode::MoeOrchestrated`], attention and the shared expert
//! run through compiled artifacts while routing and the routed experts
//! are coordinated in rust. Routed-expert execution is selected by
//! [`ExpertExec`]: the default grouped host path (one GEMM per expert
//! per layer over arena-backed buffers — see `serving::dispatch`) or
//! the capacity-factor device artifact.
//!
//! Decode-family artifacts take **per-row positions** (`pos: i32[b]`),
//! which is what lets rows of one batch sit at different KV depths —
//! the ABI requirement behind mid-flight admission. The wave path
//! simply uploads the same position for every row.

use crate::model::{LayerFfn, ModelWeights, MoeSpec};
use crate::moe::{
    k_for_ratio, route_from_scores_dynamic, route_tokens_dynamic, BalanceConfig, BiasAdapter,
    DynamicK, GroupedRouting, ResidencyDelta, TieredStore,
};
use crate::runtime::{KvSlotPool, ModelBuffers, MoeModelBuffers, XlaRuntime};
use crate::runtime::ParkedSlot;
use crate::serving::batcher::{covering_bucket, Batcher, BatcherConfig, SubmitOutcome};
use crate::serving::clock::Clock;
use crate::serving::dispatch::{DispatchArena, ExpertDispatcher, GroupedDispatcher};
use crate::serving::metrics::{EngineMetrics, PageMetrics, WaveMetrics};
use crate::serving::prefix_cache::PrefixCache;
use crate::serving::request::{Request, RequestResult};
use crate::serving::scheduler::{ContinuousSession, PrefillOutcome, StepForward};
use crate::tensor::{self, Tensor};
use anyhow::{anyhow, bail, Context, Result};
use std::sync::Arc;
use std::time::Instant;

/// How the wave executes each step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// Monolithic dense decode artifact (baseline).
    Dense,
    /// Monolithic masked-MoE decode artifact (1 call, no FLOP saving).
    MoeMonolithic,
    /// Rust-coordinated expert dispatch (FLOPs actually skipped).
    MoeOrchestrated,
}

/// How `MoeOrchestrated` executes the routed experts of a layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExpertExec {
    /// Grouped host dispatch (default): gather per-expert token blocks,
    /// one SwiGLU GEMM per expert per layer, scatter back — zero heap
    /// allocations in steady state (per-engine scratch arena).
    HostGrouped,
    /// Capacity-factor device artifact (`experts_*`): fixed `[N_r,C,d]`
    /// zero-padded blocks, one grouped-kernel call, overflow rounds.
    /// Requires the artifact to be compiled for the wave's bucket.
    DeviceCapacity,
}

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Zoo model name ("small", …) — selects artifact family.
    pub model_name: String,
    pub mode: ExecMode,
    /// Required for the MoE modes.
    pub spec: Option<MoeSpec>,
    /// KV length bucket (must be compiled: e.g. 64 or 256 for `small`).
    pub kv_len: usize,
    pub batcher: BatcherConfig,
    /// Online load-balance adaptation (orchestrated mode only).
    pub balance: Option<BalanceConfig>,
    /// Routed-expert execution strategy (orchestrated mode only).
    pub expert_exec: ExpertExec,
    /// Tokens per KV page of the continuous scheduler's paged slot
    /// pool (`cmoe serve --page-len`). Clamped to `kv_len`.
    pub page_len: usize,
    /// Share KV pages across requests whose prompts share a prefix
    /// (`cmoe serve --prefix-cache`). Two effects: matched prefix
    /// pages are stored once and mapped per slot (memory dedup, any
    /// artifact set), and when suffix-continuation artifacts
    /// (`prefill_cont_*`) are compiled, a cross-step hit also **skips
    /// the prefix's prefill compute** — the engine prefills only the
    /// uncached suffix (see [`EngineStepForward`]).
    pub prefix_cache: bool,
    /// Time source for the scheduler session (wall clock in
    /// production; [`Clock::manual`] makes queue-wait/deadline logic
    /// deterministic in tests).
    pub clock: Clock,
    /// Per-token dynamic-k gating (`cmoe serve --dynamic-k`):
    /// router-entropy-thresholded expert counts in the orchestrated
    /// mode. [`DynamicK::fixed`] (the default) is bit-identical to the
    /// fixed top-k path.
    pub dynamic_k: DynamicK,
    /// Quantize routed experts to int8 behind the [`TieredStore`]
    /// residency tier (`cmoe serve --quant-experts`). `false` (the
    /// default) keeps every expert `Fp32Resident` and the serving path
    /// bit-identical to the plain fp32 dispatch. The shared expert is
    /// always fp32 regardless.
    pub quant_experts: bool,
    /// Int8-resident expert budget per MoE layer when `quant_experts`
    /// is set (`cmoe serve --resident-cap`); experts beyond the cap
    /// demote to `Int8Host` by routing-occupancy EMA.
    pub resident_cap: usize,
}

/// Default KV page length (tokens) for the paged slot pool.
pub const DEFAULT_PAGE_LEN: usize = 16;

/// Suffix-continuation prefill grid pitch: `python/compile/aot.py`
/// emits `prefill_cont_*` artifacts at suffix lengths that are
/// multiples of this step, so any cached-prefix/suffix split the
/// scheduler produces is coverable with at most `CONT_GRID_STEP - 1`
/// recomputed overlap tokens. Mirror-drift registered:
/// `scripts/mirror_chunked_prefill.py` must agree, checked by
/// `cmoe lint` (see `lint::drift::REGISTRY`).
pub const CONT_GRID_STEP: usize = 16;

impl EngineConfig {
    pub fn dense(model_name: &str, kv_len: usize) -> Self {
        EngineConfig {
            model_name: model_name.into(),
            mode: ExecMode::Dense,
            spec: None,
            kv_len,
            batcher: BatcherConfig::default(),
            balance: None,
            expert_exec: ExpertExec::HostGrouped,
            page_len: DEFAULT_PAGE_LEN,
            prefix_cache: false,
            clock: Clock::wall(),
            dynamic_k: DynamicK::fixed(),
            quant_experts: false,
            resident_cap: crate::moe::DEFAULT_RESIDENT_CAP,
        }
    }

    pub fn moe(model_name: &str, kv_len: usize, spec: MoeSpec, mode: ExecMode) -> Self {
        EngineConfig {
            model_name: model_name.into(),
            mode,
            spec: Some(spec),
            kv_len,
            batcher: BatcherConfig::default(),
            balance: Some(BalanceConfig::default()),
            expert_exec: ExpertExec::HostGrouped,
            page_len: DEFAULT_PAGE_LEN,
            prefix_cache: false,
            clock: Clock::wall(),
            dynamic_k: DynamicK::fixed(),
            quant_experts: false,
            resident_cap: crate::moe::DEFAULT_RESIDENT_CAP,
        }
    }
}

/// The engine. Holds the runtime, uploaded weights, and (for the
/// orchestrated mode) a host-side copy of the MoE layers whose
/// load-balance biases adapt online.
pub struct Engine {
    pub rt: Arc<XlaRuntime>,
    pub cfg: EngineConfig,
    model: ModelWeights,
    dense_bufs: ModelBuffers,
    moe_bufs: Option<MoeModelBuffers>,
    /// Host-side MoE routing state (layer copies whose biases adapt
    /// online) — orchestrated mode only.
    moe_state: std::sync::Mutex<MoeState>,
    pub metrics: std::sync::Mutex<EngineMetrics>,
}

/// Host copies of the MoE layers plus their bias adapters, and the
/// per-engine grouped-dispatch scratch (routing index lists + arena)
/// reused across layers, steps, and waves — the decode loop's
/// zero-allocation working set.
struct MoeState {
    layers: Vec<crate::model::MoeLayerWeights>,
    adapters: Vec<BiasAdapter>,
    /// Expert-major routing lists, rebuilt in place each layer-step.
    routing: GroupedRouting,
    /// Gather/GEMM/scatter scratch; grows during warmup, then stable.
    arena: DispatchArena,
    /// Per-expert token counts of the current layer-step (feeds the
    /// bias adapter and the occupancy gauge).
    counts: Vec<usize>,
    /// Per-expert tokens accumulated over the current decode step's
    /// layers; flushed to `EngineMetrics::dispatch` once per step so
    /// the metrics mutex stays off the per-layer hot path.
    step_tokens: Vec<u64>,
    /// Per-MoE-layer expert storage tiers (`EngineConfig::quant_experts`);
    /// empty when quantized storage is off — the dispatcher then runs
    /// over the plain fp32 `layers[l].experts` slices, bit-identical to
    /// the pre-storage-trait path.
    stores: Vec<TieredStore>,
    /// Residency transitions accumulated over the current decode step's
    /// layers; flushed to `EngineMetrics::residency` once per step,
    /// alongside `step_tokens`.
    step_residency: ResidencyDelta,
}

impl Engine {
    pub fn new(rt: Arc<XlaRuntime>, model: ModelWeights, cfg: EngineConfig) -> Result<Engine> {
        // reject bad bucket lists up front so every later construction
        // (sessions, wave batchers, the slot pool) can rely on them
        cfg.batcher.normalized().context("engine batcher config")?;
        let dense_bufs = ModelBuffers::from_model(&rt, &model)?;
        let is_moe = model.layers.iter().any(|l| matches!(l.ffn, LayerFfn::Moe(_)));
        match cfg.mode {
            ExecMode::Dense if is_moe => bail!("dense mode needs a dense model"),
            ExecMode::MoeMonolithic | ExecMode::MoeOrchestrated if !is_moe => {
                bail!("MoE mode needs a converted model")
            }
            _ => {}
        }
        let moe_bufs =
            if is_moe { Some(MoeModelBuffers::from_model(&rt, &model)?) } else { None };
        let moe_layers: Vec<_> = model
            .layers
            .iter()
            .filter_map(|l| match &l.ffn {
                LayerFfn::Moe(m) => Some(m.clone()),
                _ => None,
            })
            .collect();
        let adapters = moe_layers
            .iter()
            .map(|m| BiasAdapter::new(m.spec.routed(), cfg.balance.unwrap_or_default()))
            .collect();
        let max_routed = moe_layers.iter().map(|m| m.spec.routed()).max().unwrap_or(0);
        // quantized expert storage: one residency tier per MoE layer;
        // the fp32 originals stay in `layers` for the bias adapter and
        // the (always-fp32) monolithic/device paths
        let stores = if cfg.quant_experts {
            moe_layers
                .iter()
                .map(|m| TieredStore::new(&m.experts, true, cfg.resident_cap))
                .collect()
        } else {
            Vec::new()
        };
        Ok(Engine {
            rt,
            cfg,
            model,
            dense_bufs,
            moe_bufs,
            moe_state: std::sync::Mutex::new(MoeState {
                layers: moe_layers,
                adapters,
                routing: GroupedRouting::new(max_routed),
                arena: DispatchArena::new(),
                counts: vec![0; max_routed],
                step_tokens: vec![0; max_routed],
                stores,
                step_residency: ResidencyDelta::default(),
            }),
            metrics: std::sync::Mutex::new(EngineMetrics::default()),
        })
    }

    /// Current per-layer load-balance biases (orchestrated mode).
    pub fn current_biases(&self) -> Vec<Vec<f32>> {
        crate::util::lock_unpoisoned(&self.moe_state).layers.iter().map(|m| m.gate_bias.clone()).collect()
    }

    pub fn model(&self) -> &ModelWeights {
        &self.model
    }

    fn spec_str(&self) -> String {
        self.cfg.spec.map(|s| s.to_string()).unwrap_or_default()
    }

    /// Compiled prefill lengths for this model/batch, ascending.
    fn prefill_lens(&self, b: usize) -> Vec<usize> {
        let prefix = match self.cfg.mode {
            ExecMode::Dense => format!("prefill_dense_{}_b{b}_s", self.cfg.model_name),
            _ => format!("prefill_moe_{}_{}_b{b}_s", self.cfg.model_name, self.spec_str()),
        };
        let suffix = format!("_t{}", self.cfg.kv_len);
        let mut lens: Vec<usize> = self
            .rt
            .manifest
            .artifacts
            .keys()
            .filter_map(|k| {
                k.strip_prefix(&prefix)?.strip_suffix(&suffix)?.parse().ok()
            })
            .collect();
        lens.sort_unstable();
        lens
    }

    /// Compiled suffix-continuation prefill lengths for this
    /// model/batch, ascending. Empty when the artifact set predates
    /// `prefill_cont_*` — the engine then recomputes continuations
    /// through the monolithic prefill (correct, no compute skip).
    fn prefill_cont_lens(&self, b: usize) -> Vec<usize> {
        let prefix = match self.cfg.mode {
            ExecMode::Dense => format!("prefill_cont_dense_{}_b{b}_s", self.cfg.model_name),
            _ => {
                format!("prefill_cont_moe_{}_{}_b{b}_s", self.cfg.model_name, self.spec_str())
            }
        };
        let suffix = format!("_t{}", self.cfg.kv_len);
        let mut lens: Vec<usize> = self
            .rt
            .manifest
            .artifacts
            .keys()
            .filter_map(|k| {
                k.strip_prefix(&prefix)?.strip_suffix(&suffix)?.parse().ok()
            })
            .collect();
        lens.sort_unstable();
        lens
    }

    /// Run a standalone batch of requests through the **continuous
    /// scheduler** (the default serving path): per-step admission into
    /// KV slots, per-step retirement, minimal covering buckets.
    pub fn run_queue(&self, requests: Vec<Request>) -> Result<Vec<RequestResult>> {
        let mut session = self.continuous_session();
        let mut shed = Vec::new();
        for r in requests {
            let id = r.id;
            if let SubmitOutcome::Rejected(_) = session.enqueue(r) {
                shed.push(id);
            }
        }
        let results = session.drain()?;
        self.record_results(&results);
        self.flush_session(&mut session);
        // a standalone batch expects every request back: surface
        // shed/failed ids as an error instead of silently returning a
        // partial set (the ticketed server reports these per request)
        let failures = session.take_failures();
        if !shed.is_empty() || !failures.is_empty() {
            bail!(
                "run_queue: shed {:?}; failed {:?}",
                shed,
                failures.iter().map(|f| (f.id, f.error.as_str())).collect::<Vec<_>>()
            );
        }
        Ok(results)
    }

    /// Start a continuous-batching session on this engine. The caller
    /// owns the step loop ([`ContinuousSession::step`]) and may enqueue
    /// between steps — that is mid-flight admission; the threaded
    /// server does exactly this.
    pub fn continuous_session(&self) -> ContinuousSession<EngineStepForward<'_>> {
        ContinuousSession::with_clock(
            self.cfg.batcher.clone(),
            EngineStepForward::new(self),
            self.cfg.clock.clone(),
        )
        // lint: allow(panic-discipline) — BatcherConfig::normalized() is re-validated by Engine::new; an invalid config cannot reach here
        .expect("batcher config validated by Engine::new")
    }

    /// Record per-request latency metrics for finished results.
    pub(crate) fn record_results(&self, results: &[RequestResult]) {
        let mut m = crate::util::lock_unpoisoned(&self.metrics);
        for r in results {
            m.record_request(r.ttft, r.latency);
        }
    }

    /// Fold a session's scheduler gauges + run summary into the engine
    /// metrics (call when the session goes idle).
    pub(crate) fn flush_session(&self, session: &mut ContinuousSession<EngineStepForward<'_>>) {
        let sm = session.take_metrics();
        let wm = session.take_run_summary();
        // delta snapshot: a long-lived server session flushes at every
        // idle, and lifetime counters must not be re-added each time
        let pm = session.take_page_metrics();
        let mut m = crate::util::lock_unpoisoned(&self.metrics);
        m.scheduler.merge(&sm);
        if let Some(p) = pm {
            m.pages.merge(&p);
        }
        if let Some(w) = wm {
            m.record_wave(w);
        }
    }

    /// Run a standalone batch wave-at-a-time (**run-to-completion**
    /// reference path): each wave decodes until its last member
    /// finishes while retired members pad the batch. Kept for the
    /// continuous-vs-waves benchmark and as the token-identity oracle
    /// — per-request outputs are identical to [`Engine::run_queue`].
    pub fn run_queue_waves(&self, requests: Vec<Request>) -> Result<Vec<RequestResult>> {
        let mut batcher = Batcher::with_clock(self.cfg.batcher.clone(), self.cfg.clock.clone())
            .context("wave batcher")?;
        // the wave path has no chunked prefill: a prompt longer than
        // the largest compiled prefill length cannot run at all, so
        // retire it as a typed per-request failure up front instead of
        // silently serving its suffix (the artifact grid is uniform
        // across buckets, so any configured bucket enumerates the
        // same lengths)
        let max_s = self
            .cfg
            .batcher
            .buckets
            .first()
            .map(|&b| self.prefill_lens(b))
            .and_then(|lens| lens.last().copied());
        let mut failures: Vec<crate::serving::RequestFailure> = Vec::new();
        for r in requests {
            match max_s {
                Some(max_s) if r.prompt.len() > max_s => {
                    failures.push(crate::serving::RequestFailure {
                        id: r.id,
                        error: format!(
                            "prompt len {} exceeds largest compiled prefill s={max_s} \
                             (wave path has no chunked prefill)",
                            r.prompt.len()
                        ),
                    });
                }
                _ => {
                    let _ = batcher.push(r);
                }
            }
        }
        let mut results = Vec::new();
        let mut wave = Vec::new();
        while !batcher.is_empty() {
            if batcher.take_wave_into(&mut wave) {
                results.extend(self.generate_wave(&mut wave)?);
            }
        }
        results.sort_by_key(|r| r.id);
        // same surfacing contract as run_queue: a standalone batch
        // expects every request back, so failed ids become an error
        if !failures.is_empty() {
            bail!(
                "run_queue_waves: failed {:?}",
                failures.iter().map(|f| (f.id, f.error.as_str())).collect::<Vec<_>>()
            );
        }
        Ok(results)
    }

    /// Execute one wave to completion. The wave buffer is drained (so
    /// callers can reuse its allocation for the next wave); on error it
    /// is left intact.
    pub fn generate_wave(&self, wave: &mut Vec<(Request, Instant)>) -> Result<Vec<RequestResult>> {
        let clock = &self.cfg.clock;
        let t_start = clock.now();
        let n_real = wave.len();
        assert!(n_real > 0);
        let bucket = {
            let mut b = n_real;
            let buckets = &self.cfg.batcher.buckets;
            for &cand in buckets {
                if n_real <= cand {
                    b = cand;
                    break;
                }
            }
            b
        };

        // --- pick a prefill length: smallest compiled s >= max prompt.
        // A prompt longer than the largest compiled s is an error, not
        // a silent suffix-truncation — run_queue_waves retires such
        // requests as typed failures before they reach a wave ---
        let lens = self.prefill_lens(bucket);
        if lens.is_empty() {
            bail!(
                "no prefill artifact for model={} mode={:?} b={bucket} t={}",
                self.cfg.model_name,
                self.cfg.mode,
                self.cfg.kv_len
            );
        }
        let max_prompt = wave.iter().map(|(r, _)| r.prompt.len()).max().unwrap_or(0);
        let s = lens.iter().copied().find(|&l| l >= max_prompt).ok_or_else(|| {
            anyhow!(
                "wave prompt len {max_prompt} exceeds largest compiled prefill s={} — the \
                 wave path has no chunked prefill; use the continuous path or compile a \
                 longer artifact",
                lens.last().copied().unwrap_or(0)
            )
        })?;

        // tokens [bucket, s]: left-align prompts (trailing padding is
        // causally invisible to the real tokens, so a row's logits and
        // KV do not depend on the compiled s — the same alignment the
        // continuous path uses, keeping the two paths token-identical)
        let mut tokens = vec![0i32; bucket * s];
        let mut ns = vec![0usize; n_real];
        for (i, (r, _)) in wave.iter().enumerate() {
            debug_assert!(r.prompt.len() <= s, "prefill s selection covers the longest prompt");
            ns[i] = r.prompt.len();
            for (j, &tok) in r.prompt.iter().enumerate() {
                tokens[i * s + j] = tok as i32;
            }
        }

        // --- prefill ---
        let t_prefill = clock.now();
        let cfgm = &self.model.config;
        let v = cfgm.vocab;
        let prefill_name = match self.cfg.mode {
            ExecMode::Dense => format!(
                "prefill_dense_{}_b{bucket}_s{s}_t{}",
                self.cfg.model_name, self.cfg.kv_len
            ),
            _ => format!(
                "prefill_moe_{}_{}_b{bucket}_s{s}_t{}",
                self.cfg.model_name,
                self.spec_str(),
                self.cfg.kv_len
            ),
        };
        let tok_buf = self.rt.upload_i32(&tokens, &[bucket, s])?;
        let args = self.param_args(&[&tok_buf]);
        let out = self.rt.execute(&prefill_name, &args).context("prefill")?;
        let logits = self.rt.download(&out[0], &[bucket, s, v])?;
        let mut kv_buf = out.into_iter().nth(1).ok_or_else(|| anyhow!("prefill: no kv"))?;
        let prefill_time = clock.now().saturating_duration_since(t_prefill);

        // --- sample first tokens ---
        let mut rngs: Vec<crate::util::Rng> =
            wave.iter().map(|(r, _)| crate::util::Rng::new(r.params.seed)).collect();
        let mut generated: Vec<Vec<usize>> = vec![Vec::new(); n_real];
        let mut active: Vec<bool> = vec![true; n_real];
        let mut cur = vec![0i32; bucket];
        for i in 0..n_real {
            // left-aligned rows: the last real prompt position
            let row_start = (i * s + (ns[i] - 1)) * v;
            let row = &logits.data[row_start..row_start + v];
            let tok = rngs[i].sample_logits(row, wave[i].0.params.temperature);
            generated[i].push(tok);
            cur[i] = tok as i32;
            if wave[i].0.params.stop_token == Some(tok)
                || wave[i].0.params.max_new_tokens <= 1
                || ns[i] >= self.cfg.kv_len
            {
                active[i] = false;
            }
        }
        let ttft = clock.now().saturating_duration_since(t_start);

        // --- decode loop ---
        let t_decode = clock.now();
        let mut steps = 0usize;
        // orchestrated mode splits kv into per-layer buffers once
        let mut kv_layers: Vec<xla::PjRtBuffer> = Vec::new();
        if self.cfg.mode == ExecMode::MoeOrchestrated {
            let name = format!(
                "split_kv_{}_b{bucket}_t{}",
                self.cfg.model_name, self.cfg.kv_len
            );
            kv_layers = self.rt.execute(&name, &[&kv_buf])?;
        }

        let mut pos_rows = vec![0i32; bucket];
        while active.iter().any(|&a| a) {
            let tok_buf = self.rt.upload_i32(&cur, &[bucket])?;
            // decode artifacts take per-row positions (continuous
            // batching ABI); left-aligned rows sit at their own prompt
            // depth, so each advances from its true length
            for i in 0..n_real {
                pos_rows[i] = (ns[i] + steps) as i32;
            }
            let pos_buf = self.rt.upload_i32(&pos_rows, &[bucket])?;
            let logits = match self.cfg.mode {
                ExecMode::Dense | ExecMode::MoeMonolithic => {
                    let name = match self.cfg.mode {
                        ExecMode::Dense => format!(
                            "decode_dense_{}_b{bucket}_t{}",
                            self.cfg.model_name, self.cfg.kv_len
                        ),
                        _ => format!(
                            "decode_moe_{}_{}_b{bucket}_t{}",
                            self.cfg.model_name,
                            self.spec_str(),
                            self.cfg.kv_len
                        ),
                    };
                    let args = self.param_args(&[&tok_buf, &kv_buf, &pos_buf]);
                    let mut out = self.rt.execute(&name, &args)?;
                    let kv_new = out.pop().ok_or_else(|| anyhow!("decode: no kv"))?;
                    let logits = self.rt.download(&out[0], &[bucket, v])?;
                    kv_buf = kv_new;
                    logits
                }
                ExecMode::MoeOrchestrated => {
                    // wave rows are untiered: full activation ratio
                    self.orchestrated_step(bucket, &tok_buf, &pos_buf, &mut kv_layers, None)?
                }
            };

            // sample
            for i in 0..n_real {
                if !active[i] {
                    continue;
                }
                let row = &logits.data[i * v..(i + 1) * v];
                let tok = rngs[i].sample_logits(row, wave[i].0.params.temperature);
                generated[i].push(tok);
                cur[i] = tok as i32;
                if wave[i].0.params.stop_token == Some(tok)
                    || generated[i].len() >= wave[i].0.params.max_new_tokens
                    || ns[i] + steps + 1 >= self.cfg.kv_len
                {
                    active[i] = false;
                }
            }
            steps += 1;
        }
        let decode_time = clock.now().saturating_duration_since(t_decode);

        // --- metrics + results ---
        let mut m = crate::util::lock_unpoisoned(&self.metrics);
        m.record_wave(WaveMetrics {
            batch: bucket,
            prompt_tokens: n_real * s,
            generated_tokens: generated.iter().map(|g| g.len()).sum(),
            prefill: prefill_time,
            decode: decode_time,
            decode_steps: steps,
        });
        let mut results = Vec::new();
        let t_end = clock.now();
        for (i, (r, enqueued)) in wave.drain(..).enumerate() {
            let latency = t_end.saturating_duration_since(enqueued);
            m.record_request(Some(ttft), latency);
            let tokens = std::mem::take(&mut generated[i]);
            // wave path: one prefill step samples every first token,
            // and an uninterrupted decode spans tokens-1 steps
            let decode_span_steps = tokens.len().saturating_sub(1) as u64;
            results.push(RequestResult {
                id: r.id,
                tokens,
                ttft: Some(ttft),
                ttft_steps: Some(1),
                decode_span_steps,
                latency,
                queued: t_start.duration_since(enqueued),
                queued_steps: 0,
                priority: r.priority,
                tier: r.tier,
            });
        }
        Ok(results)
    }

    /// Parameter buffers + extra inputs, in artifact argument order.
    fn param_args<'a>(&'a self, extra: &[&'a xla::PjRtBuffer]) -> Vec<&'a xla::PjRtBuffer> {
        let mut args: Vec<&xla::PjRtBuffer> = self.dense_bufs.named.values().collect();
        if let Some(mb) = &self.moe_bufs {
            args.extend(mb.named.values());
        }
        args.extend(extra.iter().copied());
        args
    }

    /// One rust-orchestrated MoE decode step: embed → per-layer
    /// [attention artifact → host routing → grouped expert artifact] →
    /// logits artifact. Returns host logits `[bucket, v]`.
    ///
    /// `row_ratios` (len = `bucket` when present) carries each row's
    /// effort-tier activation ratio: row `i` routes each token to at
    /// most `k_for_ratio(row_ratios[i], N_k)` experts per layer. `None`
    /// (and any ratio `>= 1`) is the untiered full-k path. Per-token
    /// dynamic-k ([`EngineConfig::dynamic_k`]) then floats k *below*
    /// that cap on router entropy.
    fn orchestrated_step(
        &self,
        bucket: usize,
        tok_buf: &xla::PjRtBuffer,
        pos_buf: &xla::PjRtBuffer,
        kv_layers: &mut [xla::PjRtBuffer],
        row_ratios: Option<&[f32]>,
    ) -> Result<Tensor> {
        let name = &self.cfg.model_name;
        let cfgm = &self.model.config;
        let d = cfgm.d_model;
        let v = cfgm.vocab;
        let t = self.cfg.kv_len;

        // embed
        let out = self.rt.execute(
            &format!("embed_{name}_b{bucket}"),
            &[
                self.dense_bufs.req("embed")?,
                self.dense_bufs.req("pos")?,
                tok_buf,
                pos_buf,
            ],
        )?;
        let mut x = self.rt.download(&out[0], &[bucket, d])?;

        let mut state = crate::util::lock_unpoisoned(&self.moe_state);
        state.step_tokens.iter_mut().for_each(|v| *v = 0);
        state.step_residency = ResidencyDelta::default();
        let mut layer_dispatches = 0u64;
        let n_layers = state.layers.len();
        for l in 0..n_layers {
            let p = format!("layers.{l}");
            let mp = format!("moe.{l}");
            let mb = self
                .moe_bufs
                .as_ref()
                .ok_or_else(|| anyhow!("orchestrated mode requires uploaded MoE buffers"))?;
            let n_r0 = state.layers[l].spec.routed();
            let sh = state.layers[l].shared.hidden_dim();

            // PERF L3-1: fused attention + pre-norm + router + shared
            // expert in one artifact (falls back to the unfused path
            // when the fused artifact isn't compiled)
            let fused = format!("attn_moe_pre_{name}_e{n_r0}_h{sh}_b{bucket}_t{t}");
            let (xn, scores, shared_out) = if self.rt.has_artifact(&fused) {
                let x_buf = self.rt.upload(&x)?;
                let out = self.rt.execute(
                    &fused,
                    &[
                        &x_buf,
                        &kv_layers[l],
                        self.dense_bufs.req(&format!("{p}.attn.wq"))?,
                        self.dense_bufs.req(&format!("{p}.attn.wk"))?,
                        self.dense_bufs.req(&format!("{p}.attn.wv"))?,
                        self.dense_bufs.req(&format!("{p}.attn.wo"))?,
                        self.dense_bufs.req(&format!("{p}.attn_norm"))?,
                        self.dense_bufs.req(&format!("{p}.ffn_norm"))?,
                        mb.req(&format!("{mp}.router.w_gate_r"))?,
                        mb.req(&format!("{mp}.router.w_up_r"))?,
                        mb.req(&format!("{mp}.shared.w_gate"))?,
                        mb.req(&format!("{mp}.shared.w_up"))?,
                        mb.req(&format!("{mp}.shared.w_down"))?,
                        pos_buf,
                    ],
                )?;
                let mut it = out.into_iter();
                let x_new = it.next().ok_or_else(|| anyhow!("pre: no x"))?;
                let kv_new = it.next().ok_or_else(|| anyhow!("pre: no kv"))?;
                let xn_b = it.next().ok_or_else(|| anyhow!("pre: no xn"))?;
                let scores_b = it.next().ok_or_else(|| anyhow!("pre: no scores"))?;
                let shared_b = it.next().ok_or_else(|| anyhow!("pre: no shared"))?;
                x = self.rt.download(&x_new, &[bucket, d])?;
                kv_layers[l] = kv_new;
                (
                    self.rt.download(&xn_b, &[bucket, d])?,
                    Some(self.rt.download(&scores_b, &[bucket, n_r0])?),
                    self.rt.download(&shared_b, &[bucket, d])?,
                )
            } else {
                // unfused fallback
                let x_buf = self.rt.upload(&x)?;
                let out = self.rt.execute(
                    &format!("attn_layer_{name}_b{bucket}_t{t}"),
                    &[
                        &x_buf,
                        &kv_layers[l],
                        self.dense_bufs.req(&format!("{p}.attn.wq"))?,
                        self.dense_bufs.req(&format!("{p}.attn.wk"))?,
                        self.dense_bufs.req(&format!("{p}.attn.wv"))?,
                        self.dense_bufs.req(&format!("{p}.attn.wo"))?,
                        self.dense_bufs.req(&format!("{p}.attn_norm"))?,
                        pos_buf,
                    ],
                )?;
                x = self.rt.download(&out[0], &[bucket, d])?;
                kv_layers[l] = out.into_iter().nth(1).ok_or_else(|| anyhow!("attn: no kv"))?;
                let xn = tensor::rmsnorm_rows(&x, &self.model.layers[l].ffn_norm, 1e-6);
                let shared_out = if sh > 0 {
                    let xn_buf = self.rt.upload(&xn)?;
                    let out = self.rt.execute(
                        &format!("ffn_{name}_h{sh}_b{bucket}"),
                        &[
                            &xn_buf,
                            mb.req(&format!("{mp}.shared.w_gate"))?,
                            mb.req(&format!("{mp}.shared.w_up"))?,
                            mb.req(&format!("{mp}.shared.w_down"))?,
                        ],
                    )?;
                    self.rt.download(&out[0], &[bucket, d])?
                } else {
                    Tensor::zeros(&[bucket, d])
                };
                (xn, None, shared_out)
            };

            // host: routing from (device-computed or host-computed)
            // scores — bias adaptation lives here either way. Tier
            // caps are resolved per layer because N_k is a layer
            // property; the ragged decisions flow into the same
            // grouped dispatch (its CSR never assumed uniform k).
            let caps: Option<Vec<usize>> = row_ratios.map(|rs| {
                let n_k = state.layers[l].spec.active;
                rs.iter().map(|&r| k_for_ratio(r, n_k)).collect()
            });
            let dk = self.cfg.dynamic_k;
            let decisions = match scores {
                Some(s) => route_from_scores_dynamic(&state.layers[l], &s, dk, caps.as_deref()),
                None => route_tokens_dynamic(&state.layers[l], &xn, dk, caps.as_deref()),
            };

            // routed experts: grouped host dispatch (default) or the
            // capacity-factor device artifact
            let n_r = state.layers[l].spec.routed();
            let m = state.layers[l].experts[0].hidden_dim();
            let mut ffn_out = shared_out;
            let st = &mut *state;
            if st.counts.len() < n_r {
                st.counts.resize(n_r, 0);
            }
            st.counts[..n_r].fill(0);
            match self.cfg.expert_exec {
                ExpertExec::HostGrouped => {
                    // one GEMM per expert per layer over arena-backed
                    // expert blocks; no padding, no overflow rounds
                    st.routing.rebuild(n_r, &decisions);
                    for (e, c) in st.counts[..n_r].iter_mut().enumerate() {
                        *c = st.routing.count(e);
                    }
                    let disp = GroupedDispatcher::new(d, m);
                    if let Some(store) = st.stores.get_mut(l) {
                        // quantized storage: meter hits/misses against
                        // the residency this step dispatches under,
                        // let the tier reshuffle on the routing trend,
                        // then dispatch through the store's views
                        let delta = store.note_step(&st.counts[..n_r]);
                        st.step_residency.hits += delta.hits;
                        st.step_residency.misses += delta.misses;
                        st.step_residency.prefetches += delta.prefetches;
                        st.step_residency.demotions += delta.demotions;
                        disp.forward(&xn, &st.routing, &*store, &mut st.arena, &mut ffn_out);
                    } else {
                        disp.forward(
                            &xn,
                            &st.routing,
                            &st.layers[l].experts,
                            &mut st.arena,
                            &mut ffn_out,
                        );
                    }
                }
                ExpertExec::DeviceCapacity => {
                    let cap = self.expert_capacity(bucket, n_r)?;
                    let disp = ExpertDispatcher::new(n_r, cap, d);
                    let mut assignments: Vec<(usize, usize, f32)> = decisions
                        .iter()
                        .enumerate()
                        .flat_map(|(tk, dec)| {
                            dec.experts.iter().zip(&dec.gates).map(move |(&e, &g)| (tk, e, g))
                        })
                        .collect();
                    while !assignments.is_empty() {
                        let dd = disp.build_from_assignments(&xn, &assignments);
                        let xs_buf = self.rt.upload(&dd.xs)?;
                        let out = self.rt.execute(
                            &format!("experts_{name}_e{n_r}_mm{m}_c{cap}_b{bucket}"),
                            &[
                                &xs_buf,
                                mb.req(&format!("{mp}.experts.w_gate"))?,
                                mb.req(&format!("{mp}.experts.w_up"))?,
                                mb.req(&format!("{mp}.experts.w_down"))?,
                            ],
                        )?;
                        let ys = self.rt.download(&out[0], &[n_r, cap, d])?;
                        disp.combine(&dd, &ys, &mut ffn_out);
                        for (e, sl) in dd.slots.iter().enumerate() {
                            st.counts[e] += sl.len();
                        }
                        assignments = dd.overflow;
                    }
                }
            }
            // residual
            tensor::add_inplace(&mut x, &ffn_out);

            // online bias adaptation (§4.3) on the host-side copy —
            // only when the engine was configured with a balance policy
            if self.cfg.balance.is_some() {
                st.adapters[l].step(&mut st.layers[l], &st.counts[..n_r]);
            }

            // occupancy bookkeeping stays inside the already-held MoE
            // state lock; it flushes to the metrics mutex once per step
            for (acc, &c) in st.step_tokens.iter_mut().zip(&st.counts[..n_r]) {
                *acc += c as u64;
            }
            layer_dispatches += 1;
        }
        // flush dispatch gauges once per step — the arena's post-warmup
        // stability is the zero-allocation signal the bench asserts on
        {
            let st = &*state;
            let mut mtr = crate::util::lock_unpoisoned(&self.metrics);
            mtr.dispatch.record_step(&st.step_tokens, layer_dispatches);
            mtr.dispatch.record_arena(st.arena.high_water_bytes(), st.arena.grow_events());
            mtr.residency.observe(&st.step_residency);
        }
        drop(state);

        // logits (device)
        let x_buf = self.rt.upload(&x)?;
        let out = self.rt.execute(
            &format!("logits_{name}_b{bucket}"),
            &[
                &x_buf,
                self.dense_bufs.req("final_norm")?,
                self.dense_bufs.req("unembed")?,
            ],
        )?;
        self.rt.download(&out[0], &[bucket, v])
    }

    /// Capacity compiled for this (model, batch, experts) combination.
    fn expert_capacity(&self, bucket: usize, n_r: usize) -> Result<usize> {
        let prefix = format!("experts_{}_e{n_r}_mm", self.cfg.model_name);
        let suffix = format!("_b{bucket}");
        self.rt
            .manifest
            .artifacts
            .iter()
            .find_map(|(k, a)| {
                if k.starts_with(&prefix) && k.ends_with(&suffix) {
                    a.meta.get("capacity").as_usize()
                } else {
                    None
                }
            })
            .ok_or_else(|| anyhow!("no experts artifact for e{n_r} b{bucket}"))
    }
}

// ---------------------------------------------------------------------------
// Artifact-backed StepForward: the continuous scheduler's model half
// ---------------------------------------------------------------------------

/// [`StepForward`] over the engine's compiled artifacts. KV ownership
/// is per-slot and **paged** ([`KvSlotPool`]): a slot's page table
/// covers exactly its written extent, each decode step gathers the
/// live slots' pages into a bucket-shaped buffer (zero beyond each
/// extent — byte-identical to the old contiguous pool), runs the
/// compiled step with per-row positions, and scatters back only the
/// one token position the step wrote. Every configured batch bucket
/// must be compiled — the scheduler switches buckets as occupancy
/// changes.
///
/// Prefill rows are **left-aligned**: prompt token `j` sits at KV
/// position `j`, trailing padding is causally invisible to the real
/// tokens, and decode continues at the true prompt length. A row's KV
/// bytes therefore do not depend on which compiled `s` carried it —
/// the invariance everything below rests on. Admissions are grouped by
/// their own covering prefill length so a request's execution never
/// depends on its admission cohort (the token-identity guarantee).
///
/// Two prefill families compose over that invariance:
///
/// * **Monolithic** (`prefill_*_b{b}_s{s}_t{t}`): computes a row from
///   position 0. Used for fresh rows, and as the recompute fallback
///   for continuations when no suitable cont artifact is compiled
///   (recomputed KV is bit-identical; only `[cached, end)` is stored).
/// * **Suffix continuation** (`prefill_cont_*_b{b}_s{s}_t{t}`): takes
///   the row's resident KV prefix plus per-row start offsets and
///   computes exactly `s` tokens at their true positions — a cached or
///   previously-chunked prefix **skips compute**, not just storage.
///   Suffixes shorter than the compiled `s` back-extend into cached
///   tokens (identical recompute, overlap not re-stored); the grid is
///   emitted at [`CONT_GRID_STEP`] pitch so the overlap is bounded.
///
/// A prefill call may also stop short of its requested end when the
/// artifact grid caps the chunk ([`PrefillOutcome::pos`] reports real
/// coverage) — the scheduler re-plans the remainder next step, which
/// is how prompts longer than the largest compiled `s` now prefill
/// completely instead of being truncated.
///
/// With `EngineConfig::prefix_cache` on, a [`PrefixCache`] keyed on
/// **raw prompt tokens** (valid precisely because of left alignment)
/// backs two layers of sharing: [`StepForward::map_prefix`] maps a
/// cached prefix at admission and the continuation path skips its
/// compute (cross-step); and inside a prefill batch, rows re-consult
/// the cache before storing so identical system-prompt rows keep one
/// physical copy (intra-step memory dedup, any artifact set). KV at
/// position `p` is a causal function of tokens `[0, p]`, so a
/// full-page token match implies identical bytes.
pub struct EngineStepForward<'e> {
    eng: &'e Engine,
    kv: KvSlotPool,
    cache: Option<PrefixCache>,
    /// Configured buckets, ascending (minimal-covering prefill groups).
    buckets: Vec<usize>,
    // gather/scatter scratch, reused across steps
    kv_batch: Vec<f32>,
    kv_layer: Vec<f32>,
    toks_pad: Vec<i32>,
    pos_pad: Vec<i32>,
    /// Per-slot effort-tier activation ratio, pushed by the session
    /// via [`StepForward::set_slot_ratio`] at every (re)assignment.
    /// 1.0 (the initial value) = untiered full-k routing for that row.
    slot_ratios: Vec<f32>,
    ratios_pad: Vec<f32>,
}

impl<'e> EngineStepForward<'e> {
    fn new(eng: &'e Engine) -> EngineStepForward<'e> {
        let mut buckets = eng.cfg.batcher.buckets.clone();
        buckets.sort_unstable();
        buckets.dedup();
        // lint: allow(panic-discipline) — BatcherConfig::normalized() rejects empty bucket lists before an Engine exists
        let pool = *buckets.last().expect("engine needs at least one batch bucket");
        let c = &eng.model.config;
        let t = eng.cfg.kv_len;
        let page_len = eng.cfg.page_len.clamp(1, t);
        // worst case (every slot fully private at the whole horizon)
        // fits by construction, so prefix sharing only frees headroom
        // and allocation-after-eviction can never fail
        let pages_per_slot = (t + page_len - 1) / page_len;
        EngineStepForward {
            eng,
            kv: KvSlotPool::new(
                pool,
                c.n_layers,
                c.n_heads,
                t,
                c.head_dim(),
                page_len,
                Some(pool * pages_per_slot),
            ),
            cache: eng.cfg.prefix_cache.then(|| PrefixCache::new(page_len)),
            buckets,
            kv_batch: Vec::new(),
            kv_layer: Vec::new(),
            toks_pad: Vec::new(),
            pos_pad: Vec::new(),
            slot_ratios: vec![1.0; pool],
            ratios_pad: Vec::new(),
        }
    }

    fn min_bucket(&self, n: usize) -> usize {
        covering_bucket(&self.buckets, n)
    }

    /// Free headroom for `need` page allocations, evicting LRU
    /// prefix-cache holds under page pressure.
    fn evict_for(&mut self, need: usize) {
        if need == 0 {
            return;
        }
        if let Some(avail) = self.kv.pages_available() {
            if avail < need {
                if let Some(cache) = &mut self.cache {
                    cache.evict(self.kv.pages_mut(), need - avail);
                }
            }
        }
    }

    /// Make sure `slot` can grow to cover `upto` tokens. Only valid
    /// immediately before that slot's store — for a batch of growths,
    /// reserve the aggregate with [`EngineStepForward::evict_for`]
    /// (per-slot checks can each pass while their sum exhausts the
    /// pool).
    fn reserve(&mut self, slot: usize, upto: usize) {
        let need = self.kv.pages_to_cover(slot, upto);
        self.evict_for(need);
    }

    fn prefill_name(&self, bucket: usize, s: usize) -> String {
        let eng = self.eng;
        match eng.cfg.mode {
            ExecMode::Dense => format!(
                "prefill_dense_{}_b{bucket}_s{s}_t{}",
                eng.cfg.model_name, eng.cfg.kv_len
            ),
            _ => format!(
                "prefill_moe_{}_{}_b{bucket}_s{s}_t{}",
                eng.cfg.model_name,
                eng.spec_str(),
                eng.cfg.kv_len
            ),
        }
    }

    fn prefill_cont_name(&self, bucket: usize, s: usize) -> String {
        let eng = self.eng;
        match eng.cfg.mode {
            ExecMode::Dense => format!(
                "prefill_cont_dense_{}_b{bucket}_s{s}_t{}",
                eng.cfg.model_name, eng.cfg.kv_len
            ),
            _ => format!(
                "prefill_cont_moe_{}_{}_b{bucket}_s{s}_t{}",
                eng.cfg.model_name,
                eng.spec_str(),
                eng.cfg.kv_len
            ),
        }
    }

    /// Choose the artifact that carries one row's prefill `[cached, n)`
    /// furthest: `(is_cont, s, start, end)`. `end < n` is a legal
    /// partial step (the scheduler re-plans the remainder); `end` is
    /// always `> cached` or this errors.
    fn plan_row(
        &self,
        cached: usize,
        n: usize,
        mono_lens: &[usize],
        cont_lens: &[usize],
    ) -> Result<(bool, usize, usize, usize)> {
        let max_mono = *mono_lens.last().ok_or_else(|| anyhow!("no prefill length available"))?;
        if cached == 0 {
            // fresh row: smallest covering monolithic length, capped at
            // the largest compiled one (the remainder continues later)
            let end = n.min(max_mono);
            let s = mono_lens.iter().copied().find(|&l| l >= end).unwrap_or(max_mono);
            return Ok((false, s, 0, end));
        }
        let l = n - cached;
        // full coverage: smallest cont s with l <= s <= n — the row
        // back-extends into cached tokens; the overlap is recomputed
        // bit-identically and not re-stored
        if let Some(s) = cont_lens.iter().copied().find(|&s| s >= l && s <= n) {
            return Ok((true, s, n - s, n));
        }
        // partial coverage: the largest cont s that fits entirely in
        // fresh tokens
        if let Some(s) = cont_lens.iter().rev().copied().find(|&s| s <= l) {
            return Ok((true, s, cached, cached + s));
        }
        // no usable continuation artifact: recompute [0, end) through
        // the monolithic prefill and store only [cached, end) — left
        // alignment makes the recomputed prefix bit-identical, so
        // correctness never depends on the cont grid
        let end = n.min(max_mono);
        if end <= cached {
            bail!(
                "prefill continuation impossible: {cached} tokens cached, largest monolithic \
                 prefill s={max_mono}, no cont artifact covers the suffix"
            );
        }
        let s = mono_lens.iter().copied().find(|&l2| l2 >= end).unwrap_or(max_mono);
        Ok((false, s, 0, end))
    }

    /// Record a slot's full-page prompt prefix in the prefix cache.
    fn insert_prefix(&mut self, slot: usize, covered: &[usize]) {
        let Some(cache) = &mut self.cache else { return };
        let page = self.kv.page_len();
        let full = covered.len() / page;
        if full == 0 {
            return;
        }
        let pages: Vec<usize> = self.kv.slot_pages(slot)[..full].to_vec();
        cache.insert(&covered[..full * page], &pages, self.kv.pages_mut());
    }

    /// Batched monolithic prefill of one same-`s` group. Rows are
    /// left-aligned, so row `r` computes `prompts[r][..end]` from
    /// position 0 and stores KV `[cached, end)` into its slot.
    fn prefill_mono_group(
        &mut self,
        s: usize,
        rows: &[RowPlan],
        prompts: &[&[usize]],
        out: &mut [Option<PrefillOutcome>],
    ) -> Result<()> {
        let eng = self.eng;
        let c = &eng.model.config;
        let (v, t) = (c.vocab, eng.cfg.kv_len);
        let bucket = self.min_bucket(rows.len());
        let name = self.prefill_name(bucket, s);

        let mut tokens = vec![0i32; bucket * s];
        for (row, r) in rows.iter().enumerate() {
            for (j, &tok) in prompts[r.idx][..r.end].iter().enumerate() {
                tokens[row * s + j] = tok as i32;
            }
        }
        let tok_buf = eng.rt.upload_i32(&tokens, &[bucket, s])?;
        let args = eng.param_args(&[&tok_buf]);
        let outb = eng.rt.execute(&name, &args).context("continuous prefill")?;
        let logits = eng.rt.download(&outb[0], &[bucket, s, v])?;
        let kv = eng.rt.download(
            &outb[1],
            &[c.n_layers, 2, bucket, c.n_heads, t, c.head_dim()],
        )?;
        for (row, r) in rows.iter().enumerate() {
            // intra-batch memory dedup: a fresh row whose raw-token
            // prefix is already cached maps those pages and stores only
            // the remainder (the compute already ran — the compute skip
            // lives in map_prefix, across steps)
            let mut have = r.cached;
            if r.cached == 0 {
                if let Some(cache) = &mut self.cache {
                    let (pages, hit) = cache.lookup(&prompts[r.idx][..r.end]);
                    if !pages.is_empty() {
                        self.kv.map_shared(r.slot, &pages, hit);
                        have = hit;
                    }
                }
            }
            self.reserve(r.slot, r.end);
            if r.end > have {
                self.kv.store_from_batch(r.slot, &kv.data, bucket, row, have, r.end);
            }
            self.insert_prefix(r.slot, &prompts[r.idx][..r.end]);
            let o = (row * s + (r.end - 1)) * v;
            // monolithic rows always compute from position 0 — even
            // when a prefix was cached (the fallback recomputes the
            // overlap), which is what the scheduler's savings meter
            // reconciles against
            out[r.idx] = Some(PrefillOutcome {
                logits: logits.data[o..o + v].to_vec(),
                pos: r.end,
                start: 0,
            });
        }
        Ok(())
    }

    /// Batched suffix-continuation prefill of one same-`s` group: each
    /// row brings `cached` resident KV tokens and computes
    /// `prompts[r][start..end]` (exactly `s` tokens, `start <= cached`)
    /// at their true positions; only `[cached, end)` is stored back, so
    /// the cached prefix — possibly shared pages — is never rewritten.
    fn prefill_cont_group(
        &mut self,
        s: usize,
        rows: &[RowPlan],
        prompts: &[&[usize]],
        out: &mut [Option<PrefillOutcome>],
    ) -> Result<()> {
        let eng = self.eng;
        let c = &eng.model.config;
        let (v, t) = (c.vocab, eng.cfg.kv_len);
        let bucket = self.min_bucket(rows.len());
        let name = self.prefill_cont_name(bucket, s);

        let mut tokens = vec![0i32; bucket * s];
        let mut starts = vec![0i32; bucket];
        let slots: Vec<usize> = rows.iter().map(|r| r.slot).collect();
        for (row, r) in rows.iter().enumerate() {
            debug_assert!(r.start <= r.cached && r.end - r.start == s, "cont row geometry");
            for (j, &tok) in prompts[r.idx][r.start..r.end].iter().enumerate() {
                tokens[row * s + j] = tok as i32;
            }
            starts[row] = r.start as i32;
        }
        // the resident prefixes ride in as the KV input; new positions
        // are scattered in-graph at start..start+s per row
        self.kv.gather_full(&slots, bucket, &mut self.kv_batch);
        let tok_buf = eng.rt.upload_i32(&tokens, &[bucket, s])?;
        let kv_buf = eng
            .rt
            .upload_f32(&self.kv_batch, &[c.n_layers, 2, bucket, c.n_heads, t, c.head_dim()])?;
        let start_buf = eng.rt.upload_i32(&starts, &[bucket])?;
        let args = eng.param_args(&[&tok_buf, &kv_buf, &start_buf]);
        let outb = eng.rt.execute(&name, &args).context("continuation prefill")?;
        let logits = eng.rt.download(&outb[0], &[bucket, s, v])?;
        let kv = eng.rt.download(
            &outb[1],
            &[c.n_layers, 2, bucket, c.n_heads, t, c.head_dim()],
        )?;
        for (row, r) in rows.iter().enumerate() {
            self.reserve(r.slot, r.end);
            self.kv.store_from_batch(r.slot, &kv.data, bucket, row, r.cached, r.end);
            self.insert_prefix(r.slot, &prompts[r.idx][..r.end]);
            let o = (row * s + (s - 1)) * v;
            // r.start < cached means bounded back-extension onto the
            // cont grid recomputed part of the cached prefix — the
            // scheduler reclaims that overlap from the savings meter
            out[r.idx] = Some(PrefillOutcome {
                logits: logits.data[o..o + v].to_vec(),
                pos: r.end,
                start: r.start,
            });
        }
        Ok(())
    }
}

/// One row of a prefill call, planned onto a concrete artifact.
struct RowPlan {
    /// Index into the call's `slots`/`prompts`.
    idx: usize,
    slot: usize,
    /// Tokens already resident in the slot (mapped or prior chunks).
    cached: usize,
    /// First computed token position (continuation rows may sit below
    /// `cached` — bounded back-extension onto the compiled grid).
    start: usize,
    /// Tokens covered after this call ([`PrefillOutcome::pos`]).
    end: usize,
}

impl StepForward for EngineStepForward<'_> {
    fn map_prefix(&mut self, slot: usize, prompt: &[usize]) -> Result<Option<usize>> {
        // a mapped prefix only skips compute through a continuation
        // artifact; without one the prefix would be recomputed anyway
        // (monolithic fallback), so report "no cache consulted" and
        // leave the sharing to the intra-batch dedup inside prefill
        let has_cont = !self.eng.prefill_cont_lens(self.buckets[0]).is_empty();
        let Some(cache) = &mut self.cache else { return Ok(None) };
        if !has_cont {
            return Ok(None);
        }
        // cap the key below the full prompt: prefill must still compute
        // the last position to produce the first token's logits
        let key_len = prompt.len().saturating_sub(1);
        let (pages, hit) = cache.lookup(&prompt[..key_len]);
        if pages.is_empty() {
            return Ok(Some(0));
        }
        self.kv.map_shared(slot, &pages, hit);
        Ok(Some(hit))
    }

    fn prefill(
        &mut self,
        slots: &[usize],
        prompts: &[&[usize]],
        cached: &[usize],
    ) -> Result<Vec<PrefillOutcome>> {
        // compiled prefill lengths; the (bucket × s) artifact grid is
        // uniform, so any configured bucket enumerates the same lengths
        let mono_lens = self.eng.prefill_lens(self.buckets[0]);
        if mono_lens.is_empty() {
            bail!(
                "no prefill artifact for model={} mode={:?} b={} t={}",
                self.eng.cfg.model_name,
                self.eng.cfg.mode,
                self.buckets[0],
                self.eng.cfg.kv_len
            );
        }
        let cont_lens = self.eng.prefill_cont_lens(self.buckets[0]);
        // plan each row onto its own artifact, then group by it — a
        // request's execution must not depend on its admission cohort
        let mut groups: std::collections::BTreeMap<(bool, usize), Vec<RowPlan>> =
            std::collections::BTreeMap::new();
        for (idx, (&slot, p)) in slots.iter().zip(prompts).enumerate() {
            let (is_cont, s, start, end) =
                self.plan_row(cached[idx], p.len(), &mono_lens, &cont_lens)?;
            groups
                .entry((is_cont, s))
                .or_default()
                .push(RowPlan { idx, slot, cached: cached[idx], start, end });
        }
        let mut out: Vec<Option<PrefillOutcome>> = (0..slots.len()).map(|_| None).collect();
        for ((is_cont, s), rows) in &groups {
            if *is_cont {
                self.prefill_cont_group(*s, rows, prompts, &mut out)?;
            } else {
                self.prefill_mono_group(*s, rows, prompts, &mut out)?;
            }
        }
        out.into_iter()
            .map(|o| o.ok_or_else(|| anyhow!("prefill group missed a member")))
            .collect()
    }

    fn decode(
        &mut self,
        slots: &[usize],
        tokens: &[i32],
        pos: &[usize],
        bucket: usize,
    ) -> Result<Vec<Vec<f32>>> {
        let eng = self.eng;
        let c = &eng.model.config;
        let (v, t, nl, h, hd) = (c.vocab, eng.cfg.kv_len, c.n_layers, c.n_heads, c.head_dim());

        self.toks_pad.clear();
        self.toks_pad.extend_from_slice(tokens);
        self.toks_pad.resize(bucket, 0);
        self.pos_pad.clear();
        self.pos_pad.extend(pos.iter().map(|&p| p as i32));
        self.pos_pad.resize(bucket, 0);
        let tok_buf = eng.rt.upload_i32(&self.toks_pad, &[bucket])?;
        let pos_buf = eng.rt.upload_i32(&self.pos_pad, &[bucket])?;

        // grow page tables before the step (may evict cache holds);
        // the artifact only writes position pos[i] of row i, so that
        // is the only token the scatter below stores back. Reserve the
        // AGGREGATE need: per-slot checks could each see enough
        // headroom while their sum exhausts the pool mid-scatter.
        let mut need = 0usize;
        for (&slot, &p) in slots.iter().zip(pos) {
            need += self.kv.pages_to_cover(slot, p + 1);
        }
        self.evict_for(need);
        let logits = match eng.cfg.mode {
            ExecMode::Dense | ExecMode::MoeMonolithic => {
                self.kv.gather_full(slots, bucket, &mut self.kv_batch);
                let kv_buf = eng.rt.upload_f32(&self.kv_batch, &[nl, 2, bucket, h, t, hd])?;
                let name = match eng.cfg.mode {
                    ExecMode::Dense => format!(
                        "decode_dense_{}_b{bucket}_t{t}",
                        eng.cfg.model_name
                    ),
                    _ => format!(
                        "decode_moe_{}_{}_b{bucket}_t{t}",
                        eng.cfg.model_name,
                        eng.spec_str()
                    ),
                };
                let args = eng.param_args(&[&tok_buf, &kv_buf, &pos_buf]);
                let mut outb = eng.rt.execute(&name, &args).context("continuous decode")?;
                let kv_new = outb.pop().ok_or_else(|| anyhow!("decode: no kv"))?;
                let logits = eng.rt.download(&outb[0], &[bucket, v])?;
                let kv_host = eng.rt.download(&kv_new, &[nl, 2, bucket, h, t, hd])?;
                for (i, (&slot, &p)) in slots.iter().zip(pos).enumerate() {
                    self.kv.store_from_batch(slot, &kv_host.data, bucket, i, p, p + 1);
                }
                logits
            }
            ExecMode::MoeOrchestrated => {
                let mut kv_layers = Vec::with_capacity(nl);
                for l in 0..nl {
                    self.kv.gather_layer(l, slots, bucket, &mut self.kv_layer);
                    kv_layers.push(eng.rt.upload_f32(&self.kv_layer, &[2, bucket, h, t, hd])?);
                }
                // per-row tier ratios for the live rows; padding rows
                // run at full ratio (their logits are discarded). Skip
                // the whole cap path when every live row is untiered —
                // keeps the default configuration on the exact
                // pre-tiering code path.
                self.ratios_pad.clear();
                self.ratios_pad.extend(slots.iter().map(|&s| self.slot_ratios[s]));
                self.ratios_pad.resize(bucket, 1.0);
                let tiered = self.ratios_pad.iter().any(|&r| r < 1.0);
                let row_ratios = tiered.then_some(self.ratios_pad.as_slice());
                let logits =
                    eng.orchestrated_step(bucket, &tok_buf, &pos_buf, &mut kv_layers, row_ratios)?;
                for (l, buf) in kv_layers.iter().enumerate() {
                    let kv_host = eng.rt.download(buf, &[2, bucket, h, t, hd])?;
                    for (i, (&slot, &p)) in slots.iter().zip(pos).enumerate() {
                        self.kv
                            .store_layer_from_batch(l, slot, &kv_host.data, bucket, i, p, p + 1);
                    }
                }
                logits
            }
        };
        Ok((0..slots.len()).map(|i| logits.data[i * v..(i + 1) * v].to_vec()).collect())
    }

    fn release(&mut self, slot: usize) {
        self.kv.release(slot);
    }

    fn park(&mut self, slot: usize) -> Option<ParkedSlot> {
        // the paged pool parks in place (host memory is the "parking
        // buffer" — KV already lives host-side between steps)
        Some(self.kv.park(slot))
    }

    fn unpark(&mut self, slot: usize, parked: ParkedSlot) {
        self.kv.unpark(slot, parked);
    }

    fn drop_parked(&mut self, parked: ParkedSlot) {
        self.kv.drop_parked(parked);
    }

    fn kv_capacity(&self) -> usize {
        self.eng.cfg.kv_len
    }

    fn set_slot_ratio(&mut self, slot: usize, ratio: f32) {
        self.slot_ratios[slot] = ratio;
    }

    fn page_metrics(&self) -> Option<PageMetrics> {
        Some(PageMetrics {
            page_len: self.kv.page_len(),
            pages_in_use: self.kv.pages().pages_in_use(),
            high_water_pages: self.kv.pages().high_water_pages,
            cow_copies: self.kv.pages().cow_copies,
            shared_maps: self.kv.shared_maps,
            cached_pages: self.cache.as_ref().map_or(0, |c| c.cached_pages()),
            evicted_pages: self.cache.as_ref().map_or(0, |c| c.evicted_pages),
        })
    }
}
