//! Injectable time source for the serving stack.
//!
//! Queue-wait, hold-window, and latency accounting all need "now".
//! Production uses the wall clock; tests use a manual clock advanced
//! explicitly, so hold-window and SLO behavior is deterministic
//! instead of racing the test host. Deadline and aging logic is
//! step-denominated (see [`Request::deadline_steps`]) and does not
//! consult the clock at all.
//!
//! [`Request::deadline_steps`]: crate::serving::Request::deadline_steps

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A cloneable time source. Clones of a manual clock share the same
/// underlying time: advancing one advances all (the scheduler and the
/// batcher can hold clones of the test's clock).
#[derive(Clone, Debug)]
pub struct Clock {
    inner: ClockInner,
}

#[derive(Clone, Debug)]
enum ClockInner {
    Wall,
    Manual { epoch: Instant, nanos: Arc<AtomicU64> },
}

impl Clock {
    /// The real wall clock (`Instant::now`).
    pub fn wall() -> Self {
        Clock { inner: ClockInner::Wall }
    }

    /// A manual clock starting at an arbitrary epoch. Time only moves
    /// through [`Clock::advance`].
    pub fn manual() -> Self {
        Clock {
            inner: ClockInner::Manual {
                epoch: Instant::now(),
                nanos: Arc::new(AtomicU64::new(0)),
            },
        }
    }

    pub fn is_manual(&self) -> bool {
        matches!(self.inner, ClockInner::Manual { .. })
    }

    pub fn now(&self) -> Instant {
        match &self.inner {
            ClockInner::Wall => Instant::now(),
            ClockInner::Manual { epoch, nanos } => {
                *epoch + Duration::from_nanos(nanos.load(Ordering::SeqCst))
            }
        }
    }

    /// Advance a manual clock by `d`. No-op on a wall clock (there is
    /// nothing meaningful to do, and panicking would make shared test
    /// helpers clock-variant).
    pub fn advance(&self, d: Duration) {
        if let ClockInner::Manual { nanos, .. } = &self.inner {
            nanos.fetch_add(d.as_nanos() as u64, Ordering::SeqCst);
        }
    }
}

impl Default for Clock {
    fn default() -> Self {
        Clock::wall()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manual_clock_moves_only_on_advance() {
        let c = Clock::manual();
        let t0 = c.now();
        assert_eq!(c.now(), t0);
        c.advance(Duration::from_millis(5));
        assert_eq!(c.now() - t0, Duration::from_millis(5));
    }

    #[test]
    fn clones_share_time() {
        let a = Clock::manual();
        let b = a.clone();
        b.advance(Duration::from_secs(1));
        assert_eq!(a.now(), b.now());
    }

    #[test]
    fn wall_clock_is_monotone_and_advance_is_noop() {
        let c = Clock::wall();
        assert!(!c.is_manual());
        let t0 = c.now();
        c.advance(Duration::from_secs(3600));
        // advancing a wall clock does not jump it into the future
        assert!(c.now() < t0 + Duration::from_secs(3600));
    }
}
