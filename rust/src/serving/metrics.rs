//! Serving metrics: TTFT / per-token latency / throughput with
//! percentile summaries for the bench harness (Tables 7-9), the
//! grouped-dispatch gauges ([`DispatchMetrics`]): per-expert occupancy
//! and the scratch-arena high-water mark whose post-warmup stability is
//! the observable "zero per-wave buffer allocations" signal — and the
//! continuous-batching gauges ([`SchedulerMetrics`]): queue wait,
//! slot-pool occupancy, and slot churn under per-step admission.
//!
//! TTFT semantics differ between the two scheduling paths: the
//! run-to-completion wave path measures TTFT from wave start (queueing
//! reported separately), while the continuous scheduler measures the
//! user-perceived enqueue→first-token time, because admission happens
//! mid-flight and queue wait is part of what the scheduler controls.

use crate::util::stats::percentile;
use std::time::Duration;

/// Gauges for the continuous in-flight batching scheduler
/// (`serving::scheduler`). All counters are cumulative over the
/// engine's lifetime; per-run views come from diffing snapshots.
#[derive(Clone, Debug, Default)]
pub struct SchedulerMetrics {
    /// Decode steps executed (each runs one bucket-sized batch).
    pub decode_steps: u64,
    /// Requests admitted into a KV slot.
    pub admitted: u64,
    /// Requests retired (stop token, max_new_tokens, or KV-full).
    pub retired: u64,
    /// Admissions that reused a previously-retired slot (the pool
    /// recycles retired slots before touching fresh ones).
    pub slot_reuses: u64,
    /// Most slots live at once.
    pub peak_live: usize,
    /// Σ live rows over decode steps (numerator of occupancy).
    pub live_row_steps: u64,
    /// Σ bucket rows over decode steps (denominator of occupancy —
    /// the GEMM rows actually executed, padding included).
    pub bucket_row_steps: u64,
    /// Admissions whose prompt was checked against the prefix cache
    /// (== admissions when the backend supports prefix mapping).
    pub prefix_lookups: u64,
    /// Admissions that mapped a cached prefix instead of prefilling it.
    pub prefix_hits: u64,
    /// Prompt tokens actually prefilled (suffix only under prefix
    /// hits) — the prefill-compute meter the sharing sweep diffs.
    pub prefill_tokens: u64,
    /// Prompt tokens served from mapped prefix pages instead of
    /// prefill. `prefill_tokens + prefill_tokens_saved` equals the
    /// unshared path's prefill work on the same trace.
    pub prefill_tokens_saved: u64,
    /// Per-request enqueue→admission wait, milliseconds.
    pub queue_wait_ms: Vec<f32>,
    /// Live requests preempted to make room for a deadline-urgent
    /// higher class (park + drop paths combined).
    pub preemptions: u64,
    /// Preemptions that parked the victim's KV pages (refcounts held).
    pub preempt_parked: u64,
    /// Preemptions that dropped the victim's KV (recomputed on resume).
    pub preempt_dropped: u64,
    /// Preempted requests readmitted into a slot.
    pub resumed: u64,
    /// Context tokens re-prefilled when resuming dropped victims (the
    /// recompute cost of `PreemptMode::Drop`; prefix-cache hits during
    /// resume reduce it).
    pub preempt_recompute_tokens: u64,
    /// Requests shed by bounded admission (the backpressure signal —
    /// nonzero means the queue bound was reached and load was refused
    /// rather than buffered without bound).
    pub shed_requests: u64,
    /// Admissions accepted into the overflow margin at a degraded
    /// effort tier (the step before shedding).
    pub degraded_admissions: u64,
    /// Admissions that happened after the request's step-denominated
    /// deadline had already lapsed.
    pub deadline_misses: u64,
    /// Requests retired with a typed error (fault containment:
    /// exactly these requests failed; the session kept serving).
    pub failed: u64,
    /// Requests that left the session without ever emitting a first
    /// token (failed mid-prefill, aborted, drained before sampling).
    /// These carry `ttft: None` and are **excluded** from the TTFT
    /// percentiles — counting them as 0ms samples dragged p50/p99 down
    /// dishonestly (the bug this counter replaced).
    pub no_first_token: u64,
    /// Backend/scheduler faults absorbed without losing a request
    /// (batch isolation, prefix-map fallback, recovered invariants).
    pub faults_contained: u64,
    /// Decoded row-steps per effort tier, indexed by
    /// `EffortTier::index()` (`[full, degraded]`). One live row that
    /// decodes one token adds one to its tier's bucket.
    pub tier_row_steps: [u64; 2],
    /// Σ activation ratio over those row-steps, same indexing — the
    /// numerator of [`SchedulerMetrics::activated_fraction`]. The
    /// ratio recorded is the operating point the backend was told to
    /// run the row at (`StepForward::set_slot_ratio`), clamped to 1.
    pub tier_ratio_sum: [f64; 2],
}

impl SchedulerMetrics {
    /// Share of executed batch rows that carried a live request
    /// (1.0 = every GEMM row was real work; the wave engine's
    /// run-to-completion padding shows up here as < 1).
    pub fn occupancy(&self) -> f64 {
        if self.bucket_row_steps == 0 {
            return 0.0;
        }
        self.live_row_steps as f64 / self.bucket_row_steps as f64
    }

    /// Admissions + retirements per decode step.
    pub fn churn_per_step(&self) -> f64 {
        if self.decode_steps == 0 {
            return 0.0;
        }
        (self.admitted + self.retired) as f64 / self.decode_steps as f64
    }

    pub fn queue_wait_p50_ms(&self) -> f32 {
        percentile(&self.queue_wait_ms, 50.0)
    }

    pub fn queue_wait_p99_ms(&self) -> f32 {
        percentile(&self.queue_wait_ms, 99.0)
    }

    /// Share of prefix-cache lookups that mapped at least one page.
    pub fn prefix_hit_rate(&self) -> f64 {
        if self.prefix_lookups == 0 {
            return 0.0;
        }
        self.prefix_hits as f64 / self.prefix_lookups as f64
    }

    /// Record one decoded row at effort `tier` running at `ratio` of
    /// full activation (clamped into `[0, 1]` — ratios above 1 cannot
    /// activate more than the full expert set).
    pub fn record_tier_row(&mut self, tier: crate::serving::EffortTier, ratio: f32) {
        let i = tier.index();
        self.tier_row_steps[i] += 1;
        self.tier_ratio_sum[i] += f64::from(ratio.clamp(0.0, 1.0));
    }

    /// Mean activated-parameter fraction of `tier`'s decoded rows
    /// (1.0 = native operating point; the paper's 25% point reads
    /// 0.25 here). 0 when the tier never decoded a row.
    pub fn activated_fraction(&self, tier: crate::serving::EffortTier) -> f64 {
        let i = tier.index();
        if self.tier_row_steps[i] == 0 {
            return 0.0;
        }
        self.tier_ratio_sum[i] / self.tier_row_steps[i] as f64
    }

    /// Fold another snapshot into this one (engine-lifetime totals
    /// absorb per-session scheduler counters).
    pub fn merge(&mut self, o: &SchedulerMetrics) {
        self.decode_steps += o.decode_steps;
        self.admitted += o.admitted;
        self.retired += o.retired;
        self.slot_reuses += o.slot_reuses;
        self.peak_live = self.peak_live.max(o.peak_live);
        self.live_row_steps += o.live_row_steps;
        self.bucket_row_steps += o.bucket_row_steps;
        self.prefix_lookups += o.prefix_lookups;
        self.prefix_hits += o.prefix_hits;
        self.prefill_tokens += o.prefill_tokens;
        self.prefill_tokens_saved += o.prefill_tokens_saved;
        self.queue_wait_ms.extend_from_slice(&o.queue_wait_ms);
        self.preemptions += o.preemptions;
        self.preempt_parked += o.preempt_parked;
        self.preempt_dropped += o.preempt_dropped;
        self.resumed += o.resumed;
        self.preempt_recompute_tokens += o.preempt_recompute_tokens;
        self.shed_requests += o.shed_requests;
        self.degraded_admissions += o.degraded_admissions;
        self.deadline_misses += o.deadline_misses;
        self.failed += o.failed;
        self.no_first_token += o.no_first_token;
        self.faults_contained += o.faults_contained;
        for i in 0..self.tier_row_steps.len() {
            self.tier_row_steps[i] += o.tier_row_steps[i];
            self.tier_ratio_sum[i] += o.tier_ratio_sum[i];
        }
    }
}

/// Gauges for the paged KV pool + prefix cache (`runtime::KvSlotPool`
/// over `runtime::PagePool`, `serving::prefix_cache`). Snapshotted
/// from the step-forward backend when a session flushes.
#[derive(Clone, Debug, Default)]
pub struct PageMetrics {
    /// Tokens per page (0 until a paged backend reported).
    pub page_len: usize,
    /// Pages resident at snapshot time (live slots + cache holds).
    pub pages_in_use: usize,
    /// Most pages resident at once (monotone) — the resident-KV meter
    /// the sharing sweep diffs.
    pub high_water_pages: usize,
    /// Copy-on-write page copies (first divergent write into a shared
    /// page).
    pub cow_copies: u64,
    /// Shared-prefix mappings performed (`KvSlotPool::map_shared`).
    pub shared_maps: u64,
    /// Pages currently held by the prefix cache.
    pub cached_pages: usize,
    /// Cache pages evicted under page pressure.
    pub evicted_pages: u64,
}

impl PageMetrics {
    /// Fold a later snapshot into this one. Counters are per-backend
    /// lifetime: monotone gauges take the max, event counts accumulate
    /// across sessions (each session owns a fresh pool), and point
    /// gauges take the latest value.
    pub fn merge(&mut self, o: &PageMetrics) {
        if o.page_len != 0 {
            self.page_len = o.page_len;
        }
        self.pages_in_use = o.pages_in_use;
        self.cached_pages = o.cached_pages;
        self.high_water_pages = self.high_water_pages.max(o.high_water_pages);
        self.cow_copies += o.cow_copies;
        self.shared_maps += o.shared_maps;
        self.evicted_pages += o.evicted_pages;
    }
}

/// Gauges for the orchestrated engine's grouped expert dispatch.
#[derive(Clone, Debug, Default)]
pub struct DispatchMetrics {
    /// Cumulative tokens dispatched to each routed-expert id, summed
    /// over layers and decode steps (feeds the occupancy view).
    pub expert_tokens: Vec<u64>,
    /// Number of layer-dispatches recorded.
    pub dispatches: u64,
    /// Scratch-arena high-water mark in bytes (monotone).
    pub arena_high_water_bytes: usize,
    /// Arena growth events so far. Constant after warmup ⇔ the decode
    /// steady state performs no per-wave buffer allocations in dispatch.
    pub arena_grow_events: u64,
}

impl DispatchMetrics {
    /// Record a whole decode step at once: per-expert tokens already
    /// summed over `layers` layer-dispatches. The engine accumulates in
    /// its (already-locked) MoE state and flushes here once per step,
    /// keeping this mutex off the per-layer hot path.
    pub fn record_step(&mut self, counts: &[u64], layers: u64) {
        if self.expert_tokens.len() < counts.len() {
            self.expert_tokens.resize(counts.len(), 0);
        }
        for (acc, &c) in self.expert_tokens.iter_mut().zip(counts) {
            *acc += c;
        }
        self.dispatches += layers;
    }

    /// Update the arena gauges (monotone high-water mark + grow count).
    pub fn record_arena(&mut self, high_water_bytes: usize, grow_events: u64) {
        self.arena_high_water_bytes = self.arena_high_water_bytes.max(high_water_bytes);
        self.arena_grow_events = self.arena_grow_events.max(grow_events);
    }

    /// Per-expert share of all dispatched tokens (sums to 1 when any
    /// token was dispatched).
    pub fn occupancy(&self) -> Vec<f64> {
        let total: u64 = self.expert_tokens.iter().sum();
        self.expert_tokens
            .iter()
            .map(|&c| if total == 0 { 0.0 } else { c as f64 / total as f64 })
            .collect()
    }
}

/// Gauges for the quantized expert-storage residency tier
/// (`moe::TieredStore` behind `EngineConfig::quant_experts`). All
/// counters are expert-step events summed over layers: one layer-step
/// that routes tokens to a warm expert is one hit regardless of how
/// many tokens rode the band.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ResidencyMetrics {
    /// Routed-to experts that were dispatch-warm (`Fp32Resident` /
    /// `Int8Resident`) at their layer-step.
    pub hits: u64,
    /// Routed-to experts that were `Int8Host` — dispatches the
    /// promotion policy failed to prefetch ahead of.
    pub misses: u64,
    /// Promotions `Int8Host → Int8Resident` (the routing trend warmed
    /// an expert back up).
    pub prefetches: u64,
    /// Evictions `Int8Resident → Int8Host` under the resident cap.
    pub demotions: u64,
}

impl ResidencyMetrics {
    /// Fold one decode step's accumulated residency transitions in
    /// (the engine flushes once per step, not per layer).
    pub fn observe(&mut self, d: &crate::moe::ResidencyDelta) {
        self.hits += d.hits;
        self.misses += d.misses;
        self.prefetches += d.prefetches;
        self.demotions += d.demotions;
    }

    /// Share of routed-expert dispatches that found the expert warm.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            return 0.0;
        }
        self.hits as f64 / total as f64
    }

    pub fn merge(&mut self, o: &ResidencyMetrics) {
        self.hits += o.hits;
        self.misses += o.misses;
        self.prefetches += o.prefetches;
        self.demotions += o.demotions;
    }
}

/// Metrics for one wave.
#[derive(Clone, Debug, Default)]
pub struct WaveMetrics {
    pub batch: usize,
    pub prompt_tokens: usize,
    pub generated_tokens: usize,
    pub prefill: Duration,
    pub decode: Duration,
    pub decode_steps: usize,
}

impl WaveMetrics {
    /// Decode throughput in generated tokens per second.
    pub fn decode_tps(&self) -> f64 {
        if self.decode.is_zero() {
            return 0.0;
        }
        self.generated_tokens as f64 / self.decode.as_secs_f64()
    }

    /// Mean time-per-output-token across the wave.
    pub fn tpot(&self) -> Duration {
        if self.decode_steps == 0 {
            return Duration::ZERO;
        }
        self.decode / self.decode_steps as u32
    }
}

/// Aggregated engine metrics.
#[derive(Clone, Debug, Default)]
pub struct EngineMetrics {
    pub waves: Vec<WaveMetrics>,
    pub ttfts_ms: Vec<f32>,
    pub latencies_ms: Vec<f32>,
    /// Grouped-dispatch gauges (orchestrated mode only; stays at its
    /// default for dense/monolithic engines).
    pub dispatch: DispatchMetrics,
    /// Continuous-batching gauges (stays at its default when only the
    /// run-to-completion wave path ran).
    pub scheduler: SchedulerMetrics,
    /// Paged-KV gauges (stays at its default until a paged backend
    /// session flushes).
    pub pages: PageMetrics,
    /// Expert-storage residency gauges (stays at its default unless
    /// the engine runs with `quant_experts`).
    pub residency: ResidencyMetrics,
}

impl EngineMetrics {
    pub fn record_wave(&mut self, w: WaveMetrics) {
        self.waves.push(w);
    }

    /// Record one completed request. `ttft: None` means the request
    /// never emitted a first token — it contributes a latency sample
    /// but **no** TTFT sample (a 0ms default here skewed the TTFT
    /// percentiles down; such requests are counted in
    /// [`SchedulerMetrics::no_first_token`] instead).
    pub fn record_request(&mut self, ttft: Option<Duration>, latency: Duration) {
        if let Some(t) = ttft {
            self.ttfts_ms.push(t.as_secs_f32() * 1e3);
        }
        self.latencies_ms.push(latency.as_secs_f32() * 1e3);
    }

    pub fn total_generated(&self) -> usize {
        self.waves.iter().map(|w| w.generated_tokens).sum()
    }

    pub fn total_decode_time(&self) -> Duration {
        self.waves.iter().map(|w| w.decode).sum()
    }

    /// Aggregate decode throughput (tok/s).
    pub fn decode_tps(&self) -> f64 {
        let t = self.total_decode_time().as_secs_f64();
        if t == 0.0 {
            return 0.0;
        }
        self.total_generated() as f64 / t
    }

    pub fn ttft_p50_ms(&self) -> f32 {
        percentile(&self.ttfts_ms, 50.0)
    }

    pub fn ttft_p99_ms(&self) -> f32 {
        percentile(&self.ttfts_ms, 99.0)
    }

    pub fn latency_p50_ms(&self) -> f32 {
        percentile(&self.latencies_ms, 50.0)
    }

    pub fn summary(&self) -> String {
        let mut s = format!(
            "{} waves, {} tokens, decode {:.1} tok/s, TTFT p50 {:.1}ms p99 {:.1}ms",
            self.waves.len(),
            self.total_generated(),
            self.decode_tps(),
            self.ttft_p50_ms(),
            self.ttft_p99_ms(),
        );
        if self.dispatch.dispatches > 0 {
            s.push_str(&format!(
                ", dispatch arena {}KiB ({} growths)",
                self.dispatch.arena_high_water_bytes / 1024,
                self.dispatch.arena_grow_events,
            ));
        }
        if self.scheduler.decode_steps > 0 {
            s.push_str(&format!(
                ", sched occupancy {:.0}% churn {:.2}/step queue-wait p50 {:.1}ms",
                self.scheduler.occupancy() * 100.0,
                self.scheduler.churn_per_step(),
                self.scheduler.queue_wait_p50_ms(),
            ));
        }
        if self.scheduler.prefix_lookups > 0 {
            s.push_str(&format!(
                ", prefix hit {:.0}% ({} tok reused)",
                self.scheduler.prefix_hit_rate() * 100.0,
                self.scheduler.prefill_tokens_saved,
            ));
        }
        if self.scheduler.preemptions > 0 || self.scheduler.shed_requests > 0 {
            s.push_str(&format!(
                ", overload: {} preempted ({} parked/{} dropped, {} resumed), {} shed, {} degraded, {} deadline misses",
                self.scheduler.preemptions,
                self.scheduler.preempt_parked,
                self.scheduler.preempt_dropped,
                self.scheduler.resumed,
                self.scheduler.shed_requests,
                self.scheduler.degraded_admissions,
                self.scheduler.deadline_misses,
            ));
        }
        if self.scheduler.tier_row_steps[1] > 0 {
            use crate::serving::EffortTier;
            s.push_str(&format!(
                ", tiers: degraded {} rows @ {:.0}% activation (full {} rows @ {:.0}%)",
                self.scheduler.tier_row_steps[1],
                self.scheduler.activated_fraction(EffortTier::Degraded) * 100.0,
                self.scheduler.tier_row_steps[0],
                self.scheduler.activated_fraction(EffortTier::Full) * 100.0,
            ));
        }
        if self.scheduler.failed > 0 || self.scheduler.faults_contained > 0 {
            s.push_str(&format!(
                ", faults: {} contained, {} requests failed",
                self.scheduler.faults_contained, self.scheduler.failed,
            ));
        }
        if self.scheduler.no_first_token > 0 {
            s.push_str(&format!(
                ", {} requests never reached a first token (excluded from TTFT)",
                self.scheduler.no_first_token,
            ));
        }
        if self.pages.high_water_pages > 0 {
            s.push_str(&format!(
                ", kv pages hw {} (cow {}, cached {}, evicted {})",
                self.pages.high_water_pages,
                self.pages.cow_copies,
                self.pages.cached_pages,
                self.pages.evicted_pages,
            ));
        }
        if self.residency.hits + self.residency.misses > 0 {
            s.push_str(&format!(
                ", expert residency hit {:.0}% ({} prefetches, {} demotions)",
                self.residency.hit_rate() * 100.0,
                self.residency.prefetches,
                self.residency.demotions,
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wave_tps() {
        let w = WaveMetrics {
            batch: 8,
            prompt_tokens: 64,
            generated_tokens: 80,
            prefill: Duration::from_millis(10),
            decode: Duration::from_millis(200),
            decode_steps: 10,
        };
        assert!((w.decode_tps() - 400.0).abs() < 1e-6);
        assert_eq!(w.tpot(), Duration::from_millis(20));
    }

    #[test]
    fn engine_aggregation() {
        let mut m = EngineMetrics::default();
        for _ in 0..3 {
            m.record_wave(WaveMetrics {
                batch: 1,
                prompt_tokens: 4,
                generated_tokens: 10,
                prefill: Duration::from_millis(5),
                decode: Duration::from_millis(100),
                decode_steps: 10,
            });
            m.record_request(Some(Duration::from_millis(5)), Duration::from_millis(105));
        }
        assert_eq!(m.total_generated(), 30);
        assert!((m.decode_tps() - 100.0).abs() < 1.0);
        assert!(m.summary().contains("3 waves"));
    }

    #[test]
    fn no_first_token_requests_do_not_skew_ttft_percentiles() {
        let mut m = EngineMetrics::default();
        m.record_request(Some(Duration::from_millis(10)), Duration::from_millis(50));
        m.record_request(Some(Duration::from_millis(20)), Duration::from_millis(60));
        // a request that died before its first token: latency sample
        // only — no 0ms TTFT dragging the percentiles down
        m.record_request(None, Duration::from_millis(5));
        m.scheduler.no_first_token += 1;
        assert_eq!(m.ttfts_ms.len(), 2);
        assert_eq!(m.latencies_ms.len(), 3);
        assert!(m.ttft_p50_ms() >= 10.0, "p50 = {}", m.ttft_p50_ms());
        assert!(m.summary().contains("1 requests never reached a first token"));
    }

    #[test]
    fn empty_metrics_are_safe() {
        let m = EngineMetrics::default();
        assert_eq!(m.decode_tps(), 0.0);
        assert_eq!(m.ttft_p50_ms(), 0.0);
        assert!(m.dispatch.occupancy().is_empty());
        assert!(!m.summary().contains("dispatch arena"));
    }

    #[test]
    fn scheduler_gauges() {
        let mut s = SchedulerMetrics::default();
        assert_eq!(s.occupancy(), 0.0);
        assert_eq!(s.churn_per_step(), 0.0);
        s.decode_steps = 10;
        s.admitted = 6;
        s.retired = 4;
        s.live_row_steps = 30;
        s.bucket_row_steps = 40;
        s.queue_wait_ms = vec![1.0, 3.0, 5.0];
        assert!((s.occupancy() - 0.75).abs() < 1e-12);
        assert!((s.churn_per_step() - 1.0).abs() < 1e-12);
        assert!(s.queue_wait_p50_ms() >= 1.0 && s.queue_wait_p50_ms() <= 5.0);

        let mut t = SchedulerMetrics { peak_live: 2, ..Default::default() };
        t.merge(&s);
        assert_eq!(t.decode_steps, 10);
        assert_eq!(t.peak_live, 2.max(s.peak_live));
        assert_eq!(t.queue_wait_ms.len(), 3);

        let mut m = EngineMetrics::default();
        assert!(!m.summary().contains("sched occupancy"));
        m.scheduler.merge(&s);
        assert!(m.summary().contains("sched occupancy 75%"));
    }

    #[test]
    fn overload_gauges_merge_and_summarize() {
        let s = SchedulerMetrics {
            decode_steps: 1,
            preemptions: 3,
            preempt_parked: 2,
            preempt_dropped: 1,
            resumed: 3,
            preempt_recompute_tokens: 12,
            shed_requests: 4,
            degraded_admissions: 2,
            deadline_misses: 1,
            failed: 1,
            faults_contained: 5,
            ..Default::default()
        };
        let mut t = SchedulerMetrics::default();
        t.merge(&s);
        t.merge(&s);
        assert_eq!(t.preemptions, 6);
        assert_eq!(t.preempt_parked, 4);
        assert_eq!(t.resumed, 6);
        assert_eq!(t.preempt_recompute_tokens, 24);
        assert_eq!(t.shed_requests, 8);
        assert_eq!(t.degraded_admissions, 4);
        assert_eq!(t.deadline_misses, 2);
        assert_eq!(t.failed, 2);
        assert_eq!(t.faults_contained, 10);

        // summary segments appear only when the machinery fired
        let quiet = EngineMetrics::default();
        assert!(!quiet.summary().contains("overload:"));
        assert!(!quiet.summary().contains("faults:"));
        let mut m = EngineMetrics::default();
        m.scheduler.merge(&s);
        let sum = m.summary();
        assert!(sum.contains("overload: 3 preempted (2 parked/1 dropped, 3 resumed)"));
        assert!(sum.contains("4 shed"));
        assert!(sum.contains("faults: 5 contained, 1 requests failed"));
    }

    #[test]
    fn tier_gauges_meter_activated_fraction() {
        use crate::serving::EffortTier;
        let mut s = SchedulerMetrics::default();
        assert_eq!(s.activated_fraction(EffortTier::Full), 0.0);
        assert_eq!(s.activated_fraction(EffortTier::Degraded), 0.0);
        for _ in 0..4 {
            s.record_tier_row(EffortTier::Full, 1.0);
        }
        for _ in 0..2 {
            s.record_tier_row(EffortTier::Degraded, 0.25);
        }
        // ratios above 1 clamp: full effort can't exceed the full set
        s.record_tier_row(EffortTier::Full, 1.5);
        assert_eq!(s.tier_row_steps, [5, 2]);
        assert!((s.activated_fraction(EffortTier::Full) - 1.0).abs() < 1e-12);
        assert!((s.activated_fraction(EffortTier::Degraded) - 0.25).abs() < 1e-12);

        // merge is elementwise; summary segment appears only when a
        // degraded row actually decoded
        let mut t = SchedulerMetrics::default();
        t.merge(&s);
        t.merge(&s);
        assert_eq!(t.tier_row_steps, [10, 4]);
        assert!((t.activated_fraction(EffortTier::Degraded) - 0.25).abs() < 1e-12);
        let quiet = EngineMetrics::default();
        assert!(!quiet.summary().contains("tiers:"));
        let mut m = EngineMetrics::default();
        m.scheduler.merge(&s);
        assert!(m.summary().contains("tiers: degraded 2 rows @ 25% activation"));
    }

    #[test]
    fn prefix_and_page_gauges() {
        let mut s = SchedulerMetrics::default();
        assert_eq!(s.prefix_hit_rate(), 0.0);
        s.prefix_lookups = 4;
        s.prefix_hits = 3;
        s.prefill_tokens = 10;
        s.prefill_tokens_saved = 30;
        assert!((s.prefix_hit_rate() - 0.75).abs() < 1e-12);
        let mut t = SchedulerMetrics::default();
        t.merge(&s);
        t.merge(&s);
        assert_eq!(t.prefix_hits, 6);
        assert_eq!(t.prefill_tokens_saved, 60);

        let mut m = EngineMetrics::default();
        assert!(!m.summary().contains("prefix hit"));
        assert!(!m.summary().contains("kv pages"));
        m.scheduler.merge(&s);
        assert!(m.summary().contains("prefix hit 75%"));
        let snap = PageMetrics {
            page_len: 4,
            pages_in_use: 5,
            high_water_pages: 9,
            cow_copies: 2,
            shared_maps: 3,
            cached_pages: 4,
            evicted_pages: 1,
        };
        m.pages.merge(&snap);
        assert!(m.summary().contains("kv pages hw 9"));
        // monotone gauges keep the max, event counts accumulate
        m.pages.merge(&PageMetrics { high_water_pages: 7, cow_copies: 1, ..Default::default() });
        assert_eq!(m.pages.high_water_pages, 9);
        assert_eq!(m.pages.cow_copies, 3);
        assert_eq!(m.pages.page_len, 4, "point gauges survive empty snapshots");
    }

    #[test]
    fn residency_gauges_observe_merge_and_summarize() {
        let mut r = ResidencyMetrics::default();
        assert_eq!(r.hit_rate(), 0.0, "no dispatches → 0, not NaN");
        r.observe(&crate::moe::ResidencyDelta {
            hits: 3,
            misses: 1,
            prefetches: 1,
            demotions: 1,
        });
        assert!((r.hit_rate() - 0.75).abs() < 1e-12);
        let mut t = ResidencyMetrics::default();
        t.merge(&r);
        t.merge(&r);
        assert_eq!(t.hits, 6);
        assert_eq!(t.misses, 2);
        assert_eq!(t.prefetches, 2);
        assert_eq!(t.demotions, 2);

        // summary segment appears only when quantized storage dispatched
        let quiet = EngineMetrics::default();
        assert!(!quiet.summary().contains("expert residency"));
        let mut m = EngineMetrics::default();
        m.residency.merge(&r);
        assert!(m.summary().contains("expert residency hit 75% (1 prefetches, 1 demotions)"));
    }

    #[test]
    fn dispatch_gauges_accumulate() {
        let mut d = DispatchMetrics::default();
        d.record_step(&[3, 0, 1], 1);
        d.record_step(&[1, 2, 1], 1);
        assert_eq!(d.expert_tokens, vec![4, 2, 2]);
        assert_eq!(d.dispatches, 2);
        let occ = d.occupancy();
        assert!((occ.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!((occ[0] - 0.5).abs() < 1e-12);
        // arena gauges are monotone
        d.record_arena(4096, 1);
        d.record_arena(2048, 1);
        assert_eq!(d.arena_high_water_bytes, 4096);
        assert_eq!(d.arena_grow_events, 1);
        // counts may widen if a later layer has more experts, and a
        // step may flush several layers at once
        d.record_step(&[0, 0, 0, 5], 6);
        assert_eq!(d.expert_tokens, vec![4, 2, 2, 5]);
        assert_eq!(d.dispatches, 8);
    }
}
