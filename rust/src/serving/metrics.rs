//! Serving metrics: TTFT / per-token latency / throughput, with
//! percentile summaries for the bench harness (Tables 7-9).

use crate::util::stats::percentile;
use std::time::Duration;

/// Metrics for one wave.
#[derive(Clone, Debug, Default)]
pub struct WaveMetrics {
    pub batch: usize,
    pub prompt_tokens: usize,
    pub generated_tokens: usize,
    pub prefill: Duration,
    pub decode: Duration,
    pub decode_steps: usize,
}

impl WaveMetrics {
    /// Decode throughput in generated tokens per second.
    pub fn decode_tps(&self) -> f64 {
        if self.decode.is_zero() {
            return 0.0;
        }
        self.generated_tokens as f64 / self.decode.as_secs_f64()
    }

    /// Mean time-per-output-token across the wave.
    pub fn tpot(&self) -> Duration {
        if self.decode_steps == 0 {
            return Duration::ZERO;
        }
        self.decode / self.decode_steps as u32
    }
}

/// Aggregated engine metrics.
#[derive(Clone, Debug, Default)]
pub struct EngineMetrics {
    pub waves: Vec<WaveMetrics>,
    pub ttfts_ms: Vec<f32>,
    pub latencies_ms: Vec<f32>,
}

impl EngineMetrics {
    pub fn record_wave(&mut self, w: WaveMetrics) {
        self.waves.push(w);
    }

    pub fn record_request(&mut self, ttft: Duration, latency: Duration) {
        self.ttfts_ms.push(ttft.as_secs_f32() * 1e3);
        self.latencies_ms.push(latency.as_secs_f32() * 1e3);
    }

    pub fn total_generated(&self) -> usize {
        self.waves.iter().map(|w| w.generated_tokens).sum()
    }

    pub fn total_decode_time(&self) -> Duration {
        self.waves.iter().map(|w| w.decode).sum()
    }

    /// Aggregate decode throughput (tok/s).
    pub fn decode_tps(&self) -> f64 {
        let t = self.total_decode_time().as_secs_f64();
        if t == 0.0 {
            return 0.0;
        }
        self.total_generated() as f64 / t
    }

    pub fn ttft_p50_ms(&self) -> f32 {
        percentile(&self.ttfts_ms, 50.0)
    }

    pub fn ttft_p99_ms(&self) -> f32 {
        percentile(&self.ttfts_ms, 99.0)
    }

    pub fn latency_p50_ms(&self) -> f32 {
        percentile(&self.latencies_ms, 50.0)
    }

    pub fn summary(&self) -> String {
        format!(
            "{} waves, {} tokens, decode {:.1} tok/s, TTFT p50 {:.1}ms p99 {:.1}ms",
            self.waves.len(),
            self.total_generated(),
            self.decode_tps(),
            self.ttft_p50_ms(),
            self.ttft_p99_ms(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wave_tps() {
        let w = WaveMetrics {
            batch: 8,
            prompt_tokens: 64,
            generated_tokens: 80,
            prefill: Duration::from_millis(10),
            decode: Duration::from_millis(200),
            decode_steps: 10,
        };
        assert!((w.decode_tps() - 400.0).abs() < 1e-6);
        assert_eq!(w.tpot(), Duration::from_millis(20));
    }

    #[test]
    fn engine_aggregation() {
        let mut m = EngineMetrics::default();
        for _ in 0..3 {
            m.record_wave(WaveMetrics {
                batch: 1,
                prompt_tokens: 4,
                generated_tokens: 10,
                prefill: Duration::from_millis(5),
                decode: Duration::from_millis(100),
                decode_steps: 10,
            });
            m.record_request(Duration::from_millis(5), Duration::from_millis(105));
        }
        assert_eq!(m.total_generated(), 30);
        assert!((m.decode_tps() - 100.0).abs() < 1.0);
        assert!(m.summary().contains("3 waves"));
    }

    #[test]
    fn empty_metrics_are_safe() {
        let m = EngineMetrics::default();
        assert_eq!(m.decode_tps(), 0.0);
        assert_eq!(m.ttft_p50_ms(), 0.0);
    }
}
