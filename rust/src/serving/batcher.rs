//! The admission queue: priority-class request intake for both
//! scheduling paths.
//!
//! * **Continuous scheduler** (the default engine path): the session
//!   polls [`Batcher::peek_next`]/[`Batcher::pop_next`] once per free
//!   KV slot at every step. Admission is FIFO *within* a class and
//!   class-ordered across classes, with two promotions layered on
//!   top: a queued request whose step-denominated deadline is about
//!   to lapse is admitted first (SLO urgency), and a request queued
//!   longer than `age_promote_steps` outranks fresher higher classes
//!   (anti-starvation aging). An all-[`Priority::Normal`] workload
//!   degenerates to the original FIFO batcher exactly.
//! * **Run-to-completion waves** (reference/benchmark path):
//!   [`Batcher::take_wave`] forms the largest available batch that fits
//!   a compiled bucket size (e.g. {1, 8, 32}), waiting up to `max_wait`
//!   for more arrivals when the queue is smaller than the largest
//!   bucket. Prompts inside a wave are left-padded bucket-wise by the
//!   engine.
//!
//! **Backpressure**: with `queue_cap` set, each class queue is
//! bounded. Arrivals past the cap are first degraded
//! ([`EffortTier::Degraded`] — served at the reduced activation
//! ratio in `BatcherConfig::tier_ratios`) into a small overflow
//! margin, then shed with a typed
//! [`SubmitOutcome::Rejected`] — queue memory is bounded by
//! `3 × (queue_cap + degrade_margin)` entries no matter the burst.

use crate::serving::clock::Clock;
use crate::serving::request::{EffortTier, Priority, Request};
use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// A `BatcherConfig` that cannot form a valid scheduler: surfaced as
/// a typed error instead of a panic deep in `Scheduler::new`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConfigError {
    /// `buckets` is empty — there is no batch shape to compile for.
    NoBuckets,
    /// A bucket of 0 rows can never hold a request.
    ZeroBucket,
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::NoBuckets => write!(f, "batcher config: need at least one batch bucket"),
            ConfigError::ZeroBucket => write!(f, "batcher config: bucket size 0 is invalid"),
        }
    }
}

impl std::error::Error for ConfigError {}

/// How the scheduler makes room for a deadline-urgent higher class
/// when the pool is full.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PreemptMode {
    /// Never preempt (the pre-SLO behavior).
    #[default]
    Off,
    /// Park the victim's KV pages (refcounts held, nothing recomputed;
    /// pages stay resident while parked). Falls back to `Drop` when
    /// the backend cannot park.
    Park,
    /// Release the victim's pages and recompute its context through
    /// the prefix cache on resume (cheapest memory, costs prefill).
    Drop,
}

/// Batcher policy.
#[derive(Clone, Debug)]
pub struct BatcherConfig {
    /// Compiled batch buckets, ascending (from the artifact manifest).
    pub buckets: Vec<usize>,
    /// How long to hold a non-full wave open for late arrivals.
    pub max_wait: Duration,
    /// Bound on each class queue (None = unbounded, the legacy
    /// behavior). Arrivals past the cap degrade, then shed.
    pub queue_cap: Option<usize>,
    /// Extra per-class entries accepted as [`EffortTier::Degraded`]
    /// once the cap is reached (the degrade-before-shed step). Only
    /// meaningful with `queue_cap` set.
    pub degrade_margin: usize,
    /// Anti-starvation aging: a request queued at least this many
    /// scheduler steps is admitted ahead of fresher higher classes.
    /// `u64::MAX` disables aging.
    pub age_promote_steps: u64,
    /// Preemption policy for deadline-urgent higher classes.
    pub preempt: PreemptMode,
    /// Effort-tier → activation-ratio operating points. The session
    /// resolves each admitted request's tier through this table and
    /// pushes the ratio to the backend (`StepForward::set_slot_ratio`),
    /// so [`EffortTier::Degraded`] rows really run cheaper. Defaults
    /// (1.0 / 0.25) keep `Full`-tier output bit-identical to the
    /// untiered scheduler.
    pub tier_ratios: crate::serving::TierRatios,
    /// Per-step prefill token budget (chunked prefill). Each scheduler
    /// step spends at most this many prompt tokens on prefill work, in
    /// admission order, before running the decode batch — so one long
    /// prompt is spread over several steps instead of freezing every
    /// live decode behind a monolithic prefill. `0` disables chunking
    /// (each admission prefills its whole prompt in its admission
    /// step). Chunking is token-invisible: output streams are
    /// bit-identical at any budget.
    pub prefill_chunk_tokens: usize,
}

/// Default per-step prefill chunk budget in prompt tokens
/// ([`BatcherConfig::prefill_chunk_tokens`]). Sized so typical chat
/// prompts still prefill in one step while a multi-thousand-token
/// prompt is spread over several, bounding the decode stall any single
/// step can suffer. Mirror-drift registered:
/// `scripts/mirror_chunked_prefill.py` must agree, checked by
/// `cmoe lint` (see `lint::drift::REGISTRY`).
pub const DEFAULT_PREFILL_CHUNK_TOKENS: usize = 256;

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            buckets: vec![1, 8, 32],
            max_wait: Duration::from_millis(2),
            queue_cap: None,
            degrade_margin: 0,
            age_promote_steps: u64::MAX,
            preempt: PreemptMode::Off,
            tier_ratios: crate::serving::TierRatios::default(),
            prefill_chunk_tokens: DEFAULT_PREFILL_CHUNK_TOKENS,
        }
    }
}

impl BatcherConfig {
    /// The single validation primitive: the bucket list sorted and
    /// deduped, or a typed error. Every scheduling surface
    /// (`Batcher::new`, `Scheduler::new`, `Engine::new`) funnels
    /// through this instead of asserting.
    pub fn normalized(&self) -> Result<Vec<usize>, ConfigError> {
        if self.buckets.is_empty() {
            return Err(ConfigError::NoBuckets);
        }
        if self.buckets.contains(&0) {
            return Err(ConfigError::ZeroBucket);
        }
        let mut b = self.buckets.clone();
        b.sort_unstable();
        b.dedup();
        Ok(b)
    }
}

/// Why a request was shed instead of queued.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShedLoad {
    /// The class whose bounded queue was full.
    pub priority: Priority,
    /// Queue depth (including the degrade margin) at rejection time.
    pub queue_len: usize,
}

impl std::fmt::Display for ShedLoad {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "shed load: {} queue full ({} queued)",
            self.priority.name(),
            self.queue_len
        )
    }
}

/// Typed admission outcome for a submitted request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitOutcome {
    /// Queued normally.
    Queued,
    /// Queued, but degraded to a lower effort tier to fit the
    /// overflow margin of a full class queue.
    QueuedDegraded,
    /// Shed: the bounded queue (cap + margin) is full. The request
    /// was not enqueued.
    Rejected(ShedLoad),
}

impl SubmitOutcome {
    pub fn is_queued(&self) -> bool {
        !matches!(self, SubmitOutcome::Rejected(_))
    }
}

/// The single bucket-policy primitive every scheduling surface shares
/// (batcher waves, the continuous scheduler, the engine's step
/// forward, the wave simulator): smallest bucket ≥ `n`, or the largest
/// when `n` exceeds them all. `buckets` must be ascending and
/// non-empty.
pub fn covering_bucket(buckets: &[usize], n: usize) -> usize {
    debug_assert!(buckets.windows(2).all(|w| w[0] <= w[1]), "buckets must be ascending");
    match buckets.iter().find(|&&b| n <= b) {
        Some(&b) => b,
        // lint: allow(panic-discipline) — documented precondition: `buckets` is non-empty (every caller holds a BatcherConfig::normalized() list, which rejects empty); the panic is the precondition's debug surface, not a request-path failure
        None => *buckets.last().expect("covering_bucket: empty bucket list"),
    }
}

struct Queued {
    req: Request,
    enqueued: Instant,
    /// Scheduler step at arrival (0 on the wave path) — the basis for
    /// deadline urgency and aging, both step-denominated.
    arrival_step: u64,
    /// Global FIFO sequence, so cross-class drains keep exact arrival
    /// order.
    seq: u64,
}

/// Per-class FIFO queues + wave former. Thread-safe wrapper lives in
/// the engine.
pub struct Batcher {
    cfg: BatcherConfig,
    queues: [VecDeque<Queued>; 3],
    clock: Clock,
    next_seq: u64,
}

impl Batcher {
    pub fn new(cfg: BatcherConfig) -> Result<Self, ConfigError> {
        Batcher::with_clock(cfg, Clock::wall())
    }

    pub fn with_clock(cfg: BatcherConfig, clock: Clock) -> Result<Self, ConfigError> {
        let buckets = cfg.normalized()?;
        let mut cfg = cfg;
        cfg.buckets = buckets;
        Ok(Batcher {
            cfg,
            queues: [VecDeque::new(), VecDeque::new(), VecDeque::new()],
            clock,
            next_seq: 0,
        })
    }

    /// Enqueue at the current clock time, arrival step 0 (wave path,
    /// which has no step counter). The continuous session uses
    /// [`Batcher::push_at`] so deadlines and aging see real steps.
    pub fn push(&mut self, r: Request) -> SubmitOutcome {
        let now = self.clock.now();
        self.push_at(r, now, 0)
    }

    /// Enqueue with an explicit arrival time and scheduler step.
    /// Applies the bounded-queue policy: under `queue_cap`, arrivals
    /// past the cap are degraded into the overflow margin, then shed.
    pub fn push_at(&mut self, mut r: Request, now: Instant, step: u64) -> SubmitOutcome {
        let c = r.priority.index();
        let mut outcome = SubmitOutcome::Queued;
        if let Some(cap) = self.cfg.queue_cap {
            let len = self.queues[c].len();
            if len >= cap + self.cfg.degrade_margin {
                return SubmitOutcome::Rejected(ShedLoad { priority: r.priority, queue_len: len });
            }
            if len >= cap {
                r.tier = EffortTier::Degraded;
                outcome = SubmitOutcome::QueuedDegraded;
            }
        }
        let seq = self.next_seq;
        self.next_seq += 1;
        self.queues[c].push_back(Queued { req: r, enqueued: now, arrival_step: step, seq });
        outcome
    }

    pub fn len(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.queues.iter().all(|q| q.is_empty())
    }

    /// Depth of one class queue (bounded-memory observability).
    pub fn class_len(&self, p: Priority) -> usize {
        self.queues[p.index()].len()
    }

    /// Bucket the next wave would use for `n` queued requests: the
    /// smallest bucket ≥ n, or the largest bucket if n exceeds all.
    pub fn bucket_for(&self, n: usize) -> usize {
        covering_bucket(&self.cfg.buckets, n)
    }

    /// Pop the globally oldest queued request regardless of class
    /// (error-drain path — exact arrival order).
    pub fn pop_front(&mut self) -> Option<(Request, Instant)> {
        let c = (0..3)
            .filter_map(|c| self.queues[c].front().map(|q| (q.seq, c)))
            .min()
            .map(|(_, c)| c)?;
        let q = self.queues[c].pop_front()?;
        Some((q.req, q.enqueued))
    }

    /// Oldest enqueue time across all classes (hold-window basis).
    fn oldest(&self) -> Option<Instant> {
        self.queues.iter().filter_map(|q| q.front()).map(|e| e.enqueued).min()
    }

    /// The hold policy shared by waves and idle continuous admission:
    /// a queue smaller than the largest bucket whose oldest entry is
    /// younger than `max_wait` is held, so an idle engine can form a
    /// fuller first batch.
    fn held(&self, now: Instant) -> bool {
        let n = self.len();
        let Some(oldest) = self.oldest() else {
            return true; // empty queue: nothing to release
        };
        // normalized() guarantees a non-empty bucket list; usize::MAX
        // keeps the hold semantics harmless if that ever changes.
        let max_bucket = self.cfg.buckets.last().copied().unwrap_or(usize::MAX);
        n < max_bucket && now.saturating_duration_since(oldest) < self.cfg.max_wait
    }

    /// Which class queue the next admission comes from at `step`
    /// (hold window not considered): deadline-urgent fronts first (in
    /// class order), then aged fronts (oldest arrival wins), then
    /// plain class order. Urgency and aging are evaluated at queue
    /// fronts only — FIFO within a class is never reordered.
    fn next_class(&self, step: u64) -> Option<usize> {
        // 1. urgency: the front would miss its deadline if it waits
        //    one more step
        for c in 0..3 {
            if let Some(front) = self.queues[c].front() {
                if let Some(d) = front.req.deadline_steps {
                    if step.saturating_sub(front.arrival_step) >= d {
                        return Some(c);
                    }
                }
            }
        }
        // 2. aging: starving fronts outrank fresher higher classes
        if self.cfg.age_promote_steps != u64::MAX {
            let aged = (0..3)
                .filter_map(|c| {
                    let front = self.queues[c].front()?;
                    (step.saturating_sub(front.arrival_step) >= self.cfg.age_promote_steps)
                        .then_some((front.arrival_step, c))
                })
                .min();
            if let Some((_, c)) = aged {
                return Some(c);
            }
        }
        // 3. class order
        (0..3).find(|&c| !self.queues[c].is_empty())
    }

    /// Class of the next admission at `step`, or None if empty.
    pub fn peek_next(&self, step: u64) -> Option<Priority> {
        self.next_class(step).map(|c| Priority::ALL[c])
    }

    /// Pop the next admission at `step` (see [`Batcher::peek_next`]
    /// for the policy). Returns the request, its enqueue time, and
    /// its arrival step.
    pub fn pop_next(&mut self, step: u64) -> Option<(Request, Instant, u64)> {
        let c = self.next_class(step)?;
        let q = self.queues[c].pop_front()?;
        Some((q.req, q.enqueued, q.arrival_step))
    }

    /// Per-class count of queued requests already at/past their
    /// admission deadline at `step` — the preemption demand the
    /// scheduler tries to make room for.
    pub fn urgent_by_class(&self, step: u64) -> [usize; 3] {
        let mut out = [0usize; 3];
        for c in 0..3 {
            out[c] = self.queues[c]
                .iter()
                .filter(|e| {
                    e.req
                        .deadline_steps
                        .is_some_and(|d| step.saturating_sub(e.arrival_step) >= d)
                })
                .count();
        }
        out
    }

    /// Whether idle admission is currently held open for late
    /// arrivals (continuous path; a busy engine never holds — a free
    /// slot always costs less than an empty row).
    pub fn holding(&self, idle: bool, now: Instant) -> bool {
        idle && self.held(now)
    }

    /// Admission for the continuous scheduler: move up to `n` requests
    /// into `out` (cleared first) in class-then-FIFO order. While
    /// `idle` (no live slots), the wave hold policy applies. Returns
    /// the number admitted. The session's step loop uses the finer
    /// [`Batcher::pop_next`]; this remains the coarse one-call form.
    pub fn admit_into(
        &mut self,
        n: usize,
        idle: bool,
        out: &mut Vec<(Request, Instant)>,
    ) -> usize {
        out.clear();
        if n == 0 || self.is_empty() {
            return 0;
        }
        if self.holding(idle, self.clock.now()) {
            return 0;
        }
        while out.len() < n {
            match self.pop_next(u64::MAX) {
                Some((r, t, _)) => out.push((r, t)),
                None => break,
            }
        }
        out.len()
    }

    /// Pop a wave: up to `bucket` requests (bucket chosen by queue
    /// depth + hold policy). Returns requests with their enqueue times.
    /// `None` if the queue is empty or still within the hold window.
    pub fn take_wave(&mut self) -> Option<Vec<(Request, Instant)>> {
        let mut wave = Vec::new();
        if self.take_wave_into(&mut wave) {
            Some(wave)
        } else {
            None
        }
    }

    /// Like [`Batcher::take_wave`], but drains into a caller-owned
    /// buffer (cleared first) so the steady-state serve loop re-forms
    /// waves without allocating. Returns whether a wave was formed.
    pub fn take_wave_into(&mut self, out: &mut Vec<(Request, Instant)>) -> bool {
        out.clear();
        let n = self.len();
        if n == 0 {
            return false;
        }
        // hold a partial wave open while fresh and below the max bucket
        if self.held(self.clock.now()) {
            return false;
        }
        let take = n.min(self.bucket_for(n));
        while out.len() < take {
            match self.pop_next(u64::MAX) {
                Some((r, t, _)) => out.push((r, t)),
                None => break,
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::request::GenParams;

    fn req(id: u64) -> Request {
        Request::new(id, vec![1, 2], GenParams::default())
    }

    fn cfg(buckets: Vec<usize>, max_wait: Duration) -> BatcherConfig {
        BatcherConfig { buckets, max_wait, ..Default::default() }
    }

    #[test]
    fn bucket_selection() {
        let b = Batcher::new(cfg(vec![1, 8, 32], Duration::ZERO)).unwrap();
        assert_eq!(b.bucket_for(1), 1);
        assert_eq!(b.bucket_for(2), 8);
        assert_eq!(b.bucket_for(8), 8);
        assert_eq!(b.bucket_for(9), 32);
        assert_eq!(b.bucket_for(100), 32);
    }

    #[test]
    fn config_errors_are_typed() {
        assert_eq!(
            Batcher::new(cfg(vec![], Duration::ZERO)).err(),
            Some(ConfigError::NoBuckets)
        );
        assert_eq!(
            Batcher::new(cfg(vec![4, 0], Duration::ZERO)).err(),
            Some(ConfigError::ZeroBucket)
        );
        // unsorted + duplicated buckets normalize instead of erroring
        let b = Batcher::new(cfg(vec![8, 1, 8, 4], Duration::ZERO)).unwrap();
        assert_eq!(b.bucket_for(2), 4);
        assert_eq!(b.bucket_for(100), 8);
    }

    #[test]
    fn wave_never_exceeds_bucket() {
        let mut b = Batcher::new(cfg(vec![1, 4], Duration::ZERO)).unwrap();
        for i in 0..10 {
            b.push(req(i));
        }
        let wave = b.take_wave().unwrap();
        assert_eq!(wave.len(), 4);
        assert_eq!(b.len(), 6);
        // FIFO order preserved
        assert_eq!(wave[0].0.id, 0);
        assert_eq!(wave[3].0.id, 3);
    }

    #[test]
    fn hold_window_delays_partial_waves() {
        let clock = Clock::manual();
        let mut b =
            Batcher::with_clock(cfg(vec![1, 8], Duration::from_secs(60)), clock.clone())
                .unwrap();
        b.push(req(0));
        // fresh single request below max bucket: held
        assert!(b.take_wave().is_none());
        // fill to the max bucket: released immediately
        for i in 1..8 {
            b.push(req(i));
        }
        assert_eq!(b.take_wave().unwrap().len(), 8);
        // a partial wave past the window is released too
        b.push(req(8));
        assert!(b.take_wave().is_none());
        clock.advance(Duration::from_secs(61));
        assert_eq!(b.take_wave().unwrap().len(), 1);
    }

    #[test]
    fn take_wave_into_reuses_buffer() {
        let mut b = Batcher::new(cfg(vec![1, 4], Duration::ZERO)).unwrap();
        for i in 0..6 {
            b.push(req(i));
        }
        let mut wave = Vec::new();
        assert!(b.take_wave_into(&mut wave));
        assert_eq!(wave.len(), 4);
        let cap = wave.capacity();
        // second wave reuses the same backing storage
        assert!(b.take_wave_into(&mut wave));
        assert_eq!(wave.len(), 2);
        assert_eq!(wave.capacity(), cap);
        assert_eq!(wave[0].0.id, 4);
        // empty queue clears the buffer and reports no wave
        assert!(!b.take_wave_into(&mut wave));
        assert!(wave.is_empty());
    }

    #[test]
    fn admit_into_fifo_and_hold() {
        let mut b = Batcher::new(cfg(vec![1, 4], Duration::from_secs(60))).unwrap();
        for i in 0..6 {
            b.push(req(i));
        }
        let mut out = Vec::new();
        // idle engine, queue (6) ≥ max bucket (4): released despite the window
        assert_eq!(b.admit_into(3, true, &mut out), 3);
        assert_eq!(out.iter().map(|(r, _)| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        // idle + fresh + below max bucket: held
        assert_eq!(b.admit_into(4, true, &mut out), 0);
        assert!(out.is_empty());
        // busy engine: admits immediately, capped at free slots
        assert_eq!(b.admit_into(2, false, &mut out), 2);
        assert_eq!(out[0].0.id, 3);
        assert_eq!(b.len(), 1);
        assert_eq!(b.admit_into(8, false, &mut out), 1);
        assert!(b.is_empty());
        assert_eq!(b.admit_into(8, false, &mut out), 0);
    }

    #[test]
    fn zero_wait_releases_immediately() {
        let mut b = Batcher::new(cfg(vec![1, 8], Duration::ZERO)).unwrap();
        b.push(req(0));
        assert_eq!(b.take_wave().unwrap().len(), 1);
        assert!(b.take_wave().is_none());
    }

    #[test]
    fn class_order_then_fifo_within_class() {
        let mut b = Batcher::new(cfg(vec![1, 8], Duration::ZERO)).unwrap();
        b.push(req(0).with_priority(Priority::Low));
        b.push(req(1));
        b.push(req(2).with_priority(Priority::High));
        b.push(req(3).with_priority(Priority::High));
        b.push(req(4));
        let order: Vec<u64> =
            std::iter::from_fn(|| b.pop_next(0).map(|(r, _, _)| r.id)).collect();
        assert_eq!(order, vec![2, 3, 1, 4, 0]);
    }

    #[test]
    fn aging_promotes_starving_low_class() {
        let mut c = cfg(vec![1, 8], Duration::ZERO);
        c.age_promote_steps = 5;
        let mut b = Batcher::new(c).unwrap();
        let now = Instant::now();
        b.push_at(req(0).with_priority(Priority::Low), now, 0);
        b.push_at(req(1).with_priority(Priority::High), now, 4);
        // fresh: class order wins
        assert_eq!(b.peek_next(4), Some(Priority::High));
        // low request has aged 5 steps: promoted past the high class
        assert_eq!(b.peek_next(5), Some(Priority::Low));
        assert_eq!(b.pop_next(5).unwrap().0.id, 0);
        assert_eq!(b.pop_next(5).unwrap().0.id, 1);
    }

    #[test]
    fn deadline_urgency_outranks_class_order() {
        let mut b = Batcher::new(cfg(vec![1, 8], Duration::ZERO)).unwrap();
        let now = Instant::now();
        b.push_at(req(0).with_priority(Priority::Normal).with_deadline_steps(3), now, 0);
        b.push_at(req(1).with_priority(Priority::High), now, 0);
        assert_eq!(b.peek_next(2), Some(Priority::High));
        // at step 3 the normal request is on its last on-time step
        assert_eq!(b.peek_next(3), Some(Priority::Normal));
        assert_eq!(b.urgent_by_class(3), [0, 1, 0]);
        assert_eq!(b.pop_next(3).unwrap().0.id, 0);
    }

    #[test]
    fn bounded_queue_degrades_then_sheds() {
        let mut c = cfg(vec![1, 8], Duration::ZERO);
        c.queue_cap = Some(2);
        c.degrade_margin = 1;
        let mut b = Batcher::new(c).unwrap();
        assert_eq!(b.push(req(0)), SubmitOutcome::Queued);
        assert_eq!(b.push(req(1)), SubmitOutcome::Queued);
        // past the cap: degraded into the margin
        assert_eq!(b.push(req(2)), SubmitOutcome::QueuedDegraded);
        // past cap + margin: shed with a typed outcome
        match b.push(req(3)) {
            SubmitOutcome::Rejected(s) => {
                assert_eq!(s.priority, Priority::Normal);
                assert_eq!(s.queue_len, 3);
            }
            other => panic!("expected shed, got {other:?}"),
        }
        // other classes have their own bound
        assert_eq!(b.push(req(4).with_priority(Priority::High)), SubmitOutcome::Queued);
        assert_eq!(b.class_len(Priority::Normal), 3);
        assert_eq!(b.class_len(Priority::High), 1);
        // the degraded entry carries the tier seam
        let tiers: Vec<EffortTier> =
            std::iter::from_fn(|| b.pop_next(0).map(|(r, _, _)| r.tier)).collect();
        assert_eq!(
            tiers,
            vec![EffortTier::Full, EffortTier::Full, EffortTier::Full, EffortTier::Degraded]
        );
    }

    #[test]
    fn pop_front_drains_in_arrival_order_across_classes() {
        let mut b = Batcher::new(cfg(vec![1, 8], Duration::ZERO)).unwrap();
        b.push(req(0).with_priority(Priority::Low));
        b.push(req(1).with_priority(Priority::High));
        b.push(req(2));
        let order: Vec<u64> = std::iter::from_fn(|| b.pop_front().map(|(r, _)| r.id)).collect();
        assert_eq!(order, vec![0, 1, 2]);
    }
}
