//! The admission queue: FIFO request intake for both scheduling paths.
//!
//! * **Continuous scheduler** (the default engine path):
//!   [`Batcher::admit_into`] pops up to the number of free KV slots at
//!   every step; the `max_wait` hold window applies only while the
//!   engine is idle, letting a first batch fill before prefill starts.
//! * **Run-to-completion waves** (reference/benchmark path):
//!   [`Batcher::take_wave`] forms the largest available batch that fits
//!   a compiled bucket size (e.g. {1, 8, 32}), waiting up to `max_wait`
//!   for more arrivals when the queue is smaller than the largest
//!   bucket. Prompts inside a wave are left-padded bucket-wise by the
//!   engine.

use crate::serving::request::Request;
use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// Batcher policy.
#[derive(Clone, Debug)]
pub struct BatcherConfig {
    /// Compiled batch buckets, ascending (from the artifact manifest).
    pub buckets: Vec<usize>,
    /// How long to hold a non-full wave open for late arrivals.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { buckets: vec![1, 8, 32], max_wait: Duration::from_millis(2) }
    }
}

/// The single bucket-policy primitive every scheduling surface shares
/// (batcher waves, the continuous scheduler, the engine's step
/// forward, the wave simulator): smallest bucket ≥ `n`, or the largest
/// when `n` exceeds them all. `buckets` must be ascending and
/// non-empty.
pub fn covering_bucket(buckets: &[usize], n: usize) -> usize {
    debug_assert!(buckets.windows(2).all(|w| w[0] <= w[1]), "buckets must be ascending");
    *buckets.iter().find(|&&b| n <= b).unwrap_or_else(|| buckets.last().unwrap())
}

/// FIFO queue + wave former. Thread-safe wrapper lives in the engine.
pub struct Batcher {
    cfg: BatcherConfig,
    queue: VecDeque<(Request, Instant)>,
}

impl Batcher {
    pub fn new(cfg: BatcherConfig) -> Self {
        assert!(!cfg.buckets.is_empty(), "need at least one batch bucket");
        let mut cfg = cfg;
        cfg.buckets.sort_unstable();
        Batcher { cfg, queue: VecDeque::new() }
    }

    pub fn push(&mut self, r: Request) {
        self.queue.push_back((r, Instant::now()));
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Bucket the next wave would use for `n` queued requests: the
    /// smallest bucket ≥ n, or the largest bucket if n exceeds all.
    pub fn bucket_for(&self, n: usize) -> usize {
        covering_bucket(&self.cfg.buckets, n)
    }

    /// Pop the oldest queued request (error-drain path).
    pub fn pop_front(&mut self) -> Option<(Request, Instant)> {
        self.queue.pop_front()
    }

    /// Admission for the continuous scheduler: move up to `n` requests
    /// FIFO into `out` (cleared first). While `idle` (no live slots),
    /// the wave hold policy applies — a queue smaller than the largest
    /// bucket whose oldest entry is younger than `max_wait` is held, so
    /// an idle engine can form a fuller first batch. A busy engine
    /// admits immediately: a free slot always costs less than an empty
    /// row. Returns the number admitted.
    pub fn admit_into(
        &mut self,
        n: usize,
        idle: bool,
        out: &mut Vec<(Request, Instant)>,
    ) -> usize {
        out.clear();
        let q = self.queue.len();
        if q == 0 || n == 0 {
            return 0;
        }
        if idle {
            let max_bucket = *self.cfg.buckets.last().unwrap();
            let oldest = self.queue.front().unwrap().1;
            if q < max_bucket && oldest.elapsed() < self.cfg.max_wait {
                return 0;
            }
        }
        let take = q.min(n);
        out.extend(self.queue.drain(..take));
        take
    }

    /// Pop a wave: up to `bucket` requests (bucket chosen by queue
    /// depth + hold policy). Returns requests with their enqueue times.
    /// `None` if the queue is empty or still within the hold window.
    pub fn take_wave(&mut self) -> Option<Vec<(Request, Instant)>> {
        let mut wave = Vec::new();
        if self.take_wave_into(&mut wave) {
            Some(wave)
        } else {
            None
        }
    }

    /// Like [`Batcher::take_wave`], but drains into a caller-owned
    /// buffer (cleared first) so the steady-state serve loop re-forms
    /// waves without allocating. Returns whether a wave was formed.
    pub fn take_wave_into(&mut self, out: &mut Vec<(Request, Instant)>) -> bool {
        out.clear();
        let n = self.queue.len();
        if n == 0 {
            return false;
        }
        let max_bucket = *self.cfg.buckets.last().unwrap();
        let oldest = self.queue.front().unwrap().1;
        // hold a partial wave open while fresh and below the max bucket
        if n < max_bucket && oldest.elapsed() < self.cfg.max_wait {
            return false;
        }
        let bucket = self.bucket_for(n);
        let take = n.min(bucket);
        out.extend(self.queue.drain(..take));
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::request::GenParams;

    fn req(id: u64) -> Request {
        Request::new(id, vec![1, 2], GenParams::default())
    }

    #[test]
    fn bucket_selection() {
        let b = Batcher::new(BatcherConfig { buckets: vec![1, 8, 32], max_wait: Duration::ZERO });
        assert_eq!(b.bucket_for(1), 1);
        assert_eq!(b.bucket_for(2), 8);
        assert_eq!(b.bucket_for(8), 8);
        assert_eq!(b.bucket_for(9), 32);
        assert_eq!(b.bucket_for(100), 32);
    }

    #[test]
    fn wave_never_exceeds_bucket() {
        let mut b =
            Batcher::new(BatcherConfig { buckets: vec![1, 4], max_wait: Duration::ZERO });
        for i in 0..10 {
            b.push(req(i));
        }
        let wave = b.take_wave().unwrap();
        assert_eq!(wave.len(), 4);
        assert_eq!(b.len(), 6);
        // FIFO order preserved
        assert_eq!(wave[0].0.id, 0);
        assert_eq!(wave[3].0.id, 3);
    }

    #[test]
    fn hold_window_delays_partial_waves() {
        let mut b = Batcher::new(BatcherConfig {
            buckets: vec![1, 8],
            max_wait: Duration::from_secs(60),
        });
        b.push(req(0));
        // fresh single request below max bucket: held
        assert!(b.take_wave().is_none());
        // fill to the max bucket: released immediately
        for i in 1..8 {
            b.push(req(i));
        }
        assert_eq!(b.take_wave().unwrap().len(), 8);
    }

    #[test]
    fn take_wave_into_reuses_buffer() {
        let mut b =
            Batcher::new(BatcherConfig { buckets: vec![1, 4], max_wait: Duration::ZERO });
        for i in 0..6 {
            b.push(req(i));
        }
        let mut wave = Vec::new();
        assert!(b.take_wave_into(&mut wave));
        assert_eq!(wave.len(), 4);
        let cap = wave.capacity();
        // second wave reuses the same backing storage
        assert!(b.take_wave_into(&mut wave));
        assert_eq!(wave.len(), 2);
        assert_eq!(wave.capacity(), cap);
        assert_eq!(wave[0].0.id, 4);
        // empty queue clears the buffer and reports no wave
        assert!(!b.take_wave_into(&mut wave));
        assert!(wave.is_empty());
    }

    #[test]
    fn admit_into_fifo_and_hold() {
        let mut b = Batcher::new(BatcherConfig {
            buckets: vec![1, 4],
            max_wait: Duration::from_secs(60),
        });
        for i in 0..6 {
            b.push(req(i));
        }
        let mut out = Vec::new();
        // idle engine, queue (6) ≥ max bucket (4): released despite the window
        assert_eq!(b.admit_into(3, true, &mut out), 3);
        assert_eq!(out.iter().map(|(r, _)| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        // idle + fresh + below max bucket: held
        assert_eq!(b.admit_into(4, true, &mut out), 0);
        assert!(out.is_empty());
        // busy engine: admits immediately, capped at free slots
        assert_eq!(b.admit_into(2, false, &mut out), 2);
        assert_eq!(out[0].0.id, 3);
        assert_eq!(b.len(), 1);
        assert_eq!(b.admit_into(8, false, &mut out), 1);
        assert!(b.is_empty());
        assert_eq!(b.admit_into(8, false, &mut out), 0);
    }

    #[test]
    fn zero_wait_releases_immediately() {
        let mut b =
            Batcher::new(BatcherConfig { buckets: vec![1, 8], max_wait: Duration::ZERO });
        b.push(req(0));
        assert_eq!(b.take_wave().unwrap().len(), 1);
        assert!(b.take_wave().is_none());
    }
}
