//! Prompt-prefix cache over the paged KV pool: a token trie at page
//! granularity, LRU-evicted under page pressure.
//!
//! Heavy serving traffic repeats prompt preambles (system prompts,
//! few-shot headers). Re-prefilling and re-storing them per request
//! wastes both compute and KV pages — the dominant serving-side lever
//! next to expert dispatch (arXiv 2412.14219). This cache keys **full
//! pages** of KV on the exact token chunk they encode: a trie node per
//! `page_len`-token chunk, holding one [`PagePool`] reference. Lookup
//! walks the trie along a prompt's leading chunks and returns the
//! matched pages; an admitted request maps them
//! ([`crate::runtime::KvSlotPool::map_shared`]) and prefills only the
//! remainder.
//!
//! Correctness rests on two facts:
//! * a full-chunk token match implies identical KV content — KV at
//!   position `p` is a deterministic causal function of tokens
//!   `[0, p]` (per-position projections; the causal mask lets later
//!   tokens see, never alter, earlier KV);
//! * cached pages are immutable: the pool's copy-on-write
//!   ([`PagePool::try_page_mut`]) copies a shared page before any
//!   divergent write, so a mapper can never corrupt the cached bytes.
//!
//! Both backends key on the **raw prompt tokens**. The artifact engine
//! prefills left-aligned rows (prompt token `j` at KV position `j`,
//! trailing padding causally invisible — see `serving::engine`), so a
//! position's KV bytes depend only on the token prefix, never on the
//! compiled row length; the host stub stores one token per KV column.
//! Either way the key is the exact semantic determinant of the cached
//! bytes, and a prefix cached by any artifact size (or any chunk
//! schedule) is valid for every other.
//!
//! Eviction is LRU over **leaf** nodes whose page has no mapper other
//! than the cache itself (refcount 1): a prefix currently mapped by a
//! live slot is never evicted, and interior nodes are only evictable
//! once their descendants are gone. Children are kept in a `BTreeMap`
//! so eviction order — and therefore every replay — is deterministic.

use crate::runtime::PagePool;
use std::collections::BTreeMap;

struct Node {
    /// The page holding this chunk's KV (one cache reference).
    page: usize,
    /// LRU stamp (logical clock: touched by lookup and insert).
    last_used: u64,
    children: BTreeMap<Vec<usize>, Node>,
}

/// Token-trie prefix cache at page granularity.
pub struct PrefixCache {
    page_len: usize,
    children: BTreeMap<Vec<usize>, Node>,
    clock: u64,
    /// Pages currently held by the cache.
    cached_pages: usize,
    /// Lifetime counters (gauges).
    pub lookups: u64,
    pub hits: u64,
    pub hit_tokens: u64,
    pub inserted_pages: u64,
    pub evicted_pages: u64,
}

impl PrefixCache {
    pub fn new(page_len: usize) -> PrefixCache {
        assert!(page_len >= 1, "page_len 0 is not a page");
        PrefixCache {
            page_len,
            children: BTreeMap::new(),
            clock: 0,
            cached_pages: 0,
            lookups: 0,
            hits: 0,
            hit_tokens: 0,
            inserted_pages: 0,
            evicted_pages: 0,
        }
    }

    pub fn page_len(&self) -> usize {
        self.page_len
    }

    /// Pages currently held (each carries one pool reference).
    pub fn cached_pages(&self) -> usize {
        self.cached_pages
    }

    /// Longest cached prefix of `key`: the pages covering its leading
    /// full `page_len`-token chunks, and the token count they cover
    /// (`pages.len() * page_len`). The caller maps them and decides how
    /// much prefill that actually saves (at least the last prompt
    /// position must still run to produce first-token logits).
    pub fn lookup(&mut self, key: &[usize]) -> (Vec<usize>, usize) {
        self.lookups += 1;
        self.clock += 1;
        let mut pages = Vec::new();
        let mut map = &mut self.children;
        for chunk in key.chunks_exact(self.page_len) {
            match map.get_mut(chunk) {
                Some(n) => {
                    n.last_used = self.clock;
                    pages.push(n.page);
                    map = &mut n.children;
                }
                None => break,
            }
        }
        let tokens = pages.len() * self.page_len;
        if !pages.is_empty() {
            self.hits += 1;
            self.hit_tokens += tokens as u64;
        }
        (pages, tokens)
    }

    /// Insert `key`'s leading full chunks, holding `slot_pages[i]` for
    /// chunk `i` (one [`PagePool::retain`] per *new* node). Chunks
    /// already cached keep their original page — a full-chunk token
    /// match means the bytes are identical, so deduplication is free.
    /// Returns the number of pages newly cached.
    pub fn insert(&mut self, key: &[usize], slot_pages: &[usize], pool: &mut PagePool) -> usize {
        self.clock += 1;
        let mut new = 0usize;
        let mut map = &mut self.children;
        for (i, chunk) in key.chunks_exact(self.page_len).enumerate() {
            if i >= slot_pages.len() {
                break;
            }
            let n = map.entry(chunk.to_vec()).or_insert_with(|| {
                pool.retain(slot_pages[i]);
                new += 1;
                Node { page: slot_pages[i], last_used: 0, children: BTreeMap::new() }
            });
            n.last_used = self.clock;
            map = &mut n.children;
        }
        self.cached_pages += new;
        self.inserted_pages += new as u64;
        new
    }

    /// Free up to `need` pages under pool pressure: evict
    /// least-recently-used **leaves** whose page only the cache still
    /// references (refcount 1) — a prefix mapped by a live slot is
    /// never evicted. One DFS collects every currently evictable leaf
    /// (not one walk per page); parents become evictable only once
    /// their subtree is gone, so chains drain across waves. Returns
    /// how many pages were actually freed.
    pub fn evict(&mut self, pool: &mut PagePool, need: usize) -> usize {
        let mut freed = 0usize;
        while freed < need {
            let mut victims: Vec<(u64, Vec<Vec<usize>>)> = Vec::new();
            let mut path = Vec::new();
            collect_evictable(&self.children, pool, &mut path, &mut victims);
            if victims.is_empty() {
                break;
            }
            // oldest first; path order breaks LRU ties deterministically
            victims.sort();
            for (_, victim) in victims.into_iter().take(need - freed) {
                let node = remove_path(&mut self.children, &victim);
                pool.release(node.page);
                self.cached_pages -= 1;
                self.evicted_pages += 1;
                freed += 1;
            }
        }
        freed
    }
}

/// Depth-first scan collecting every evictable leaf (deterministic:
/// BTreeMap iteration order).
fn collect_evictable(
    map: &BTreeMap<Vec<usize>, Node>,
    pool: &PagePool,
    path: &mut Vec<Vec<usize>>,
    out: &mut Vec<(u64, Vec<Vec<usize>>)>,
) {
    for (chunk, node) in map {
        path.push(chunk.clone());
        if node.children.is_empty() {
            if pool.refcount(node.page) == 1 {
                out.push((node.last_used, path.clone()));
            }
        } else {
            collect_evictable(&node.children, pool, path, out);
        }
        path.pop();
    }
}

/// Remove and return the node at `path` (must exist and be a leaf).
fn remove_path(map: &mut BTreeMap<Vec<usize>, Node>, path: &[Vec<usize>]) -> Node {
    if path.len() == 1 {
        // lint: allow(panic-discipline) — the path was just collected from a live traversal of this trie under the same &mut borrow, so every segment still exists; vanishing means the trie mutated mid-eviction, which the exclusive borrow rules out
        return map.remove(&path[0]).expect("prefix cache: eviction path vanished");
    }
    remove_path(
        // lint: allow(panic-discipline) — same invariant as above: path segments come from a live traversal under this exclusive borrow
        &mut map.get_mut(&path[0]).expect("prefix cache: eviction path vanished").children,
        &path[1..],
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool() -> PagePool {
        PagePool::new(2, 4, None)
    }

    /// Simulate a slot owning pages for `key` and insert them.
    fn insert_owned(cache: &mut PrefixCache, pool: &mut PagePool, key: &[usize]) -> Vec<usize> {
        let n = key.len() / cache.page_len();
        let pages: Vec<usize> = (0..n).map(|_| pool.try_alloc().unwrap()).collect();
        cache.insert(key, &pages, pool);
        // the "slot" retires: only the cache's holds remain
        for &p in &pages {
            pool.release(p);
        }
        pages
    }

    #[test]
    fn lookup_walks_full_chunks_only() {
        let mut pool = pool();
        let mut c = PrefixCache::new(2);
        let pages = insert_owned(&mut c, &mut pool, &[1, 2, 3, 4, 5]);
        assert_eq!(pages.len(), 2, "partial final chunk never cached");
        assert_eq!(c.cached_pages(), 2);
        let (hit, toks) = c.lookup(&[1, 2, 3, 4, 9, 9]);
        assert_eq!((hit, toks), (pages.clone(), 4));
        let (hit, toks) = c.lookup(&[1, 2, 7]);
        assert_eq!((hit.len(), toks), (1, 2));
        assert_eq!(hit[0], pages[0]);
        let (hit, toks) = c.lookup(&[1, 3, 3, 4]);
        assert!(hit.is_empty() && toks == 0, "chunk must match exactly");
        let (hit, _) = c.lookup(&[1]);
        assert!(hit.is_empty(), "prompts shorter than a page never hit");
    }

    #[test]
    fn insert_dedupes_shared_prefixes() {
        let mut pool = pool();
        let mut c = PrefixCache::new(2);
        let a = insert_owned(&mut c, &mut pool, &[1, 2, 3, 4]);
        let before = pool.pages_in_use();
        // same first chunk, new second chunk: only one new page cached
        let n = 2;
        let pages: Vec<usize> = (0..n).map(|_| pool.try_alloc().unwrap()).collect();
        let new = c.insert(&[1, 2, 9, 9], &pages, &mut pool);
        for &p in &pages {
            pool.release(p);
        }
        assert_eq!(new, 1);
        assert_eq!(pool.pages_in_use(), before + 1, "duplicate first chunk page freed");
        let (hit, _) = c.lookup(&[1, 2, 9, 9]);
        assert_eq!(hit[0], a[0], "existing chunk keeps its original page");
    }

    #[test]
    fn eviction_is_lru_leaf_first_and_skips_mapped_pages() {
        let mut pool = pool();
        let mut c = PrefixCache::new(2);
        let a = insert_owned(&mut c, &mut pool, &[1, 1, 2, 2]); // chain A: 2 pages
        let b = insert_owned(&mut c, &mut pool, &[5, 5]); // chain B: 1 page
        // a live slot maps chain B's page
        pool.retain(b[0]);
        // touch chain A so B is LRU — but B is mapped, so eviction must
        // take A's leaf instead
        c.lookup(&[1, 1, 2, 2]);
        assert_eq!(c.evict(&mut pool, 1), 1);
        let (hit, _) = c.lookup(&[1, 1, 2, 2]);
        assert_eq!(hit, vec![a[0]], "A's leaf evicted, its root kept");
        let (hit, _) = c.lookup(&[5, 5]);
        assert_eq!(hit, vec![b[0]], "mapped chain survives eviction");
        // drain everything evictable: A's root goes, B stays mapped
        assert_eq!(c.evict(&mut pool, 10), 1);
        assert_eq!(c.cached_pages(), 1);
        assert_eq!(pool.refcount(b[0]), 2);
        // once the slot releases, B becomes evictable
        pool.release(b[0]);
        assert_eq!(c.evict(&mut pool, 10), 1);
        assert_eq!(c.cached_pages(), 0);
        assert_eq!(pool.pages_in_use(), 0, "no leaked pages");
    }
}
