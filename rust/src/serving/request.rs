//! Request / response types.

use std::time::Duration;

/// Sampling parameters.
#[derive(Clone, Copy, Debug)]
pub struct GenParams {
    pub max_new_tokens: usize,
    /// 0.0 = greedy.
    pub temperature: f32,
    pub seed: u64,
    /// Stop at this token id (None = run to max_new_tokens).
    pub stop_token: Option<usize>,
}

impl Default for GenParams {
    fn default() -> Self {
        GenParams { max_new_tokens: 32, temperature: 0.0, seed: 0, stop_token: None }
    }
}

/// Priority class for SLO-aware admission. Lower index = more
/// important; admission and preemption compare classes, never raw
/// deadlines across classes.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    /// Latency-sensitive (interactive) traffic.
    High,
    /// The default class; an all-[`Priority::Normal`] workload behaves
    /// exactly like the pre-priority FIFO scheduler.
    #[default]
    Normal,
    /// Throughput/batch traffic: first to be preempted, last admitted.
    Low,
}

impl Priority {
    /// Every class, most- to least-important. Queue layouts index by
    /// [`Priority::index`] in this order.
    pub const ALL: [Priority; 3] = [Priority::High, Priority::Normal, Priority::Low];

    pub fn index(self) -> usize {
        self as usize
    }

    pub fn name(self) -> &'static str {
        match self {
            Priority::High => "high",
            Priority::Normal => "normal",
            Priority::Low => "low",
        }
    }
}

/// Serving effort tier — request-level activation-ratio selection.
///
/// Each tier maps to a concrete activation-ratio operating point via
/// [`TierRatios`] (defaults: `Full` = 1.0, `Degraded` = 0.25 — the
/// CMoE paper's 25% point, §5). The scheduler sets
/// [`EffortTier::Degraded`] on admissions accepted into a bounded
/// queue's overflow margin, and callers may set it directly with
/// [`Request::with_tier`]. The session pushes the resolved ratio to
/// the backend through `StepForward::set_slot_ratio` at admission and
/// resume, so degraded rows really run at the reduced expert count
/// (per-row `k = ceil(ratio · k_full)`), and meters activated
/// fraction per tier in `SchedulerMetrics`. A backend that ignores
/// `set_slot_ratio` degrades nothing — the tier is then purely an
/// admission-pressure signal, as before ROADMAP item 4 landed.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum EffortTier {
    /// Full activation ratio (the converted model's native operating
    /// point).
    #[default]
    Full,
    /// Reduced activation ratio under overload (graceful degradation
    /// before shed-load).
    Degraded,
}

impl EffortTier {
    /// Every tier, full- to least-effort. Metrics index by
    /// [`EffortTier::index`] in this order.
    pub const ALL: [EffortTier; 2] = [EffortTier::Full, EffortTier::Degraded];

    pub fn index(self) -> usize {
        self as usize
    }

    pub fn name(self) -> &'static str {
        match self {
            EffortTier::Full => "full",
            EffortTier::Degraded => "degraded",
        }
    }
}

/// Tier → activation-ratio operating points. A ratio `r` makes every
/// row of that tier route each token to at most `ceil(r · k_full)`
/// experts (`moe::k_for_ratio`); `r >= 1` is exactly the untiered
/// path, which is what keeps `Full`-tier token streams bit-identical
/// with tiering on or off.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TierRatios {
    /// [`EffortTier::Full`] operating point (default 1.0 — lossless).
    pub full: f32,
    /// [`EffortTier::Degraded`] operating point (default 0.25 — the
    /// paper's fast point).
    pub degraded: f32,
}

/// Default [`EffortTier::Full`] operating point (mirror-drift
/// registered: `scripts/mirror_dynamic_k.py` must agree, checked by
/// `cmoe lint` — see `lint::drift::REGISTRY`).
pub const DEFAULT_TIER_FULL: f32 = 1.0;
/// Default [`EffortTier::Degraded`] operating point — the paper's fast
/// point (mirror-drift registered).
pub const DEFAULT_TIER_DEGRADED: f32 = 0.25;

impl Default for TierRatios {
    fn default() -> Self {
        TierRatios { full: DEFAULT_TIER_FULL, degraded: DEFAULT_TIER_DEGRADED }
    }
}

impl TierRatios {
    /// The operating point for one tier.
    pub fn ratio(&self, tier: EffortTier) -> f32 {
        match tier {
            EffortTier::Full => self.full,
            EffortTier::Degraded => self.degraded,
        }
    }
}

/// A generation request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<usize>,
    pub params: GenParams,
    /// Admission/preemption class (default [`Priority::Normal`]).
    pub priority: Priority,
    /// Admission deadline in scheduler steps after arrival: the
    /// request should be holding a KV slot within this many steps or
    /// it counts as an SLO miss (and, when preemption is enabled, may
    /// preempt a lower class to make its target). Step-denominated so
    /// deadline logic is deterministic under a manual [`Clock`].
    /// `None` = best effort.
    ///
    /// [`Clock`]: crate::serving::Clock
    pub deadline_steps: Option<u64>,
    /// Effort tier (see [`EffortTier`]); set by bounded admission
    /// under overload, or up front by callers via
    /// [`Request::with_tier`].
    pub tier: EffortTier,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<usize>, params: GenParams) -> Self {
        Request {
            id,
            prompt,
            params,
            priority: Priority::Normal,
            deadline_steps: None,
            tier: EffortTier::Full,
        }
    }

    pub fn with_priority(mut self, priority: Priority) -> Self {
        self.priority = priority;
        self
    }

    pub fn with_deadline_steps(mut self, steps: u64) -> Self {
        self.deadline_steps = Some(steps);
        self
    }

    /// Request a specific effort tier up front (e.g. a batch caller
    /// opting into [`EffortTier::Degraded`] for cheaper tokens).
    /// Bounded admission may still degrade a `Full` request under
    /// overload; it never promotes a `Degraded` one.
    pub fn with_tier(mut self, tier: EffortTier) -> Self {
        self.tier = tier;
        self
    }
}

/// Completed generation.
#[derive(Clone, Debug)]
pub struct RequestResult {
    pub id: u64,
    pub tokens: Vec<usize>,
    /// Time to first token. Wave path: from wave start. Continuous
    /// path: from enqueue (user-perceived, queue wait included).
    /// `None` when the request retired without ever emitting a first
    /// token (drained mid-prefill, aborted, failed before sampling) —
    /// such requests are excluded from TTFT percentiles and counted in
    /// `SchedulerMetrics::no_first_token` instead of being recorded as
    /// a dishonest 0ms sample.
    pub ttft: Option<Duration>,
    /// Enqueue→first-token in scheduler steps, inclusive of the step
    /// that sampled the token (continuous path; deterministic under a
    /// manual clock, and ≥ 1 + `queued_steps` once chunked prefill
    /// spreads a long prompt over several steps). Wave path: `Some(1)`
    /// — one prefill call. `None` iff [`RequestResult::ttft`] is.
    pub ttft_steps: Option<u64>,
    /// Scheduler steps spanned from the first sampled token to the
    /// last (0 when ≤ 1 token). Equals `tokens.len() - 1` for an
    /// uninterrupted decode; preemption stretches it, which is exactly
    /// what makes per-request TPOT (`decode_span_steps / (tokens - 1)`)
    /// honest about interference.
    pub decode_span_steps: u64,
    /// Total latency including queueing.
    pub latency: Duration,
    /// Enqueue→(wave start | slot admission) wait.
    pub queued: Duration,
    /// Scheduler steps spent queued before admission (continuous path
    /// only; the wave path reports 0 — its wait is wave-granular and
    /// captured by `queued`). Deterministic, so simulation tests can
    /// assert starvation bounds on it.
    pub queued_steps: u64,
    /// The request's priority class, echoed back so per-class SLO
    /// accounting needs no side table.
    pub priority: Priority,
    /// The effort tier the request was served at (including a
    /// degrade applied by bounded admission), echoed back so callers
    /// can see which results traded quality for latency.
    pub tier: EffortTier,
}

/// A request retired without completing: the fault-containment
/// outcome. The session keeps serving everything else; only this id
/// is affected.
#[derive(Clone, Debug)]
pub struct RequestFailure {
    pub id: u64,
    /// What failed, with the backend error inline.
    pub error: String,
}

impl std::fmt::Display for RequestFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "request {} failed: {}", self.id, self.error)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults() {
        let p = GenParams::default();
        assert_eq!(p.temperature, 0.0);
        let r = Request::new(1, vec![1, 2, 3], p);
        assert_eq!(r.prompt.len(), 3);
        assert_eq!(r.priority, Priority::Normal);
        assert_eq!(r.deadline_steps, None);
        assert_eq!(r.tier, EffortTier::Full);
    }

    #[test]
    fn priority_order_and_index() {
        assert!(Priority::High < Priority::Normal);
        assert!(Priority::Normal < Priority::Low);
        for (i, p) in Priority::ALL.iter().enumerate() {
            assert_eq!(p.index(), i);
        }
    }

    #[test]
    fn builders() {
        let r = Request::new(7, vec![1], GenParams::default())
            .with_priority(Priority::High)
            .with_deadline_steps(4)
            .with_tier(EffortTier::Degraded);
        assert_eq!(r.priority, Priority::High);
        assert_eq!(r.deadline_steps, Some(4));
        assert_eq!(r.tier, EffortTier::Degraded);
    }

    #[test]
    fn tier_ratios_defaults_and_lookup() {
        let tr = TierRatios::default();
        assert_eq!(tr.ratio(EffortTier::Full), 1.0);
        assert_eq!(tr.ratio(EffortTier::Degraded), 0.25);
        for (i, t) in EffortTier::ALL.iter().enumerate() {
            assert_eq!(t.index(), i);
        }
        assert_eq!(EffortTier::Full.name(), "full");
        assert_eq!(EffortTier::Degraded.name(), "degraded");
    }
}
