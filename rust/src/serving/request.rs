//! Request / response types.

use std::time::Duration;

/// Sampling parameters.
#[derive(Clone, Copy, Debug)]
pub struct GenParams {
    pub max_new_tokens: usize,
    /// 0.0 = greedy.
    pub temperature: f32,
    pub seed: u64,
    /// Stop at this token id (None = run to max_new_tokens).
    pub stop_token: Option<usize>,
}

impl Default for GenParams {
    fn default() -> Self {
        GenParams { max_new_tokens: 32, temperature: 0.0, seed: 0, stop_token: None }
    }
}

/// A generation request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<usize>,
    pub params: GenParams,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<usize>, params: GenParams) -> Self {
        Request { id, prompt, params }
    }
}

/// Completed generation.
#[derive(Clone, Debug)]
pub struct RequestResult {
    pub id: u64,
    pub tokens: Vec<usize>,
    /// Time to first token. Wave path: from wave start. Continuous
    /// path: from enqueue (user-perceived, queue wait included).
    pub ttft: Duration,
    /// Total latency including queueing.
    pub latency: Duration,
    /// Enqueue→(wave start | slot admission) wait.
    pub queued: Duration,
    /// Scheduler steps spent queued before admission (continuous path
    /// only; the wave path reports 0 — its wait is wave-granular and
    /// captured by `queued`). Deterministic, so simulation tests can
    /// assert starvation bounds on it.
    pub queued_steps: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults() {
        let p = GenParams::default();
        assert_eq!(p.temperature, 0.0);
        let r = Request::new(1, vec![1, 2, 3], p);
        assert_eq!(r.prompt.len(), 3);
    }
}
