//! Expert dispatch schedules for the orchestrated decode path.
//!
//! Two schedules live here:
//!
//! * [`GroupedDispatcher`] — the host-side **grouped dispatch** hot
//!   path: gather every token routed to each expert into contiguous
//!   per-expert activation blocks, run **one SwiGLU GEMM per expert per
//!   layer**, and scatter the gated results back. All tensor-sized
//!   intermediates are drawn from a reusable [`DispatchArena`], so the
//!   steady-state decode loop performs zero per-wave *buffer*
//!   allocations (the one remaining per-wave cost on large waves is
//!   spawning a core-count-bounded set of scoped worker threads — see
//!   the parallelism note below).
//! * [`ExpertDispatcher`] — the capacity-factor schedule for the
//!   *device* expert artifact (fixed `[N_r, C, d]` zero-padded blocks,
//!   one grouped-kernel call, overflow rounds). Kept for engines
//!   configured with `ExpertExec::DeviceCapacity` and for FLOPs
//!   accounting parity with the paper's Table 9 mechanism.
//!
//! # Grouped-dispatch invariants
//!
//! * **Expert block layout.** Gathered buffers are expert-major: rows
//!   `routing.expert_rows(e)` belong to expert `e`, tokens ascending
//!   within the block (see [`crate::moe::GroupedRouting`]). The scatter
//!   walks rows in that order, so a token's expert contributions
//!   accumulate ascending-by-expert — the same order
//!   [`crate::moe::moe_ffn_forward`] uses, which makes the two paths
//!   comparable **bit-for-bit** (they also share the serial GEMM kernel
//!   [`crate::tensor::matmul_rows`]).
//! * **Ragged decisions.** Nothing here assumes a uniform experts-per-
//!   token count: the CSR is built from each decision's own
//!   `experts.len()`, so per-token dynamic-k and per-row tier caps
//!   (ROADMAP item 4) flow through unchanged — total gathered rows is
//!   `Σ_t k_t` instead of `q · N_k`, and the arena sizes to that sum
//!   (a *smaller* footprint than fixed-k, so dynamic-k can never
//!   trigger late arena growth). `rust/tests/dynamic_k.rs` pins the
//!   CSR ↔ decision permutation equivalence under ragged loads.
//! * **Storage-agnostic bands.** Expert weights arrive through
//!   [`crate::moe::ExpertStore`] views, so precision and placement are
//!   the store's policy: a fp32 view runs [`tensor::swiglu_rows_into`]
//!   (the exact pre-trait path — plain `&[FfnWeights]` stores keep the
//!   bit-identity guarantee), an int8 view runs the fused-dequant twin
//!   with per-column scales applied in the GEMM epilogue. The shared
//!   expert never flows through here and stays fp32.
//! * **Arena lifetime.** One [`DispatchArena`] per engine, owned by the
//!   engine's MoE state and reused across layers, steps, and waves. It
//!   only ever grows; after the first wave of the largest compiled
//!   bucket, [`DispatchArena::grow_events`] stabilizes and the hot loop
//!   is allocation-free. The high-water mark is exported through
//!   `serving::metrics::DispatchMetrics`.
//! * **Parallelism.** Expert GEMMs run in parallel using the same
//!   row-band scheme as `util::pool`'s matmul (band count =
//!   `pool::num_threads()`), but bands are cut over the *gathered rows*
//!   (i.e. token-weighted), not over expert indices — a hot expert's
//!   block is itself split across threads instead of serializing the
//!   wave. The bands run on scoped threads spawned per dispatch (like
//!   every `util::pool` helper, which is also scope-spawn based); below
//!   [`GroupedDispatcher`]'s work threshold the whole dispatch runs
//!   serial and spawns nothing.

use crate::model::FfnWeights;
use crate::moe::{ExpertStore, ExpertView, GateDecision, GroupedRouting};
use crate::tensor::{self, Tensor};
use crate::util::pool;

/// Reusable scratch for the grouped dispatch stage. Buffers only grow;
/// see the module docs for the lifetime contract.
#[derive(Clone, Debug, Default)]
pub struct DispatchArena {
    /// Gathered activations, expert-major: `[A, d]` flat.
    xs: Vec<f32>,
    /// SwiGLU gate pre-activations / fused hidden: `[A, m]` flat.
    hidden: Vec<f32>,
    /// SwiGLU up projections: `[A, m]` flat.
    up: Vec<f32>,
    /// Gated expert outputs awaiting scatter: `[A, d]` flat.
    ys: Vec<f32>,
    /// Max total f32 elements ever held.
    high_water: usize,
    /// Number of `ensure` calls that had to (re)allocate.
    grow_events: u64,
}

fn grow(v: &mut Vec<f32>, need: usize) -> bool {
    if v.len() >= need {
        return false;
    }
    v.resize(need, 0.0);
    true
}

impl DispatchArena {
    pub fn new() -> DispatchArena {
        DispatchArena::default()
    }

    /// Make room for `rows` gathered rows of width `d` with expert
    /// hidden dim `m`. Never shrinks.
    fn ensure(&mut self, rows: usize, d: usize, m: usize) {
        let mut grew = false;
        grew |= grow(&mut self.xs, rows * d);
        grew |= grow(&mut self.hidden, rows * m);
        grew |= grow(&mut self.up, rows * m);
        grew |= grow(&mut self.ys, rows * d);
        if grew {
            self.grow_events += 1;
        }
        // capacity, not len: Vec growth over-allocates, and the gauge
        // should report the heap the arena actually retains
        let held = self.xs.capacity()
            + self.hidden.capacity()
            + self.up.capacity()
            + self.ys.capacity();
        self.high_water = self.high_water.max(held);
    }

    /// High-water mark of arena memory, in bytes. A steady value across
    /// waves is the observable "zero per-wave buffer allocations"
    /// signal.
    pub fn high_water_bytes(&self) -> usize {
        self.high_water * std::mem::size_of::<f32>()
    }

    /// How many times the arena had to grow. Stabilizes after warmup.
    pub fn grow_events(&self) -> u64 {
        self.grow_events
    }
}

/// Grouped gather→GEMM→scatter executor (see module docs).
#[derive(Clone, Copy, Debug)]
pub struct GroupedDispatcher {
    /// Model width `d`.
    pub d: usize,
    /// Expert hidden (neuron) dimension `m`.
    pub m: usize,
}

impl GroupedDispatcher {
    /// Below this many fused multiply-adds worth of work (`A · m`), the
    /// per-wave thread handoff costs more than it saves; run serial.
    const PAR_THRESHOLD: usize = 32 * 1024;

    pub fn new(d: usize, m: usize) -> GroupedDispatcher {
        assert!(d > 0 && m > 0);
        GroupedDispatcher { d, m }
    }

    /// Execute all routed experts for one wave and accumulate the gated
    /// outputs into `out` (`out += Σ_e g · E_e(xn)`, Eq. 4's routed
    /// term). `xn: [B, d]` are the normed token states, `routing` the
    /// expert-major assignment lists, `experts` any [`ExpertStore`] —
    /// a plain fp32 slice runs the exact pre-trait band kernel
    /// (bit-identity preserved); a quantized store's int8 views run
    /// the fused-dequant twin with the same per-band layout.
    // lint: hot-path
    pub fn forward<S: ExpertStore + ?Sized>(
        &self,
        xn: &Tensor,
        routing: &GroupedRouting,
        experts: &S,
        arena: &mut DispatchArena,
        out: &mut Tensor,
    ) {
        let (d, m) = (self.d, self.m);
        assert_eq!(xn.shape[1], d);
        assert_eq!(out.shape, xn.shape);
        assert_eq!(experts.n_experts(), routing.n_experts());
        debug_assert!((0..experts.n_experts()).all(|e| match experts.view(e) {
            ExpertView::Fp32(w) => w.hidden_dim() == m && w.w_gate.shape[0] == d,
            ExpertView::Int8(q) => q.hidden_dim() == m && q.model_dim() == d,
        }));
        let a = routing.total_rows();
        if a == 0 {
            return;
        }
        arena.ensure(a, d, m);
        tensor::gather_rows(xn, routing.token_idx(), &mut arena.xs[..a * d]);

        let nbands = pool::num_threads().min(a);
        if nbands <= 1 || a * m < Self::PAR_THRESHOLD {
            run_band(
                &arena.xs[..a * d],
                0,
                a,
                routing,
                experts,
                d,
                m,
                &mut arena.hidden[..a * m],
                &mut arena.up[..a * m],
                &mut arena.ys[..a * d],
            );
        } else {
            // Token-weighted row bands: equal row counts per band, so a
            // hot expert's block is split across threads. Scratch is
            // handed out by walking split_at_mut — no per-band Vec.
            let band = (a + nbands - 1) / nbands;
            let xs = &arena.xs[..a * d];
            let hidden = &mut arena.hidden[..a * m];
            let up = &mut arena.up[..a * m];
            let ys = &mut arena.ys[..a * d];
            std::thread::scope(|s| {
                let mut hid_rest = hidden;
                let mut up_rest = up;
                let mut ys_rest = ys;
                let mut r0 = 0usize;
                while r0 < a {
                    let rows = band.min(a - r0);
                    let (h, rest) = std::mem::take(&mut hid_rest).split_at_mut(rows * m);
                    hid_rest = rest;
                    let (u, rest) = std::mem::take(&mut up_rest).split_at_mut(rows * m);
                    up_rest = rest;
                    let (y, rest) = std::mem::take(&mut ys_rest).split_at_mut(rows * d);
                    ys_rest = rest;
                    s.spawn(move || run_band(xs, r0, rows, routing, experts, d, m, h, u, y));
                    r0 += rows;
                }
            });
        }

        // Deterministic combine: rows scatter back expert-major.
        tensor::scatter_add_scaled(
            &arena.ys[..a * d],
            d,
            routing.token_idx(),
            routing.gates(),
            out,
        );
    }
}

/// Grouped SwiGLU for gathered rows `[r0, r0 + rows)`, walking the
/// expert segments that overlap the band. Each segment is one call on
/// that expert's weights through whichever kernel its store view
/// selects: fp32 [`tensor::swiglu_rows_into`], or the fused-dequant
/// int8 twin [`crate::quant::QuantizedFfn::swiglu_rows_into`] — both
/// share the band's scratch slices and k-accumulation order, so the
/// token-weighted banding stays precision-agnostic.
#[allow(clippy::too_many_arguments)]
// lint: hot-path
fn run_band<S: ExpertStore + ?Sized>(
    xs: &[f32],
    r0: usize,
    rows: usize,
    routing: &GroupedRouting,
    experts: &S,
    d: usize,
    m: usize,
    hidden: &mut [f32],
    up: &mut [f32],
    ys: &mut [f32],
) {
    let end = r0 + rows;
    let mut r = r0;
    let mut e = routing.expert_of_row(r);
    while r < end {
        let e_end = routing.expert_rows(e).end;
        if e_end <= r {
            e += 1;
            continue;
        }
        let seg = e_end.min(end) - r;
        let lo = r - r0;
        let x_seg = &xs[r * d..(r + seg) * d];
        let h_seg = &mut hidden[lo * m..(lo + seg) * m];
        let u_seg = &mut up[lo * m..(lo + seg) * m];
        let y_seg = &mut ys[lo * d..(lo + seg) * d];
        match experts.view(e) {
            ExpertView::Fp32(w) => tensor::swiglu_rows_into(
                x_seg, &w.w_gate, &w.w_up, &w.w_down, h_seg, u_seg, y_seg,
            ),
            ExpertView::Int8(q) => q.swiglu_rows_into(x_seg, h_seg, u_seg, y_seg),
        }
        r += seg;
    }
}

/// Per-token reference dispatch: one tiny SwiGLU per (token, expert)
/// assignment — the pre-grouping baseline the sweep benchmarks compare
/// against, and the independent oracle the parity tests check
/// [`GroupedDispatcher`] against. Accumulation is expert-major to match
/// the grouped path's scatter order, so the comparison is bit-for-bit.
pub fn per_token_reference(
    xn: &Tensor,
    decisions: &[GateDecision],
    experts: &[FfnWeights],
    out: &mut Tensor,
) {
    let d = xn.shape[1];
    assert_eq!(out.shape, xn.shape);
    for (e, exp) in experts.iter().enumerate() {
        for (t, dec) in decisions.iter().enumerate() {
            for (k, &de) in dec.experts.iter().enumerate() {
                if de != e {
                    continue;
                }
                let x = Tensor::from_vec(xn.row(t).to_vec(), &[1, d]);
                let y = tensor::swiglu_ffn(&x, &exp.w_gate, &exp.w_up, &exp.w_down);
                let g = dec.gates[k];
                for (o, v) in out.row_mut(t).iter_mut().zip(&y.data) {
                    *o += g * v;
                }
            }
        }
    }
}

/// Builds grouped expert inputs and scatters outputs back — the
/// fixed-capacity schedule for the *device* expert artifact
/// (`experts_*`): gather each expert's tokens into a `[N_r, C, d]`
/// zero-padded block so all routed experts execute in one grouped
/// kernel call; tokens overflowing an expert's capacity are returned
/// and processed in a follow-up round (never dropped — reconstruction,
/// not quality, would silently degrade otherwise).
#[derive(Clone, Debug)]
pub struct ExpertDispatcher {
    pub n_experts: usize,
    pub capacity: usize,
    pub d: usize,
}

/// One dispatch round.
#[derive(Debug)]
pub struct Dispatch {
    /// `[N_r, C, d]` gathered (zero-padded) token block.
    pub xs: Tensor,
    /// Per expert: the (token, gate) filling each used slot.
    pub slots: Vec<Vec<(usize, f32)>>,
    /// Assignments that did not fit: (token, expert, gate).
    pub overflow: Vec<(usize, usize, f32)>,
}

impl ExpertDispatcher {
    pub fn new(n_experts: usize, capacity: usize, d: usize) -> Self {
        assert!(n_experts > 0 && capacity > 0 && d > 0);
        ExpertDispatcher { n_experts, capacity, d }
    }

    /// Build a dispatch from normed token states `xn: [B, d]` and the
    /// per-token decisions (token order preserved per expert — FIFO
    /// capacity assignment, matching the GShard convention).
    pub fn build(&self, xn: &Tensor, decisions: &[GateDecision]) -> Dispatch {
        let assignments: Vec<(usize, usize, f32)> = decisions
            .iter()
            .enumerate()
            .flat_map(|(t, dec)| {
                dec.experts.iter().zip(&dec.gates).map(move |(&e, &g)| (t, e, g))
            })
            .collect();
        self.build_from_assignments(xn, &assignments)
    }

    /// Build from explicit (token, expert, gate) triples (used for
    /// overflow rounds).
    pub fn build_from_assignments(
        &self,
        xn: &Tensor,
        assignments: &[(usize, usize, f32)],
    ) -> Dispatch {
        assert_eq!(xn.shape[1], self.d);
        let mut xs = Tensor::zeros(&[self.n_experts, self.capacity, self.d]);
        let mut slots: Vec<Vec<(usize, f32)>> = vec![Vec::new(); self.n_experts];
        let mut overflow = Vec::new();
        for &(t, e, g) in assignments {
            debug_assert!(e < self.n_experts, "expert {e} out of range");
            if slots[e].len() < self.capacity {
                let slot = slots[e].len();
                let dst_off = (e * self.capacity + slot) * self.d;
                xs.data[dst_off..dst_off + self.d].copy_from_slice(xn.row(t));
                slots[e].push((t, g));
            } else {
                overflow.push((t, e, g));
            }
        }
        Dispatch { xs, slots, overflow }
    }

    /// Scatter-add gated expert outputs `ys: [N_r, C, d]` into
    /// `out: [B, d]`.
    pub fn combine(&self, dispatch: &Dispatch, ys: &Tensor, out: &mut Tensor) {
        assert_eq!(ys.shape, vec![self.n_experts, self.capacity, self.d]);
        assert_eq!(out.shape[1], self.d);
        for (e, slot_list) in dispatch.slots.iter().enumerate() {
            for (slot, &(t, g)) in slot_list.iter().enumerate() {
                let src_off = (e * self.capacity + slot) * self.d;
                let src = &ys.data[src_off..src_off + self.d];
                let dst = out.row_mut(t);
                for (o, v) in dst.iter_mut().zip(src) {
                    *o += g * v;
                }
            }
        }
    }

    /// Tokens actually occupying slots in this dispatch (for FLOPs
    /// accounting / utilization tracking).
    pub fn used_slots(dispatch: &Dispatch) -> Vec<usize> {
        dispatch.slots.iter().map(|s| s.len()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn decisions_of(assign: &[(usize, Vec<(usize, f32)>)]) -> Vec<GateDecision> {
        assign
            .iter()
            .map(|(_, pairs)| GateDecision {
                experts: pairs.iter().map(|&(e, _)| e).collect(),
                gates: pairs.iter().map(|&(_, g)| g).collect(),
                scores: vec![],
            })
            .collect()
    }

    fn random_experts(rng: &mut Rng, n_e: usize, d: usize, m: usize) -> Vec<FfnWeights> {
        (0..n_e)
            .map(|_| FfnWeights {
                w_gate: Tensor::randn(rng, &[d, m], 0.5),
                w_up: Tensor::randn(rng, &[d, m], 0.5),
                w_down: Tensor::randn(rng, &[m, d], 0.5),
            })
            .collect()
    }

    fn random_decisions(rng: &mut Rng, b: usize, n_e: usize) -> Vec<GateDecision> {
        (0..b)
            .map(|_| {
                let k = rng.range(1, n_e + 1);
                let experts = rng.choose_k(n_e, k);
                GateDecision {
                    gates: (0..k).map(|_| 0.25 + rng.f32()).collect(),
                    experts,
                    scores: vec![],
                }
            })
            .collect()
    }

    /// Core parity check: grouped gather→GEMM→scatter must equal the
    /// per-token reference bit-for-bit (shared serial kernel + matched
    /// accumulation order — see module docs).
    fn assert_grouped_matches_reference(
        xn: &Tensor,
        decisions: &[GateDecision],
        experts: &[FfnWeights],
        arena: &mut DispatchArena,
    ) {
        let b = xn.shape[0];
        let d = xn.shape[1];
        let m = experts[0].hidden_dim();
        let mut routing = GroupedRouting::new(experts.len());
        routing.rebuild(experts.len(), decisions);
        let mut grouped = Tensor::zeros(&[b, d]);
        GroupedDispatcher::new(d, m).forward(xn, &routing, experts, arena, &mut grouped);
        let mut reference = Tensor::zeros(&[b, d]);
        per_token_reference(xn, decisions, experts, &mut reference);
        assert_eq!(
            grouped.data, reference.data,
            "grouped dispatch diverged from per-token reference"
        );
    }

    #[test]
    fn grouped_matches_per_token_reference_bit_for_bit() {
        crate::util::prop::check(
            "grouped-vs-per-token",
            crate::util::prop::Config { cases: 24, max_size: 20, ..Default::default() },
            |rng, size| {
                let b = rng.range(1, size + 2);
                let n_e = rng.range(1, 7);
                let d = rng.range(2, 10);
                let m = rng.range(1, 12);
                let xn = Tensor::randn(rng, &[b, d], 1.0);
                let experts = random_experts(rng, n_e, d, m);
                let decisions = random_decisions(rng, b, n_e);
                let mut arena = DispatchArena::new();
                assert_grouped_matches_reference(&xn, &decisions, &experts, &mut arena);
                Ok(())
            },
        );
    }

    #[test]
    fn grouped_handles_empty_experts_and_empty_wave() {
        let mut rng = Rng::new(402);
        let (d, m) = (6, 8);
        let experts = random_experts(&mut rng, 4, d, m);
        let xn = Tensor::randn(&mut rng, &[3, d], 1.0);
        // experts 1 and 3 never selected
        let decisions = decisions_of(&[
            (0, vec![(0, 1.0), (2, 0.5)]),
            (1, vec![(2, 2.0)]),
            (2, vec![(0, 0.25)]),
        ]);
        let mut arena = DispatchArena::new();
        assert_grouped_matches_reference(&xn, &decisions, &experts, &mut arena);

        // empty wave: forward is a no-op and must not touch `out`
        let mut routing = GroupedRouting::new(4);
        routing.rebuild(4, &[]);
        let mut out = Tensor::full(&[3, d], 7.0);
        GroupedDispatcher::new(d, m).forward(&xn, &routing, &experts, &mut arena, &mut out);
        assert!(out.data.iter().all(|&v| v == 7.0));
    }

    #[test]
    fn grouped_handles_all_tokens_on_one_expert() {
        // hot-expert extreme: the whole wave lands on expert 1; the
        // row-band scheme must split (not serialize) and stay exact
        let mut rng = Rng::new(403);
        let (b, d, m) = (33, 8, 16);
        let experts = random_experts(&mut rng, 3, d, m);
        let xn = Tensor::randn(&mut rng, &[b, d], 1.0);
        let decisions: Vec<GateDecision> = (0..b)
            .map(|_| GateDecision { experts: vec![1], gates: vec![1.5], scores: vec![] })
            .collect();
        let mut arena = DispatchArena::new();
        assert_grouped_matches_reference(&xn, &decisions, &experts, &mut arena);
    }

    #[test]
    fn grouped_is_parallelism_invariant() {
        // force the parallel path (work above PAR_THRESHOLD) and check
        // it against the serial reference — band splitting must not
        // change a single bit
        let mut rng = Rng::new(404);
        let (b, d, m) = (64, 32, 128);
        let experts = random_experts(&mut rng, 4, d, m);
        let xn = Tensor::randn(&mut rng, &[b, d], 1.0);
        // every token activates every expert: A = 4·b rows, so
        // A · m = 32768 ≥ PAR_THRESHOLD and the banded path runs
        let decisions: Vec<GateDecision> = (0..b)
            .map(|_| GateDecision {
                experts: vec![0, 1, 2, 3],
                gates: (0..4).map(|_| 0.25 + rng.f32()).collect(),
                scores: vec![],
            })
            .collect();
        assert!(4 * b * m >= GroupedDispatcher::PAR_THRESHOLD);
        let mut arena = DispatchArena::new();
        assert_grouped_matches_reference(&xn, &decisions, &experts, &mut arena);
    }

    #[test]
    fn arena_stabilizes_after_warmup() {
        // the zero-allocation claim, observable: after the first (largest)
        // wave, repeated dispatch grows nothing
        let mut rng = Rng::new(405);
        let (b, d, m) = (16, 8, 8);
        let experts = random_experts(&mut rng, 4, d, m);
        let disp = GroupedDispatcher::new(d, m);
        let mut arena = DispatchArena::new();
        let mut routing = GroupedRouting::new(4);
        let mut out = Tensor::zeros(&[b, d]);
        // warmup wave at maximum assignment count (every token → every
        // expert): one allocation, sized for anything that follows
        let full: Vec<GateDecision> = (0..b)
            .map(|_| GateDecision {
                experts: vec![0, 1, 2, 3],
                gates: vec![1.0; 4],
                scores: vec![],
            })
            .collect();
        let xn = Tensor::randn(&mut rng, &[b, d], 1.0);
        routing.rebuild(4, &full);
        disp.forward(&xn, &routing, &experts, &mut arena, &mut out);
        assert_eq!(arena.grow_events(), 1, "warmup wave allocates once");
        assert!(arena.high_water_bytes() > 0);
        // steady state: smaller-or-equal random waves grow nothing
        for _ in 0..5 {
            let xn = Tensor::randn(&mut rng, &[b, d], 1.0);
            let decisions = random_decisions(&mut rng, b, 4);
            routing.rebuild(4, &decisions);
            out.data.fill(0.0);
            disp.forward(&xn, &routing, &experts, &mut arena, &mut out);
        }
        assert_eq!(arena.grow_events(), 1, "steady state must not reallocate");
        let hwm = arena.high_water_bytes();
        // smaller waves fit in the warm arena
        let xn = Tensor::randn(&mut rng, &[4, d], 1.0);
        let decisions = random_decisions(&mut rng, 4, 4);
        routing.rebuild(4, &decisions);
        let mut small_out = Tensor::zeros(&[4, d]);
        disp.forward(&xn, &routing, &experts, &mut arena, &mut small_out);
        assert_eq!(arena.grow_events(), 1);
        assert_eq!(arena.high_water_bytes(), hwm);
    }

    #[test]
    fn gather_places_tokens_in_expert_blocks() {
        let mut rng = Rng::new(321);
        let xn = Tensor::randn(&mut rng, &[3, 4], 1.0);
        let disp = ExpertDispatcher::new(2, 2, 4);
        let dec = decisions_of(&[
            (0, vec![(0, 1.0)]),
            (1, vec![(1, 1.0)]),
            (2, vec![(0, 1.0)]),
        ]);
        let d = disp.build(&xn, &dec);
        assert!(d.overflow.is_empty());
        assert_eq!(d.slots[0], vec![(0, 1.0), (2, 1.0)]);
        assert_eq!(d.slots[1], vec![(1, 1.0)]);
        // expert 0 slot 1 holds token 2's row
        assert_eq!(&d.xs.data[(0 * 2 + 1) * 4..(0 * 2 + 1) * 4 + 4], xn.row(2));
        // unused slot is zero
        assert!(d.xs.data[(1 * 2 + 1) * 4..(1 * 2 + 1) * 4 + 4].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn overflow_is_captured_not_dropped() {
        let mut rng = Rng::new(322);
        let xn = Tensor::randn(&mut rng, &[4, 3], 1.0);
        let disp = ExpertDispatcher::new(2, 1, 3);
        let dec = decisions_of(&[
            (0, vec![(0, 1.0)]),
            (1, vec![(0, 2.0)]),
            (2, vec![(0, 3.0)]),
            (3, vec![(1, 1.0)]),
        ]);
        let d = disp.build(&xn, &dec);
        assert_eq!(d.slots[0].len(), 1);
        assert_eq!(d.overflow, vec![(1, 0, 2.0), (2, 0, 3.0)]);
        // second round drains the overflow
        let d2 = disp.build_from_assignments(&xn, &d.overflow);
        assert_eq!(d2.slots[0], vec![(1, 2.0)]);
        assert_eq!(d2.overflow, vec![(2, 0, 3.0)]);
    }

    #[test]
    fn combine_is_exact_gated_sum() {
        // gather→(identity expert)→combine must equal Σ g·x per token
        let mut rng = Rng::new(323);
        let b = 5;
        let d = 4;
        let xn = Tensor::randn(&mut rng, &[b, d], 1.0);
        let disp = ExpertDispatcher::new(3, 4, d);
        let dec: Vec<GateDecision> = (0..b)
            .map(|t| GateDecision {
                experts: vec![t % 3, (t + 1) % 3],
                gates: vec![1.0, 0.5],
                scores: vec![],
            })
            .collect();
        let dd = disp.build(&xn, &dec);
        assert!(dd.overflow.is_empty());
        // experts compute identity: ys = xs
        let ys = dd.xs.clone();
        let mut out = Tensor::zeros(&[b, d]);
        disp.combine(&dd, &ys, &mut out);
        for t in 0..b {
            for j in 0..d {
                let want = 1.0 * xn.at2(t, j) + 0.5 * xn.at2(t, j);
                assert!((out.at2(t, j) - want).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn dispatch_preserves_every_assignment() {
        // property: slots + overflow = all assignments
        crate::util::prop::check(
            "dispatch-conservation",
            crate::util::prop::Config { cases: 40, max_size: 24, ..Default::default() },
            |rng, size| {
                let b = rng.range(1, size + 2);
                let n_e = rng.range(1, 6);
                let cap = rng.range(1, 5);
                let d = rng.range(1, 6);
                let xn = Tensor::randn(rng, &[b, d], 1.0);
                let disp = ExpertDispatcher::new(n_e, cap, d);
                let dec: Vec<GateDecision> = (0..b)
                    .map(|_| {
                        let k = rng.range(1, n_e + 1);
                        let experts = rng.choose_k(n_e, k);
                        GateDecision {
                            gates: vec![1.0; k],
                            experts,
                            scores: vec![],
                        }
                    })
                    .collect();
                let total: usize = dec.iter().map(|d| d.experts.len()).sum();
                let dd = disp.build(&xn, &dec);
                let placed: usize = dd.slots.iter().map(|s| s.len()).sum();
                crate::prop_assert!(
                    placed + dd.overflow.len() == total,
                    "lost assignments: {placed} + {} != {total}",
                    dd.overflow.len()
                );
                for s in &dd.slots {
                    crate::prop_assert!(s.len() <= cap, "capacity exceeded");
                }
                Ok(())
            },
        );
    }
}
