//! Capacity-factor expert dispatch (the schedule GPU MoE serving uses,
//! and the Table 9 FLOPs-saving mechanism).
//!
//! Given per-token routing decisions, gather each expert's tokens into
//! a fixed-capacity block `xs: [N_r, C, d]` (padding unused slots with
//! zeros) so ALL routed experts execute in ONE grouped-kernel call.
//! Tokens that overflow an expert's capacity are returned and processed
//! in a follow-up round (never dropped — reconstruction, not quality,
//! would silently degrade otherwise).

use crate::moe::GateDecision;
use crate::tensor::Tensor;

/// Builds grouped expert inputs and scatters outputs back.
#[derive(Clone, Debug)]
pub struct ExpertDispatcher {
    pub n_experts: usize,
    pub capacity: usize,
    pub d: usize,
}

/// One dispatch round.
#[derive(Debug)]
pub struct Dispatch {
    /// `[N_r, C, d]` gathered (zero-padded) token block.
    pub xs: Tensor,
    /// Per expert: the (token, gate) filling each used slot.
    pub slots: Vec<Vec<(usize, f32)>>,
    /// Assignments that did not fit: (token, expert, gate).
    pub overflow: Vec<(usize, usize, f32)>,
}

impl ExpertDispatcher {
    pub fn new(n_experts: usize, capacity: usize, d: usize) -> Self {
        assert!(n_experts > 0 && capacity > 0 && d > 0);
        ExpertDispatcher { n_experts, capacity, d }
    }

    /// Build a dispatch from normed token states `xn: [B, d]` and the
    /// per-token decisions (token order preserved per expert — FIFO
    /// capacity assignment, matching the GShard convention).
    pub fn build(&self, xn: &Tensor, decisions: &[GateDecision]) -> Dispatch {
        let assignments: Vec<(usize, usize, f32)> = decisions
            .iter()
            .enumerate()
            .flat_map(|(t, dec)| {
                dec.experts.iter().zip(&dec.gates).map(move |(&e, &g)| (t, e, g))
            })
            .collect();
        self.build_from_assignments(xn, &assignments)
    }

    /// Build from explicit (token, expert, gate) triples (used for
    /// overflow rounds).
    pub fn build_from_assignments(
        &self,
        xn: &Tensor,
        assignments: &[(usize, usize, f32)],
    ) -> Dispatch {
        assert_eq!(xn.shape[1], self.d);
        let mut xs = Tensor::zeros(&[self.n_experts, self.capacity, self.d]);
        let mut slots: Vec<Vec<(usize, f32)>> = vec![Vec::new(); self.n_experts];
        let mut overflow = Vec::new();
        for &(t, e, g) in assignments {
            debug_assert!(e < self.n_experts, "expert {e} out of range");
            if slots[e].len() < self.capacity {
                let slot = slots[e].len();
                let dst_off = (e * self.capacity + slot) * self.d;
                xs.data[dst_off..dst_off + self.d].copy_from_slice(xn.row(t));
                slots[e].push((t, g));
            } else {
                overflow.push((t, e, g));
            }
        }
        Dispatch { xs, slots, overflow }
    }

    /// Scatter-add gated expert outputs `ys: [N_r, C, d]` into
    /// `out: [B, d]`.
    pub fn combine(&self, dispatch: &Dispatch, ys: &Tensor, out: &mut Tensor) {
        assert_eq!(ys.shape, vec![self.n_experts, self.capacity, self.d]);
        assert_eq!(out.shape[1], self.d);
        for (e, slot_list) in dispatch.slots.iter().enumerate() {
            for (slot, &(t, g)) in slot_list.iter().enumerate() {
                let src_off = (e * self.capacity + slot) * self.d;
                let src = &ys.data[src_off..src_off + self.d];
                let dst = out.row_mut(t);
                for (o, v) in dst.iter_mut().zip(src) {
                    *o += g * v;
                }
            }
        }
    }

    /// Tokens actually occupying slots in this dispatch (for FLOPs
    /// accounting / utilization tracking).
    pub fn used_slots(dispatch: &Dispatch) -> Vec<usize> {
        dispatch.slots.iter().map(|s| s.len()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn decisions_of(assign: &[(usize, Vec<(usize, f32)>)]) -> Vec<GateDecision> {
        assign
            .iter()
            .map(|(_, pairs)| GateDecision {
                experts: pairs.iter().map(|&(e, _)| e).collect(),
                gates: pairs.iter().map(|&(_, g)| g).collect(),
                scores: vec![],
            })
            .collect()
    }

    #[test]
    fn gather_places_tokens_in_expert_blocks() {
        let mut rng = Rng::new(321);
        let xn = Tensor::randn(&mut rng, &[3, 4], 1.0);
        let disp = ExpertDispatcher::new(2, 2, 4);
        let dec = decisions_of(&[
            (0, vec![(0, 1.0)]),
            (1, vec![(1, 1.0)]),
            (2, vec![(0, 1.0)]),
        ]);
        let d = disp.build(&xn, &dec);
        assert!(d.overflow.is_empty());
        assert_eq!(d.slots[0], vec![(0, 1.0), (2, 1.0)]);
        assert_eq!(d.slots[1], vec![(1, 1.0)]);
        // expert 0 slot 1 holds token 2's row
        assert_eq!(&d.xs.data[(0 * 2 + 1) * 4..(0 * 2 + 1) * 4 + 4], xn.row(2));
        // unused slot is zero
        assert!(d.xs.data[(1 * 2 + 1) * 4..(1 * 2 + 1) * 4 + 4].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn overflow_is_captured_not_dropped() {
        let mut rng = Rng::new(322);
        let xn = Tensor::randn(&mut rng, &[4, 3], 1.0);
        let disp = ExpertDispatcher::new(2, 1, 3);
        let dec = decisions_of(&[
            (0, vec![(0, 1.0)]),
            (1, vec![(0, 2.0)]),
            (2, vec![(0, 3.0)]),
            (3, vec![(1, 1.0)]),
        ]);
        let d = disp.build(&xn, &dec);
        assert_eq!(d.slots[0].len(), 1);
        assert_eq!(d.overflow, vec![(1, 0, 2.0), (2, 0, 3.0)]);
        // second round drains the overflow
        let d2 = disp.build_from_assignments(&xn, &d.overflow);
        assert_eq!(d2.slots[0], vec![(1, 2.0)]);
        assert_eq!(d2.overflow, vec![(2, 0, 3.0)]);
    }

    #[test]
    fn combine_is_exact_gated_sum() {
        // gather→(identity expert)→combine must equal Σ g·x per token
        let mut rng = Rng::new(323);
        let b = 5;
        let d = 4;
        let xn = Tensor::randn(&mut rng, &[b, d], 1.0);
        let disp = ExpertDispatcher::new(3, 4, d);
        let dec: Vec<GateDecision> = (0..b)
            .map(|t| GateDecision {
                experts: vec![t % 3, (t + 1) % 3],
                gates: vec![1.0, 0.5],
                scores: vec![],
            })
            .collect();
        let dd = disp.build(&xn, &dec);
        assert!(dd.overflow.is_empty());
        // experts compute identity: ys = xs
        let ys = dd.xs.clone();
        let mut out = Tensor::zeros(&[b, d]);
        disp.combine(&dd, &ys, &mut out);
        for t in 0..b {
            for j in 0..d {
                let want = 1.0 * xn.at2(t, j) + 0.5 * xn.at2(t, j);
                assert!((out.at2(t, j) - want).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn dispatch_preserves_every_assignment() {
        // property: slots + overflow = all assignments
        crate::util::prop::check(
            "dispatch-conservation",
            crate::util::prop::Config { cases: 40, max_size: 24, ..Default::default() },
            |rng, size| {
                let b = rng.range(1, size + 2);
                let n_e = rng.range(1, 6);
                let cap = rng.range(1, 5);
                let d = rng.range(1, 6);
                let xn = Tensor::randn(rng, &[b, d], 1.0);
                let disp = ExpertDispatcher::new(n_e, cap, d);
                let dec: Vec<GateDecision> = (0..b)
                    .map(|_| {
                        let k = rng.range(1, n_e + 1);
                        let experts = rng.choose_k(n_e, k);
                        GateDecision {
                            gates: vec![1.0; k],
                            experts,
                            scores: vec![],
                        }
                    })
                    .collect();
                let total: usize = dec.iter().map(|d| d.experts.len()).sum();
                let dd = disp.build(&xn, &dec);
                let placed: usize = dd.slots.iter().map(|s| s.len()).sum();
                crate::prop_assert!(
                    placed + dd.overflow.len() == total,
                    "lost assignments: {placed} + {} != {total}",
                    dd.overflow.len()
                );
                for s in &dd.slots {
                    crate::prop_assert!(s.len() <= cap, "capacity exceeded");
                }
                Ok(())
            },
        );
    }
}
