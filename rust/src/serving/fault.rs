//! Fault injection for the serving stack: a [`StepForward`] decorator
//! that fails calls on a seeded schedule, used to prove the engine's
//! containment contract — any single forward failure degrades **one
//! request at a time, never the process** (`tests/fault_injection.rs`).
//!
//! Faults are injected *before* delegating, so a failed call has no
//! side effects on the inner backend — the same failure envelope as a
//! device error surfacing from PJRT before kernel launch. The session
//! reacts by isolating the batch (retrying each request alone) and
//! retiring individually-failing requests with a typed
//! [`crate::serving::RequestFailure`]; everything else keeps its exact
//! token stream.
//!
//! Three knobs:
//! * **seeded rates** (`p_map`, `p_prefill`, `p_decode`) — each call
//!   rolls the decorator's own [`Rng`]; deterministic per seed, so a
//!   failing trace replays exactly;
//! * **one-shot counters** (`fail_next_prefill`, `fail_next_decode`)
//!   — deterministic unit tests arm exactly one failure;
//! * **poison token** — every prefill whose prompt contains the token
//!   fails, which targets exactly one request end-to-end (its isolated
//!   retry fails too, so precisely that request retires with an
//!   error).

use crate::runtime::ParkedSlot;
use crate::serving::metrics::PageMetrics;
use crate::serving::scheduler::{PrefillOutcome, StepForward};
use crate::util::Rng;
use anyhow::{bail, Result};

/// A [`StepForward`] that injects failures in front of `inner`.
pub struct FaultInjectingForward<F: StepForward> {
    inner: F,
    rng: Rng,
    /// Probability each `map_prefix` call fails.
    pub p_map: f32,
    /// Probability each `prefill` call fails.
    pub p_prefill: f32,
    /// Probability each `decode` call fails.
    pub p_decode: f32,
    /// Fail prefills whose prompt contains this token (the isolated
    /// retry included — targets exactly the poisoned request).
    pub poison_token: Option<usize>,
    /// Fail the next N prefill calls unconditionally.
    pub fail_next_prefill: u32,
    /// Fail the next N decode calls unconditionally.
    pub fail_next_decode: u32,
    /// Faults injected so far (tests assert the schedule actually
    /// fired).
    pub injected: u64,
}

impl<F: StepForward> FaultInjectingForward<F> {
    /// Wrap `inner` with all fault knobs off; arm them via the public
    /// fields or [`FaultInjectingForward::with_rates`].
    pub fn new(inner: F, seed: u64) -> Self {
        FaultInjectingForward {
            inner,
            rng: Rng::new(seed),
            p_map: 0.0,
            p_prefill: 0.0,
            p_decode: 0.0,
            poison_token: None,
            fail_next_prefill: 0,
            fail_next_decode: 0,
            injected: 0,
        }
    }

    /// Seeded random failure rates for the three forward entry points.
    pub fn with_rates(mut self, p_map: f32, p_prefill: f32, p_decode: f32) -> Self {
        self.p_map = p_map;
        self.p_prefill = p_prefill;
        self.p_decode = p_decode;
        self
    }

    pub fn inner(&self) -> &F {
        &self.inner
    }

    pub fn inner_mut(&mut self) -> &mut F {
        &mut self.inner
    }

    fn roll(&mut self, p: f32) -> bool {
        p > 0.0 && self.rng.f32() < p
    }
}

impl<F: StepForward> StepForward for FaultInjectingForward<F> {
    fn map_prefix(&mut self, slot: usize, prompt: &[usize]) -> Result<Option<usize>> {
        if self.roll(self.p_map) {
            self.injected += 1;
            bail!("injected map_prefix fault (slot {slot})");
        }
        self.inner.map_prefix(slot, prompt)
    }

    fn prefill(
        &mut self,
        slots: &[usize],
        prompts: &[&[usize]],
        cached: &[usize],
    ) -> Result<Vec<PrefillOutcome>> {
        if self.fail_next_prefill > 0 {
            self.fail_next_prefill -= 1;
            self.injected += 1;
            bail!("injected prefill fault ({} slots)", slots.len());
        }
        if let Some(tok) = self.poison_token {
            if prompts.iter().any(|p| p.contains(&tok)) {
                self.injected += 1;
                bail!("injected prefill fault: poison token {tok} in prompt");
            }
        }
        if self.roll(self.p_prefill) {
            self.injected += 1;
            bail!("injected prefill fault ({} slots)", slots.len());
        }
        self.inner.prefill(slots, prompts, cached)
    }

    fn decode(
        &mut self,
        slots: &[usize],
        tokens: &[i32],
        pos: &[usize],
        bucket: usize,
    ) -> Result<Vec<Vec<f32>>> {
        if self.fail_next_decode > 0 {
            self.fail_next_decode -= 1;
            self.injected += 1;
            bail!("injected decode fault ({} rows)", slots.len());
        }
        if self.roll(self.p_decode) {
            self.injected += 1;
            bail!("injected decode fault ({} rows)", slots.len());
        }
        self.inner.decode(slots, tokens, pos, bucket)
    }

    fn release(&mut self, slot: usize) {
        self.inner.release(slot);
    }

    fn park(&mut self, slot: usize) -> Option<ParkedSlot> {
        self.inner.park(slot)
    }

    fn unpark(&mut self, slot: usize, parked: ParkedSlot) {
        self.inner.unpark(slot, parked);
    }

    fn drop_parked(&mut self, parked: ParkedSlot) {
        self.inner.drop_parked(parked);
    }

    fn kv_capacity(&self) -> usize {
        self.inner.kv_capacity()
    }

    fn set_slot_ratio(&mut self, slot: usize, ratio: f32) {
        // never faulted: the operating point is host bookkeeping, not
        // a device call — and a lost ratio would silently serve the
        // wrong tier rather than fail a request, which is outside the
        // containment contract under test
        self.inner.set_slot_ratio(slot, ratio);
    }

    fn page_metrics(&self) -> Option<PageMetrics> {
        self.inner.page_metrics()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::scheduler::StubForward;

    #[test]
    fn armed_counter_fails_exactly_once_with_no_side_effects() {
        let mut f = FaultInjectingForward::new(StubForward::new(1, 7, 16), 1);
        f.fail_next_prefill = 1;
        assert!(f.prefill(&[0], &[&[1, 2][..]], &[0]).is_err());
        assert_eq!(f.injected, 1);
        assert_eq!(f.inner().prefilled_tokens, 0, "fault fired before delegation");
        // disarmed: the retry succeeds
        assert!(f.prefill(&[0], &[&[1, 2][..]], &[0]).is_ok());
        assert_eq!(f.inner().prefilled_tokens, 2);
    }

    #[test]
    fn poison_token_targets_matching_prompts_only() {
        let mut f = FaultInjectingForward::new(StubForward::new(2, 7, 16), 1);
        f.poison_token = Some(99);
        assert!(f.prefill(&[0], &[&[1, 99][..]], &[0]).is_err());
        assert!(f.prefill(&[0], &[&[1, 2][..]], &[0]).is_ok());
        assert_eq!(f.injected, 1);
    }

    #[test]
    fn seeded_rates_replay_identically() {
        let schedule = |seed: u64| -> Vec<bool> {
            let mut f = FaultInjectingForward::new(StubForward::new(1, 7, 64), seed)
                .with_rates(0.0, 0.0, 0.5);
            (0..32).map(|_| f.decode(&[], &[], &[], 1).is_err()).collect()
        };
        assert_eq!(schedule(7), schedule(7));
        assert_ne!(schedule(7), schedule(8), "different seeds, different schedules");
    }
}
