//! `cmoe` — the CLI for the CMoE reproduction.
//!
//! ```text
//! cmoe convert  --model artifacts/small.cmw [--method cmoe] --spec S3A3E8 --out converted.cmw
//!               [--finetune 2048] [--save-stages stages/] [--resume-from stages/profile.json]
//! cmoe methods  # conversion-method registry (incl. <base>+cmoe-router hybrids)
//! cmoe profile  --model artifacts/small.cmw [--domain markov] [--ka 10]
//! cmoe eval     --model <cmw> [--ppl markov,arith]
//! cmoe serve    --model <cmw> --mode dense|moe|orchestrated [--spec S3A3E8] --requests 32
//!               [--sched continuous|waves] [--buckets 1,8,32]
//!               [--page-len 16] [--prefix-cache]
//!               [--dynamic-k 0.5] [--k-min 1] [--tier-ratios 1.0,0.25]
//!               [--quant-experts] [--resident-cap 6]
//! cmoe bench    --exp table1|fig2|serving|all [--out results/]
//! cmoe info     # artifact + zoo inventory
//! ```

use anyhow::{bail, Context, Result};
use cmoe::bench_harness::{self, common::Ctx};
use cmoe::data::calibration::{CalibrationSpec, DEFAULT_SEED, DEFAULT_SEQ};
use cmoe::data::corpus::Domain;
use cmoe::model::{ModelWeights, MoeSpec};
use cmoe::pipeline::{registry, Pipeline};
use cmoe::util::argparse::Args;

fn main() {
    let args = Args::from_env(&["verbose", "no-finetune", "prefix-cache", "json", "quant-experts"]);
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn artifact_dir(args: &Args) -> String {
    args.get_or("artifacts", cmoe::DEFAULT_ARTIFACT_DIR).to_string()
}

fn run(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("convert") => cmd_convert(args),
        Some("methods") => cmd_methods(args),
        Some("profile") => cmd_profile(args),
        Some("eval") => cmd_eval(args),
        Some("serve") => cmd_serve(args),
        Some("bench") => cmd_bench(args),
        Some("info") => cmd_info(args),
        Some("lint") => cmd_lint(args),
        Some(other) => {
            bail!("unknown subcommand '{other}' (try: convert methods profile eval serve bench info lint)")
        }
        None => {
            println!("cmoe {} — analytical FFN-to-MoE restructuring", cmoe::VERSION);
            println!("subcommands: convert methods profile eval serve bench info lint");
            Ok(())
        }
    }
}

fn load_model(args: &Args) -> Result<ModelWeights> {
    let default = format!("{}/small.cmw", artifact_dir(args));
    let path = args.get_or("model", &default);
    ModelWeights::load(path).with_context(|| format!("loading model from {path}"))
}

fn calib_from_args(args: &Args) -> Result<CalibrationSpec> {
    let domain = Domain::parse(args.get_or("domain", "markov")).context("bad --domain")?;
    Ok(CalibrationSpec {
        domain,
        examples: args.get_usize("calib-examples", 8),
        seq: DEFAULT_SEQ,
        k_a: args.get_usize("ka", 10),
        seed: DEFAULT_SEED,
    })
}

fn cmd_convert(args: &Args) -> Result<()> {
    let model = load_model(args)?;
    let method = args.get_or("method", "cmoe");
    let calib = calib_from_args(args)?;
    let out = args.get_or("out", "converted.cmw");

    let mut pipe = Pipeline::for_method(method)?.calib(calib);
    if let Some(s) = args.get("spec") {
        pipe = pipe.spec(s.parse()?);
    }
    let ft = if args.has("no-finetune") { 0 } else { args.get_usize("finetune", 2048) };
    pipe = pipe.finetune(ft);
    if let Some(dir) = args.get("save-stages") {
        pipe = pipe.save_stages(dir);
    }
    if let Some(path) = args.get("resume-from") {
        pipe = pipe.resume_from(path);
    }

    println!("converting with method '{method}' to {} …", pipe.current_spec());
    let run = pipe.run_and_save(&model, out)?;
    println!("{}", run.summary());
    println!("wrote {out}");
    Ok(())
}

fn cmd_methods(_args: &Args) -> Result<()> {
    let mut t = cmoe::util::table::Table::new(
        "conversion-method registry (cmoe convert --method <name>)",
        &["Method", "Grouping", "Router", "Default spec"],
    );
    for name in registry::names() {
        let m = registry::get(&name)?;
        t.row(vec![m.name, m.grouping.to_string(), m.routing.to_string(), m.default_spec.to_string()]);
    }
    println!("{}", t.render());
    println!(
        "hybrids: <base>{} swaps any baseline's router for CMoE's analytical one (Table 5's \"+ ours\" rows)",
        registry::CMOE_ROUTER_SUFFIX
    );
    println!("stages resume from --save-stages artifacts: profile.json, partition.json, router.cmw");
    println!(
        "serve-time dynamic activation: `cmoe serve --dynamic-k <h>` floats per-token expert \
         counts on router entropy; `--tier-ratios full,degraded` maps effort tiers to \
         activation ratios (paper's 25%/75% operating points) applied per slot-row"
    );
    Ok(())
}

fn cmd_profile(args: &Args) -> Result<()> {
    let model = load_model(args)?;
    let profiles = calib_from_args(args)?.profiles(&model);
    for (l, p) in profiles.iter().enumerate() {
        println!(
            "layer {l}: q={} K_a={} bimodality={:.3} sparsity(|h|<0.05)={:.3}",
            p.q,
            p.k_a,
            p.rate_bimodality(),
            p.sparsity_fraction(0.05)
        );
    }
    if args.has("verbose") {
        println!("\nactivation-rate histogram (layer 0):");
        println!("{}", profiles[0].rate_histogram(20).ascii(50));
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let model = load_model(args)?;
    let suites = [
        cmoe::eval::tasks::TaskSuite {
            name: "Knowledge".into(),
            tasks: cmoe::data::gen_choice_tasks(
                cmoe::data::tasks_gen::TaskFamily::Knowledge,
                80,
                0xC0DE ^ 1,
            ),
        },
        cmoe::eval::tasks::TaskSuite {
            name: "Arith".into(),
            tasks: cmoe::data::gen_choice_tasks(
                cmoe::data::tasks_gen::TaskFamily::Arith,
                80,
                0xC0DE ^ 2,
            ),
        },
        cmoe::eval::tasks::TaskSuite {
            name: "Pattern".into(),
            tasks: cmoe::data::gen_choice_tasks(
                cmoe::data::tasks_gen::TaskFamily::Pattern,
                80,
                0xC0DE ^ 3,
            ),
        },
    ];
    for s in &suites {
        println!("{}: {:.2}%", s.name, cmoe::eval::choice_accuracy(&model, s) * 100.0);
    }
    for name in args.get_or("ppl", "markov,arith").split(',') {
        let Some(domain) = Domain::parse(name) else { continue };
        let toks =
            CalibrationSpec { domain, ..Default::default() }.eval_tokens(8 * 1024);
        println!("PPL {}: {:.3}", name, cmoe::eval::perplexity(&model, &toks, 256));
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    use cmoe::serving::{Engine, EngineConfig, ExecMode, GenParams, Request};
    let model = load_model(args)?;
    let rt = std::sync::Arc::new(cmoe::runtime::XlaRuntime::load(artifact_dir(args))?);
    let model_name = args.get_or("model-name", "small").to_string();
    let kv_len = args.get_usize("kv-len", 256);
    let mode = match args.get_or("mode", "dense") {
        "dense" => ExecMode::Dense,
        "moe" => ExecMode::MoeMonolithic,
        "orchestrated" => ExecMode::MoeOrchestrated,
        m => bail!("unknown --mode {m}"),
    };
    let spec: Option<MoeSpec> = args.get("spec").map(|s| s.parse()).transpose()?;
    let mut cfg = match mode {
        ExecMode::Dense => EngineConfig::dense(&model_name, kv_len),
        m => EngineConfig::moe(
            &model_name,
            kv_len,
            spec.context("MoE modes need --spec")?,
            m,
        ),
    };
    let batch = args.get_usize("batch", 8);
    // --buckets 1,8,32 gives the continuous scheduler its ladder; the
    // default single bucket pins both schedulers to one compiled batch
    cfg.batcher.buckets = match args.get("buckets") {
        Some(s) => {
            let buckets = s
                .split(',')
                .map(|b| b.trim().parse::<usize>().context("bad --buckets"))
                .collect::<Result<Vec<_>>>()?;
            if buckets.is_empty() || buckets.contains(&0) {
                bail!("--buckets needs a non-empty list of batch sizes >= 1");
            }
            buckets
        }
        None => vec![batch],
    };
    cfg.batcher.max_wait = std::time::Duration::ZERO;
    // paged KV: --page-len sets the slot pool's page size; --prefix-cache
    // deduplicates shared prefill rows across requests (memory dedup on
    // the artifact path — see serving::engine)
    cfg.page_len = args.get_usize("page-len", cmoe::serving::DEFAULT_PAGE_LEN).max(1);
    cfg.prefix_cache = args.has("prefix-cache");
    // dynamic activation (ROADMAP item 4, orchestrated mode):
    // --dynamic-k <h> floats per-token expert counts on router entropy
    // (0 = fixed top-k, the default); --tier-ratios full,degraded sets
    // the effort-tier activation operating points applied per slot-row
    let dk_threshold = args.get_f64("dynamic-k", 0.0) as f32;
    if !(0.0..=1.0).contains(&dk_threshold) {
        bail!("--dynamic-k must be a normalized-entropy threshold in [0, 1]");
    }
    cfg.dynamic_k = cmoe::moe::DynamicK {
        threshold: dk_threshold,
        k_min: args.get_usize("k-min", 1).max(1),
    };
    if let Some(s) = args.get("tier-ratios") {
        let parts: Vec<f32> = s
            .split(',')
            .map(|r| r.trim().parse::<f32>().context("bad --tier-ratios"))
            .collect::<Result<Vec<_>>>()?;
        let [full, degraded] = parts[..] else {
            bail!("--tier-ratios takes exactly two values: full,degraded (e.g. 1.0,0.25)");
        };
        if !(0.0..=1.0).contains(&degraded) || !(0.0..=1.0).contains(&full) {
            bail!("--tier-ratios values must be activation ratios in [0, 1]");
        }
        cfg.batcher.tier_ratios = cmoe::serving::TierRatios { full, degraded };
    }
    // quantized expert storage (orchestrated mode): --quant-experts
    // serves routed experts as int8 row bands behind the residency
    // tier; --resident-cap bounds the warm set per MoE layer
    cfg.quant_experts = args.has("quant-experts");
    cfg.resident_cap = args.get_usize("resident-cap", cmoe::moe::DEFAULT_RESIDENT_CAP);
    if cfg.quant_experts && mode != ExecMode::MoeOrchestrated {
        bail!("--quant-experts requires --mode orchestrated (expert weights are in-graph elsewhere)");
    }
    if cfg.resident_cap == 0 {
        bail!("--resident-cap must be >= 1");
    }
    let sched = args.get_or("sched", "continuous").to_string();
    let engine = Engine::new(rt, model, cfg)?;

    let n = args.get_usize("requests", 16);
    let new_tokens = args.get_usize("max-new-tokens", 32);
    let reqs: Vec<Request> = (0..n)
        .map(|i| {
            let prompt_text = cmoe::data::corpus::gen_corpus(&cmoe::data::corpus::CorpusSpec {
                domain: Domain::Arith,
                bytes: 16,
                seed: i as u64,
            });
            Request::new(
                i as u64,
                cmoe::data::encode(&prompt_text),
                GenParams {
                    max_new_tokens: new_tokens,
                    temperature: args.get_f64("temperature", 0.0) as f32,
                    seed: i as u64,
                    stop_token: None,
                },
            )
        })
        .collect();
    // lint: allow(clock-discipline) — CLI-facing wall-clock elapsed report, not serving logic
    let t0 = std::time::Instant::now();
    let results = match sched.as_str() {
        "continuous" => engine.run_queue(reqs)?,
        "waves" => engine.run_queue_waves(reqs)?,
        s => bail!("unknown --sched {s} (continuous|waves)"),
    };
    let elapsed = t0.elapsed();
    for r in results.iter().take(4) {
        println!("req {} -> {:?}", r.id, cmoe::data::decode(&r.tokens));
    }
    let m = engine.metrics.lock().unwrap();
    println!(
        "{} requests in {:?} [{sched}] — {}",
        results.len(),
        elapsed,
        m.summary()
    );
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    let exp = args.get_or("exp", "all").to_string();
    let out = args.get_or("out", "results").to_string();
    let mut ctx = Ctx::new(artifact_dir(args), out);
    let tables = bench_harness::run(&exp, &mut ctx)?;
    for t in &tables {
        println!("\n{}", t.render());
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    println!("model zoo:");
    for &(name, vocab, d, l, h, dff, seq) in cmoe::model::MODEL_ZOO {
        println!("  {name}: vocab={vocab} d={d} layers={l} heads={h} d_ff={dff} max_seq={seq}");
    }
    let dir = artifact_dir(args);
    match cmoe::runtime::Manifest::load(std::path::Path::new(&dir).join("manifest.json").as_path())
    {
        Ok(m) => {
            println!("artifacts in {dir}: {}", m.artifacts.len());
            if args.has("verbose") {
                let mut names: Vec<&String> = m.artifacts.keys().collect();
                names.sort();
                for n in names {
                    println!("  {n}");
                }
            }
        }
        Err(_) => println!("no artifacts in {dir} (run `make artifacts`)"),
    }
    Ok(())
}

/// `cmoe lint [--json] [--root DIR] [paths…]` — the static-analysis
/// gate over the serving stack's written invariants (see `cmoe::lint`).
/// Exit code 1 when any finding survives the inline allowlist, so
/// `scripts/check.sh` can use it directly as a gate.
fn cmd_lint(args: &Args) -> Result<()> {
    let root = match args.get("root") {
        Some(dir) => std::path::PathBuf::from(dir),
        None => cmoe::lint::find_root()?,
    };
    let findings = if args.positional.is_empty() {
        cmoe::lint::lint_tree(&root)?
    } else {
        cmoe::lint::lint_paths(&root, &args.positional)?
    };
    if args.has("json") {
        print!("{}", cmoe::lint::report::render_json(&findings));
    } else {
        print!("{}", cmoe::lint::report::render_text(&findings));
    }
    if findings.is_empty() {
        if !args.has("json") {
            println!("cmoe lint: clean");
        }
        Ok(())
    } else {
        bail!("cmoe lint: {} finding(s)", findings.len())
    }
}
