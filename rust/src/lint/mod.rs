//! `cmoe lint` — the in-repo static-analysis gate.
//!
//! PRs 2–7 accumulated written invariants that only runtime property
//! tests enforced: the injectable Clock seam (PR 6), typed per-request
//! fault containment (PR 6), the DispatchArena's amortized
//! zero-allocation claim (PR 2), BTreeMap replay determinism (PR 5),
//! and the line-faithful python mirrors' bit-exactness story (every
//! PR). This module turns each into a *static* check over a hand-rolled
//! token scan ([`lexer`]) — dependency-free because the workspace
//! vendors its deps offline and `syn` is not among them.
//!
//! Rules ([`rules`], [`drift`]):
//!
//! * `clock-discipline` — no `Instant::now`/`SystemTime::now` outside
//!   `serving/clock.rs`; wall-clock reads must route through the seam.
//! * `panic-discipline` — no `unwrap`/`expect`/`panic!`/`unreachable!`/
//!   `todo!`/`unimplemented!` in `serving/` and `runtime/`.
//! * `hot-path-alloc` — no allocating constructs inside fns annotated
//!   `lint: hot-path` (arena-reuse calls like `push`/`resize` stay
//!   legal: the contract is amortized zero-allocation).
//! * `determinism` — no `HashMap`/`HashSet` in `serving/`, `moe/`,
//!   `pipeline/`; replay determinism requires ordered maps.
//! * `mirror-drift` — registered numeric constants must agree between
//!   `rust/src` and the `scripts/mirror_*.py` mirrors.
//!
//! Suppression is per-site and must carry prose: an inline comment of
//! the form `lint: allow(<rule>) — <reason>` on the offending line or
//! the line above. A missing reason or unknown rule name is itself a
//! finding (`allow-syntax`), and allow-syntax findings cannot be
//! allowlisted.
//!
//! `scripts/check.sh` runs this as a gate via `cmoe lint`; on
//! rustc-less images the line-faithful `scripts/mirror_lint.py` runs
//! the same rules (same lexer, same scopes, same registry) so the gate
//! executes everywhere.

pub mod drift;
pub mod lexer;
pub mod report;
pub mod rules;

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// One rule violation at a source location.
#[derive(Debug, Clone, PartialEq)]
pub struct Finding {
    pub rule: String,
    pub path: String,
    pub line: usize,
    pub message: String,
}

impl Finding {
    pub fn new(rule: &str, path: &str, line: usize, message: String) -> Finding {
        Finding { rule: rule.to_string(), path: path.to_string(), line, message }
    }
}

/// Lint one file's source text under its repo-relative path (forward
/// slashes). This is the whole per-file pipeline: lex → directives →
/// rules → allowlist filter. Used directly by the fixture tests.
pub fn lint_source(path: &str, src: &str) -> Vec<Finding> {
    let sc = lexer::scan(src);
    let directives = rules::parse_directives(&sc.comments);
    let allowed = rules::allowed_lines(&directives);
    let mut findings = rules::scan_rules(path, &sc, &directives);
    findings.retain(|f| {
        f.rule == rules::RULE_ALLOW_SYNTAX
            || !allowed.get(&f.line).is_some_and(|s| s.contains(&f.rule))
    });
    findings
}

/// Every Rust file the tree-wide lint covers: `rust/src`, `rust/tests`,
/// `rust/benches` (vendored deps are out of scope — not our code).
pub fn rust_files(root: &Path) -> Vec<PathBuf> {
    let mut out = Vec::new();
    for sub in ["rust/src", "rust/tests", "rust/benches"] {
        collect_rs(&root.join(sub), &mut out);
    }
    out.sort();
    out
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    for entry in entries.flatten() {
        let p = entry.path();
        if p.is_dir() {
            collect_rs(&p, out);
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
}

fn rel_path(root: &Path, file: &Path) -> String {
    file.strip_prefix(root)
        .unwrap_or(file)
        .to_string_lossy()
        .replace('\\', "/")
}

/// Lint the whole tree rooted at the repo checkout: every in-scope
/// Rust file plus the mirror-drift registry. Findings sort by
/// (path, line, rule) so output is deterministic.
pub fn lint_tree(root: &Path) -> Result<Vec<Finding>> {
    let mut out = Vec::new();
    for file in rust_files(root) {
        let src = std::fs::read_to_string(&file)
            .with_context(|| format!("read {}", file.display()))?;
        out.extend(lint_source(&rel_path(root, &file), &src));
    }
    out.extend(drift::check(root));
    sort_findings(&mut out);
    Ok(out)
}

/// Lint an explicit set of files (the `cmoe lint [paths…]` form).
/// The mirror-drift registry only runs in whole-tree mode — a partial
/// file list can't answer whether both sides agree.
pub fn lint_paths(root: &Path, paths: &[String]) -> Result<Vec<Finding>> {
    let mut out = Vec::new();
    for p in paths {
        let file = if Path::new(p).is_absolute() { PathBuf::from(p) } else { root.join(p) };
        let src = std::fs::read_to_string(&file)
            .with_context(|| format!("read {}", file.display()))?;
        out.extend(lint_source(&rel_path(root, &file), &src));
    }
    sort_findings(&mut out);
    Ok(out)
}

fn sort_findings(out: &mut [Finding]) {
    out.sort_by(|a, b| {
        (a.path.as_str(), a.line, a.rule.as_str()).cmp(&(b.path.as_str(), b.line, b.rule.as_str()))
    });
}

/// Locate the repo root from the working directory: either the repo
/// checkout itself or the `rust/` crate dir (where `cargo run` lands).
pub fn find_root() -> Result<PathBuf> {
    let cwd = std::env::current_dir().context("current_dir")?;
    if cwd.join("rust/src").is_dir() {
        return Ok(cwd);
    }
    if let Some(parent) = cwd.parent() {
        if parent.join("rust/src").is_dir() {
            return Ok(parent.to_path_buf());
        }
    }
    anyhow::bail!(
        "cannot locate the repo root (no rust/src under {} or its parent); \
         run from the checkout or pass --root",
        cwd.display()
    )
}
