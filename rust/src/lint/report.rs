//! Finding rendering: human text and machine JSON.
//!
//! The JSON shape is stable (`{"count": N, "findings": [{rule, path,
//! line, message}]}`) and round-trips through `util::json::Json` —
//! pinned by `tests/lint_rules.rs`.

use super::Finding;

/// `path:line: [rule] message` — one finding per line, clickable in
/// editors and CI logs.
pub fn render_text(findings: &[Finding]) -> String {
    let mut out = String::new();
    for f in findings {
        out.push_str(&format!("{}:{}: [{}] {}\n", f.path, f.line, f.rule, f.message));
    }
    out
}

pub fn render_json(findings: &[Finding]) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str(&format!("  \"count\": {},\n", findings.len()));
    out.push_str("  \"findings\": [\n");
    for (i, f) in findings.iter().enumerate() {
        let comma = if i + 1 < findings.len() { "," } else { "" };
        out.push_str(&format!(
            "    {{\"rule\": \"{}\", \"path\": \"{}\", \"line\": {}, \"message\": \"{}\"}}{}\n",
            esc(&f.rule),
            esc(&f.path),
            f.line,
            esc(&f.message),
            comma
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

/// Minimal JSON string escaping (quotes, backslash, control chars).
fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}
