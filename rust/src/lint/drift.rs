//! mirror-drift: the cross-validation story as a checked property.
//!
//! Every algorithm in this repo that matters is validated twice — once
//! natively, once by a line-faithful python mirror under `scripts/`.
//! That only means something while the numeric constants both sides
//! share (PCG32/FNV hashing, tier-ratio defaults, the k_for_ratio
//! operating points) actually agree. This rule extracts each registered
//! constant from its Rust definition and its python mirror and fails
//! when they diverge, or when either side stops defining it.
//!
//! Registered constants must be single, suffix-free numeric literals:
//! `pub const NAME: T = <literal>;` on the Rust side and a module-level
//! `NAME = <literal>` assignment on the python side.

use std::path::Path;

use super::lexer::{self, Tok, Token};
use super::Finding;

/// One shared constant: its name and the two files that must agree.
pub struct Entry {
    pub name: &'static str,
    pub rust: &'static str,
    pub py: &'static str,
}

const MIRROR_DYNK: &str = "scripts/mirror_dynamic_k.py";
const MIRROR_CHUNK: &str = "scripts/mirror_chunked_prefill.py";
const MIRROR_QUANT: &str = "scripts/mirror_quant.py";

/// The seeded registry (ISSUE 8): PCG32/splitmix seeding, the FNV
/// stub-logits hash, default TierRatios, and the paper's k_for_ratio
/// operating points (75%/25% on N_k = 4 → k = 3/1). Extended (ISSUE 9)
/// with the chunked-prefill/suffix-continuation constants, and (ISSUE
/// 10) with the int8 quantization / expert-residency constants.
pub const REGISTRY: &[Entry] = &[
    Entry { name: "PCG_MULT", rust: "rust/src/util/rng.rs", py: MIRROR_DYNK },
    Entry { name: "SPLITMIX_GAMMA", rust: "rust/src/util/rng.rs", py: MIRROR_DYNK },
    Entry { name: "SPLITMIX_MIX1", rust: "rust/src/util/rng.rs", py: MIRROR_DYNK },
    Entry { name: "SPLITMIX_MIX2", rust: "rust/src/util/rng.rs", py: MIRROR_DYNK },
    Entry { name: "FNV_OFFSET_BASIS", rust: "rust/src/serving/scheduler.rs", py: MIRROR_DYNK },
    Entry { name: "FNV_PRIME", rust: "rust/src/serving/scheduler.rs", py: MIRROR_DYNK },
    Entry { name: "DEFAULT_TIER_FULL", rust: "rust/src/serving/request.rs", py: MIRROR_DYNK },
    Entry { name: "DEFAULT_TIER_DEGRADED", rust: "rust/src/serving/request.rs", py: MIRROR_DYNK },
    Entry { name: "PAPER_RATIO_HIGH", rust: "rust/src/moe/gating.rs", py: MIRROR_DYNK },
    Entry { name: "PAPER_RATIO_LOW", rust: "rust/src/moe/gating.rs", py: MIRROR_DYNK },
    Entry { name: "PAPER_N_K", rust: "rust/src/moe/gating.rs", py: MIRROR_DYNK },
    Entry { name: "PAPER_K_HIGH", rust: "rust/src/moe/gating.rs", py: MIRROR_DYNK },
    Entry { name: "PAPER_K_LOW", rust: "rust/src/moe/gating.rs", py: MIRROR_DYNK },
    Entry {
        name: "DEFAULT_PREFILL_CHUNK_TOKENS",
        rust: "rust/src/serving/batcher.rs",
        py: MIRROR_CHUNK,
    },
    Entry { name: "CONT_GRID_STEP", rust: "rust/src/serving/engine.rs", py: MIRROR_CHUNK },
    Entry { name: "INT8_CLAMP", rust: "rust/src/quant/mod.rs", py: MIRROR_QUANT },
    Entry { name: "SCALE_EPS", rust: "rust/src/quant/mod.rs", py: MIRROR_QUANT },
    Entry { name: "RESIDENCY_EMA_DECAY", rust: "rust/src/moe/store.rs", py: MIRROR_QUANT },
    Entry { name: "DEFAULT_RESIDENT_CAP", rust: "rust/src/moe/store.rs", py: MIRROR_QUANT },
];

/// Extracted constant value. Int vs Float is part of the contract:
/// `1` on one side and `1.0` on the other is drift, not agreement.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Val {
    Int(i128),
    Float(f64),
}

impl std::fmt::Display for Val {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Val::Int(v) => write!(f, "{v}"),
            Val::Float(v) => write!(f, "{v}"),
        }
    }
}

/// Parse a numeric literal token (underscores stripped; hex or decimal
/// int, else float). Returns None for suffixed or malformed literals —
/// registered constants are written suffix-free by contract.
pub fn parse_num(s: &str) -> Option<Val> {
    let s = s.replace('_', "");
    if let Some(hex) = s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
        return i128::from_str_radix(hex, 16).ok().map(Val::Int);
    }
    if s.contains('.') || s.contains('e') || s.contains('E') {
        return s.parse::<f64>().ok().map(Val::Float);
    }
    s.parse::<i128>().ok().map(Val::Int)
}

/// A numeric literal with optional leading `-` at token index `i`.
fn num_at(t: &[Token], i: usize) -> Option<Val> {
    let (neg, j) = if i < t.len() && t[i].is_sym('-') { (true, i + 1) } else { (false, i) };
    let Tok::Num(s) = &t.get(j)?.tok else { return None };
    let v = parse_num(s)?;
    Some(if neg {
        match v {
            Val::Int(x) => Val::Int(-x),
            Val::Float(x) => Val::Float(-x),
        }
    } else {
        v
    })
}

/// Find `const NAME … = <literal>` in a Rust token stream.
pub fn extract_rust(tokens: &[Token], name: &str) -> Option<(usize, Option<Val>)> {
    for i in 0..tokens.len().saturating_sub(1) {
        if tokens[i].is_ident("const") && tokens[i + 1].is_ident(name) {
            let line = tokens[i + 1].line;
            let mut j = i + 2;
            while j < tokens.len() && !tokens[j].is_sym('=') && !tokens[j].is_sym(';') {
                j += 1;
            }
            if j < tokens.len() && tokens[j].is_sym('=') {
                return Some((line, num_at(tokens, j + 1)));
            }
            return Some((line, None));
        }
    }
    None
}

/// Find the module-level `NAME = <literal>` assignment in a python
/// token stream (`==` comparisons and attribute accesses don't match).
pub fn extract_py(tokens: &[Token], name: &str) -> Option<(usize, Option<Val>)> {
    for i in 0..tokens.len().saturating_sub(1) {
        let assigns = tokens[i].is_ident(name)
            && tokens[i + 1].is_sym('=')
            && !matches!(tokens.get(i + 2), Some(t) if t.is_sym('='))
            && (i == 0 || !tokens[i - 1].is_sym('.'));
        if assigns {
            return Some((tokens[i].line, num_at(tokens, i + 2)));
        }
    }
    None
}

/// Run the drift check over the whole registry. Unreadable files and
/// missing/unparseable constants are findings, not errors — the gate
/// must fail loudly, not crash.
pub fn check(root: &Path) -> Vec<Finding> {
    let mut out = Vec::new();
    for e in REGISTRY {
        let rust_side = match std::fs::read_to_string(root.join(e.rust)) {
            Ok(src) => extract_rust(&lexer::scan(&src).tokens, e.name),
            Err(err) => {
                out.push(Finding::new(
                    "mirror-drift",
                    e.rust,
                    1,
                    format!("cannot read registered file: {err}"),
                ));
                continue;
            }
        };
        let py_side = match std::fs::read_to_string(root.join(e.py)) {
            Ok(src) => extract_py(&lexer::scan_py(&src).tokens, e.name),
            Err(err) => {
                out.push(Finding::new(
                    "mirror-drift",
                    e.py,
                    1,
                    format!("cannot read registered mirror: {err}"),
                ));
                continue;
            }
        };
        let (rl, rv) = match rust_side {
            Some((line, Some(v))) => (line, v),
            Some((line, None)) => {
                out.push(Finding::new(
                    "mirror-drift",
                    e.rust,
                    line,
                    format!("registered constant {} is not a single numeric literal", e.name),
                ));
                continue;
            }
            None => {
                out.push(Finding::new(
                    "mirror-drift",
                    e.rust,
                    1,
                    format!("registered constant {} not defined here", e.name),
                ));
                continue;
            }
        };
        let pv = match py_side {
            Some((_, Some(v))) => v,
            Some((line, None)) => {
                out.push(Finding::new(
                    "mirror-drift",
                    e.py,
                    line,
                    format!("registered constant {} is not a single numeric literal", e.name),
                ));
                continue;
            }
            None => {
                out.push(Finding::new(
                    "mirror-drift",
                    e.py,
                    1,
                    format!("registered constant {} not defined in the mirror", e.name),
                ));
                continue;
            }
        };
        if rv != pv {
            out.push(Finding::new(
                "mirror-drift",
                e.rust,
                rl,
                format!(
                    "{} = {} here but {} in {} — the mirror cross-validation is void",
                    e.name, rv, pv, e.py
                ),
            ));
        }
    }
    out
}
