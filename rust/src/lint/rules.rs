//! The lint rules and the inline-allowlist machinery.
//!
//! Every rule is a token-pattern scan over [`lexer::Scan`] output, so
//! string literals, comments and doc prose can never trigger a finding.
//! Rules skip `#[cfg(test)]` regions — the panic/clock discipline is a
//! *serving-path* contract, and tests legitimately unwrap and take wall
//! time. The hot-path-alloc rule is opt-in per function via a
//! `lint: hot-path` directive and therefore applies wherever annotated.
//!
//! Mirrored statement by statement in `scripts/mirror_lint.py`.

use std::collections::{BTreeMap, BTreeSet};

use super::lexer::{Scan, Token};
use super::Finding;

/// Rule identifiers accepted by `allow(...)` directives.
pub const KNOWN_RULES: &[&str] = &[
    "clock-discipline",
    "panic-discipline",
    "hot-path-alloc",
    "determinism",
    "mirror-drift",
];

/// Meta-rule for malformed `lint:` directives; not itself allowlistable.
pub const RULE_ALLOW_SYNTAX: &str = "allow-syntax";

/// A parsed `lint:` directive from a `//` comment.
#[derive(Debug, Clone)]
pub enum Directive {
    /// `allow(<rule>) — <reason>`: suppress `rule` on this line
    /// and the next.
    Allow { line: usize, rule: String },
    /// `hot-path`: the next `fn` body is an allocation-free hot path.
    HotPath { line: usize },
    /// Anything else under `lint:` — reported as an allow-syntax finding.
    Malformed { line: usize, message: String },
}

/// Parse every `lint:` directive out of the file's line comments.
/// The directive grammar is deliberately rigid: an unknown rule name or
/// a missing reason is a malformed directive, not a silent no-op.
pub fn parse_directives(comments: &[(usize, String)]) -> Vec<Directive> {
    let mut out = Vec::new();
    for (line, raw) in comments {
        // Doc comments capture as `/ …` or `! …`; strip the markers.
        let t = raw.trim_start_matches(['/', '!']).trim();
        let Some(body) = t.strip_prefix("lint:") else { continue };
        let body = body.trim();
        if body == "hot-path" {
            out.push(Directive::HotPath { line: *line });
            continue;
        }
        if let Some(rest) = body.strip_prefix("allow(") {
            let Some(p) = rest.find(')') else {
                out.push(Directive::Malformed {
                    line: *line,
                    message: "unclosed `allow(` directive".to_string(),
                });
                continue;
            };
            let rule = rest[..p].trim().to_string();
            let mut reason = rest[p + 1..].trim();
            // Accept `— reason`, `- reason`, `: reason`; the separator
            // is cosmetic, the reason is not.
            while let Some(r) = reason.strip_prefix(['\u{2014}', '\u{2013}', '-', ':', ',']) {
                reason = r.trim();
            }
            if !KNOWN_RULES.contains(&rule.as_str()) {
                out.push(Directive::Malformed {
                    line: *line,
                    message: format!("allow() names unknown rule `{rule}`"),
                });
            } else if reason.is_empty() {
                out.push(Directive::Malformed {
                    line: *line,
                    message: format!("allow({rule}) requires a written reason"),
                });
            } else {
                out.push(Directive::Allow { line: *line, rule });
            }
            continue;
        }
        out.push(Directive::Malformed {
            line: *line,
            message: format!("unrecognized lint directive `{body}`"),
        });
    }
    out
}

/// Lines suppressed per rule: an allow on line L covers findings on
/// L (trailing comment) and L+1 (comment on its own line above).
pub fn allowed_lines(directives: &[Directive]) -> BTreeMap<usize, BTreeSet<String>> {
    let mut map: BTreeMap<usize, BTreeSet<String>> = BTreeMap::new();
    for d in directives {
        if let Directive::Allow { line, rule } = d {
            map.entry(*line).or_default().insert(rule.clone());
            map.entry(*line + 1).or_default().insert(rule.clone());
        }
    }
    map
}

/// Token-index ranges covered by `#[cfg(test)] … { … }` items.
pub fn test_regions(tokens: &[Token]) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i + 6 < tokens.len() {
        let is_cfg_test = tokens[i].is_sym('#')
            && tokens[i + 1].is_sym('[')
            && tokens[i + 2].is_ident("cfg")
            && tokens[i + 3].is_sym('(')
            && tokens[i + 4].is_ident("test")
            && tokens[i + 5].is_sym(')')
            && tokens[i + 6].is_sym(']');
        if is_cfg_test {
            // The attribute must gate a braced item (`mod tests { … }`);
            // a `;` before the `{` means it gated a bare item instead.
            let mut j = i + 7;
            while j < tokens.len() && !tokens[j].is_sym('{') && !tokens[j].is_sym(';') {
                j += 1;
            }
            if j < tokens.len() && tokens[j].is_sym('{') {
                let end = match_brace(tokens, j);
                out.push((j, end));
                i = end + 1;
                continue;
            }
        }
        i += 1;
    }
    out
}

/// Index of the `}` matching the `{` at `open` (or the last token if
/// the file is truncated — strings are stripped, so braces balance in
/// any parseable file).
fn match_brace(tokens: &[Token], open: usize) -> usize {
    let mut depth = 0usize;
    let mut i = open;
    while i < tokens.len() {
        if tokens[i].is_sym('{') {
            depth += 1;
        } else if tokens[i].is_sym('}') {
            depth -= 1;
            if depth == 0 {
                return i;
            }
        }
        i += 1;
    }
    tokens.len().saturating_sub(1)
}

fn in_regions(regions: &[(usize, usize)], idx: usize) -> bool {
    regions.iter().any(|&(a, b)| a <= idx && idx <= b)
}

/// `Ident(a) :: Ident(b)` at token index `i`.
fn is_path2(t: &[Token], i: usize, a: &str, b: &str) -> bool {
    i + 3 < t.len()
        && t[i].is_ident(a)
        && t[i + 1].is_sym(':')
        && t[i + 2].is_sym(':')
        && t[i + 3].is_ident(b)
}

/// Per-rule file scopes, on repo-relative forward-slash paths.
pub fn clock_scope(path: &str) -> bool {
    path.starts_with("rust/src/") && path != "rust/src/serving/clock.rs"
}

pub fn panic_scope(path: &str) -> bool {
    path.starts_with("rust/src/serving/") || path.starts_with("rust/src/runtime/")
}

pub fn determinism_scope(path: &str) -> bool {
    path.starts_with("rust/src/serving/")
        || path.starts_with("rust/src/moe/")
        || path.starts_with("rust/src/pipeline/")
}

const PANIC_METHODS: &[&str] = &["unwrap", "expect"];
const PANIC_MACROS: &[&str] = &["panic", "unreachable", "todo", "unimplemented"];
const ALLOC_METHODS: &[&str] = &["to_vec", "to_owned", "clone", "collect"];
const ALLOC_PATHS: &[(&str, &str)] = &[
    ("Vec", "new"),
    ("Vec", "with_capacity"),
    ("Box", "new"),
    ("String", "new"),
    ("String", "from"),
    ("String", "with_capacity"),
];
const ALLOC_MACROS: &[&str] = &["vec", "format"];

/// Run every token rule on one lexed file; returns raw findings
/// (allowlist filtering happens in the caller so allow-syntax findings
/// cannot be suppressed by the very mechanism they police).
pub fn scan_rules(path: &str, scan: &Scan, directives: &[Directive]) -> Vec<Finding> {
    let t = &scan.tokens;
    let tests = test_regions(t);
    let mut out = Vec::new();

    for d in directives {
        if let Directive::Malformed { line, message } = d {
            out.push(Finding::new(RULE_ALLOW_SYNTAX, path, *line, message.clone()));
        }
    }

    if clock_scope(path) {
        for i in 0..t.len() {
            if in_regions(&tests, i) {
                continue;
            }
            for src in ["Instant", "SystemTime"] {
                if is_path2(t, i, src, "now") {
                    out.push(Finding::new(
                        "clock-discipline",
                        path,
                        t[i].line,
                        format!(
                            "{src}::now() bypasses the injectable Clock seam \
                             (route through serving::clock::Clock)"
                        ),
                    ));
                }
            }
        }
    }

    if panic_scope(path) {
        for i in 0..t.len() {
            if in_regions(&tests, i) {
                continue;
            }
            if i + 2 < t.len() && t[i].is_sym('.') && t[i + 2].is_sym('(') {
                if let Some(m) = t[i + 1].ident() {
                    if PANIC_METHODS.contains(&m) {
                        out.push(Finding::new(
                            "panic-discipline",
                            path,
                            t[i + 1].line,
                            format!(
                                ".{m}() can panic the serving process; return a typed \
                                 error (fault containment promises per-request failures)"
                            ),
                        ));
                    }
                }
            }
            if i + 1 < t.len() && t[i + 1].is_sym('!') {
                if let Some(m) = t[i].ident() {
                    if PANIC_MACROS.contains(&m)
                        && (i == 0 || !t[i - 1].is_sym('.') && !t[i - 1].is_sym('#'))
                    {
                        out.push(Finding::new(
                            "panic-discipline",
                            path,
                            t[i].line,
                            format!(
                                "{m}! can panic the serving process; return a typed \
                                 error or allowlist with the unreachability invariant"
                            ),
                        ));
                    }
                }
            }
        }
    }

    if determinism_scope(path) {
        for (i, tok) in t.iter().enumerate() {
            if in_regions(&tests, i) {
                continue;
            }
            for ty in ["HashMap", "HashSet"] {
                if tok.is_ident(ty) {
                    out.push(Finding::new(
                        "determinism",
                        path,
                        tok.line,
                        format!(
                            "{ty} iteration order is nondeterministic; replay \
                             determinism requires BTreeMap/BTreeSet here"
                        ),
                    ));
                }
            }
        }
    }

    // hot-path-alloc: only inside bodies annotated `lint: hot-path`.
    for d in directives {
        let Directive::HotPath { line } = d else { continue };
        let Some(fn_idx) = t
            .iter()
            .position(|tok| tok.line >= *line && tok.is_ident("fn"))
        else {
            out.push(Finding::new(
                RULE_ALLOW_SYNTAX,
                path,
                *line,
                "hot-path directive does not precede a fn".to_string(),
            ));
            continue;
        };
        let Some(open) = (fn_idx..t.len()).find(|&j| t[j].is_sym('{')) else {
            out.push(Finding::new(
                RULE_ALLOW_SYNTAX,
                path,
                *line,
                "hot-path fn has no body".to_string(),
            ));
            continue;
        };
        let close = match_brace(t, open);
        scan_hot_path(path, t, open, close, &mut out);
    }

    out
}

/// Scan one annotated fn body for allocating constructs. Deliberately
/// NOT banned: `push`/`resize`/`clear` — the DispatchArena warm-up
/// contract is "amortized zero-allocation", and those are exactly the
/// capacity-reusing calls the arena is built from.
fn scan_hot_path(path: &str, t: &[Token], open: usize, close: usize, out: &mut Vec<Finding>) {
    let mut i = open;
    while i <= close && i < t.len() {
        for &(a, b) in ALLOC_PATHS {
            if is_path2(t, i, a, b) {
                out.push(alloc_finding(path, t[i].line, &format!("{a}::{b}")));
            }
        }
        if i + 1 < t.len() && t[i + 1].is_sym('!') {
            if let Some(m) = t[i].ident() {
                if ALLOC_MACROS.contains(&m) && (i == 0 || !t[i - 1].is_sym('#')) {
                    out.push(alloc_finding(path, t[i].line, &format!("{m}!")));
                }
            }
        }
        if i + 2 < t.len() && t[i].is_sym('.') && (t[i + 2].is_sym('(') || t[i + 2].is_sym(':')) {
            if let Some(m) = t[i + 1].ident() {
                if ALLOC_METHODS.contains(&m) {
                    out.push(alloc_finding(path, t[i + 1].line, &format!(".{m}()")));
                }
            }
        }
        i += 1;
    }
}

fn alloc_finding(path: &str, line: usize, what: &str) -> Finding {
    Finding::new(
        "hot-path-alloc",
        path,
        line,
        format!("{what} allocates inside a `lint: hot-path` fn (arena reuse only)"),
    )
}
