//! Hand-rolled lexers for the lint subsystem (no `syn` — the workspace
//! vendors its dependencies offline, so the linter must be free-standing).
//!
//! [`scan`] tokenizes Rust source into identifiers, numeric literals and
//! single-character symbols, with every comment and string/char literal
//! stripped so rules can never fire on prose or fixture text embedded in
//! string literals. Line-comment text is captured separately (that is
//! where `lint:` directives live). [`scan_py`] is a python-lite variant
//! used only by the mirror-drift rule to read `scripts/mirror_*.py`.
//!
//! Both are transcribed statement by statement in `scripts/mirror_lint.py`
//! so the gate runs identically on rustc-less images.

/// One lexical token. Symbols are single characters; multi-character
/// operators (`::`, `->`) appear as consecutive `Sym` tokens, which is
/// all the pattern matchers need.
#[derive(Debug, Clone, PartialEq)]
pub enum Tok {
    Ident(String),
    Num(String),
    Sym(char),
}

/// A token tagged with its 1-based source line.
#[derive(Debug, Clone)]
pub struct Token {
    pub line: usize,
    pub tok: Tok,
}

impl Token {
    pub fn is_sym(&self, c: char) -> bool {
        matches!(self.tok, Tok::Sym(s) if s == c)
    }

    pub fn is_ident(&self, name: &str) -> bool {
        matches!(&self.tok, Tok::Ident(s) if s == name)
    }

    pub fn ident(&self) -> Option<&str> {
        match &self.tok {
            Tok::Ident(s) => Some(s.as_str()),
            _ => None,
        }
    }
}

/// Lexed file: the code token stream plus the text of every `//` line
/// comment (doc comments included), in file order.
pub struct Scan {
    pub tokens: Vec<Token>,
    pub comments: Vec<(usize, String)>,
}

/// Tokenize Rust source. Comments and string/char literal *contents*
/// never reach the token stream; raw strings (`r#"…"#`), byte strings
/// and lifetimes are handled so an embedded quote cannot desynchronize
/// the scan and hide later findings.
pub fn scan(src: &str) -> Scan {
    let cs: Vec<char> = src.chars().collect();
    let n = cs.len();
    let mut i = 0usize;
    let mut line = 1usize;
    let mut tokens: Vec<Token> = Vec::new();
    let mut comments: Vec<(usize, String)> = Vec::new();

    while i < n {
        let c = cs[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // `//` line comment (also `///` and `//!` doc comments).
        if c == '/' && i + 1 < n && cs[i + 1] == '/' {
            let start = i + 2;
            let mut j = start;
            while j < n && cs[j] != '\n' {
                j += 1;
            }
            comments.push((line, cs[start..j].iter().collect()));
            i = j;
            continue;
        }
        // `/* … */` block comment, nestable per the Rust grammar.
        if c == '/' && i + 1 < n && cs[i + 1] == '*' {
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if cs[i] == '\n' {
                    line += 1;
                    i += 1;
                } else if cs[i] == '/' && i + 1 < n && cs[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if cs[i] == '*' && i + 1 < n && cs[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            continue;
        }
        // Raw / byte string prefixes: r"…", r#"…"#, b"…", br#"…"#, b'…'.
        if c == 'r' || c == 'b' {
            let (raw_candidate, mut j) = if c == 'r' {
                (true, i + 1)
            } else if i + 1 < n && cs[i + 1] == 'r' {
                (true, i + 2)
            } else {
                (false, i + 1)
            };
            if raw_candidate {
                let mut hashes = 0usize;
                while j < n && cs[j] == '#' {
                    hashes += 1;
                    j += 1;
                }
                if j < n && cs[j] == '"' {
                    i = j + 1;
                    while i < n {
                        if cs[i] == '\n' {
                            line += 1;
                            i += 1;
                            continue;
                        }
                        if cs[i] == '"' {
                            let mut k = 0usize;
                            while k < hashes && i + 1 + k < n && cs[i + 1 + k] == '#' {
                                k += 1;
                            }
                            if k == hashes {
                                i += 1 + hashes;
                                break;
                            }
                        }
                        i += 1;
                    }
                    continue;
                }
                // Not a raw string (e.g. an identifier starting with `r`,
                // or a raw identifier `r#kw`): fall through to ident.
            } else if j < n && (cs[j] == '"' || cs[j] == '\'') {
                // Byte string / byte char: normal escape rules.
                let quote = cs[j];
                i = j + 1;
                while i < n {
                    if cs[i] == '\\' {
                        if i + 1 < n && cs[i + 1] == '\n' {
                            line += 1;
                        }
                        i += 2;
                        continue;
                    }
                    if cs[i] == '\n' {
                        line += 1;
                        i += 1;
                        continue;
                    }
                    if cs[i] == quote {
                        i += 1;
                        break;
                    }
                    i += 1;
                }
                continue;
            }
        }
        // String literal.
        if c == '"' {
            i += 1;
            while i < n {
                if cs[i] == '\\' {
                    if i + 1 < n && cs[i + 1] == '\n' {
                        line += 1;
                    }
                    i += 2;
                    continue;
                }
                if cs[i] == '\n' {
                    line += 1;
                    i += 1;
                    continue;
                }
                if cs[i] == '"' {
                    i += 1;
                    break;
                }
                i += 1;
            }
            continue;
        }
        // Char literal vs lifetime. `'\n'` / `'\''` are escaped chars;
        // `'a'` is a char iff the character after next is a quote;
        // otherwise (`'a`, `'static`) it is a lifetime and only the
        // quote is consumed (the name lexes as a harmless identifier).
        if c == '\'' {
            if i + 1 < n && cs[i + 1] == '\\' {
                i += 3;
                while i < n && cs[i] != '\'' {
                    if cs[i] == '\n' {
                        line += 1;
                    }
                    i += 1;
                }
                i += 1;
                continue;
            }
            if i + 2 < n && cs[i + 2] == '\'' && cs[i + 1] != '\'' {
                i += 3;
                continue;
            }
            i += 1;
            continue;
        }
        // Identifier.
        if c.is_alphabetic() || c == '_' {
            let s = i;
            i += 1;
            while i < n && (cs[i].is_alphanumeric() || cs[i] == '_') {
                i += 1;
            }
            tokens.push(Token { line, tok: Tok::Ident(cs[s..i].iter().collect()) });
            continue;
        }
        // Numeric literal (tolerant: hex, underscores, float, exponent,
        // type suffix — drift parsing re-validates the exact shape).
        if c.is_ascii_digit() {
            let s = i;
            let hex = c == '0' && i + 1 < n && (cs[i + 1] == 'x' || cs[i + 1] == 'X');
            i += 1;
            while i < n {
                let d = cs[i];
                if d.is_alphanumeric() || d == '_' {
                    i += 1;
                    if !hex && (d == 'e' || d == 'E') && i < n && (cs[i] == '+' || cs[i] == '-') {
                        i += 1;
                    }
                    continue;
                }
                if d == '.' && i + 1 < n && cs[i + 1].is_ascii_digit() {
                    i += 1;
                    continue;
                }
                break;
            }
            tokens.push(Token { line, tok: Tok::Num(cs[s..i].iter().collect()) });
            continue;
        }
        // Anything else is a single-character symbol.
        tokens.push(Token { line, tok: Tok::Sym(c) });
        i += 1;
    }

    Scan { tokens, comments }
}

/// Tokenize Python source (mirror files only). Handles `#` comments,
/// single/triple-quoted strings with optional prefix letters (`r`, `f`,
/// `b`, …); everything else follows the Rust lexer's token model.
pub fn scan_py(src: &str) -> Scan {
    let cs: Vec<char> = src.chars().collect();
    let n = cs.len();
    let mut i = 0usize;
    let mut line = 1usize;
    let mut tokens: Vec<Token> = Vec::new();
    let mut comments: Vec<(usize, String)> = Vec::new();

    while i < n {
        let c = cs[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        if c == '#' {
            let start = i + 1;
            let mut j = start;
            while j < n && cs[j] != '\n' {
                j += 1;
            }
            comments.push((line, cs[start..j].iter().collect()));
            i = j;
            continue;
        }
        if c == '"' || c == '\'' {
            i = skip_py_string(&cs, i, &mut line);
            continue;
        }
        if c.is_alphabetic() || c == '_' {
            let s = i;
            i += 1;
            while i < n && (cs[i].is_alphanumeric() || cs[i] == '_') {
                i += 1;
            }
            // String prefix (r"…", f'…', rb"…", …): consume the literal.
            let word: String = cs[s..i].iter().collect();
            let is_prefix = word.len() <= 2
                && word.chars().all(|ch| "rRbBuUfF".contains(ch))
                && i < n
                && (cs[i] == '"' || cs[i] == '\'');
            if is_prefix {
                i = skip_py_string(&cs, i, &mut line);
                continue;
            }
            tokens.push(Token { line, tok: Tok::Ident(word) });
            continue;
        }
        if c.is_ascii_digit() {
            let s = i;
            let hex = c == '0' && i + 1 < n && (cs[i + 1] == 'x' || cs[i + 1] == 'X');
            i += 1;
            while i < n {
                let d = cs[i];
                if d.is_alphanumeric() || d == '_' {
                    i += 1;
                    if !hex && (d == 'e' || d == 'E') && i < n && (cs[i] == '+' || cs[i] == '-') {
                        i += 1;
                    }
                    continue;
                }
                if d == '.' && i + 1 < n && cs[i + 1].is_ascii_digit() {
                    i += 1;
                    continue;
                }
                break;
            }
            tokens.push(Token { line, tok: Tok::Num(cs[s..i].iter().collect()) });
            continue;
        }
        tokens.push(Token { line, tok: Tok::Sym(c) });
        i += 1;
    }

    Scan { tokens, comments }
}

/// Skip a python string starting at the opening quote `cs[i]`;
/// returns the index just past the closing quote. Triple quotes span
/// lines; single quotes terminate at an (unescaped) newline like CPython.
fn skip_py_string(cs: &[char], mut i: usize, line: &mut usize) -> usize {
    let n = cs.len();
    let q = cs[i];
    let triple = i + 2 < n && cs[i + 1] == q && cs[i + 2] == q;
    if triple {
        i += 3;
        while i < n {
            if cs[i] == '\n' {
                *line += 1;
                i += 1;
                continue;
            }
            if cs[i] == '\\' {
                if i + 1 < n && cs[i + 1] == '\n' {
                    *line += 1;
                }
                i += 2;
                continue;
            }
            if cs[i] == q && i + 2 < n && cs[i + 1] == q && cs[i + 2] == q {
                return i + 3;
            }
            if cs[i] == q && i + 2 >= n {
                // Closing triple at EOF without room for the lookahead.
                return n;
            }
            i += 1;
        }
        return n;
    }
    i += 1;
    while i < n {
        if cs[i] == '\\' {
            if i + 1 < n && cs[i + 1] == '\n' {
                *line += 1;
            }
            i += 2;
            continue;
        }
        if cs[i] == '\n' {
            // Unterminated single-quoted string: stop at the newline.
            *line += 1;
            return i + 1;
        }
        if cs[i] == q {
            return i + 1;
        }
        i += 1;
    }
    n
}
