//! MoEfication (Zhang et al. 2021): parameter-space K-means over the
//! gate-projection weight columns, balanced post-hoc, with a trained
//! linear router. Treats all neurons uniformly — no shared experts —
//! which is exactly the design choice CMoE's Table 5 ablates.

use crate::baselines::router_train::{train_linear_router, RouterTrainConfig};
use crate::baselines::moe_from_partition;
use crate::clustering::{lloyd_kmeans, rebalance};
use crate::model::{FfnWeights, MoeLayerWeights, Router};
use crate::tensor::Tensor;
use crate::util::Rng;

/// Options for MoEfication conversion.
#[derive(Clone, Copy, Debug)]
pub struct MoeficationOptions {
    pub n_experts: usize,
    /// Active experts per token, sized so the FLOP budget matches CMoE's
    /// 25% sparsity (e.g. 6-of-8).
    pub active: usize,
    pub kmeans_iters: usize,
    pub router: RouterTrainConfig,
    pub seed: u64,
}

impl Default for MoeficationOptions {
    fn default() -> Self {
        MoeficationOptions {
            n_experts: 8,
            active: 6,
            kmeans_iters: 30,
            router: RouterTrainConfig::default(),
            seed: 0x30EF,
        }
    }
}

/// Compute the weight-space neuron partition (shared by G-MoEfication).
pub fn weight_kmeans_partition(
    ffn: &FfnWeights,
    n_experts: usize,
    iters: usize,
    seed: u64,
) -> Vec<Vec<usize>> {
    let d_h = ffn.hidden_dim();
    assert_eq!(d_h % n_experts, 0, "experts must divide d_h");
    // points: gate-weight columns (each neuron's input feature vector)
    let points = ffn.w_gate.t(); // [d_h, d]
    let mut rng = Rng::new(seed);
    let mut cl = lloyd_kmeans(&points, n_experts, &mut rng, iters);
    rebalance(&points, &mut cl, n_experts);
    cl.members(n_experts)
}

/// Restructure a dense FFN with MoEfication.
pub fn moefication_convert(
    ffn: &FfnWeights,
    calib_x: &Tensor,
    opts: &MoeficationOptions,
) -> MoeLayerWeights {
    let partition = weight_kmeans_partition(ffn, opts.n_experts, opts.kmeans_iters, opts.seed);
    let w = train_linear_router(ffn, &partition, calib_x, &opts.router);
    moe_from_partition(ffn, partition, opts.active, Router::Linear(w))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor;

    fn setup(rng: &mut Rng) -> (FfnWeights, Tensor) {
        let d = 10;
        let d_h = 64;
        let ffn = FfnWeights {
            w_gate: Tensor::randn(rng, &[d, d_h], 0.5),
            w_up: Tensor::randn(rng, &[d, d_h], 0.5),
            w_down: Tensor::randn(rng, &[d_h, d], 0.5),
        };
        let x = Tensor::randn(rng, &[200, d], 1.0);
        (ffn, x)
    }

    #[test]
    fn partition_is_balanced_and_complete() {
        let mut rng = Rng::new(221);
        let (ffn, _) = setup(&mut rng);
        let p = weight_kmeans_partition(&ffn, 8, 20, 1);
        assert_eq!(p.len(), 8);
        for mem in &p {
            assert_eq!(mem.len(), 8);
        }
        let mut all: Vec<usize> = p.into_iter().flatten().collect();
        all.sort_unstable();
        assert_eq!(all, (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn full_conversion_runs_and_reconstructs_when_all_active() {
        let mut rng = Rng::new(222);
        let (ffn, x) = setup(&mut rng);
        let opts = MoeficationOptions { active: 8, ..Default::default() };
        let moe = moefication_convert(&ffn, &x, &opts);
        let probe = Tensor::randn(&mut rng, &[7, 10], 1.0);
        let dense = tensor::swiglu_ffn(&probe, &ffn.w_gate, &ffn.w_up, &ffn.w_down);
        let (out, _) = crate::moe::moe_ffn_forward(&moe, &probe);
        assert!(dense.max_abs_diff(&out) < 1e-4);
    }

    #[test]
    fn cmoe_beats_moefication_reconstruction_at_same_budget() {
        // the headline Table 5 claim in miniature: activation-based
        // clustering + shared experts reconstructs better than weight
        // k-means at matched sparsity
        let mut rng = Rng::new(223);
        let d = 10;
        let d_h = 64;
        // structured FFN: CMoE's claim holds when activations have the
        // §3.2 bimodal / co-activation structure of real LLM FFNs
        let ffn = crate::testutil::structured_ffn(&mut rng, d, d_h, 16, 6).ffn;
        let x = Tensor::randn(&mut rng, &[300, d], 1.0);
        let h = tensor::swiglu_hidden(&x, &ffn.w_gate, &ffn.w_up);
        let prof = crate::profiling::ActivationProfile::from_hidden(&h, 12);
        let spec = "S2A4E8".parse().unwrap(); // 6/8 active
        let ours = crate::converter::convert_ffn(
            &ffn,
            &prof,
            &spec,
            &crate::converter::ConvertOptions::default(),
        )
        .unwrap();
        let moef =
            moefication_convert(&ffn, &x, &MoeficationOptions { active: 6, ..Default::default() });
        let probe = Tensor::randn(&mut rng, &[128, d], 1.0);
        let e_ours = crate::converter::reconstruction_error(&ffn, &ours, &probe);
        let e_moef = crate::converter::reconstruction_error(&ffn, &moef, &probe);
        assert!(
            e_ours < e_moef,
            "CMoE ({e_ours:.4}) should beat MoEfication ({e_moef:.4}) on structured FFNs"
        );
    }
}
