//! LLaMA-MoE (Zhu et al. 2024) stand-in: *uniform random* neuron
//! partition with a trained router. The original recovers quality with
//! 200B tokens of continual pre-training; under the paper's matched
//! 2k-sample budget (Table 1/6) the random split cannot be healed,
//! which is exactly the effect the comparison demonstrates.

use crate::baselines::router_train::{train_linear_router, RouterTrainConfig};
use crate::baselines::moe_from_partition;
use crate::model::{FfnWeights, MoeLayerWeights, Router};
use crate::tensor::Tensor;
use crate::util::Rng;

/// Options for LLaMA-MoE conversion.
#[derive(Clone, Copy, Debug)]
pub struct LlamaMoeOptions {
    pub n_experts: usize,
    pub active: usize,
    pub router: RouterTrainConfig,
    pub seed: u64,
}

impl Default for LlamaMoeOptions {
    fn default() -> Self {
        LlamaMoeOptions { n_experts: 8, active: 6, router: RouterTrainConfig::default(), seed: 0x11A }
    }
}

/// Random equal-size partition of `d_h` neurons.
pub fn random_partition(d_h: usize, n_experts: usize, seed: u64) -> Vec<Vec<usize>> {
    assert_eq!(d_h % n_experts, 0);
    let m = d_h / n_experts;
    let mut ids: Vec<usize> = (0..d_h).collect();
    let mut rng = Rng::new(seed);
    rng.shuffle(&mut ids);
    (0..n_experts).map(|e| {
        let mut mem = ids[e * m..(e + 1) * m].to_vec();
        mem.sort_unstable();
        mem
    }).collect()
}

/// Restructure a dense FFN LLaMA-MoE style.
pub fn llama_moe_convert(
    ffn: &FfnWeights,
    calib_x: &Tensor,
    opts: &LlamaMoeOptions,
) -> MoeLayerWeights {
    let partition = random_partition(ffn.hidden_dim(), opts.n_experts, opts.seed);
    let w = train_linear_router(ffn, &partition, calib_x, &opts.router);
    moe_from_partition(ffn, partition, opts.active, Router::Linear(w))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor;

    #[test]
    fn random_partition_is_partition() {
        let p = random_partition(64, 8, 3);
        assert_eq!(p.len(), 8);
        let mut all: Vec<usize> = p.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..64).collect::<Vec<_>>());
        for mem in &p {
            assert_eq!(mem.len(), 8);
        }
    }

    #[test]
    fn different_seeds_different_partitions() {
        assert_ne!(random_partition(64, 8, 1), random_partition(64, 8, 2));
    }

    #[test]
    fn random_split_reconstructs_worse_than_cmoe() {
        // random grouping scatters co-activated neurons across experts,
        // so at the same sparsity its reconstruction is worse — the §3.2
        // motivation made measurable.
        let mut rng = Rng::new(241);
        let d = 10;
        let d_h = 64;
        // structured FFN: correlated co-activation groups + hot neurons
        let ffn = crate::testutil::structured_ffn(&mut rng, d, d_h, 16, 6).ffn;
        let x = Tensor::randn(&mut rng, &[300, d], 1.0);
        let h = tensor::swiglu_hidden(&x, &ffn.w_gate, &ffn.w_up);
        let prof = crate::profiling::ActivationProfile::from_hidden(&h, 12);
        let ours = crate::converter::convert_ffn(
            &ffn,
            &prof,
            &"S2A4E8".parse().unwrap(),
            &crate::converter::ConvertOptions::default(),
        )
        .unwrap();
        let lm = llama_moe_convert(&ffn, &x, &LlamaMoeOptions { active: 6, ..Default::default() });
        let probe = Tensor::randn(&mut rng, &[128, d], 1.0);
        let e_ours = crate::converter::reconstruction_error(&ffn, &ours, &probe);
        let e_lm = crate::converter::reconstruction_error(&ffn, &lm, &probe);
        assert!(
            e_ours < e_lm,
            "CMoE ({e_ours:.4}) should beat random split ({e_lm:.4}) on structured activations"
        );
    }
}
