//! EMoE (Qiu et al. 2023) stand-in: clusters neurons by their
//! *up-projection key vectors* (the "key" half of the key-value FFN
//! view) rather than gate weights, with a trained linear router.

use crate::baselines::router_train::{train_linear_router, RouterTrainConfig};
use crate::baselines::moe_from_partition;
use crate::clustering::{lloyd_kmeans, rebalance};
use crate::model::{FfnWeights, MoeLayerWeights, Router};
use crate::tensor::Tensor;
use crate::util::Rng;

/// Options for EMoE conversion.
#[derive(Clone, Copy, Debug)]
pub struct EmoeOptions {
    pub n_experts: usize,
    pub active: usize,
    pub kmeans_iters: usize,
    pub router: RouterTrainConfig,
    pub seed: u64,
}

impl Default for EmoeOptions {
    fn default() -> Self {
        EmoeOptions {
            n_experts: 8,
            active: 6,
            kmeans_iters: 30,
            router: RouterTrainConfig::default(),
            seed: 0xE40E,
        }
    }
}

/// Key-vector partition: k-means on the columns of `w_up`.
pub fn key_kmeans_partition(
    ffn: &FfnWeights,
    n_experts: usize,
    iters: usize,
    seed: u64,
) -> Vec<Vec<usize>> {
    let points = ffn.w_up.t(); // [d_h, d] — each row is a neuron's key
    let mut rng = Rng::new(seed);
    let mut cl = lloyd_kmeans(&points, n_experts, &mut rng, iters);
    rebalance(&points, &mut cl, n_experts);
    cl.members(n_experts)
}

/// Restructure a dense FFN EMoE style.
pub fn emoe_convert(ffn: &FfnWeights, calib_x: &Tensor, opts: &EmoeOptions) -> MoeLayerWeights {
    let partition = key_kmeans_partition(ffn, opts.n_experts, opts.kmeans_iters, opts.seed);
    let w = train_linear_router(ffn, &partition, calib_x, &opts.router);
    moe_from_partition(ffn, partition, opts.active, Router::Linear(w))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_balanced() {
        let mut rng = Rng::new(251);
        let ffn = FfnWeights {
            w_gate: Tensor::randn(&mut rng, &[8, 48], 0.5),
            w_up: Tensor::randn(&mut rng, &[8, 48], 0.5),
            w_down: Tensor::randn(&mut rng, &[48, 8], 0.5),
        };
        let p = key_kmeans_partition(&ffn, 6, 20, 1);
        for mem in &p {
            assert_eq!(mem.len(), 8);
        }
        let mut all: Vec<usize> = p.into_iter().flatten().collect();
        all.sort_unstable();
        assert_eq!(all, (0..48).collect::<Vec<_>>());
    }

    #[test]
    fn emoe_differs_from_moefication_partition() {
        // gate-space and key-space clustering should produce different
        // groupings on generic weights
        let mut rng = Rng::new(252);
        let ffn = FfnWeights {
            w_gate: Tensor::randn(&mut rng, &[8, 48], 0.5),
            w_up: Tensor::randn(&mut rng, &[8, 48], 0.5),
            w_down: Tensor::randn(&mut rng, &[48, 8], 0.5),
        };
        let a = key_kmeans_partition(&ffn, 6, 20, 1);
        let b = crate::baselines::moefication::weight_kmeans_partition(&ffn, 6, 20, 1);
        assert_ne!(a, b);
    }

    #[test]
    fn conversion_runs() {
        let mut rng = Rng::new(253);
        let ffn = FfnWeights {
            w_gate: Tensor::randn(&mut rng, &[8, 48], 0.5),
            w_up: Tensor::randn(&mut rng, &[8, 48], 0.5),
            w_down: Tensor::randn(&mut rng, &[48, 8], 0.5),
        };
        let x = Tensor::randn(&mut rng, &[100, 8], 1.0);
        let moe = emoe_convert(&ffn, &x, &EmoeOptions { n_experts: 6, active: 4, ..Default::default() });
        assert_eq!(moe.experts.len(), 6);
        let probe = Tensor::randn(&mut rng, &[5, 8], 1.0);
        let (out, _) = crate::moe::moe_ffn_forward(&moe, &probe);
        assert_eq!(out.shape, vec![5, 8]);
    }
}
