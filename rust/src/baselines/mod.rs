//! Baseline restructuring / sparsification methods the paper compares
//! against (Tables 1, 5, 8). All are re-implemented on the same
//! substrate so comparisons isolate the *method*:
//!
//! | Module | Paper baseline | Expert grouping | Router |
//! |---|---|---|---|
//! | [`moefication`] | MoEfication (Zhang et al. 2021) | k-means on gate-weight columns | trained linear |
//! | [`gmoefication`] | G-MoEfication (Lee et al. 2024) | same | trained linear + mean-output compensation |
//! | [`llama_moe`] | LLaMA-MoE (Zhu et al. 2024) | uniform random split | trained linear |
//! | [`emoe`] | EMoE (Qiu et al. 2023) | k-means on up-projection key vectors | trained linear |
//! | [`readme_like`] | Read-ME (Cai et al. 2024) | domain-aware grouping | global (per-domain precomputed) |
//! | [`wina`] | WINA (Chen et al. 2025) | — (neuron-level sparsity) | — |
//! | [`pruning`] | SliceGPT/SLEB stand-in | — (static removal) | — |
//!
//! Every MoE-producing baseline emits a [`crate::model::MoeLayerWeights`]
//! so the downstream evaluation / serving stack is identical; only the
//! partition and router differ. Hybrid ablations (Table 5's
//! "baseline + our router") are built by [`with_analytical_router`].

pub mod router_train;
pub mod moefication;
pub mod gmoefication;
pub mod llama_moe;
pub mod emoe;
pub mod readme_like;
pub mod wina;
pub mod pruning;

pub use router_train::train_linear_router;
pub use wina::{wina_ffn_forward, wina_keep_fraction};

use crate::converter::{self, LayerPartition, RouterBuild};
use crate::model::{FfnWeights, MoeLayerWeights, Router};
use crate::profiling::ActivationProfile;

/// Swap any baseline's router for CMoE's analytical representative-
/// neuron router (the Table 5 "+ ours" rows). Representatives are
/// recomputed from the baseline's own expert partition via the shared
/// Eq. 25 helper [`converter::representative_neurons`] — the same code
/// the pipeline's analytical `RouterBuilder` runs, so the swap and the
/// registry's `<base>+cmoe-router` hybrids cannot diverge.
pub fn with_analytical_router(
    moe: &MoeLayerWeights,
    ffn: &FfnWeights,
    profile: &ActivationProfile,
) -> MoeLayerWeights {
    let mut out = moe.clone();
    let representatives = converter::representative_neurons(profile, &moe.expert_neurons);
    out.router = converter::analytical_router(ffn, &representatives);
    out.representatives = representatives;
    out
}

/// Shared helper: build a MoeLayerWeights from an explicit neuron
/// partition (no shared experts — these baselines don't have them, so
/// the "shared" slice is empty and all experts are routed). Assembly
/// itself is [`converter::assemble_moe_layer`], shared with CMoE.
pub(crate) fn moe_from_partition(
    ffn: &FfnWeights,
    partition: Vec<Vec<usize>>,
    active: usize,
    router: Router,
) -> MoeLayerWeights {
    let part = LayerPartition {
        spec: crate::model::MoeSpec::new(0, active, partition.len())
            .expect("partition always yields a valid spec"),
        shared_neurons: Vec::new(),
        expert_neurons: partition,
        representatives: None,
    };
    converter::assemble_moe_layer(
        ffn,
        &part,
        RouterBuild { router, representatives: Vec::new(), compensation: None },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use crate::util::Rng;

    #[test]
    fn empty_shared_expert_moe_runs() {
        let mut rng = Rng::new(201);
        let d = 8;
        let d_h = 32;
        let ffn = FfnWeights {
            w_gate: Tensor::randn(&mut rng, &[d, d_h], 0.5),
            w_up: Tensor::randn(&mut rng, &[d, d_h], 0.5),
            w_down: Tensor::randn(&mut rng, &[d_h, d], 0.5),
        };
        let partition: Vec<Vec<usize>> = (0..4).map(|e| (e * 8..(e + 1) * 8).collect()).collect();
        let w = Tensor::randn(&mut rng, &[d, 4], 0.5);
        let moe = moe_from_partition(&ffn, partition, 4, Router::Linear(w));
        let x = Tensor::randn(&mut rng, &[6, d], 1.0);
        // all 4 active -> must equal dense
        let dense = crate::tensor::swiglu_ffn(&x, &ffn.w_gate, &ffn.w_up, &ffn.w_down);
        let (out, _) = crate::moe::moe_ffn_forward(&moe, &x);
        assert!(dense.max_abs_diff(&out) < 1e-4);
    }

    #[test]
    fn analytical_router_swap_keeps_partition() {
        let mut rng = Rng::new(202);
        let d = 8;
        let d_h = 32;
        let ffn = FfnWeights {
            w_gate: Tensor::randn(&mut rng, &[d, d_h], 0.5),
            w_up: Tensor::randn(&mut rng, &[d, d_h], 0.5),
            w_down: Tensor::randn(&mut rng, &[d_h, d], 0.5),
        };
        let x = Tensor::randn(&mut rng, &[60, d], 1.0);
        let h = crate::tensor::swiglu_hidden(&x, &ffn.w_gate, &ffn.w_up);
        let prof = crate::profiling::ActivationProfile::from_hidden(&h, 6);
        let partition: Vec<Vec<usize>> = (0..4).map(|e| (e * 8..(e + 1) * 8).collect()).collect();
        let w = Tensor::randn(&mut rng, &[d, 4], 0.5);
        let moe = moe_from_partition(&ffn, partition.clone(), 2, Router::Linear(w));
        let swapped = with_analytical_router(&moe, &ffn, &prof);
        assert_eq!(swapped.expert_neurons, partition);
        assert!(matches!(swapped.router, Router::Analytical(_)));
        // representative of each expert must be a member of it
        for (e, &r) in swapped.representatives.iter().enumerate() {
            assert!(partition[e].contains(&r));
        }
    }
}
