//! Read-ME (Cai et al. 2024) stand-in: *domain-aware* expert
//! construction — neurons are grouped by which calibration *domain*
//! they respond to most, and routing is a **global** (sequence-level)
//! decision rather than per-token. This reproduces Read-ME's
//! router-decoupled design at our scale; Table 5 shows why per-token
//! routing wins on mixed-domain streams.

use crate::baselines::moe_from_partition;
use crate::model::{FfnWeights, MoeLayerWeights, Router};
use crate::profiling::ActivationProfile;
use crate::tensor::Tensor;

/// Build the domain-aware partition: for each neuron, compute its
/// activation rate within each domain's calibration slice, assign it to
/// its argmax domain, then balance to equal sizes (experts cycle over
/// domains when `n_experts > n_domains`).
pub fn domain_partition(
    profiles: &[&ActivationProfile],
    n_experts: usize,
) -> Vec<Vec<usize>> {
    assert!(!profiles.is_empty());
    let d_h = profiles[0].d_h;
    assert_eq!(d_h % n_experts, 0);
    let m = d_h / n_experts;
    let n_dom = profiles.len();
    let rates: Vec<Vec<f32>> = profiles.iter().map(|p| p.rates()).collect();

    // score per neuron: preferred domain and preference strength
    let mut neurons: Vec<(usize, usize, f32)> = (0..d_h)
        .map(|i| {
            let mut best = 0usize;
            for dom in 1..n_dom {
                if rates[dom][i] > rates[best][i] {
                    best = dom;
                }
            }
            (i, best, rates[best][i])
        })
        .collect();
    // strongest preference first so each domain's expert gets its most
    // characteristic neurons
    neurons.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap().then(a.0.cmp(&b.0)));

    let mut partition: Vec<Vec<usize>> = vec![Vec::new(); n_experts];
    // experts are assigned to domains round-robin
    let expert_domain: Vec<usize> = (0..n_experts).map(|e| e % n_dom).collect();
    let mut spill = Vec::new();
    for (i, dom, _) in neurons {
        // first expert of this domain with space
        let slot = (0..n_experts)
            .find(|&e| expert_domain[e] == dom && partition[e].len() < m);
        match slot {
            Some(e) => partition[e].push(i),
            None => spill.push(i),
        }
    }
    // spill into any expert with space
    for i in spill {
        let e = (0..n_experts).find(|&e| partition[e].len() < m).unwrap();
        partition[e].push(i);
    }
    for mem in partition.iter_mut() {
        mem.sort_unstable();
    }
    partition
}

/// Build the Read-ME-style layer: domain partition + a *global* linear
/// router trained on domain-mean inputs (one prototype per expert —
/// scores are similarities to domain prototypes, so every token of a
/// sequence routes the same way).
pub fn readme_convert(
    ffn: &FfnWeights,
    profiles: &[&ActivationProfile],
    domain_means: &[Tensor],
    active: usize,
    n_experts: usize,
) -> MoeLayerWeights {
    let partition = domain_partition(profiles, n_experts);
    // router columns = prototypes of each expert's domain
    let d = ffn.w_gate.shape[0];
    let mut w = Tensor::zeros(&[d, n_experts]);
    for e in 0..n_experts {
        let dom = e % domain_means.len();
        let proto = &domain_means[dom];
        for r in 0..d {
            *w.at2_mut(r, e) = proto.data[r];
        }
    }
    moe_from_partition(ffn, partition, active, Router::Linear(w))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn domain_profiles(rng: &mut Rng, d_h: usize) -> (ActivationProfile, ActivationProfile) {
        // domain A lights the first half of neurons, B the second half
        let q = 80;
        let mut ha = Tensor::zeros(&[q, d_h]);
        let mut hb = Tensor::zeros(&[q, d_h]);
        for t in 0..q {
            for i in 0..d_h {
                let base = 0.01 * rng.normal();
                ha.row_mut(t)[i] = if i < d_h / 2 { 1.0 + base } else { base };
                hb.row_mut(t)[i] = if i >= d_h / 2 { 1.0 + base } else { base };
            }
        }
        (
            ActivationProfile::from_hidden(&ha, d_h / 4),
            ActivationProfile::from_hidden(&hb, d_h / 4),
        )
    }

    #[test]
    fn domain_partition_separates_domains() {
        let mut rng = Rng::new(261);
        let d_h = 32;
        let (pa, pb) = domain_profiles(&mut rng, d_h);
        let partition = domain_partition(&[&pa, &pb], 4);
        // experts 0,2 ↔ domain A (first half), 1,3 ↔ domain B
        let first_half = |mem: &Vec<usize>| mem.iter().filter(|&&i| i < d_h / 2).count();
        assert!(first_half(&partition[0]) >= 6, "expert0 {:?}", partition[0]);
        assert!(first_half(&partition[2]) >= 6);
        assert!(first_half(&partition[1]) <= 2);
        assert!(first_half(&partition[3]) <= 2);
    }

    #[test]
    fn partition_is_balanced() {
        let mut rng = Rng::new(262);
        let (pa, pb) = domain_profiles(&mut rng, 32);
        let partition = domain_partition(&[&pa, &pb], 8);
        for mem in &partition {
            assert_eq!(mem.len(), 4);
        }
        let mut all: Vec<usize> = partition.into_iter().flatten().collect();
        all.sort_unstable();
        assert_eq!(all, (0..32).collect::<Vec<_>>());
    }

    #[test]
    fn convert_runs() {
        let mut rng = Rng::new(263);
        let d = 8;
        let d_h = 32;
        let ffn = FfnWeights {
            w_gate: Tensor::randn(&mut rng, &[d, d_h], 0.5),
            w_up: Tensor::randn(&mut rng, &[d, d_h], 0.5),
            w_down: Tensor::randn(&mut rng, &[d_h, d], 0.5),
        };
        let (pa, pb) = domain_profiles(&mut rng, d_h);
        let means = vec![Tensor::randn(&mut rng, &[d], 1.0), Tensor::randn(&mut rng, &[d], 1.0)];
        let moe = readme_convert(&ffn, &[&pa, &pb], &means, 3, 4);
        assert_eq!(moe.experts.len(), 4);
        let x = Tensor::randn(&mut rng, &[5, d], 1.0);
        let (out, _) = crate::moe::moe_ffn_forward(&moe, &x);
        assert_eq!(out.shape, vec![5, d]);
    }
}
