//! G-MoEfication (Lee et al. 2024): MoEfication generalized to
//! non-ReLU models by *retaining representative values* for unselected
//! experts — deactivated experts contribute their calibration-mean
//! output instead of zero, which repairs the bias that SwiGLU's
//! non-zero-mean activations introduce.

use crate::baselines::moefication::{weight_kmeans_partition, MoeficationOptions};
use crate::baselines::router_train::train_linear_router;
use crate::baselines::moe_from_partition;
use crate::model::{FfnWeights, MoeLayerWeights, Router};
use crate::tensor::{self, Tensor};

/// Restructure with G-MoEfication: MoEfication partition + router, plus
/// per-expert mean-output compensation estimated on `calib_x`.
pub fn gmoefication_convert(
    ffn: &FfnWeights,
    calib_x: &Tensor,
    opts: &MoeficationOptions,
) -> MoeLayerWeights {
    let partition = weight_kmeans_partition(ffn, opts.n_experts, opts.kmeans_iters, opts.seed);
    let w = train_linear_router(ffn, &partition, calib_x, &opts.router);
    let mut moe = moe_from_partition(ffn, partition, opts.active, Router::Linear(w));
    moe.compensation = Some(expert_mean_outputs(&moe, calib_x));
    moe
}

/// Calibration-mean output of each routed expert.
pub fn expert_mean_outputs(moe: &MoeLayerWeights, calib_x: &Tensor) -> Vec<Vec<f32>> {
    mean_outputs(moe.experts.iter(), calib_x)
}

/// Partition form of [`expert_mean_outputs`]: mean outputs of the
/// expert *slices* of `ffn`, before a layer is assembled — what the
/// pipeline's router stage uses to attach compensation.
pub fn partition_mean_outputs(
    ffn: &FfnWeights,
    partition: &[Vec<usize>],
    calib_x: &Tensor,
) -> Vec<Vec<f32>> {
    let slices: Vec<FfnWeights> = partition.iter().map(|idx| ffn.slice_neurons(idx)).collect();
    mean_outputs(slices.iter(), calib_x)
}

fn mean_outputs<'a>(
    experts: impl Iterator<Item = &'a FfnWeights>,
    calib_x: &Tensor,
) -> Vec<Vec<f32>> {
    let q = calib_x.shape[0];
    let d = calib_x.shape[1];
    experts
        .map(|e| {
            let y = tensor::swiglu_ffn(calib_x, &e.w_gate, &e.w_up, &e.w_down);
            let mut mean = vec![0.0f32; d];
            for t in 0..q {
                for (m, v) in mean.iter_mut().zip(y.row(t)) {
                    *m += v;
                }
            }
            for m in mean.iter_mut() {
                *m /= q as f32;
            }
            mean
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn setup(rng: &mut Rng) -> (FfnWeights, Tensor) {
        let d = 10;
        let d_h = 64;
        // correlate gate and up columns: Swish(x·wg)·(x·wu) then has a
        // positive mean (the non-zero-mean activations G-MoEfication's
        // representative-value compensation exists to repair)
        let w_gate = Tensor::randn(rng, &[d, d_h], 0.5);
        let mut w_up = Tensor::randn(rng, &[d, d_h], 0.2);
        for (u, g) in w_up.data.iter_mut().zip(&w_gate.data) {
            *u += 0.8 * g;
        }
        let ffn = FfnWeights { w_gate, w_up, w_down: Tensor::randn(rng, &[d_h, d], 0.5) };
        let x = Tensor::randn(rng, &[256, d], 1.0);
        (ffn, x)
    }

    #[test]
    fn compensation_improves_reconstruction_over_plain() {
        let mut rng = Rng::new(231);
        let (ffn, x) = setup(&mut rng);
        // aggressive sparsity (2-of-8) so the deactivated-expert bias
        // that compensation repairs actually dominates the error
        let opts = MoeficationOptions { active: 2, ..Default::default() };
        let plain = crate::baselines::moefication::moefication_convert(&ffn, &x, &opts);
        let gmo = gmoefication_convert(&ffn, &x, &opts);
        let probe = Tensor::randn(&mut rng, &[200, 10], 1.0);
        let e_plain = crate::converter::reconstruction_error(&ffn, &plain, &probe);
        let e_gmo = crate::converter::reconstruction_error(&ffn, &gmo, &probe);
        assert!(
            e_gmo < e_plain,
            "compensation should reduce reconstruction error ({e_gmo:.4} vs {e_plain:.4})"
        );
    }

    #[test]
    fn compensation_vanishes_when_all_active() {
        let mut rng = Rng::new(232);
        let (ffn, x) = setup(&mut rng);
        let opts = MoeficationOptions { active: 8, ..Default::default() };
        let gmo = gmoefication_convert(&ffn, &x, &opts);
        let probe = Tensor::randn(&mut rng, &[9, 10], 1.0);
        let dense = tensor::swiglu_ffn(&probe, &ffn.w_gate, &ffn.w_up, &ffn.w_down);
        let (out, _) = crate::moe::moe_ffn_forward(&gmo, &probe);
        // all experts selected ⇒ compensation cancels exactly
        assert!(dense.max_abs_diff(&out) < 1e-4);
    }

    #[test]
    fn mean_outputs_shape() {
        let mut rng = Rng::new(233);
        let (ffn, x) = setup(&mut rng);
        let opts = MoeficationOptions::default();
        let moe = crate::baselines::moefication::moefication_convert(&ffn, &x, &opts);
        let comp = expert_mean_outputs(&moe, &x);
        assert_eq!(comp.len(), 8);
        assert!(comp.iter().all(|c| c.len() == 10));
    }
}
