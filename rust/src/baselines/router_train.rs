//! Trained linear router (the "MLP router" of MoEfication / LLaMA-MoE).
//!
//! Given a fixed expert partition, the router learns to predict which
//! experts carry the most hidden mass for each input: targets are the
//! per-expert hidden-state L1 shares (softmax-normalized), and the
//! scorer `s = x @ w` is trained with cross-entropy against that soft
//! target — the same recipe MoEfication describes, sized to the paper's
//! matched 2k-sample budget.

use crate::model::FfnWeights;
use crate::tensor::{self, Tensor};

/// Training hyperparameters.
#[derive(Clone, Copy, Debug)]
pub struct RouterTrainConfig {
    pub lr: f32,
    pub epochs: usize,
    pub batch: usize,
}

impl Default for RouterTrainConfig {
    fn default() -> Self {
        RouterTrainConfig { lr: 0.05, epochs: 8, batch: 64 }
    }
}

/// Train `w: [d, n_experts]` on calibration inputs `x: [q, d]` for the
/// partition `expert_neurons` of `ffn`.
pub fn train_linear_router(
    ffn: &FfnWeights,
    expert_neurons: &[Vec<usize>],
    x: &Tensor,
    cfg: &RouterTrainConfig,
) -> Tensor {
    let q = x.shape[0];
    let d = x.shape[1];
    let n_e = expert_neurons.len();

    // targets: softmax over per-expert hidden L1 mass
    let h = tensor::swiglu_hidden(x, &ffn.w_gate, &ffn.w_up);
    let mut targets = Tensor::zeros(&[q, n_e]);
    for t in 0..q {
        let mut mass: Vec<f32> = expert_neurons
            .iter()
            .map(|mem| mem.iter().map(|&i| h.at2(t, i).abs()).sum::<f32>())
            .collect();
        // scale so softmax has contrast
        let max = mass.iter().cloned().fold(0.0f32, f32::max).max(1e-6);
        for v in mass.iter_mut() {
            *v = *v / max * 4.0;
        }
        let p = tensor::softmax(&mass);
        targets.row_mut(t).copy_from_slice(&p);
    }

    // SGD on cross-entropy( softmax(x@w), targets )
    let mut w = Tensor::zeros(&[d, n_e]);
    for _ in 0..cfg.epochs {
        for start in (0..q).step_by(cfg.batch) {
            let end = (start + cfg.batch).min(q);
            let idx: Vec<usize> = (start..end).collect();
            let xb = x.select_rows(&idx);
            let b = xb.shape[0];
            let mut logits = tensor::matmul(&xb, &w);
            tensor::softmax_rows(&mut logits);
            // grad of CE wrt logits = p - t ; dW = x^T (p - t) / b
            for (r, &ti) in idx.iter().enumerate() {
                for e in 0..n_e {
                    *logits.at2_mut(r, e) -= targets.at2(ti, e);
                }
            }
            let grad = tensor::matmul(&xb.t(), &logits);
            for (wv, gv) in w.data.iter_mut().zip(&grad.data) {
                *wv -= cfg.lr * gv / b as f32;
            }
        }
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn trained_router_beats_random_routing() {
        let mut rng = Rng::new(211);
        let d = 12;
        let d_h = 48;
        let ffn = FfnWeights {
            w_gate: Tensor::randn(&mut rng, &[d, d_h], 0.5),
            w_up: Tensor::randn(&mut rng, &[d, d_h], 0.5),
            w_down: Tensor::randn(&mut rng, &[d_h, d], 0.5),
        };
        let partition: Vec<Vec<usize>> = (0..6).map(|e| (e * 8..(e + 1) * 8).collect()).collect();
        let x = Tensor::randn(&mut rng, &[400, d], 1.0);
        let w = train_linear_router(&ffn, &partition, &x, &RouterTrainConfig::default());

        // evaluate top-1 agreement with the true max-mass expert on a
        // fresh probe
        let probe = Tensor::randn(&mut rng, &[128, d], 1.0);
        let h = tensor::swiglu_hidden(&probe, &ffn.w_gate, &ffn.w_up);
        let scores = tensor::matmul(&probe, &w);
        let mut hits = 0usize;
        for t in 0..128 {
            let truth = (0..6)
                .max_by(|&a, &b| {
                    let ma: f32 = partition[a].iter().map(|&i| h.at2(t, i).abs()).sum();
                    let mb: f32 = partition[b].iter().map(|&i| h.at2(t, i).abs()).sum();
                    ma.partial_cmp(&mb).unwrap()
                })
                .unwrap();
            let pred = (0..6)
                .max_by(|&a, &b| scores.at2(t, a).partial_cmp(&scores.at2(t, b)).unwrap())
                .unwrap();
            if truth == pred {
                hits += 1;
            }
        }
        // chance = 1/6 ≈ 21/128
        assert!(hits > 40, "trained router top-1 only {hits}/128");
    }

    #[test]
    fn router_shape() {
        let mut rng = Rng::new(212);
        let ffn = FfnWeights {
            w_gate: Tensor::randn(&mut rng, &[4, 8], 0.5),
            w_up: Tensor::randn(&mut rng, &[4, 8], 0.5),
            w_down: Tensor::randn(&mut rng, &[8, 4], 0.5),
        };
        let partition = vec![vec![0, 1, 2, 3], vec![4, 5, 6, 7]];
        let x = Tensor::randn(&mut rng, &[32, 4], 1.0);
        let w = train_linear_router(&ffn, &partition, &x, &RouterTrainConfig { epochs: 1, ..Default::default() });
        assert_eq!(w.shape, vec![4, 2]);
        assert!(w.data.iter().all(|v| v.is_finite()));
    }
}
