//! WINA (Chen et al. 2025): training-free *neuron-level* activation
//! sparsity. Per token, keep the top fraction of neurons ranked by
//! `|h_i| · ‖w_down[i,:]‖₂` (weight-informed magnitude) and zero the
//! rest. Orthogonal to expert-level restructuring — Table 8 composes it
//! with CMoE inside each expert.

use crate::model::FfnWeights;
use crate::tensor::{self, Tensor};

/// Precomputed column norms of `w_down` (the weight-informed part).
pub fn down_norms(ffn: &FfnWeights) -> Vec<f32> {
    let d_h = ffn.hidden_dim();
    let d = ffn.w_down.shape[1];
    (0..d_h)
        .map(|i| {
            let row = &ffn.w_down.data[i * d..(i + 1) * d];
            row.iter().map(|v| v * v).sum::<f32>().sqrt()
        })
        .collect()
}

/// FFN forward with WINA sparsity: per token keep `keep` fraction of
/// neurons by weight-informed score, zero the rest. `keep = 1.0`
/// recovers the dense FFN exactly.
pub fn wina_ffn_forward(ffn: &FfnWeights, x: &Tensor, keep: f32) -> Tensor {
    assert!((0.0..=1.0).contains(&keep));
    let mut h = tensor::swiglu_hidden(x, &ffn.w_gate, &ffn.w_up);
    let d_h = ffn.hidden_dim();
    let k = ((d_h as f32 * keep).round() as usize).clamp(0, d_h);
    if k < d_h {
        let norms = down_norms(ffn);
        for t in 0..h.shape[0] {
            let row = h.row_mut(t);
            let scores: Vec<f32> =
                row.iter().zip(&norms).map(|(v, n)| v.abs() * n).collect();
            let top = tensor::top_k_indices(&scores, k);
            let keep_set: std::collections::HashSet<usize> = top.into_iter().collect();
            for (i, v) in row.iter_mut().enumerate() {
                if !keep_set.contains(&i) {
                    *v = 0.0;
                }
            }
        }
    }
    tensor::matmul(&h, &ffn.w_down)
}

/// The FLOPs keep-fraction WINA achieves at ratio `keep` (identity —
/// named for call-site clarity in the Table 8 harness).
pub fn wina_keep_fraction(keep: f64) -> f64 {
    keep
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn ffn(rng: &mut Rng) -> FfnWeights {
        FfnWeights {
            w_gate: Tensor::randn(rng, &[10, 40], 0.5),
            w_up: Tensor::randn(rng, &[10, 40], 0.5),
            w_down: Tensor::randn(rng, &[40, 10], 0.5),
        }
    }

    #[test]
    fn keep_one_is_dense() {
        let mut rng = Rng::new(271);
        let f = ffn(&mut rng);
        let x = Tensor::randn(&mut rng, &[6, 10], 1.0);
        let dense = tensor::swiglu_ffn(&x, &f.w_gate, &f.w_up, &f.w_down);
        let wina = wina_ffn_forward(&f, &x, 1.0);
        assert!(dense.max_abs_diff(&wina) < 1e-6);
    }

    #[test]
    fn error_grows_as_keep_shrinks() {
        let mut rng = Rng::new(272);
        let f = ffn(&mut rng);
        let x = Tensor::randn(&mut rng, &[32, 10], 1.0);
        let dense = tensor::swiglu_ffn(&x, &f.w_gate, &f.w_up, &f.w_down);
        let mut last = 0.0f32;
        for keep in [0.75f32, 0.5, 0.25] {
            let w = wina_ffn_forward(&f, &x, keep);
            let err = dense.max_abs_diff(&w);
            assert!(err >= last, "error not monotone at keep={keep}");
            last = err;
        }
        assert!(last > 0.0);
    }

    #[test]
    fn wina_beats_naive_magnitude_pruning() {
        // weight-informed ranking should reconstruct at least as well as
        // |h| alone when down-projection norms vary strongly
        let mut rng = Rng::new(273);
        let mut f = ffn(&mut rng);
        // make down-norms wildly non-uniform
        for i in 0..40 {
            let scale = if i % 2 == 0 { 4.0 } else { 0.05 };
            for v in f.w_down.row_mut(i) {
                *v *= scale;
            }
        }
        let x = Tensor::randn(&mut rng, &[64, 10], 1.0);
        let dense = tensor::swiglu_ffn(&x, &f.w_gate, &f.w_up, &f.w_down);
        // naive: zero by |h| only
        let mut h = tensor::swiglu_hidden(&x, &f.w_gate, &f.w_up);
        for t in 0..64 {
            let row = h.row_mut(t);
            let scores: Vec<f32> = row.iter().map(|v| v.abs()).collect();
            let top: std::collections::HashSet<usize> =
                tensor::top_k_indices(&scores, 20).into_iter().collect();
            for (i, v) in row.iter_mut().enumerate() {
                if !top.contains(&i) {
                    *v = 0.0;
                }
            }
        }
        let naive = tensor::matmul(&h, &f.w_down);
        let wina = wina_ffn_forward(&f, &x, 0.5);
        let err = |a: &Tensor| -> f64 {
            let mut d = dense.clone();
            for (x, y) in d.data.iter_mut().zip(&a.data) {
                *x -= y;
            }
            d.norm() as f64
        };
        assert!(
            err(&wina) <= err(&naive) * 1.01,
            "WINA {:.4} should beat naive {:.4}",
            err(&wina),
            err(&naive)
        );
    }
}
