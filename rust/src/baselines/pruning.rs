//! Structured pruning stand-in for SliceGPT / SLEB (Tables 1): remove
//! the least-important FFN neurons *statically* (same neurons for every
//! input), shrinking the weight matrices. Importance is the
//! calibration-mean contribution `E[|h_i|]·‖w_down[i,:]‖` — the same
//! signal the WINA/Wanda family uses for structured removal.

use crate::baselines::wina::down_norms;
use crate::model::{FfnWeights, LayerFfn, ModelWeights};
use crate::profiling::ActivationProfile;
use crate::tensor::top_k_indices;

/// Prune `drop_frac` of neurons from one FFN by importance.
pub fn prune_ffn(ffn: &FfnWeights, profile: &ActivationProfile, drop_frac: f64) -> FfnWeights {
    let d_h = ffn.hidden_dim();
    assert_eq!(profile.d_h, d_h);
    let keep = d_h - ((d_h as f64 * drop_frac).round() as usize).min(d_h);
    let norms = down_norms(ffn);
    let importance: Vec<f32> = profile
        .mean_abs_h
        .iter()
        .zip(&norms)
        .map(|(h, n)| h * n)
        .collect();
    let mut kept = top_k_indices(&importance, keep);
    kept.sort_unstable();
    ffn.slice_neurons(&kept)
}

/// Prune every dense FFN layer of a model (the 20%-reduction setting of
/// Table 1; attention is left intact, matching the "effective FFN
/// sparsity" note in §5.1).
pub fn prune_model(
    model: &ModelWeights,
    profiles: &[ActivationProfile],
    drop_frac: f64,
) -> ModelWeights {
    let mut out = model.clone();
    for (l, layer) in out.layers.iter_mut().enumerate() {
        if let LayerFfn::Dense(f) = &layer.ffn {
            layer.ffn = LayerFfn::Dense(prune_ffn(f, &profiles[l], drop_frac));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{self, Tensor};
    use crate::util::Rng;

    #[test]
    fn prune_removes_requested_fraction() {
        let mut rng = Rng::new(281);
        let ffn = FfnWeights {
            w_gate: Tensor::randn(&mut rng, &[8, 40], 0.5),
            w_up: Tensor::randn(&mut rng, &[8, 40], 0.5),
            w_down: Tensor::randn(&mut rng, &[40, 8], 0.5),
        };
        let x = Tensor::randn(&mut rng, &[50, 8], 1.0);
        let h = tensor::swiglu_hidden(&x, &ffn.w_gate, &ffn.w_up);
        let prof = ActivationProfile::from_hidden(&h, 8);
        let pruned = prune_ffn(&ffn, &prof, 0.25);
        assert_eq!(pruned.hidden_dim(), 30);
    }

    #[test]
    fn pruning_keeps_important_neurons() {
        let mut rng = Rng::new(282);
        let mut ffn = FfnWeights {
            w_gate: Tensor::randn(&mut rng, &[8, 40], 0.1),
            w_up: Tensor::randn(&mut rng, &[8, 40], 0.1),
            w_down: Tensor::randn(&mut rng, &[40, 8], 0.1),
        };
        // inflate neuron 7 so it dominates outputs
        for r in 0..8 {
            *ffn.w_gate.at2_mut(r, 7) *= 30.0;
            *ffn.w_up.at2_mut(r, 7) *= 30.0;
        }
        let x = Tensor::randn(&mut rng, &[50, 8], 1.0);
        let h = tensor::swiglu_hidden(&x, &ffn.w_gate, &ffn.w_up);
        let prof = ActivationProfile::from_hidden(&h, 8);
        let pruned = prune_ffn(&ffn, &prof, 0.5);
        // neuron 7's gate column must survive: check its (huge) values
        // appear among the pruned w_gate columns
        let orig_col: Vec<f32> = (0..8).map(|r| ffn.w_gate.at2(r, 7)).collect();
        let survives = (0..pruned.hidden_dim()).any(|c| {
            (0..8).all(|r| (pruned.w_gate.at2(r, c) - orig_col[r]).abs() < 1e-9)
        });
        assert!(survives, "dominant neuron pruned away");
    }

    #[test]
    fn prune_model_shrinks_all_layers() {
        let cfg = crate::model::model_config("tiny").unwrap();
        let mut rng = Rng::new(283);
        let model = ModelWeights::random(&cfg, &mut rng);
        let fwd = crate::eval::forward::DenseForward::new(&model);
        let calib: Vec<usize> = (0..64).map(|_| rng.below(cfg.vocab)).collect();
        let profiles: Vec<ActivationProfile> = fwd
            .capture_hidden(&calib)
            .iter()
            .map(|h| ActivationProfile::from_hidden(h, 16))
            .collect();
        let pruned = prune_model(&model, &profiles, 0.2);
        for l in 0..cfg.n_layers {
            assert_eq!(pruned.dense_ffn(l).hidden_dim(), cfg.d_ff - cfg.d_ff / 5);
        }
        // pruned model still runs
        let fwd2 = crate::eval::forward::DenseForward::new(&pruned);
        let logits = fwd2.logits(&[1, 2, 3]);
        assert_eq!(logits.shape, vec![3, cfg.vocab]);
    }
}
