//! Test-only helpers shared across modules.
//!
//! [`structured_ffn`] builds FFN weights that genuinely exhibit the
//! paper's §3 activation structure when driven by gaussian inputs:
//! * **hot neurons** — large-norm gate/up columns whose |h| ranks in the
//!   ATopK for almost every token (activation rate ≈ 1);
//! * **grouped neurons** — gate columns aligned with one of `n_groups`
//!   latent input directions, so group members co-activate exactly when
//!   the token points along their direction (rates ≪ 1, clustered).
//!
//! This is the planted-structure ground truth used to verify that the
//! converter recovers shared experts and co-activation clusters, and
//! that CMoE's comparative claims hold where the paper says they do.

#![cfg(test)]

use crate::model::FfnWeights;
use crate::tensor::Tensor;
use crate::util::Rng;

/// Planted structure description returned with the weights.
pub struct PlantedFfn {
    pub ffn: FfnWeights,
    /// Indices of always-hot neurons.
    pub hot: Vec<usize>,
    /// Group id per neuron (usize::MAX for hot neurons).
    pub group_of: Vec<usize>,
}

/// Build a structured FFN: `n_hot` hot neurons + the rest in
/// `n_groups` co-activation groups.
pub fn structured_ffn(
    rng: &mut Rng,
    d: usize,
    d_h: usize,
    n_hot: usize,
    n_groups: usize,
) -> PlantedFfn {
    // latent directions (unit-ish)
    let dirs: Vec<Vec<f32>> = (0..n_groups)
        .map(|_| {
            let mut v: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
            let n = v.iter().map(|x| x * x).sum::<f32>().sqrt();
            v.iter_mut().for_each(|x| *x /= n);
            v
        })
        .collect();

    let mut ids: Vec<usize> = (0..d_h).collect();
    rng.shuffle(&mut ids);
    let hot: Vec<usize> = ids[..n_hot].to_vec();
    let mut group_of = vec![usize::MAX; d_h];
    for (k, &i) in ids[n_hot..].iter().enumerate() {
        group_of[i] = k % n_groups;
    }

    let mut w_gate = Tensor::zeros(&[d, d_h]);
    let mut w_up = Tensor::zeros(&[d, d_h]);
    let w_down = Tensor::randn(rng, &[d_h, d], (1.0 / d_h as f32).sqrt());
    for i in 0..d_h {
        if group_of[i] == usize::MAX {
            // hot: big random column → |h| large for nearly all inputs
            for r in 0..d {
                *w_gate.at2_mut(r, i) = 3.0 * rng.normal();
                *w_up.at2_mut(r, i) = 1.5 * rng.normal();
            }
        } else {
            // grouped: aligned with the group direction + small noise
            let u = &dirs[group_of[i]];
            for r in 0..d {
                *w_gate.at2_mut(r, i) = 2.0 * u[r] + 0.15 * rng.normal();
                *w_up.at2_mut(r, i) = 0.8 * rng.normal();
            }
        }
    }
    PlantedFfn { ffn: FfnWeights { w_gate, w_up, w_down }, hot, group_of }
}
