//! Choice-ranking task evaluation (the Table 1/2 protocol): score each
//! candidate completion by the model's total log-likelihood of its
//! tokens given the context, pick the argmax, report accuracy.

use crate::data::{encode, ChoiceTask};
use crate::eval::forward::DenseForward;
use crate::model::ModelWeights;

/// A named set of choice tasks (one "benchmark").
#[derive(Clone, Debug)]
pub struct TaskSuite {
    pub name: String,
    pub tasks: Vec<ChoiceTask>,
}

/// Log-likelihood of `completion` following `context`.
pub fn completion_loglik(model: &ModelWeights, context: &str, completion: &str) -> f64 {
    let ctx = encode(context);
    let comp = encode(completion);
    let mut full = ctx.clone();
    full.extend_from_slice(&comp);
    let max_seq = model.config.max_seq;
    if full.len() > max_seq {
        // keep the suffix (completion must stay intact)
        full.drain(..full.len() - max_seq);
    }
    let fwd = DenseForward::new(model);
    let logits = fwd.logits(&full);
    let comp_start = full.len() - comp.len();
    let mut ll = 0.0f64;
    for t in comp_start..full.len() {
        // position t is predicted by logits at t-1
        let row = logits.row(t - 1);
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let lse: f32 = row.iter().map(|&v| (v - max).exp()).sum::<f32>().ln() + max;
        ll += (row[full[t]] - lse) as f64;
    }
    ll
}

/// Greedy choice-ranking accuracy over a suite.
pub fn choice_accuracy(model: &ModelWeights, suite: &TaskSuite) -> f64 {
    if suite.tasks.is_empty() {
        return 0.0;
    }
    let mut correct = 0usize;
    for task in &suite.tasks {
        let pick = best_choice(model, task);
        if pick == task.answer {
            correct += 1;
        }
    }
    correct as f64 / suite.tasks.len() as f64
}

/// Argmax-likelihood choice for one task.
pub fn best_choice(model: &ModelWeights, task: &ChoiceTask) -> usize {
    let mut best = 0usize;
    let mut best_ll = f64::NEG_INFINITY;
    for (i, choice) in task.choices.iter().enumerate() {
        // length-normalized loglik, as lm-eval does for acc_norm
        let ll = completion_loglik(model, &task.context, choice)
            / choice.len().max(1) as f64;
        if ll > best_ll {
            best_ll = ll;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tasks_gen::{gen_choice_tasks, TaskFamily};
    use crate::model::model_config;
    use crate::util::Rng;

    #[test]
    fn random_model_scores_near_chance() {
        let cfg = model_config("tiny").unwrap();
        let mut rng = Rng::new(81);
        let model = crate::model::ModelWeights::random(&cfg, &mut rng);
        let suite = TaskSuite {
            name: "arith".into(),
            tasks: gen_choice_tasks(TaskFamily::Arith, 40, 1),
        };
        let acc = choice_accuracy(&model, &suite);
        assert!((0.0..=0.65).contains(&acc), "untrained acc {acc} suspiciously high");
    }

    #[test]
    fn loglik_is_negative_and_finite() {
        let cfg = model_config("tiny").unwrap();
        let mut rng = Rng::new(82);
        let model = crate::model::ModelWeights::random(&cfg, &mut rng);
        let ll = completion_loglik(&model, "12+34=", "46;");
        assert!(ll.is_finite());
        assert!(ll < 0.0);
    }

    #[test]
    fn long_context_truncates_from_left() {
        let cfg = model_config("tiny").unwrap();
        let mut rng = Rng::new(83);
        let model = crate::model::ModelWeights::random(&cfg, &mut rng);
        let ctx = "x".repeat(cfg.max_seq + 50);
        let ll = completion_loglik(&model, &ctx, "ab");
        assert!(ll.is_finite());
    }
}
