//! Pure-rust reference forward pass.
//!
//! The serving engine executes compiled XLA artifacts; this forward is
//! the numerics oracle — it handles dense *and* MoE-restructured layers
//! (dispatching through [`crate::moe::moe_ffn_forward`]) and is used by
//! profiling, perplexity evaluation, the task suites and the
//! artifact-parity integration tests.

use crate::model::{LayerFfn, ModelWeights};
use crate::moe::MoeForwardStats;
use crate::tensor::{self, Tensor};

/// Per-forward statistics (routing counts per MoE layer).
#[derive(Clone, Debug, Default)]
pub struct ForwardStats {
    /// One entry per MoE layer encountered (layer index, stats).
    pub moe: Vec<(usize, MoeForwardStats)>,
}

/// Reference forward executor over a model.
pub struct DenseForward<'a> {
    pub model: &'a ModelWeights,
}

impl<'a> DenseForward<'a> {
    pub fn new(model: &'a ModelWeights) -> Self {
        DenseForward { model }
    }

    /// Logits for every position of `tokens` (one causal sequence).
    pub fn logits(&self, tokens: &[usize]) -> Tensor {
        self.run(tokens, false, None).0
    }

    /// Logits + routing stats (for Figure 5 / FLOPs accounting).
    pub fn logits_with_stats(&self, tokens: &[usize]) -> (Tensor, ForwardStats) {
        let mut stats = ForwardStats::default();
        let (logits, _) = self.run(tokens, false, Some(&mut stats));
        (logits, stats)
    }

    /// FFN hidden states per layer (dense layers only — used by the
    /// activation profiler).
    pub fn capture_hidden(&self, tokens: &[usize]) -> Vec<Tensor> {
        self.run(tokens, true, None).1
    }

    /// Normed FFN *inputs* per layer (`x_n` fed to each FFN) — the
    /// calibration tensor the baseline converters train routers on.
    pub fn capture_ffn_inputs(&self, tokens: &[usize]) -> Vec<Tensor> {
        let mut inputs = Vec::new();
        self.run_with_input_capture(tokens, &mut inputs);
        inputs
    }

    fn run_with_input_capture(&self, tokens: &[usize], inputs: &mut Vec<Tensor>) {
        // a second pass that records xn before each FFN; kept separate
        // from `run` to avoid burdening the common path
        let cfg = &self.model.config;
        let q = tokens.len();
        let d = cfg.d_model;
        let mut x = Tensor::zeros(&[q, d]);
        for (t, &id) in tokens.iter().enumerate() {
            let e = self.model.embed.row(id);
            let p = self.model.pos.row(t);
            let row = x.row_mut(t);
            for j in 0..d {
                row[j] = e[j] + p[j];
            }
        }
        for layer in &self.model.layers {
            let xn = tensor::rmsnorm_rows(&x, &layer.attn_norm, 1e-6);
            let attn_out = causal_attention(&xn, layer, cfg.n_heads);
            tensor::add_inplace(&mut x, &attn_out);
            let xn = tensor::rmsnorm_rows(&x, &layer.ffn_norm, 1e-6);
            let ffn_out = match &layer.ffn {
                LayerFfn::Dense(f) => tensor::swiglu_ffn(&xn, &f.w_gate, &f.w_up, &f.w_down),
                LayerFfn::Moe(moe) => crate::moe::moe_ffn_forward(moe, &xn).0,
            };
            inputs.push(xn);
            tensor::add_inplace(&mut x, &ffn_out);
        }
    }

    fn run(
        &self,
        tokens: &[usize],
        capture: bool,
        mut stats: Option<&mut ForwardStats>,
    ) -> (Tensor, Vec<Tensor>) {
        let cfg = &self.model.config;
        let q = tokens.len();
        assert!(q > 0 && q <= cfg.max_seq, "sequence length {q} out of range");
        let d = cfg.d_model;

        // embeddings + learned positions
        let mut x = Tensor::zeros(&[q, d]);
        for (t, &id) in tokens.iter().enumerate() {
            assert!(id < cfg.vocab, "token id {id} >= vocab");
            let e = self.model.embed.row(id);
            let p = self.model.pos.row(t);
            let row = x.row_mut(t);
            for j in 0..d {
                row[j] = e[j] + p[j];
            }
        }

        let mut captured = Vec::new();
        for (l, layer) in self.model.layers.iter().enumerate() {
            // --- attention block ---
            let xn = tensor::rmsnorm_rows(&x, &layer.attn_norm, 1e-6);
            let attn_out = causal_attention(&xn, layer, cfg.n_heads);
            tensor::add_inplace(&mut x, &attn_out);

            // --- FFN block ---
            let xn = tensor::rmsnorm_rows(&x, &layer.ffn_norm, 1e-6);
            let ffn_out = match &layer.ffn {
                LayerFfn::Dense(f) => {
                    if capture {
                        let h = tensor::swiglu_hidden(&xn, &f.w_gate, &f.w_up);
                        let out = tensor::matmul(&h, &f.w_down);
                        captured.push(h);
                        out
                    } else {
                        tensor::swiglu_ffn(&xn, &f.w_gate, &f.w_up, &f.w_down)
                    }
                }
                LayerFfn::Moe(moe) => {
                    let (out, s) = crate::moe::moe_ffn_forward(moe, &xn);
                    if let Some(st) = stats.as_deref_mut() {
                        st.moe.push((l, s));
                    }
                    out
                }
            };
            tensor::add_inplace(&mut x, &ffn_out);
        }

        let xn = tensor::rmsnorm_rows(&x, &self.model.final_norm, 1e-6);
        let logits = tensor::matmul(&xn, &self.model.unembed);
        (logits, captured)
    }
}

/// Public re-export of the attention primitive for custom evaluation
/// loops (e.g. the WINA-composed forward in the bench harness).
pub fn attention_for_tests(
    x: &Tensor,
    layer: &crate::model::LayerWeights,
    n_heads: usize,
) -> Tensor {
    causal_attention(x, layer, n_heads)
}

/// Multi-head causal self-attention for one sequence `x: [q, d]`.
fn causal_attention(x: &Tensor, layer: &crate::model::LayerWeights, n_heads: usize) -> Tensor {
    let q_len = x.shape[0];
    let d = x.shape[1];
    let hd = d / n_heads;
    let scale = 1.0 / (hd as f32).sqrt();

    let qm = tensor::matmul(x, &layer.attn.wq);
    let km = tensor::matmul(x, &layer.attn.wk);
    let vm = tensor::matmul(x, &layer.attn.wv);

    let mut ctx = Tensor::zeros(&[q_len, d]);
    for h in 0..n_heads {
        let off = h * hd;
        for t in 0..q_len {
            // scores over prefix 0..=t
            let qrow = &qm.row(t)[off..off + hd];
            let mut scores = Vec::with_capacity(t + 1);
            for s in 0..=t {
                let krow = &km.row(s)[off..off + hd];
                let dot: f32 = qrow.iter().zip(krow).map(|(a, b)| a * b).sum();
                scores.push(dot * scale);
            }
            let probs = tensor::softmax(&scores);
            let orow = &mut ctx.row_mut(t)[off..off + hd];
            for (s, &p) in probs.iter().enumerate() {
                let vrow = &vm.row(s)[off..off + hd];
                for (o, v) in orow.iter_mut().zip(vrow) {
                    *o += p * v;
                }
            }
        }
    }
    tensor::matmul(&ctx, &layer.attn.wo)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::model_config;
    use crate::util::Rng;

    #[test]
    fn logits_shape_and_finiteness() {
        let cfg = model_config("tiny").unwrap();
        let mut rng = Rng::new(61);
        let model = ModelWeights::random(&cfg, &mut rng);
        let fwd = DenseForward::new(&model);
        let tokens: Vec<usize> = (0..12).map(|_| rng.below(cfg.vocab)).collect();
        let logits = fwd.logits(&tokens);
        assert_eq!(logits.shape, vec![12, cfg.vocab]);
        assert!(logits.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn capture_matches_layer_count() {
        let cfg = model_config("tiny").unwrap();
        let mut rng = Rng::new(62);
        let model = ModelWeights::random(&cfg, &mut rng);
        let fwd = DenseForward::new(&model);
        let tokens: Vec<usize> = (0..8).map(|_| rng.below(cfg.vocab)).collect();
        let h = fwd.capture_hidden(&tokens);
        assert_eq!(h.len(), cfg.n_layers);
        for t in &h {
            assert_eq!(t.shape, vec![8, cfg.d_ff]);
        }
    }

    #[test]
    fn causality_prefix_invariance() {
        // logits at position t must not depend on tokens after t
        let cfg = model_config("tiny").unwrap();
        let mut rng = Rng::new(63);
        let model = ModelWeights::random(&cfg, &mut rng);
        let fwd = DenseForward::new(&model);
        let a: Vec<usize> = (0..10).map(|_| rng.below(cfg.vocab)).collect();
        let mut b = a.clone();
        b[9] = (b[9] + 1) % cfg.vocab; // change only the last token
        let la = fwd.logits(&a);
        let lb = fwd.logits(&b);
        for t in 0..9 {
            for v in 0..cfg.vocab {
                assert!(
                    (la.at2(t, v) - lb.at2(t, v)).abs() < 1e-5,
                    "position {t} leaked future tokens"
                );
            }
        }
    }

    #[test]
    fn moe_model_forward_runs_and_reports_stats() {
        use crate::converter::{convert_model, ConvertOptions};
        use crate::profiling::ActivationProfile;
        let cfg = model_config("tiny").unwrap();
        let mut rng = Rng::new(64);
        let model = ModelWeights::random(&cfg, &mut rng);
        let calib: Vec<usize> = (0..128).map(|_| rng.below(cfg.vocab)).collect();
        let fwd = DenseForward::new(&model);
        let hs = fwd.capture_hidden(&calib[..64.min(calib.len())]);
        let profiles: Vec<ActivationProfile> =
            hs.iter().map(|h| ActivationProfile::from_hidden(h, 16)).collect();
        let spec = "S3A3E8".parse().unwrap();
        let conv = convert_model(&model, &profiles, &spec, &ConvertOptions::default()).unwrap();
        let fwd2 = DenseForward::new(&conv.model);
        let tokens: Vec<usize> = (0..16).map(|_| rng.below(cfg.vocab)).collect();
        let (logits, stats) = fwd2.logits_with_stats(&tokens);
        assert_eq!(logits.shape, vec![16, cfg.vocab]);
        assert_eq!(stats.moe.len(), cfg.n_layers);
        for (_, s) in &stats.moe {
            assert_eq!(s.tokens, 16);
            assert_eq!(s.expert_tokens.iter().sum::<usize>(), 16 * 3);
        }
    }

    #[test]
    fn converted_model_logits_stay_close_to_dense() {
        use crate::converter::{convert_model, ConvertOptions};
        use crate::profiling::ActivationProfile;
        let cfg = model_config("tiny").unwrap();
        let mut rng = Rng::new(65);
        let model = ModelWeights::random(&cfg, &mut rng);
        let fwd = DenseForward::new(&model);
        let calib: Vec<usize> = (0..64).map(|_| rng.below(cfg.vocab)).collect();
        let hs = fwd.capture_hidden(&calib);
        let profiles: Vec<ActivationProfile> =
            hs.iter().map(|h| ActivationProfile::from_hidden(h, 32)).collect();
        // nearly dense spec (only 1 of 6 routed experts off)
        let spec = "S2A5E8".parse().unwrap();
        let conv = convert_model(&model, &profiles, &spec, &ConvertOptions::default()).unwrap();
        let tokens: Vec<usize> = (0..12).map(|_| rng.below(cfg.vocab)).collect();
        let dense_logits = fwd.logits(&tokens);
        let moe_logits = DenseForward::new(&conv.model).logits(&tokens);
        // A random (untrained) model has near-uniform logits, so argmax
        // is fragile; require both argmax agreement above chance AND a
        // small relative logit perturbation.
        let mut same = 0;
        for t in 0..12 {
            let am = |l: &Tensor| {
                (0..cfg.vocab).max_by(|&a, &b| l.at2(t, a).partial_cmp(&l.at2(t, b)).unwrap()).unwrap()
            };
            if am(&dense_logits) == am(&moe_logits) {
                same += 1;
            }
        }
        assert!(same >= 4, "argmax agreement only {same}/12 (chance ≈ 0/12)");
        let mut diff = dense_logits.clone();
        for (a, b) in diff.data.iter_mut().zip(&moe_logits.data) {
            *a -= b;
        }
        let rel = diff.norm() / dense_logits.norm();
        assert!(rel < 0.5, "relative logit perturbation {rel} too large");
    }
}
