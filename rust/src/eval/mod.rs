//! Evaluation: rust-reference forward pass, perplexity, choice-ranking
//! task suites, self-consistency voting and the analytic FLOPs/MACs
//! counter used by Tables 7/8.

pub mod forward;
pub mod ppl;
pub mod tasks;
pub mod flops;
pub mod selfconsistency;

pub use flops::{FlopsReport, count_flops};
pub use forward::{DenseForward, ForwardStats};
pub use ppl::perplexity;
pub use selfconsistency::self_consistency_accuracy;
pub use tasks::{choice_accuracy, TaskSuite};
