//! Perplexity evaluation over a token stream (Tables 3/4/10).

use crate::eval::forward::DenseForward;
use crate::model::ModelWeights;

/// Negative log-likelihood of `tokens[1..]` given prefixes, summed.
/// Returns (total_nll, token_count).
pub fn nll(model: &ModelWeights, tokens: &[usize], seq_len: usize) -> (f64, usize) {
    let fwd = DenseForward::new(model);
    let mut total = 0.0f64;
    let mut count = 0usize;
    for chunk in tokens.chunks(seq_len) {
        if chunk.len() < 2 {
            continue;
        }
        let logits = fwd.logits(chunk);
        for t in 0..chunk.len() - 1 {
            let row = logits.row(t);
            let target = chunk[t + 1];
            // log-softmax
            let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let lse: f32 = row.iter().map(|&v| (v - max).exp()).sum::<f32>().ln() + max;
            total += (lse - row[target]) as f64;
            count += 1;
        }
    }
    (total, count)
}

/// Perplexity `exp(mean NLL)` over a corpus, chunked at `seq_len`.
pub fn perplexity(model: &ModelWeights, tokens: &[usize], seq_len: usize) -> f64 {
    let (total, count) = nll(model, tokens, seq_len);
    if count == 0 {
        return f64::NAN;
    }
    (total / count as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::model_config;
    use crate::util::Rng;

    #[test]
    fn random_model_ppl_near_vocab() {
        // An untrained model's PPL should be near |vocab| on random data.
        let cfg = model_config("tiny").unwrap();
        let mut rng = Rng::new(71);
        let model = ModelWeights::random(&cfg, &mut rng);
        let tokens: Vec<usize> = (0..256).map(|_| rng.below(cfg.vocab)).collect();
        let ppl = perplexity(&model, &tokens, 64);
        assert!(ppl.is_finite());
        assert!(
            ppl > cfg.vocab as f64 * 0.3 && ppl < cfg.vocab as f64 * 3.0,
            "ppl {ppl} not near vocab {}",
            cfg.vocab
        );
    }

    #[test]
    fn short_chunks_are_skipped() {
        let cfg = model_config("tiny").unwrap();
        let mut rng = Rng::new(72);
        let model = ModelWeights::random(&cfg, &mut rng);
        let (nll_v, count) = nll(&model, &[1], 64);
        assert_eq!(count, 0);
        assert_eq!(nll_v, 0.0);
        assert!(perplexity(&model, &[1], 64).is_nan());
    }
}
