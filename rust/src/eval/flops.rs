//! Analytic FLOPs/MACs accounting (Tables 7/8): count multiply-
//! accumulates per token for dense, CMoE, WINA-augmented and
//! hierarchical models. 1 MAC = 2 FLOPs.

use crate::model::{LayerFfn, ModelWeights, MoeSpec, TransformerConfig};

/// Per-token compute accounting.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FlopsReport {
    pub macs_attn: f64,
    pub macs_ffn: f64,
    pub macs_router: f64,
    pub macs_logits: f64,
}

impl FlopsReport {
    pub fn macs_total(&self) -> f64 {
        self.macs_attn + self.macs_ffn + self.macs_router + self.macs_logits
    }
    pub fn flops_total(&self) -> f64 {
        2.0 * self.macs_total()
    }
    /// Relative FFN+router savings vs a dense report.
    pub fn savings_vs(&self, dense: &FlopsReport) -> f64 {
        1.0 - self.flops_total() / dense.flops_total()
    }
}

/// MACs/token for the *current* structure of `model` (dense layers count
/// fully, MoE layers count shared + N_k experts + router).
/// `wina_keep` < 1.0 additionally scales expert/dense FFN MACs by the
/// WINA neuron-keep fraction (Table 8's composition).
pub fn count_flops(model: &ModelWeights, wina_keep: f64) -> FlopsReport {
    let cfg = &model.config;
    let d = cfg.d_model as f64;
    let mut r = FlopsReport::default();
    r.macs_attn = cfg.n_layers as f64 * 4.0 * d * d; // q,k,v,o projections
    r.macs_logits = d * cfg.vocab as f64;
    for layer in &model.layers {
        match &layer.ffn {
            LayerFfn::Dense(f) => {
                r.macs_ffn += 3.0 * d * f.hidden_dim() as f64 * wina_keep;
            }
            LayerFfn::Moe(moe) => {
                let m = moe.experts[0].hidden_dim() as f64;
                let shared = moe.shared.hidden_dim() as f64;
                let active = moe.spec.active as f64 * m;
                r.macs_ffn += 3.0 * d * (shared + active) * wina_keep;
                r.macs_router += d * moe.spec.routed() as f64 * 2.0; // gate+up columns
            }
        }
    }
    r
}

/// Closed-form expected MACs/token for a spec applied to a config —
/// used for sweeps without building weights.
pub fn spec_macs(cfg: &TransformerConfig, spec: Option<&MoeSpec>, wina_keep: f64) -> f64 {
    let d = cfg.d_model as f64;
    let attn = cfg.n_layers as f64 * 4.0 * d * d;
    let logits = d * cfg.vocab as f64;
    let ffn = match spec {
        None => cfg.n_layers as f64 * 3.0 * d * cfg.d_ff as f64 * wina_keep,
        Some(s) => {
            let m = (cfg.d_ff / s.total) as f64;
            let per_layer = 3.0 * d * ((s.shared + s.active) as f64 * m) * wina_keep
                + d * s.routed() as f64 * 2.0;
            cfg.n_layers as f64 * per_layer
        }
    };
    attn + ffn + logits
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::converter::{convert_model, ConvertOptions};
    use crate::eval::forward::DenseForward;
    use crate::model::{model_config, ModelWeights};
    use crate::profiling::ActivationProfile;
    use crate::util::Rng;

    fn converted(spec: &str) -> (ModelWeights, ModelWeights) {
        let cfg = model_config("tiny").unwrap();
        let mut rng = Rng::new(91);
        let model = ModelWeights::random(&cfg, &mut rng);
        let fwd = DenseForward::new(&model);
        let calib: Vec<usize> = (0..64).map(|_| rng.below(cfg.vocab)).collect();
        let profiles: Vec<ActivationProfile> = fwd
            .capture_hidden(&calib)
            .iter()
            .map(|h| ActivationProfile::from_hidden(h, 16))
            .collect();
        let conv =
            convert_model(&model, &profiles, &spec.parse().unwrap(), &ConvertOptions::default())
                .unwrap();
        (model, conv.model)
    }

    #[test]
    fn moe_saves_ffn_flops() {
        let (dense, moe) = converted("S3A3E8");
        let rd = count_flops(&dense, 1.0);
        let rm = count_flops(&moe, 1.0);
        assert!(rm.macs_ffn < rd.macs_ffn);
        // 6/8 of neurons active → ffn MACs ratio 0.75
        assert!((rm.macs_ffn / rd.macs_ffn - 0.75).abs() < 1e-9);
        assert!(rm.macs_router > 0.0);
        assert!(rm.savings_vs(&rd) > 0.0);
        assert_eq!(rm.macs_attn, rd.macs_attn);
    }

    #[test]
    fn spec_macs_matches_counted() {
        let (dense, moe) = converted("S3A3E8");
        let cfg = &dense.config;
        let analytic_dense = spec_macs(cfg, None, 1.0);
        let analytic_moe = spec_macs(cfg, Some(&"S3A3E8".parse().unwrap()), 1.0);
        assert!((count_flops(&dense, 1.0).macs_total() - analytic_dense).abs() < 1e-6);
        assert!((count_flops(&moe, 1.0).macs_total() - analytic_moe).abs() < 1e-6);
    }

    #[test]
    fn wina_composes_multiplicatively() {
        let (_, moe) = converted("S3A3E8");
        let full = count_flops(&moe, 1.0);
        let wina = count_flops(&moe, 0.75);
        assert!((wina.macs_ffn / full.macs_ffn - 0.75).abs() < 1e-9);
        assert_eq!(wina.macs_router, full.macs_router);
    }

    #[test]
    fn flops_are_2x_macs() {
        let (dense, _) = converted("S3A3E8");
        let r = count_flops(&dense, 1.0);
        assert!((r.flops_total() - 2.0 * r.macs_total()).abs() < 1e-9);
    }
}
