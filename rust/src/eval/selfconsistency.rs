//! k-sample self-consistency (Table 11): sample the model's choice `k`
//! times with temperature over the choice posteriors and majority-vote.
//!
//! For choice tasks the sampling distribution is the softmax of
//! length-normalized choice log-likelihoods at temperature `t`; k = 1
//! with t = 0 degenerates to greedy ranking (the Table 1 protocol).

use crate::data::ChoiceTask;
use crate::eval::tasks::{completion_loglik, TaskSuite};
use crate::model::ModelWeights;
use crate::util::Rng;

/// Accuracy under k-sample majority voting.
pub fn self_consistency_accuracy(
    model: &ModelWeights,
    suite: &TaskSuite,
    k: usize,
    temperature: f32,
    seed: u64,
) -> f64 {
    if suite.tasks.is_empty() {
        return 0.0;
    }
    let mut rng = Rng::new(seed);
    let mut correct = 0usize;
    for task in &suite.tasks {
        if vote(model, task, k, temperature, &mut rng) == task.answer {
            correct += 1;
        }
    }
    correct as f64 / suite.tasks.len() as f64
}

fn vote(model: &ModelWeights, task: &ChoiceTask, k: usize, temperature: f32, rng: &mut Rng) -> usize {
    let lls: Vec<f32> = task
        .choices
        .iter()
        .map(|c| (completion_loglik(model, &task.context, c) / c.len().max(1) as f64) as f32)
        .collect();
    if k <= 1 || temperature <= 0.0 {
        return argmax(&lls);
    }
    let mut counts = vec![0usize; task.choices.len()];
    for _ in 0..k {
        counts[rng.sample_logits(&lls, temperature)] += 1;
    }
    argmax(&counts.iter().map(|&c| c as f32).collect::<Vec<_>>())
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for i in 1..xs.len() {
        if xs[i] > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tasks_gen::{gen_choice_tasks, TaskFamily};
    use crate::model::model_config;

    #[test]
    fn k1_matches_greedy() {
        let cfg = model_config("tiny").unwrap();
        let mut rng = Rng::new(101);
        let model = ModelWeights::random(&cfg, &mut rng);
        let suite = TaskSuite {
            name: "p".into(),
            tasks: gen_choice_tasks(TaskFamily::Pattern, 15, 2),
        };
        let greedy = crate::eval::tasks::choice_accuracy(&model, &suite);
        let sc = self_consistency_accuracy(&model, &suite, 1, 0.7, 0);
        assert!((greedy - sc).abs() < 1e-12);
    }

    #[test]
    fn voting_is_deterministic_given_seed() {
        let cfg = model_config("tiny").unwrap();
        let mut rng = Rng::new(102);
        let model = ModelWeights::random(&cfg, &mut rng);
        let suite = TaskSuite {
            name: "a".into(),
            tasks: gen_choice_tasks(TaskFamily::Arith, 10, 3),
        };
        let a = self_consistency_accuracy(&model, &suite, 5, 1.0, 42);
        let b = self_consistency_accuracy(&model, &suite, 5, 1.0, 42);
        assert_eq!(a, b);
    }
}
