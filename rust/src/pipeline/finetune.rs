//! The pipeline's optional fine-tune stage: lightweight gate
//! fine-tuning of every MoE layer against the dense teacher
//! (§4.3's learnable scaling + load balancing on the paper's 2k-sample
//! budget). Moved here from the bench harness so the CLI, the
//! [`super::Pipeline`] and `cmoe bench` all share one implementation.

use crate::eval::forward::DenseForward;
use crate::model::{LayerFfn, ModelWeights};
use anyhow::Result;

/// Fine-tune every MoE layer's gates on `samples` token rows drawn from
/// the calibration stream (the paper's 2k-sample budget analog). FFN
/// inputs are captured from the *dense* teacher in `seq`-token chunks —
/// pass the calibration sequence length so attention context matches
/// profiling.
pub fn finetune_model(
    moe_model: &mut ModelWeights,
    dense_model: &ModelWeights,
    calib: &[usize],
    samples: usize,
    seq: usize,
) -> Result<()> {
    let seq = seq.max(2);
    let fwd = DenseForward::new(dense_model);
    let take = samples.min(calib.len());
    let inputs = fwd.capture_ffn_inputs(&calib[..take.min(seq)]);
    // gather more chunks if needed
    let mut per_layer: Vec<crate::tensor::Tensor> = inputs;
    let mut consumed = take.min(seq);
    while consumed < take {
        let chunk = &calib[consumed..(consumed + seq).min(take)];
        if chunk.len() < 2 {
            break;
        }
        let more = fwd.capture_ffn_inputs(chunk);
        for (acc, m) in per_layer.iter_mut().zip(more) {
            let mut data = std::mem::take(&mut acc.data);
            data.extend_from_slice(&m.data);
            let rows = acc.shape[0] + m.shape[0];
            *acc = crate::tensor::Tensor::from_vec(data, &[rows, m.shape[1]]);
        }
        consumed += seq;
    }
    let cfg = crate::moe::FinetuneConfig::default();
    for (l, layer) in moe_model.layers.iter_mut().enumerate() {
        if let LayerFfn::Moe(moe) = &mut layer.ffn {
            crate::moe::finetune_gates(moe, &per_layer[l], &cfg);
        }
    }
    Ok(())
}
