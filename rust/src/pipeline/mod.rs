//! The staged, resumable conversion pipeline — ONE API over CMoE and
//! every baseline (§4's observation that dense→MoE restructuring is a
//! pipeline, made first-class):
//!
//! ```text
//!   profile ──> partition ──> router ──> assemble ──> finetune ──> save
//!      │            │            │
//!      ▼            ▼            ▼
//!  profile.json  partition.json  router.cmw        (stage artifacts)
//! ```
//!
//! * **profile** — per-layer [`ActivationProfile`]s on the calibration
//!   stream ([`CalibrationSpec`]); skipped when the method needs none.
//! * **partition** — a [`Partitioner`] turns profile + weights into a
//!   [`LayerPartition`] (expert neuron membership) per layer.
//! * **router** — a [`RouterBuilder`] turns a partition into a
//!   [`RouterBuild`] (router weights + representatives + compensation).
//! * **assemble** — [`crate::converter::assemble_moe_layer`] slices the
//!   original weights; the only constructor of MoE layers.
//! * **finetune** — optional gate fine-tuning against the dense teacher.
//!
//! Every stage boundary serializes through [`artifact`] (`cmoe convert
//! --save-stages <dir>`), and [`Pipeline::resume_from`] restarts from
//! any of the three files — so one expensive profiling pass is shared
//! by a whole method sweep, and a killed conversion resumes mid-way.
//!
//! Methods are named entries in [`registry`] (`cmoe`, `moefication`,
//! `gmoefication`, `llama-moe`, `emoe`, `readme`, plus the Table 5
//! hybrids `<base>+cmoe-router`). The `cmoe` entry composes the exact
//! functions [`crate::converter::convert_ffn_timed`] runs, so the
//! pipeline's output is bit-identical to the classic
//! `converter::convert_model` path — pinned by the golden test in
//! `tests/pipeline_golden.rs` and `scripts/check.sh`.

pub mod artifact;
mod finetune;
pub mod methods;
pub mod registry;

pub use crate::converter::{LayerPartition, RouterBuild};
pub use finetune::finetune_model;
pub use registry::Method;

use crate::converter;
use crate::data::calibration::CalibrationSpec;
use crate::data::corpus::Domain;
use crate::eval::forward::DenseForward;
use crate::model::{FfnWeights, LayerFfn, ModelWeights, MoeSpec};
use crate::profiling::ActivationProfile;
use crate::tensor::Tensor;
use crate::util::timer::fmt_duration;
use crate::util::Timer;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};
use std::time::Duration;

/// Per-layer inputs a stage implementation may draw on. Fields are
/// optional because the pipeline only computes what the method's flags
/// request; the accessors turn a missing input into a clear error.
pub struct StageCtx<'a> {
    pub layer: usize,
    /// Primary-domain activation profile of this layer.
    pub profile: Option<&'a ActivationProfile>,
    /// Profiles of auxiliary calibration domains (Read-ME), same layer.
    pub aux_profiles: Vec<&'a ActivationProfile>,
    /// Captured FFN inputs `x: [q, d]` of this layer on the calibration
    /// prefix (router training, compensation, global prototypes).
    pub calib_inputs: Option<&'a Tensor>,
}

impl<'a> StageCtx<'a> {
    /// The activation profile, or a descriptive error.
    pub fn profile(&self) -> Result<&'a ActivationProfile> {
        self.profile.with_context(|| {
            format!("layer {}: stage needs an activation profile but the profile stage was skipped", self.layer)
        })
    }

    /// Captured calibration inputs, or a descriptive error.
    pub fn calib_inputs(&self) -> Result<&'a Tensor> {
        self.calib_inputs.with_context(|| {
            format!("layer {}: stage needs captured calibration FFN inputs", self.layer)
        })
    }
}

/// Expert-membership stage: profile + weights → [`LayerPartition`].
pub trait Partitioner {
    /// Whether partitioning reads activation profiles (drives the
    /// pipeline's decision to run the profile stage).
    fn needs_profile(&self) -> bool;
    /// Whether the produced partitions carry representatives (CMoE
    /// does), letting an analytical router skip profiling entirely.
    fn provides_representatives(&self) -> bool {
        false
    }
    fn partition(&self, ffn: &FfnWeights, spec: &MoeSpec, ctx: &StageCtx) -> Result<LayerPartition>;
}

/// Router stage: partition → [`RouterBuild`].
pub trait RouterBuilder {
    /// Whether the builder may need profiles (only when the partition
    /// lacks precomputed representatives).
    fn wants_profile(&self) -> bool {
        false
    }
    fn build(&self, ffn: &FfnWeights, part: &LayerPartition, ctx: &StageCtx) -> Result<RouterBuild>;
}

/// Pipeline stage identifiers, in execution order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    Profile,
    Partition,
    Router,
    Assemble,
    Finetune,
    Save,
}

impl Stage {
    pub fn name(&self) -> &'static str {
        match self {
            Stage::Profile => "profile",
            Stage::Partition => "partition",
            Stage::Router => "router",
            Stage::Assemble => "assemble",
            Stage::Finetune => "finetune",
            Stage::Save => "save",
        }
    }
}

/// What one stage did in a [`Pipeline::run`].
#[derive(Clone, Debug)]
pub struct StageRecord {
    pub stage: Stage,
    pub duration: Duration,
    /// Stage artifact written (fresh runs with `--save-stages`) or
    /// loaded (resumed stages).
    pub artifact: Option<PathBuf>,
    pub resumed: bool,
}

impl StageRecord {
    fn resumed(stage: Stage, path: &Path) -> StageRecord {
        StageRecord {
            stage,
            duration: Duration::ZERO,
            artifact: Some(path.to_path_buf()),
            resumed: true,
        }
    }
}

/// Output of a pipeline run: the converted model plus the stage log.
pub struct PipelineRun {
    pub model: ModelWeights,
    pub stages: Vec<StageRecord>,
}

impl PipelineRun {
    /// Record of `stage`, if it executed or was resumed.
    pub fn stage(&self, stage: Stage) -> Option<&StageRecord> {
        self.stages.iter().find(|r| r.stage == stage)
    }

    /// Human-readable per-stage summary (printed by `cmoe convert`).
    pub fn summary(&self) -> String {
        let mut s = String::new();
        for r in &self.stages {
            let line = if r.resumed {
                format!(
                    "  {:<9} resumed from {}",
                    r.stage.name(),
                    r.artifact.as_ref().map(|p| p.display().to_string()).unwrap_or_default()
                )
            } else {
                let art = r
                    .artifact
                    .as_ref()
                    .map(|p| format!("  -> {}", p.display()))
                    .unwrap_or_default();
                format!("  {:<9} {}{}", r.stage.name(), fmt_duration(r.duration), art)
            };
            s.push_str(&line);
            s.push('\n');
        }
        s.trim_end().to_string()
    }
}

/// The staged conversion driver. Build one with [`Pipeline::for_method`]
/// (registry lookup) or [`Pipeline::from_method`], chain the setters,
/// then [`run`](Pipeline::run) it over a dense checkpoint.
pub struct Pipeline {
    method: Method,
    spec: MoeSpec,
    calib: CalibrationSpec,
    finetune_samples: usize,
    stage_dir: Option<PathBuf>,
    resume_from: Option<PathBuf>,
    profiles_override: Option<Vec<ActivationProfile>>,
    aux_profiles_override: Option<Vec<Vec<ActivationProfile>>>,
}

impl Pipeline {
    /// Pipeline for a registered method name (see [`registry::names`]).
    pub fn for_method(name: &str) -> Result<Pipeline> {
        Ok(Pipeline::from_method(registry::get(name)?))
    }

    /// Pipeline for an explicit (possibly custom) method.
    pub fn from_method(method: Method) -> Pipeline {
        let spec = method.default_spec;
        Pipeline {
            method,
            spec,
            calib: CalibrationSpec::default(),
            finetune_samples: 0,
            stage_dir: None,
            resume_from: None,
            profiles_override: None,
            aux_profiles_override: None,
        }
    }

    /// Override the expert layout (defaults to the method's).
    pub fn spec(mut self, spec: MoeSpec) -> Pipeline {
        self.spec = spec;
        self
    }

    /// Calibration setup for profiling / router training / fine-tuning.
    pub fn calib(mut self, calib: CalibrationSpec) -> Pipeline {
        self.calib = calib;
        self
    }

    /// Enable the fine-tune stage on `samples` calibration rows
    /// (0 = training-free).
    pub fn finetune(mut self, samples: usize) -> Pipeline {
        self.finetune_samples = samples;
        self
    }

    /// Write stage artifacts (`profile.json`, `partition.json`,
    /// `router.cmw`) into `dir` as stages complete.
    pub fn save_stages(mut self, dir: impl Into<PathBuf>) -> Pipeline {
        self.stage_dir = Some(dir.into());
        self
    }

    /// Resume from a previously saved stage artifact; everything up to
    /// and including that stage is loaded instead of recomputed.
    pub fn resume_from(mut self, path: impl Into<PathBuf>) -> Pipeline {
        self.resume_from = Some(path.into());
        self
    }

    /// Inject precomputed profiles (the bench harness shares one
    /// profiling pass across a whole method sweep this way).
    pub fn with_profiles(mut self, profiles: Vec<ActivationProfile>) -> Pipeline {
        self.profiles_override = Some(profiles);
        self
    }

    /// Inject precomputed auxiliary-domain profiles (one `Vec` of
    /// layers per extra calibration domain, for domain-aware methods).
    pub fn with_aux_profiles(mut self, aux: Vec<Vec<ActivationProfile>>) -> Pipeline {
        self.aux_profiles_override = Some(aux);
        self
    }

    pub fn method_name(&self) -> &str {
        &self.method.name
    }

    pub fn current_spec(&self) -> MoeSpec {
        self.spec
    }

    fn stage_path(&self, file: &str) -> Result<Option<PathBuf>> {
        let Some(dir) = &self.stage_dir else { return Ok(None) };
        std::fs::create_dir_all(dir).with_context(|| format!("create {}", dir.display()))?;
        Ok(Some(dir.join(file)))
    }

    /// Partitions are shareable within a base method family only
    /// (`moefication` ↔ `moefication+cmoe-router`, …).
    fn check_artifact_method(&self, artifact_method: &str) -> Result<()> {
        if registry::base_name(artifact_method) != registry::base_name(&self.method.name) {
            bail!(
                "artifact was produced by method '{artifact_method}' but this pipeline runs '{}' \
                 — stage artifacts are only shared within a base method family",
                self.method.name
            );
        }
        Ok(())
    }

    /// A resumed artifact's expert layout must match the requested spec
    /// — otherwise the run would silently ship a different activation
    /// ratio than the caller asked (and the CLI printed).
    fn check_artifact_spec(&self, layers: &[LayerPartition]) -> Result<()> {
        for (l, p) in layers.iter().enumerate() {
            if p.spec != self.spec {
                bail!(
                    "layer {l} of the artifact was partitioned as {} but the pipeline requests {} \
                     — pass --spec {} to resume this artifact",
                    p.spec,
                    self.spec,
                    p.spec
                );
            }
        }
        Ok(())
    }

    /// Auxiliary calibration domains for domain-aware methods: the
    /// "other" synthetic domain at the same calibration settings.
    fn aux_specs(&self) -> Vec<CalibrationSpec> {
        let other = match self.calib.domain {
            Domain::Markov => Domain::Arith,
            Domain::Arith => Domain::Markov,
        };
        vec![self.calib.with_domain(other)]
    }

    /// Run the staged conversion over a dense checkpoint.
    pub fn run(&self, model: &ModelWeights) -> Result<PipelineRun> {
        let n_layers = model.config.n_layers;
        for (l, layer) in model.layers.iter().enumerate() {
            if !matches!(layer.ffn, LayerFfn::Dense(_)) {
                bail!(
                    "layer {l} is already MoE — the pipeline restructures dense checkpoints \
                     (use converter::hierarchical_convert for MoE layers)"
                );
            }
        }
        let mut stages: Vec<StageRecord> = Vec::new();
        let mut profiles: Option<Vec<ActivationProfile>> = self.profiles_override.clone();
        let mut aux: Option<Vec<Vec<ActivationProfile>>> = self.aux_profiles_override.clone();
        let mut parts_res: Option<Vec<LayerPartition>> = None;
        let mut builds_res: Option<Vec<RouterBuild>> = None;

        // ---- resume ------------------------------------------------------
        if let Some(path) = &self.resume_from {
            let art = artifact::load_stage(path)
                .with_context(|| format!("resume from {}", path.display()))?;
            match art {
                artifact::StageArtifact::Profiles { layers, aux: a } => {
                    if layers.len() != n_layers {
                        bail!(
                            "profile artifact holds {} layers, model has {n_layers}",
                            layers.len()
                        );
                    }
                    for dom in &a {
                        if dom.len() != n_layers {
                            bail!(
                                "profile artifact aux domain holds {} layers, model has {n_layers}",
                                dom.len()
                            );
                        }
                    }
                    stages.push(StageRecord::resumed(Stage::Profile, path));
                    profiles = Some(layers);
                    if !a.is_empty() {
                        aux = Some(a);
                    }
                }
                artifact::StageArtifact::Partition { method, layers } => {
                    self.check_artifact_method(&method)?;
                    if layers.len() != n_layers {
                        bail!("partition artifact holds {} layers, model has {n_layers}", layers.len());
                    }
                    self.check_artifact_spec(&layers)?;
                    stages.push(StageRecord::resumed(Stage::Partition, path));
                    parts_res = Some(layers);
                }
                artifact::StageArtifact::Routers { method, layers, builds } => {
                    // Routers are method-specific: a hybrid must not ship
                    // its base method's trained router (or vice versa), so
                    // unlike partitions this demands an exact name match.
                    if method != self.method.name {
                        bail!(
                            "router artifact was produced by method '{method}' but this pipeline \
                             runs '{}' — routers are method-specific; resume from the \
                             partition.json instead",
                            self.method.name
                        );
                    }
                    if layers.len() != n_layers {
                        bail!("router artifact holds {} layers, model has {n_layers}", layers.len());
                    }
                    self.check_artifact_spec(&layers)?;
                    stages.push(StageRecord::resumed(Stage::Partition, path));
                    stages.push(StageRecord::resumed(Stage::Router, path));
                    parts_res = Some(layers);
                    builds_res = Some(builds);
                }
            }
        }

        let need_partition = parts_res.is_none();
        let need_router = builds_res.is_none();

        // ---- stage: profile ---------------------------------------------
        // Run only when some downstream stage actually reads profiles —
        // an analytical router whose partition already carries
        // representatives does not re-profile.
        let partition_wants_profile = need_partition && self.method.partitioner.needs_profile();
        let router_wants_profile = need_router
            && self.method.router.wants_profile()
            && !match &parts_res {
                Some(ps) => ps.iter().all(|p| p.representatives.is_some()),
                None => self.method.partitioner.provides_representatives(),
            };
        let need_primary = profiles.is_none() && (partition_wants_profile || router_wants_profile);
        let need_aux =
            aux.is_none() && need_partition && self.method.needs_aux_domain;
        if need_primary || need_aux {
            let mut timer = Timer::start();
            if need_primary {
                profiles = Some(self.calib.profiles(model));
            }
            if need_aux {
                aux = Some(self.aux_specs().iter().map(|c| c.profiles(model)).collect());
            }
            let art = match self.stage_path("profile.json")? {
                Some(path) => {
                    artifact::save_profiles(
                        &path,
                        profiles.as_deref().unwrap_or(&[]),
                        aux.as_deref().unwrap_or(&[]),
                    )?;
                    Some(path)
                }
                None => None,
            };
            stages.push(StageRecord {
                stage: Stage::Profile,
                duration: timer.lap(),
                artifact: art,
                resumed: false,
            });
        }

        // ---- stage: partition -------------------------------------------
        let parts: Vec<LayerPartition> = match parts_res {
            Some(p) => p,
            None => {
                let mut timer = Timer::start();
                let aux_ref: &[Vec<ActivationProfile>] = aux.as_deref().unwrap_or(&[]);
                let mut v = Vec::with_capacity(n_layers);
                for l in 0..n_layers {
                    let ctx = StageCtx {
                        layer: l,
                        profile: profiles.as_ref().map(|ps| &ps[l]),
                        aux_profiles: aux_ref.iter().map(|dom| &dom[l]).collect(),
                        calib_inputs: None,
                    };
                    let part = self
                        .method
                        .partitioner
                        .partition(model.dense_ffn(l), &self.spec, &ctx)
                        .with_context(|| {
                            format!("method '{}': partition layer {l}", self.method.name)
                        })?;
                    v.push(part);
                }
                let art = match self.stage_path("partition.json")? {
                    Some(path) => {
                        artifact::save_partition(&path, &self.method.name, &v)?;
                        Some(path)
                    }
                    None => None,
                };
                stages.push(StageRecord {
                    stage: Stage::Partition,
                    duration: timer.lap(),
                    artifact: art,
                    resumed: false,
                });
                v
            }
        };

        // ---- stage: router ----------------------------------------------
        let builds: Vec<RouterBuild> = match builds_res {
            Some(b) => b,
            None => {
                let mut timer = Timer::start();
                let calib_inputs: Option<Vec<Tensor>> = if self.method.needs_calib_inputs {
                    let toks = self.calib.calib_tokens();
                    let take = self.calib.seq.min(toks.len());
                    Some(DenseForward::new(model).capture_ffn_inputs(&toks[..take]))
                } else {
                    None
                };
                let mut v = Vec::with_capacity(n_layers);
                for l in 0..n_layers {
                    let ctx = StageCtx {
                        layer: l,
                        profile: profiles.as_ref().map(|ps| &ps[l]),
                        aux_profiles: Vec::new(),
                        calib_inputs: calib_inputs.as_ref().map(|c| &c[l]),
                    };
                    let b = self
                        .method
                        .router
                        .build(model.dense_ffn(l), &parts[l], &ctx)
                        .with_context(|| {
                            format!("method '{}': router layer {l}", self.method.name)
                        })?;
                    v.push(b);
                }
                let art = match self.stage_path("router.cmw")? {
                    Some(path) => {
                        artifact::save_routers(&path, &self.method.name, &parts, &v)?;
                        Some(path)
                    }
                    None => None,
                };
                stages.push(StageRecord {
                    stage: Stage::Router,
                    duration: timer.lap(),
                    artifact: art,
                    resumed: false,
                });
                v
            }
        };

        // ---- stage: assemble --------------------------------------------
        let mut timer = Timer::start();
        let mut out = model.clone();
        for (l, build) in builds.into_iter().enumerate() {
            let ffn = model.dense_ffn(l);
            parts[l].validate(ffn.hidden_dim()).with_context(|| {
                format!("method '{}': invalid partition for layer {l}", self.method.name)
            })?;
            out.layers[l].ffn = LayerFfn::Moe(converter::assemble_moe_layer(ffn, &parts[l], build));
        }
        stages.push(StageRecord {
            stage: Stage::Assemble,
            duration: timer.lap(),
            artifact: None,
            resumed: false,
        });

        // ---- stage: finetune --------------------------------------------
        if self.finetune_samples > 0 {
            let mut timer = Timer::start();
            let tokens = self
                .calib
                .tokens_of(self.finetune_samples.max(self.calib.examples * self.calib.seq));
            finetune::finetune_model(&mut out, model, &tokens, self.finetune_samples, self.calib.seq)?;
            stages.push(StageRecord {
                stage: Stage::Finetune,
                duration: timer.lap(),
                artifact: None,
                resumed: false,
            });
        }

        Ok(PipelineRun { model: out, stages })
    }

    /// [`run`](Pipeline::run) plus the save stage: persist the converted
    /// model to `out_path`.
    pub fn run_and_save(&self, model: &ModelWeights, out_path: impl AsRef<Path>) -> Result<PipelineRun> {
        let mut run = self.run(model)?;
        let mut timer = Timer::start();
        run.model.save(out_path.as_ref())?;
        run.stages.push(StageRecord {
            stage: Stage::Save,
            duration: timer.lap(),
            artifact: Some(out_path.as_ref().to_path_buf()),
            resumed: false,
        });
        Ok(run)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn tiny_model() -> ModelWeights {
        let cfg = crate::model::model_config("tiny").unwrap();
        let mut rng = Rng::new(77);
        ModelWeights::random(&cfg, &mut rng)
    }

    fn fast_calib() -> CalibrationSpec {
        CalibrationSpec { examples: 1, seq: 48, k_a: 8, ..Default::default() }
    }

    #[test]
    fn cmoe_pipeline_converts_every_layer() {
        let model = tiny_model();
        let run = Pipeline::for_method("cmoe")
            .unwrap()
            .spec("S2A2E8".parse().unwrap())
            .calib(fast_calib())
            .run(&model)
            .unwrap();
        assert!(run.model.layers.iter().all(|l| matches!(l.ffn, LayerFfn::Moe(_))));
        // profile, partition, router, assemble — no finetune requested
        assert!(run.stage(Stage::Profile).is_some());
        assert!(run.stage(Stage::Partition).is_some());
        assert!(run.stage(Stage::Router).is_some());
        assert!(run.stage(Stage::Assemble).is_some());
        assert!(run.stage(Stage::Finetune).is_none());
    }

    #[test]
    fn profiles_override_skips_profiling_stage() {
        let model = tiny_model();
        let profiles = fast_calib().profiles(&model);
        let run = Pipeline::for_method("cmoe")
            .unwrap()
            .spec("S2A2E8".parse().unwrap())
            .calib(fast_calib())
            .with_profiles(profiles)
            .run(&model)
            .unwrap();
        assert!(run.stage(Stage::Profile).is_none(), "override must skip the profile stage");
    }

    #[test]
    fn methods_that_need_no_profile_never_profile() {
        let model = tiny_model();
        let run = Pipeline::for_method("llama-moe")
            .unwrap()
            .calib(fast_calib())
            .run(&model)
            .unwrap();
        assert!(run.stage(Stage::Profile).is_none(), "random split must not pay for profiling");
    }

    #[test]
    fn finetune_stage_moves_gate_scales() {
        let model = tiny_model();
        let run = Pipeline::for_method("cmoe")
            .unwrap()
            .spec("S2A2E8".parse().unwrap())
            .calib(fast_calib())
            .finetune(64)
            .run(&model)
            .unwrap();
        assert!(run.stage(Stage::Finetune).is_some());
        let moved = run.model.layers.iter().any(|l| match &l.ffn {
            LayerFfn::Moe(m) => m.gate_scale.iter().any(|&u| u != 0.0),
            _ => false,
        });
        assert!(moved, "fine-tuning was a no-op");
    }

    #[test]
    fn converting_a_converted_model_fails() {
        let model = tiny_model();
        let pipe = Pipeline::for_method("cmoe").unwrap().spec("S2A2E8".parse().unwrap()).calib(fast_calib());
        let run = pipe.run(&model).unwrap();
        assert!(pipe.run(&run.model).is_err());
    }

    #[test]
    fn mismatched_resume_method_rejected() {
        let model = tiny_model();
        let dir = std::env::temp_dir().join("cmoe_pipeline_mismatch");
        let run = Pipeline::for_method("llama-moe")
            .unwrap()
            .calib(fast_calib())
            .save_stages(&dir)
            .run(&model)
            .unwrap();
        let art = run.stage(Stage::Partition).unwrap().artifact.clone().unwrap();
        let err = Pipeline::for_method("emoe")
            .unwrap()
            .calib(fast_calib())
            .resume_from(&art)
            .run(&model);
        assert!(err.is_err(), "partition artifacts must not cross method families");
    }
}
