//! Serializable stage artifacts: every pipeline stage boundary can be
//! written to disk and resumed from, so the expensive profiling pass is
//! paid once per sweep and a killed conversion restarts mid-way
//! (`cmoe convert --resume-from <artifact>`).
//!
//! Three artifact kinds, reusing the repo's existing codecs:
//!
//! | Stage | File | Codec |
//! |---|---|---|
//! | profile | `profile.json` | JSON (`kind: "profile"`); ATopK bits as a `'0'`/`'1'` string; includes aux-domain profiles when the method uses them |
//! | partition | `partition.json` | JSON (`kind: "partition"`) with spec + neuron lists |
//! | router | `router.cmw` | `.cmw` tensors (router weights, representatives, compensation) with the partition JSON embedded as meta |
//!
//! All float payloads round-trip exactly: f32 → JSON f64 → f32 is
//! lossless, and `.cmw` stores raw little-endian f32.

use crate::converter::{LayerPartition, RouterBuild};
use crate::model::{read_cmw, write_cmw, MoeSpec, Router, RouterWeights};
use crate::profiling::ActivationProfile;
use crate::tensor::Tensor;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// A loaded stage artifact, dispatched on by the pipeline's resume
/// logic. Later stages subsume earlier ones: a router artifact carries
/// its partition, so resuming from it skips profiling entirely.
pub enum StageArtifact {
    /// Primary-domain profiles plus any auxiliary calibration domains'
    /// profiles (Read-ME), so a profile resume skips ALL profiling.
    Profiles { layers: Vec<ActivationProfile>, aux: Vec<Vec<ActivationProfile>> },
    Partition { method: String, layers: Vec<LayerPartition> },
    Routers { method: String, layers: Vec<LayerPartition>, builds: Vec<RouterBuild> },
}

/// Load any pipeline artifact, detecting its kind (`.cmw` extension ⇒
/// router; otherwise the JSON `kind` field).
pub fn load_stage(path: &Path) -> Result<StageArtifact> {
    if path.extension().and_then(|e| e.to_str()) == Some("cmw") {
        let (method, layers, builds) = load_routers(path)?;
        return Ok(StageArtifact::Routers { method, layers, builds });
    }
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("read artifact {}", path.display()))?;
    let j = Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
    match j.get("kind").as_str() {
        Some("profile") => {
            let (layers, aux) = profiles_from_json(&j)?;
            Ok(StageArtifact::Profiles { layers, aux })
        }
        Some("partition") => {
            let (method, layers) = partition_from_json(&j)?;
            Ok(StageArtifact::Partition { method, layers })
        }
        other => bail!(
            "{}: not a pipeline artifact (kind = {:?}; expected \"profile\", \"partition\" or a .cmw router)",
            path.display(),
            other
        ),
    }
}

// ---------------------------------------------------------------------------
// profile.json
// ---------------------------------------------------------------------------

/// Write per-layer activation profiles: the primary calibration
/// domain's, plus any auxiliary domains' (one list of layers each).
pub fn save_profiles(
    path: &Path,
    profiles: &[ActivationProfile],
    aux: &[Vec<ActivationProfile>],
) -> Result<()> {
    let mut root = Json::obj();
    root.set("kind", "profile");
    root.set("layers", Json::Arr(profiles.iter().map(profile_to_json).collect()));
    root.set(
        "aux",
        Json::Arr(
            aux.iter()
                .map(|dom| Json::Arr(dom.iter().map(profile_to_json).collect()))
                .collect(),
        ),
    );
    std::fs::write(path, root.pretty())
        .with_context(|| format!("write {}", path.display()))?;
    Ok(())
}

/// Read profiles back (exact inverse of [`save_profiles`]):
/// `(primary layers, aux domains)`.
pub fn load_profiles(path: &Path) -> Result<(Vec<ActivationProfile>, Vec<Vec<ActivationProfile>>)> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("read {}", path.display()))?;
    let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
    if j.get("kind").as_str() != Some("profile") {
        bail!("{}: not a profile artifact", path.display());
    }
    profiles_from_json(&j)
}

fn profile_to_json(p: &ActivationProfile) -> Json {
    let mut o = Json::obj();
    o.set("d_h", p.d_h).set("q", p.q).set("k_a", p.k_a);
    let bits: String = p.a.iter().map(|&b| if b != 0 { '1' } else { '0' }).collect();
    o.set("a", bits);
    o.set("mean_abs_h", Json::Arr(p.mean_abs_h.iter().map(|&v| Json::from(v)).collect()));
    o.set("h_sample", Json::Arr(p.h_sample.iter().map(|&v| Json::from(v)).collect()));
    o
}

fn profile_from_json(e: &Json, l: usize) -> Result<ActivationProfile> {
    let d_h = e.get("d_h").as_usize().with_context(|| format!("layer {l}: d_h"))?;
    let q = e.get("q").as_usize().with_context(|| format!("layer {l}: q"))?;
    let k_a = e.get("k_a").as_usize().with_context(|| format!("layer {l}: k_a"))?;
    let bits = e.get("a").as_str().with_context(|| format!("layer {l}: a"))?;
    if bits.len() != q * d_h {
        bail!("layer {l}: activation matrix holds {} bits, expected {}", bits.len(), q * d_h);
    }
    let a: Vec<u8> = bits
        .bytes()
        .map(|c| match c {
            b'0' => Ok(0u8),
            b'1' => Ok(1u8),
            other => Err(anyhow::anyhow!("layer {l}: bad activation bit {:?}", other as char)),
        })
        .collect::<Result<_>>()?;
    let mean_abs_h =
        f32_arr(e.get("mean_abs_h"), d_h).with_context(|| format!("layer {l}: mean_abs_h"))?;
    let h_sample = match e.get("h_sample") {
        Json::Arr(v) => v
            .iter()
            .map(|x| x.as_f64().map(|f| f as f32).context("h_sample value"))
            .collect::<Result<Vec<f32>>>()?,
        _ => bail!("layer {l}: h_sample"),
    };
    Ok(ActivationProfile { d_h, q, k_a, a, mean_abs_h, h_sample })
}

fn profiles_from_json(j: &Json) -> Result<(Vec<ActivationProfile>, Vec<Vec<ActivationProfile>>)> {
    let layers = j.get("layers").as_arr().context("profile artifact: layers")?;
    let primary = layers
        .iter()
        .enumerate()
        .map(|(l, e)| profile_from_json(e, l))
        .collect::<Result<Vec<_>>>()?;
    let mut aux = Vec::new();
    if let Json::Arr(doms) = j.get("aux") {
        for dom in doms {
            let dl = dom.as_arr().context("profile artifact: aux domain")?;
            aux.push(
                dl.iter()
                    .enumerate()
                    .map(|(l, e)| profile_from_json(e, l))
                    .collect::<Result<Vec<_>>>()?,
            );
        }
    }
    Ok((primary, aux))
}

fn f32_arr(j: &Json, expect_len: usize) -> Result<Vec<f32>> {
    let arr = j.as_arr().context("expected array")?;
    if arr.len() != expect_len {
        bail!("array length {} != {expect_len}", arr.len());
    }
    arr.iter().map(|v| v.as_f64().map(|f| f as f32).context("non-number")).collect()
}

// ---------------------------------------------------------------------------
// partition.json
// ---------------------------------------------------------------------------

/// Write the per-layer partition of `method`.
pub fn save_partition(path: &Path, method: &str, parts: &[LayerPartition]) -> Result<()> {
    std::fs::write(path, partition_to_json(method, parts).pretty())
        .with_context(|| format!("write {}", path.display()))?;
    Ok(())
}

/// Read a partition artifact back.
pub fn load_partition(path: &Path) -> Result<(String, Vec<LayerPartition>)> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("read {}", path.display()))?;
    let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
    partition_from_json(&j)
}

fn partition_to_json(method: &str, parts: &[LayerPartition]) -> Json {
    let mut layers = Vec::with_capacity(parts.len());
    for p in parts {
        let mut o = Json::obj();
        o.set("spec", p.spec.to_string());
        o.set("shared", idx_json(&p.shared_neurons));
        o.set(
            "experts",
            Json::Arr(p.expert_neurons.iter().map(|mem| idx_json(mem)).collect()),
        );
        match &p.representatives {
            Some(r) => o.set("representatives", idx_json(r)),
            None => o.set("representatives", Json::Null),
        };
        layers.push(o);
    }
    let mut root = Json::obj();
    root.set("kind", "partition").set("method", method).set("layers", Json::Arr(layers));
    root
}

fn partition_from_json(j: &Json) -> Result<(String, Vec<LayerPartition>)> {
    if j.get("kind").as_str() != Some("partition") {
        bail!("not a partition artifact");
    }
    let method = j.get("method").as_str().context("partition artifact: method")?.to_string();
    let layers = j.get("layers").as_arr().context("partition artifact: layers")?;
    let mut out = Vec::with_capacity(layers.len());
    for (l, e) in layers.iter().enumerate() {
        let spec: MoeSpec = e
            .get("spec")
            .as_str()
            .with_context(|| format!("layer {l}: spec"))?
            .parse()?;
        let shared_neurons = idx_from_json(e.get("shared")).with_context(|| format!("layer {l}: shared"))?;
        let expert_neurons = e
            .get("experts")
            .as_arr()
            .with_context(|| format!("layer {l}: experts"))?
            .iter()
            .map(idx_from_json)
            .collect::<Result<Vec<_>>>()?;
        let representatives = match e.get("representatives") {
            Json::Null => None,
            other => Some(idx_from_json(other).with_context(|| format!("layer {l}: representatives"))?),
        };
        out.push(LayerPartition { spec, shared_neurons, expert_neurons, representatives });
    }
    Ok((method, out))
}

fn idx_json(idx: &[usize]) -> Json {
    Json::Arr(idx.iter().map(|&i| Json::from(i)).collect())
}

fn idx_from_json(j: &Json) -> Result<Vec<usize>> {
    j.as_arr()
        .context("expected index array")?
        .iter()
        .map(|v| v.as_usize().context("non-integer index"))
        .collect()
}

// ---------------------------------------------------------------------------
// router.cmw
// ---------------------------------------------------------------------------

fn idx_tensor(v: &[usize]) -> Tensor {
    Tensor::from_vec(v.iter().map(|&i| i as f32).collect(), &[v.len()])
}

fn tensor_idx(t: &Tensor) -> Vec<usize> {
    t.data.iter().map(|&f| f as usize).collect()
}

/// Write routers (+ the partition they were built for, as meta) to a
/// `.cmw` file — the deepest resume point before assembly.
pub fn save_routers(
    path: &Path,
    method: &str,
    parts: &[LayerPartition],
    builds: &[RouterBuild],
) -> Result<()> {
    assert_eq!(parts.len(), builds.len(), "one router per partitioned layer");
    let mut tensors: BTreeMap<String, Tensor> = BTreeMap::new();
    for (l, b) in builds.iter().enumerate() {
        let p = format!("layers.{l}");
        match &b.router {
            Router::Analytical(r) => {
                tensors.insert(format!("{p}.router.w_gate_r"), r.w_gate_r.clone());
                tensors.insert(format!("{p}.router.w_up_r"), r.w_up_r.clone());
            }
            Router::Linear(w) => {
                tensors.insert(format!("{p}.router.linear"), w.clone());
            }
        }
        tensors.insert(format!("{p}.representatives"), idx_tensor(&b.representatives));
        if let Some(comp) = &b.compensation {
            for (e, c) in comp.iter().enumerate() {
                tensors.insert(
                    format!("{p}.compensation.{e}"),
                    Tensor::from_vec(c.clone(), &[c.len()]),
                );
            }
        }
    }
    let mut config = Json::obj();
    config.set("kind", "router").set("method", method).set("layers", parts.len());
    let meta = partition_to_json(method, parts);
    write_cmw(path, &config, &meta, &tensors)
}

/// Read a router artifact back: (method, partition, router builds).
pub fn load_routers(path: &Path) -> Result<(String, Vec<LayerPartition>, Vec<RouterBuild>)> {
    let file = read_cmw(path)?;
    if file.config.get("kind").as_str() != Some("router") {
        bail!("{}: not a router artifact", path.display());
    }
    let (method, parts) = partition_from_json(&file.meta)
        .with_context(|| format!("{}: embedded partition", path.display()))?;
    let t = &file.tensors;
    let get = |name: &str| -> Result<Tensor> {
        t.get(name).cloned().ok_or_else(|| anyhow::anyhow!("missing tensor {name}"))
    };
    let mut builds = Vec::with_capacity(parts.len());
    for (l, part) in parts.iter().enumerate() {
        let p = format!("layers.{l}");
        let router = if t.contains_key(&format!("{p}.router.linear")) {
            Router::Linear(get(&format!("{p}.router.linear"))?)
        } else {
            Router::Analytical(RouterWeights {
                w_gate_r: get(&format!("{p}.router.w_gate_r"))?,
                w_up_r: get(&format!("{p}.router.w_up_r"))?,
            })
        };
        let representatives = tensor_idx(&get(&format!("{p}.representatives"))?);
        let compensation = if t.contains_key(&format!("{p}.compensation.0")) {
            Some(
                (0..part.spec.routed())
                    .map(|e| get(&format!("{p}.compensation.{e}")).map(|t| t.data))
                    .collect::<Result<Vec<_>>>()?,
            )
        } else {
            None
        };
        builds.push(RouterBuild { router, representatives, compensation });
    }
    Ok((method, parts, builds))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("cmoe_pipeline_artifact");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn sample_parts() -> Vec<LayerPartition> {
        vec![
            LayerPartition {
                spec: "S1A2E4".parse().unwrap(),
                shared_neurons: vec![3, 0],
                expert_neurons: vec![vec![1, 2], vec![4, 5], vec![6, 7]],
                representatives: Some(vec![2, 4, 7]),
            },
            LayerPartition {
                spec: "S0A2E4".parse().unwrap(),
                shared_neurons: vec![],
                expert_neurons: vec![vec![0, 1], vec![2, 3], vec![4, 5], vec![6, 7]],
                representatives: None,
            },
        ]
    }

    #[test]
    fn profile_artifact_roundtrips_exactly() {
        let mut rng = Rng::new(41);
        let h = Tensor::randn(&mut rng, &[30, 16], 1.0);
        let ha = Tensor::randn(&mut rng, &[20, 16], 1.0);
        let profiles =
            vec![ActivationProfile::from_hidden(&h, 4), ActivationProfile::from_hidden(&h, 7)];
        let aux = vec![vec![
            ActivationProfile::from_hidden(&ha, 4),
            ActivationProfile::from_hidden(&ha, 7),
        ]];
        let path = tmp("p.profile.json");
        save_profiles(&path, &profiles, &aux).unwrap();
        let (back, back_aux) = load_profiles(&path).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back_aux.len(), 1);
        for (a, b) in profiles.iter().zip(&back).chain(aux[0].iter().zip(&back_aux[0])) {
            assert_eq!(a.d_h, b.d_h);
            assert_eq!(a.q, b.q);
            assert_eq!(a.k_a, b.k_a);
            assert_eq!(a.a, b.a);
            assert_eq!(a.mean_abs_h, b.mean_abs_h, "f32 roundtrip must be exact");
            assert_eq!(a.h_sample, b.h_sample);
        }
    }

    #[test]
    fn partition_artifact_roundtrips() {
        let parts = sample_parts();
        let path = tmp("p.partition.json");
        save_partition(&path, "cmoe", &parts).unwrap();
        let (method, back) = load_partition(&path).unwrap();
        assert_eq!(method, "cmoe");
        assert_eq!(back, parts);
    }

    #[test]
    fn router_artifact_roundtrips_all_router_kinds() {
        let mut rng = Rng::new(42);
        let parts = sample_parts();
        let builds = vec![
            RouterBuild {
                router: Router::Analytical(RouterWeights {
                    w_gate_r: Tensor::randn(&mut rng, &[4, 3], 1.0),
                    w_up_r: Tensor::randn(&mut rng, &[4, 3], 1.0),
                }),
                representatives: vec![2, 4, 7],
                compensation: None,
            },
            RouterBuild {
                router: Router::Linear(Tensor::randn(&mut rng, &[4, 4], 1.0)),
                representatives: vec![],
                compensation: Some(vec![vec![0.5, -0.25, 0.0, 1.0]; 4]),
            },
        ];
        let path = tmp("p.router.cmw");
        save_routers(&path, "gmoefication", &parts, &builds).unwrap();
        let (method, bparts, bbuilds) = load_routers(&path).unwrap();
        assert_eq!(method, "gmoefication");
        assert_eq!(bparts, parts);
        for (a, b) in builds.iter().zip(&bbuilds) {
            assert_eq!(a.representatives, b.representatives);
            assert_eq!(a.compensation, b.compensation);
            match (&a.router, &b.router) {
                (Router::Analytical(x), Router::Analytical(y)) => {
                    assert_eq!(x.w_gate_r, y.w_gate_r);
                    assert_eq!(x.w_up_r, y.w_up_r);
                }
                (Router::Linear(x), Router::Linear(y)) => assert_eq!(x, y),
                _ => panic!("router kind changed in roundtrip"),
            }
        }
    }

    #[test]
    fn load_stage_dispatches_on_kind() {
        let parts = sample_parts();
        let ppath = tmp("s.partition.json");
        save_partition(&ppath, "emoe", &parts).unwrap();
        match load_stage(&ppath).unwrap() {
            StageArtifact::Partition { method, layers } => {
                assert_eq!(method, "emoe");
                assert_eq!(layers, parts);
            }
            _ => panic!("wrong artifact kind"),
        }
        let bad = tmp("s.garbage.json");
        std::fs::write(&bad, "{\"kind\": \"nope\"}").unwrap();
        assert!(load_stage(&bad).is_err());
    }
}
