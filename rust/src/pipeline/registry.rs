//! The method registry: every dense→MoE conversion method the repo
//! implements, addressable by name (`cmoe convert --method <name>`,
//! `cmoe methods`), plus the Table 5 hybrids `<base>+cmoe-router`
//! (any baseline's partition driven by CMoE's analytical router).
//!
//! A method is a [`Partitioner`] + [`RouterBuilder`] pair with the
//! flags the [`super::Pipeline`] needs to plan its stages. Adding a
//! method is: implement the two traits (usually thin adapters, see
//! [`super::methods`]), add a `Method` row here — the CLI listing,
//! the bench-harness sweeps and the registry parity test suite pick it
//! up automatically.

use crate::baselines::router_train::RouterTrainConfig;
use crate::model::MoeSpec;
use crate::pipeline::methods::{
    AnalyticalRouterBuilder, CmoePartitioner, DomainPartitioner, GlobalPrototypeRouterBuilder,
    KeyKmeansPartitioner, RandomPartitioner, TrainedLinearRouterBuilder,
    WeightKmeansPartitioner,
};
use crate::pipeline::{Partitioner, RouterBuilder};
use anyhow::{bail, Result};

/// Suffix that swaps any base method's router for CMoE's analytical
/// one (the Table 5 "+ ours" rows).
pub const CMOE_ROUTER_SUFFIX: &str = "+cmoe-router";

/// Base method names, in paper order.
pub const BASE_METHODS: &[&str] =
    &["cmoe", "moefication", "gmoefication", "llama-moe", "emoe", "readme"];

/// A registered conversion method: the two stage implementations plus
/// what the pipeline must prepare for them.
pub struct Method {
    pub name: String,
    /// Human description of the expert grouping (for `cmoe methods`).
    pub grouping: &'static str,
    /// Human description of the router.
    pub routing: &'static str,
    /// Spec used when the caller doesn't pass `--spec`. Baselines
    /// default to 6-of-8 active (S0A6E8) to match CMoE's 25% sparsity
    /// FLOP budget (Table 1).
    pub default_spec: MoeSpec,
    /// Router stage needs captured FFN inputs (router training /
    /// compensation / global prototypes).
    pub needs_calib_inputs: bool,
    /// Partition stage needs profiles of a second calibration domain.
    pub needs_aux_domain: bool,
    pub partitioner: Box<dyn Partitioner>,
    pub router: Box<dyn RouterBuilder>,
}

/// Strip the hybrid suffix: the partition-producing base method name.
pub fn base_name(name: &str) -> &str {
    name.strip_suffix(CMOE_ROUTER_SUFFIX).unwrap_or(name)
}

/// All registered method names: bases first, then hybrids.
pub fn names() -> Vec<String> {
    let mut v: Vec<String> = BASE_METHODS.iter().map(|s| s.to_string()).collect();
    for b in BASE_METHODS {
        if *b != "cmoe" {
            v.push(format!("{b}{CMOE_ROUTER_SUFFIX}"));
        }
    }
    v
}

fn baseline_spec() -> MoeSpec {
    MoeSpec::new(0, 6, 8).expect("S0A6E8 is valid")
}

/// Look up a method by name. Unknown names error with the available
/// set; `<base>+cmoe-router` resolves the base and swaps its router.
pub fn get(name: &str) -> Result<Method> {
    if let Some(base) = name.strip_suffix(CMOE_ROUTER_SUFFIX) {
        if base == "cmoe" {
            bail!("'cmoe' already uses the analytical router; drop the {CMOE_ROUTER_SUFFIX} suffix");
        }
        let mut m = get(base)?;
        // keep G-MoEfication's compensation when only the router is swapped
        let keep_compensation = base == "gmoefication";
        m.router = Box::new(AnalyticalRouterBuilder { compensation: keep_compensation });
        m.routing = "Analytical (Eq. 25/8)";
        m.needs_calib_inputs = keep_compensation;
        m.name = format!("{base}{CMOE_ROUTER_SUFFIX}");
        return Ok(m);
    }
    let m = match name {
        "cmoe" => Method {
            name: "cmoe".into(),
            grouping: "Activation-pattern balanced k-means + shared experts (§4)",
            routing: "Analytical representative neurons (Eq. 8)",
            default_spec: MoeSpec::new(3, 3, 8).expect("S3A3E8 is valid"),
            needs_calib_inputs: false,
            needs_aux_domain: false,
            partitioner: Box::new(CmoePartitioner::default()),
            router: Box::new(AnalyticalRouterBuilder { compensation: false }),
        },
        "moefication" => Method {
            name: "moefication".into(),
            grouping: "K-means on gate-weight columns",
            routing: "Trained linear",
            default_spec: baseline_spec(),
            needs_calib_inputs: true,
            needs_aux_domain: false,
            partitioner: Box::new(WeightKmeansPartitioner { iters: 30, seed: 0x30EF }),
            router: Box::new(TrainedLinearRouterBuilder {
                cfg: RouterTrainConfig::default(),
                compensation: false,
            }),
        },
        "gmoefication" => Method {
            name: "gmoefication".into(),
            grouping: "K-means on gate-weight columns",
            routing: "Trained linear + mean-output compensation",
            default_spec: baseline_spec(),
            needs_calib_inputs: true,
            needs_aux_domain: false,
            partitioner: Box::new(WeightKmeansPartitioner { iters: 30, seed: 0x30EF }),
            router: Box::new(TrainedLinearRouterBuilder {
                cfg: RouterTrainConfig::default(),
                compensation: true,
            }),
        },
        "llama-moe" => Method {
            name: "llama-moe".into(),
            grouping: "Uniform random split",
            routing: "Trained linear",
            default_spec: baseline_spec(),
            needs_calib_inputs: true,
            needs_aux_domain: false,
            partitioner: Box::new(RandomPartitioner { seed: 0x11A }),
            router: Box::new(TrainedLinearRouterBuilder {
                cfg: RouterTrainConfig::default(),
                compensation: false,
            }),
        },
        "emoe" => Method {
            name: "emoe".into(),
            grouping: "K-means on up-projection key vectors",
            routing: "Trained linear",
            default_spec: baseline_spec(),
            needs_calib_inputs: true,
            needs_aux_domain: false,
            partitioner: Box::new(KeyKmeansPartitioner { iters: 30, seed: 0xE40E }),
            router: Box::new(TrainedLinearRouterBuilder {
                cfg: RouterTrainConfig::default(),
                compensation: false,
            }),
        },
        "readme" => Method {
            name: "readme".into(),
            grouping: "Domain-aware grouping (two calibration domains)",
            routing: "Global domain-prototype (sequence-level)",
            default_spec: baseline_spec(),
            needs_calib_inputs: true,
            needs_aux_domain: true,
            partitioner: Box::new(DomainPartitioner),
            router: Box::new(GlobalPrototypeRouterBuilder),
        },
        other => bail!(
            "unknown method '{other}' — available: {}; hybrids: <base>{CMOE_ROUTER_SUFFIX}",
            BASE_METHODS.join(", ")
        ),
    };
    Ok(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_listed_name_resolves() {
        for name in names() {
            let m = get(&name).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(m.name, name);
            assert_eq!(m.default_spec.sparsity(), 0.25, "{name}: default spec is not 25% sparse");
        }
    }

    #[test]
    fn hybrid_swaps_router_and_keeps_base_partitioner() {
        let m = get("moefication+cmoe-router").unwrap();
        assert_eq!(m.routing, "Analytical (Eq. 25/8)");
        assert!(!m.needs_calib_inputs, "analytical hybrid needs no router training data");
        let g = get("gmoefication+cmoe-router").unwrap();
        assert!(g.needs_calib_inputs, "compensation still needs calibration inputs");
    }

    #[test]
    fn bogus_names_rejected() {
        assert!(get("dot-moe").is_err());
        assert!(get("cmoe+cmoe-router").is_err());
        assert!(get("nope+cmoe-router").is_err());
    }

    #[test]
    fn base_name_strips_suffix() {
        assert_eq!(base_name("emoe+cmoe-router"), "emoe");
        assert_eq!(base_name("cmoe"), "cmoe");
    }
}
