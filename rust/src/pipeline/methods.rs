//! The [`Partitioner`] / [`RouterBuilder`] implementations behind the
//! method registry — each one a thin adapter over the corresponding
//! math in [`crate::converter`] and [`crate::baselines`], so a method
//! plugin is ~the size of its options struct.

use crate::baselines;
use crate::converter::{self, ConvertOptions, LayerPartition, RouterBuild};
use crate::model::{FfnWeights, MoeSpec, Router};
use crate::pipeline::{Partitioner, RouterBuilder, StageCtx};
use crate::tensor::Tensor;
use anyhow::{bail, Result};

fn ensure_no_shared(spec: &MoeSpec, what: &str) -> Result<()> {
    if spec.shared != 0 {
        bail!("{what} has no shared experts — use an S0 spec (got {spec})");
    }
    Ok(())
}

fn ensure_divides(d_h: usize, spec: &MoeSpec, what: &str) -> Result<()> {
    if d_h % spec.total != 0 {
        bail!("{what}: expert count {} does not divide d_ff {d_h}", spec.total);
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Partitioners
// ---------------------------------------------------------------------------

/// CMoE (§4): shared-expert selection + balanced activation clustering.
/// Picks representatives off the clustering state, so its analytical
/// router needs no further profile access.
#[derive(Clone, Debug, Default)]
pub struct CmoePartitioner {
    pub opts: ConvertOptions,
}

impl Partitioner for CmoePartitioner {
    fn needs_profile(&self) -> bool {
        true
    }
    fn provides_representatives(&self) -> bool {
        true
    }
    fn partition(&self, ffn: &FfnWeights, spec: &MoeSpec, ctx: &StageCtx) -> Result<LayerPartition> {
        let profile = ctx.profile()?;
        if profile.d_h != ffn.hidden_dim() {
            bail!("profile d_h {} != ffn d_h {}", profile.d_h, ffn.hidden_dim());
        }
        let (part, _timings) = converter::cmoe_layer_partition(profile, spec, &self.opts)?;
        Ok(part)
    }
}

/// MoEfication / G-MoEfication: k-means over gate-weight columns.
#[derive(Clone, Debug)]
pub struct WeightKmeansPartitioner {
    pub iters: usize,
    pub seed: u64,
}

impl Partitioner for WeightKmeansPartitioner {
    fn needs_profile(&self) -> bool {
        false
    }
    fn partition(&self, ffn: &FfnWeights, spec: &MoeSpec, _ctx: &StageCtx) -> Result<LayerPartition> {
        ensure_no_shared(spec, "moefication")?;
        ensure_divides(ffn.hidden_dim(), spec, "moefication")?;
        let expert_neurons =
            baselines::moefication::weight_kmeans_partition(ffn, spec.total, self.iters, self.seed);
        Ok(LayerPartition {
            spec: *spec,
            shared_neurons: Vec::new(),
            expert_neurons,
            representatives: None,
        })
    }
}

/// LLaMA-MoE: uniform random split.
#[derive(Clone, Debug)]
pub struct RandomPartitioner {
    pub seed: u64,
}

impl Partitioner for RandomPartitioner {
    fn needs_profile(&self) -> bool {
        false
    }
    fn partition(&self, ffn: &FfnWeights, spec: &MoeSpec, _ctx: &StageCtx) -> Result<LayerPartition> {
        ensure_no_shared(spec, "llama-moe")?;
        ensure_divides(ffn.hidden_dim(), spec, "llama-moe")?;
        let expert_neurons =
            baselines::llama_moe::random_partition(ffn.hidden_dim(), spec.total, self.seed);
        Ok(LayerPartition {
            spec: *spec,
            shared_neurons: Vec::new(),
            expert_neurons,
            representatives: None,
        })
    }
}

/// EMoE: k-means over up-projection key vectors.
#[derive(Clone, Debug)]
pub struct KeyKmeansPartitioner {
    pub iters: usize,
    pub seed: u64,
}

impl Partitioner for KeyKmeansPartitioner {
    fn needs_profile(&self) -> bool {
        false
    }
    fn partition(&self, ffn: &FfnWeights, spec: &MoeSpec, _ctx: &StageCtx) -> Result<LayerPartition> {
        ensure_no_shared(spec, "emoe")?;
        ensure_divides(ffn.hidden_dim(), spec, "emoe")?;
        let expert_neurons =
            baselines::emoe::key_kmeans_partition(ffn, spec.total, self.iters, self.seed);
        Ok(LayerPartition {
            spec: *spec,
            shared_neurons: Vec::new(),
            expert_neurons,
            representatives: None,
        })
    }
}

/// Read-ME: domain-aware grouping over the primary + auxiliary
/// calibration domains' activation profiles.
#[derive(Clone, Debug, Default)]
pub struct DomainPartitioner;

impl Partitioner for DomainPartitioner {
    fn needs_profile(&self) -> bool {
        true
    }
    fn partition(&self, ffn: &FfnWeights, spec: &MoeSpec, ctx: &StageCtx) -> Result<LayerPartition> {
        ensure_no_shared(spec, "readme")?;
        ensure_divides(ffn.hidden_dim(), spec, "readme")?;
        let primary = ctx.profile()?;
        if ctx.aux_profiles.is_empty() {
            bail!("readme needs profiles from at least two calibration domains");
        }
        let mut profs: Vec<&crate::profiling::ActivationProfile> = vec![primary];
        profs.extend(ctx.aux_profiles.iter().copied());
        let expert_neurons = baselines::readme_like::domain_partition(&profs, spec.total);
        Ok(LayerPartition {
            spec: *spec,
            shared_neurons: Vec::new(),
            expert_neurons,
            representatives: None,
        })
    }
}

// ---------------------------------------------------------------------------
// Router builders
// ---------------------------------------------------------------------------

/// CMoE's analytical representative-neuron router (Eq. 25/8). Reuses
/// the partitioner's representatives when present; otherwise runs the
/// shared Eq. 25 search — which is exactly what the Table 5
/// `<base>+cmoe-router` hybrids do. `compensation` keeps
/// G-MoEfication's mean-output repair when hybridizing it.
#[derive(Clone, Debug)]
pub struct AnalyticalRouterBuilder {
    pub compensation: bool,
}

impl RouterBuilder for AnalyticalRouterBuilder {
    fn wants_profile(&self) -> bool {
        true
    }
    fn build(&self, ffn: &FfnWeights, part: &LayerPartition, ctx: &StageCtx) -> Result<RouterBuild> {
        let representatives = match &part.representatives {
            Some(r) => r.clone(),
            None => converter::representative_neurons(ctx.profile()?, &part.expert_neurons),
        };
        let compensation = if self.compensation {
            let x = ctx.calib_inputs()?;
            Some(baselines::gmoefication::partition_mean_outputs(ffn, &part.expert_neurons, x))
        } else {
            None
        };
        Ok(RouterBuild {
            router: converter::analytical_router(ffn, &representatives),
            representatives,
            compensation,
        })
    }
}

/// The baselines' trained linear scorer (MoEfication / LLaMA-MoE /
/// EMoE); with `compensation` it is G-MoEfication's router stage.
#[derive(Clone, Debug)]
pub struct TrainedLinearRouterBuilder {
    pub cfg: baselines::router_train::RouterTrainConfig,
    pub compensation: bool,
}

impl RouterBuilder for TrainedLinearRouterBuilder {
    fn build(&self, ffn: &FfnWeights, part: &LayerPartition, ctx: &StageCtx) -> Result<RouterBuild> {
        let x = ctx.calib_inputs()?;
        let w = baselines::train_linear_router(ffn, &part.expert_neurons, x, &self.cfg);
        let compensation = if self.compensation {
            Some(baselines::gmoefication::partition_mean_outputs(ffn, &part.expert_neurons, x))
        } else {
            None
        };
        Ok(RouterBuild { router: Router::Linear(w), representatives: Vec::new(), compensation })
    }
}

/// Read-ME's global (sequence-level) router: expert columns are domain
/// prototypes — the calibration-mean FFN input for the primary domain
/// and its negation for the auxiliary one, cycling over experts, as in
/// the Table 5 ablation.
#[derive(Clone, Debug, Default)]
pub struct GlobalPrototypeRouterBuilder;

impl RouterBuilder for GlobalPrototypeRouterBuilder {
    fn build(&self, _ffn: &FfnWeights, part: &LayerPartition, ctx: &StageCtx) -> Result<RouterBuild> {
        let x = ctx.calib_inputs()?;
        let (q, d) = (x.shape[0], x.shape[1]);
        let mut mean = vec![0.0f32; d];
        for r in 0..q {
            for (m, v) in mean.iter_mut().zip(x.row(r)) {
                *m += v;
            }
        }
        for m in mean.iter_mut() {
            *m /= q as f32;
        }
        let n_r = part.expert_neurons.len();
        let mut w = Tensor::zeros(&[d, n_r]);
        for e in 0..n_r {
            // prototypes cycle: domain 0 = mean, domain 1 = -mean
            let sign = if e % 2 == 0 { 1.0f32 } else { -1.0 };
            for r in 0..d {
                *w.at2_mut(r, e) = sign * mean[r];
            }
        }
        Ok(RouterBuild { router: Router::Linear(w), representatives: Vec::new(), compensation: None })
    }
}
