//! Clustering algorithms for expert construction.
//!
//! * [`balanced_kmeans`] — CMoE's constrained balanced K-means (§A.3):
//!   every cluster gets exactly `m` members; the assignment step is a
//!   Jonker–Volgenant LAP over a cost matrix whose cluster columns are
//!   replicated `m` times. On binary activation columns the L2 distance
//!   is the square root of the Hamming distance (Eq. 19), so this is
//!   co-activation clustering.
//! * [`lloyd_kmeans`] — plain (unbalanced) K-means, used by the
//!   MoEfication / EMoE baselines which cluster *weight* vectors.

use crate::lap::{self, CostMatrix};
use crate::tensor::Tensor;
use crate::util::pool;
use crate::util::Rng;

/// Result of a clustering run over `n` points.
#[derive(Clone, Debug)]
pub struct Clustering {
    /// point -> cluster id
    pub assign: Vec<usize>,
    /// cluster centroids `[k, dim]`
    pub centroids: Tensor,
    /// summed within-cluster squared distance
    pub inertia: f64,
    /// iterations executed
    pub iters: usize,
}

impl Clustering {
    /// Members of each cluster (sorted ascending for determinism).
    pub fn members(&self, k: usize) -> Vec<Vec<usize>> {
        let mut out = vec![Vec::new(); k];
        for (p, &c) in self.assign.iter().enumerate() {
            out[c].push(p);
        }
        out
    }
}

#[inline]
fn sq_dist(a: &[f32], b: &[f32]) -> f64 {
    let mut s = 0.0f64;
    for (x, y) in a.iter().zip(b) {
        let d = (*x - *y) as f64;
        s += d * d;
    }
    s
}

/// Balanced K-means: exactly `n/k` points per cluster (requires `k | n`).
///
/// `points` is `[n, dim]`. Each iteration solves an exact LAP assigning
/// points to `k` clusters × `m` replicated slots, then recomputes
/// centroids (Eq. 20–21). Initial centroids are chosen by the caller-
/// provided `init` indices (CMoE uses the highest-activation-rate
/// remaining neurons; see `converter`).
pub fn balanced_kmeans(
    points: &Tensor,
    k: usize,
    init: &[usize],
    max_iters: usize,
) -> Clustering {
    assert_eq!(points.rank(), 2);
    let n = points.shape[0];
    let dim = points.shape[1];
    assert!(k > 0 && n % k == 0, "balanced_kmeans requires k | n (n={n}, k={k})");
    assert_eq!(init.len(), k, "need k initial centroid indices");
    let m = n / k;

    let mut centroids = points.select_rows(init);
    let mut assign = vec![0usize; n];
    let mut last_inertia = f64::INFINITY;
    let mut iters = 0;

    for it in 0..max_iters {
        iters = it + 1;
        // distance matrix point x cluster (parallel over points)
        let mut dist = vec![0.0f64; n * k];
        {
            let centroids = &centroids;
            pool::par_chunks_mut(&mut dist, k, |p, row| {
                let pt = points.row(p);
                for (c, slot) in row.iter_mut().enumerate() {
                    *slot = sq_dist(pt, centroids.row(c));
                }
            });
        }
        // LAP with replicated columns: column index j maps to cluster j / m.
        // (Costs replicate; we expand lazily through the closure.)
        let cost = CostMatrix::from_fn(n, n, |p, j| dist[p * k + j / m]);
        let sol = lap::solve(&cost);
        let mut new_assign = vec![0usize; n];
        for p in 0..n {
            new_assign[p] = sol.row_to_col[p] / m;
        }

        // centroid update
        let mut new_centroids = Tensor::zeros(&[k, dim]);
        let mut counts = vec![0usize; k];
        for p in 0..n {
            let c = new_assign[p];
            counts[c] += 1;
            let crow = new_centroids.row_mut(c);
            for (d, v) in crow.iter_mut().zip(points.row(p)) {
                *d += *v;
            }
        }
        for c in 0..k {
            debug_assert_eq!(counts[c], m, "balance violated");
            let crow = new_centroids.row_mut(c);
            for v in crow.iter_mut() {
                *v /= m as f32;
            }
        }

        let inertia: f64 = (0..n).map(|p| sq_dist(points.row(p), new_centroids.row(new_assign[p]))).sum();
        let converged = new_assign == assign || (last_inertia - inertia).abs() < 1e-9;
        assign = new_assign;
        centroids = new_centroids;
        last_inertia = inertia;
        if converged {
            break;
        }
    }

    Clustering { assign, centroids, inertia: last_inertia, iters }
}

/// Plain Lloyd K-means with k-means++ initialization. Unbalanced; the
/// MoEfication baseline post-balances by size-capped reassignment.
pub fn lloyd_kmeans(points: &Tensor, k: usize, rng: &mut Rng, max_iters: usize) -> Clustering {
    assert_eq!(points.rank(), 2);
    let n = points.shape[0];
    let dim = points.shape[1];
    assert!(k <= n);

    // k-means++ seeding
    let mut centers: Vec<usize> = vec![rng.below(n)];
    let mut d2: Vec<f64> = (0..n).map(|p| sq_dist(points.row(p), points.row(centers[0]))).collect();
    while centers.len() < k {
        let total: f64 = d2.iter().sum();
        let next = if total <= 0.0 {
            rng.below(n)
        } else {
            let mut t = rng.f64() * total;
            let mut pick = n - 1;
            for (p, &w) in d2.iter().enumerate() {
                t -= w;
                if t <= 0.0 {
                    pick = p;
                    break;
                }
            }
            pick
        };
        centers.push(next);
        for p in 0..n {
            d2[p] = d2[p].min(sq_dist(points.row(p), points.row(next)));
        }
    }
    let mut centroids = points.select_rows(&centers);
    let mut assign = vec![0usize; n];
    let mut inertia = f64::INFINITY;
    let mut iters = 0;

    for it in 0..max_iters {
        iters = it + 1;
        let mut changed = false;
        let mut new_inertia = 0.0f64;
        for p in 0..n {
            let mut best = 0;
            let mut best_d = f64::INFINITY;
            for c in 0..k {
                let d = sq_dist(points.row(p), centroids.row(c));
                if d < best_d {
                    best_d = d;
                    best = c;
                }
            }
            if assign[p] != best {
                changed = true;
                assign[p] = best;
            }
            new_inertia += best_d;
        }
        let mut new_centroids = Tensor::zeros(&[k, dim]);
        let mut counts = vec![0usize; k];
        for p in 0..n {
            counts[assign[p]] += 1;
            let crow = new_centroids.row_mut(assign[p]);
            for (d, v) in crow.iter_mut().zip(points.row(p)) {
                *d += *v;
            }
        }
        for c in 0..k {
            if counts[c] > 0 {
                let crow = new_centroids.row_mut(c);
                for v in crow.iter_mut() {
                    *v /= counts[c] as f32;
                }
            } else {
                // keep previous centroid for empty cluster
                let prev = centroids.row(c).to_vec();
                new_centroids.row_mut(c).copy_from_slice(&prev);
            }
        }
        centroids = new_centroids;
        inertia = new_inertia;
        if !changed {
            break;
        }
    }
    Clustering { assign, centroids, inertia, iters }
}

/// Force a (possibly unbalanced) assignment to exact balance by moving
/// overflow points to their nearest under-full cluster. Used to make the
/// MoEfication/EMoE baselines produce equal-size experts like the paper's
/// setup requires (all methods use N equal experts).
pub fn rebalance(points: &Tensor, clustering: &mut Clustering, k: usize) {
    let n = points.shape[0];
    assert!(n % k == 0);
    let m = n / k;
    let mut counts = vec![0usize; k];
    for &c in &clustering.assign {
        counts[c] += 1;
    }
    // order points within overfull clusters by distance to their centroid
    // (farthest leave first)
    loop {
        let Some(over) = (0..k).find(|&c| counts[c] > m) else { break };
        // farthest member of `over`
        let mut worst_p = usize::MAX;
        let mut worst_d = -1.0f64;
        for p in 0..n {
            if clustering.assign[p] == over {
                let d = sq_dist(points.row(p), clustering.centroids.row(over));
                if d > worst_d {
                    worst_d = d;
                    worst_p = p;
                }
            }
        }
        // nearest under-full cluster
        let mut best_c = usize::MAX;
        let mut best_d = f64::INFINITY;
        for c in 0..k {
            if counts[c] < m {
                let d = sq_dist(points.row(worst_p), clustering.centroids.row(c));
                if d < best_d {
                    best_d = d;
                    best_c = c;
                }
            }
        }
        clustering.assign[worst_p] = best_c;
        counts[over] -= 1;
        counts[best_c] += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Config};

    /// Generate `k` well-separated blobs of `m` points each.
    fn blobs(rng: &mut Rng, k: usize, m: usize, dim: usize, sep: f32) -> (Tensor, Vec<usize>) {
        let n = k * m;
        let mut pts = Tensor::zeros(&[n, dim]);
        let mut truth = vec![0usize; n];
        let centers: Vec<Vec<f32>> =
            (0..k).map(|_| (0..dim).map(|_| rng.normal() * sep).collect()).collect();
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        for (slot, &p) in order.iter().enumerate() {
            let c = slot / m;
            truth[p] = c;
            let row = pts.row_mut(p);
            for (d, v) in row.iter_mut().enumerate() {
                *v = centers[c][d] + 0.05 * rng.normal();
            }
        }
        (pts, truth)
    }

    /// cluster-id permutation-invariant agreement
    fn agreement(a: &[usize], b: &[usize], k: usize) -> f64 {
        // majority mapping a->b
        let mut counts = vec![vec![0usize; k]; k];
        for (&x, &y) in a.iter().zip(b) {
            counts[x][y] += 1;
        }
        let mut hits = 0usize;
        for row in &counts {
            hits += row.iter().max().unwrap();
        }
        hits as f64 / a.len() as f64
    }

    #[test]
    fn balanced_kmeans_exact_balance() {
        let mut rng = Rng::new(2);
        let (pts, _) = blobs(&mut rng, 4, 8, 6, 5.0);
        let init: Vec<usize> = (0..4).collect();
        let cl = balanced_kmeans(&pts, 4, &init, 20);
        let members = cl.members(4);
        for m in &members {
            assert_eq!(m.len(), 8);
        }
    }

    #[test]
    fn balanced_kmeans_recovers_planted_blobs() {
        let mut rng = Rng::new(3);
        let (pts, truth) = blobs(&mut rng, 4, 8, 6, 8.0);
        // init from one true member of each blob for determinism
        let mut init = Vec::new();
        for c in 0..4 {
            init.push(truth.iter().position(|&t| t == c).unwrap());
        }
        let cl = balanced_kmeans(&pts, 4, &init, 30);
        let agr = agreement(&cl.assign, &truth, 4);
        assert!(agr > 0.95, "agreement {agr}");
    }

    #[test]
    fn balanced_kmeans_property_balance_and_permutation() {
        check("balanced-kmeans", Config { cases: 20, max_size: 6, ..Default::default() }, |rng, size| {
            let k = rng.range(1, size.min(4) + 1);
            let m = rng.range(1, 5);
            let dim = rng.range(1, 6);
            let n = k * m;
            let pts = Tensor::randn(rng, &[n, dim], 1.0);
            let init: Vec<usize> = (0..k).collect();
            let cl = balanced_kmeans(&pts, k, &init, 10);
            let members = cl.members(k);
            for mem in &members {
                crate::prop_assert!(mem.len() == m, "imbalanced: {:?}", members.iter().map(|x| x.len()).collect::<Vec<_>>());
            }
            // every point appears exactly once
            let mut all: Vec<usize> = members.into_iter().flatten().collect();
            all.sort_unstable();
            crate::prop_assert!(all == (0..n).collect::<Vec<_>>(), "not a partition");
            Ok(())
        });
    }

    #[test]
    fn lloyd_recovers_blobs() {
        let mut rng = Rng::new(4);
        let (pts, truth) = blobs(&mut rng, 3, 12, 5, 8.0);
        let cl = lloyd_kmeans(&pts, 3, &mut rng, 50);
        let agr = agreement(&cl.assign, &truth, 3);
        assert!(agr > 0.95, "agreement {agr}");
    }

    #[test]
    fn rebalance_fixes_sizes() {
        let mut rng = Rng::new(5);
        let (pts, _) = blobs(&mut rng, 3, 10, 4, 2.0);
        let mut cl = lloyd_kmeans(&pts, 3, &mut rng, 50);
        rebalance(&pts, &mut cl, 3);
        let members = cl.members(3);
        for m in members {
            assert_eq!(m.len(), 10);
        }
    }

    #[test]
    fn binary_vectors_hamming_equivalence() {
        // Eq. 19: squared L2 on binary vectors == Hamming distance
        let a = [1.0f32, 0.0, 1.0, 1.0, 0.0];
        let b = [0.0f32, 0.0, 1.0, 0.0, 1.0];
        let hamming = a.iter().zip(&b).filter(|(x, y)| x != y).count() as f64;
        assert_eq!(sq_dist(&a, &b), hamming);
    }
}
