//! Tensor operations: blocked/threaded matmul, SwiGLU, softmax, top-k,
//! and the allocation-free grouped-dispatch kernels (gather / grouped
//! SwiGLU / scatter-add) the serving engine's expert dispatcher runs on.
//!
//! The matmul uses a cache-blocked i-k-j loop order with 8-wide manual
//! unrolling over j and row-parallelism via `util::pool` — enough to keep
//! the conversion path (seconds, not hours) and the rust-side fine-tuner
//! fast. `cargo bench --bench kernel_bench` reproduces the measured
//! numbers; docs/ARCHITECTURE.md documents the invariants.
//!
//! **Determinism invariant.** The serial row-band kernel [`matmul_rows`]
//! is the single implementation behind [`matmul`], [`matmul_into`] and
//! [`swiglu_rows_into`]: for a given output row, the floating-point
//! accumulation order is fixed (k-blocked, then k-ascending) regardless
//! of how rows are banded across threads or grouped across experts.
//! This is what lets the grouped expert dispatcher promise bit-identical
//! results to the per-token reference path.

use super::Tensor;
use crate::util::pool;

/// `out = a @ b` for 2-D tensors `[m,k] x [k,n] -> [m,n]`.
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.rank(), 2);
    assert_eq!(b.rank(), 2);
    let (m, k) = (a.shape[0], a.shape[1]);
    let (k2, n) = (b.shape[0], b.shape[1]);
    assert_eq!(k, k2, "matmul inner dim mismatch: {k} vs {k2}");
    let mut out = Tensor::zeros(&[m, n]);
    matmul_into(a, b, &mut out);
    out
}

/// `out = a @ b` writing into a preallocated output (hot-loop reuse).
pub fn matmul_into(a: &Tensor, b: &Tensor, out: &mut Tensor) {
    let (m, k) = (a.shape[0], a.shape[1]);
    let n = b.shape[1];
    assert_eq!(out.shape, vec![m, n]);
    out.data.fill(0.0);
    if m == 0 || n == 0 || k == 0 {
        return; // degenerate dims (e.g. an empty shared-expert slice)
    }
    let a_data = &a.data;
    let b_data = &b.data;
    // Row-parallel: each task owns a band of output rows.
    let band = ((m + pool::num_threads() - 1) / pool::num_threads()).max(1);
    pool::par_chunks_mut(&mut out.data, band * n, |band_idx, out_chunk| {
        let row0 = band_idx * band;
        let rows = out_chunk.len() / n;
        matmul_rows(&a_data[row0 * k..(row0 + rows) * k], b_data, out_chunk, k, n);
    });
}

/// Serial cache-blocked matmul over a band of rows:
/// `out[r,:] = a_rows[r,:] @ b` with `a_rows: [rows, k]` and `b: [k, n]`
/// flat row-major. This is the kernel `matmul_into` runs per thread
/// band, exposed so the grouped expert dispatcher can drive its own
/// banding (by tokens-per-expert) while producing bit-identical rows.
// lint: hot-path
pub fn matmul_rows(a_rows: &[f32], b: &[f32], out: &mut [f32], k: usize, n: usize) {
    assert!(k > 0 && n > 0, "matmul_rows: degenerate dims k={k} n={n}");
    debug_assert_eq!(a_rows.len() % k, 0);
    debug_assert_eq!(out.len() % n, 0);
    let rows = a_rows.len() / k;
    debug_assert_eq!(out.len() / n, rows, "matmul_rows: rows mismatch");
    debug_assert_eq!(b.len(), k * n);
    out.fill(0.0);
    // blocked over k for cache reuse
    const KB: usize = 64;
    for kb in (0..k).step_by(KB) {
        let k_end = (kb + KB).min(k);
        for r in 0..rows {
            let a_row = &a_rows[r * k..(r + 1) * k];
            let o_row = &mut out[r * n..(r + 1) * n];
            for kk in kb..k_end {
                let av = a_row[kk];
                if av == 0.0 {
                    continue; // sparse activations: skip zero rows cheaply
                }
                let b_row = &b[kk * n..(kk + 1) * n];
                // 8-wide unroll
                let chunks = n / 8;
                for c in 0..chunks {
                    let j = c * 8;
                    o_row[j] += av * b_row[j];
                    o_row[j + 1] += av * b_row[j + 1];
                    o_row[j + 2] += av * b_row[j + 2];
                    o_row[j + 3] += av * b_row[j + 3];
                    o_row[j + 4] += av * b_row[j + 4];
                    o_row[j + 5] += av * b_row[j + 5];
                    o_row[j + 6] += av * b_row[j + 6];
                    o_row[j + 7] += av * b_row[j + 7];
                }
                for j in chunks * 8..n {
                    o_row[j] += av * b_row[j];
                }
            }
        }
    }
}

/// Serial cache-blocked matmul over a band of rows against a symmetric
/// per-output-column **int8** weight matrix, with the dequantization
/// fused into the accumulation epilogue:
/// `out[r,j] = (Σ_k a_rows[r,k] · q[k,j]) · scales[j]`.
///
/// `q: [k, n]` flat row-major int8, `scales: [n]` per-column. The loop
/// structure (k-blocked, k-ascending, 8-wide unroll over j) matches
/// [`matmul_rows`] exactly, so per-row accumulation order is fixed the
/// same way — quantized expert bands inherit the determinism invariant.
/// The raw `Σ x·q` accumulates in f32 and one scale multiply per output
/// element lands at the end, instead of dequantizing `q` into a scratch
/// matrix first: no f32 copy of the weights ever materializes.
// lint: hot-path
pub fn matmul_rows_q8(a_rows: &[f32], q: &[i8], scales: &[f32], out: &mut [f32], k: usize, n: usize) {
    assert!(k > 0 && n > 0, "matmul_rows_q8: degenerate dims k={k} n={n}");
    debug_assert_eq!(a_rows.len() % k, 0);
    debug_assert_eq!(out.len() % n, 0);
    let rows = a_rows.len() / k;
    debug_assert_eq!(out.len() / n, rows, "matmul_rows_q8: rows mismatch");
    debug_assert_eq!(q.len(), k * n);
    debug_assert_eq!(scales.len(), n);
    out.fill(0.0);
    const KB: usize = 64;
    for kb in (0..k).step_by(KB) {
        let k_end = (kb + KB).min(k);
        for r in 0..rows {
            let a_row = &a_rows[r * k..(r + 1) * k];
            let o_row = &mut out[r * n..(r + 1) * n];
            for kk in kb..k_end {
                let av = a_row[kk];
                if av == 0.0 {
                    continue; // sparse activations: skip zero rows cheaply
                }
                let q_row = &q[kk * n..(kk + 1) * n];
                // 8-wide unroll
                let chunks = n / 8;
                for c in 0..chunks {
                    let j = c * 8;
                    o_row[j] += av * q_row[j] as f32;
                    o_row[j + 1] += av * q_row[j + 1] as f32;
                    o_row[j + 2] += av * q_row[j + 2] as f32;
                    o_row[j + 3] += av * q_row[j + 3] as f32;
                    o_row[j + 4] += av * q_row[j + 4] as f32;
                    o_row[j + 5] += av * q_row[j + 5] as f32;
                    o_row[j + 6] += av * q_row[j + 6] as f32;
                    o_row[j + 7] += av * q_row[j + 7] as f32;
                }
                for j in chunks * 8..n {
                    o_row[j] += av * q_row[j] as f32;
                }
            }
        }
    }
    // fused dequant epilogue: one per-column scale pass
    for r in 0..rows {
        let o_row = &mut out[r * n..(r + 1) * n];
        for (o, &s) in o_row.iter_mut().zip(scales.iter()) {
            *o *= s;
        }
    }
}

/// Naive reference matmul for testing the blocked one.
pub fn matmul_naive(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.shape[0], a.shape[1]);
    let n = b.shape[1];
    let mut out = Tensor::zeros(&[m, n]);
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f32;
            for kk in 0..k {
                acc += a.data[i * k + kk] * b.data[kk * n + j];
            }
            out.data[i * n + j] = acc;
        }
    }
    out
}

/// SiLU / Swish: `x * sigmoid(x)`.
#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Elementwise Swish in place.
pub fn silu_inplace(t: &mut Tensor) {
    for v in t.data.iter_mut() {
        *v = silu(*v);
    }
}

/// Elementwise product in place: `a *= b`.
pub fn mul_inplace(a: &mut Tensor, b: &Tensor) {
    assert_eq!(a.shape, b.shape);
    for (x, y) in a.data.iter_mut().zip(&b.data) {
        *x *= *y;
    }
}

/// `a += b` in place.
pub fn add_inplace(a: &mut Tensor, b: &Tensor) {
    assert_eq!(a.shape, b.shape);
    for (x, y) in a.data.iter_mut().zip(&b.data) {
        *x += *y;
    }
}

/// `a += s * b` in place.
pub fn axpy(a: &mut Tensor, s: f32, b: &Tensor) {
    assert_eq!(a.shape, b.shape);
    for (x, y) in a.data.iter_mut().zip(&b.data) {
        *x += s * *y;
    }
}

/// SwiGLU hidden states: `H = Swish(X @ Wg) ⊙ (X @ Wu)`.
/// `x: [q, d]`, `w_gate/w_up: [d, d_h]` → `[q, d_h]`.
/// This mirrors Eq. (13); the XLA artifact `ffn_hidden` computes the same
/// thing on the compiled path — `tests/artifact_parity.rs` cross-checks.
pub fn swiglu_hidden(x: &Tensor, w_gate: &Tensor, w_up: &Tensor) -> Tensor {
    let mut g = matmul(x, w_gate);
    let u = matmul(x, w_up);
    silu_inplace(&mut g);
    mul_inplace(&mut g, &u);
    g
}

/// Full SwiGLU FFN: `F(x) = H @ Wd` with `w_down: [d_h, d]` (Eq. 3).
pub fn swiglu_ffn(x: &Tensor, w_gate: &Tensor, w_up: &Tensor, w_down: &Tensor) -> Tensor {
    let h = swiglu_hidden(x, w_gate, w_up);
    matmul(&h, w_down)
}

/// Allocation-free grouped SwiGLU over a flat block of rows:
/// `out[r,:] = (Swish(x[r,:] @ Wg) ⊙ (x[r,:] @ Wu)) @ Wd`.
///
/// `x_rows: [rows, d]` flat; `hidden`/`up` are caller-owned scratch of
/// at least `rows * m` (`m` = `w_gate.shape[1]`); `out: [rows, d]` flat.
/// All three GEMMs run through [`matmul_rows`], so each output row is
/// bit-identical to `swiglu_ffn` on the same row — the property the
/// grouped expert dispatcher's parity tests rely on. Serial by design:
/// the caller (dispatcher or pool) owns the parallelism.
// lint: hot-path
pub fn swiglu_rows_into(
    x_rows: &[f32],
    w_gate: &Tensor,
    w_up: &Tensor,
    w_down: &Tensor,
    hidden: &mut [f32],
    up: &mut [f32],
    out: &mut [f32],
) {
    let d = w_gate.shape[0];
    let m = w_gate.shape[1];
    debug_assert_eq!(w_up.shape, [d, m]);
    debug_assert_eq!(w_down.shape, [m, d]);
    debug_assert_eq!(x_rows.len() % d, 0);
    let rows = x_rows.len() / d;
    let (hidden, up) = (&mut hidden[..rows * m], &mut up[..rows * m]);
    let out = &mut out[..rows * d];
    matmul_rows(x_rows, &w_gate.data, hidden, d, m);
    matmul_rows(x_rows, &w_up.data, up, d, m);
    for (h, u) in hidden.iter_mut().zip(up.iter()) {
        *h = silu(*h) * *u;
    }
    matmul_rows(hidden, &w_down.data, out, m, d);
}

/// Gather rows of a 2-D tensor into a flat destination block:
/// `dst[i,:] = src[idx[i],:]`. `dst` must hold `idx.len() * d` floats.
/// This is the dispatch-side gather that builds contiguous per-expert
/// activation blocks out of a wave's token states.
// lint: hot-path
pub fn gather_rows(src: &Tensor, idx: &[usize], dst: &mut [f32]) {
    assert_eq!(src.rank(), 2);
    let d = src.shape[1];
    let dst = &mut dst[..idx.len() * d];
    for (i, &t) in idx.iter().enumerate() {
        dst[i * d..(i + 1) * d].copy_from_slice(src.row(t));
    }
}

/// Scatter-add gate-scaled rows back into a 2-D tensor:
/// `out[idx[i],:] += scale[i] * src[i,:]` for each flat source row, in
/// row order (the combine of gather→GEMM→scatter). Iteration order is
/// part of the contract: rows arrive expert-major from the dispatcher,
/// so a token's expert contributions accumulate in ascending-expert
/// order — the same order `moe_ffn_forward` uses, keeping the two paths
/// bit-identical.
// lint: hot-path
pub fn scatter_add_scaled(src: &[f32], d: usize, idx: &[usize], scale: &[f32], out: &mut Tensor) {
    assert_eq!(out.rank(), 2);
    assert_eq!(out.shape[1], d);
    assert_eq!(idx.len(), scale.len());
    let src = &src[..idx.len() * d];
    for (i, (&t, &g)) in idx.iter().zip(scale.iter()).enumerate() {
        let row = &src[i * d..(i + 1) * d];
        let dst = out.row_mut(t);
        for (o, v) in dst.iter_mut().zip(row) {
            *o += g * v;
        }
    }
}

/// Row-wise softmax in place over the last dim of a 2-D tensor.
pub fn softmax_rows(t: &mut Tensor) {
    assert_eq!(t.rank(), 2);
    let (r, c) = (t.shape[0], t.shape[1]);
    for i in 0..r {
        let row = &mut t.data[i * c..(i + 1) * c];
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
}

/// Softmax of a 1-D slice, returned as a new Vec (used for gate scores
/// `s' = Softmax(s)` in Eq. 9).
pub fn softmax(xs: &[f32]) -> Vec<f32> {
    let max = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = xs.iter().map(|&v| (v - max).exp()).collect();
    let sum: f32 = exps.iter().sum();
    exps.iter().map(|&e| e / sum).collect()
}

/// Indices of the `k` largest values (descending by value; ties broken by
/// lower index for determinism). `O(n log k)` via a small heap-free scan —
/// `k` is tiny (≤ experts) everywhere this is called.
pub fn top_k_indices(xs: &[f32], k: usize) -> Vec<usize> {
    let k = k.min(xs.len());
    let mut best: Vec<usize> = Vec::with_capacity(k + 1);
    for (i, &v) in xs.iter().enumerate() {
        // insert i into the sorted-by-value list if it beats the tail
        let pos = best
            .iter()
            .position(|&b| v > xs[b] || (v == xs[b] && i < b))
            .unwrap_or(best.len());
        if pos < k {
            best.insert(pos, i);
            if best.len() > k {
                best.pop();
            }
        }
    }
    best
}

/// ATopK per row: boolean mask of the top-`k` entries of each row by
/// |value| (§A.2 Step 2). Returns a `[rows, cols]` 0/1 u8 matrix.
pub fn atopk_mask(h: &Tensor, k: usize) -> Vec<u8> {
    assert_eq!(h.rank(), 2);
    let (r, c) = (h.shape[0], h.shape[1]);
    let mut mask = vec![0u8; r * c];
    pool::par_chunks_mut(&mut mask, c, |row_idx, mrow| {
        let hrow = &h.data[row_idx * c..(row_idx + 1) * c];
        let abs: Vec<f32> = hrow.iter().map(|v| v.abs()).collect();
        for i in top_k_indices(&abs, k) {
            mrow[i] = 1;
        }
    });
    mask
}

/// RMSNorm of rows with learned gain `g`: `x / rms(x) * g`.
pub fn rmsnorm_rows(x: &Tensor, g: &[f32], eps: f32) -> Tensor {
    assert_eq!(x.rank(), 2);
    let (r, c) = (x.shape[0], x.shape[1]);
    assert_eq!(g.len(), c);
    let mut out = Tensor::zeros(&[r, c]);
    for i in 0..r {
        let row = x.row(i);
        let ms = row.iter().map(|v| v * v).sum::<f32>() / c as f32;
        let inv = 1.0 / (ms + eps).sqrt();
        let orow = out.row_mut(i);
        for j in 0..c {
            orow[j] = row[j] * inv * g[j];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Config};
    use crate::util::Rng;

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(5);
        for (m, k, n) in [(1, 1, 1), (3, 4, 5), (17, 33, 9), (64, 128, 32)] {
            let a = Tensor::randn(&mut rng, &[m, k], 1.0);
            let b = Tensor::randn(&mut rng, &[k, n], 1.0);
            let fast = matmul(&a, &b);
            let slow = matmul_naive(&a, &b);
            assert!(fast.max_abs_diff(&slow) < 1e-4, "({m},{k},{n})");
        }
    }

    #[test]
    fn matmul_property_random_shapes() {
        check("matmul-vs-naive", Config { cases: 24, max_size: 40, ..Default::default() }, |rng, size| {
            let m = rng.range(1, size + 2);
            let k = rng.range(1, size + 2);
            let n = rng.range(1, size + 2);
            let a = Tensor::randn(rng, &[m, k], 1.0);
            let b = Tensor::randn(rng, &[k, n], 1.0);
            let d = matmul(&a, &b).max_abs_diff(&matmul_naive(&a, &b));
            crate::prop_assert!(d < 1e-3, "diff {d} at ({m},{k},{n})");
            Ok(())
        });
    }

    #[test]
    fn matmul_rows_equals_matmul_any_banding() {
        // the serial band kernel must reproduce matmul_into exactly for
        // every row-band decomposition (bit-for-bit, not approximately)
        let mut rng = Rng::new(51);
        let (m, k, n) = (13, 37, 21);
        let a = Tensor::randn(&mut rng, &[m, k], 1.0);
        let b = Tensor::randn(&mut rng, &[k, n], 1.0);
        let whole = matmul(&a, &b);
        for band in [1usize, 2, 5, 13] {
            let mut out = vec![0.0f32; m * n];
            let mut r0 = 0;
            while r0 < m {
                let rows = band.min(m - r0);
                matmul_rows(
                    &a.data[r0 * k..(r0 + rows) * k],
                    &b.data,
                    &mut out[r0 * n..(r0 + rows) * n],
                    k,
                    n,
                );
                r0 += rows;
            }
            assert_eq!(out, whole.data, "band={band}");
        }
    }

    #[test]
    fn swiglu_rows_into_matches_swiglu_ffn_exactly() {
        let mut rng = Rng::new(52);
        let (rows, d, m) = (7, 12, 20);
        let x = Tensor::randn(&mut rng, &[rows, d], 1.0);
        let wg = Tensor::randn(&mut rng, &[d, m], 0.5);
        let wu = Tensor::randn(&mut rng, &[d, m], 0.5);
        let wd = Tensor::randn(&mut rng, &[m, d], 0.5);
        let want = swiglu_ffn(&x, &wg, &wu, &wd);
        let mut hidden = vec![0.0f32; rows * m];
        let mut up = vec![0.0f32; rows * m];
        let mut out = vec![0.0f32; rows * d];
        swiglu_rows_into(&x.data, &wg, &wu, &wd, &mut hidden, &mut up, &mut out);
        assert_eq!(out, want.data);
        // oversized scratch is fine (the dispatcher reuses one arena)
        let mut hidden2 = vec![9.0f32; rows * m + 64];
        let mut up2 = vec![9.0f32; rows * m + 64];
        let mut out2 = vec![9.0f32; rows * d + 64];
        swiglu_rows_into(&x.data, &wg, &wu, &wd, &mut hidden2, &mut up2, &mut out2);
        assert_eq!(&out2[..rows * d], &want.data[..]);
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let mut rng = Rng::new(53);
        let src = Tensor::randn(&mut rng, &[5, 3], 1.0);
        let idx = [4usize, 0, 4, 2];
        let mut block = vec![0.0f32; idx.len() * 3];
        gather_rows(&src, &idx, &mut block);
        assert_eq!(&block[0..3], src.row(4));
        assert_eq!(&block[3..6], src.row(0));
        // scatter the gathered rows back with gates; token 4 appears
        // twice so it accumulates both contributions
        let mut out = Tensor::zeros(&[5, 3]);
        let gates = [1.0f32, 2.0, 0.5, 1.0];
        scatter_add_scaled(&block, 3, &idx, &gates, &mut out);
        for j in 0..3 {
            assert!((out.at2(4, j) - 1.5 * src.at2(4, j)).abs() < 1e-6);
            assert!((out.at2(0, j) - 2.0 * src.at2(0, j)).abs() < 1e-6);
            assert!((out.at2(2, j) - src.at2(2, j)).abs() < 1e-6);
            assert_eq!(out.at2(1, j), 0.0);
            assert_eq!(out.at2(3, j), 0.0);
        }
    }

    #[test]
    fn silu_values() {
        assert!((silu(0.0) - 0.0).abs() < 1e-7);
        assert!((silu(10.0) - 10.0).abs() < 1e-3); // saturates to identity
        assert!(silu(-10.0).abs() < 1e-3);
    }

    #[test]
    fn swiglu_decomposes_as_neuron_sum() {
        // Eq. (1): F(x) = Σ_i h_i · w_down[i,:]
        let mut rng = Rng::new(6);
        let (d, dh) = (8, 16);
        let x = Tensor::randn(&mut rng, &[3, d], 1.0);
        let wg = Tensor::randn(&mut rng, &[d, dh], 0.5);
        let wu = Tensor::randn(&mut rng, &[d, dh], 0.5);
        let wd = Tensor::randn(&mut rng, &[dh, d], 0.5);
        let full = swiglu_ffn(&x, &wg, &wu, &wd);
        let h = swiglu_hidden(&x, &wg, &wu);
        let mut acc = Tensor::zeros(&[3, d]);
        for i in 0..dh {
            for t in 0..3 {
                for j in 0..d {
                    acc.data[t * d + j] += h.at2(t, i) * wd.at2(i, j);
                }
            }
        }
        assert!(full.max_abs_diff(&acc) < 1e-4);
    }

    #[test]
    fn softmax_sums_to_one() {
        let p = softmax(&[1.0, 2.0, 3.0]);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn top_k_basic_and_ties() {
        assert_eq!(top_k_indices(&[0.1, 5.0, 3.0, 4.0], 2), vec![1, 3]);
        // ties broken by lower index
        assert_eq!(top_k_indices(&[2.0, 2.0, 2.0], 2), vec![0, 1]);
        assert_eq!(top_k_indices(&[1.0], 5), vec![0]);
    }

    #[test]
    fn top_k_property_contains_max() {
        check("topk-max", Config { cases: 64, ..Default::default() }, |rng, size| {
            let n = rng.range(1, size + 2);
            let xs: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let k = rng.range(1, n + 1);
            let top = top_k_indices(&xs, k);
            crate::prop_assert!(top.len() == k.min(n), "wrong count");
            let max_i = (0..n).max_by(|&a, &b| xs[a].partial_cmp(&xs[b]).unwrap()).unwrap();
            crate::prop_assert!(xs[top[0]] == xs[max_i], "first isn't max");
            // returned values are ≥ every excluded value
            let min_in = top.iter().map(|&i| xs[i]).fold(f32::INFINITY, f32::min);
            for i in 0..n {
                if !top.contains(&i) {
                    crate::prop_assert!(xs[i] <= min_in, "excluded {i} beats included");
                }
            }
            Ok(())
        });
    }

    #[test]
    fn atopk_mask_rows_have_k_ones() {
        let mut rng = Rng::new(8);
        let h = Tensor::randn(&mut rng, &[10, 32], 1.0);
        let mask = atopk_mask(&h, 5);
        for r in 0..10 {
            let ones: u32 = mask[r * 32..(r + 1) * 32].iter().map(|&v| v as u32).sum();
            assert_eq!(ones, 5);
        }
    }

    #[test]
    fn atopk_selects_by_magnitude() {
        let h = Tensor::from_vec(vec![0.1, -9.0, 0.2, 8.0], &[1, 4]);
        let mask = atopk_mask(&h, 2);
        assert_eq!(mask, vec![0, 1, 0, 1]);
    }

    #[test]
    fn rmsnorm_unit_rows() {
        let x = Tensor::from_vec(vec![3.0, 4.0], &[1, 2]);
        let out = rmsnorm_rows(&x, &[1.0, 1.0], 1e-6);
        let rms = (out.data.iter().map(|v| v * v).sum::<f32>() / 2.0).sqrt();
        assert!((rms - 1.0).abs() < 1e-4);
    }
}
