//! Dense f32 tensor substrate for the conversion/analysis path **and**
//! the serving engine's grouped expert dispatch.
//!
//! Attention/logits on the serving hot path run through XLA-compiled
//! artifacts ([`crate::runtime`]); this module provides the host-side
//! linear algebra: a contiguous row-major `Tensor`, a blocked+threaded
//! matmul, SwiGLU pieces, softmax/top-k, slicing/gather by neuron
//! index — and the allocation-free dispatch kernels ([`matmul_rows`],
//! [`swiglu_rows_into`], [`gather_rows`], [`scatter_add_scaled`]) whose
//! shared serial band GEMM fixes the floating-point accumulation order,
//! making grouped expert execution bit-identical to the per-token
//! reference (see `serving::dispatch` for the layout invariants).

mod ops;

pub use ops::*;

use crate::util::Rng;
use std::fmt;

/// Contiguous row-major f32 tensor with up to 3 dimensions (the crate
/// never needs more; batch dims are flattened by callers).
#[derive(Clone, PartialEq)]
pub struct Tensor {
    pub data: Vec<f32>,
    pub shape: Vec<usize>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}[", self.shape)?;
        for (i, v) in self.data.iter().take(6).enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{v:.4}")?;
        }
        if self.data.len() > 6 {
            write!(f, ", …")?;
        }
        write!(f, "]")
    }
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Tensor { data: vec![0.0; n], shape: shape.to_vec() }
    }

    pub fn from_vec(data: Vec<f32>, shape: &[usize]) -> Self {
        assert_eq!(data.len(), shape.iter().product::<usize>(), "shape/data mismatch");
        Tensor { data, shape: shape.to_vec() }
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        let n = shape.iter().product();
        Tensor { data: vec![v; n], shape: shape.to_vec() }
    }

    /// i.i.d. normal entries scaled by `std`.
    pub fn randn(rng: &mut Rng, shape: &[usize], std: f32) -> Self {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(&mut t.data, std);
        t
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Rows of a 2-D tensor.
    pub fn rows(&self) -> usize {
        assert_eq!(self.rank(), 2);
        self.shape[0]
    }

    /// Cols of a 2-D tensor.
    pub fn cols(&self) -> usize {
        assert_eq!(self.rank(), 2);
        self.shape[1]
    }

    #[inline]
    pub fn at2(&self, r: usize, c: usize) -> f32 {
        debug_assert_eq!(self.rank(), 2);
        self.data[r * self.shape[1] + c]
    }

    #[inline]
    pub fn at2_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        debug_assert_eq!(self.rank(), 2);
        let c1 = self.shape[1];
        &mut self.data[r * c1 + c]
    }

    /// Borrow row `r` of a 2-D tensor.
    pub fn row(&self, r: usize) -> &[f32] {
        assert_eq!(self.rank(), 2);
        let c = self.shape[1];
        &self.data[r * c..(r + 1) * c]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        assert_eq!(self.rank(), 2);
        let c = self.shape[1];
        &mut self.data[r * c..(r + 1) * c]
    }

    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(self.numel(), shape.iter().product::<usize>(), "reshape numel mismatch");
        self.shape = shape.to_vec();
        self
    }

    /// 2-D transpose (copies).
    pub fn t(&self) -> Tensor {
        assert_eq!(self.rank(), 2);
        let (r, c) = (self.shape[0], self.shape[1]);
        let mut out = Tensor::zeros(&[c, r]);
        for i in 0..r {
            for j in 0..c {
                out.data[j * r + i] = self.data[i * c + j];
            }
        }
        out
    }

    /// Select columns by index (2-D): result is `[rows, idx.len()]`.
    /// This is how expert weight slices are carved out of FFN matrices.
    pub fn select_cols(&self, idx: &[usize]) -> Tensor {
        assert_eq!(self.rank(), 2);
        let (r, c) = (self.shape[0], self.shape[1]);
        let mut out = Tensor::zeros(&[r, idx.len()]);
        for i in 0..r {
            let src = &self.data[i * c..(i + 1) * c];
            let dst = &mut out.data[i * idx.len()..(i + 1) * idx.len()];
            for (k, &j) in idx.iter().enumerate() {
                debug_assert!(j < c, "col index {j} out of {c}");
                dst[k] = src[j];
            }
        }
        out
    }

    /// Select rows by index (2-D): result is `[idx.len(), cols]`.
    pub fn select_rows(&self, idx: &[usize]) -> Tensor {
        assert_eq!(self.rank(), 2);
        let c = self.shape[1];
        let mut out = Tensor::zeros(&[idx.len(), c]);
        for (k, &i) in idx.iter().enumerate() {
            out.row_mut(k).copy_from_slice(self.row(i));
        }
        out
    }

    /// Frobenius norm.
    pub fn norm(&self) -> f32 {
        self.data.iter().map(|v| v * v).sum::<f32>().sqrt()
    }

    /// Max |a - b| between same-shape tensors.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_index() {
        let t = Tensor::from_vec(vec![1., 2., 3., 4., 5., 6.], &[2, 3]);
        assert_eq!(t.at2(0, 2), 3.0);
        assert_eq!(t.at2(1, 0), 4.0);
        assert_eq!(t.row(1), &[4., 5., 6.]);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(1);
        let t = Tensor::randn(&mut rng, &[5, 7], 1.0);
        assert_eq!(t.t().t(), t);
    }

    #[test]
    fn select_cols_carves_slices() {
        let t = Tensor::from_vec((0..12).map(|v| v as f32).collect(), &[3, 4]);
        let s = t.select_cols(&[3, 1]);
        assert_eq!(s.shape, vec![3, 2]);
        assert_eq!(s.row(0), &[3., 1.]);
        assert_eq!(s.row(2), &[11., 9.]);
    }

    #[test]
    fn select_rows_gathers() {
        let t = Tensor::from_vec((0..12).map(|v| v as f32).collect(), &[4, 3]);
        let s = t.select_rows(&[2, 0]);
        assert_eq!(s.shape, vec![2, 3]);
        assert_eq!(s.row(0), &[6., 7., 8.]);
        assert_eq!(s.row(1), &[0., 1., 2.]);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn bad_shape_panics() {
        Tensor::from_vec(vec![1.0; 5], &[2, 3]);
    }

    #[test]
    fn norm_and_diff() {
        let a = Tensor::from_vec(vec![3.0, 4.0], &[2]);
        assert!((a.norm() - 5.0).abs() < 1e-6);
        let b = Tensor::from_vec(vec![3.0, 4.5], &[2]);
        assert!((a.max_abs_diff(&b) - 0.5).abs() < 1e-6);
    }
}
