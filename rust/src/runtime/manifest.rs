//! The artifact manifest (written by `python/compile/aot.py`).

use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// Dtype of an artifact argument.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

/// One argument of an artifact, in call order.
#[derive(Clone, Debug)]
pub struct ArgSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

/// One artifact entry.
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub file: String,
    pub args: Vec<ArgSpec>,
    pub outputs: Vec<String>,
    pub meta: Json,
}

/// The whole manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub artifacts: HashMap<String, ArtifactInfo>,
    pub models: Json,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read {}", path.display()))?;
        let j = Json::parse(&text).context("parse manifest.json")?;
        let mut artifacts = HashMap::new();
        for (name, a) in j.get("artifacts").as_obj().context("artifacts")? {
            let args = a
                .get("args")
                .as_arr()
                .context("args")?
                .iter()
                .map(|arg| ArgSpec {
                    name: arg.get("name").as_str().unwrap_or("?").to_string(),
                    shape: arg
                        .get("shape")
                        .as_arr()
                        .map(|s| s.iter().filter_map(|v| v.as_usize()).collect())
                        .unwrap_or_default(),
                    dtype: if arg.get("dtype").as_str() == Some("i32") {
                        Dtype::I32
                    } else {
                        Dtype::F32
                    },
                })
                .collect();
            let outputs = a
                .get("outputs")
                .as_arr()
                .map(|o| o.iter().filter_map(|v| v.as_str().map(String::from)).collect())
                .unwrap_or_default();
            artifacts.insert(
                name.clone(),
                ArtifactInfo {
                    file: a.get("file").as_str().unwrap_or("").to_string(),
                    args,
                    outputs,
                    meta: a.get("meta").clone(),
                },
            );
        }
        Ok(Manifest { artifacts, models: j.get("models").clone() })
    }

    /// Batch buckets available for a (family, model) pair, ascending —
    /// e.g. `decode_dense_small_b{B}_t{T}`. Used by the batcher.
    pub fn batch_buckets(&self, prefix: &str) -> Vec<usize> {
        let mut out: Vec<usize> = self
            .artifacts
            .values()
            .filter_map(|a| {
                let name = &a.file;
                if name.starts_with(prefix) {
                    a.meta.get("batch").as_usize()
                } else {
                    None
                }
            })
            .collect();
        out.sort_unstable();
        out.dedup();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_manifest() {
        let dir = std::env::temp_dir().join("cmoe_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("manifest.json");
        std::fs::write(
            &path,
            r#"{
              "version": 1,
              "models": {"tiny": {"d_model": 64}},
              "artifacts": {
                "decode_dense_tiny_b1_t128": {
                  "file": "decode_dense_tiny_b1_t128.hlo.txt",
                  "args": [
                    {"name": "embed", "shape": [256, 64], "dtype": "f32"},
                    {"name": "pos", "shape": [], "dtype": "i32"}
                  ],
                  "outputs": ["logits", "kv"],
                  "meta": {"batch": 1, "model": "tiny"}
                }
              }
            }"#,
        )
        .unwrap();
        let m = Manifest::load(&path).unwrap();
        let a = &m.artifacts["decode_dense_tiny_b1_t128"];
        assert_eq!(a.args.len(), 2);
        assert_eq!(a.args[0].shape, vec![256, 64]);
        assert_eq!(a.args[0].dtype, Dtype::F32);
        assert_eq!(a.args[1].dtype, Dtype::I32);
        assert_eq!(a.outputs, vec!["logits", "kv"]);
        assert_eq!(m.models.get("tiny").get("d_model").as_usize(), Some(64));
        assert_eq!(m.batch_buckets("decode_dense_tiny"), vec![1]);
    }
}
