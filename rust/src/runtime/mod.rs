//! PJRT runtime: loads the AOT HLO-text artifacts (`make artifacts`)
//! and executes them on the CPU PJRT client. Python never runs here.
//!
//! Key design points:
//! * **HLO text interchange** — `HloModuleProto::from_text_file`
//!   re-assigns instruction ids, sidestepping the 64-bit-id proto
//!   incompatibility between jax ≥ 0.5 and xla_extension 0.5.1.
//! * **Weights upload once** — artifacts take weights as arguments;
//!   [`ModelBuffers`] caches weight `PjRtBuffer`s per model so the hot
//!   loop only uploads activations (`execute_b`).
//! * **Executable cache** — each artifact is compiled on first use and
//!   memoized (compilation is tens of ms; decode steps are sub-ms).

mod manifest;
mod bindings;
mod kv_pool;
mod pages;

pub use bindings::{ModelBuffers, MoeModelBuffers};
pub use kv_pool::{KvPoolError, KvSlotPool, ParkedSlot};
pub use manifest::{ArgSpec, ArtifactInfo, Manifest};
pub use pages::PagePool;

use crate::tensor::Tensor;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

/// Handle to the PJRT client + artifact registry.
pub struct XlaRuntime {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    dir: PathBuf,
    cache: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
}

impl XlaRuntime {
    /// Open an artifact directory (must contain `manifest.json`).
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(XlaRuntime { client, manifest, dir, cache: Mutex::new(HashMap::new()) })
    }

    /// Artifact names available.
    pub fn artifact_names(&self) -> Vec<String> {
        self.manifest.artifacts.keys().cloned().collect()
    }

    pub fn has_artifact(&self, name: &str) -> bool {
        self.manifest.artifacts.contains_key(name)
    }

    /// Compile (or fetch the cached) executable for an artifact.
    pub fn executable(&self, name: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = crate::util::lock_unpoisoned(&self.cache).get(name) {
            return Ok(exe.clone());
        }
        let info = self
            .manifest
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?;
        let path = self.dir.join(&info.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        let exe = Arc::new(exe);
        crate::util::lock_unpoisoned(&self.cache).insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Precompile a set of artifacts (warm-up before serving).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.executable(n)?;
        }
        Ok(())
    }

    // ---- host <-> device transfers ------------------------------------

    /// Upload an f32 tensor.
    pub fn upload(&self, t: &Tensor) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(&t.data, &t.shape, None)
            .map_err(|e| anyhow!("upload: {e:?}"))
    }

    /// Upload a raw f32 slice with an explicit shape.
    pub fn upload_f32(&self, data: &[f32], shape: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, shape, None)
            .map_err(|e| anyhow!("upload_f32: {e:?}"))
    }

    /// Upload i32 data (token ids, positions).
    pub fn upload_i32(&self, data: &[i32], shape: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, shape, None)
            .map_err(|e| anyhow!("upload_i32: {e:?}"))
    }

    /// Download a buffer into a [`Tensor`] with the given shape.
    pub fn download(&self, buf: &xla::PjRtBuffer, shape: &[usize]) -> Result<Tensor> {
        let lit = buf.to_literal_sync().map_err(|e| anyhow!("to_literal: {e:?}"))?;
        let data: Vec<f32> = lit.to_vec().map_err(|e| anyhow!("to_vec: {e:?}"))?;
        if data.len() != shape.iter().product::<usize>() {
            bail!("download: {} elements but shape {:?}", data.len(), shape);
        }
        Ok(Tensor::from_vec(data, shape))
    }

    /// Execute an artifact on device buffers. The jax-lowered modules
    /// return a tuple; PJRT untuples it, so element `k` of the result is
    /// the k-th output (single replica).
    pub fn execute(
        &self,
        name: &str,
        args: &[&xla::PjRtBuffer],
    ) -> Result<Vec<xla::PjRtBuffer>> {
        let exe = self.executable(name)?;
        let info = &self.manifest.artifacts[name];
        if args.len() != info.args.len() {
            bail!(
                "artifact '{name}' wants {} args, got {} — arg order: {:?}",
                info.args.len(),
                args.len(),
                info.args.iter().map(|a| a.name.as_str()).collect::<Vec<_>>()
            );
        }
        let mut out = exe.execute_b(args).map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        if out.is_empty() {
            bail!("execute {name}: no replica output");
        }
        Ok(out.swap_remove(0))
    }

    /// Execute with host literals (slow path: uploads everything).
    pub fn execute_literals(
        &self,
        name: &str,
        args: &[xla::Literal],
    ) -> Result<Vec<xla::PjRtBuffer>> {
        let exe = self.executable(name)?;
        let mut out = exe.execute(args).map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        if out.is_empty() {
            bail!("execute {name}: no replica output");
        }
        Ok(out.swap_remove(0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// These tests need `make artifacts` to have run; they are skipped
    /// (not failed) otherwise so `cargo test` works on a fresh clone.
    fn runtime() -> Option<XlaRuntime> {
        let dir = crate::test_artifact_dir()?;
        XlaRuntime::load(dir).ok()
    }

    #[test]
    fn manifest_lists_tiny_artifacts() {
        let Some(rt) = runtime() else { return };
        assert!(rt.has_artifact("ffn_hidden_tiny_q128"));
        assert!(rt.has_artifact("decode_dense_tiny_b1_t128"));
    }

    #[test]
    fn ffn_hidden_artifact_matches_rust_tensor_math() {
        let Some(rt) = runtime() else { return };
        let mut rng = crate::util::Rng::new(301);
        let d = 64;
        let dh = 256; // tiny config
        let x = Tensor::randn(&mut rng, &[128, d], 1.0);
        let wg = Tensor::randn(&mut rng, &[d, dh], 0.3);
        let wu = Tensor::randn(&mut rng, &[d, dh], 0.3);
        let bufs =
            [rt.upload(&x).unwrap(), rt.upload(&wg).unwrap(), rt.upload(&wu).unwrap()];
        let refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        let out = rt.execute("ffn_hidden_tiny_q128", &refs).unwrap();
        let got = rt.download(&out[0], &[128, dh]).unwrap();
        let want = crate::tensor::swiglu_hidden(&x, &wg, &wu);
        assert!(got.max_abs_diff(&want) < 1e-3, "diff {}", got.max_abs_diff(&want));
    }

    #[test]
    fn wrong_arg_count_is_reported() {
        let Some(rt) = runtime() else { return };
        let b = rt.upload_i32(&[0], &[1]).unwrap();
        let err = match rt.execute("ffn_hidden_tiny_q128", &[&b]) {
            Err(e) => e,
            Ok(_) => panic!("expected arg-count error"),
        };
        assert!(err.to_string().contains("wants 3 args"));
    }

    #[test]
    fn unknown_artifact_errors() {
        let Some(rt) = runtime() else { return };
        assert!(rt.executable("no_such_artifact").is_err());
    }
}
