//! Reference-counted pool of fixed-size KV pages — the allocator under
//! the paged [`crate::runtime::KvSlotPool`].
//!
//! A page holds `page_len` tokens of KV for every `[L, 2, H, hd]`
//! plane, laid out `[L, 2, H, page_len, hd]` row-major. The pool is a
//! pure allocator: it knows nothing about slots, prompts or caches —
//! policy (page tables, prefix sharing, eviction) lives in the slot
//! pool and `serving::prefix_cache`. That separation is what makes the
//! allocator exhaustively property-testable (`tests/page_pool.rs`).
//!
//! Invariants (property-tested):
//! * a page's refcount equals the number of live mappings holding it
//!   (slot page tables + prefix-cache holds + parked tables — a
//!   preempted slot's detached [`crate::runtime::ParkedSlot`] keeps
//!   its references, so parked KV can never be recycled underneath a
//!   victim awaiting resume);
//! * `release` on the last reference returns the page to the free
//!   list; a page is never double-freed (refcount underflow panics);
//! * allocation hands out **zeroed** pages — recycled or fresh — so a
//!   recycled page can never leak stale KV into a new slot (this
//!   supersedes the old slot pool's "prefill overwrites everything"
//!   discipline, which page-granular ownership can no longer rely on);
//! * writes through [`PagePool::try_page_mut`] copy-on-write: a page
//!   mapped by more than one holder is copied before the first
//!   divergent write, so shared prefix pages are immutable from any
//!   single mapper's point of view;
//! * `high_water_pages` (most pages resident at once) is monotone.

/// Reference-counted fixed-size page allocator.
pub struct PagePool {
    page_len: usize,
    page_elems: usize,
    /// Hard page budget (`None` = grow on demand, host-only stubs).
    max_pages: Option<usize>,
    /// Page storage; index = page id. Never shrinks (freed pages are
    /// recycled through `free`).
    data: Vec<Vec<f32>>,
    /// Live references per page id; 0 = free.
    refcount: Vec<u32>,
    /// Free-list (LIFO — recycled pages are reused before fresh ones,
    /// same warmth argument as the scheduler's slot stack).
    free: Vec<usize>,
    /// Most pages resident at once (monotone memory gauge).
    pub high_water_pages: usize,
    /// Copy-on-write page copies performed so far.
    pub cow_copies: u64,
    /// Total successful allocations (fresh + recycled).
    pub total_allocs: u64,
}

impl PagePool {
    /// `page_elems` is the element count of one page
    /// (`layers * 2 * heads * page_len * head_dim` for a KV pool).
    pub fn new(page_len: usize, page_elems: usize, max_pages: Option<usize>) -> PagePool {
        assert!(page_len >= 1, "page_len 0 is not a page");
        assert!(page_elems >= 1, "empty pages");
        PagePool {
            page_len,
            page_elems,
            max_pages,
            data: Vec::new(),
            refcount: Vec::new(),
            free: Vec::new(),
            high_water_pages: 0,
            cow_copies: 0,
            total_allocs: 0,
        }
    }

    pub fn page_len(&self) -> usize {
        self.page_len
    }

    pub fn page_elems(&self) -> usize {
        self.page_elems
    }

    /// Pages currently referenced by at least one holder.
    pub fn pages_in_use(&self) -> usize {
        self.data.len() - self.free.len()
    }

    /// Pages ever allocated (backing storage footprint).
    pub fn pages_allocated(&self) -> usize {
        self.data.len()
    }

    /// Pages allocatable right now without anyone releasing
    /// (`None` = unbounded).
    pub fn available(&self) -> Option<usize> {
        self.max_pages.map(|cap| cap.saturating_sub(self.pages_in_use()))
    }

    pub fn capacity(&self) -> Option<usize> {
        self.max_pages
    }

    pub fn refcount(&self, page: usize) -> u32 {
        self.refcount[page]
    }

    /// Allocate a zeroed page with refcount 1, or `None` when the
    /// budget is exhausted (callers evict prefix-cache holds and
    /// retry — see `serving::prefix_cache`).
    pub fn try_alloc(&mut self) -> Option<usize> {
        let page = if let Some(p) = self.free.pop() {
            // the stale-KV guarantee: recycled pages are zeroed before
            // they can be mapped again
            self.data[p].fill(0.0);
            self.refcount[p] = 1;
            p
        } else {
            if let Some(cap) = self.max_pages {
                if self.data.len() >= cap {
                    return None;
                }
            }
            self.data.push(vec![0.0; self.page_elems]);
            self.refcount.push(1);
            self.data.len() - 1
        };
        self.total_allocs += 1;
        self.high_water_pages = self.high_water_pages.max(self.pages_in_use());
        Some(page)
    }

    /// Add a reference (a second holder maps the page).
    pub fn retain(&mut self, page: usize) {
        assert!(self.refcount[page] > 0, "pages: retain on a free page {page}");
        self.refcount[page] += 1;
    }

    /// Drop a reference; the last release frees the page.
    pub fn release(&mut self, page: usize) {
        assert!(self.refcount[page] > 0, "pages: double free of page {page}");
        self.refcount[page] -= 1;
        if self.refcount[page] == 0 {
            self.free.push(page);
        }
    }

    /// Read-only view of a live page.
    pub fn page(&self, page: usize) -> &[f32] {
        assert!(self.refcount[page] > 0, "pages: read of a free page {page}");
        &self.data[page]
    }

    /// Mutable view with copy-on-write: a shared page (refcount > 1)
    /// is copied first and `entry` repointed at the private copy, so
    /// the other holders keep the original bytes. `None` when a copy
    /// was needed but the pool is exhausted.
    pub fn try_page_mut(&mut self, entry: &mut usize) -> Option<&mut [f32]> {
        let p = *entry;
        assert!(self.refcount[p] > 0, "pages: write to a free page {p}");
        if self.refcount[p] > 1 {
            let n = self.try_alloc()?;
            // split the storage borrow by temporarily moving the
            // destination page out (a Vec move, not a copy)
            let mut dst = std::mem::take(&mut self.data[n]);
            dst.copy_from_slice(&self.data[p]);
            self.data[n] = dst;
            self.release(p);
            self.cow_copies += 1;
            *entry = n;
        }
        Some(&mut self.data[*entry])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_release_recycles_zeroed() {
        let mut pool = PagePool::new(4, 8, None);
        let a = pool.try_alloc().unwrap();
        {
            let mut e = a;
            let view = pool.try_page_mut(&mut e).unwrap();
            view.iter_mut().for_each(|x| *x = 7.0);
            assert_eq!(e, a, "private page must not COW");
        }
        pool.release(a);
        assert_eq!(pool.pages_in_use(), 0);
        let b = pool.try_alloc().unwrap();
        assert_eq!(b, a, "LIFO recycling");
        assert!(pool.page(b).iter().all(|&x| x == 0.0), "recycled page leaked stale data");
        assert_eq!(pool.high_water_pages, 1);
        assert_eq!(pool.total_allocs, 2);
    }

    #[test]
    fn cow_preserves_the_shared_original() {
        let mut pool = PagePool::new(2, 4, None);
        let p = pool.try_alloc().unwrap();
        {
            let mut e = p;
            pool.try_page_mut(&mut e).unwrap().copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        }
        pool.retain(p); // second holder
        let mut entry = p;
        {
            let view = pool.try_page_mut(&mut entry).unwrap();
            view[0] = 9.0;
        }
        assert_ne!(entry, p, "divergent write must COW");
        assert_eq!(pool.refcount(p), 1);
        assert_eq!(pool.refcount(entry), 1);
        assert_eq!(pool.page(p), &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(pool.page(entry), &[9.0, 2.0, 3.0, 4.0]);
        assert_eq!(pool.cow_copies, 1);
    }

    #[test]
    fn budget_is_enforced() {
        let mut pool = PagePool::new(2, 2, Some(2));
        let a = pool.try_alloc().unwrap();
        let _b = pool.try_alloc().unwrap();
        assert!(pool.try_alloc().is_none(), "over budget");
        assert_eq!(pool.available(), Some(0));
        pool.release(a);
        assert_eq!(pool.available(), Some(1));
        assert!(pool.try_alloc().is_some());
        // a COW under exhaustion reports failure instead of corrupting
        let mut e = 1usize;
        pool.retain(1);
        assert!(pool.try_page_mut(&mut e).is_none());
        assert_eq!(e, 1, "failed COW must leave the mapping untouched");
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut pool = PagePool::new(2, 2, None);
        let a = pool.try_alloc().unwrap();
        pool.release(a);
        pool.release(a);
    }
}
