//! Weight-buffer bindings: upload a [`ModelWeights`] to the PJRT device
//! once, in exactly the argument order the artifacts expect
//! (`aot.py`'s sorted-name convention), and keep the buffers alive for
//! the serving engine's hot loop.

use crate::model::{LayerFfn, ModelWeights, Router};
use crate::runtime::XlaRuntime;
use crate::tensor::Tensor;
use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Dense-model parameter buffers in sorted-name order (matches
/// `aot.py::dense_param_names`). BTreeMap iteration is byte-lexicographic,
/// identical to python's `sorted()` on ASCII names.
pub struct ModelBuffers {
    pub named: BTreeMap<String, xla::PjRtBuffer>,
}

impl ModelBuffers {
    /// Upload all dense parameters of a model. MoE layers contribute
    /// zero-filled placeholders for the (unused) dense FFN slots only if
    /// `fill_ffn_zeros` — the dense artifacts need those args, the MoE
    /// artifacts don't reference them.
    pub fn from_model(rt: &XlaRuntime, model: &ModelWeights) -> Result<ModelBuffers> {
        let mut named = BTreeMap::new();
        let mut up = |name: String, t: &Tensor| -> Result<()> {
            named.insert(name, rt.upload(t)?);
            Ok(())
        };
        up("embed".into(), &model.embed)?;
        up("pos".into(), &model.pos)?;
        up("final_norm".into(), &vec1(&model.final_norm))?;
        up("unembed".into(), &model.unembed)?;
        for (l, layer) in model.layers.iter().enumerate() {
            let p = format!("layers.{l}");
            up(format!("{p}.attn_norm"), &vec1(&layer.attn_norm))?;
            up(format!("{p}.ffn_norm"), &vec1(&layer.ffn_norm))?;
            up(format!("{p}.attn.wq"), &layer.attn.wq)?;
            up(format!("{p}.attn.wk"), &layer.attn.wk)?;
            up(format!("{p}.attn.wv"), &layer.attn.wv)?;
            up(format!("{p}.attn.wo"), &layer.attn.wo)?;
            if let LayerFfn::Dense(f) = &layer.ffn {
                up(format!("{p}.ffn.w_gate"), &f.w_gate)?;
                up(format!("{p}.ffn.w_up"), &f.w_up)?;
                up(format!("{p}.ffn.w_down"), &f.w_down)?;
            }
        }
        Ok(ModelBuffers { named })
    }

    /// Buffers in sorted order, followed by `extra` (runtime inputs).
    pub fn args_with<'a>(&'a self, extra: &[&'a xla::PjRtBuffer]) -> Vec<&'a xla::PjRtBuffer> {
        self.named.values().chain(extra.iter().copied()).collect()
    }

    pub fn get(&self, name: &str) -> Option<&xla::PjRtBuffer> {
        self.named.get(name)
    }

    /// Required lookup: a missing parameter buffer is a model/artifact
    /// mismatch, reported as an error instead of a process panic.
    pub fn req(&self, name: &str) -> Result<&xla::PjRtBuffer> {
        self.named.get(name).ok_or_else(|| anyhow::anyhow!("missing dense parameter buffer: {name}"))
    }
}

fn vec1(v: &[f32]) -> Tensor {
    Tensor::from_vec(v.to_vec(), &[v.len()])
}

/// Stacked MoE-layer buffers in sorted-name order (matches
/// `aot.py::moe_param_names`): per layer,
/// `moe.{l}.{bias, experts.w_down, experts.w_gate, experts.w_up,
/// router.w_gate_r, router.w_up_r, scale, shared.w_down, shared.w_gate,
/// shared.w_up}`.
pub struct MoeModelBuffers {
    pub named: BTreeMap<String, xla::PjRtBuffer>,
}

impl MoeModelBuffers {
    pub fn from_model(rt: &XlaRuntime, model: &ModelWeights) -> Result<MoeModelBuffers> {
        let mut named = BTreeMap::new();
        for (l, layer) in model.layers.iter().enumerate() {
            let LayerFfn::Moe(moe) = &layer.ffn else {
                bail!("layer {l} is not MoE — convert the model first");
            };
            let Router::Analytical(rw) = &moe.router else {
                bail!("layer {l}: monolithic MoE artifacts need the analytical router");
            };
            let p = format!("moe.{l}");
            let n_r = moe.experts.len();
            let d = moe.shared.w_gate.shape[0];
            let m = moe.experts[0].hidden_dim();
            // stack experts: [Nr, d, m] / [Nr, m, d]
            let stack = |f: &dyn Fn(usize) -> Tensor, shape: &[usize]| -> Tensor {
                let mut out = Tensor::zeros(shape);
                let per = shape[1] * shape[2];
                for e in 0..n_r {
                    let t = f(e);
                    out.data[e * per..(e + 1) * per].copy_from_slice(&t.data);
                }
                out
            };
            let ew_g = stack(&|e| moe.experts[e].w_gate.clone(), &[n_r, d, m]);
            let ew_u = stack(&|e| moe.experts[e].w_up.clone(), &[n_r, d, m]);
            let ew_d = stack(&|e| moe.experts[e].w_down.clone(), &[n_r, m, d]);
            named.insert(format!("{p}.experts.w_gate"), rt.upload(&ew_g)?);
            named.insert(format!("{p}.experts.w_up"), rt.upload(&ew_u)?);
            named.insert(format!("{p}.experts.w_down"), rt.upload(&ew_d)?);
            named.insert(format!("{p}.shared.w_gate"), rt.upload(&moe.shared.w_gate)?);
            named.insert(format!("{p}.shared.w_up"), rt.upload(&moe.shared.w_up)?);
            named.insert(format!("{p}.shared.w_down"), rt.upload(&moe.shared.w_down)?);
            named.insert(format!("{p}.router.w_gate_r"), rt.upload(&rw.w_gate_r)?);
            named.insert(format!("{p}.router.w_up_r"), rt.upload(&rw.w_up_r)?);
            named.insert(format!("{p}.scale"), rt.upload(&vec1(&moe.gate_scale))?);
            named.insert(format!("{p}.bias"), rt.upload(&vec1(&moe.gate_bias))?);
        }
        Ok(MoeModelBuffers { named })
    }

    pub fn get(&self, name: &str) -> Option<&xla::PjRtBuffer> {
        self.named.get(name)
    }

    /// Required lookup: a missing parameter buffer is a model/artifact
    /// mismatch, reported as an error instead of a process panic.
    pub fn req(&self, name: &str) -> Result<&xla::PjRtBuffer> {
        self.named.get(name).ok_or_else(|| anyhow::anyhow!("missing MoE parameter buffer: {name}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::model_config;
    use crate::util::Rng;

    #[test]
    fn dense_buffer_names_match_aot_convention() {
        // the exact arg-order contract with aot.py: sorted names
        let cfg = model_config("tiny").unwrap();
        let mut rng = Rng::new(311);
        let model = ModelWeights::random(&cfg, &mut rng);
        // build name list without uploading (no runtime needed)
        let mut names = vec![
            "embed".to_string(),
            "pos".into(),
            "final_norm".into(),
            "unembed".into(),
        ];
        for l in 0..cfg.n_layers {
            let p = format!("layers.{l}");
            names.push(format!("{p}.attn_norm"));
            names.push(format!("{p}.ffn_norm"));
            for w in ["wq", "wk", "wv", "wo"] {
                names.push(format!("{p}.attn.{w}"));
            }
            for w in ["w_gate", "w_up", "w_down"] {
                names.push(format!("{p}.ffn.{w}"));
            }
        }
        names.sort();
        // expected python sort: layers.0.attn.wk < layers.0.attn.wo < wq < wv
        let i = names.iter().position(|n| n == "layers.0.attn.wk").unwrap();
        assert_eq!(names[i + 1], "layers.0.attn.wo");
        assert_eq!(names[i + 2], "layers.0.attn.wq");
        assert_eq!(names[i + 3], "layers.0.attn.wv");
        let _ = model;
    }
}
